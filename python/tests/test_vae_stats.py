"""Statistical quality gates on the trained VAE artifacts.

These run only when artifacts/weights_vae.npz exists (i.e. after
`make artifacts`); they assert the properties the compression experiment
relies on: the estimator is discriminative on average (density-ratio
signal > 0) and the decoder reconstructs better with the true latent than
with a prior draw.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import digits, train, vae

WEIGHTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "weights_vae.npz")

pytestmark = pytest.mark.skipif(
    not os.path.exists(WEIGHTS), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def params():
    return train.unflatten_params(dict(np.load(WEIGHTS)))


@pytest.fixture(scope="module")
def images():
    return digits.synthetic_digits(150, seed=999)


def test_estimator_discriminative_on_average(params, images):
    rng = np.random.default_rng(0)
    matched, mismatched = [], []
    for i in range(len(images)):
        src = digits.right_half(images[i])[None]
        mu, _ = vae.encode(params, jnp.asarray(src))
        cx, cy = int(rng.integers(0, 8)), int(rng.integers(0, 22))
        feat = vae.project(params, jnp.asarray(digits.left_crop(images[i], cx, cy)[None]))
        j = (i + 7) % len(images)
        feat_j = vae.project(params, jnp.asarray(digits.left_crop(images[j], cx, cy)[None]))
        matched.append(float(vae.estimate(params, mu, feat)[0]))
        mismatched.append(float(vae.estimate(params, mu, feat_j)[0]))
    m, mm = np.mean(matched), np.mean(mismatched)
    assert m > mm, f"estimator not discriminative: matched {m:.4f} <= mismatched {mm:.4f}"
    win = np.mean(np.array(matched) > np.array(mismatched))
    assert win > 0.5, f"win rate {win:.2f}"


def test_decoder_prefers_true_latent(params, images):
    rng = np.random.default_rng(1)
    err_true, err_prior = [], []
    for i in range(60):
        src = digits.right_half(images[i])
        mu, _ = vae.encode(params, jnp.asarray(src[None]))
        feat = vae.project(params, jnp.asarray(digits.left_crop(images[i], 3, 10)[None]))
        recon_true = np.asarray(vae.decode(params, mu, feat))[0]
        w_prior = jnp.asarray(rng.standard_normal((1, 4)), jnp.float32)
        recon_prior = np.asarray(vae.decode(params, w_prior, feat))[0]
        err_true.append(((recon_true - src) ** 2).mean())
        err_prior.append(((recon_prior - src) ** 2).mean())
    assert np.mean(err_true) < np.mean(err_prior), (
        f"true-latent recon {np.mean(err_true):.4f} not better than prior "
        f"{np.mean(err_prior):.4f}"
    )


def test_encoder_latents_roughly_standard(params, images):
    # KL regularization should keep aggregate latents near N(0, 1).
    mus = []
    for i in range(100):
        mu, _ = vae.encode(params, jnp.asarray(digits.right_half(images[i])[None]))
        mus.append(np.asarray(mu)[0])
    mus = np.stack(mus)
    assert np.all(np.abs(mus.mean(axis=0)) < 1.0), mus.mean(axis=0)
    assert np.all(mus.std(axis=0) < 3.0), mus.std(axis=0)
