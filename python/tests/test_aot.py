"""AOT interchange contract (python half).

The HLO *text* written by aot.py must parse back through XLA's HLO parser
(the identical code path `HloModuleProto::from_text_file` uses in the Rust
runtime), preserve entry-signature shapes, and embed large constants
(weights) rather than eliding them. Numeric equivalence of the executed
artifact against eager JAX is asserted from the Rust side
(rust/tests/runtime_artifacts.rs), where the real consumer lives.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import model as m
from compile.aot import to_hlo_text
from compile.kernels.gls import gls_select

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def parse(text: str):
    return xc._xla.hlo_module_from_text(text)


class TestHloTextContract:
    def test_simple_fn_parses_and_is_stable(self):
        def fn(x, y):
            return (jnp.matmul(x, y) + 1.0,)

        spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
        text = to_hlo_text(jax.jit(fn).lower(spec, spec))
        assert "HloModule" in text
        mod = parse(text)
        # Stability: parse → print → parse round-trips.
        text2 = mod.to_string()
        assert "HloModule" in text2
        parse(text2)

    def test_lm_logits_export_embeds_weights(self):
        cfg = m.LmConfig(d_model=32, n_heads=2, n_layers=1, max_seq=12)
        params = m.init_params(cfg, jax.random.PRNGKey(0))
        spec = jax.ShapeDtypeStruct((2, 12), jnp.int32)
        text = to_hlo_text(
            jax.jit(lambda t: (m.lm_logits(params, t, cfg, use_pallas=True),)).lower(spec)
        )
        parse(text)
        # Weights must be embedded, not elided as "constant({...})".
        assert "constant({...})" not in text
        # Embedding table is 259×32 ≈ 8k floats: the text must be large.
        assert len(text) > 100_000, f"suspiciously small export: {len(text)} chars"
        # Single entry parameter: the token array (nested reduce bodies
        # have their own parameter(1)s, so restrict to the ENTRY block).
        entry = text[text.index("ENTRY"):]
        assert "parameter(0)" in entry
        assert "parameter(1)" not in entry

    def test_gls_select_export_parses(self):
        k, n = 2, 64
        spec = jax.ShapeDtypeStruct((k, n), jnp.float32)
        text = to_hlo_text(jax.jit(lambda u, q, p: gls_select(u, q, p)).lower(spec, spec, spec))
        parse(text)
        assert "parameter(2)" in text  # u, q, p

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ARTIFACTS, "manifest.txt")),
        reason="run `make artifacts` first",
    )
    def test_shipped_artifacts_parse(self):
        manifest = open(os.path.join(ARTIFACTS, "manifest.txt")).read()
        names = [
            line.split("=")[1].strip()
            for line in manifest.splitlines()
            if line.strip() and not line.startswith("#") and line.split("=")[1].strip().endswith(".hlo.txt")
        ]
        assert len(names) >= 8, names
        for name in names:
            text = open(os.path.join(ARTIFACTS, name)).read()
            parse(text)
            assert "constant({...})" not in text, f"{name} has elided constants"


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
