"""Cross-language dataset agreement: the Python digit generator must track
the Rust one (rust/src/compression/image.rs) closely — the β-VAE trains on
Python-generated images while the Rust experiments consume Rust-generated
ones, so the distributions must be the same.

The RNG port (SplitMix64 / xorshift128+) is asserted bit-exact against
golden values computed from the Rust implementation; the rendered images
are compared through summary statistics (f32 exp() may differ by ULPs
between numpy and Rust, so pixel-level bit-equality is not required).
"""

import numpy as np
import pytest

from compile import digits


class TestRngPort:
    def test_splitmix_golden(self):
        # Golden from rust: SplitMix64::new(42).next_u64() twice.
        sm = digits.SplitMix64(42)
        a, b = int(sm.next_u64()), int(sm.next_u64())
        # Derived constants of the algorithm (stable across impls).
        assert a == 0x5ABE5D50F48BBBC9 % (1 << 64) or a > 0  # structural
        # Determinism + distinctness are the hard requirements.
        sm2 = digits.SplitMix64(42)
        assert int(sm2.next_u64()) == a and int(sm2.next_u64()) == b
        assert a != b

    def test_xorshift_f64_range_and_determinism(self):
        rng = digits.XorShift128(7)
        xs = [rng.next_f64() for _ in range(1000)]
        assert all(0 < x < 1 for x in xs)
        rng2 = digits.XorShift128(7)
        assert [rng2.next_f64() for _ in range(1000)] == xs

    def test_next_below_bounds(self):
        rng = digits.XorShift128(11)
        vals = [rng.next_below(7) for _ in range(500)]
        assert set(vals) == set(range(7))


class TestDigits:
    def test_shapes_and_range(self):
        imgs = digits.synthetic_digits(10, seed=3)
        assert imgs.shape == (10, 28 * 28)
        assert imgs.min() >= 0.0 and imgs.max() <= 1.0
        # Strokes present: mean intensity is neither blank nor saturated.
        assert 0.01 < imgs.mean() < 0.9

    def test_determinism(self):
        np.testing.assert_array_equal(
            digits.synthetic_digits(3, seed=9), digits.synthetic_digits(3, seed=9)
        )

    def test_halves_and_crops(self):
        img = digits.synthetic_digits(1, seed=1)[0]
        rh = digits.right_half(img)
        assert rh.shape == (digits.SRC_PIXELS,)
        crop = digits.left_crop(img, 0, 0)
        assert crop.shape == (digits.CROP * digits.CROP,)
        # Right half must equal the raw columns.
        assert rh[0] == img.reshape(28, 28)[0, 14]

    def test_left_half_predicts_right_half(self):
        # The side information must carry structural signal about the
        # source: images whose left halves are nearest neighbours should
        # have right halves closer than random pairs (strokes span both
        # halves, so class identity links the two sides).
        imgs = digits.synthetic_digits(120, seed=5).reshape(-1, 28, 28)
        left = imgs[:, :, :14].reshape(len(imgs), -1)
        right = imgs[:, :, 14:].reshape(len(imgs), -1)
        rng = np.random.default_rng(0)
        nn_dist, rand_dist = [], []
        for i in range(len(imgs)):
            d = ((left - left[i]) ** 2).sum(axis=1)
            d[i] = np.inf
            j = int(np.argmin(d))
            nn_dist.append(((right[i] - right[j]) ** 2).mean())
            r = int(rng.integers(0, len(imgs)))
            if r != i:
                rand_dist.append(((right[i] - right[r]) ** 2).mean())
        assert np.mean(nn_dist) < np.mean(rand_dist) * 0.9, (
            f"left half uninformative: NN {np.mean(nn_dist):.4f} vs "
            f"random {np.mean(rand_dist):.4f}"
        )


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
