"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and distribution shapes; every property is also
pinned by a couple of deterministic cases so failures localize fast.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import causal_attention, decode_attention
from compile.kernels.gls import gls_select

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def random_case(seed, k, n, sparse=False):
    rng = np.random.default_rng(seed)
    u = rng.uniform(1e-6, 1 - 1e-6, (k, n)).astype(np.float32)
    q = rng.dirichlet(np.ones(n) * 0.5, k).astype(np.float32)
    p = rng.dirichlet(np.ones(n) * 0.5, k).astype(np.float32)
    if sparse:
        # Zero out a random half of the support (renormalized).
        mask = rng.uniform(size=(k, n)) < 0.5
        mask[:, 0] = True  # keep at least one symbol
        q = np.where(mask, q, 0)
        p = np.where(mask, p, 0)
        q = q / q.sum(axis=1, keepdims=True)
        p = p / p.sum(axis=1, keepdims=True)
    return jnp.asarray(u), jnp.asarray(q), jnp.asarray(p)


class TestGlsSelect:
    @given(
        seed=st.integers(0, 10_000),
        k=st.integers(1, 8),
        n=st.integers(2, 300),
        block=st.sampled_from([16, 64, 128]),
    )
    def test_matches_reference_argmins(self, seed, k, n, block):
        u, q, p = random_case(seed, k, n)
        y, xs = gls_select(u, q, p, block_n=block)
        yr, xsr = ref.gls_select_ref(u, q, p)
        assert int(y) == int(yr)
        np.testing.assert_array_equal(np.asarray(xs), np.asarray(xsr))

    @given(seed=st.integers(0, 10_000), k=st.integers(1, 4), n=st.integers(4, 64))
    def test_sparse_support_never_selects_zero_mass(self, seed, k, n):
        u, q, p = random_case(seed, k, n, sparse=True)
        y, xs = gls_select(u, q, p)
        # Y must have q-mass in at least one draft row; X[k] must have p-mass.
        assert float(jnp.max(q[:, int(y)])) > 0
        for kk in range(k):
            assert float(p[kk, int(xs[kk])]) > 0

    def test_block_size_invariance(self):
        u, q, p = random_case(7, 4, 200)
        outs = [gls_select(u, q, p, block_n=b) for b in (16, 32, 128, 256)]
        base_y, base_xs = outs[0]
        for y, xs in outs[1:]:
            assert int(y) == int(base_y)
            np.testing.assert_array_equal(np.asarray(xs), np.asarray(base_xs))

    def test_identical_p_q_rows_match(self):
        # p == q with K = 1 ⇒ the two races are identical ⇒ X == Y.
        for seed in range(20):
            u, q, _ = random_case(seed, 1, 37)
            y, xs = gls_select(u, q, q)
            assert int(y) == int(xs[0])

    def test_gumbel_max_marginal_statistics(self):
        # The kernel is the sampler: empirical marginal of X^(0) follows p.
        n = 8
        rng = np.random.default_rng(3)
        p_row = rng.dirichlet(np.ones(n)).astype(np.float32)
        counts = np.zeros(n)
        trials = 3000
        us = rng.uniform(1e-6, 1 - 1e-6, (trials, 1, n)).astype(np.float32)
        for t in range(trials):
            _, xs = gls_select(
                jnp.asarray(us[t]), jnp.asarray(p_row[None]), jnp.asarray(p_row[None]),
            )
            counts[int(xs[0])] += 1
        freq = counts / trials
        np.testing.assert_allclose(freq, p_row, atol=0.04)


class TestDecodeAttention:
    @given(
        seed=st.integers(0, 10_000),
        h=st.integers(1, 4),
        s=st.integers(2, 100),
        d=st.sampled_from([8, 16, 32]),
        block=st.sampled_from([16, 64]),
    )
    def test_matches_reference(self, seed, h, s, d, block):
        rng = np.random.default_rng(seed)
        q = rng.standard_normal((h, d)).astype(np.float32)
        kc = rng.standard_normal((h, s, d)).astype(np.float32)
        vc = rng.standard_normal((h, s, d)).astype(np.float32)
        length = int(rng.integers(1, s + 1))
        out = decode_attention(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), length, block_s=block)
        expect = ref.decode_attention_ref(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), length)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-4)

    def test_length_one_attends_only_first(self):
        rng = np.random.default_rng(1)
        h, s, d = 2, 10, 8
        q = rng.standard_normal((h, d)).astype(np.float32)
        kc = rng.standard_normal((h, s, d)).astype(np.float32)
        vc = rng.standard_normal((h, s, d)).astype(np.float32)
        out = decode_attention(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc), 1)
        np.testing.assert_allclose(np.asarray(out), vc[:, 0], atol=1e-5)


class TestCausalAttention:
    @given(seed=st.integers(0, 10_000), h=st.integers(1, 4), s=st.integers(2, 48))
    def test_matches_jnp_softmax_attention(self, seed, h, s):
        d = 16
        rng = np.random.default_rng(seed)
        q = rng.standard_normal((h, s, d)).astype(np.float32)
        k = rng.standard_normal((h, s, d)).astype(np.float32)
        v = rng.standard_normal((h, s, d)).astype(np.float32)
        out = causal_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
        # jnp reference
        scale = 1.0 / np.sqrt(d)
        logits = np.einsum("hqd,hkd->hqk", q, k) * scale
        mask = np.tril(np.ones((s, s), bool))
        logits = np.where(mask[None], logits, -1e30)
        w = np.exp(logits - logits.max(-1, keepdims=True))
        w /= w.sum(-1, keepdims=True)
        expect = np.einsum("hqk,hkd->hqd", w, v)
        np.testing.assert_allclose(np.asarray(out), expect, atol=2e-4)

    def test_first_position_is_value_passthrough(self):
        rng = np.random.default_rng(5)
        h, s, d = 2, 6, 8
        q = rng.standard_normal((h, s, d)).astype(np.float32)
        k = rng.standard_normal((h, s, d)).astype(np.float32)
        v = rng.standard_normal((h, s, d)).astype(np.float32)
        out = causal_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
        np.testing.assert_allclose(np.asarray(out)[:, 0], v[:, 0], atol=1e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
