"""L2 model correctness: shapes, Pallas/jnp agreement, KV-step vs full
forward, loss behaviour, VAE stack, corpus generator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus as corpus_lib
from compile import model as m
from compile import vae as v

SMALL = m.LmConfig(d_model=32, n_heads=2, n_layers=2, max_seq=20)


@pytest.fixture(scope="module")
def params():
    return m.init_params(SMALL, jax.random.PRNGKey(0))


def toks(b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, m.VOCAB, (b, s)), jnp.int32)


class TestTransformer:
    def test_logits_shape_and_finiteness(self, params):
        out = m.lm_logits(params, toks(3, 20), SMALL)
        assert out.shape == (3, 20, m.VOCAB)
        assert bool(jnp.isfinite(out).all())

    def test_pallas_and_jnp_paths_agree(self, params):
        t = toks(2, 20, seed=3)
        a = m.lm_logits(params, t, SMALL, use_pallas=True)
        b = m.lm_logits(params, t, SMALL, use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)

    def test_causality(self, params):
        # Changing a future token must not affect earlier logits.
        t1 = toks(1, 20, seed=1)
        t2 = t1.at[0, 15].set((t1[0, 15] + 1) % m.VOCAB)
        a = m.lm_logits(params, t1, SMALL, use_pallas=False)
        b = m.lm_logits(params, t2, SMALL, use_pallas=False)
        np.testing.assert_allclose(np.asarray(a)[0, :15], np.asarray(b)[0, :15], atol=1e-5)
        assert not np.allclose(np.asarray(a)[0, 15:], np.asarray(b)[0, 15:], atol=1e-5)

    def test_kv_step_matches_full_forward(self, params):
        t = toks(1, 12, seed=2)
        kv = m.init_kv(SMALL)
        last = None
        for pos in range(12):
            last, kv = m.lm_step(params, kv, t[0, pos], pos, SMALL)
        full = m.lm_logits(params, t, SMALL, use_pallas=False)
        np.testing.assert_allclose(np.asarray(last), np.asarray(full)[0, -1], atol=1e-3)

    def test_loss_decreases_with_one_adam_ish_step(self, params):
        from compile import train as train_lib

        batch = toks(4, 20, seed=9)
        loss0, grads = jax.value_and_grad(lambda p: m.lm_loss(p, batch, SMALL))(params)
        opt = train_lib.adam_init(params)
        p2, _ = train_lib.adam_step(params, grads, opt, lr=3e-3)
        loss1 = m.lm_loss(p2, batch, SMALL)
        assert float(loss1) < float(loss0)

    def test_pad_masked_out_of_loss(self, params):
        base = toks(1, 20, seed=4)
        with_pad = base.at[0, 10:].set(258)
        l_full = m.lm_loss(params, base, SMALL)
        l_pad = m.lm_loss(params, with_pad, SMALL)
        assert np.isfinite(float(l_pad))
        assert float(l_pad) != float(l_full)


class TestVae:
    def test_shapes(self):
        cfg = v.VaeConfig()
        p = v.init_params(cfg, jax.random.PRNGKey(1))
        src = jnp.zeros((5, cfg.src))
        side = jnp.zeros((5, cfg.side))
        mu, lv = v.encode(p, src)
        assert mu.shape == (5, cfg.latent) and lv.shape == (5, cfg.latent)
        assert bool((lv <= 2.0).all()) and bool((lv >= -6.0).all())
        feat = v.project(p, side)
        assert feat.shape == (5, cfg.feat)
        assert v.estimate(p, mu, feat).shape == (5,)
        recon = v.decode(p, mu, feat)
        assert recon.shape == (5, cfg.src)
        assert bool((recon >= 0).all()) and bool((recon <= 1).all())

    def test_loss_components_positive(self):
        cfg = v.VaeConfig()
        p = v.init_params(cfg, jax.random.PRNGKey(2))
        rng = np.random.default_rng(0)
        src = jnp.asarray(rng.uniform(0, 1, (8, cfg.src)), jnp.float32)
        side = jnp.asarray(rng.uniform(0, 1, (8, cfg.side)), jnp.float32)
        loss, aux = v.vae_loss(p, src, side, jax.random.PRNGKey(3), cfg)
        assert float(loss) > 0
        assert float(aux["recon"]) > 0 and float(aux["kl"]) >= 0 and float(aux["bce"]) > 0


class TestCorpus:
    def test_deterministic_and_ascii(self):
        a = corpus_lib.build_corpus(50, seed=3)
        b = corpus_lib.build_corpus(50, seed=3)
        assert a == b
        assert all(c < 128 for c in a)

    def test_batches_shapes_and_bos(self):
        c = corpus_lib.build_corpus(200, seed=0)
        for batch in corpus_lib.batches(c, batch=4, seq=32, steps=3):
            assert batch.shape == (4, 32)
            assert (batch[:, 0] == corpus_lib.BOS).all()
            assert batch.max() < corpus_lib.VOCAB


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
