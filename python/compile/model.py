"""L2: the JAX transformer language model (draft + target pair).

A small decoder-only transformer with learned positional embeddings,
pre-norm blocks and a full-context forward. The attention inside is the
L1 Pallas kernel (`kernels.attention.causal_attention`), so lowering
`lm_logits` bakes the kernel into the exported HLO.

Exported entrypoints (see aot.py):
  lm_logits(params, tokens i32[B, S]) -> logits f32[B, S, V]
  lm_step(params, kv, token, pos)     -> single-token decode with explicit
                                         KV cache, using the tiled
                                         decode_attention kernel (the TPU
                                         serving path; the CPU PJRT backend
                                         prefers full recompute, DESIGN.md).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.attention import causal_attention, decode_attention

VOCAB = 259  # 256 bytes + BOS/EOS/PAD — must match rust tokenizer.rs


@dataclasses.dataclass(frozen=True)
class LmConfig:
    vocab: int = VOCAB
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    max_seq: int = 96

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


TARGET_CONFIG = LmConfig(d_model=128, n_heads=4, n_layers=4)
DRAFT_CONFIG = LmConfig(d_model=64, n_heads=4, n_layers=2)


def init_params(cfg: LmConfig, key):
    """Initialize transformer parameters (dict pytree)."""
    keys = jax.random.split(key, 4 + 8 * cfg.n_layers)
    it = iter(keys)
    scale = lambda d: 1.0 / jnp.sqrt(jnp.float32(d))
    params = {
        "tok_emb": jax.random.normal(next(it), (cfg.vocab, cfg.d_model)) * 0.02,
        "pos_emb": jax.random.normal(next(it), (cfg.max_seq, cfg.d_model)) * 0.02,
        "ln_f": jnp.ones((cfg.d_model,)),
        "head": jax.random.normal(next(it), (cfg.d_model, cfg.vocab)) * scale(cfg.d_model),
        "layers": [],
    }
    _ = next(it)
    for _layer in range(cfg.n_layers):
        params["layers"].append(
            {
                "ln1": jnp.ones((cfg.d_model,)),
                "wq": jax.random.normal(next(it), (cfg.d_model, cfg.d_model)) * scale(cfg.d_model),
                "wk": jax.random.normal(next(it), (cfg.d_model, cfg.d_model)) * scale(cfg.d_model),
                "wv": jax.random.normal(next(it), (cfg.d_model, cfg.d_model)) * scale(cfg.d_model),
                "wo": jax.random.normal(next(it), (cfg.d_model, cfg.d_model)) * scale(cfg.d_model),
                "ln2": jnp.ones((cfg.d_model,)),
                "w1": jax.random.normal(next(it), (cfg.d_model, 4 * cfg.d_model)) * scale(cfg.d_model),
                "w2": jax.random.normal(next(it), (4 * cfg.d_model, cfg.d_model)) * scale(4 * cfg.d_model),
            }
        )
    return params


def _rmsnorm(x, g):
    return x * g / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _block(layer, x, cfg: LmConfig, use_pallas: bool):
    # x: [S, D]
    s = x.shape[0]
    h = _rmsnorm(x, layer["ln1"])
    q = (h @ layer["wq"]).reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    k = (h @ layer["wk"]).reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    v = (h @ layer["wv"]).reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)
    if use_pallas:
        o = causal_attention(q, k, v)  # [H, S, Dh]
    else:
        scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_head))
        logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
        row = jnp.arange(s)[None, :, None]
        col = jnp.arange(s)[None, None, :]
        logits = jnp.where(col <= row, logits, -jnp.float32(1e30))
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("hqk,hkd->hqd", w, v)
    o = o.transpose(1, 0, 2).reshape(s, cfg.d_model)
    x = x + o @ layer["wo"]
    h = _rmsnorm(x, layer["ln2"])
    x = x + jax.nn.gelu(h @ layer["w1"]) @ layer["w2"]
    return x


def lm_logits(params, tokens, cfg: LmConfig, use_pallas: bool = True):
    """Full-context forward: tokens i32[B, S] -> logits f32[B, S, V]."""
    b, s = tokens.shape

    def one(row):
        x = params["tok_emb"][row] + params["pos_emb"][:s]
        for layer in params["layers"]:
            x = _block(layer, x, cfg, use_pallas)
        x = _rmsnorm(x, params["ln_f"])
        return x @ params["head"]

    return jax.vmap(one)(tokens)


# ---------------------------------------------------------------------------
# Explicit-KV single-step decode (the TPU serving path).
# ---------------------------------------------------------------------------


def init_kv(cfg: LmConfig):
    """Empty KV cache: (k, v) each f32[L, H, S, Dh]."""
    shape = (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def lm_step(params, kv, token, pos, cfg: LmConfig):
    """Decode one token with the Pallas decode_attention kernel.

    Args:
      kv: (k, v) caches f32[L, H, S, Dh]; `pos` i32 scalar — current length.
      token: i32 scalar — the token at position `pos`.

    Returns: (logits f32[V], new_kv).
    """
    kc, vc = kv
    x = params["tok_emb"][token] + params["pos_emb"][pos]
    new_k, new_v = [], []
    for li, layer in enumerate(params["layers"]):
        h = _rmsnorm(x, layer["ln1"])
        q = (h @ layer["wq"]).reshape(cfg.n_heads, cfg.d_head)
        k = (h @ layer["wk"]).reshape(cfg.n_heads, cfg.d_head)
        v = (h @ layer["wv"]).reshape(cfg.n_heads, cfg.d_head)
        kc_l = jax.lax.dynamic_update_index_in_dim(kc[li], k, pos, axis=1)
        vc_l = jax.lax.dynamic_update_index_in_dim(vc[li], v, pos, axis=1)
        new_k.append(kc_l)
        new_v.append(vc_l)
        o = decode_attention(q, kc_l, vc_l, pos + 1)  # Pallas tiled kernel
        x = x + o.reshape(cfg.d_model) @ layer["wo"]
        h = _rmsnorm(x, layer["ln2"])
        x = x + jax.nn.gelu(h @ layer["w1"]) @ layer["w2"]
    x = _rmsnorm(x, params["ln_f"])
    logits = x @ params["head"]
    return logits, (jnp.stack(new_k), jnp.stack(new_v))


@functools.partial(jax.jit, static_argnames=("cfg", "use_pallas"))
def lm_loss(params, tokens, cfg: LmConfig, use_pallas: bool = False):
    """Next-token cross-entropy with PAD (=258) masked out of the loss."""
    logits = lm_logits(params, tokens[:, :-1], cfg, use_pallas)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != 258).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
