"""L2: the β-VAE latent codec networks (paper App. D.3, miniaturized).

Four networks, mirroring Table 7's roles with MLP bodies sized for the
28×28 synthetic-digit dataset and CPU training (DESIGN.md §2):

  encoder   : source half [392] -> (mu [4], logvar [4])   == p_{W|A}
  projection: side crop   [49]  -> feature [32]
  estimator : (w [4], feat [32]) -> logit                  ∝ log p_{W|T}/p_W
  decoder   : (w [4], feat [32]) -> reconstruction [392]

The estimator is trained as a joint-vs-marginal classifier (BCE), so its
pre-sigmoid logit estimates the density log-ratio — exactly the decoder
weight the GLS codec needs (density-ratio trick, as in Phan et al.).
"""

import dataclasses

import jax
import jax.numpy as jnp

SRC = 392
SIDE = 49
LATENT = 4
FEAT = 32


@dataclasses.dataclass(frozen=True)
class VaeConfig:
    src: int = SRC
    side: int = SIDE
    latent: int = LATENT
    feat: int = FEAT
    enc_hidden: int = 128
    proj_hidden: int = 64
    est_hidden: int = 64
    dec_hidden: int = 256
    beta: float = 0.35


def _dense(key, n_in, n_out):
    w = jax.random.normal(key, (n_in, n_out)) * jnp.sqrt(2.0 / n_in)
    return {"w": w, "b": jnp.zeros((n_out,))}


def init_params(cfg: VaeConfig, key):
    ks = jax.random.split(key, 10)
    return {
        "enc1": _dense(ks[0], cfg.src, cfg.enc_hidden),
        "enc_mu": _dense(ks[1], cfg.enc_hidden, cfg.latent),
        "enc_lv": _dense(ks[2], cfg.enc_hidden, cfg.latent),
        "proj1": _dense(ks[3], cfg.side, cfg.proj_hidden),
        "proj2": _dense(ks[4], cfg.proj_hidden, cfg.feat),
        "est1": _dense(ks[5], cfg.latent + cfg.feat, cfg.est_hidden),
        "est2": _dense(ks[6], cfg.est_hidden, 1),
        "dec1": _dense(ks[7], cfg.latent + cfg.feat, cfg.dec_hidden),
        "dec2": _dense(ks[8], cfg.dec_hidden, cfg.src),
    }


def _mlp(x, layers, act=jax.nn.relu):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i + 1 < len(layers):
            x = act(x)
    return x


def encode(params, source):
    """source f32[B, 392] -> (mu f32[B, 4], logvar f32[B, 4])."""
    h = jax.nn.relu(source @ params["enc1"]["w"] + params["enc1"]["b"])
    mu = h @ params["enc_mu"]["w"] + params["enc_mu"]["b"]
    lv = h @ params["enc_lv"]["w"] + params["enc_lv"]["b"]
    # Clamp logvar for stability (encoder target must stay a proper density).
    return mu, jnp.clip(lv, -6.0, 2.0)


def project(params, side):
    """side f32[B, 49] -> feat f32[B, 32]."""
    return _mlp(side, [params["proj1"], params["proj2"]])


def estimate(params, w, feat):
    """(w f32[B, 4], feat f32[B, 32]) -> logit f32[B]."""
    x = jnp.concatenate([w, feat], axis=-1)
    return _mlp(x, [params["est1"], params["est2"]])[..., 0]


def decode(params, w, feat):
    """(w f32[B, 4], feat f32[B, 32]) -> recon f32[B, 392] in (0, 1)."""
    x = jnp.concatenate([w, feat], axis=-1)
    return jax.nn.sigmoid(_mlp(x, [params["dec1"], params["dec2"]]))


def vae_loss(params, source, side, key, cfg: VaeConfig):
    """Joint objective: β-VAE ELBO + estimator BCE.

    The reparameterized latent w ~ N(mu, σ²) feeds the decoder alongside
    the projected side features; the estimator classifies (w, feat) joint
    pairs against shuffled (w, feat') marginal pairs.
    """
    mu, lv = encode(params, source)
    eps = jax.random.normal(key, mu.shape)
    w = mu + jnp.exp(0.5 * lv) * eps
    feat = project(params, side)

    recon = decode(params, w, feat)
    recon_loss = jnp.mean(jnp.sum((recon - source) ** 2, axis=-1))
    kl = 0.5 * jnp.mean(jnp.sum(jnp.exp(lv) + mu**2 - 1.0 - lv, axis=-1))

    # Estimator: positives (aligned) vs negatives (rolled batch).
    pos_logit = estimate(params, w, feat)
    neg_logit = estimate(params, w, jnp.roll(feat, 1, axis=0))
    bce = jnp.mean(jax.nn.softplus(-pos_logit)) + jnp.mean(jax.nn.softplus(neg_logit))

    return recon_loss + cfg.beta * kl + bce, {
        "recon": recon_loss,
        "kl": kl,
        "bce": bce,
    }
