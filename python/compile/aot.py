"""AOT export: train (or load cached) weights, lower every entrypoint to
HLO text, write artifacts/ + manifest.txt.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 (behind the Rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import train as train_lib
from . import vae as vae_lib

LM_BATCH = 8
LM_MAX_SEQ = 96
GLS_K = 4
GLS_N = model_lib.VOCAB


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {os.path.basename(path)} ({len(text) / 1e6:.2f} MB)")


def load_or_train_lm(out_dir, name, cfg, steps, seed):
    cache = os.path.join(out_dir, f"weights_{name}.npz")
    if os.path.exists(cache):
        print(f"[{name}] loading cached weights {cache}")
        flat = dict(np.load(cache))
        return train_lib.unflatten_params(flat)
    params, _ = train_lib.train_lm(cfg, steps, seed, name)
    np.savez(cache, **train_lib.flatten_params(params))
    return params


def load_or_train_vae(out_dir, cfg, steps, seed):
    cache = os.path.join(out_dir, "weights_vae.npz")
    if os.path.exists(cache):
        print(f"[vae] loading cached weights {cache}")
        return train_lib.unflatten_params(dict(np.load(cache)))
    params, _ = train_lib.train_vae(cfg, steps, seed)
    np.savez(cache, **train_lib.flatten_params(params))
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--lm-steps", type=int, default=int(os.environ.get("GLS_LM_STEPS", 300)))
    ap.add_argument("--vae-steps", type=int, default=int(os.environ.get("GLS_VAE_STEPS", 600)))
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    t0 = time.time()

    # ------------------------------------------------------------------ LMs
    target_cfg = model_lib.TARGET_CONFIG
    draft_cfg = model_lib.DRAFT_CONFIG
    target_params = load_or_train_lm(out, "target", target_cfg, args.lm_steps, seed=0)
    draft_params = load_or_train_lm(out, "draft", draft_cfg, args.lm_steps, seed=1)

    tokens_spec = jax.ShapeDtypeStruct((LM_BATCH, LM_MAX_SEQ), jnp.int32)

    print("[aot] lowering LM forwards (Pallas causal attention inside)")
    export(
        lambda toks: (model_lib.lm_logits(target_params, toks, target_cfg, use_pallas=True),),
        (tokens_spec,),
        os.path.join(out, "target_lm.hlo.txt"),
    )
    export(
        lambda toks: (model_lib.lm_logits(draft_params, toks, draft_cfg, use_pallas=True),),
        (tokens_spec,),
        os.path.join(out, "draft_lm.hlo.txt"),
    )

    # Single-step decode with explicit KV cache (Pallas decode_attention).
    print("[aot] lowering lm_step (explicit-KV decode)")
    kv_spec = jax.ShapeDtypeStruct(
        (target_cfg.n_layers, target_cfg.n_heads, target_cfg.max_seq, target_cfg.d_head),
        jnp.float32,
    )
    export(
        lambda kc, vc, tok, pos: model_lib.lm_step(
            target_params, (kc, vc), tok, pos, target_cfg
        ),
        (
            kv_spec,
            kv_spec,
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
        ),
        os.path.join(out, "target_lm_step.hlo.txt"),
    )

    # ------------------------------------------------------------ GLS kernel
    print("[aot] lowering gls_select (Pallas)")
    from .kernels.gls import gls_select

    grid_spec = jax.ShapeDtypeStruct((GLS_K, GLS_N), jnp.float32)
    export(
        lambda u, q, p: gls_select(u, q, p),
        (grid_spec, grid_spec, grid_spec),
        os.path.join(out, "gls_select.hlo.txt"),
    )

    # ------------------------------------------------------------------ VAE
    vae_cfg = vae_lib.VaeConfig()
    vae_params = load_or_train_vae(out, vae_cfg, args.vae_steps, seed=2)

    print("[aot] lowering VAE stack")
    export(
        lambda s: vae_lib.encode(vae_params, s),
        (jax.ShapeDtypeStruct((1, vae_cfg.src), jnp.float32),),
        os.path.join(out, "vae_encode.hlo.txt"),
    )
    export(
        lambda s: (vae_lib.project(vae_params, s),),
        (jax.ShapeDtypeStruct((1, vae_cfg.side), jnp.float32),),
        os.path.join(out, "vae_project.hlo.txt"),
    )
    export(
        lambda w, f: (vae_lib.estimate(vae_params, w, f),),
        (
            jax.ShapeDtypeStruct((1, vae_cfg.latent), jnp.float32),
            jax.ShapeDtypeStruct((1, vae_cfg.feat), jnp.float32),
        ),
        os.path.join(out, "vae_estimate.hlo.txt"),
    )
    export(
        lambda w, f: (vae_lib.decode(vae_params, w, f),),
        (
            jax.ShapeDtypeStruct((1, vae_cfg.latent), jnp.float32),
            jax.ShapeDtypeStruct((1, vae_cfg.feat), jnp.float32),
        ),
        os.path.join(out, "vae_decode.hlo.txt"),
    )

    # -------------------------------------------------------------- manifest
    manifest = f"""# generated by python/compile/aot.py
vocab = {model_lib.VOCAB}
lm_batch = {LM_BATCH}
lm_max_seq = {LM_MAX_SEQ}
target_lm = target_lm.hlo.txt
draft_lm = draft_lm.hlo.txt
target_lm_step = target_lm_step.hlo.txt
gls_select = gls_select.hlo.txt
gls_k = {GLS_K}
gls_n = {GLS_N}
vae_encode = vae_encode.hlo.txt
vae_project = vae_project.hlo.txt
vae_estimate = vae_estimate.hlo.txt
vae_decode = vae_decode.hlo.txt
vae_latent = {vae_cfg.latent}
vae_feat_dim = {vae_cfg.feat}
vae_src_pixels = {vae_cfg.src}
vae_side_pixels = {vae_cfg.side}
"""
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write(manifest)
    print(f"[aot] done in {time.time() - t0:.0f}s -> {out}/manifest.txt")


if __name__ == "__main__":
    main()
