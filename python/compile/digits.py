"""Bit-exact Python port of the Rust synthetic-digit generator
(rust/src/compression/image.rs) and its RNG (rust/src/stats/rng.rs).

The β-VAE trains on the *same distribution* (same bits, in fact) that the
Rust compression experiments consume — the cross-language agreement is
asserted by python/tests/test_cross_language.py against golden values.
"""

import numpy as np

MASK = np.uint64(0xFFFFFFFFFFFFFFFF)

IMG = 28
HALF_W = 14
CROP = 7
SRC_PIXELS = IMG * HALF_W


class SplitMix64:
    def __init__(self, seed: int):
        self.state = np.uint64(seed)

    def next_u64(self) -> np.uint64:
        with np.errstate(over="ignore"):
            self.state = (self.state + np.uint64(0x9E3779B97F4A7C15)) & MASK
            z = self.state
            z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & MASK
            z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & MASK
            return z ^ (z >> np.uint64(31))


class XorShift128:
    """xorshift128+ matching rust/src/stats/rng.rs exactly."""

    def __init__(self, seed: int):
        sm = SplitMix64(seed)
        self.s0 = sm.next_u64() | np.uint64(1)
        self.s1 = sm.next_u64()

    def next_u64(self) -> np.uint64:
        with np.errstate(over="ignore"):
            x = self.s0
            y = self.s1
            self.s0 = y
            x = (x ^ ((x << np.uint64(23)) & MASK)) & MASK
            self.s1 = x ^ y ^ (x >> np.uint64(17)) ^ (y >> np.uint64(26))
            return (self.s1 + y) & MASK

    def next_f64(self) -> float:
        bits = self.next_u64() >> np.uint64(11)
        return (float(bits) + 0.5) * (1.0 / 9007199254740992.0)

    def next_below(self, n: int) -> int:
        n = np.uint64(n)
        while True:
            x = self.next_u64()
            wide = int(x) * int(n)
            hi, lo = wide >> 64, np.uint64(wide & int(MASK))
            neg_mod = np.uint64((2**64 - int(n)) % int(n))
            if lo >= n or lo >= neg_mod:
                return int(hi)


def _point_segment_dist(px, py, x0, y0, x1, y1):
    dx, dy = x1 - x0, y1 - y0
    len2 = dx * dx + dy * dy
    if len2 <= 1e-9:
        t = np.zeros_like(px)
    else:
        t = ((px - x0) * dx + (py - y0) * dy) / len2
    t = np.clip(t, 0.0, 1.0)
    cx, cy = x0 + t * dx, y0 + t * dy
    return np.sqrt((px - cx) ** 2 + (py - cy) ** 2)


def synthetic_digits(n: int, seed: int) -> np.ndarray:
    """Port of image.rs::synthetic_digits — returns f32[n, 28*28]."""
    rng = XorShift128(seed)
    prng = XorShift128(0xD1617000)
    protos = []
    for _ in range(10):
        strokes = []
        for _ in range(4):
            x0 = 4.0 + 8.0 * prng.next_f64()
            y0 = 3.0 + 22.0 * prng.next_f64()
            x1 = 14.0 + 10.0 * prng.next_f64()
            y1 = 3.0 + 22.0 * prng.next_f64()
            strokes.append((x0, y0, x1, y1))
        protos.append(strokes)

    py_grid, px_grid = np.meshgrid(
        np.arange(IMG, dtype=np.float32), np.arange(IMG, dtype=np.float32), indexing="ij"
    )
    out = np.zeros((n, IMG * IMG), dtype=np.float32)
    for img_i in range(n):
        cls = rng.next_below(10)
        dx = np.float32(rng.next_f64()) * np.float32(4.0) - np.float32(2.0)
        dy = np.float32(rng.next_f64()) * np.float32(4.0) - np.float32(2.0)
        img = np.zeros((IMG, IMG), dtype=np.float32)
        for (x0, y0, x1, y1) in protos[cls]:
            x0f, y0f = np.float32(x0) + dx, np.float32(y0) + dy
            x1f, y1f = np.float32(x1) + dx, np.float32(y1) + dy
            d = _point_segment_dist(px_grid, py_grid, x0f, y0f, x1f, y1f).astype(np.float32)
            img = np.minimum(img + np.exp(-d * d / np.float32(1.6)), np.float32(1.0))
        flat = img.reshape(-1)
        for p in range(IMG * IMG):
            flat[p] = np.clip(flat[p] + np.float32(0.05) * np.float32(rng.next_f64()), 0.0, 1.0)
        out[img_i] = flat
    return out


def right_half(img: np.ndarray) -> np.ndarray:
    """f32[784] -> f32[392] (columns 14..28 of each row)."""
    return img.reshape(IMG, IMG)[:, HALF_W:].reshape(-1)


def left_crop(img: np.ndarray, cx: int, cy: int) -> np.ndarray:
    """7×7 crop from the left half."""
    assert cx + CROP <= HALF_W and cy + CROP <= IMG
    return img.reshape(IMG, IMG)[cy : cy + CROP, cx : cx + CROP].reshape(-1)
