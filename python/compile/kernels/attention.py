"""Pallas decode-attention kernel: single-query causal attention over an
explicit KV cache — the transformer's per-step hot spot.

TPU adaptation of the usual GPU flash-decoding scheme: the cache is tiled
along S via the BlockSpec grid (HBM→VMEM streaming); each grid step fuses
QK^T, the masked online-softmax update, and the PV accumulation, carrying
(m, l, acc) running statistics exactly like flash attention. At our sizes
(S ≤ 160, D ≤ 64) a single tile also fits VMEM whole, but the tiling is
what would scale this to real cache lengths on hardware.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _causal_kernel(q_ref, k_ref, v_ref, o_ref):
    """Full causal self-attention for one head: [S, D] in VMEM whole.

    Used by the exported full-context forward (`model.lm_logits`): one grid
    step per head; the S×S score matrix fits VMEM at our sizes (S ≤ 160).
    """
    q = q_ref[...][0]  # [S, D]
    k = k_ref[...][0]
    v = v_ref[...][0]
    s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = (q @ k.T) * scale  # [S, S]
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    logits = jnp.where(col <= row, logits, -jnp.float32(1e30))
    m = logits.max(axis=1, keepdims=True)
    p = jnp.exp(logits - m)
    w = p / p.sum(axis=1, keepdims=True)
    o_ref[...] = (w @ v)[None]


@jax.jit
def causal_attention(q, k, v):
    """Pallas causal self-attention: f32[H, S, D] -> f32[H, S, D]."""
    h, s, d = q.shape
    assert k.shape == (h, s, d) and v.shape == (h, s, d)
    return pl.pallas_call(
        _causal_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        interpret=True,
    )(q, k, v)


def _attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, block_s: int):
    tile = pl.program_id(0)
    base = tile * block_s

    q = q_ref[...]          # [H, D]
    k = k_ref[...]          # [H, block_s, D]
    v = v_ref[...]          # [H, block_s, D]
    length = len_ref[0]

    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("hd,hsd->hs", q, k) * scale  # [H, block_s]
    pos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) + base
    logits = jnp.where(pos < length, logits, -jnp.float32(1e30))

    m_tile = logits.max(axis=1)                       # [H]
    p = jnp.exp(logits - m_tile[:, None])             # [H, block_s]
    l_tile = p.sum(axis=1)                            # [H]
    acc_tile = jnp.einsum("hs,hsd->hd", p, v)         # [H, D]

    @pl.when(tile == 0)
    def _init():
        m_ref[...] = m_tile
        l_ref[...] = l_tile
        o_ref[...] = acc_tile

    @pl.when(tile != 0)
    def _fold():
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, m_tile)
        alpha = jnp.exp(m_old - m_new)
        beta = jnp.exp(m_tile - m_new)
        l_ref[...] = l_ref[...] * alpha + l_tile * beta
        o_ref[...] = o_ref[...] * alpha[:, None] + acc_tile * beta[:, None]
        m_ref[...] = m_new


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention(q, k_cache, v_cache, length, block_s: int = 64):
    """Single-position attention over the KV cache (interpret-mode Pallas).

    Args:
      q: f32[H, D]; k_cache/v_cache: f32[H, S, D]; length: i32 scalar.
      block_s: cache tile length (VMEM sizing knob).

    Returns: f32[H, D] attention output (un-normalized softmax folded in).
    """
    h, s, d = k_cache.shape
    assert q.shape == (h, d) and v_cache.shape == (h, s, d)
    if s % block_s != 0:
        pad = block_s - (s % block_s)
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0)))
        s = s + pad

    grid = (s // block_s,)
    length_arr = jnp.asarray(length, dtype=jnp.int32).reshape((1,))
    o, m, l = pl.pallas_call(
        functools.partial(_attn_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((h, d), lambda i: (0, 0)),
            pl.BlockSpec((h, block_s, d), lambda i: (0, i, 0)),
            pl.BlockSpec((h, block_s, d), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((h, d), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, d), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
        ],
        interpret=True,
    )(length_arr, q, k_cache, v_cache)
    return o / l[:, None]
