# L1: Pallas kernels for the paper's compute hot-spots.
#
# All kernels run under interpret=True — the CPU PJRT plugin cannot execute
# Mosaic custom-calls, so interpret mode is both the correctness and the
# lowering path here; real-TPU performance is estimated analytically in
# DESIGN.md §8.

from .attention import decode_attention
from .gls import gls_select
