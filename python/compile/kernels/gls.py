"""Pallas kernel for Gumbel-max List Sampling (paper Alg. 1 / Alg. 2).

The hot spot of GLS verification is the coupled double race over the
[K, N] grid of shared exponentials:

    Y      = argmin_i  min_k  (-ln U[k, i]) / q[k, i]
    X^(k)  = argmin_i         (-ln U[k, i]) / p[k, i]

GPU-paper -> TPU adaptation (DESIGN.md §Hardware-Adaptation): instead of a
per-threadblock reduction over vocab shards, the kernel tiles N into
VMEM-sized blocks via the BlockSpec grid and carries running (min, argmin)
accumulators in the output refs; the elementwise  -ln(U)/prob  math is VPU
work, and the final reduction per tile is a 2D min over the K×BLOCK tile.

Numerical contract (mirrored by ref.py and the Rust implementation):
the race runs on f32; prob <= 0 entries are masked to +inf so zero-mass
symbols can never win.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF_GUARD = 3.4e38  # effectively +inf race value (python scalar: pallas kernels must not capture tracers)


def _gls_kernel(u_ref, q_ref, p_ref, ybest_ref, yarg_ref, xbest_ref, xarg_ref, *, block_n: int):
    """One grid step: fold one N-tile into the running (min, argmin)."""
    tile = pl.program_id(0)
    base = tile * block_n

    u = u_ref[...]  # [K, block_n]
    q = q_ref[...]
    p = p_ref[...]

    s = -jnp.log(u)  # shared Exp(1) variates
    # Masked race values.
    yv = jnp.where(q > 0.0, s / q, _NEG_INF_GUARD)
    xv = jnp.where(p > 0.0, s / p, _NEG_INF_GUARD)

    k_dim, bn = yv.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (k_dim, bn), 1) + base

    # --- Y: global min over the whole K×tile block. ---
    y_tile_best = jnp.min(yv)
    flat = jnp.argmin(yv.reshape(-1))
    y_tile_arg = (flat % bn) + base

    @pl.when(tile == 0)
    def _init():
        ybest_ref[0] = y_tile_best
        yarg_ref[0] = y_tile_arg.astype(jnp.int32)
        xbest_ref[...] = jnp.min(xv, axis=1)
        xarg_ref[...] = (jnp.argmin(xv, axis=1) + base).astype(jnp.int32)

    @pl.when(tile != 0)
    def _fold():
        better_y = y_tile_best < ybest_ref[0]
        ybest_ref[0] = jnp.where(better_y, y_tile_best, ybest_ref[0])
        yarg_ref[0] = jnp.where(better_y, y_tile_arg.astype(jnp.int32), yarg_ref[0])

        x_tile_best = jnp.min(xv, axis=1)
        x_tile_arg = (jnp.argmin(xv, axis=1) + base).astype(jnp.int32)
        better_x = x_tile_best < xbest_ref[...]
        xbest_ref[...] = jnp.where(better_x, x_tile_best, xbest_ref[...])
        xarg_ref[...] = jnp.where(better_x, x_tile_arg, xarg_ref[...])

    del cols  # iota retained for clarity of the tiling story


@functools.partial(jax.jit, static_argnames=("block_n",))
def gls_select(u, q, p, block_n: int = 128):
    """Coupled GLS selection.

    Args:
      u: shared uniforms, f32[K, N] in (0, 1).
      q: per-draft target probabilities, f32[K, N] (rows may differ when the
         active-set semantics of Alg. 2 feed per-draft targets).
      p: per-draft proposal probabilities, f32[K, N].
      block_n: N-tile width (VMEM sizing knob).

    Returns:
      (y, xs): y i32[] — the target's coupled sample;
               xs i32[K] — each draft's proposal sample.
    """
    k, n = u.shape
    assert q.shape == (k, n) and p.shape == (k, n)
    if n % block_n != 0:
        # Pad with zero-probability symbols: masked out by the kernel.
        pad = block_n - (n % block_n)
        u = jnp.pad(u, ((0, 0), (0, pad)), constant_values=0.5)
        q = jnp.pad(q, ((0, 0), (0, pad)))
        p = jnp.pad(p, ((0, 0), (0, pad)))
        n = n + pad

    grid = (n // block_n,)
    ybest, yarg, xbest, xarg = pl.pallas_call(
        functools.partial(_gls_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
            pl.BlockSpec((k, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=True,
    )(u, q, p)
    del ybest, xbest
    return yarg[0], xarg
