"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest (plus hypothesis sweeps)
asserts the kernels agree with these bit-for-bit on argmin identities and
to tight tolerances on values. The Rust coordinator implements the same
math in f64; the cross-language agreement test lives in
python/tests/test_cross_language.py.
"""

import jax.numpy as jnp


def gls_select_ref(u, q, p):
    """Reference GLS coupled selection over f32[K, N] inputs.

    Y = argmin_i min_k -ln(u[k,i]) / q[k,i]   (masked where q <= 0)
    X[k] = argmin_i -ln(u[k,i]) / p[k,i]      (masked where p <= 0)
    """
    s = -jnp.log(u)
    guard = jnp.float32(3.4e38)
    yv = jnp.where(q > 0.0, s / q, guard)
    xv = jnp.where(p > 0.0, s / p, guard)
    # Global argmin over (k, i), reported as the symbol index i.
    flat = jnp.argmin(yv.reshape(-1))
    y = (flat % u.shape[1]).astype(jnp.int32)
    xs = jnp.argmin(xv, axis=1).astype(jnp.int32)
    return y, xs


def decode_attention_ref(q, k_cache, v_cache, length):
    """Reference single-query causal attention with an explicit KV cache.

    Args:
      q: f32[H, D] query for the current position.
      k_cache: f32[H, S, D]; v_cache: f32[H, S, D].
      length: number of valid cache positions (<= S).

    Returns: f32[H, D].
    """
    h, s, d = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("hd,hsd->hs", q, k_cache) * scale
    mask = jnp.arange(s)[None, :] < length
    logits = jnp.where(mask, logits, -jnp.float32(1e30))
    w = jnp.exp(logits - logits.max(axis=1, keepdims=True))
    w = w / w.sum(axis=1, keepdims=True)
    return jnp.einsum("hs,hsd->hd", w, v_cache)
