"""Synthetic byte-level training corpus for the draft/target LM pair.

Deterministic template-grammar text: arithmetic word problems, code-ish
snippets, and prose-ish filler — enough structure that a tiny transformer
learns real conditional distributions (and a half-size drafter learns an
aligned-but-weaker approximation), which is all speculative decoding
needs. Byte-level tokens match rust/src/model/tokenizer.rs (BOS=256).
"""

import numpy as np

BOS, EOS, PAD = 256, 257, 258
VOCAB = 259

_NAMES = ["ada", "bob", "cleo", "dan", "eve", "finn", "grace", "hugo"]
_ITEMS = ["apples", "books", "coins", "drums", "eggs", "forks"]
_VERBS = ["buys", "sells", "finds", "loses", "counts", "stacks"]
_FUNCS = ["sum", "min", "max", "mean", "sort", "scan"]


def _sentences(rng: np.random.Generator, n: int):
    out = []
    for _ in range(n):
        kind = rng.integers(0, 3)
        if kind == 0:  # arithmetic word problem
            a, b = int(rng.integers(2, 60)), int(rng.integers(2, 60))
            name = _NAMES[rng.integers(0, len(_NAMES))]
            item = _ITEMS[rng.integers(0, len(_ITEMS))]
            verb = _VERBS[rng.integers(0, len(_VERBS))]
            out.append(
                f"{name} {verb} {a} {item} and then {b} more. total: {a + b} {item}."
            )
        elif kind == 1:  # code-ish
            f = _FUNCS[rng.integers(0, len(_FUNCS))]
            k = int(rng.integers(1, 9))
            out.append(f"def {f}{k}(xs): return {f}(xs[:{k}]) # {f} of first {k}")
        else:  # prose filler
            n1 = _NAMES[rng.integers(0, len(_NAMES))]
            n2 = _NAMES[rng.integers(0, len(_NAMES))]
            out.append(f"{n1} said to {n2} that the {_ITEMS[rng.integers(0, len(_ITEMS))]} were ready.")
    return out


def build_corpus(num_docs: int = 2000, seed: int = 0) -> bytes:
    rng = np.random.default_rng(seed)
    return ("\n".join(_sentences(rng, num_docs)) + "\n").encode()


def batches(corpus: bytes, batch: int, seq: int, steps: int, seed: int = 1):
    """Yield i32[batch, seq] windows with a BOS prepended to each."""
    data = np.frombuffer(corpus, dtype=np.uint8).astype(np.int32)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, len(data) - seq, size=batch)
        rows = np.stack([data[i : i + seq - 1] for i in idx])
        yield np.concatenate([np.full((batch, 1), BOS, np.int32), rows], axis=1)
