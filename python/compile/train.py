"""Build-time training: the draft/target LM pair and the β-VAE codec.

Everything here runs exactly once, inside `make artifacts`, and is cached
as artifacts/weights_*.npz. Adam is implemented inline (no optax needed).
Budgets are sized for a couple of minutes of CPU time: enough for the
target model to clearly out-predict the drafter while the drafter stays
aligned — the regime the paper's experiments live in.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_lib
from . import digits as digits_lib
from . import model as model_lib
from . import vae as vae_lib


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def train_lm(cfg: model_lib.LmConfig, steps: int, seed: int, log_name: str):
    """Train one LM on the synthetic corpus; returns (params, final_loss)."""
    key = jax.random.PRNGKey(seed)
    params = model_lib.init_params(cfg, key)
    opt = adam_init(params)
    corpus = corpus_lib.build_corpus()

    # Training uses the jnp attention path (use_pallas=False): interpret-mode
    # Pallas inside a grad loop is needlessly slow; the exported inference
    # graph (aot.py) uses the Pallas kernel and pytest asserts both paths
    # agree numerically.
    loss_grad = jax.jit(
        jax.value_and_grad(lambda p, toks: model_lib.lm_loss(p, toks, cfg, use_pallas=False))
    )

    t0 = time.time()
    loss = None
    for step, batch in enumerate(
        corpus_lib.batches(corpus, batch=16, seq=cfg.max_seq, steps=steps, seed=seed)
    ):
        loss, grads = loss_grad(params, jnp.asarray(batch))
        params, opt = adam_step(params, grads, opt)
        if step % 50 == 0:
            print(f"[{log_name}] step {step:4d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    print(f"[{log_name}] done: loss {float(loss):.4f} after {steps} steps")
    return params, float(loss)


def train_vae(cfg: vae_lib.VaeConfig, steps: int, seed: int):
    """Train the β-VAE stack on synthetic digits; returns (params, loss)."""
    key = jax.random.PRNGKey(seed)
    params = vae_lib.init_params(cfg, key)
    opt = adam_init(params)

    imgs = digits_lib.synthetic_digits(2000, seed=1234)
    sources = np.stack([digits_lib.right_half(i) for i in imgs])
    rng = np.random.default_rng(seed)

    loss_grad = jax.jit(
        jax.value_and_grad(
            lambda p, s, c, k: vae_lib.vae_loss(p, s, c, k, cfg)[0],
        )
    )

    t0 = time.time()
    loss = None
    for step in range(steps):
        idx = rng.integers(0, len(imgs), size=64)
        src = jnp.asarray(sources[idx])
        # Random side crops (independent per example, like the experiment).
        crops = np.stack(
            [
                digits_lib.left_crop(
                    imgs[i],
                    int(rng.integers(0, digits_lib.HALF_W - digits_lib.CROP + 1)),
                    int(rng.integers(0, digits_lib.IMG - digits_lib.CROP + 1)),
                )
                for i in idx
            ]
        )
        key, sub = jax.random.split(key)
        loss, grads = loss_grad(params, src, jnp.asarray(crops), sub)
        params, opt = adam_step(params, grads, opt)
        if step % 100 == 0:
            print(f"[vae] step {step:4d} loss {float(loss):.3f} ({time.time()-t0:.0f}s)")
    print(f"[vae] done: loss {float(loss):.3f} after {steps} steps")
    return params, float(loss)


def flatten_params(params, prefix=""):
    """Flatten a pytree of arrays into {dotted.name: np.ndarray}."""
    out = {}
    if isinstance(params, dict):
        for k, v in params.items():
            out.update(flatten_params(v, f"{prefix}{k}."))
    elif isinstance(params, (list, tuple)):
        for i, v in enumerate(params):
            out.update(flatten_params(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = np.asarray(params)
    return out


def unflatten_params(flat):
    """Inverse of flatten_params (lists reconstructed from int keys)."""
    tree = {}
    for name, arr in flat.items():
        parts = name.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(arr)

    def listify(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(k.isdigit() for k in keys):
            return [listify(node[str(i)]) for i in range(len(keys))]
        return {k: listify(v) for k, v in node.items()}

    return listify(tree)
