//! Distributed lossy compression demo (paper §5): compress an image's
//! right half, broadcast one message, decode at K sub-stations each
//! holding an independent 7×7 crop of the left half — the aircraft-
//! detection scenario of the paper's introduction to §5.
//!
//! Uses the AOT-compiled β-VAE artifacts when available (the full
//! three-layer path), otherwise the analytic linear-Gaussian codec.
//!
//! ```bash
//! cargo run --release --offline --example compress_side_info
//! ```

use gls_serve::bench::Table;
use gls_serve::compression::codec::{CodecConfig, CodecWorkspace, GlsCodec, RandomnessMode};
use gls_serve::compression::gaussian::{run_gaussian, GaussianSource};
use gls_serve::compression::image::{
    left_crop, mse, right_half, synthetic_digits, AnalyticVae, EncState, LatentCodecModel,
    LatentSource, CROP, HALF_W, IMG,
};
use gls_serve::runtime::{Artifacts, PjrtVae};
use gls_serve::stats::rng::XorShift128;

fn demo_images<M: LatentCodecModel>(model: &M, images: &[Vec<f32>], k: usize, l_max: u64) {
    let src_model = LatentSource { model };
    let cfg = CodecConfig {
        n_samples: 192,
        l_max,
        k_decoders: k,
        seed: 77,
        mode: RandomnessMode::Independent,
    };
    let codec = GlsCodec::new(&src_model, cfg);
    let mut ws = CodecWorkspace::new();
    let mut crop_rng = XorShift128::new(5);

    let mut t = Table::new(&["image", "matched?", "best decoder MSE", "per-decoder MSE"]);
    for (b, img) in images.iter().enumerate() {
        let source = right_half(img);
        let (mu, var) = model.encode(&source);
        let sides: Vec<Vec<f64>> = (0..k)
            .map(|_| {
                let cx = crop_rng.next_below((HALF_W - CROP + 1) as u64) as usize;
                let cy = crop_rng.next_below((IMG - CROP + 1) as u64) as usize;
                model.project(&left_crop(img, cx, cy))
            })
            .collect();
        // One shared-randomness materialization serves the encoder, all K
        // decoders, and reconstruction.
        let ctx = codec.block_context(b as u64);
        let (_, dec, hit) = codec.roundtrip_with(&mut ws, &ctx, &EncState { mu, var }, &sides);
        let errs: Vec<f64> = dec
            .iter()
            .zip(&sides)
            .map(|(&idx, side)| mse(&model.decode(&ctx.samples[idx], side), &source))
            .collect();
        let best = errs.iter().cloned().fold(f64::INFINITY, f64::min);
        t.row(&[
            format!("#{b}"),
            if hit { "yes".into() } else { "no".into() },
            format!("{best:.4}"),
            errs.iter().map(|e| format!("{e:.3}")).collect::<Vec<_>>().join(" / "),
        ]);
    }
    t.print();
}

fn main() {
    println!("== 1. Gaussian source (paper §5.2) ==");
    let mut t = Table::new(&["K", "scheme", "match", "distortion dB"]);
    for k in [1usize, 2, 4] {
        for (name, mode) in
            [("GLS", RandomnessMode::Independent), ("baseline", RandomnessMode::Shared)]
        {
            let p = run_gaussian(GaussianSource::paper_default(0.005), k, 8, 1 << 11, 300, 3, mode);
            t.row(&[
                k.to_string(),
                name.into(),
                format!("{:.3}", p.match_rate),
                format!("{:.1}", p.mse_db),
            ]);
        }
    }
    t.print();

    println!("\n== 2. Image compression: one message, K=3 independent decoders ==");
    let images = synthetic_digits(206, 21);
    let (train, eval) = images.split_at(200);

    match Artifacts::discover().and_then(|m| PjrtVae::load(&m)) {
        Ok(vae) => {
            println!("(β-VAE artifacts: JAX-trained, AOT-compiled, PJRT-executed)");
            demo_images(&vae, eval, 3, 16);
        }
        Err(e) => {
            println!("(analytic codec — PJRT VAE unavailable: {e})");
            let vae = AnalyticVae::fit(train, 4, 0.05, 13);
            demo_images(&vae, eval, 3, 16);
        }
    }
    println!("\nRate = log2(L_max) = 4 bits per image-half; success = any decoder");
    println!("recovers the encoder's index (the paper's list-decoding criterion).");
}
