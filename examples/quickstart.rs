//! Quickstart: the GLS public API in five minutes.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Walks through (1) one-shot coupled sampling (Alg. 1) and the List
//! Matching Lemma, (2) drafter-invariant multi-draft speculative decoding
//! on a simulated model pair, and (3) a tiny side-information compression
//! round trip.

use gls_serve::compression::codec::RandomnessMode;
use gls_serve::compression::gaussian::{run_gaussian, GaussianSource};
use gls_serve::coordinator::engine::SpecDecodeEngine;
use gls_serve::coordinator::kv::PagedKvCache;
use gls_serve::coordinator::sequence::{Request, SequenceState};
use gls_serve::coordinator::EngineConfig;
use gls_serve::model::backend::ModelPair;
use gls_serve::model::sim::SimLm;
use gls_serve::spec::gls::sample_gls;
use gls_serve::spec::lml;
use gls_serve::spec::types::{Categorical, VerifierKind};
use gls_serve::stats::rng::CounterRng;

fn main() {
    // ---------------------------------------------------------------- (1)
    println!("== 1. Gumbel-max List Sampling (paper Alg. 1) ==");
    let p = Categorical::new(vec![0.1, 0.6, 0.3]); // Alice's proposal dist
    let q = Categorical::new(vec![0.4, 0.2, 0.4]); // Bob's target dist
    let shared = CounterRng::new(0xC0FFEE); // the common randomness R

    for k in [1usize, 2, 4, 8] {
        let trials = 20_000;
        let hits = (0..trials)
            .filter(|&t| sample_gls(&p, &q, k, &shared, t as u64).accept)
            .count();
        println!(
            "K = {k}: empirical match {:.3} ≥ LML bound {:.3}",
            hits as f64 / trials as f64,
            lml::theorem1_bound(&p, &q, k)
        );
    }

    // ---------------------------------------------------------------- (2)
    println!("\n== 2. Drafter-invariant multi-draft speculative decoding ==");
    let (draft, target) = SimLm::pair(64, 7, 2.0); // aligned-but-imperfect
    let cfg = EngineConfig {
        num_drafts: 4,
        block_len: 4,
        verifier: VerifierKind::Gls,
        max_seq_len: 256,
        ..EngineConfig::default()
    };
    let mut engine = SpecDecodeEngine::new(
        cfg,
        ModelPair::new(Box::new(draft), Box::new(target)),
        PagedKvCache::new(1024, 16),
    );
    let mut seq = SequenceState::from_request(&Request::new(1, vec![3, 1, 4, 1, 5], 48));
    engine.decode_sequence(&mut seq);
    println!(
        "generated {} tokens in {} target calls → block efficiency {:.2} \
         (vs 1.0 for plain autoregression)",
        seq.generated(),
        seq.target_calls,
        seq.block_efficiency()
    );

    // ---------------------------------------------------------------- (3)
    println!("\n== 3. Lossy compression with side information at K decoders ==");
    for k in [1usize, 4] {
        let point = run_gaussian(
            GaussianSource::paper_default(0.005),
            k,
            16, // L_max = 16 → 4 bits per sample
            1 << 11,
            400,
            42,
            RandomnessMode::Independent,
        );
        println!(
            "K = {k}: match probability {:.3}, distortion {:.1} dB at 4 bits/sample",
            point.match_rate, point.mse_db
        );
    }
    println!("\nSee examples/serve_e2e.rs for the full serving stack and");
    println!("examples/compress_side_info.rs for the image pipeline.");
}
