//! Toy-distribution coupling explorer (the paper's §3 story, Figure 6 in
//! miniature): sweep the number of drafts K on one random (p, q) pair and
//! print every quantity the theory relates:
//!
//!   empirical GLS acceptance  ≥  LML bound (Thm. 1 eq. 3)
//!   relaxed bound (App. A.2)  ≤  LML bound's target
//!   optimal-with-communication upper bound, and the exact LP optimum
//!   for small K.
//!
//! Also demonstrates Prop. 5 (diverse proposals) and the conditional
//! acceptance guarantee (eq. 4) per symbol.

use gls_serve::bench::Table;
use gls_serve::spec::gls::{sample_gls, sample_gls_diverse};
use gls_serve::spec::{lml, optimal};
use gls_serve::stats::rng::{CounterRng, XorShift128};
use gls_serve::testkit::gen_categorical;

fn main() {
    let mut gen = XorShift128::new(2025);
    let n = 8;
    let p = gen_categorical(&mut gen, n);
    let q = gen_categorical(&mut gen, n);
    println!("alphabet N = {n}, d_TV(p, q) = {:.3}\n", p.tv_distance(&q));

    let rng = CounterRng::new(99);
    let trials = 40_000u64;

    let mut t = Table::new(&["K", "empirical", "LML (3)", "relaxed", "optimal UB", "LP exact"]);
    for k in [1usize, 2, 3, 4, 6, 8, 12, 16] {
        let hits = (0..trials).filter(|&s| sample_gls(&p, &q, k, &rng, s).accept).count();
        let lp = if k <= 2 {
            optimal::lp_optimal(&p, &q, k).map(|v| format!("{v:.4}")).unwrap_or("—".into())
        } else {
            "—".into()
        };
        t.row(&[
            k.to_string(),
            format!("{:.4}", hits as f64 / trials as f64),
            format!("{:.4}", lml::theorem1_bound(&p, &q, k)),
            format!("{:.4}", lml::relaxed_bound(&p, &q, k)),
            format!("{:.4}", optimal::upper_bound(&p, &q, k)),
            lp,
        ]);
    }
    t.print();

    // Conditional acceptance per symbol (Thm. 1 eq. 4) at K = 4.
    println!("\nconditional acceptance given Y = j (K = 4):");
    let k = 4;
    let mut cond_hits = vec![0u64; n];
    let mut cond_n = vec![0u64; n];
    for s in 0..trials {
        let out = sample_gls(&p, &q, k, &rng, s);
        cond_n[out.y] += 1;
        if out.accept {
            cond_hits[out.y] += 1;
        }
    }
    let mut t = Table::new(&["j", "q_j", "p_j", "empirical", "bound (4)"]);
    for j in 0..n {
        if cond_n[j] < 200 {
            continue;
        }
        t.row(&[
            j.to_string(),
            format!("{:.3}", q.prob(j)),
            format!("{:.3}", p.prob(j)),
            format!("{:.4}", cond_hits[j] as f64 / cond_n[j] as f64),
            format!("{:.4}", lml::conditional_bound(p.prob(j), q.prob(j), k)),
        ]);
    }
    t.print();

    // Diverse proposals (Prop. 5): two very different drafters still give
    // valid marginals and a list-level gain.
    println!("\ndiverse proposals (Prop. 5), K = 2 heterogeneous drafters:");
    let p1 = gen_categorical(&mut gen, n);
    let p2 = gen_categorical(&mut gen, n);
    let hits = (0..trials)
        .filter(|&s| sample_gls_diverse(&[p1.clone(), p2.clone()], &q, &rng, s).accept)
        .count();
    let single_best = {
        let h1 = (0..trials).filter(|&s| sample_gls(&p1, &q, 1, &rng, s).accept).count();
        let h2 = (0..trials).filter(|&s| sample_gls(&p2, &q, 1, &rng, s).accept).count();
        h1.max(h2)
    };
    println!(
        "  two-drafter list acceptance {:.4} vs best single drafter {:.4}",
        hits as f64 / trials as f64,
        single_best as f64 / trials as f64
    );
}
