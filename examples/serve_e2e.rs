//! End-to-end serving driver (the repository's headline validation run).
//!
//! Loads the AOT-compiled draft/target transformer artifacts (trained at
//! build time by `make artifacts`), starts the full coordinator stack
//! (router → batcher → scheduler → GLS engine → PJRT backends), serves a
//! batched workload of real text prompts with Poisson arrivals, and
//! reports block efficiency, token throughput and latency percentiles for
//! GLS multi-draft vs single-draft verification.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example serve_e2e
//! ```
//!
//! Without artifacts it falls back to the timed SimLm backend so the
//! driver always demonstrates the full serving path.

use std::time::{Duration, Instant};

use gls_serve::bench::Table;
use gls_serve::coordinator::router::RoutingPolicy;
use gls_serve::coordinator::server::Server;
use gls_serve::coordinator::{EngineConfig, ServerConfig};
use gls_serve::model::backend::ModelPair;
use gls_serve::model::sampling::SamplingParams;
use gls_serve::model::tokenizer::ByteTokenizer;
use gls_serve::runtime::{Artifacts, PjrtLm};
use gls_serve::spec::types::VerifierKind;
use gls_serve::workload::trace::PoissonTrace;
use gls_serve::workload::suites::TaskSuite;

const PROMPTS: &[&str] = &[
    "ada buys 3 apples and then 4 more. total:",
    "bob sells 12 eggs and then 5 more. total:",
    "def sum3(xs): return ",
    "cleo counts 7 coins and then 9 more. total:",
    "finn stacks 21 books and then 14 more. total:",
    "def max2(xs): return ",
    "grace said to hugo that the drums were ready.",
    "eve finds 8 forks and then 11 more. total:",
];

fn main() {
    let have_artifacts = Artifacts::discover().is_ok();
    let tok = ByteTokenizer::new();
    let requests = 24;
    let max_new = if have_artifacts { 20 } else { 48 };

    println!("== gls-serve end-to-end driver ==");
    println!(
        "backend: {}",
        if have_artifacts {
            "PJRT artifacts (JAX transformer + Pallas attention, AOT)"
        } else {
            "timed SimLm (run `make artifacts` for the PJRT path)"
        }
    );

    // Open-loop arrival schedule (Poisson), as a real serving benchmark.
    let trace = PoissonTrace::generate(400.0, requests, PROMPTS.len(), 7);
    println!(
        "workload: {requests} requests, Poisson arrivals at ~{:.0} req/s over {:?}\n",
        trace.empirical_rate(),
        trace.duration()
    );

    let mut table = Table::new(&[
        "verifier", "K", "BE", "gen tok/s", "p50 ms", "p95 ms", "wall ms",
    ]);

    for (vk, k) in [
        (VerifierKind::SingleDraft, 1usize),
        (VerifierKind::Daliri, 1),
        (VerifierKind::Gls, 2),
        (VerifierKind::Gls, 4),
        (VerifierKind::SpecInfer, 4),
    ] {
        let sc = ServerConfig {
            workers: 2,
            max_batch: 4,
            batch_deadline: Duration::from_millis(1),
            ..ServerConfig::default()
        };
        let ec = EngineConfig {
            num_drafts: k,
            block_len: 3,
            verifier: vk,
            target_params: SamplingParams::new(1.0, Some(50)),
            draft_params: vec![SamplingParams::new(1.0, Some(50))],
            max_seq_len: if have_artifacts { 90 } else { 512 },
            seed: 0xE2E,
            ..EngineConfig::default()
        };

        let start = Instant::now();
        let mut server = if have_artifacts {
            let manifest = Artifacts::discover().unwrap();
            Server::start(&sc, &ec, RoutingPolicy::LeastLoaded, |_| {
                let draft = PjrtLm::load(&manifest, "draft_lm").expect("draft");
                let target = PjrtLm::load(&manifest, "target_lm").expect("target");
                ModelPair::new(Box::new(draft), Box::new(target))
            })
        } else {
            let suite = TaskSuite::by_name("gsm8k-sim").unwrap();
            Server::start(&sc, &ec, RoutingPolicy::LeastLoaded, |_| {
                suite.timed_model_pair(64, 7)
            })
        };

        // Replay the trace in real time.
        for ev in &trace.events {
            let until = start.elapsed();
            if ev.at > until {
                std::thread::sleep(ev.at - until);
            }
            let prompt = tok.encode(PROMPTS[ev.prompt_idx]);
            server.submit(prompt, max_new);
        }
        let report = server.finish();
        let wall = start.elapsed();

        table.row(&[
            vk.name().to_string(),
            k.to_string(),
            format!("{:.2}", report.mean_block_efficiency()),
            format!("{:.0}", report.metrics.emitted_tokens as f64 / wall.as_secs_f64()),
            format!("{:.1}", report.p50_latency() * 1e3),
            format!("{:.1}", report.p95_latency() * 1e3),
            format!("{:.0}", wall.as_secs_f64() * 1e3),
        ]);

        // Show one decoded completion from the GLS K=4 run.
        if vk == VerifierKind::Gls && k == 4 {
            let r = &report.results[0];
            println!("sample completion (GLS K=4):\n  {:?}\n", tok.decode(&r.tokens));
        }
    }

    table.print();
    println!("\nRecorded in EXPERIMENTS.md §E2E.");
}
