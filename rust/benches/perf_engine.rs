//! Performance benchmarks over the serving hot path (§Perf deliverable).
//!
//! Measures, per layer:
//!   L3a  verify-only: GLS / SpecInfer / SpecTr block verification on
//!        synthetic BlockInputs (pure coordinator math, no model);
//!   L3b  end-to-end engine blocks/s on the SimLm backend at several
//!        batch sizes (continuous-batching efficiency);
//!   L3c  serving stack requests/s through router + scheduler;
//!   L1/L2 (when artifacts exist) PJRT forward latency per call and
//!        engine blocks/s on the PJRT backend.
//!
//! Run before/after every optimization; EXPERIMENTS.md §Perf records the
//! iteration log.

use std::time::Duration;

use gls_serve::bench::{time_budget, Table};
use gls_serve::coordinator::engine::SpecDecodeEngine;
use gls_serve::coordinator::kv::PagedKvCache;
use gls_serve::coordinator::router::RoutingPolicy;
use gls_serve::coordinator::sequence::Request;
use gls_serve::coordinator::server::Server;
use gls_serve::coordinator::{EngineConfig, ServerConfig};
use gls_serve::model::backend::{LmBackend, ModelPair};
use gls_serve::model::sampling::SamplingParams;
use gls_serve::model::sim::SimLm;
use gls_serve::spec::types::{BlockInput, Categorical, VerifierKind};
use gls_serve::spec::make_verifier;
use gls_serve::stats::rng::{CounterRng, XorShift128};
use gls_serve::testkit::gen_categorical;

fn synth_block(k: usize, l: usize, n: usize, seed: u64) -> BlockInput {
    let mut gen = XorShift128::new(seed);
    let p: Vec<Categorical> = (0..l).map(|_| gen_categorical(&mut gen, n)).collect();
    let q: Vec<Categorical> = (0..=l).map(|_| gen_categorical(&mut gen, n)).collect();
    let rng = CounterRng::new(seed);
    let mut draft_tokens = vec![Vec::with_capacity(l); k];
    for kk in 0..k {
        for j in 0..l {
            draft_tokens[kk].push(p[j].sample_race(&rng, j as u64, kk as u64) as u32);
        }
    }
    BlockInput { draft_tokens, draft_dists: vec![p; k], target_dists: vec![q; k] }
}

fn main() {
    let budget = Duration::from_millis(400);
    println!("# §Perf — serving hot-path benchmarks\n");

    // ---------------------------------------------------------- L3a verify
    {
        let mut t = Table::new(&["verifier", "K", "N(vocab)", "µs/block", "blocks/s"]);
        for &vk in &[VerifierKind::Gls, VerifierKind::SpecInfer, VerifierKind::SpecTr] {
            for &(k, n) in &[(4usize, 64usize), (8, 64), (8, 259), (8, 2048)] {
                let v = make_verifier(vk);
                let input = synth_block(k, 4, n, 42);
                let rng = CounterRng::new(7);
                let mut slot = 0u64;
                let r = time_budget(&format!("{vk:?}-K{k}-N{n}"), budget, 20, || {
                    std::hint::black_box(v.verify_block(&input, &rng, slot));
                    slot = slot.wrapping_add(5);
                });
                t.row(&[
                    vk.name().to_string(),
                    k.to_string(),
                    n.to_string(),
                    format!("{:.1}", r.per_iter.mean * 1e6),
                    format!("{:.0}", 1.0 / r.per_iter.mean),
                ]);
            }
        }
        println!("## L3a — block verification (coupling math only)");
        t.print();
        println!();
    }

    // ----------------------------------------------------- L3b engine step
    {
        let mut t = Table::new(&["batch", "K", "blocks/s", "tokens/s"]);
        for &batch in &[1usize, 4, 16] {
            for &k in &[4usize, 8] {
                let (d, tg) = SimLm::pair(64, 5, 2.0);
                let cfg = EngineConfig {
                    num_drafts: k,
                    block_len: 4,
                    verifier: VerifierKind::Gls,
                    target_params: SamplingParams::new(1.0, Some(50)),
                    draft_params: vec![SamplingParams::new(1.0, Some(50))],
                    max_seq_len: 4096,
                    seed: 3,
                };
                let mut eng = SpecDecodeEngine::new(
                    cfg,
                    ModelPair::new(Box::new(d), Box::new(tg)),
                    PagedKvCache::new(1 << 14, 16),
                );
                let mut seqs: Vec<_> = (0..batch)
                    .map(|i| {
                        let req = Request::new(i as u64, vec![1, 2, 3], 3000);
                        let s = gls_serve::coordinator::sequence::SequenceState::from_request(&req);
                        eng.kv.register(s.id, 3, 3103, 5).unwrap();
                        s
                    })
                    .collect();
                let r = time_budget(&format!("engine-B{batch}-K{k}"), budget, 10, || {
                    let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
                    std::hint::black_box(eng.step_blocks(&mut refs));
                });
                let blocks_per_s = batch as f64 / r.per_iter.mean;
                let be = eng.metrics.block_efficiency();
                t.row(&[
                    batch.to_string(),
                    k.to_string(),
                    format!("{:.0}", blocks_per_s),
                    format!("{:.0}", blocks_per_s * be),
                ]);
            }
        }
        println!("## L3b — engine blocks/s (SimLm backend, L = 4)");
        t.print();
        println!();
    }

    // --------------------------------------------------- L3c serving stack
    {
        let mut t = Table::new(&["workers", "policy", "req/s", "gen tok/s", "p95 ms"]);
        for &workers in &[1usize, 2, 4] {
            for policy in [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded] {
                let sc = ServerConfig { workers, ..ServerConfig::default() };
                let ec = EngineConfig {
                    num_drafts: 4,
                    block_len: 4,
                    verifier: VerifierKind::Gls,
                    max_seq_len: 256,
                    ..EngineConfig::default()
                };
                let n_req = 48;
                let workload: Vec<(Vec<u32>, usize)> =
                    (0..n_req).map(|i| (vec![i as u32, 1, 2], 32)).collect();
                let report = Server::serve_all(
                    &sc,
                    &ec,
                    policy,
                    |_| {
                        let (d, tg) = SimLm::pair(64, 9, 2.0);
                        ModelPair::new(Box::new(d), Box::new(tg))
                    },
                    workload,
                );
                t.row(&[
                    workers.to_string(),
                    format!("{policy:?}"),
                    format!("{:.0}", n_req as f64 / report.wall.as_secs_f64()),
                    format!("{:.0}", report.token_rate()),
                    format!("{:.1}", report.p95_latency() * 1e3),
                ]);
            }
        }
        println!("## L3c — serving stack throughput");
        t.print();
        println!();
    }

    // ------------------------------------------------ L1/L2 PJRT artifacts
    match gls_serve::runtime::Artifacts::discover() {
        Err(e) => println!("## L1/L2 — skipped (no artifacts: {e})"),
        Ok(m) => {
            use gls_serve::runtime::PjrtLm;
            let mut target = PjrtLm::load(&m, "target_lm").expect("target");
            let seqs: Vec<Vec<u32>> = (0..8).map(|i| vec![256, i, 1, 2, 3, 4]).collect();
            let r = time_budget("pjrt-forward-B8", Duration::from_secs(2), 5, || {
                std::hint::black_box(target.next_logits(&seqs));
            });
            let mut t = Table::new(&["op", "ms/call", "rows/s"]);
            t.row(&[
                "target_lm forward (B=8, S=96)".into(),
                format!("{:.2}", r.per_iter.mean * 1e3),
                format!("{:.0}", 8.0 / r.per_iter.mean),
            ]);

            // GLS select artifact vs native Rust implementation.
            use gls_serve::runtime::client::{compile_hlo_file, execute_tuple, new_client};
            let client = new_client().unwrap();
            let exe = compile_hlo_file(&client, &m.path("gls_select").unwrap()).unwrap();
            let k = m.get_usize("gls_k").unwrap();
            let n = m.get_usize("gls_n").unwrap();
            let rng = CounterRng::new(1);
            let u: Vec<f32> = (0..k * n).map(|i| rng.uniform(0, 0, i as u64) as f32).collect();
            let lit = |d: &[f32]| xla::Literal::vec1(d).reshape(&[k as i64, n as i64]).unwrap();
            let r = time_budget("pjrt-gls-select", Duration::from_secs(1), 10, || {
                std::hint::black_box(
                    execute_tuple(&exe, &[lit(&u), lit(&u), lit(&u)]).unwrap(),
                );
            });
            t.row(&[
                format!("gls_select artifact (K={k}, N={n})"),
                format!("{:.3}", r.per_iter.mean * 1e3),
                format!("{:.0}", 1.0 / r.per_iter.mean),
            ]);
            let mut gen = XorShift128::new(2);
            let q = gen_categorical(&mut gen, n);
            let p = gen_categorical(&mut gen, n);
            let r = time_budget("native-gls-select", Duration::from_secs(1), 10, || {
                std::hint::black_box(gls_serve::spec::gls::sample_gls(&p, &q, k, &rng, 0));
            });
            t.row(&[
                format!("gls_select native (K={k}, N={n})"),
                format!("{:.3}", r.per_iter.mean * 1e3),
                format!("{:.0}", 1.0 / r.per_iter.mean),
            ]);
            println!("## L1/L2 — PJRT artifact hot ops");
            t.print();
        }
    }
}
