//! Performance benchmarks over the serving hot path (§Perf deliverable).
//!
//! Measures, per layer:
//!   L3a  verify-only: GLS / SpecInfer / SpecTr block verification on
//!        synthetic BlockInputs (pure coordinator math, no model), plus the
//!        scalar-vs-kernel GLS comparison on top-k-50 truncated
//!        distributions (the paper's LLM regime);
//!   L3b  end-to-end engine blocks/s on the SimLm backend at several
//!        batch sizes (continuous-batching efficiency);
//!   L3c  serving stack requests/s through router + scheduler;
//!   L3d  persistent verify pool vs per-block scoped spawn at batch
//!        1/4/16 (K=8, N=2048, top-k 50) — the worker-pool acceptance
//!        pair, and the sweep behind the parallel-threshold calibration;
//!   L3e  server-global shared verify pool vs per-engine pools at
//!        workers ∈ {2, 4} (full serving stack): throughput AND live
//!        thread census — the shared pool must match or beat per-engine
//!        pooling while holding verify-thread count independent of the
//!        worker count;
//!   L1/L2 (with the `pjrt` feature and artifacts) PJRT forward latency
//!        per call and the GLS select artifact vs native.
//!
//! Run before/after every optimization; EXPERIMENTS.md §Perf records the
//! iteration log. Every case is also appended to `BENCH_perf.json`
//! (override the path with `BENCH_PERF_JSON`) so the perf trajectory is
//! machine-readable — CI smoke-checks that file's shape.

use std::time::Duration;

use gls_serve::bench::{time_budget, BenchResult, Table};
use gls_serve::coordinator::config::DEFAULT_PARALLEL_THRESHOLD;
use gls_serve::coordinator::engine::SpecDecodeEngine;
use gls_serve::coordinator::kv::PagedKvCache;
use gls_serve::coordinator::router::{Router, RoutingPolicy};
use gls_serve::coordinator::sequence::Request;
use gls_serve::coordinator::server::Server;
use gls_serve::coordinator::{EngineConfig, PoolScope, ServerConfig, VerifyBackend};
use gls_serve::model::backend::ModelPair;
use gls_serve::model::sampling::SamplingParams;
use gls_serve::model::sim::SimLm;
use gls_serve::perf::{CounterSnapshot, PerfCounters};
use gls_serve::spec::daliri::DaliriVerifier;
use gls_serve::spec::gls::GlsVerifier;
use gls_serve::spec::make_verifier;
use gls_serve::spec::specinfer::SpecInferVerifier;
use gls_serve::spec::spectr::SpecTrVerifier;
use gls_serve::spec::types::{BlockInput, BlockVerifier, Categorical, VerifierKind};
use gls_serve::stats::rng::{CounterRng, XorShift128};
use gls_serve::testkit::gen_categorical;

/// Flat JSON sink for the machine-readable perf log. Hand-rolled because
/// the environment is offline (no serde); the schema is deliberately
/// trivial: one array of flat entries plus a summary object.
struct PerfJson {
    entries: Vec<String>,
    summary: Vec<(String, f64)>,
}

impl PerfJson {
    fn new() -> Self {
        Self { entries: Vec::new(), summary: Vec::new() }
    }

    /// Append one flat entry. When a hardware-counter snapshot is present
    /// (already normalized per iteration/block by the caller), the entry
    /// carries the counter columns; otherwise the columns are simply
    /// absent — downstream tooling treats missing columns as "counters
    /// unavailable here", never as zero.
    fn entry(&mut self, section: &str, case: &str, r: &BenchResult, c: Option<&CounterSnapshot>) {
        let us = r.per_iter.mean * 1e6;
        let per_s = if r.per_iter.mean > 0.0 { 1.0 / r.per_iter.mean } else { 0.0 };
        let counters = match c {
            Some(c) => format!(
                ",\"cycles\":{},\"instructions\":{},\"ipc\":{:.3},\"llc_refs\":{},\"llc_misses\":{}",
                c.cycles,
                c.instructions,
                c.ipc(),
                c.llc_refs,
                c.llc_misses
            ),
            None => String::new(),
        };
        self.entries.push(format!(
            "{{\"section\":\"{}\",\"case\":\"{}\",\"us_per_iter\":{:.3},\"iters_per_s\":{:.3},\"iters\":{}{}}}",
            section, case, us, per_s, r.iters, counters
        ));
    }

    fn metric(&mut self, key: &str, value: f64) {
        self.summary.push((key.to_string(), value));
    }

    fn write(&self) {
        let path = std::env::var("BENCH_PERF_JSON").unwrap_or_else(|_| "BENCH_perf.json".into());
        let summary: Vec<String> = self
            .summary
            .iter()
            .map(|(k, v)| format!("\"{k}\":{v:.3}"))
            .collect();
        let doc = format!(
            "{{\n\"schema\":\"gls-serve/BENCH_perf/v1\",\n\"entries\":[\n{}\n],\n\"summary\":{{{}}}\n}}\n",
            self.entries.join(",\n"),
            summary.join(",")
        );
        match std::fs::write(&path, doc) {
            Ok(()) => println!("\nwrote {path}"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}

/// Hardware counters over `iters` runs of `f`, normalized to one of the
/// `iters * denom` logical blocks executed (`denom` = blocks per run).
/// `None` when counters are unavailable — the reason was already printed
/// once at startup by the probe.
///
/// Counters are thread-scoped (this thread only): for pooled engine cases
/// they cover the engine thread's dispatch + draft recording, not the
/// worker threads — a deliberate, documented scope (EXPERIMENTS.md §Perf,
/// "Counter methodology").
fn counters_per_block(iters: u64, denom: u64, mut f: impl FnMut()) -> Option<CounterSnapshot> {
    let mut c = PerfCounters::open().ok()?;
    c.start().ok()?;
    for _ in 0..iters {
        f();
    }
    let s = c.stop().ok()?;
    let d = (iters * denom).max(1);
    Some(CounterSnapshot {
        cycles: s.cycles / d,
        instructions: s.instructions / d,
        llc_refs: s.llc_refs / d,
        llc_misses: s.llc_misses / d,
    })
}

/// Push the standard per-block counter metrics into the summary.
fn counter_metrics(json: &mut PerfJson, prefix: &str, c: &CounterSnapshot) {
    json.metric(&format!("{prefix}_cycles_per_block_k8_n2048_topk50"), c.cycles as f64);
    json.metric(&format!("{prefix}_instructions_per_block_k8_n2048_topk50"), c.instructions as f64);
    json.metric(&format!("{prefix}_ipc_k8_n2048_topk50"), c.ipc());
    json.metric(&format!("{prefix}_llc_refs_per_block_k8_n2048_topk50"), c.llc_refs as f64);
    json.metric(&format!("{prefix}_llc_misses_per_block_k8_n2048_topk50"), c.llc_misses as f64);
}

fn synth_block(k: usize, l: usize, n: usize, seed: u64) -> BlockInput {
    let mut gen = XorShift128::new(seed);
    let p: Vec<Categorical> = (0..l).map(|_| gen_categorical(&mut gen, n)).collect();
    let q: Vec<Categorical> = (0..=l).map(|_| gen_categorical(&mut gen, n)).collect();
    let rng = CounterRng::new(seed);
    let mut draft_tokens = vec![Vec::with_capacity(l); k];
    for kk in 0..k {
        for j in 0..l {
            draft_tokens[kk].push(p[j].sample_race(&rng, j as u64, kk as u64) as u32);
        }
    }
    BlockInput { draft_tokens: draft_tokens.into(), draft_dists: vec![p; k], target_dists: vec![q; k] }
}

/// Block with top-k truncated draft/target distributions — the paper's LLM
/// post-processing (top-k 50), which is where the sparse-support kernel
/// earns its keep on large vocabularies.
fn synth_block_topk(k: usize, l: usize, n: usize, top_k: usize, seed: u64) -> BlockInput {
    let mut gen = XorShift128::new(seed);
    let mut rand_topk = |temp: f64| {
        let logits: Vec<f32> = (0..n).map(|_| (gen.next_f64() * 8.0) as f32).collect();
        Categorical::from_logits(&logits, temp, Some(top_k))
    };
    let p: Vec<Categorical> = (0..l).map(|_| rand_topk(1.0)).collect();
    let q: Vec<Categorical> = (0..=l).map(|_| rand_topk(1.0)).collect();
    let rng = CounterRng::new(seed);
    let mut draft_tokens = vec![Vec::with_capacity(l); k];
    for kk in 0..k {
        for j in 0..l {
            draft_tokens[kk].push(p[j].sample_race(&rng, j as u64, kk as u64) as u32);
        }
    }
    BlockInput { draft_tokens: draft_tokens.into(), draft_dists: vec![p; k], target_dists: vec![q; k] }
}

fn main() {
    let budget = Duration::from_millis(400);
    let mut json = PerfJson::new();
    println!("# §Perf — serving hot-path benchmarks\n");

    // One probe up front; every section then measures or skips uniformly.
    // A skip is labeled, never silent: CI greps this line to distinguish
    // "counters forbidden here" from "harness broke".
    let counters_on = match gls_serve::perf::probe() {
        Ok(()) => {
            println!("perf-counters: available — cycles/instructions/IPC/LLC columns attached\n");
            true
        }
        Err(e) => {
            println!("perf-counters: unavailable ({e}) — counter columns omitted\n");
            false
        }
    };
    json.metric("perf_counters_available", if counters_on { 1.0 } else { 0.0 });

    // ---------------------------------------------------------- L3a verify
    {
        let mut t = Table::new(&["verifier", "K", "N(vocab)", "µs/block", "blocks/s"]);
        for &vk in &[VerifierKind::Gls, VerifierKind::SpecInfer, VerifierKind::SpecTr] {
            for &(k, n) in &[(4usize, 64usize), (8, 64), (8, 259), (8, 2048)] {
                let v = make_verifier(vk);
                let input = synth_block(k, 4, n, 42);
                let rng = CounterRng::new(7);
                let mut slot = 0u64;
                let case = format!("{}-K{k}-N{n}", vk.name());
                let r = time_budget(&case, budget, 20, || {
                    std::hint::black_box(v.verify_block(&input, &rng, slot));
                    slot = slot.wrapping_add(5);
                });
                json.entry("L3a", &case, &r, None);
                t.row(&[
                    vk.name().to_string(),
                    k.to_string(),
                    n.to_string(),
                    format!("{:.1}", r.per_iter.mean * 1e6),
                    format!("{:.0}", 1.0 / r.per_iter.mean),
                ]);
            }
        }
        println!("## L3a — block verification (coupling math only)");
        t.print();
        println!();
    }

    // ------------------------------------- L3a' scalar vs kernel (top-k-50)
    // The acceptance-criterion case: GLS verify_block at K=8, N=2048 with
    // top-k-50 distributions — scalar full-alphabet baseline vs the
    // sparse-support workspace kernel. Outcomes are bit-identical
    // (tests/kernel_parity.rs); only the wall clock may differ.
    {
        let mut t = Table::new(&["path", "K", "N", "top-k", "µs/block", "blocks/s"]);
        let (k, n, top_k, l) = (8usize, 2048usize, 50usize, 4usize);
        let input = synth_block_topk(k, l, n, top_k, 99);
        let rng = CounterRng::new(13);
        let cond = GlsVerifier::conditional();

        let mut slot = 0u64;
        let r_scalar = time_budget("gls-scalar-K8-N2048-topk50", budget, 20, || {
            std::hint::black_box(cond.verify_block_scalar(&input, &rng, slot));
            slot = slot.wrapping_add(5);
        });
        let mut slot = 0u64;
        let v = make_verifier(VerifierKind::Gls);
        let r_kernel = time_budget("gls-kernel-K8-N2048-topk50", budget, 20, || {
            std::hint::black_box(v.verify_block(&input, &rng, slot));
            slot = slot.wrapping_add(5);
        });

        // Parity spot check inside the bench itself (same slot, same rng).
        assert_eq!(
            cond.verify_block_scalar(&input, &rng, 12345),
            v.verify_block(&input, &rng, 12345),
            "kernel/scalar divergence — see tests/kernel_parity.rs"
        );

        // Counter pass (separate from the timing pass, same workload):
        // per-block cycles/instructions/IPC/LLC for the acceptance pair.
        let (c_scalar, c_kernel) = if counters_on {
            let mut slot = 0u64;
            let cs = counters_per_block(400, 1, || {
                std::hint::black_box(cond.verify_block_scalar(&input, &rng, slot));
                slot = slot.wrapping_add(5);
            });
            let mut slot = 0u64;
            let ck = counters_per_block(400, 1, || {
                std::hint::black_box(v.verify_block(&input, &rng, slot));
                slot = slot.wrapping_add(5);
            });
            (cs, ck)
        } else {
            (None, None)
        };

        let scalar_us = r_scalar.per_iter.mean * 1e6;
        let kernel_us = r_kernel.per_iter.mean * 1e6;
        json.entry("L3a-kernel", "gls-scalar-K8-N2048-topk50", &r_scalar, c_scalar.as_ref());
        json.entry("L3a-kernel", "gls-kernel-K8-N2048-topk50", &r_kernel, c_kernel.as_ref());
        json.metric("scalar_us_per_block_k8_n2048_topk50", scalar_us);
        json.metric("kernel_us_per_block_k8_n2048_topk50", kernel_us);
        json.metric("kernel_speedup_k8_n2048_topk50", scalar_us / kernel_us);
        if let Some(c) = &c_scalar {
            counter_metrics(&mut json, "scalar", c);
        }
        if let Some(c) = &c_kernel {
            counter_metrics(&mut json, "kernel", c);
        }
        if let (Some(cs), Some(ck)) = (&c_scalar, &c_kernel) {
            println!(
                "counters: scalar {} cyc/blk (IPC {:.2}, LLC {}/{}) | kernel {} cyc/blk (IPC {:.2}, LLC {}/{})",
                cs.cycles, cs.ipc(), cs.llc_misses, cs.llc_refs,
                ck.cycles, ck.ipc(), ck.llc_misses, ck.llc_refs,
            );
        }

        for (name, r) in [("scalar", &r_scalar), ("kernel", &r_kernel)] {
            t.row(&[
                name.to_string(),
                k.to_string(),
                n.to_string(),
                top_k.to_string(),
                format!("{:.1}", r.per_iter.mean * 1e6),
                format!("{:.0}", 1.0 / r.per_iter.mean),
            ]);
        }
        println!("## L3a' — GLS verify_block, scalar vs sparse-support kernel");
        t.print();
        println!("speedup: {:.2}×\n", scalar_us / kernel_us);
    }

    // ---------------------------- L3a'' ported baselines, scalar vs kernel
    // Every ported verifier (SpecTr, SpecInfer, Daliri) carries its own
    // scalar-vs-kernel pair at the same LLM shape (K=8, N=2048, top-k-50).
    // Outcomes are bit-identical (tests/kernel_parity.rs per-verifier
    // suites); CI's perf-smoke job gates each speedup at ≥3×.
    {
        let mut t = Table::new(&["verifier", "path", "µs/block", "blocks/s", "speedup"]);
        let (k, n, top_k, l) = (8usize, 2048usize, 50usize, 4usize);
        let input = synth_block_topk(k, l, n, top_k, 123);
        let rng = CounterRng::new(29);
        let spectr = SpecTrVerifier::new();
        let specinfer = SpecInferVerifier::new();
        let daliri = DaliriVerifier::new();

        let bench_pair = |name: &str,
                              json: &mut PerfJson,
                              t: &mut Table,
                              scalar_fn: &dyn Fn(u64),
                              kernel_fn: &dyn Fn(u64)| {
            let mut slot = 0u64;
            let case_scalar = format!("{name}-scalar-K8-N2048-topk50");
            let r_scalar = time_budget(&case_scalar, budget, 20, || {
                scalar_fn(slot);
                slot = slot.wrapping_add(5);
            });
            let mut slot = 0u64;
            let case_kernel = format!("{name}-kernel-K8-N2048-topk50");
            let r_kernel = time_budget(&case_kernel, budget, 20, || {
                kernel_fn(slot);
                slot = slot.wrapping_add(5);
            });
            let measure = |f: &dyn Fn(u64)| -> Option<CounterSnapshot> {
                if !counters_on {
                    return None;
                }
                let mut slot = 0u64;
                counters_per_block(400, 1, || {
                    f(slot);
                    slot = slot.wrapping_add(5);
                })
            };
            let c_scalar = measure(scalar_fn);
            let c_kernel = measure(kernel_fn);
            let scalar_us = r_scalar.per_iter.mean * 1e6;
            let kernel_us = r_kernel.per_iter.mean * 1e6;
            json.entry("L3a-ported", &case_scalar, &r_scalar, c_scalar.as_ref());
            json.entry("L3a-ported", &case_kernel, &r_kernel, c_kernel.as_ref());
            json.metric(&format!("{name}_scalar_us_per_block_k8_n2048_topk50"), scalar_us);
            json.metric(&format!("{name}_kernel_us_per_block_k8_n2048_topk50"), kernel_us);
            json.metric(&format!("{name}_speedup_k8_n2048_topk50"), scalar_us / kernel_us);
            t.row(&[
                name.to_string(),
                "scalar".into(),
                format!("{scalar_us:.1}"),
                format!("{:.0}", 1.0 / r_scalar.per_iter.mean),
                String::new(),
            ]);
            t.row(&[
                String::new(),
                "kernel".into(),
                format!("{kernel_us:.1}"),
                format!("{:.0}", 1.0 / r_kernel.per_iter.mean),
                format!("{:.2}×", scalar_us / kernel_us),
            ]);
        };

        bench_pair(
            "spectr",
            &mut json,
            &mut t,
            &|s| {
                std::hint::black_box(spectr.verify_block_scalar(&input, &rng, s));
            },
            &|s| {
                std::hint::black_box(spectr.verify_block(&input, &rng, s));
            },
        );
        bench_pair(
            "specinfer",
            &mut json,
            &mut t,
            &|s| {
                std::hint::black_box(specinfer.verify_block_scalar(&input, &rng, s));
            },
            &|s| {
                std::hint::black_box(specinfer.verify_block(&input, &rng, s));
            },
        );
        bench_pair(
            "daliri",
            &mut json,
            &mut t,
            &|s| {
                std::hint::black_box(daliri.verify_block_scalar(&input, &rng, s));
            },
            &|s| {
                std::hint::black_box(daliri.verify_block(&input, &rng, s));
            },
        );

        // Parity spot checks inside the bench itself (same slot, same rng).
        assert_eq!(
            spectr.verify_block_scalar(&input, &rng, 54321),
            spectr.verify_block(&input, &rng, 54321),
            "spectr kernel/scalar divergence — see tests/kernel_parity.rs"
        );
        assert_eq!(
            specinfer.verify_block_scalar(&input, &rng, 54321),
            specinfer.verify_block(&input, &rng, 54321),
            "specinfer kernel/scalar divergence — see tests/kernel_parity.rs"
        );
        assert_eq!(
            daliri.verify_block_scalar(&input, &rng, 54321),
            daliri.verify_block(&input, &rng, 54321),
            "daliri kernel/scalar divergence — see tests/kernel_parity.rs"
        );

        println!("## L3a'' — ported baselines, scalar vs workspace kernel");
        t.print();
        println!();
    }

    // ----------------------------------------------------- L3b engine step
    {
        let mut t = Table::new(&["batch", "K", "blocks/s", "tokens/s"]);
        for &batch in &[1usize, 4, 16] {
            for &k in &[4usize, 8] {
                let (d, tg) = SimLm::pair(64, 5, 2.0);
                let cfg = EngineConfig {
                    num_drafts: k,
                    block_len: 4,
                    verifier: VerifierKind::Gls,
                    target_params: SamplingParams::new(1.0, Some(50)),
                    draft_params: vec![SamplingParams::new(1.0, Some(50))],
                    max_seq_len: 4096,
                    seed: 3,
                    ..EngineConfig::default()
                };
                let mut eng = SpecDecodeEngine::new(
                    cfg,
                    ModelPair::new(Box::new(d), Box::new(tg)),
                    PagedKvCache::new(1 << 14, 16),
                );
                let mut seqs: Vec<_> = (0..batch)
                    .map(|i| {
                        let req = Request::new(i as u64, vec![1, 2, 3], 3000);
                        let s = gls_serve::coordinator::sequence::SequenceState::from_request(&req);
                        eng.kv.register(s.id, 3, 3103, 5).unwrap();
                        s
                    })
                    .collect();
                let case = format!("engine-B{batch}-K{k}");
                let r = time_budget(&case, budget, 10, || {
                    let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
                    std::hint::black_box(eng.step_blocks(&mut refs));
                });
                json.entry("L3b", &case, &r, None);
                let blocks_per_s = batch as f64 / r.per_iter.mean;
                let be = eng.metrics.block_efficiency();
                t.row(&[
                    batch.to_string(),
                    k.to_string(),
                    format!("{:.0}", blocks_per_s),
                    format!("{:.0}", blocks_per_s * be),
                ]);
            }
        }
        println!("## L3b — engine blocks/s (SimLm backend, L = 4)");
        t.print();
        println!();
    }

    // ------------------------------------------- L3d pool vs scoped spawn
    // The persistent-worker-pool acceptance case: end-to-end `step_blocks`
    // at the LLM shape (K=8, N=2048, top-k-50) under the pooled backend vs
    // the per-block scoped-spawn baseline it replaced. Batch 1 never fans
    // out (both backends serialize — the no-regression control); batches 4
    // and 16 clear the calibrated threshold, so the delta is pure thread
    // lifecycle + panel-handoff reuse. Outputs are bit-identical
    // (tests/kernel_parity.rs pool grid); only the wall clock may differ.
    // The same sweep, re-run with `parallel_threshold` varied, is the
    // calibration procedure for EngineConfig::parallel_threshold
    // (EXPERIMENTS.md §Perf).
    {
        let mut t = Table::new(&["batch", "backend", "blocks/s", "pool/spawn"]);
        let (k, l, top_k, vocab) = (8usize, 4usize, 50usize, 2048usize);
        // Longer budget than the micro-cases: the CI gate compares the two
        // backends' wall clocks directly, so tighter means matter more
        // than total bench runtime here.
        let budget = Duration::from_millis(900);
        let mut bench_backend = |batch: usize,
                                 backend: VerifyBackend,
                                 json: &mut PerfJson|
         -> (f64, Option<CounterSnapshot>) {
            let (d, tg) = SimLm::pair(vocab, 5, 2.0);
            let cfg = EngineConfig {
                num_drafts: k,
                block_len: l,
                verifier: VerifierKind::Gls,
                target_params: SamplingParams::new(1.0, Some(top_k)),
                draft_params: vec![SamplingParams::new(1.0, Some(top_k))],
                max_seq_len: 4096,
                seed: 3,
                verify_backend: backend,
                ..EngineConfig::default()
            };
            let mut eng = SpecDecodeEngine::new(
                cfg,
                ModelPair::new(Box::new(d), Box::new(tg)),
                PagedKvCache::new(1 << 14, 16),
            );
            let mut seqs: Vec<_> = (0..batch)
                .map(|i| {
                    let req = Request::new(i as u64, vec![1, 2, 3], 3000);
                    let s = gls_serve::coordinator::sequence::SequenceState::from_request(&req);
                    eng.kv.register(s.id, 3, 3103, 5).unwrap();
                    s
                })
                .collect();
            let case = format!("engine-{}-B{batch}", backend.name());
            let r = time_budget(&case, budget, 10, || {
                let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
                std::hint::black_box(eng.step_blocks(&mut refs));
            });
            // Counter pass over the same warmed engine. Thread-scoped: on
            // the pooled backend this is the engine thread's share
            // (dispatch, draft recording, epilogue) per verified block.
            let c = if counters_on {
                counters_per_block(10, batch as u64, || {
                    let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
                    std::hint::black_box(eng.step_blocks(&mut refs));
                })
            } else {
                None
            };
            json.entry("L3d", &case, &r, c.as_ref());
            (batch as f64 / r.per_iter.mean, c)
        };
        for &batch in &[1usize, 4, 16] {
            let (spawn_bps, c_spawn) = bench_backend(batch, VerifyBackend::Spawn, &mut json);
            let (pool_bps, c_pool) = bench_backend(batch, VerifyBackend::Pool, &mut json);
            let speedup = pool_bps / spawn_bps;
            json.metric(&format!("engine_spawn_blocks_per_s_b{batch}"), spawn_bps);
            json.metric(&format!("engine_pool_blocks_per_s_b{batch}"), pool_bps);
            json.metric(&format!("engine_pool_vs_spawn_speedup_b{batch}"), speedup);
            if batch == 4 {
                // The per-verified-block counter columns for the pooled
                // path at the acceptance shape (K=8, N=2048, top-k 50).
                if let Some(c) = &c_pool {
                    counter_metrics(&mut json, "pool", c);
                }
                if let Some(c) = &c_spawn {
                    counter_metrics(&mut json, "spawn", c);
                }
            }
            t.row(&[
                batch.to_string(),
                "spawn".into(),
                format!("{spawn_bps:.0}"),
                String::new(),
            ]);
            t.row(&[
                String::new(),
                "pool".into(),
                format!("{pool_bps:.0}"),
                format!("{speedup:.2}×"),
            ]);
        }
        println!("## L3d — engine step_blocks: persistent pool vs per-block spawn (K=8, N=2048, top-k 50)");
        t.print();
        println!();
    }

    // --------------------- L3d' parallel-threshold calibration sweep
    // The measurement behind DEFAULT_PARALLEL_THRESHOLD: serial stepping
    // vs forced pool fan-out at batch 4 (K=8, L=4, top-k 50) across vocab
    // sizes, i.e. across per-sequence work `k·(l+1)·vocab` — the exact
    // quantity the engine's dispatch gate compares against the threshold.
    // The crossover (smallest work where the pool first wins) is the
    // calibrated threshold; the shipped default rounds it UP to the next
    // power of two, biasing toward serial where fan-out wins nothing
    // (EXPERIMENTS.md §Perf, "Threshold sweep").
    {
        let mut t = Table::new(&["vocab", "work", "serial blk/s", "pool blk/s", "pool/serial"]);
        let (k, l, top_k, batch) = (8usize, 4usize, 50usize, 4usize);
        let budget = Duration::from_millis(500);
        let mut bench_sweep = |vocab: usize, backend: VerifyBackend, json: &mut PerfJson| -> f64 {
            let (d, tg) = SimLm::pair(vocab, 5, 2.0);
            let cfg = EngineConfig {
                num_drafts: k,
                block_len: l,
                verifier: VerifierKind::Gls,
                target_params: SamplingParams::new(1.0, Some(top_k)),
                draft_params: vec![SamplingParams::new(1.0, Some(top_k))],
                max_seq_len: 4096,
                seed: 3,
                verify_backend: backend,
                // Pin the dispatch decision instead of letting the gate
                // make it: the sweep measures both sides of the decision
                // at every work size, so the gate must not veto either.
                parallel_threshold: 0,
                ..EngineConfig::default()
            };
            let mut eng = SpecDecodeEngine::new(
                cfg,
                ModelPair::new(Box::new(d), Box::new(tg)),
                PagedKvCache::new(1 << 14, 16),
            );
            let mut seqs: Vec<_> = (0..batch)
                .map(|i| {
                    let req = Request::new(i as u64, vec![1, 2, 3], 3000);
                    let s = gls_serve::coordinator::sequence::SequenceState::from_request(&req);
                    eng.kv.register(s.id, 3, 3103, 5).unwrap();
                    s
                })
                .collect();
            let case = format!("sweep-{}-V{vocab}", backend.name());
            let r = time_budget(&case, budget, 10, || {
                let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
                std::hint::black_box(eng.step_blocks(&mut refs));
            });
            json.entry("L3d-sweep", &case, &r, None);
            batch as f64 / r.per_iter.mean
        };
        let mut crossover_work: Option<usize> = None;
        for &vocab in &[64usize, 128, 256, 512, 1024, 2048] {
            let work = k * (l + 1) * vocab;
            let serial_bps = bench_sweep(vocab, VerifyBackend::Serial, &mut json);
            let pool_bps = bench_sweep(vocab, VerifyBackend::Pool, &mut json);
            json.metric(&format!("threshold_sweep_serial_blocks_per_s_v{vocab}"), serial_bps);
            json.metric(&format!("threshold_sweep_pool_blocks_per_s_v{vocab}"), pool_bps);
            if pool_bps > serial_bps && crossover_work.is_none() {
                crossover_work = Some(work);
            }
            t.row(&[
                vocab.to_string(),
                work.to_string(),
                format!("{serial_bps:.0}"),
                format!("{pool_bps:.0}"),
                format!("{:.2}×", pool_bps / serial_bps),
            ]);
        }
        // 0 = the pool never won inside the swept range (threshold should
        // then sit above the largest swept work, not inside it).
        json.metric("threshold_sweep_crossover_work", crossover_work.map_or(0.0, |w| w as f64));
        json.metric("threshold_sweep_shipped_default", DEFAULT_PARALLEL_THRESHOLD as f64);
        println!("## L3d' — parallel-threshold calibration sweep (batch 4, K=8, L=4, top-k 50)");
        t.print();
        match crossover_work {
            Some(w) => println!(
                "crossover work {w}; shipped DEFAULT_PARALLEL_THRESHOLD = {DEFAULT_PARALLEL_THRESHOLD}\n"
            ),
            None => println!(
                "no crossover in swept range; shipped DEFAULT_PARALLEL_THRESHOLD = {DEFAULT_PARALLEL_THRESHOLD}\n"
            ),
        }
    }

    // --------------------------------------------------- L3c serving stack
    {
        let mut t = Table::new(&["workers", "policy", "req/s", "gen tok/s", "p95 ms"]);
        for &workers in &[1usize, 2, 4] {
            for policy in [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded] {
                let sc = ServerConfig { workers, ..ServerConfig::default() };
                let ec = EngineConfig {
                    num_drafts: 4,
                    block_len: 4,
                    verifier: VerifierKind::Gls,
                    max_seq_len: 256,
                    ..EngineConfig::default()
                };
                let n_req = 48;
                let workload: Vec<(Vec<u32>, usize)> =
                    (0..n_req).map(|i| (vec![i as u32, 1, 2], 32)).collect();
                let report = Server::serve_all(
                    &sc,
                    &ec,
                    policy,
                    |_| {
                        let (d, tg) = SimLm::pair(64, 9, 2.0);
                        ModelPair::new(Box::new(d), Box::new(tg))
                    },
                    workload,
                );
                let req_s = n_req as f64 / report.wall.as_secs_f64();
                json.entries.push(format!(
                    "{{\"section\":\"L3c\",\"case\":\"serve-W{}-{:?}\",\"req_per_s\":{:.3},\"tok_per_s\":{:.3},\"p95_ms\":{:.3}}}",
                    workers,
                    policy,
                    req_s,
                    report.token_rate(),
                    report.p95_latency() * 1e3
                ));
                t.row(&[
                    workers.to_string(),
                    format!("{policy:?}"),
                    format!("{:.0}", req_s),
                    format!("{:.0}", report.token_rate()),
                    format!("{:.1}", report.p95_latency() * 1e3),
                ]);
            }
        }
        println!("## L3c — serving stack throughput");
        t.print();
        println!();
    }

    // ---------------------------------- L3e shared vs per-engine verify pool
    // The server-global pool acceptance case: the full serving stack
    // (router → scheduler → engine) at workers ∈ {2, 4}, verify pool
    // forced hot (`parallel_threshold = 0`, explicit pool size), under
    // `pool_scope = server` (ONE pool, epoch-tagged tickets) vs
    // `pool_scope = engine` (one pool per worker — the PR 4 topology).
    // Tokens are bit-identical (tests/pool_shared.rs); the deltas are
    // wall clock and the live thread census, which CI gates: shared
    // throughput ≥ per-engine at every worker count, shared thread count
    // ≤ per-engine. Batch-1 has no analogue here (single-sequence batches
    // never fan out); the L3d B1 case remains the no-regression control.
    {
        let mut t = Table::new(&["workers", "pool scope", "gen tok/s", "threads", "shared/engine"]);
        // Shared helper with tests/pool_shared.rs; -1 = census unavailable
        // (non-Linux), which the CI gate treats as "skip the thread check".
        let thread_census =
            || -> f64 { gls_serve::testkit::thread_census().map_or(-1.0, |n| n as f64) };
        let verify_workers = 4usize;
        let mut serve = |workers: usize, scope: PoolScope| -> (f64, f64) {
            let sc = ServerConfig {
                workers,
                max_batch: 8,
                batch_deadline: Duration::from_millis(1),
                max_running: 16,
                kv_pages: 1 << 14,
                kv_page_size: 16,
                pool_scope: scope,
                ..ServerConfig::default()
            };
            let ec = EngineConfig {
                num_drafts: 4,
                block_len: 4,
                verifier: VerifierKind::Gls,
                target_params: SamplingParams::new(1.0, Some(50)),
                draft_params: vec![SamplingParams::new(1.0, Some(50))],
                max_seq_len: 512,
                seed: 3,
                parallel_threshold: 0,
                verify_workers,
                verify_backend: VerifyBackend::Pool,
                ..EngineConfig::default()
            };
            let n_req = 12 * workers as u64;
            let max_new = 40usize;
            let t0 = std::time::Instant::now();
            let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, |_| {
                let (d, tg) = SimLm::pair(512, 5, 2.0);
                ModelPair::new(Box::new(d), Box::new(tg))
            });
            for i in 0..n_req {
                router.submit(Request::new(i, vec![1, 2, (i % 7) as u32], max_new));
            }
            let mut generated = 0usize;
            let mut threads = thread_census();
            for _ in 0..n_req {
                let res = router.results_rx.recv().expect("worker alive");
                generated += res.tokens.len() - 3;
                threads = threads.max(thread_census());
            }
            let wall = t0.elapsed().as_secs_f64();
            router.shutdown();
            (generated as f64 / wall, threads)
        };
        for &workers in &[2usize, 4] {
            let (shared_tps, shared_threads) = serve(workers, PoolScope::Server);
            let (engine_tps, engine_threads) = serve(workers, PoolScope::Engine);
            let ratio = shared_tps / engine_tps;
            json.entries.push(format!(
                "{{\"section\":\"L3e\",\"case\":\"serve-shared-pool-W{workers}\",\"tok_per_s\":{shared_tps:.3},\"threads\":{shared_threads}}}"
            ));
            json.entries.push(format!(
                "{{\"section\":\"L3e\",\"case\":\"serve-engine-pool-W{workers}\",\"tok_per_s\":{engine_tps:.3},\"threads\":{engine_threads}}}"
            ));
            json.metric(&format!("serve_shared_pool_tok_per_s_w{workers}"), shared_tps);
            json.metric(&format!("serve_engine_pool_tok_per_s_w{workers}"), engine_tps);
            json.metric(&format!("serve_shared_vs_engine_pool_ratio_w{workers}"), ratio);
            json.metric(&format!("serve_shared_pool_threads_w{workers}"), shared_threads);
            json.metric(&format!("serve_engine_pool_threads_w{workers}"), engine_threads);
            t.row(&[
                workers.to_string(),
                "server (shared)".into(),
                format!("{shared_tps:.0}"),
                format!("{shared_threads:.0}"),
                format!("{ratio:.2}×"),
            ]);
            t.row(&[
                String::new(),
                "engine (per-worker)".into(),
                format!("{engine_tps:.0}"),
                format!("{engine_threads:.0}"),
                String::new(),
            ]);
        }
        println!("## L3e — serving stack: server-global shared pool vs per-engine pools");
        t.print();
        println!();
    }

    // ------------------------------------------------ L1/L2 PJRT artifacts
    pjrt_section(&mut json);

    json.write();
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_section(_json: &mut PerfJson) {
    println!("## L1/L2 — skipped (built without the `pjrt` feature)");
}

#[cfg(feature = "pjrt")]
fn pjrt_section(json: &mut PerfJson) {
    use gls_serve::model::backend::LmBackend;
    match gls_serve::runtime::Artifacts::discover() {
        Err(e) => println!("## L1/L2 — skipped (no artifacts: {e})"),
        Ok(m) => {
            use gls_serve::runtime::PjrtLm;
            let mut target = PjrtLm::load(&m, "target_lm").expect("target");
            let seqs: Vec<Vec<u32>> = (0..8).map(|i| vec![256, i, 1, 2, 3, 4]).collect();
            let r = time_budget("pjrt-forward-B8", Duration::from_secs(2), 5, || {
                std::hint::black_box(target.next_logits(&seqs));
            });
            json.entry("L1L2", "pjrt-forward-B8", &r, None);
            let mut t = Table::new(&["op", "ms/call", "rows/s"]);
            t.row(&[
                "target_lm forward (B=8, S=96)".into(),
                format!("{:.2}", r.per_iter.mean * 1e3),
                format!("{:.0}", 8.0 / r.per_iter.mean),
            ]);

            // GLS select artifact vs native Rust implementation.
            use gls_serve::runtime::client::{compile_hlo_file, execute_tuple, new_client};
            let client = new_client().unwrap();
            let exe = compile_hlo_file(&client, &m.path("gls_select").unwrap()).unwrap();
            let k = m.get_usize("gls_k").unwrap();
            let n = m.get_usize("gls_n").unwrap();
            let rng = CounterRng::new(1);
            let u: Vec<f32> = (0..k * n).map(|i| rng.uniform(0, 0, i as u64) as f32).collect();
            let lit = |d: &[f32]| xla::Literal::vec1(d).reshape(&[k as i64, n as i64]).unwrap();
            let r = time_budget("pjrt-gls-select", Duration::from_secs(1), 10, || {
                std::hint::black_box(
                    execute_tuple(&exe, &[lit(&u), lit(&u), lit(&u)]).unwrap(),
                );
            });
            json.entry("L1L2", "pjrt-gls-select", &r, None);
            t.row(&[
                format!("gls_select artifact (K={k}, N={n})"),
                format!("{:.3}", r.per_iter.mean * 1e3),
                format!("{:.0}", 1.0 / r.per_iter.mean),
            ]);
            let mut gen = XorShift128::new(2);
            let q = gen_categorical(&mut gen, n);
            let p = gen_categorical(&mut gen, n);
            let r = time_budget("native-gls-select", Duration::from_secs(1), 10, || {
                std::hint::black_box(gls_serve::spec::gls::sample_gls(&p, &q, k, &rng, 0));
            });
            json.entry("L1L2", "native-gls-select", &r, None);
            t.row(&[
                format!("gls_select native (K={k}, N={n})"),
                format!("{:.3}", r.per_iter.mean * 1e3),
                format!("{:.0}", 1.0 / r.per_iter.mean),
            ]);
            println!("## L1/L2 — PJRT artifact hot ops");
            t.print();
        }
    }
}
