//! Figure 2 + Tables 5/6 reproduction: the synthetic Gaussian source.
//!
//! (a)–(c): matching probability vs rate (L_max ∈ 2¹..2⁶) and number of
//! decoders K ∈ {1..4}, for GLS with side information vs the
//! shared-randomness baseline. (d): rate-distortion curves — per (K, L_max)
//! the distortion is minimized over the paper's σ²_{W|A} grid.
//! Also prints the Prop. 4 lower bound next to the measured match rate.
//!
//! Expected shape: match probability ↑ in rate and (for GLS) in K;
//! baseline barely moves with K; distortion ↓ with rate, GLS < baseline
//! for K > 1 with the gap largest at low rates; equal at K = 1.

use gls_serve::bench::Table;
use gls_serve::compression::bounds::gaussian_prop4_bound;
use gls_serve::compression::codec::RandomnessMode;
use gls_serve::compression::gaussian::{best_over_distortion_grid, run_gaussian, GaussianSource};

fn main() {
    let quick = std::env::var("GLS_BENCH_QUICK").is_ok();
    let n_samples = if quick { 1 << 10 } else { 1 << 12 };
    let trials: u64 = if quick { 200 } else { 500 };
    let l_maxes: Vec<u64> = vec![2, 4, 8, 16, 32, 64];
    let ks: Vec<usize> = vec![1, 2, 3, 4];

    println!("# Figure 2 (a)–(c) — matching probability (σ²_W|A = 0.005, σ²_T|A = 0.5)");
    println!("# N = {n_samples} importance samples, {trials} trials per cell\n");
    let src = GaussianSource::paper_default(0.005);

    let mut t = Table::new(&[
        "L_max", "rate(b)", "K", "GLS match", "BL match", "Prop4 bound",
    ]);
    for &l_max in &l_maxes {
        for &k in &ks {
            let gls =
                run_gaussian(src, k, l_max, n_samples, trials, 7, RandomnessMode::Independent);
            let bl = run_gaussian(src, k, l_max, n_samples, trials, 7, RandomnessMode::Shared);
            let bound = gaussian_prop4_bound(src, k, l_max, 4000, 3);
            t.row(&[
                l_max.to_string(),
                format!("{:.0}", (l_max as f64).log2()),
                k.to_string(),
                format!("{:.3}", gls.match_rate),
                format!("{:.3}", bl.match_rate),
                format!("{:.3}", bound),
            ]);
        }
    }
    t.print();

    println!("\n# Figure 2 (d) + Tables 5/6 — rate-distortion (best σ²_W|A per cell)\n");
    let mut rd = Table::new(&[
        "K", "L_max", "GLS σ²_W|A*", "GLS dist (dB)", "BL σ²_W|A*", "BL dist (dB)",
    ]);
    let rd_trials = if quick { 150 } else { 250 };
    for &k in &ks {
        for &l_max in &l_maxes {
            let g = best_over_distortion_grid(
                k, l_max, n_samples, rd_trials, 7, RandomnessMode::Independent,
            );
            let b = best_over_distortion_grid(
                k, l_max, n_samples, rd_trials, 7, RandomnessMode::Shared,
            );
            rd.row(&[
                k.to_string(),
                l_max.to_string(),
                format!("{:.3}", g.var_w_given_a),
                format!("{:.2}", g.mse_db),
                format!("{:.3}", b.var_w_given_a),
                format!("{:.2}", b.mse_db),
            ]);
        }
    }
    rd.print();
    println!(
        "\nshape checks: GLS match ↑ in K; baseline ~flat in K; distortion ↓ with rate;\n\
         GLS ≤ BL distortion for K > 1 (gap largest at low rate); equal at K = 1."
    );
}
