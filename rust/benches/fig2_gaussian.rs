//! Figure 2 + Tables 5/6 reproduction — now doubling as the Gaussian
//! compression throughput bench.
//!
//! Part 1 races the three pipelines over one identical request batch:
//! the retained scalar reference (seed-style, re-materializing the shared
//! randomness O((K+2)·N) times per block), the kernel path (one
//! `BlockContext` per block + reused `CodecWorkspace`), and the
//! `CompressionServer` (persistent multi-worker decode pool). All three
//! must produce bit-identical match/MSE statistics — asserted here — so
//! the speedup numbers compare genuinely equivalent work.
//!
//! Part 2 keeps the paper tables: matching probability vs rate
//! (L_max ∈ 2¹..2⁶) and K ∈ {1..4} for GLS vs the shared-randomness
//! baseline, next to the Prop. 4 bound; then the rate-distortion sweep
//! minimized over the σ²_{W|A} grid.
//!
//! Results merge into `BENCH_perf.json` (override `BENCH_PERF_JSON`)
//! under `"section":"fig2-gaussian"` entries plus `compression_gaussian_*`
//! summary keys; CI's compression job gates the kernel-vs-scalar speedup,
//! match-rate monotonicity in K, and the rate-distortion ordering.
//! `GLS_BENCH_QUICK=1` shrinks every grid.

use std::sync::Arc;

use gls_serve::bench::{time, MergingPerfJson, Table};
use gls_serve::compression::bounds::gaussian_prop4_bound;
use gls_serve::compression::codec::{CodecConfig, RandomnessMode};
use gls_serve::compression::gaussian::{
    best_over_distortion_grid, gaussian_point, gaussian_requests, run_gaussian, GaussianSource,
};
use gls_serve::compression::service::{run_blocks_scalar, run_blocks_workspace, CompressionServer};

const SECTION: &str = "fig2-gaussian";

fn main() {
    let quick = std::env::var("GLS_BENCH_QUICK").is_ok();
    let mut json = MergingPerfJson::load(&[SECTION], &["compression_gaussian_"]);

    // ---- Part 1: throughput (scalar vs kernel vs service) ----
    let src = GaussianSource::paper_default(0.005);
    let tp_n = if quick { 1 << 9 } else { 1 << 11 };
    let tp_trials: u64 = if quick { 96 } else { 384 };
    let tp_k = 4usize;
    let workers = 4usize;
    let iters = if quick { 2 } else { 3 };
    let cfg = CodecConfig {
        n_samples: tp_n,
        l_max: 8,
        k_decoders: tp_k,
        seed: 7,
        mode: RandomnessMode::Independent,
    };
    let requests = gaussian_requests(src, tp_k, tp_trials, 7);
    // Candidate samples raced per pipeline pass: the unit of throughput.
    let samples = (tp_trials as usize * tp_n) as f64;

    println!("# Gaussian compression throughput — K = {tp_k}, L_max = 8, N = {tp_n}, {tp_trials} blocks\n");

    // Equivalence first: the three pipelines must agree bit-for-bit on the
    // statistics before their timings are comparable.
    let p_scalar = gaussian_point(src, cfg, &requests, &run_blocks_scalar(&src, cfg, &requests));
    let p_kernel =
        gaussian_point(src, cfg, &requests, &run_blocks_workspace(&src, cfg, &requests));
    let mut server = CompressionServer::new(Arc::new(src), cfg, workers);
    let p_service = gaussian_point(src, cfg, &requests, &server.run_batch(requests.clone()));
    assert_eq!(
        p_scalar.match_rate.to_bits(),
        p_kernel.match_rate.to_bits(),
        "scalar and kernel paths diverged"
    );
    assert_eq!(p_scalar.mse.to_bits(), p_kernel.mse.to_bits());
    assert_eq!(
        p_kernel.match_rate.to_bits(),
        p_service.match_rate.to_bits(),
        "service diverged from the serial kernel reference"
    );
    assert_eq!(p_kernel.mse.to_bits(), p_service.mse.to_bits());

    let r_scalar = time("scalar (seed-style, O((K+2)N)/block)", 1, iters, || {
        std::hint::black_box(run_blocks_scalar(&src, cfg, &requests));
    });
    let r_kernel = time("kernel (workspace, O(N)/block)", 1, iters, || {
        std::hint::black_box(run_blocks_workspace(&src, cfg, &requests));
    });
    let r_service = time(&format!("service ({workers} decode workers)"), 1, iters, || {
        std::hint::black_box(server.run_batch(requests.clone()));
    });

    let sps_scalar = r_scalar.throughput(samples);
    let sps_kernel = r_kernel.throughput(samples);
    let sps_service = r_service.throughput(samples);
    let speedup = sps_kernel / sps_scalar.max(1e-12);
    let service_ratio = sps_service / sps_kernel.max(1e-12);

    let mut tt = Table::new(&["pipeline", "ms/pass", "samples/s", "vs scalar"]);
    for (r, sps) in [(&r_scalar, sps_scalar), (&r_kernel, sps_kernel), (&r_service, sps_service)]
    {
        tt.row(&[
            r.name.clone(),
            format!("{:.2}", r.per_iter.mean * 1e3),
            format!("{sps:.0}"),
            format!("{:.2}x", sps / sps_scalar.max(1e-12)),
        ]);
    }
    tt.print();
    println!("(match rate {:.3}, identical bits across all three pipelines)\n", p_kernel.match_rate);

    for (case, r, sps) in [
        ("scalar", &r_scalar, sps_scalar),
        ("kernel", &r_kernel, sps_kernel),
        ("service-w4", &r_service, sps_service),
    ] {
        json.entry(format!(
            "{{\"section\":\"{SECTION}\",\"case\":\"{case}\",\"samples_per_s\":{sps:.3},\
             \"ms_per_pass\":{:.3},\"match_rate\":{:.4}}}",
            r.per_iter.mean * 1e3,
            p_kernel.match_rate
        ));
    }
    json.metric("compression_gaussian_scalar_samples_per_s", sps_scalar);
    json.metric("compression_gaussian_kernel_samples_per_s", sps_kernel);
    json.metric("compression_gaussian_kernel_speedup", speedup);
    json.metric("compression_gaussian_service_samples_per_s_w4", sps_service);
    json.metric("compression_gaussian_service_vs_kernel_w4", service_ratio);

    // ---- Part 2: the paper tables ----
    let n_samples = if quick { 1 << 9 } else { 1 << 12 };
    let trials: u64 = if quick { 200 } else { 500 };
    let l_maxes: Vec<u64> = vec![2, 4, 8, 16, 32, 64];
    let ks: Vec<usize> = vec![1, 2, 3, 4];

    println!("# Figure 2 (a)–(c) — matching probability (σ²_W|A = 0.005, σ²_T|A = 0.5)");
    println!("# N = {n_samples} importance samples, {trials} trials per cell\n");

    // Match rates at the gated operating point (L_max = 4) per K.
    let mut match_by_k = [0.0f64; 3]; // K = 1, 2, 4
    let mut t = Table::new(&[
        "L_max", "rate(b)", "K", "GLS match", "BL match", "Prop4 bound",
    ]);
    for &l_max in &l_maxes {
        for &k in &ks {
            let gls =
                run_gaussian(src, k, l_max, n_samples, trials, 7, RandomnessMode::Independent);
            let bl = run_gaussian(src, k, l_max, n_samples, trials, 7, RandomnessMode::Shared);
            let bound = gaussian_prop4_bound(src, k, l_max, 4000, 3);
            if l_max == 4 {
                match k {
                    1 => match_by_k[0] = gls.match_rate,
                    2 => match_by_k[1] = gls.match_rate,
                    4 => match_by_k[2] = gls.match_rate,
                    _ => {}
                }
            }
            t.row(&[
                l_max.to_string(),
                format!("{:.0}", (l_max as f64).log2()),
                k.to_string(),
                format!("{:.3}", gls.match_rate),
                format!("{:.3}", bl.match_rate),
                format!("{:.3}", bound),
            ]);
        }
    }
    t.print();
    json.metric("compression_gaussian_match_k1", match_by_k[0]);
    json.metric("compression_gaussian_match_k2", match_by_k[1]);
    json.metric("compression_gaussian_match_k4", match_by_k[2]);

    println!("\n# Figure 2 (d) + Tables 5/6 — rate-distortion (best σ²_W|A per cell)\n");
    let mut rd = Table::new(&[
        "K", "L_max", "GLS σ²_W|A*", "GLS dist (dB)", "BL σ²_W|A*", "BL dist (dB)",
    ]);
    let rd_trials = if quick { 150 } else { 250 };
    let mut mse_db_l2 = 0.0f64;
    let mut mse_db_l64 = 0.0f64;
    for &k in &ks {
        for &l_max in &l_maxes {
            let g = best_over_distortion_grid(
                k, l_max, n_samples, rd_trials, 7, RandomnessMode::Independent,
            );
            let b = best_over_distortion_grid(
                k, l_max, n_samples, rd_trials, 7, RandomnessMode::Shared,
            );
            if k == 2 && l_max == 2 {
                mse_db_l2 = g.mse_db;
            }
            if k == 2 && l_max == 64 {
                mse_db_l64 = g.mse_db;
            }
            rd.row(&[
                k.to_string(),
                l_max.to_string(),
                format!("{:.3}", g.var_w_given_a),
                format!("{:.2}", g.mse_db),
                format!("{:.3}", b.var_w_given_a),
                format!("{:.2}", b.mse_db),
            ]);
        }
    }
    rd.print();
    json.metric("compression_gaussian_mse_db_l2", mse_db_l2);
    json.metric("compression_gaussian_mse_db_l64", mse_db_l64);

    println!(
        "\nshape checks: GLS match ↑ in K; baseline ~flat in K; distortion ↓ with rate;\n\
         GLS ≤ BL distortion for K > 1 (gap largest at low rate); equal at K = 1."
    );
    json.write();
}
