//! Figure 4 + Tables 8/9 reproduction (distributed image compression on
//! the synthetic-digit dataset — DESIGN.md §2) — now doubling as the
//! image compression throughput bench.
//!
//! Part 1 races the three pipelines over one identical request batch (the
//! latent β-VAE stand-in codec): the retained scalar reference, the
//! kernel workspace path, and the `CompressionServer` decode pool. All
//! three must produce bit-identical match/MSE statistics — asserted here.
//!
//! Part 2 keeps the paper tables: per (K, L_max) cell the
//! rate-distortion MSE minimized over the hyperparameter grid
//! (N candidates × encoder channel variance, playing the paper's N × β
//! grid), GLS vs the shared-randomness baseline, plus Figure 3's
//! success/failure anatomy.
//!
//! Results merge into `BENCH_perf.json` (override `BENCH_PERF_JSON`)
//! under `"section":"fig4-image"` entries plus `compression_image_*`
//! summary keys; CI's compression job gates the kernel-vs-scalar speedup,
//! match-rate monotonicity in K, and the rate-distortion ordering.
//! `GLS_BENCH_QUICK=1` shrinks every grid.

use std::sync::Arc;

use gls_serve::bench::{time, MergingPerfJson, Table};
use gls_serve::compression::codec::{CodecConfig, RandomnessMode};
use gls_serve::compression::image::{
    image_point, image_requests, run_image, synthetic_digits, AnalyticVae, ImagePoint,
    SharedLatentSource,
};
use gls_serve::compression::service::{run_blocks_scalar, run_blocks_workspace, CompressionServer};

const SECTION: &str = "fig4-image";

fn main() {
    let quick = std::env::var("GLS_BENCH_QUICK").is_ok();
    let mut json = MergingPerfJson::load(&[SECTION], &["compression_image_"]);

    let train_n = if quick { 150 } else { 400 };
    let eval_n = if quick { 60 } else { 200 };
    let all = synthetic_digits(train_n + eval_n, 21);
    let (train, eval) = all.split_at(train_n);

    // ---- Part 1: throughput (scalar vs kernel vs service) ----
    let vae = Arc::new(AnalyticVae::fit(train, 4, 0.05, 13));
    let tp_n = if quick { 128 } else { 256 };
    let tp_k = 3usize;
    let workers = 4usize;
    let iters = if quick { 2 } else { 3 };
    let cfg = CodecConfig {
        n_samples: tp_n,
        l_max: 8,
        k_decoders: tp_k,
        seed: 7,
        mode: RandomnessMode::Independent,
    };
    let requests = image_requests(&*vae, eval, tp_k, 7);
    let shared_src = SharedLatentSource { model: Arc::clone(&vae) };
    // Latent candidates raced per pipeline pass: the unit of throughput.
    let samples = (eval.len() * tp_n) as f64;

    println!(
        "# Image compression throughput — K = {tp_k}, L_max = 8, N = {tp_n}, {} images\n",
        eval.len()
    );

    // Equivalence first: all three pipelines must agree bit-for-bit on the
    // statistics before their timings are comparable.
    let p_scalar =
        image_point(&*vae, cfg, eval, &requests, &run_blocks_scalar(&shared_src, cfg, &requests));
    let p_kernel = image_point(
        &*vae,
        cfg,
        eval,
        &requests,
        &run_blocks_workspace(&shared_src, cfg, &requests),
    );
    let mut server =
        CompressionServer::new(Arc::new(SharedLatentSource { model: Arc::clone(&vae) }), cfg, workers);
    let p_service = image_point(&*vae, cfg, eval, &requests, &server.run_batch(requests.clone()));
    assert_eq!(
        p_scalar.match_rate.to_bits(),
        p_kernel.match_rate.to_bits(),
        "scalar and kernel paths diverged"
    );
    assert_eq!(p_scalar.mse.to_bits(), p_kernel.mse.to_bits());
    assert_eq!(
        p_kernel.match_rate.to_bits(),
        p_service.match_rate.to_bits(),
        "service diverged from the serial kernel reference"
    );
    assert_eq!(p_kernel.mse.to_bits(), p_service.mse.to_bits());

    let r_scalar = time("scalar (seed-style, O((K+2)N)/block)", 1, iters, || {
        std::hint::black_box(run_blocks_scalar(&shared_src, cfg, &requests));
    });
    let r_kernel = time("kernel (workspace, O(N)/block)", 1, iters, || {
        std::hint::black_box(run_blocks_workspace(&shared_src, cfg, &requests));
    });
    let r_service = time(&format!("service ({workers} decode workers)"), 1, iters, || {
        std::hint::black_box(server.run_batch(requests.clone()));
    });

    let sps_scalar = r_scalar.throughput(samples);
    let sps_kernel = r_kernel.throughput(samples);
    let sps_service = r_service.throughput(samples);
    let speedup = sps_kernel / sps_scalar.max(1e-12);
    let service_ratio = sps_service / sps_kernel.max(1e-12);

    let mut tt = Table::new(&["pipeline", "ms/pass", "samples/s", "vs scalar"]);
    for (r, sps) in [(&r_scalar, sps_scalar), (&r_kernel, sps_kernel), (&r_service, sps_service)]
    {
        tt.row(&[
            r.name.clone(),
            format!("{:.2}", r.per_iter.mean * 1e3),
            format!("{sps:.0}"),
            format!("{:.2}x", sps / sps_scalar.max(1e-12)),
        ]);
    }
    tt.print();
    println!("(match rate {:.3}, identical bits across all three pipelines)\n", p_kernel.match_rate);

    for (case, r, sps) in [
        ("scalar", &r_scalar, sps_scalar),
        ("kernel", &r_kernel, sps_kernel),
        ("service-w4", &r_service, sps_service),
    ] {
        json.entry(format!(
            "{{\"section\":\"{SECTION}\",\"case\":\"{case}\",\"samples_per_s\":{sps:.3},\
             \"ms_per_pass\":{:.3},\"match_rate\":{:.4}}}",
            r.per_iter.mean * 1e3,
            p_kernel.match_rate
        ));
    }
    json.metric("compression_image_scalar_samples_per_s", sps_scalar);
    json.metric("compression_image_kernel_samples_per_s", sps_kernel);
    json.metric("compression_image_kernel_speedup", speedup);
    json.metric("compression_image_service_samples_per_s_w4", sps_service);
    json.metric("compression_image_service_vs_kernel_w4", service_ratio);

    // Match-rate monotonicity in K at a fixed low-rate operating point.
    let m1 = run_image(&*vae, eval, 1, 4, 128, 3, RandomnessMode::Independent);
    let m2 = run_image(&*vae, eval, 2, 4, 128, 3, RandomnessMode::Independent);
    let m4 = run_image(&*vae, eval, 4, 4, 128, 3, RandomnessMode::Independent);
    json.metric("compression_image_match_k1", m1.match_rate);
    json.metric("compression_image_match_k2", m2.match_rate);
    json.metric("compression_image_match_k4", m4.match_rate);

    // ---- Part 2: the paper tables ----
    let l_maxes: Vec<u64> = vec![4, 8, 16, 32, 64];
    let ks: Vec<usize> = vec![1, 2, 3, 4];
    let n_grid: Vec<usize> = if quick { vec![128] } else { vec![128, 256, 512] };
    let var_grid: Vec<f64> = if quick { vec![0.05] } else { vec![0.02, 0.05, 0.15] };

    // Fit one codec per encoder-variance point (the paper trains one VAE
    // per β); grid-search at eval time like App. D.3.
    let vaes: Vec<AnalyticVae> = var_grid
        .iter()
        .map(|&v| AnalyticVae::fit(train, 4, v, 13))
        .collect();

    let best_cell = |k: usize, l_max: u64, mode: RandomnessMode| -> ImagePoint {
        let mut best: Option<ImagePoint> = None;
        for vae in &vaes {
            for &n in &n_grid {
                let p = run_image(vae, eval, k, l_max, n, 3, mode);
                if best.as_ref().map_or(true, |b| p.mse < b.mse) {
                    best = Some(p);
                }
            }
        }
        best.unwrap()
    };

    println!("# Figure 4 + Tables 8/9 — image compression (synthetic digits)");
    println!("# {train_n} train / {eval_n} eval images; grid: N ∈ {n_grid:?}, σ² ∈ {var_grid:?}\n");

    let mut mse_l4 = 0.0f64;
    let mut mse_l64 = 0.0f64;
    let mut t = Table::new(&[
        "K", "L_max", "rate(b)", "GLS MSE", "GLS match", "BL MSE", "BL match",
    ]);
    for &k in &ks {
        for &l_max in &l_maxes {
            let g = best_cell(k, l_max, RandomnessMode::Independent);
            let b = best_cell(k, l_max, RandomnessMode::Shared);
            if k == 2 && l_max == 4 {
                mse_l4 = g.mse;
            }
            if k == 2 && l_max == 64 {
                mse_l64 = g.mse;
            }
            t.row(&[
                k.to_string(),
                l_max.to_string(),
                format!("{:.0}", (l_max as f64).log2()),
                format!("{:.4}", g.mse),
                format!("{:.3}", g.match_rate),
                format!("{:.4}", b.mse),
                format!("{:.3}", b.match_rate),
            ]);
        }
    }
    t.print();
    json.metric("compression_image_mse_l4", mse_l4);
    json.metric("compression_image_mse_l64", mse_l64);

    // Figure 3 stand-in: success/failure anatomy at a mid-rate point.
    println!("\n# Figure 3 — success/failure anatomy (K = 2, L_max = 8)");
    let g = best_cell(2, 8, RandomnessMode::Independent);
    println!(
        "decoder matched encoder index on {:.1}% of images; mismatches are the\n\
         error events bounded by Prop. 4 / eq. (5). MSE over all images: {:.4}",
        g.match_rate * 100.0,
        g.mse
    );
    println!(
        "\nshape checks: MSE ↓ with rate and K (GLS); GLS ≤ BL, gap largest at low rate;\n\
         K = 1 rows identical between schemes."
    );
    json.write();
}
