//! Figure 4 + Tables 8/9 reproduction: distributed image compression on
//! the synthetic-digit dataset (MNIST stand-in — DESIGN.md §2).
//!
//! Per (K, L_max) cell, rate-distortion MSE is minimized over the
//! hyperparameter grid (N candidates × encoder channel variance, playing
//! the paper's N × β grid), for GLS vs the shared-randomness baseline.
//! Figure 3's qualitative success/failure split is reported as match-rate
//! buckets (encoder-decoder agreement vs miss).
//!
//! Expected shape: MSE ↓ with rate and with K under GLS; GLS ≤ baseline
//! with the gap largest at low rates; K = 1 equal.

use gls_serve::bench::Table;
use gls_serve::compression::codec::RandomnessMode;
use gls_serve::compression::image::{run_image, synthetic_digits, AnalyticVae, ImagePoint};

fn main() {
    let quick = std::env::var("GLS_BENCH_QUICK").is_ok();
    let train_n = if quick { 150 } else { 400 };
    let eval_n = if quick { 60 } else { 200 };
    let l_maxes: Vec<u64> = vec![4, 8, 16, 32, 64];
    let ks: Vec<usize> = vec![1, 2, 3, 4];
    let n_grid: Vec<usize> = if quick { vec![128] } else { vec![128, 256, 512] };
    let var_grid: Vec<f64> = if quick { vec![0.05] } else { vec![0.02, 0.05, 0.15] };

    let all = synthetic_digits(train_n + eval_n, 21);
    let (train, eval) = all.split_at(train_n);

    // Fit one codec per encoder-variance point (the paper trains one VAE
    // per β); grid-search at eval time like App. D.3.
    let vaes: Vec<AnalyticVae> = var_grid
        .iter()
        .map(|&v| AnalyticVae::fit(train, 4, v, 13))
        .collect();

    let best_cell = |k: usize, l_max: u64, mode: RandomnessMode| -> ImagePoint {
        let mut best: Option<ImagePoint> = None;
        for vae in &vaes {
            for &n in &n_grid {
                let p = run_image(vae, eval, k, l_max, n, 3, mode);
                if best.as_ref().map_or(true, |b| p.mse < b.mse) {
                    best = Some(p);
                }
            }
        }
        best.unwrap()
    };

    println!("# Figure 4 + Tables 8/9 — image compression (synthetic digits)");
    println!("# {train_n} train / {eval_n} eval images; grid: N ∈ {n_grid:?}, σ² ∈ {var_grid:?}\n");

    let mut t = Table::new(&[
        "K", "L_max", "rate(b)", "GLS MSE", "GLS match", "BL MSE", "BL match",
    ]);
    for &k in &ks {
        for &l_max in &l_maxes {
            let g = best_cell(k, l_max, RandomnessMode::Independent);
            let b = best_cell(k, l_max, RandomnessMode::Shared);
            t.row(&[
                k.to_string(),
                l_max.to_string(),
                format!("{:.0}", (l_max as f64).log2()),
                format!("{:.4}", g.mse),
                format!("{:.3}", g.match_rate),
                format!("{:.4}", b.mse),
                format!("{:.3}", b.match_rate),
            ]);
        }
    }
    t.print();

    // Figure 3 stand-in: success/failure anatomy at a mid-rate point.
    println!("\n# Figure 3 — success/failure anatomy (K = 2, L_max = 8)");
    let g = best_cell(2, 8, RandomnessMode::Independent);
    println!(
        "decoder matched encoder index on {:.1}% of images; mismatches are the\n\
         error events bounded by Prop. 4 / eq. (5). MSE over all images: {:.4}",
        g.match_rate * 100.0,
        g.mse
    );
    println!(
        "\nshape checks: MSE ↓ with rate and K (GLS); GLS ≤ BL, gap largest at low rate;\n\
         K = 1 rows identical between schemes."
    );
}
