//! Ablation benches for the coordinator design choices DESIGN.md calls
//! out: KV page size, dynamic-batching deadline, routing policy, and the
//! draft-length (L) sweep that motivates the paper's choice of L = 4/5.
//!
//! Not a paper table — these justify the serving framework's defaults.

use std::time::Duration;

use gls_serve::bench::Table;
use gls_serve::coordinator::router::RoutingPolicy;
use gls_serve::coordinator::server::Server;
use gls_serve::coordinator::{EngineConfig, ServerConfig};
use gls_serve::model::sampling::SamplingParams;
use gls_serve::spec::types::VerifierKind;
use gls_serve::workload::suites::TaskSuite;

const VOCAB: usize = 64;

fn serve(sc: &ServerConfig, ec: &EngineConfig, requests: usize, policy: RoutingPolicy) -> (f64, f64, f64) {
    let suite = TaskSuite::by_name("gsm8k-sim").unwrap();
    let prompts = suite.prompts(requests, VOCAB, 42);
    let workload: Vec<(Vec<u32>, usize)> = prompts.into_iter().map(|p| (p, 64)).collect();
    let report = Server::serve_all(sc, ec, policy, |_| suite.timed_model_pair(VOCAB, 7), workload);
    (report.token_rate(), report.p95_latency() * 1e3, report.mean_block_efficiency())
}

fn main() {
    let requests = if std::env::var("GLS_BENCH_QUICK").is_ok() { 8 } else { 24 };
    let base_ec = EngineConfig {
        num_drafts: 4,
        block_len: 4,
        verifier: VerifierKind::Gls,
        target_params: SamplingParams::new(1.0, Some(50)),
        draft_params: vec![SamplingParams::new(1.0, Some(50))],
        max_seq_len: 512,
        seed: 7,
        ..EngineConfig::default()
    };
    let base_sc = ServerConfig { workers: 2, ..ServerConfig::default() };

    println!("# Ablations — coordinator design choices ({requests} requests)\n");

    // --------------------------------------------------------- draft length
    {
        let mut t = Table::new(&["L", "BE", "tok/s", "p95 ms"]);
        for l in [1usize, 2, 4, 6, 8] {
            let ec = EngineConfig { block_len: l, ..base_ec.clone() };
            let (rate, p95, be) = serve(&base_sc, &ec, requests, RoutingPolicy::LeastLoaded);
            t.row(&[
                l.to_string(),
                format!("{be:.2}"),
                format!("{rate:.0}"),
                format!("{p95:.0}"),
            ]);
        }
        println!("## draft length L (BE rises then saturates; throughput peaks mid-range)");
        t.print();
        println!();
    }

    // --------------------------------------------------------- KV page size
    {
        let mut t = Table::new(&["page size", "tok/s", "peak pages", "util-equiv tokens"]);
        for page in [4usize, 16, 64, 256] {
            let sc = ServerConfig {
                kv_page_size: page,
                kv_pages: (64 * 1024) / page, // constant byte budget
                ..base_sc.clone()
            };
            let (rate, _, _) = serve(&sc, &base_ec, requests, RoutingPolicy::LeastLoaded);
            t.row(&[
                page.to_string(),
                format!("{rate:.0}"),
                "-".into(),
                (64 * 1024).to_string(),
            ]);
        }
        println!("## KV page size at constant token budget (fragmentation vs granularity)");
        t.print();
        println!();
    }

    // --------------------------------------------------- batching deadline
    {
        let mut t = Table::new(&["deadline ms", "tok/s", "p95 ms"]);
        for ms in [0u64, 1, 2, 8, 32] {
            let sc = ServerConfig {
                batch_deadline: Duration::from_millis(ms),
                ..base_sc.clone()
            };
            let (rate, p95, _) = serve(&sc, &base_ec, requests, RoutingPolicy::LeastLoaded);
            t.row(&[ms.to_string(), format!("{rate:.0}"), format!("{p95:.0}")]);
        }
        println!("## dynamic-batching deadline (throughput/latency dial)");
        t.print();
        println!();
    }

    // ------------------------------------------------------ routing policy
    {
        let mut t = Table::new(&["policy", "workers", "tok/s", "p95 ms"]);
        for workers in [1usize, 2, 4] {
            for policy in [RoutingPolicy::RoundRobin, RoutingPolicy::LeastLoaded] {
                let sc = ServerConfig { workers, ..base_sc.clone() };
                let (rate, p95, _) = serve(&sc, &base_ec, requests, policy);
                t.row(&[
                    format!("{policy:?}"),
                    workers.to_string(),
                    format!("{rate:.0}"),
                    format!("{p95:.0}"),
                ]);
            }
        }
        println!("## routing policy × workers");
        t.print();
    }
}
