//! Table 2 / Table 4 reproduction: LLM inference with *diverse* drafts.
//!
//! K = 2 drafters with independently varied temperatures, target
//! temperature 2.0, L = 5. SpecTr is excluded (K-SEQ requires identically
//! distributed proposals — paper §4.3). Rows follow the paper's
//! temperature grid; TR% is relative to single-draft speculative decoding
//! with drafter temperature 1.0.
//!
//! Expected shape: GLS beats SpecInfer on BE/TR under mismatch, and GLS is
//! (near-)insensitive to draft order while SpecInfer favors the first
//! draft (compare the a/b vs b/a rows); the strongly invariant variant
//! pays a visible penalty.

use gls_serve::bench::{pm, Table};
use gls_serve::coordinator::router::RoutingPolicy;
use gls_serve::coordinator::server::Server;
use gls_serve::coordinator::{EngineConfig, ServerConfig};
use gls_serve::model::sampling::SamplingParams;
use gls_serve::spec::types::VerifierKind;
use gls_serve::stats::summary::Summary;
use gls_serve::workload::suites::{TaskSuite, SUITES};

const VOCAB: usize = 64;
const SEEDS: [u64; 5] = [101, 202, 303, 404, 505];
const TARGET_TEMP: f64 = 2.0;
/// The paper's diverse drafters are one model at different temperatures —
/// structurally aligned. Scale the suites' draft divergence down so
/// temperature mismatch is the dominant misalignment, as in the paper.
const DIV_SCALE: f32 = 0.3;

fn run_once(
    suite: &TaskSuite,
    verifier: VerifierKind,
    draft_temps: &[f64],
    l: usize,
    seed: u64,
    requests: usize,
) -> (f64, f64) {
    let sc = ServerConfig { workers: 2, ..ServerConfig::default() };
    let k = draft_temps.len().max(1);
    let ec = EngineConfig {
        num_drafts: k,
        block_len: l,
        verifier,
        target_params: SamplingParams::new(TARGET_TEMP, Some(50)),
        draft_params: draft_temps
            .iter()
            .map(|&t| SamplingParams::new(t, Some(50)))
            .collect(),
        max_seq_len: 512,
        seed,
        ..EngineConfig::default()
    };
    let prompts = suite.prompts(requests, VOCAB, seed ^ 0xD1);
    let workload: Vec<(Vec<u32>, usize)> =
        prompts.into_iter().map(|p| (p, suite.max_new_tokens)).collect();
    let report = Server::serve_all(
        &sc,
        &ec,
        RoutingPolicy::LeastLoaded,
        |_| suite.timed_model_pair_scaled(VOCAB, 7, DIV_SCALE),
        workload,
    );
    (report.mean_block_efficiency(), report.token_rate())
}

fn main() {
    let quick = std::env::var("GLS_BENCH_QUICK").is_ok();
    let requests = if quick { 8 } else { 24 };
    let l = 5;
    let temp_grid: &[(f64, f64)] =
        &[(0.5, 1.0), (1.0, 0.5), (1.5, 1.0), (1.0, 1.5), (2.0, 1.0), (1.0, 2.0), (1.0, 1.0)];
    let suites: Vec<&TaskSuite> = if quick {
        vec![&SUITES[0]]
    } else {
        vec![&SUITES[0], &SUITES[1], &SUITES[3]] // gsm8k / humaneval / mbpp
    };

    println!(
        "# Table 2/4 — diverse drafts (K = 2, L = {l}, target temp {TARGET_TEMP}, top-k 50)"
    );
    println!("# TR% vs single-draft with drafter temp 1.0 (same seed)\n");

    let strategies = [
        ("SpecInfer", VerifierKind::SpecInfer),
        ("Our scheme (GLS)", VerifierKind::Gls),
        ("Strongly invariant", VerifierKind::GlsStrong),
    ];

    for suite in suites {
        // Cache every (strategy, temps, seed) run: the main table and the
        // order-sensitivity summary share them.
        let mut cache: std::collections::HashMap<(usize, u64, u64, u64), (f64, f64)> =
            std::collections::HashMap::new();
        let key = |vi: usize, t1: f64, t2: f64, seed: u64| {
            (vi, t1.to_bits(), t2.to_bits(), seed)
        };
        let mut baselines = std::collections::HashMap::new();
        for &seed in &SEEDS {
            let (_, base) = run_once(suite, VerifierKind::SingleDraft, &[1.0], l, seed, requests);
            baselines.insert(seed, base);
        }

        let mut t = Table::new(&["strategy", "Tmp. 1/2", "BE", "TR (%)"]);
        for (vi, (name, vk)) in strategies.iter().enumerate() {
            for &(t1, t2) in temp_grid {
                let mut bes = Vec::new();
                let mut trs = Vec::new();
                for &seed in &SEEDS {
                    let (be, rate) = *cache
                        .entry(key(vi, t1, t2, seed))
                        .or_insert_with(|| run_once(suite, *vk, &[t1, t2], l, seed, requests));
                    bes.push(be);
                    trs.push(100.0 * (rate - baselines[&seed]) / baselines[&seed]);
                }
                let b = Summary::of(&bes);
                let r = Summary::of(&trs);
                t.row(&[
                    name.to_string(),
                    format!("{t1}/{t2}"),
                    pm(b.mean, b.sem),
                    pm(r.mean, r.sem),
                ]);
            }
        }
        println!("## {}", suite.name);
        t.print();

        // Order-sensitivity summary: |BE(a/b) − BE(b/a)| per scheme, reusing
        // the cached runs from the main grid.
        let mut order = Table::new(&["strategy", "|ΔBE| 0.5↔1.0", "|ΔBE| 2.0↔1.0"]);
        for (vi, (name, _vk)) in strategies.iter().enumerate() {
            let gap = |a: (f64, f64), b: (f64, f64), cache: &std::collections::HashMap<_, (f64, f64)>| {
                let mut d = Vec::new();
                for &seed in &SEEDS {
                    let (be_a, _) = cache[&key(vi, a.0, a.1, seed)];
                    let (be_b, _) = cache[&key(vi, b.0, b.1, seed)];
                    d.push((be_a - be_b) as f64);
                }
                let abs: Vec<f64> = d.iter().map(|x| x.abs()).collect();
                Summary::of(&abs)
            };
            let g1 = gap((0.5, 1.0), (1.0, 0.5), &cache);
            let g2 = gap((2.0, 1.0), (1.0, 2.0), &cache);
            order.row(&[name.to_string(), pm(g1.mean, g1.sem), pm(g2.mean, g2.sem)]);
        }
        println!("\n### order sensitivity (lower = more symmetric)");
        order.print();
        println!();
    }
}
