//! Table 1 / Table 3 reproduction: LLM inference with i.i.d. drafts.
//!
//! For each task suite (the calibrated stand-ins for GSM8K / HumanEval /
//! NaturalReasoning / MBPP / DROP — DESIGN.md §2) and each verification
//! strategy, measure block efficiency (BE) and the token-rate speedup (TR%)
//! relative to single-draft speculative decoding, across K ∈ {2, 4, 6, 8},
//! L = 4, top-k 50, temperature 1.0. Five seeds → mean ± SEM, exactly the
//! paper's protocol (App. D.1).
//!
//! Expected shape: all multi-draft schemes cluster within noise on BE and
//! beat both the single-draft baseline (TR > 0) and Daliri et al.'s
//! single-draft coupling; BE grows with K; the strongly-invariant variant
//! trails the conditional one.

use gls_serve::bench::{pm, Table};
use gls_serve::coordinator::router::RoutingPolicy;
use gls_serve::coordinator::server::Server;
use gls_serve::coordinator::{EngineConfig, ServerConfig};
use gls_serve::model::sampling::SamplingParams;
use gls_serve::spec::types::VerifierKind;
use gls_serve::stats::summary::Summary;
use gls_serve::workload::suites::{TaskSuite, SUITES};

const VOCAB: usize = 64;
const SEEDS: [u64; 5] = [11, 22, 33, 44, 55];

struct Cell {
    be: Summary,
    tr: Summary,
}

fn run_once(
    suite: &TaskSuite,
    verifier: VerifierKind,
    k: usize,
    l: usize,
    seed: u64,
    requests: usize,
) -> (f64, f64) {
    let sc = ServerConfig { workers: 2, ..ServerConfig::default() };
    let ec = EngineConfig {
        num_drafts: k,
        block_len: l,
        verifier,
        target_params: SamplingParams::new(1.0, Some(50)),
        draft_params: vec![SamplingParams::new(1.0, Some(50))],
        max_seq_len: 512,
        seed,
        ..EngineConfig::default()
    };
    let prompts = suite.prompts(requests, VOCAB, seed ^ 0x51E);
    let workload: Vec<(Vec<u32>, usize)> =
        prompts.into_iter().map(|p| (p, suite.max_new_tokens)).collect();
    let report = Server::serve_all(
        &sc,
        &ec,
        RoutingPolicy::LeastLoaded,
        |_| suite.timed_model_pair(VOCAB, 7),
        workload,
    );
    (report.mean_block_efficiency(), report.token_rate())
}

fn cell(
    suite: &TaskSuite,
    verifier: VerifierKind,
    k: usize,
    l: usize,
    requests: usize,
    baselines: &std::collections::HashMap<(&'static str, u64), f64>,
) -> Cell {
    // TR% is relative to single-draft with the same seed (paper protocol);
    // baselines are measured once per (suite, seed) and reused.
    let mut bes = Vec::new();
    let mut trs = Vec::new();
    for &seed in &SEEDS {
        let (be, rate) = run_once(suite, verifier, k, l, seed, requests);
        let base_rate = baselines[&(suite.name, seed)];
        bes.push(be);
        trs.push(100.0 * (rate - base_rate) / base_rate);
    }
    Cell { be: Summary::of(&bes), tr: Summary::of(&trs) }
}

fn main() {
    let quick = std::env::var("GLS_BENCH_QUICK").is_ok();
    let requests = if quick { 8 } else { 24 };
    let ks: Vec<usize> = if quick { vec![4, 8] } else { vec![2, 4, 6, 8] };
    let l = 4;

    println!("# Table 1/3 — LLM inference with i.i.d. drafts (L = {l}, top-k 50, temp 1.0)");
    println!("# suites are calibrated dataset stand-ins; TR% vs single-draft (same seed)\n");

    // Single-draft reference BEs + token rates per (suite, seed): printed
    // like the paper's captions and reused as the TR denominators.
    let mut baselines = std::collections::HashMap::new();
    {
        let mut t = Table::new(&["suite", "single-draft BE"]);
        for suite in &SUITES {
            let mut bes = Vec::new();
            for &seed in &SEEDS {
                let (be, rate) = run_once(suite, VerifierKind::SingleDraft, 1, l, seed, requests);
                bes.push(be);
                baselines.insert((suite.name, seed), rate);
            }
            t.row(&[suite.name.to_string(), format!("{}", Summary::of(&bes))]);
        }
        t.print();
        println!();
    }

    let strategies = [
        ("SpecInfer", VerifierKind::SpecInfer),
        ("SpecTr", VerifierKind::SpecTr),
        ("Our scheme (GLS)", VerifierKind::Gls),
        ("Strongly invariant", VerifierKind::GlsStrong),
    ];

    for suite in &SUITES {
        let mut t = Table::new(&["strategy", "K", "BE", "TR (%)"]);
        for (name, vk) in &strategies {
            for &k in &ks {
                let c = cell(suite, *vk, k, l, requests, &baselines);
                t.row(&[
                    name.to_string(),
                    k.to_string(),
                    pm(c.be.mean, c.be.sem),
                    pm(c.tr.mean, c.tr.sem),
                ]);
            }
        }
        // Daliri et al. single-draft coupling (K = 1 row, as in the paper).
        let c = cell(suite, VerifierKind::Daliri, 1, l, requests, &baselines);
        t.row(&[
            "Daliri et al.".to_string(),
            "1".to_string(),
            pm(c.be.mean, c.be.sem),
            pm(c.tr.mean, c.tr.sem),
        ]);
        println!("## {}", suite.name);
        t.print();
        println!();
    }
}
