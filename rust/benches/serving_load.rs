//! Serving-load bench: replay the `workload::drills` scenarios against
//! the full serving stack and report goodput, per-token latency
//! quantiles (p95/p99) and TTFT (p50/p95) per scenario.
//!
//! Results land in `BENCH_perf.json` (override with `BENCH_PERF_JSON`)
//! under `"section":"serving-load"` entries plus `serving_load_*` summary
//! keys; CI's serving-load job gates the no-fault goodput baseline, the
//! quantile ordering, the scripted failure counts, and the flat thread
//! census. The writer merges into an existing `BENCH_perf.json` (e.g.
//! one `perf_engine` just wrote), replacing only its own stale
//! serving-load entries, so the two benches can share one perf log.
//!
//! `GLS_BENCH_QUICK=1` shrinks every drill to 16 requests.

use gls_serve::bench::{MergingPerfJson, Table};
use gls_serve::workload::{Drill, Scenario};

fn main() {
    let quick = std::env::var("GLS_BENCH_QUICK").is_ok();
    let seed = 0xD811u64;
    let mut json = MergingPerfJson::load(&["serving-load"], &["serving_load_"]);
    let mut table = Table::new(&[
        "scenario", "goodput tok/s", "p95 tok ms", "p99 tok ms", "ttft p50 ms", "ttft p95 ms",
        "failed", "cancelled", "shed", "threads",
    ]);
    println!(
        "# Serving-load drills (seed {seed:#x}, {} requests/drill)\n",
        if quick { 16 } else { 48 }
    );
    let mut goodput_no_fault = 0.0f64;
    let mut goodput_storm = 0.0f64;
    for sc in [
        Scenario::NoFault,
        Scenario::Bursty,
        Scenario::PanicStorm,
        Scenario::Straggler,
        Scenario::DeadlineStorm,
        Scenario::CancelFlood,
        Scenario::OverloadShed,
        Scenario::DrainUnderStorm,
        Scenario::ComposedFault,
    ] {
        let mut drill = Drill::new(sc, seed);
        if quick {
            drill.trace.requests.truncate(16);
            drill.poisoned.retain(|&id| id < 16);
            drill.deadline_zero.retain(|&id| id < 16);
            drill.cancel_at_submit.retain(|&id| id < 16);
            if let Some(d) = drill.drain_after.as_mut() {
                *d = (*d).min(8);
            }
        }
        // Scripted expectations, emitted alongside the measured counters
        // so the CI gate can assert counter == script per scenario.
        let expected_timed_out = drill.deadline_zero.len();
        let expected_cancelled = drill.cancel_at_submit.len();
        let admit_bound = drill.server_cfg.admit_queue;
        let submitted = drill.drain_after.unwrap_or(drill.trace.requests.len());
        let out = drill.run();
        let rep = &out.report;
        let goodput = rep.goodput();
        let p95_tok = rep.p95_token_latency() * 1e3;
        let p99_tok = rep.p99_token_latency() * 1e3;
        let ttft_p50 = rep.p50_ttft() * 1e3;
        let ttft_p95 = rep.p95_ttft() * 1e3;
        let failed = out.failed_ids().len();
        let completed = rep.results.len();
        let cancelled = rep.metrics.cancelled;
        let timed_out = rep.metrics.timed_out;
        let shed = rep.metrics.shed_full + rep.metrics.shed_expired;
        let queue_peak = rep.metrics.queue_peak;
        // -1.0 = census unavailable (non-Linux); the CI gate skips then.
        let threads = out.census_delta().map_or(-1.0, |d| d as f64);
        match sc {
            Scenario::NoFault => goodput_no_fault = goodput,
            Scenario::PanicStorm => goodput_storm = goodput,
            _ => {}
        }
        table.row(&[
            sc.name().to_string(),
            format!("{goodput:.0}"),
            format!("{p95_tok:.2}"),
            format!("{p99_tok:.2}"),
            format!("{ttft_p50:.2}"),
            format!("{ttft_p95:.2}"),
            format!("{failed}"),
            format!("{}", cancelled + timed_out),
            format!("{shed}"),
            format!("{threads:.0}"),
        ]);
        json.entry(format!(
            "{{\"section\":\"serving-load\",\"case\":\"{}\",\"goodput_tok_per_s\":{:.3},\
             \"p95_token_ms\":{:.3},\"p99_token_ms\":{:.3},\"ttft_p50_ms\":{:.3},\
             \"ttft_p95_ms\":{:.3},\"failed\":{},\"completed\":{},\"threads\":{:.0},\
             \"cancelled\":{},\"timed_out\":{},\"shed\":{},\"shed_recorded\":{},\
             \"queue_peak\":{},\"expected_timed_out\":{},\"expected_cancelled\":{},\
             \"admit_bound\":{},\"submitted\":{}}}",
            sc.name(),
            goodput,
            p95_tok,
            p99_tok,
            ttft_p50,
            ttft_p95,
            failed,
            completed,
            threads,
            cancelled,
            timed_out,
            shed,
            out.shed_ids.len(),
            queue_peak,
            expected_timed_out,
            expected_cancelled,
            admit_bound,
            submitted
        ));
        let slug = sc.name().replace('-', "_");
        json.metric(&format!("serving_load_goodput_tok_per_s_{slug}"), goodput);
        json.metric(&format!("serving_load_p95_token_latency_ms_{slug}"), p95_tok);
        json.metric(&format!("serving_load_p99_token_latency_ms_{slug}"), p99_tok);
        json.metric(&format!("serving_load_ttft_p50_ms_{slug}"), ttft_p50);
        json.metric(&format!("serving_load_ttft_p95_ms_{slug}"), ttft_p95);
        json.metric(&format!("serving_load_failed_{slug}"), failed as f64);
        json.metric(&format!("serving_load_threads_{slug}"), threads);
        json.metric(&format!("serving_load_cancelled_{slug}"), cancelled as f64);
        json.metric(&format!("serving_load_timed_out_{slug}"), timed_out as f64);
        json.metric(&format!("serving_load_shed_{slug}"), shed as f64);
        json.metric(&format!("serving_load_queue_peak_{slug}"), queue_peak as f64);
    }
    table.print();
    if goodput_no_fault > 0.0 {
        json.metric(
            "serving_load_goodput_ratio_storm_vs_nofault",
            goodput_storm / goodput_no_fault,
        );
    }
    json.write();
}
