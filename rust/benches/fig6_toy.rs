//! Figure 6 reproduction: token-level matching rate on random toy
//! distributions (N = 10 symbols, 100 random (p, q) instances) as the
//! number of drafts K sweeps 1..20, for GLS, SpecTr, SpecInfer, and the
//! optimal-with-communication reference (closed-form upper bound, LP-exact
//! cross-checked at small K).
//!
//! Paper expectation (shape): all schemes increase monotonically in K,
//! cluster within a few percent of each other, and sit below the optimal
//! curve, with the gap narrowing as K grows.

use gls_serve::bench::Table;
use gls_serve::spec::gls::sample_gls;
use gls_serve::spec::specinfer::SpecInferVerifier;
use gls_serve::spec::spectr::SpecTrVerifier;
use gls_serve::spec::types::Categorical;
use gls_serve::spec::{lml, optimal};
use gls_serve::stats::rng::{CounterRng, XorShift128};
use gls_serve::stats::summary::mean;
use gls_serve::testkit::gen_categorical;

const N: usize = 10;
const INSTANCES: usize = 100;
const TRIALS: u64 = 2000;

fn main() {
    let ks: Vec<usize> = vec![1, 2, 3, 4, 6, 8, 12, 16, 20];
    let mut gen = XorShift128::new(0xF16_6);
    let instances: Vec<(Categorical, Categorical)> = (0..INSTANCES)
        .map(|_| (gen_categorical(&mut gen, N), gen_categorical(&mut gen, N)))
        .collect();

    let mut table = Table::new(&[
        "K", "GLS", "SpecTr", "SpecInfer", "LML bound", "Optimal (UB)", "LP (exact)",
    ]);

    for &k in &ks {
        let mut gls_rates = Vec::new();
        let mut spectr_rates = Vec::new();
        let mut specinfer_rates = Vec::new();
        let mut bounds = Vec::new();
        let mut ubs = Vec::new();
        let mut lps = Vec::new();

        for (idx, (p, q)) in instances.iter().enumerate() {
            let rng = CounterRng::new(1000 + idx as u64);

            // GLS accept rate.
            let hits = (0..TRIALS).filter(|&t| sample_gls(p, q, k, &rng, t).accept).count();
            gls_rates.push(hits as f64 / TRIALS as f64);

            // SpecTr K-SEQ accept rate (i.i.d. proposals).
            let st = SpecTrVerifier::new();
            let hits = (0..TRIALS)
                .filter(|&t| {
                    let cands: Vec<(usize, u32)> = (0..k)
                        .map(|kk| (kk, p.sample_race(&rng, t, kk as u64) as u32))
                        .collect();
                    st.step(p, q, &cands, &rng, t, k).1.is_some()
                })
                .count();
            spectr_rates.push(hits as f64 / TRIALS as f64);

            // SpecInfer recursive rejection accept rate.
            let si = SpecInferVerifier::new();
            let hits = (0..TRIALS)
                .filter(|&t| {
                    let toks: Vec<u32> =
                        (0..k).map(|kk| p.sample_race(&rng, t, kk as u64) as u32).collect();
                    let cands: Vec<(usize, u32, &Categorical)> =
                        toks.iter().enumerate().map(|(kk, &x)| (kk, x, p)).collect();
                    si.step(q, &cands, &rng, t, k).1.is_some()
                })
                .count();
            specinfer_rates.push(hits as f64 / TRIALS as f64);

            bounds.push(lml::theorem1_bound(p, q, k));
            ubs.push(optimal::upper_bound(p, q, k));
            // Exact LP only where tractable (N^(K+1) vars).
            if k <= 2 {
                if let Ok(v) = optimal::lp_optimal(p, q, k) {
                    lps.push(v);
                }
            }
        }

        let lp_cell = if lps.is_empty() {
            "—".to_string()
        } else {
            format!("{:.4}", mean(&lps))
        };
        table.row(&[
            k.to_string(),
            format!("{:.4}", mean(&gls_rates)),
            format!("{:.4}", mean(&spectr_rates)),
            format!("{:.4}", mean(&specinfer_rates)),
            format!("{:.4}", mean(&bounds)),
            format!("{:.4}", mean(&ubs)),
            lp_cell,
        ]);
    }

    println!("# Figure 6 — toy-distribution matching rate vs number of drafts");
    println!("# N = {N} symbols, {INSTANCES} random instances, {TRIALS} trials each\n");
    table.print();
    println!(
        "\nshape checks: rates monotone in K; GLS within a few % of SpecTr/SpecInfer;\n\
         all ≤ Optimal (UB); LML bound ≤ GLS empirical."
    );
}
