//! Repo-specific source lint: a std-only, dependency-free auditor for the
//! invariant classes this codebase has actually shipped bugs in.
//!
//! Clippy cannot know that `usable()` is the one blessed weight filter, that
//! `sync::lock_recover` is the one blessed way to take a lock, or that
//! `CounterRng::lane` construction is centralized in
//! [`crate::analysis::lanes`]. This scanner encodes those house rules as
//! typed findings over `rust/src`, with a checked-in allowlist
//! ([`ALLOWLIST`]) for deliberate exceptions. `tests/static_audit.rs` runs it
//! as a tier-1 test and CI runs it in the `lint` job.
//!
//! The scanner is line-oriented and deliberately simple: it strips comments,
//! string/char literals, and `#[cfg(test)]` items (so doc tables and test
//! scaffolding can mention the forbidden patterns freely), then matches
//! substrings on what remains. That misses exotic formattings
//! (`partial_cmp` split across lines) — acceptable for a tripwire whose goal
//! is catching the idioms people actually type.

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

use super::lanes;

/// The repo-specific rule set.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    /// `.partial_cmp(` — NaN-panicking or NaN-swallowing float comparison;
    /// use `total_cmp` (with an explicit NaN policy where sign matters).
    NanUnsafeCmp,
    /// Bare `<= 0.0` / `> 0.0` weight filters in `compression/` outside the
    /// `usable()` helper — a NaN weight passes `!(w <= 0.0)` and can win a
    /// race (the PR 8 bug class).
    NanUnsafeWeightFilter,
    /// `.lock().unwrap()` / `.wait(..).unwrap()` — poison-propagating lock
    /// acquisition; use `crate::sync::{lock_recover, wait_recover}`.
    LockUnwrap,
    /// `thread::spawn` / `thread::Builder` / `thread::scope` outside the
    /// pool/router/batcher/service modules that own thread lifecycles.
    RawThreadSpawn,
    /// `.lane(` outside [`lanes::BLESSED_LANE_MODULES`] — lane construction
    /// must go through the registry's constants and helpers.
    UnregisteredLane,
}

impl RuleId {
    pub const ALL: [RuleId; 5] = [
        RuleId::NanUnsafeCmp,
        RuleId::NanUnsafeWeightFilter,
        RuleId::LockUnwrap,
        RuleId::RawThreadSpawn,
        RuleId::UnregisteredLane,
    ];

    pub fn name(self) -> &'static str {
        match self {
            RuleId::NanUnsafeCmp => "nan-unsafe-cmp",
            RuleId::NanUnsafeWeightFilter => "nan-unsafe-weight-filter",
            RuleId::LockUnwrap => "lock-unwrap",
            RuleId::RawThreadSpawn => "raw-thread-spawn",
            RuleId::UnregisteredLane => "unregistered-lane",
        }
    }
}

/// One rule violation at one source line.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: RuleId,
    /// Path relative to `rust/src`, with `/` separators.
    pub file: String,
    /// 1-based line number in the original file.
    pub line: usize,
    /// The offending line (trimmed, capped) from the *original* source.
    pub excerpt: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] {}:{}: {}",
            self.rule.name(),
            self.file,
            self.line,
            self.excerpt
        )
    }
}

/// A deliberate, justified exception. Policy (EXPERIMENTS.md §Analysis):
/// every entry must name the rule, the file, a distinguishing substring of
/// the offending line, and a one-line justification; stale entries (matching
/// nothing) fail the audit so the list can only shrink.
#[derive(Clone, Copy, Debug)]
pub struct AllowEntry {
    pub rule: RuleId,
    /// Suffix of the relative file path, e.g. `compression/image.rs`.
    pub file_suffix: &'static str,
    /// Substring of the offending line's excerpt.
    pub contains: &'static str,
    pub why: &'static str,
}

/// The checked-in allowlist. Empty after this PR's fixes: the three
/// `partial_cmp` sites, the service lock ports, and the lane-constant moves
/// eliminated every known violation. Additions need a `why` that survives
/// review.
pub const ALLOWLIST: &[AllowEntry] = &[];

/// Files (suffix match, relative to `rust/src`) that own thread lifecycles
/// and may call `thread::spawn` / `thread::scope` directly.
pub const SPAWN_BLESSED: &[&str] = &[
    "coordinator/batcher.rs",
    "coordinator/pool.rs",
    "coordinator/router.rs",
    "compression/service.rs",
];

/// Scan one file's source text. `rel` is the path relative to `rust/src`.
pub fn scan_source(rel: &str, raw: &str) -> Vec<Finding> {
    let clean = strip_comments_and_strings(raw);
    let active = non_test_line_mask(&clean);
    let usable_body = fn_body_mask(&clean, "fn usable");
    let raw_lines: Vec<&str> = raw.lines().collect();

    let lane_blessed = lanes::BLESSED_LANE_MODULES
        .iter()
        .any(|m| rel.ends_with(m));
    let spawn_blessed = SPAWN_BLESSED.iter().any(|m| rel.ends_with(m));
    let in_compression = rel.starts_with("compression/");

    let mut findings = Vec::new();
    for (idx, line) in clean.lines().enumerate() {
        if !active.get(idx).copied().unwrap_or(true) {
            continue;
        }
        let mut hit = |rule: RuleId| {
            let original = raw_lines.get(idx).copied().unwrap_or(line).trim();
            let excerpt: String = original.chars().take(120).collect();
            findings.push(Finding {
                rule,
                file: rel.to_string(),
                line: idx + 1,
                excerpt,
            });
        };

        if line.contains(".partial_cmp(") {
            hit(RuleId::NanUnsafeCmp);
        }
        if in_compression
            && (line.contains("<= 0.0") || line.contains("> 0.0"))
            && !line.contains("assert")
            && !usable_body.get(idx).copied().unwrap_or(false)
        {
            hit(RuleId::NanUnsafeWeightFilter);
        }
        if line.contains(".lock().unwrap()")
            || line.contains(".lock().expect(")
            || (line.contains(".wait(") && line.contains(".unwrap()"))
        {
            hit(RuleId::LockUnwrap);
        }
        if !spawn_blessed
            && (line.contains("thread::spawn")
                || line.contains("thread::Builder")
                || line.contains("thread::scope"))
        {
            hit(RuleId::RawThreadSpawn);
        }
        if !lane_blessed && line.contains(".lane(") {
            hit(RuleId::UnregisteredLane);
        }
    }
    findings
}

/// Walk `root` (the `rust/src` directory) and scan every `.rs` file.
pub fn scan_dir(root: &Path) -> io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for rel in rust_files(root)? {
        let raw = fs::read_to_string(root.join(&rel))?;
        findings.extend(scan_source(&rel, &raw));
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(findings)
}

/// Split findings into (violations not covered by the allowlist, allowlist
/// entries that matched nothing). Both must be empty for the audit to pass.
pub fn apply_allowlist<'a>(
    findings: &[Finding],
    allowlist: &'a [AllowEntry],
) -> (Vec<Finding>, Vec<&'a AllowEntry>) {
    let mut matched = vec![false; allowlist.len()];
    let mut unmatched_findings = Vec::new();
    for f in findings {
        let mut covered = false;
        for (i, a) in allowlist.iter().enumerate() {
            if a.rule == f.rule && f.file.ends_with(a.file_suffix) && f.excerpt.contains(a.contains)
            {
                matched[i] = true;
                covered = true;
            }
        }
        if !covered {
            unmatched_findings.push(f.clone());
        }
    }
    let stale = allowlist
        .iter()
        .zip(&matched)
        .filter(|(_, m)| !**m)
        .map(|(a, _)| a)
        .collect();
    (unmatched_findings, stale)
}

/// Files (relative paths) whose *non-test* code calls `.lane(` — the
/// registry-coverage audit compares this set against
/// [`lanes::BLESSED_LANE_MODULES`].
pub fn lane_call_files(root: &Path) -> io::Result<BTreeSet<String>> {
    let mut out = BTreeSet::new();
    for rel in rust_files(root)? {
        let raw = fs::read_to_string(root.join(&rel))?;
        let clean = strip_comments_and_strings(&raw);
        let active = non_test_line_mask(&clean);
        for (idx, line) in clean.lines().enumerate() {
            if active.get(idx).copied().unwrap_or(true) && line.contains(".lane(") {
                out.insert(rel.clone());
                break;
            }
        }
    }
    Ok(out)
}

/// Recursively list `.rs` files under `root`, as `/`-separated relative
/// paths in sorted order.
pub fn rust_files(root: &Path) -> io::Result<Vec<String>> {
    fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
        for entry in fs::read_dir(dir)? {
            let entry = entry?;
            let path = entry.path();
            if path.is_dir() {
                walk(root, &path, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                let rel = path
                    .strip_prefix(root)
                    .expect("walk stays under root")
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                out.push(rel);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(root, root, &mut out)?;
    out.sort();
    Ok(out)
}

// ---------------------------------------------------------------------------
// Source-text preprocessing.
// ---------------------------------------------------------------------------

/// Replace comments (line + nested block), string literals (plain, raw, and
/// byte variants), and char literals with spaces, preserving the line
/// structure so findings keep their original line numbers. Lifetimes (`'a`)
/// are left intact.
pub fn strip_comments_and_strings(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;

    // Emit `c` if it is a newline (keep structure), else a space.
    fn blank(out: &mut String, c: char) {
        out.push(if c == '\n' { '\n' } else { ' ' });
    }

    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw (and raw-byte) string literal: r"...", r#"..."#, br#"..."#.
        if c == 'r' || (c == 'b' && i + 1 < n && b[i + 1] == 'r') {
            let start = if c == 'b' { i + 1 } else { i };
            let mut j = start + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            let is_raw = j < n && b[j] == '"';
            // Only treat as a literal when `r` starts a token (previous char
            // is not identifier-continuing), so `for`/`ptr` etc. don't match.
            let token_start = i == 0 || !(b[i - 1].is_alphanumeric() || b[i - 1] == '_');
            if is_raw && token_start {
                for _ in i..=j {
                    out.push(' ');
                }
                i = j + 1;
                // Scan to closing quote + `hashes` hashes.
                'raw: while i < n {
                    if b[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && i + 1 + k < n && b[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                out.push(' ');
                            }
                            i += 1 + hashes;
                            break 'raw;
                        }
                    }
                    blank(&mut out, b[i]);
                    i += 1;
                }
                continue;
            }
        }
        // Plain (and byte) string literal.
        if c == '"' || (c == 'b' && i + 1 < n && b[i + 1] == '"') {
            if c == 'b' {
                out.push(' ');
                i += 1;
            }
            out.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    out.push_str("  ");
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                }
                blank(&mut out, b[i]);
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime. `'x'` / `'\n'` are literals; `'a` (no
        // closing quote right after one char) is a lifetime and stays.
        if c == '\'' {
            let is_escape = i + 1 < n && b[i + 1] == '\\';
            let closes_after_one = i + 2 < n && b[i + 2] == '\'';
            if is_escape || closes_after_one {
                out.push(' ');
                i += 1;
                while i < n {
                    if b[i] == '\\' && i + 1 < n {
                        out.push_str("  ");
                        i += 2;
                        continue;
                    }
                    if b[i] == '\'' {
                        out.push(' ');
                        i += 1;
                        break;
                    }
                    blank(&mut out, b[i]);
                    i += 1;
                }
                continue;
            }
            // Lifetime: emit as-is.
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

/// Per-line mask over *stripped* text: `true` = outside every `#[cfg(test)]`
/// item. Attribute lines, the item header, and its brace-balanced body are
/// all masked. Handles `;`-terminated items (e.g. `#[cfg(test)] use ...;`)
/// and attributes stacked between the cfg and the item.
pub fn non_test_line_mask(clean: &str) -> Vec<bool> {
    let lines: Vec<&str> = clean.lines().collect();
    let mut active = vec![true; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let t = lines[i].trim_start();
        if !(t.starts_with("#[cfg(test)]") || t.starts_with("#[cfg(all(test")) {
            i += 1;
            continue;
        }
        // Mask from the attribute line through the end of the item it gates:
        // a brace-balanced block, or a `;`-terminated item. Characters inside
        // attributes (`#[...]`, bracket-balanced) are skipped so stacked
        // attributes and `#[cfg(test)] use x;` on one line both work.
        let mut depth: i64 = 0;
        let mut saw_open = false;
        let mut saw_item = false;
        let mut attr_depth: i64 = 0;
        let mut j = i;
        'mask: while j < lines.len() {
            active[j] = false;
            let chars: Vec<char> = lines[j].chars().collect();
            let mut c = 0;
            while c < chars.len() {
                if attr_depth > 0 {
                    match chars[c] {
                        '[' => attr_depth += 1,
                        ']' => attr_depth -= 1,
                        _ => {}
                    }
                } else if chars[c] == '#' && c + 1 < chars.len() && chars[c + 1] == '[' {
                    attr_depth = 1;
                    c += 1;
                } else {
                    match chars[c] {
                        '{' => {
                            depth += 1;
                            saw_open = true;
                            saw_item = true;
                        }
                        '}' => {
                            depth -= 1;
                            if saw_open && depth == 0 {
                                j += 1;
                                break 'mask;
                            }
                        }
                        ';' if depth == 0 && !saw_open && saw_item => {
                            j += 1;
                            break 'mask;
                        }
                        ch if !ch.is_whitespace() => {
                            saw_item = true;
                        }
                        _ => {}
                    }
                }
                c += 1;
            }
            j += 1;
        }
        i = j;
    }
    active
}

/// Per-line mask: `true` = line is inside the body of the first function
/// whose header contains `header_needle` (e.g. `"fn usable"`). Used to exempt
/// the blessed weight filter itself from the weight-filter rule.
fn fn_body_mask(clean: &str, header_needle: &str) -> Vec<bool> {
    let lines: Vec<&str> = clean.lines().collect();
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].contains(header_needle) {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut saw_open = false;
        let mut j = i;
        while j < lines.len() {
            mask[j] = true;
            let mut done = false;
            for ch in lines[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        saw_open = true;
                    }
                    '}' => {
                        depth -= 1;
                        if saw_open && depth == 0 {
                            done = true;
                        }
                    }
                    _ => {}
                }
            }
            j += 1;
            if done {
                break;
            }
        }
        i = j;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripper_blanks_comments_strings_and_chars_but_keeps_lifetimes() {
        let src = concat!(
            "let a = \"x.partial_cmp(y)\"; // .lock().unwrap()\n",
            "/* thread::spawn /* nested */ still comment */\n",
            "let r = r#\"raw .lane( body\"#;\n",
            "let c = '\\n'; let q = '\"';\n",
            "fn f<'a>(x: &'a str) -> &'a str { x }\n",
        );
        let clean = strip_comments_and_strings(src);
        assert!(!clean.contains("partial_cmp"));
        assert!(!clean.contains("lock()"));
        assert!(!clean.contains("thread::spawn"));
        assert!(!clean.contains(".lane("));
        assert!(clean.contains("<'a>"), "lifetimes must survive:\n{clean}");
        assert_eq!(clean.lines().count(), src.lines().count());
    }

    #[test]
    fn stray_double_quote_in_char_literal_does_not_derail_stripper() {
        // The '"' char literal above must not open a string that swallows
        // the rest of the file.
        let src = "let q = '\"';\nlet bad = x.partial_cmp(&y);\n";
        let clean = strip_comments_and_strings(src);
        assert!(clean.contains("partial_cmp"));
    }

    #[test]
    fn cfg_test_items_are_masked() {
        let src = concat!(
            "fn prod() { a.partial_cmp(&b); }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn helper() { std::thread::spawn(|| {}); }\n",
            "}\n",
            "fn prod2() {}\n",
        );
        let findings = scan_source("stats/other.rs", src);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, RuleId::NanUnsafeCmp);
        assert_eq!(findings[0].line, 1);
    }

    #[test]
    fn weight_filter_rule_exempts_usable_and_asserts() {
        let src = concat!(
            "fn usable(w: f64) -> bool {\n",
            "    w.is_finite() && w > 0.0\n",
            "}\n",
            "fn bad(w: f64) -> bool { w > 0.0 }\n",
            "fn checked(w: f64) { assert!(w > 0.0); }\n",
        );
        let findings = scan_source("compression/codec.rs", src);
        let weights: Vec<_> = findings
            .iter()
            .filter(|f| f.rule == RuleId::NanUnsafeWeightFilter)
            .collect();
        assert_eq!(weights.len(), 1, "{findings:?}");
        assert_eq!(weights[0].line, 4);
        // Same source outside compression/ raises no weight findings.
        let outside = scan_source("spec/other.rs", src);
        assert!(outside
            .iter()
            .all(|f| f.rule != RuleId::NanUnsafeWeightFilter));
    }

    #[test]
    fn lock_and_spawn_and_lane_rules_respect_blessings() {
        let src = concat!(
            "fn f() {\n",
            "    let g = self.state.lock().unwrap();\n",
            "    let g = cv.wait(g).unwrap();\n",
            "    std::thread::spawn(move || {});\n",
            "    let l = rng.lane(slot, 3);\n",
            "}\n",
        );
        let findings = scan_source("coordinator/server.rs", src);
        let rules: Vec<RuleId> = findings.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&RuleId::LockUnwrap));
        assert!(rules.contains(&RuleId::RawThreadSpawn));
        assert!(rules.contains(&RuleId::UnregisteredLane));
        assert_eq!(
            findings
                .iter()
                .filter(|f| f.rule == RuleId::LockUnwrap)
                .count(),
            2
        );
        // pool.rs may spawn; kernel.rs may build lanes.
        let pool = scan_source("coordinator/pool.rs", src);
        assert!(pool.iter().all(|f| f.rule != RuleId::RawThreadSpawn));
        let kernel = scan_source("spec/kernel.rs", src);
        assert!(kernel.iter().all(|f| f.rule != RuleId::UnregisteredLane));
    }

    #[test]
    fn allowlist_covers_and_reports_stale_entries() {
        let findings = vec![Finding {
            rule: RuleId::NanUnsafeCmp,
            file: "compression/image.rs".to_string(),
            line: 7,
            excerpt: "a.partial_cmp(&b)".to_string(),
        }];
        let allow = [
            AllowEntry {
                rule: RuleId::NanUnsafeCmp,
                file_suffix: "compression/image.rs",
                contains: "partial_cmp",
                why: "test entry",
            },
            AllowEntry {
                rule: RuleId::LockUnwrap,
                file_suffix: "nowhere.rs",
                contains: "never",
                why: "stale entry",
            },
        ];
        let (open, stale) = apply_allowlist(&findings, &allow);
        assert!(open.is_empty());
        assert_eq!(stale.len(), 1);
        assert_eq!(stale[0].why, "stale entry");
    }
}
