//! Static-analysis subsystem: the machine-checked invariants layer.
//!
//! Two halves, both std-only and dependency-free:
//!
//! - [`lanes`] — the central RNG lane registry: every `(slot, lane)` region
//!   the coupling stack consumes, declared as data with owner/span/budget,
//!   plus a pure overlap checker that runs as a tier-1 test and as debug
//!   assertions at dispatch sites.
//! - [`repo_lint`] — a repo-specific source auditor that scans `rust/src`
//!   for the bug classes this codebase has shipped (NaN-unsafe comparisons,
//!   poison-propagating locks, stray thread spawns, unregistered lane
//!   construction), gated in CI via `tests/static_audit.rs`.
//!
//! Policy and the human-readable lane table live in EXPERIMENTS.md
//! §Analysis.

pub mod lanes;
pub mod repo_lint;
