//! Central RNG lane registry: the machine-checked coordinate map.
//!
//! The coupling stack is correct only if an implicit contract holds: every
//! `(slot, lane)` coordinate of the shared [`CounterRng`] is owned by exactly
//! one consumer, except where two consumers *deliberately* read the same
//! coordinates (GLS verification re-reading draft exponentials — that overlap
//! IS the coupling). PR 8 shipped a real aliasing bug from this class
//! (candidate prior draws walking into the next candidate's lane), so the map
//! is no longer allowed to live only in module docs: this module declares each
//! lane region as data, checks the contract as a tier-1 test, and exports the
//! constants/helpers the hot sites use so a future collision is a typed
//! failure instead of silent correlation.
//!
//! The human-readable version of this table lives in `EXPERIMENTS.md`
//! §Analysis; `spec/kernel.rs` and `compression/codec.rs` module docs point
//! here. Contexts are independent key spaces (different root RNGs or different
//! `slot` conventions); regions only need to be disjoint *within* a context.

use crate::spec::types::VerifierKind;
use crate::stats::rng::SplitMix64;

// ---------------------------------------------------------------------------
// Shared lane constants (single source of truth; consumers re-export).
// ---------------------------------------------------------------------------

/// Codec lane carrying the bin-selection exponentials. Sits just above the
/// reserved per-candidate exp-set lanes `0..k` (k is bounded far below 2^32).
pub const CODEC_LANE_BINS: u64 = (1 << 32) + 1;
/// First lane of the codec's per-candidate prior-draw block: candidate `i`
/// draws from lane `CODEC_PRIOR_LANE_BASE + i`.
pub const CODEC_PRIOR_LANE_BASE: u64 = 1 << 33;
/// Number of lanes reserved for the per-candidate prior block; `n_samples`
/// must stay strictly below this so the block never reaches other regions.
pub const CODEC_PRIOR_LANE_SPAN: u64 = 1 << 32;
/// Per-candidate draw budget inside one prior lane (debug tripwire in the
/// codec's `shared_randomness`).
pub const CODEC_PRIOR_DRAW_BUDGET: u64 = 1 << 32;
/// Salt base for per-prompt token sub-streams in `workload/trace.rs`.
pub const TRACE_PROMPT_SALT_BASE: u64 = 0x70_0000;

/// Source files (relative to `rust/src`) allowed to call `CounterRng::lane`
/// directly. Everyone else must go through these modules so the registry
/// stays the single map of lane construction. Consumed by the repo lint
/// (rule `UnregisteredLane`) and cross-checked by `tests/static_audit.rs`.
pub const BLESSED_LANE_MODULES: &[&str] = &[
    "compression/codec.rs",
    "spec/kernel.rs",
    "spec/types.rs",
    "stats/rng.rs",
];

// ---------------------------------------------------------------------------
// Region model + pure checker.
// ---------------------------------------------------------------------------

/// How a consumer relates to the lanes it touches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneRole {
    /// Sole writer/reader of the region; must not overlap any other owner in
    /// the same context.
    Owner,
    /// Deliberately re-reads the named owner's coordinates (the coupling).
    /// Must lie entirely inside that owner's span.
    CoupledReader(&'static str),
    /// Draws whose outputs are provably dropped (e.g. extra draft lanes under
    /// a single-draft verifier). Exempt from overlap checking: sharing a
    /// coordinate with a discarded draw cannot correlate anything observable.
    Discarded,
}

/// One contiguous lane region `[lo, hi)` used by one consumer.
#[derive(Clone, Debug)]
pub struct LaneRegion {
    /// Stable name, referenced by `CoupledReader` entries and error messages.
    pub name: &'static str,
    /// Module path of the code performing the draws.
    pub owner: &'static str,
    pub role: LaneRole,
    /// First lane (inclusive).
    pub lo: u64,
    /// One past the last lane (exclusive).
    pub hi: u64,
    /// Max item-coordinate draws per lane. `u64::MAX` means "indexed by item
    /// id over the whole counter space" (one draw per item coordinate).
    pub draw_budget: u64,
    pub purpose: &'static str,
}

impl LaneRegion {
    fn owner_span(&self) -> bool {
        matches!(self.role, LaneRole::Owner)
    }
}

/// Typed contract violations reported by [`check`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LaneError {
    /// `hi <= lo`.
    EmptyRegion { name: String },
    /// A region with no draws allowed is a registry bug.
    ZeroBudget { name: String },
    /// Two `Owner` regions in the same context intersect.
    Overlap { a: String, b: String },
    /// A `CoupledReader` names an owner that is not registered.
    UnknownOwner { reader: String, owner: String },
    /// A `CoupledReader` reads lanes outside its owner's span.
    ReaderOutsideOwner { reader: String, owner: String },
    /// A region extends past the span reserved for it in the layout.
    RegionOverReserved {
        name: String,
        len: u64,
        reserved: u64,
    },
    /// Two derived RNG salts collide, so two sub-streams would be identical.
    SaltCollision { a: String, b: String },
}

impl std::fmt::Display for LaneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LaneError::EmptyRegion { name } => write!(f, "lane region `{name}` is empty"),
            LaneError::ZeroBudget { name } => {
                write!(f, "lane region `{name}` has a zero draw budget")
            }
            LaneError::Overlap { a, b } => {
                write!(f, "owner lane regions `{a}` and `{b}` overlap")
            }
            LaneError::UnknownOwner { reader, owner } => {
                write!(f, "coupled reader `{reader}` names unknown owner `{owner}`")
            }
            LaneError::ReaderOutsideOwner { reader, owner } => write!(
                f,
                "coupled reader `{reader}` reads lanes outside owner `{owner}`"
            ),
            LaneError::RegionOverReserved {
                name,
                len,
                reserved,
            } => write!(
                f,
                "lane region `{name}` needs {len} lanes but only {reserved} are reserved"
            ),
            LaneError::SaltCollision { a, b } => {
                write!(f, "RNG salts collide between `{a}` and `{b}`")
            }
        }
    }
}

impl std::error::Error for LaneError {}

/// Check one context's regions: non-empty, budgeted, owners pairwise
/// disjoint, every coupled reader inside its named owner. Pure — no IO, no
/// global state — so it can run as a tier-1 test and as a debug assertion at
/// dispatch time.
pub fn check(regions: &[LaneRegion]) -> Result<(), LaneError> {
    for r in regions {
        if r.hi <= r.lo {
            return Err(LaneError::EmptyRegion {
                name: r.name.to_string(),
            });
        }
        if r.draw_budget == 0 {
            return Err(LaneError::ZeroBudget {
                name: r.name.to_string(),
            });
        }
    }
    for r in regions {
        if let LaneRole::CoupledReader(of) = r.role {
            let owner = regions
                .iter()
                .find(|o| o.name == of && o.owner_span())
                .ok_or_else(|| LaneError::UnknownOwner {
                    reader: r.name.to_string(),
                    owner: of.to_string(),
                })?;
            if r.lo < owner.lo || r.hi > owner.hi {
                return Err(LaneError::ReaderOutsideOwner {
                    reader: r.name.to_string(),
                    owner: of.to_string(),
                });
            }
        }
    }
    let mut owners: Vec<&LaneRegion> = regions.iter().filter(|r| r.owner_span()).collect();
    owners.sort_by_key(|r| r.lo);
    for w in owners.windows(2) {
        if w[1].lo < w[0].hi {
            return Err(LaneError::Overlap {
                a: w[0].name.to_string(),
                b: w[1].name.to_string(),
            });
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Engine context: one decoding slot of a K-draft engine.
// ---------------------------------------------------------------------------

/// Lane-consumption shape of a verifier family at one decoding slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineLaneProfile {
    /// Gumbel-max panel race: verification re-reads draft exponentials
    /// (GLS conditional/strong, Daliri, fault-injection shim).
    PanelRace,
    /// Rejection cascade (SpecInfer/SpecTr): verification uniforms live
    /// strictly above the draft lanes.
    Rejection,
    /// Single-draft baseline: consumes draft lane 0 plus uniforms at lanes
    /// {1, 2}; any further draft lanes are drawn but discarded.
    SingleDraft,
    /// Bilateral GLS harness: its own context with `K * m_targets` lanes.
    Bilateral { m_targets: usize },
}

/// The registry's view of each [`VerifierKind`]. `FaultInjection` behaves as
/// GLS with an armed token, so it shares the panel-race profile.
pub fn engine_profile_of(kind: VerifierKind) -> EngineLaneProfile {
    match kind {
        VerifierKind::Gls
        | VerifierKind::GlsStrong
        | VerifierKind::Daliri
        | VerifierKind::FaultInjection => EngineLaneProfile::PanelRace,
        VerifierKind::SpecInfer | VerifierKind::SpecTr => EngineLaneProfile::Rejection,
        VerifierKind::SingleDraft => EngineLaneProfile::SingleDraft,
    }
}

/// Materialize the lane regions a profile touches at one slot of a `k`-draft
/// engine. Mirrors the "RNG coordinate map" table in `spec/kernel.rs`.
pub fn engine_regions(profile: EngineLaneProfile, k: usize) -> Vec<LaneRegion> {
    let k = k as u64;
    match profile {
        EngineLaneProfile::PanelRace => vec![
            LaneRegion {
                name: "engine-draft-exp",
                owner: "spec::engine",
                role: LaneRole::Owner,
                lo: 0,
                hi: k,
                draw_budget: u64::MAX,
                purpose: "draft-phase Exp(slot, lane, item), lane per draft",
            },
            LaneRegion {
                name: "race-verify-exp",
                owner: "spec::kernel",
                role: LaneRole::CoupledReader("engine-draft-exp"),
                lo: 0,
                hi: k,
                draw_budget: u64::MAX,
                purpose: "GLS/Daliri verify re-reads draft exponentials (the coupling)",
            },
        ],
        EngineLaneProfile::Rejection => vec![
            LaneRegion {
                name: "engine-draft-exp",
                owner: "spec::engine",
                role: LaneRole::Owner,
                lo: 0,
                hi: k,
                draw_budget: u64::MAX,
                purpose: "draft-phase Exp(slot, lane, item), lane per draft",
            },
            LaneRegion {
                name: "rejection-verify-uniforms",
                owner: "spec::kernel",
                role: LaneRole::Owner,
                lo: k,
                hi: 2 * k + 2,
                draw_budget: u64::MAX,
                purpose: "SpecInfer/SpecTr round + bonus uniforms, disjoint from drafting",
            },
        ],
        EngineLaneProfile::SingleDraft => {
            let mut v = vec![
                LaneRegion {
                    name: "single-draft-exp",
                    owner: "spec::engine",
                    role: LaneRole::Owner,
                    lo: 0,
                    hi: 1,
                    draw_budget: u64::MAX,
                    purpose: "the one draft lane the baseline verifier consumes",
                },
                LaneRegion {
                    name: "single-draft-uniforms",
                    owner: "spec::kernel",
                    role: LaneRole::Owner,
                    lo: 1,
                    hi: 3,
                    draw_budget: u64::MAX,
                    purpose: "accept + bonus uniforms at lanes {1, 2}",
                },
            ];
            if k > 1 {
                v.push(LaneRegion {
                    name: "single-draft-ignored-drafts",
                    owner: "spec::engine",
                    role: LaneRole::Discarded,
                    lo: 1,
                    hi: k,
                    draw_budget: u64::MAX,
                    purpose: "batch-wide drafting fills lanes 1..K; outputs are dropped",
                });
            }
            v
        }
        EngineLaneProfile::Bilateral { m_targets } => vec![LaneRegion {
            name: "bilateral-exp",
            owner: "spec::gls::bilateral",
            role: LaneRole::Owner,
            lo: 0,
            hi: k * m_targets as u64,
            draw_budget: u64::MAX,
            purpose: "Exp(slot, k*M + m, item) grid over drafts x targets",
        }],
    }
}

/// Registry check for one engine slot; `spec::kernel::verify_block_kind`
/// debug-asserts this at dispatch.
pub fn check_engine_profile(profile: EngineLaneProfile, k: usize) -> Result<(), LaneError> {
    check(&engine_regions(profile, k.max(1)))
}

// ---------------------------------------------------------------------------
// Codec context: one block of the list-coupled codec.
// ---------------------------------------------------------------------------

/// Lane regions one codec block touches (`compression/codec.rs`): the
/// per-decoder exp-set lanes, the bin-selection lane, and the per-candidate
/// prior block.
pub fn codec_regions(n_samples: usize, k_decoders: usize) -> Vec<LaneRegion> {
    vec![
        LaneRegion {
            name: "codec-exp-sets",
            owner: "compression::codec",
            role: LaneRole::Owner,
            lo: 0,
            hi: (k_decoders as u64).max(1),
            draw_budget: u64::MAX,
            purpose: "per-decoder race exponentials (Shared mode uses lane 0 only)",
        },
        LaneRegion {
            name: "codec-bins",
            owner: "compression::codec",
            role: LaneRole::Owner,
            lo: CODEC_LANE_BINS,
            hi: CODEC_LANE_BINS + 1,
            draw_budget: u64::MAX,
            purpose: "bin-selection exponentials for the list race",
        },
        LaneRegion {
            name: "codec-candidate-priors",
            owner: "compression::codec",
            role: LaneRole::Owner,
            lo: CODEC_PRIOR_LANE_BASE,
            hi: CODEC_PRIOR_LANE_BASE + (n_samples as u64).max(1),
            draw_budget: CODEC_PRIOR_DRAW_BUDGET,
            purpose: "candidate i draws its prior stream from lane BASE + i",
        },
    ]
}

/// Full layout check for a codec configuration. Preserves the seed's strict
/// bound `n_samples < 2^32` (the per-candidate block must fit its reserved
/// span) and re-checks region disjointness generically.
/// `CodecConfig::validate` delegates here.
pub fn check_codec_layout(n_samples: usize, k_decoders: usize) -> Result<(), LaneError> {
    if n_samples as u64 >= CODEC_PRIOR_LANE_SPAN {
        return Err(LaneError::RegionOverReserved {
            name: "codec-candidate-priors".to_string(),
            len: n_samples as u64,
            reserved: CODEC_PRIOR_LANE_SPAN,
        });
    }
    check(&codec_regions(n_samples, k_decoders))
}

// ---------------------------------------------------------------------------
// Trace context: salted sub-RNG seeds in workload/trace.rs.
// ---------------------------------------------------------------------------

/// The four salted sub-streams `RequestTrace::generate` derives from one base
/// seed. Discriminants are the salts fed to `SplitMix64::mix`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceStream {
    Arrivals = 1,
    PromptLen = 2,
    OutputLen = 3,
    VerifierMix = 4,
}

impl TraceStream {
    pub const ALL: [TraceStream; 4] = [
        TraceStream::Arrivals,
        TraceStream::PromptLen,
        TraceStream::OutputLen,
        TraceStream::VerifierMix,
    ];

    pub fn label(self) -> &'static str {
        match self {
            TraceStream::Arrivals => "arrivals",
            TraceStream::PromptLen => "prompt-len",
            TraceStream::OutputLen => "output-len",
            TraceStream::VerifierMix => "verifier-mix",
        }
    }
}

/// Seed for one of the four trace sub-RNGs. Because `x ^ a == x ^ b` iff
/// `a == b`, distinct salts give distinct seeds for *every* base seed, so the
/// collision check below is base-seed independent.
pub fn trace_stream_seed(base_seed: u64, stream: TraceStream) -> u64 {
    base_seed ^ SplitMix64::mix(stream as u64)
}

/// Seed for the per-request prompt-token sub-RNG (request `idx`).
pub fn trace_prompt_seed(base_seed: u64, idx: usize) -> u64 {
    base_seed ^ SplitMix64::mix(TRACE_PROMPT_SALT_BASE + idx as u64)
}

/// Check that the four stream salts plus `n_requests` prompt salts are
/// pairwise distinct (equivalently: the derived seeds are distinct for every
/// base seed).
pub fn check_trace_salts(n_requests: usize) -> Result<(), LaneError> {
    let label = |tag: u64| -> String {
        if tag < 4 {
            format!("trace-stream:{}", TraceStream::ALL[tag as usize].label())
        } else {
            format!("trace-prompt:{}", tag - 4)
        }
    };
    // Tag streams 0..4 and prompts 4.. so labels survive the sort.
    let mut salts: Vec<(u64, u64)> = TraceStream::ALL
        .iter()
        .enumerate()
        .map(|(i, &s)| (SplitMix64::mix(s as u64), i as u64))
        .collect();
    salts.extend(
        (0..n_requests).map(|i| (SplitMix64::mix(TRACE_PROMPT_SALT_BASE + i as u64), 4 + i as u64)),
    );
    salts.sort_unstable();
    for w in salts.windows(2) {
        if w[0].0 == w[1].0 {
            return Err(LaneError::SaltCollision {
                a: label(w[0].1),
                b: label(w[1].1),
            });
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Server context: the lane = id request convention.
// ---------------------------------------------------------------------------

/// The serving layer's lane convention: request `id` streams from sub-RNG
/// `root.split(id)`. The identity map is the contract — distinct request ids
/// get distinct split lanes, so per-request randomness never aliases across
/// requests. `Request::new` and `Server::try_submit` route through this
/// function; the property test in `tests/static_audit.rs` checks the derived
/// split keys stay distinct over 10k requests.
#[inline]
pub fn server_request_lane(request_id: u64) -> u64 {
    request_id
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_profiles_check_over_grid() {
        let mut kinds: Vec<VerifierKind> = VerifierKind::all().to_vec();
        kinds.push(VerifierKind::FaultInjection);
        for k in [1usize, 2, 4, 8, 16] {
            for &kind in &kinds {
                check_engine_profile(engine_profile_of(kind), k)
                    .unwrap_or_else(|e| panic!("{kind:?} K={k}: {e}"));
            }
            for m in [1usize, 2, 4] {
                check_engine_profile(EngineLaneProfile::Bilateral { m_targets: m }, k)
                    .unwrap_or_else(|e| panic!("bilateral K={k} M={m}: {e}"));
            }
        }
    }

    #[test]
    fn codec_layout_checks_and_rejects_oversize() {
        for (n, k) in [(1usize, 1usize), (64, 4), (1 << 10, 16)] {
            check_codec_layout(n, k).unwrap_or_else(|e| panic!("n={n} k={k}: {e}"));
        }
        let err = check_codec_layout(CODEC_PRIOR_LANE_SPAN as usize, 4).unwrap_err();
        assert!(matches!(err, LaneError::RegionOverReserved { .. }), "{err}");
    }

    #[test]
    fn checker_catches_owner_overlap() {
        let mut regions = engine_regions(EngineLaneProfile::Rejection, 4);
        regions[1].lo = 3; // collide with draft lanes [0, 4)
        let err = check(&regions).unwrap_err();
        assert!(matches!(err, LaneError::Overlap { .. }), "{err}");
    }

    #[test]
    fn checker_catches_reader_escaping_owner() {
        let mut regions = engine_regions(EngineLaneProfile::PanelRace, 4);
        regions[1].hi = 5; // verify reads a lane the draft phase never wrote
        let err = check(&regions).unwrap_err();
        assert!(matches!(err, LaneError::ReaderOutsideOwner { .. }), "{err}");
    }

    #[test]
    fn checker_catches_unknown_owner_and_empty_region() {
        let regions = vec![LaneRegion {
            name: "orphan-reader",
            owner: "nowhere",
            role: LaneRole::CoupledReader("missing"),
            lo: 0,
            hi: 1,
            draw_budget: 1,
            purpose: "",
        }];
        assert!(matches!(
            check(&regions).unwrap_err(),
            LaneError::UnknownOwner { .. }
        ));
        let empty = vec![LaneRegion {
            name: "empty",
            owner: "x",
            role: LaneRole::Owner,
            lo: 3,
            hi: 3,
            draw_budget: 1,
            purpose: "",
        }];
        assert!(matches!(
            check(&empty).unwrap_err(),
            LaneError::EmptyRegion { .. }
        ));
    }

    #[test]
    fn discarded_regions_may_overlap_owners() {
        // Single-draft under a K=8 engine: ignored draft lanes 1..8 overlap
        // the verify uniforms {1, 2}; the registry must accept that because
        // the overlapping draws are discarded.
        check_engine_profile(EngineLaneProfile::SingleDraft, 8).unwrap();
    }

    #[test]
    fn trace_salts_distinct_for_ten_thousand_requests() {
        check_trace_salts(10_000).unwrap();
    }

    #[test]
    fn salt_collision_is_reported_with_labels() {
        // Two identical salts must trip the checker; build the collision by
        // hand through the internal representation used by check_trace_salts.
        let err = LaneError::SaltCollision {
            a: "trace-stream:arrivals".into(),
            b: "trace-prompt:7".into(),
        };
        assert!(err.to_string().contains("collide"));
    }
}
