//! Configuration for the engine and server.
//!
//! Parsed from a tiny `key = value` config format (no serde offline) plus
//! CLI overrides; every experiment in `rust/benches/` builds these
//! programmatically.

use crate::model::sampling::SamplingParams;
use crate::spec::types::VerifierKind;

/// Default for [`EngineConfig::parallel_threshold`]: minimum per-sequence
/// verification work `k · (l+1) · vocab` before `step_blocks` fans
/// verification out to worker threads; below it the serial path wins.
///
/// This is a *measured* default, not a magic number: the calibration
/// procedure (documented in EXPERIMENTS.md §Perf, "Threshold sweep")
/// sweeps `benches/perf_engine.rs`'s L3d threshold-sweep section — serial
/// vs pooled stepping at batch 4 across vocab sizes, i.e. across
/// `k · (l+1) · vocab` — and picks the crossover where the pooled path
/// first beats serial on CI hardware, rounded up to the next power of
/// two. Rounding *up* biases toward serial near the crossover, where
/// dispatch overhead (ticket build, two condvar round-trips, panel-slice
/// handoff) is the same order as the verification math itself and
/// fan-out wins nothing. Re-run the sweep (`BENCH_perf.json` L3d entries
/// are the artifact) and override via the `parallel_threshold` config key
/// when deploying on different cores.
pub const DEFAULT_PARALLEL_THRESHOLD: usize = 16_384;

/// How `step_blocks` executes the per-sequence verification jobs once the
/// batch clears the parallelism threshold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VerifyBackend {
    /// Always verify on the engine thread (the bit-exactness oracle).
    Serial,
    /// Per-block `std::thread::scope` fan-out with cold workspaces and no
    /// draft-panel reuse — the pre-pool engine, kept faithful as the perf
    /// baseline `benches/perf_engine.rs` compares the pool against.
    Spawn,
    /// Persistent worker pool: long-lived threads parked on a condvar,
    /// each owning a `CouplingWorkspace` that persists across blocks,
    /// with panel-slice handoff from the draft phase (the default).
    Pool,
}

impl VerifyBackend {
    pub fn name(&self) -> &'static str {
        match self {
            VerifyBackend::Serial => "serial",
            VerifyBackend::Spawn => "spawn",
            VerifyBackend::Pool => "pool",
        }
    }

    pub fn parse(s: &str) -> Option<VerifyBackend> {
        [VerifyBackend::Serial, VerifyBackend::Spawn, VerifyBackend::Pool]
            .into_iter()
            .find(|b| b.name() == s)
    }
}

/// Who owns the verify pool when serving (`verify_backend = pool`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolScope {
    /// One pool per worker engine (the PR 4 design): steady-state verify
    /// threads scale as `workers × verify_workers`. Kept as the
    /// isolation-first escape hatch and the L3e comparison baseline.
    Engine,
    /// One server-global pool shared by every router worker (the
    /// default): verify-thread count equals the pool size, independent of
    /// the server worker count. Engines submit concurrently through
    /// epoch-tagged tickets (`coordinator::pool` module docs).
    Server,
}

impl PoolScope {
    pub fn name(&self) -> &'static str {
        match self {
            PoolScope::Engine => "engine",
            PoolScope::Server => "server",
        }
    }

    pub fn parse(s: &str) -> Option<PoolScope> {
        [PoolScope::Engine, PoolScope::Server].into_iter().find(|p| p.name() == s)
    }
}

/// Speculative-decoding engine configuration (one worker).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Number of drafts K (paper: 2–8).
    pub num_drafts: usize,
    /// Draft length L per block (paper: 4 for i.i.d., 5 for diverse).
    pub block_len: usize,
    /// Verification scheme.
    pub verifier: VerifierKind,
    /// Target model sampling (temperature / top-k).
    pub target_params: SamplingParams,
    /// Per-draft-lane sampling. Length 1 = shared across lanes (i.i.d.
    /// drafts); length K = diverse drafts (Table 2/4 temperature grid).
    pub draft_params: Vec<SamplingParams>,
    /// Hard cap on sequence length (prompt + generation).
    pub max_seq_len: usize,
    /// Shared-randomness root key; each request splits its own lane.
    pub seed: u64,
    /// Minimum per-sequence verification work `k · (l+1) · vocab` before
    /// verification fans out across threads (see
    /// [`DEFAULT_PARALLEL_THRESHOLD`] for the calibration procedure).
    /// `0` means "always parallel once the batch has ≥ 2 sequences".
    pub parallel_threshold: usize,
    /// Verify-pool size. `0` = auto: `available_parallelism`. Under
    /// `pool_scope = engine` the router divides the auto size by the
    /// server's worker count (so W per-engine pools don't oversubscribe
    /// cores); under the server-global pool there is exactly one pool, so
    /// auto uses the full parallelism undivided.
    pub verify_workers: usize,
    /// Parallel execution backend for verification jobs.
    pub verify_backend: VerifyBackend,
    /// Resubmit verify jobs that fail on the pool once before failing
    /// the sequence. Retries target *transient* faults — a worker dying
    /// mid-ticket — where resubmission succeeds; a deterministic
    /// verifier panic simply fails again and the sequence retires
    /// `Failed` exactly as before (one extra contained pool fault, same
    /// engine-side accounting). Off by default: the retry spares are
    /// cloned job inputs on every pooled dispatch, so serving configs
    /// opt in explicitly.
    pub retry_transient_faults: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            num_drafts: 4,
            block_len: 4,
            verifier: VerifierKind::Gls,
            target_params: SamplingParams::default(),
            draft_params: vec![SamplingParams::default()],
            max_seq_len: 512,
            seed: 0xC0FFEE,
            parallel_threshold: DEFAULT_PARALLEL_THRESHOLD,
            verify_workers: 0,
            verify_backend: VerifyBackend::Pool,
            retry_transient_faults: false,
        }
    }
}

impl EngineConfig {
    pub fn draft_params_for(&self, lane: usize) -> SamplingParams {
        if self.draft_params.len() == 1 {
            self.draft_params[0]
        } else {
            self.draft_params[lane % self.draft_params.len()]
        }
    }

    /// Effective number of draft lanes: single-draft verifiers only ever
    /// consume lane 0.
    pub fn effective_drafts(&self) -> usize {
        if self.verifier.is_single_draft() {
            1
        } else {
            self.num_drafts
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.num_drafts == 0 {
            return Err("num_drafts must be ≥ 1".into());
        }
        if self.block_len == 0 {
            return Err("block_len must be ≥ 1".into());
        }
        if self.draft_params.len() != 1 && self.draft_params.len() != self.num_drafts {
            return Err(format!(
                "draft_params must have length 1 or K={}, got {}",
                self.num_drafts,
                self.draft_params.len()
            ));
        }
        if self.max_seq_len < self.block_len + 2 {
            return Err("max_seq_len too small for one block".into());
        }
        if self.verifier == VerifierKind::SpecTr && self.draft_params.len() > 1 {
            return Err("SpecTr verification requires identically distributed drafts".into());
        }
        Ok(())
    }
}

/// Server-level configuration (routing + batching + capacity).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Worker threads, each with its own engine + model instance.
    pub workers: usize,
    /// Max sequences batched into one engine iteration.
    pub max_batch: usize,
    /// Batching deadline: flush a partial batch after this long.
    pub batch_deadline: std::time::Duration,
    /// Max concurrently running sequences per worker (continuous batching).
    pub max_running: usize,
    /// KV cache capacity per worker, in pages.
    pub kv_pages: usize,
    /// Tokens per KV page.
    pub kv_page_size: usize,
    /// Verify-pool ownership: one server-global shared pool (default) or
    /// one pool per worker engine. Only meaningful with
    /// `verify_backend = pool`.
    pub pool_scope: PoolScope,
    /// Admission bound: maximum requests in flight (admitted but not yet
    /// retired) across all workers before `try_submit` sheds with
    /// `AdmitError::QueueFull`. `0` = unbounded (the default, preserving
    /// pre-lifecycle behavior where `submit` never refuses work).
    pub admit_queue: usize,
    /// Shed requests whose deadline has already expired at admission
    /// time (`AdmitError::DeadlineExpired`) instead of admitting them
    /// just to cancel them at the first block boundary. Off by default.
    pub shed_expired: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            batch_deadline: std::time::Duration::from_millis(2),
            max_running: 16,
            kv_pages: 4096,
            kv_page_size: 16,
            pool_scope: PoolScope::Server,
            admit_queue: 0,
            shed_expired: false,
        }
    }
}

impl ServerConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.workers == 0 || self.max_batch == 0 || self.max_running == 0 {
            return Err("workers, max_batch, max_running must be ≥ 1".into());
        }
        if self.kv_pages == 0 || self.kv_page_size == 0 {
            return Err("kv capacity must be ≥ 1".into());
        }
        Ok(())
    }
}

/// Parse `key = value` lines ('#' comments). Unknown keys are errors —
/// catching config typos loudly is worth more than forward compatibility
/// in a reproduction repo.
pub fn parse_config(text: &str) -> Result<(EngineConfig, ServerConfig), String> {
    let mut ec = EngineConfig::default();
    let mut sc = ServerConfig::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        let value = value.trim();
        let err = |e: &str| format!("line {}: {key}: {e}", lineno + 1);
        match key {
            "num_drafts" => ec.num_drafts = value.parse().map_err(|_| err("bad usize"))?,
            "block_len" => ec.block_len = value.parse().map_err(|_| err("bad usize"))?,
            "verifier" => {
                ec.verifier =
                    VerifierKind::parse(value).ok_or_else(|| err("unknown verifier"))?
            }
            "target_temperature" => {
                ec.target_params.temperature = value.parse().map_err(|_| err("bad f64"))?
            }
            "draft_temperatures" => {
                let temps: Result<Vec<f64>, _> =
                    value.split(',').map(|t| t.trim().parse::<f64>()).collect();
                let temps = temps.map_err(|_| err("bad f64 list"))?;
                ec.draft_params = temps
                    .into_iter()
                    .map(|t| SamplingParams::new(t, ec.target_params.top_k))
                    .collect();
            }
            "top_k" => {
                let k: usize = value.parse().map_err(|_| err("bad usize"))?;
                let top_k = if k == 0 { None } else { Some(k) };
                ec.target_params.top_k = top_k;
                for dp in ec.draft_params.iter_mut() {
                    dp.top_k = top_k;
                }
            }
            "max_seq_len" => ec.max_seq_len = value.parse().map_err(|_| err("bad usize"))?,
            "seed" => ec.seed = value.parse().map_err(|_| err("bad u64"))?,
            "parallel_threshold" => {
                ec.parallel_threshold = value.parse().map_err(|_| err("bad usize"))?
            }
            "verify_workers" => {
                ec.verify_workers = value.parse().map_err(|_| err("bad usize"))?
            }
            "verify_backend" => {
                ec.verify_backend =
                    VerifyBackend::parse(value).ok_or_else(|| err("unknown backend"))?
            }
            "retry_transient_faults" => {
                ec.retry_transient_faults = value.parse().map_err(|_| err("bad bool"))?
            }
            "workers" => sc.workers = value.parse().map_err(|_| err("bad usize"))?,
            "max_batch" => sc.max_batch = value.parse().map_err(|_| err("bad usize"))?,
            "batch_deadline_ms" => {
                let ms: u64 = value.parse().map_err(|_| err("bad u64"))?;
                sc.batch_deadline = std::time::Duration::from_millis(ms);
            }
            "max_running" => sc.max_running = value.parse().map_err(|_| err("bad usize"))?,
            "kv_pages" => sc.kv_pages = value.parse().map_err(|_| err("bad usize"))?,
            "kv_page_size" => sc.kv_page_size = value.parse().map_err(|_| err("bad usize"))?,
            "pool_scope" => {
                sc.pool_scope = PoolScope::parse(value).ok_or_else(|| err("unknown pool scope"))?
            }
            "admit_queue" => sc.admit_queue = value.parse().map_err(|_| err("bad usize"))?,
            "shed_expired" => sc.shed_expired = value.parse().map_err(|_| err("bad bool"))?,
            _ => return Err(format!("line {}: unknown key '{key}'", lineno + 1)),
        }
    }
    ec.validate()?;
    sc.validate()?;
    Ok((ec, sc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        assert!(EngineConfig::default().validate().is_ok());
        assert!(ServerConfig::default().validate().is_ok());
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
            # experiment: table 2 diverse drafts
            num_drafts = 2
            block_len = 5
            verifier = gls
            target_temperature = 2.0
            draft_temperatures = 0.5, 1.0
            top_k = 50
            workers = 4
            max_batch = 16
            batch_deadline_ms = 5
        "#;
        let (ec, sc) = parse_config(text).unwrap();
        assert_eq!(ec.num_drafts, 2);
        assert_eq!(ec.block_len, 5);
        assert_eq!(ec.draft_params.len(), 2);
        assert_eq!(ec.draft_params[0].temperature, 0.5);
        assert_eq!(ec.target_params.temperature, 2.0);
        assert_eq!(sc.workers, 4);
        assert_eq!(sc.batch_deadline, std::time::Duration::from_millis(5));
    }

    #[test]
    fn parse_rejects_unknown_key() {
        assert!(parse_config("bogus = 1").is_err());
    }

    #[test]
    fn parse_rejects_bad_value() {
        assert!(parse_config("num_drafts = many").is_err());
    }

    #[test]
    fn validate_rejects_spectr_with_diverse_drafts() {
        let text = "verifier = spectr\nnum_drafts = 2\ndraft_temperatures = 0.5, 1.5";
        assert!(parse_config(text).is_err());
    }

    #[test]
    fn validate_rejects_draft_params_mismatch() {
        let mut ec = EngineConfig {
            num_drafts: 4,
            draft_params: vec![SamplingParams::default(); 3],
            ..EngineConfig::default()
        };
        assert!(ec.validate().is_err());
        ec.draft_params = vec![SamplingParams::default(); 4];
        assert!(ec.validate().is_ok());
    }

    #[test]
    fn effective_drafts_collapses_for_single_draft_verifiers() {
        let ec = EngineConfig {
            verifier: VerifierKind::Daliri,
            num_drafts: 8,
            ..EngineConfig::default()
        };
        assert_eq!(ec.effective_drafts(), 1);
    }

    #[test]
    fn top_k_zero_means_disabled() {
        let (ec, _) = parse_config("top_k = 0").unwrap();
        assert_eq!(ec.target_params.top_k, None);
    }

    #[test]
    fn parse_verify_pool_keys() {
        let text = "parallel_threshold = 4096\nverify_workers = 3\nverify_backend = spawn";
        let (ec, _) = parse_config(text).unwrap();
        assert_eq!(ec.parallel_threshold, 4096);
        assert_eq!(ec.verify_workers, 3);
        assert_eq!(ec.verify_backend, VerifyBackend::Spawn);
        assert!(parse_config("verify_backend = rayon").is_err());
        // Defaults: calibrated threshold, auto-sized pool.
        let (ec, _) = parse_config("").unwrap();
        assert_eq!(ec.parallel_threshold, DEFAULT_PARALLEL_THRESHOLD);
        assert_eq!(ec.verify_workers, 0);
        assert_eq!(ec.verify_backend, VerifyBackend::Pool);
    }

    #[test]
    fn parse_retry_transient_faults_key() {
        let (ec, _) = parse_config("retry_transient_faults = true").unwrap();
        assert!(ec.retry_transient_faults);
        assert!(parse_config("retry_transient_faults = maybe").is_err());
        // Default: off (retry spares cost clones on the hot path).
        let (ec, _) = parse_config("").unwrap();
        assert!(!ec.retry_transient_faults);
    }

    #[test]
    fn verify_backend_roundtrip() {
        for b in [VerifyBackend::Serial, VerifyBackend::Spawn, VerifyBackend::Pool] {
            assert_eq!(VerifyBackend::parse(b.name()), Some(b));
        }
        assert_eq!(VerifyBackend::parse("nope"), None);
    }

    #[test]
    fn parse_admission_keys() {
        let (_, sc) = parse_config("admit_queue = 32\nshed_expired = true").unwrap();
        assert_eq!(sc.admit_queue, 32);
        assert!(sc.shed_expired);
        assert!(parse_config("admit_queue = lots").is_err());
        assert!(parse_config("shed_expired = sometimes").is_err());
        // Defaults: unbounded admission, no expiry shedding — submission
        // behavior is byte-identical to the pre-lifecycle server.
        let (_, sc) = parse_config("").unwrap();
        assert_eq!(sc.admit_queue, 0);
        assert!(!sc.shed_expired);
    }

    #[test]
    fn parse_pool_scope_key() {
        let (_, sc) = parse_config("pool_scope = engine").unwrap();
        assert_eq!(sc.pool_scope, PoolScope::Engine);
        let (_, sc) = parse_config("pool_scope = server").unwrap();
        assert_eq!(sc.pool_scope, PoolScope::Server);
        assert!(parse_config("pool_scope = global").is_err());
        // Default: the server-global shared pool.
        let (_, sc) = parse_config("").unwrap();
        assert_eq!(sc.pool_scope, PoolScope::Server);
        for p in [PoolScope::Engine, PoolScope::Server] {
            assert_eq!(PoolScope::parse(p.name()), Some(p));
        }
    }
}
