//! Request router over a pool of worker threads, each owning a private
//! engine (model pair + KV cache + scheduler). Mirrors the vLLM router
//! architecture: stateless routing in front, stateful workers behind.
//!
//! With `pool_scope = server` (the default) the router also owns the one
//! server-global [`VerifyPool`] every worker engine verifies through —
//! steady-state verify-thread count is the pool size, independent of the
//! worker count (see `coordinator::pool`, "Ticket protocol").

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::batcher::DynamicBatcher;
use super::config::{EngineConfig, PoolScope, ServerConfig, VerifyBackend};
use super::engine::SpecDecodeEngine;
use super::kv::PagedKvCache;
use super::metrics::EngineMetrics;
use super::pool::VerifyPool;
use super::scheduler::Scheduler;
use super::sequence::{CancelToken, Request, RequestResult};
use crate::model::backend::ModelPair;
use crate::spec::types::VerifierKind;

/// Why the router refused a submission. Admission control never drops a
/// request silently: every shed is a typed error the caller must handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The bounded admission window (`ServerConfig::admit_queue`) is at
    /// capacity: `depth` requests are already in flight against `bound`.
    QueueFull { depth: usize, bound: usize },
    /// `ServerConfig::shed_expired` is on and this request's deadline had
    /// already passed at submission time — decoding it would only produce
    /// a result nobody can use.
    DeadlineExpired,
    /// A drain has begun; intake is closed for good.
    Draining,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull { depth, bound } => {
                write!(f, "admission queue full ({depth} in flight >= bound {bound})")
            }
            AdmitError::DeadlineExpired => write!(f, "deadline already expired at submission"),
            AdmitError::Draining => write!(f, "router is draining; intake closed"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// What `Router::drain` does with requests still in flight.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DrainPolicy {
    /// Let everything already admitted run to completion, then stop.
    #[default]
    Finish,
    /// Cancel everything in flight — each open request retires with a
    /// typed `cancelled` result at its next block boundary (or straight
    /// from the queue, if it never started).
    CancelInFlight,
}

/// Registry length at which `register_cancel` prunes tokens whose request
/// has already retired (registry copy is the only live handle).
const CANCEL_REGISTRY_PRUNE: usize = 128;

/// Cost a request contributes to a worker's `LeastLoaded` load signal.
///
/// Charged at submission and credited back identically at completion
/// (the signal is strictly additive — see `worker_loop`), so charge and
/// credit MUST be computed from fields preserved on both `Request` and
/// `RequestResult`. The model: every budgeted token costs one weighted
/// unit — two for multi-draft verifiers (K draft lanes + a batched
/// target span per block) versus one for single-draft kinds — plus a
/// prompt-length term for the prefill and per-block span cost heavy
/// prompts keep paying. A declared-budget-only signal dogpiles workers
/// under heavy-tailed prompts: two 8-token requests look identical even
/// when one carries a 96-token prompt.
pub fn routing_cost(prompt_len: usize, max_new_tokens: usize, verifier: Option<VerifierKind>) -> usize {
    let lane_weight = match verifier {
        Some(k) if k.is_single_draft() => 1,
        _ => 2,
    };
    max_new_tokens * lane_weight + prompt_len / 4
}

/// How the router picks a worker for each request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through workers — optimal for homogeneous loads.
    RoundRobin,
    /// Pick the worker with the fewest outstanding tokens — adapts to
    /// heterogeneous request lengths.
    LeastLoaded,
}

struct WorkerHandle {
    tx: Sender<Request>,
    load: Arc<AtomicUsize>,
    join: JoinHandle<EngineMetrics>,
}

pub struct Router {
    workers: Vec<WorkerHandle>,
    policy: RoutingPolicy,
    next_rr: usize,
    pub results_rx: Receiver<RequestResult>,
    /// The server-global verify pool (`pool_scope = server` with the pool
    /// backend); `None` under per-engine pooling or non-pool backends.
    shared_pool: Option<Arc<VerifyPool>>,
    /// Requests admitted but not yet retired, across all workers.
    /// Incremented at admission; each worker decrements once per result
    /// it emits, so the count is exact (one result per admitted request).
    in_flight: Arc<AtomicUsize>,
    /// `ServerConfig::admit_queue` (0 = unbounded, the default).
    admit_bound: usize,
    /// `ServerConfig::shed_expired`.
    shed_expired_policy: bool,
    /// Set by `begin_drain`; closes intake.
    draining: bool,
    /// Router-side shed counters, folded into the merged `EngineMetrics`
    /// at shutdown/drain (workers never see shed requests).
    shed_full: u64,
    shed_expired: u64,
    /// High-water mark of `in_flight` observed at admission.
    queue_peak: u64,
    /// Cancel handles of admitted requests, so `drain(CancelInFlight)`
    /// can cut everything still open. Append-only between prunes;
    /// `register_cancel` drops entries whose request already retired.
    cancels: Vec<CancelToken>,
}

impl Router {
    /// Spawn `cfg.workers` workers; `make_pair(worker_idx)` builds each
    /// worker's model pair (backends are not clonable — PJRT executables
    /// hold device handles).
    pub fn start<F>(
        server_cfg: &ServerConfig,
        engine_cfg: &EngineConfig,
        policy: RoutingPolicy,
        make_pair: F,
    ) -> Self
    where
        F: Fn(usize) -> ModelPair,
    {
        server_cfg.validate().expect("server config");
        engine_cfg.validate().expect("engine config");
        // One server-global verify pool shared by all workers: spawned
        // eagerly (workers park until batches arrive), sized by
        // `verify_workers` — auto (0) uses the machine's full parallelism
        // *undivided*, since there is exactly one pool no matter how many
        // workers submit to it.
        let shared_pool = if engine_cfg.verify_backend == VerifyBackend::Pool
            && server_cfg.pool_scope == PoolScope::Server
        {
            let size = if engine_cfg.verify_workers > 0 {
                engine_cfg.verify_workers
            } else {
                std::thread::available_parallelism().map_or(1, |n| n.get())
            };
            Some(Arc::new(VerifyPool::new(size)))
        } else {
            None
        };
        let (results_tx, results_rx) = mpsc::channel();
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(server_cfg.workers);
        for w in 0..server_cfg.workers {
            let pair = make_pair(w);
            let (tx, rx) = mpsc::channel::<Request>();
            let load = Arc::new(AtomicUsize::new(0));
            let load_w = Arc::clone(&load);
            let inflight_w = Arc::clone(&in_flight);
            let results = results_tx.clone();
            let ec = engine_cfg.clone();
            let sc = server_cfg.clone();
            let pool = shared_pool.clone();
            let join = std::thread::Builder::new()
                .name(format!("gls-worker-{w}"))
                .spawn(move || worker_loop(w, rx, results, load_w, inflight_w, ec, sc, pool, pair))
                .expect("spawn worker");
            workers.push(WorkerHandle { tx, load, join });
        }
        Self {
            workers,
            policy,
            next_rr: 0,
            results_rx,
            shared_pool,
            in_flight,
            admit_bound: server_cfg.admit_queue,
            shed_expired_policy: server_cfg.shed_expired,
            draining: false,
            shed_full: 0,
            shed_expired: 0,
            queue_peak: 0,
            cancels: Vec::new(),
        }
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// The server-global verify pool, when one exists (observability:
    /// per-engine stats, thread-census tests, benches).
    pub fn verify_pool(&self) -> Option<&Arc<VerifyPool>> {
        self.shared_pool.as_ref()
    }

    /// Requests admitted but not yet retired (observability).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Route one request. Returns the worker index chosen.
    ///
    /// Panics if admission control refuses — with the default config
    /// (unbounded queue, no expiry shedding, not draining) admission is
    /// always open and this never fires. Backpressure-aware callers use
    /// [`Router::try_submit`] and handle the typed error.
    pub fn submit(&mut self, req: Request) -> usize {
        self.try_submit(req).expect("admission open")
    }

    /// Route one request through admission control. Returns the worker
    /// index chosen, or a typed [`AdmitError`] explaining the shed.
    pub fn try_submit(&mut self, req: Request) -> Result<usize, AdmitError> {
        if self.draining {
            return Err(AdmitError::Draining);
        }
        if self.admit_bound > 0 {
            let depth = self.in_flight.load(Ordering::Acquire);
            if depth >= self.admit_bound {
                self.shed_full += 1;
                return Err(AdmitError::QueueFull { depth, bound: self.admit_bound });
            }
        }
        if self.shed_expired_policy {
            if let Some(d) = req.deadline {
                if req.submitted_at.elapsed() >= d {
                    self.shed_expired += 1;
                    return Err(AdmitError::DeadlineExpired);
                }
            }
        }
        let idx = match self.policy {
            RoutingPolicy::RoundRobin => {
                let i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.workers.len();
                i
            }
            RoutingPolicy::LeastLoaded => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(_, w)| w.load.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .unwrap(),
        };
        let depth = self.in_flight.fetch_add(1, Ordering::AcqRel) + 1;
        self.queue_peak = self.queue_peak.max(depth as u64);
        self.register_cancel(req.cancel.clone());
        let cost = routing_cost(req.prompt.len(), req.max_new_tokens, req.verifier);
        self.workers[idx].load.fetch_add(cost, Ordering::Relaxed);
        self.workers[idx].tx.send(req).expect("worker alive");
        Ok(idx)
    }

    fn register_cancel(&mut self, token: CancelToken) {
        if self.cancels.len() >= CANCEL_REGISTRY_PRUNE {
            // A retired request's only remaining strong handle is the
            // registry copy (unless the caller kept one, which is on the
            // caller); drop those so the registry stays bounded by the
            // number of genuinely open requests.
            self.cancels.retain(|c| c.handle_count() > 1);
        }
        self.cancels.push(token);
    }

    /// Close intake without joining workers: subsequent `try_submit`
    /// returns [`AdmitError::Draining`], and under
    /// [`DrainPolicy::CancelInFlight`] every open request's cancel token
    /// is flipped so workers retire them typed at the next block boundary.
    /// Idempotent; [`Router::drain`] calls this first.
    pub fn begin_drain(&mut self, policy: DrainPolicy) {
        self.draining = true;
        if policy == DrainPolicy::CancelInFlight {
            for c in &self.cancels {
                c.cancel();
            }
        }
    }

    /// Graceful drain: close intake, apply `policy` to in-flight work,
    /// join every worker, and return the merged metrics plus any results
    /// the caller had not yet received. After this returns, no worker
    /// threads remain and every admitted request has exactly one terminal
    /// result (delivered earlier via `results_rx` or in the returned Vec).
    pub fn drain(mut self, policy: DrainPolicy) -> (EngineMetrics, Vec<RequestResult>) {
        self.begin_drain(policy);
        let Router { workers, results_rx, shed_full, shed_expired, queue_peak, .. } = self;
        let mut merged = EngineMetrics::new();
        for w in workers {
            drop(w.tx);
            merged.merge(&w.join.join().expect("worker panicked"));
        }
        merged.shed_full += shed_full;
        merged.shed_expired += shed_expired;
        merged.queue_peak = merged.queue_peak.max(queue_peak);
        let mut leftovers = Vec::new();
        while let Ok(r) = results_rx.try_recv() {
            leftovers.push(r);
        }
        (merged, leftovers)
    }

    /// Close intake and join all workers, returning merged metrics.
    pub fn shutdown(self) -> EngineMetrics {
        let Router { workers, shed_full, shed_expired, queue_peak, .. } = self;
        let mut merged = EngineMetrics::new();
        // Dropping senders closes intake; workers drain and exit.
        for w in workers {
            drop(w.tx);
            let m = w.join.join().expect("worker panicked");
            merged.merge(&m);
        }
        merged.shed_full += shed_full;
        merged.shed_expired += shed_expired;
        merged.queue_peak = merged.queue_peak.max(queue_peak);
        merged
    }
}

/// Credit completed work back to the router-visible load counter without
/// ever underflowing (saturating subtraction on the atomic).
fn credit_load(load: &AtomicUsize, amount: usize) {
    let _ = load.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
        Some(v.saturating_sub(amount))
    });
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker_idx: usize,
    rx: Receiver<Request>,
    results: Sender<RequestResult>,
    load: Arc<AtomicUsize>,
    in_flight: Arc<AtomicUsize>,
    engine_cfg: EngineConfig,
    server_cfg: ServerConfig,
    shared_pool: Option<Arc<VerifyPool>>,
    pair: ModelPair,
) -> EngineMetrics {
    // Per-worker seed offset keeps randomness lanes disjoint across workers
    // even when clients reuse request ids. Under *per-engine* pooling an
    // auto-sized pool (`verify_workers = 0`) is divided by the server's
    // worker count so W engines don't each spawn `available_parallelism`
    // verify threads and oversubscribe the cores; the server-global pool
    // was sized once by the router instead.
    let verify_workers = if engine_cfg.verify_workers == 0 && shared_pool.is_none() {
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        (cores / server_cfg.workers.max(1)).max(1)
    } else {
        engine_cfg.verify_workers
    };
    let cfg = EngineConfig {
        seed: engine_cfg.seed ^ (0x9E3779B97F4A7C15u64.wrapping_mul(worker_idx as u64 + 1)),
        verify_workers,
        ..engine_cfg
    };
    let kv = PagedKvCache::new(server_cfg.kv_pages, server_cfg.kv_page_size);
    let mut engine = SpecDecodeEngine::new(cfg, pair, kv);
    if let Some(pool) = shared_pool {
        engine.attach_shared_pool(pool, worker_idx as u64);
    }
    let mut sched = Scheduler::new(server_cfg.max_running);
    let batcher = DynamicBatcher::new(server_cfg.max_batch, server_cfg.batch_deadline);

    'outer: loop {
        // Blocking wait for the next batch when idle.
        match batcher.next_batch(&rx) {
            Some(batch) => batch.into_iter().for_each(|r| sched.submit(r)),
            None => break 'outer, // disconnected and empty
        }
        // Serve until drained, topping up opportunistically each tick.
        while sched.has_work() {
            for req in batcher.drain_ready(&rx) {
                sched.submit(req);
            }
            for res in sched.tick(&mut engine) {
                // The load signal is strictly additive: the router charged
                // `routing_cost(..)` at submission; completion recomputes
                // and credits the identical amount from the fields the
                // result preserves. (The old `load.store(sched.load())`
                // overwrote the counter each tick, erasing the charge for
                // requests still queued in this worker's channel — a burst
                // would dogpile whichever worker last stored a stale low
                // value.)
                credit_load(&load, routing_cost(res.prompt_len, res.max_new_tokens, res.verifier));
                // One decrement per result keeps the router's in-flight
                // depth exact: every admitted request emits exactly one
                // terminal result (finished, failed, or cancelled).
                let _ = in_flight.fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
                    Some(v.saturating_sub(1))
                });
                let _ = results.send(res);
            }
        }
    }
    engine.metrics.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sim::SimLm;
    use crate::spec::types::VerifierKind;
    use std::time::Duration;

    fn small_cfgs() -> (ServerConfig, EngineConfig) {
        let sc = ServerConfig {
            workers: 2,
            max_batch: 4,
            batch_deadline: Duration::from_millis(1),
            max_running: 8,
            kv_pages: 512,
            kv_page_size: 16,
            ..ServerConfig::default()
        };
        let ec = EngineConfig {
            verifier: VerifierKind::Gls,
            num_drafts: 2,
            block_len: 4,
            max_seq_len: 128,
            ..EngineConfig::default()
        };
        (sc, ec)
    }

    fn sim_pair(_w: usize) -> ModelPair {
        let (draft, target) = SimLm::pair(32, 5, 1.5);
        ModelPair::new(Box::new(draft), Box::new(target))
    }

    #[test]
    fn router_serves_all_requests_round_robin() {
        let (sc, ec) = small_cfgs();
        let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, sim_pair);
        let n = 20;
        for i in 0..n {
            router.submit(Request::new(i, vec![1, 2], 10));
        }
        let mut got = 0;
        while got < n {
            let res = router.results_rx.recv().unwrap();
            assert_eq!(res.tokens.len(), 12);
            got += 1;
        }
        let metrics = router.shutdown();
        assert_eq!(metrics.completed, n);
        assert!(metrics.block_efficiency() > 1.0);
    }

    #[test]
    fn round_robin_spreads_requests_evenly() {
        let (sc, ec) = small_cfgs();
        let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, sim_pair);
        let mut counts = vec![0usize; router.num_workers()];
        for i in 0..10 {
            counts[router.submit(Request::new(i, vec![1], 4))] += 1;
        }
        assert_eq!(counts, vec![5, 5]);
        for _ in 0..10 {
            router.results_rx.recv().unwrap();
        }
        router.shutdown();
    }

    #[test]
    fn least_loaded_prefers_idle_worker() {
        let (sc, ec) = small_cfgs();
        let mut router = Router::start(&sc, &ec, RoutingPolicy::LeastLoaded, sim_pair);
        // One huge request loads worker A; the following small ones should
        // avoid it initially.
        let first = router.submit(Request::new(0, vec![1], 100));
        let mut others = Vec::new();
        for i in 1..5 {
            others.push(router.submit(Request::new(i, vec![1], 4)));
        }
        assert!(others.iter().any(|&w| w != first));
        for _ in 0..5 {
            router.results_rx.recv().unwrap();
        }
        router.shutdown();
    }

    #[test]
    fn least_loaded_burst_spreads_before_any_completion() {
        // Regression for the stale-load bug: the old worker loop stored
        // `sched.load()` each tick, erasing the router's in-advance charge
        // for requests still queued in a worker's channel, so a burst
        // dogpiled whichever worker last looked idle. With the additive
        // signal, a burst of equal requests must spread evenly regardless
        // of worker timing: each submission charges the chosen worker
        // before the next one picks.
        let (sc, ec) = small_cfgs();
        let mut router = Router::start(&sc, &ec, RoutingPolicy::LeastLoaded, sim_pair);
        // Two long anchors occupy both workers symmetrically.
        router.submit(Request::new(0, vec![1], 60));
        router.submit(Request::new(1, vec![1], 60));
        // Burst: submitted back-to-back, far faster than 60-token decodes
        // complete; the additive signal alone must balance them.
        let mut counts = vec![0usize; router.num_workers()];
        let burst = 6;
        for i in 0..burst {
            counts[router.submit(Request::new(2 + i as u64, vec![1], 8))] += 1;
        }
        assert!(
            counts.iter().all(|&c| c >= burst / 2 - 1 && c <= burst / 2 + 1),
            "burst dogpiled: {counts:?}"
        );
        for _ in 0..(2 + burst) {
            router.results_rx.recv().unwrap();
        }
        router.shutdown();
    }

    #[test]
    fn least_loaded_spreads_heavy_tailed_prompt_mass() {
        // Cost-weighted routing: two heavy-prompt requests with the same
        // declared budget as tiny ones must land on different workers.
        // Under the old budget-only charge all four tie, min_by_key
        // breaks ties toward worker 0, and both heavy prompts dogpile it.
        let (sc, ec) = small_cfgs();
        let mut router = Router::start(&sc, &ec, RoutingPolicy::LeastLoaded, sim_pair);
        let huge = |id: u64| Request::new(id, vec![1u32; 96], 16);
        let tiny = |id: u64| Request::new(id, vec![1, 2], 16);
        let w_huge1 = router.submit(huge(0));
        let _ = router.submit(tiny(1));
        let w_huge2 = router.submit(huge(2));
        let _ = router.submit(tiny(3));
        assert_ne!(
            w_huge1, w_huge2,
            "heavy-tailed prompt mass dogpiled one worker"
        );
        for _ in 0..4 {
            router.results_rx.recv().unwrap();
        }
        router.shutdown();
    }

    #[test]
    fn routing_cost_weighs_prompts_and_verifier_kind() {
        // Multi-draft (or default) kinds charge double per budgeted token.
        assert_eq!(routing_cost(0, 10, None), 20);
        assert_eq!(routing_cost(0, 10, Some(VerifierKind::SpecInfer)), 20);
        assert_eq!(routing_cost(0, 10, Some(VerifierKind::Daliri)), 10);
        assert_eq!(routing_cost(0, 10, Some(VerifierKind::SingleDraft)), 10);
        // Prompt mass contributes: a 96-token prompt outweighs a tiny one.
        assert!(routing_cost(96, 16, None) > routing_cost(2, 16, None));
        // Charge == credit: the result-side fields reconstruct the charge.
        let req = Request::new(1, vec![7; 33], 12).with_verifier(Some(VerifierKind::Gls));
        let charged = routing_cost(req.prompt.len(), req.max_new_tokens, req.verifier);
        let res = crate::coordinator::sequence::SequenceState::from_request(&req).into_result();
        assert_eq!(
            charged,
            routing_cost(res.prompt_len, res.max_new_tokens, res.verifier)
        );
    }

    #[test]
    fn server_scope_creates_one_shared_pool_and_attributes_engines() {
        use crate::coordinator::config::{PoolScope, VerifyBackend};
        let (sc, ec) = small_cfgs();
        let sc = ServerConfig { pool_scope: PoolScope::Server, ..sc };
        let ec = EngineConfig {
            parallel_threshold: 0,
            verify_workers: 2,
            verify_backend: VerifyBackend::Pool,
            ..ec
        };
        let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, sim_pair);
        let pool = Arc::clone(router.verify_pool().expect("server-global pool exists"));
        assert_eq!(pool.workers(), 2);
        let n = 12;
        for i in 0..n {
            router.submit(Request::new(i, vec![1, 2], 10));
        }
        for _ in 0..n {
            router.results_rx.recv().unwrap();
        }
        router.shutdown();
        // Both workers verified through the one pool, tagged separately.
        let s0 = pool.engine_stats(0);
        let s1 = pool.engine_stats(1);
        assert!(s0.jobs > 0, "worker 0 never submitted to the shared pool");
        assert!(s1.jobs > 0, "worker 1 never submitted to the shared pool");
        assert_eq!(s0.faults + s1.faults, 0);
        // Per-engine pooling must NOT create a router-owned pool.
        let sc_engine = ServerConfig { pool_scope: PoolScope::Engine, ..sc };
        let router2 = Router::start(&sc_engine, &ec, RoutingPolicy::RoundRobin, sim_pair);
        assert!(router2.verify_pool().is_none());
        router2.shutdown();
    }

    #[test]
    fn bounded_admission_sheds_typed_and_counts() {
        let (sc, ec) = small_cfgs();
        let sc = ServerConfig { admit_queue: 1, ..sc };
        let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, sim_pair);
        let mut admitted = 0u64;
        let mut shed = 0u64;
        for i in 0..4 {
            match router.try_submit(Request::new(i, vec![1], 64)) {
                Ok(_) => admitted += 1,
                Err(AdmitError::QueueFull { depth, bound }) => {
                    assert_eq!(bound, 1);
                    assert!(depth >= 1);
                    shed += 1;
                }
                Err(e) => panic!("unexpected admit error: {e}"),
            }
        }
        assert!(admitted >= 1, "first submission always admits");
        assert!(shed >= 1, "burst against bound 1 must shed");
        assert_eq!(admitted + shed, 4, "every submission gets a typed outcome");
        for _ in 0..admitted {
            router.results_rx.recv().unwrap();
        }
        let m = router.shutdown();
        assert_eq!(m.shed_full, shed);
        assert_eq!(m.completed, admitted);
        assert!(m.queue_peak >= 1 && m.queue_peak <= 1, "peak bounded by admit_queue");
    }

    #[test]
    fn expired_deadline_sheds_at_admission_when_enabled() {
        let (sc, ec) = small_cfgs();
        let sc = ServerConfig { shed_expired: true, ..sc };
        let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, sim_pair);
        let err = router
            .try_submit(Request::new(1, vec![1], 4).with_deadline(Duration::ZERO))
            .unwrap_err();
        assert_eq!(err, AdmitError::DeadlineExpired);
        // Live-deadline and no-deadline requests still admit.
        router.try_submit(Request::new(2, vec![1], 4)).unwrap();
        router
            .try_submit(Request::new(3, vec![1], 4).with_deadline(Duration::from_secs(60)))
            .unwrap();
        for _ in 0..2 {
            router.results_rx.recv().unwrap();
        }
        let m = router.shutdown();
        assert_eq!(m.shed_expired, 1);
        assert_eq!(m.completed, 2);
    }

    #[test]
    fn drain_cancels_in_flight_and_closes_intake() {
        let (sc, ec) = small_cfgs();
        let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, sim_pair);
        let n = 8u64;
        for i in 0..n {
            router.submit(Request::new(i, vec![1], 100));
        }
        router.begin_drain(DrainPolicy::CancelInFlight);
        assert_eq!(
            router.try_submit(Request::new(99, vec![1], 4)).unwrap_err(),
            AdmitError::Draining
        );
        let (metrics, results) = router.drain(DrainPolicy::CancelInFlight);
        // Every admitted request has exactly one terminal result, none
        // were received before the drain, so all land in the leftovers.
        assert_eq!(results.len() as u64, n);
        let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len() as u64, n, "no lost or duplicated sequences");
        let cancelled = results.iter().filter(|r| r.cancelled.is_some()).count() as u64;
        for r in &results {
            assert!(!r.failed, "drain is not a failure");
            if r.cancelled.is_none() {
                assert_eq!(r.tokens.len(), 101, "uncancelled requests ran to completion");
            }
        }
        assert_eq!(metrics.completed, n);
        assert_eq!(metrics.cancelled + metrics.timed_out, cancelled);
        // drain() joins every worker handle before returning, so reaching
        // this line means no worker thread survived; the full OS-level
        // thread census gate lives in `tests/serving_load.rs`.
    }

    #[test]
    fn drain_finish_policy_completes_everything() {
        let (sc, ec) = small_cfgs();
        let mut router = Router::start(&sc, &ec, RoutingPolicy::LeastLoaded, sim_pair);
        for i in 0..6 {
            router.submit(Request::new(i, vec![1, 2], 10));
        }
        let (metrics, results) = router.drain(DrainPolicy::Finish);
        assert_eq!(results.len(), 6);
        for r in &results {
            assert!(r.ok());
            assert_eq!(r.tokens.len(), 12);
        }
        assert_eq!(metrics.completed, 6);
        assert_eq!(metrics.cancelled + metrics.timed_out, 0);
    }

    #[test]
    fn shutdown_merges_metrics_across_workers() {
        let (sc, ec) = small_cfgs();
        let mut router = Router::start(&sc, &ec, RoutingPolicy::RoundRobin, sim_pair);
        for i in 0..6 {
            router.submit(Request::new(i, vec![1], 6));
        }
        for _ in 0..6 {
            router.results_rx.recv().unwrap();
        }
        let metrics = router.shutdown();
        assert_eq!(metrics.completed, 6);
        assert!(metrics.blocks >= 6);
    }
}
