//! The speculative-decoding engine: draft K lanes × L steps, verify with
//! one batched target pass, accept/rollback via the configured coupling
//! scheme (paper Alg. 2 for GLS, or the baselines).
//!
//! One engine owns one draft/target model pair and serves a *batch* of
//! sequences per iteration: all lanes of all sequences are flattened into a
//! single backend call per draft step and a single target verification
//! call — the L2 fusion that makes the CPU path tractable and the TPU path
//! MXU-friendly.

use std::sync::Arc;
use std::time::Instant;

use crate::model::backend::ModelPair;
use crate::spec::kernel::{CouplingWorkspace, PanelCacheStats, PanelSlice, SliceBank, SliceRecycler};
use crate::spec::types::{Categorical, TokenMatrix};
use crate::spec::VerifierKind;
use crate::stats::rng::CounterRng;

use super::config::{EngineConfig, VerifyBackend};
use super::kv::PagedKvCache;
use super::metrics::EngineMetrics;
use super::pool::{JobCut, PoolError, VerifyJob, VerifyPool};
use super::sequence::{CancelCause, SeqPhase, SequenceState};

/// Outcome of one speculative block for one sequence.
#[derive(Clone, Debug)]
pub struct BlockOutcome {
    pub emitted: Vec<u32>,
    pub accepted: usize,
    /// The sequence's verify job panicked: nothing was emitted, the KV
    /// reservation was rolled back, and the sequence is now
    /// `SeqPhase::Failed` (the scheduler retires it with an error result).
    pub failed: bool,
}

pub struct SpecDecodeEngine {
    pub cfg: EngineConfig,
    pair: ModelPair,
    root_rng: CounterRng,
    pub kv: PagedKvCache,
    pub metrics: EngineMetrics,
    /// Engine-thread workspace: serial verification runs here, persisting
    /// scratch and panel cache across blocks exactly like a pool worker.
    ws: CouplingWorkspace,
    /// Persistent verification pool. Either the server-global shared pool
    /// injected via [`SpecDecodeEngine::attach_shared_pool`]
    /// (`pool_scope = server` — the router owns it, every worker engine
    /// holds the same `Arc`), or a per-engine pool spawned lazily on the
    /// first batch that clears the parallelism threshold (sized once from
    /// `cfg.verify_workers`; serial-only engines never spawn threads).
    pool: Option<Arc<VerifyPool>>,
    /// Tag identifying this engine on a shared pool (per-engine metric
    /// attribution; the router passes the worker index).
    engine_tag: u64,
    /// Verify-pool size resolved once at construction — the configured
    /// `cfg.verify_workers`, or (at `0` = auto) `available_parallelism` —
    /// so the per-block dispatch never repeats the syscall. Mutating
    /// `cfg.verify_workers` after construction has no effect;
    /// `attach_shared_pool` overrides it with the shared pool's size.
    resolved_workers: usize,
    /// Lease/return endpoint of the panel-slice recycling channel: every
    /// verify job ships its spent slice back here, so steady-state draft
    /// recording is allocation-free (spec::kernel handoff protocol step 5).
    recycler: SliceRecycler,
    /// Pool-level spare-slice bank (set by [`attach_shared_pool`]): leases
    /// fall back here when the local recycler runs dry, and surplus local
    /// returns are deposited for other engines — recycling capacity
    /// follows load across engines instead of stranding per-engine.
    ///
    /// [`attach_shared_pool`]: SpecDecodeEngine::attach_shared_pool
    bank: Option<Arc<SliceBank>>,
}

impl SpecDecodeEngine {
    pub fn new(cfg: EngineConfig, pair: ModelPair, kv: PagedKvCache) -> Self {
        cfg.validate().expect("invalid engine config");
        let root_rng = CounterRng::new(cfg.seed);
        let resolved_workers = if cfg.verify_workers > 0 {
            cfg.verify_workers
        } else {
            std::thread::available_parallelism().map_or(1, |n| n.get())
        };
        Self {
            cfg,
            pair,
            root_rng,
            kv,
            metrics: EngineMetrics::new(),
            ws: CouplingWorkspace::new(),
            pool: None,
            engine_tag: 0,
            resolved_workers,
            recycler: SliceRecycler::new(),
            bank: None,
        }
    }

    /// Use a server-global shared verify pool instead of a lazily spawned
    /// per-engine one. `tag` identifies this engine's submissions for the
    /// pool's per-engine stats (the router passes the worker index). Also
    /// joins the pool's shared [`SliceBank`] so panel-slice recycling
    /// capacity moves across the pool's engines.
    pub fn attach_shared_pool(&mut self, pool: Arc<VerifyPool>, tag: u64) {
        self.resolved_workers = pool.workers();
        self.bank = Some(pool.slice_bank());
        self.pool = Some(pool);
        self.engine_tag = tag;
    }

    pub fn verifier_kind(&self) -> VerifierKind {
        self.cfg.verifier
    }

    pub fn vocab(&self) -> usize {
        self.pair.vocab()
    }

    /// Shared-randomness stream for a request lane.
    pub fn rng_for(&self, lane: u64) -> CounterRng {
        self.root_rng.split(lane)
    }

    /// Run one speculative block for every sequence in `seqs`, batched
    /// across sequences and draft lanes. Sequences must be `Running` and
    /// have KV reservations available; the engine reserves/commits pages
    /// itself. Returns one outcome per sequence.
    pub fn step_blocks(&mut self, seqs: &mut [&mut SequenceState]) -> Vec<BlockOutcome> {
        if seqs.is_empty() {
            return Vec::new();
        }
        let k = self.cfg.effective_drafts();
        let l = self.cfg.block_len;

        // --- KV reservation for the speculative block (L + 1 positions). ---
        for seq in seqs.iter() {
            self.kv
                .reserve_block(seq.id, l + 1)
                .expect("scheduler must not dispatch without KV headroom");
        }

        // --- Draft phase: K lanes × L autoregressive steps, batched. ------
        let t0 = Instant::now();
        // rows[s * k + lane] = context ++ drafted-so-far for that lane.
        let mut rows: Vec<Vec<u32>> = Vec::with_capacity(seqs.len() * k);
        for seq in seqs.iter() {
            for _ in 0..k {
                let mut row = Vec::with_capacity(seq.tokens.len() + l);
                row.extend_from_slice(&seq.tokens);
                rows.push(row);
            }
        }
        // Per-sequence randomness lanes, split once (not once per step).
        let seq_rngs: Vec<CounterRng> =
            seqs.iter().map(|s| self.root_rng.split(s.rng_lane)).collect();
        // Dispatch decision, made up front (it also gates draft-phase
        // recording): fan verification out only when the batch and the
        // per-sequence math clear the calibrated threshold
        // (`EngineConfig::parallel_threshold` — see
        // DEFAULT_PARALLEL_THRESHOLD for the procedure). All backends are
        // bit-identical, so this is a pure perf decision. A one-worker
        // pool only ever loses to the serial path, so it runs solely when
        // fan-out is forced (`parallel_threshold = 0` — how the parity
        // grid pins the pool-of-one case).
        let per_seq_work = k * (l + 1) * self.pair.vocab();
        let workers = self.resolved_workers;
        let parallel = seqs.len() >= 2
            && per_seq_work >= self.cfg.parallel_threshold
            && self.cfg.verify_backend != VerifyBackend::Serial
            && (workers > 1 || self.cfg.parallel_threshold == 0);
        // Record draft-phase exponentials into per-sequence panel slices
        // when the verification phase will race panels at the same (slot,
        // lane) coordinates — the GLS family and Daliri; the rejection
        // baselines consume uniforms at disjoint coordinates. The slice is
        // handed to whichever workspace verifies that sequence (the engine
        // thread's or a pool worker's), so draft-exponential reuse works on
        // serial AND parallel paths. Exception: a parallel Spawn block
        // discards slices by design (the faithful pre-pool baseline), so
        // don't pay for recording them. `record_race` and `sample_race`
        // are bit-exact, so none of this ever changes a token.
        // Per-sequence verifier kinds: a request override (mixed-verifier
        // traces) or the engine default. Drafting stays batch-wide at the
        // engine's effective K — kinds that consume fewer lanes ignore the
        // extras, bit-exactly matching a dedicated engine at the same K.
        let spawn_discard = parallel && self.cfg.verify_backend == VerifyBackend::Spawn;
        let seq_kinds: Vec<VerifierKind> =
            seqs.iter().map(|s| s.verifier.unwrap_or(self.cfg.verifier)).collect();
        let records: Vec<bool> = seq_kinds
            .iter()
            .map(|kd| {
                matches!(
                    kd,
                    VerifierKind::Gls | VerifierKind::GlsStrong | VerifierKind::Daliri
                ) && !spawn_discard
            })
            .collect();
        let any_record = records.iter().any(|&r| r);
        let mut panels: Vec<PanelSlice> = Vec::with_capacity(records.len());
        for &r in &records {
            if r {
                // Leased from the recycler: spent slices return from
                // whichever workspace consumed them, so steady-state
                // recording reuses their buffers instead of allocating.
                // When the local channel is dry, fall back to the pool's
                // shared bank (capacity donated by sibling engines)
                // before allocating fresh.
                let slice = self
                    .recycler
                    .try_lease()
                    .or_else(|| self.bank.as_ref().and_then(|b| b.lease(self.engine_tag)))
                    .unwrap_or_default();
                panels.push(slice);
            } else {
                panels.push(PanelSlice::default());
            }
        }
        self.metrics.panel_slices_recycled += self.recycler.drain_recycled();
        // Local returns beyond what this block leased would strand in the
        // channel (this engine's batches shrank); bank them for siblings.
        if let Some(bank) = &self.bank {
            for s in self.recycler.drain_surplus() {
                bank.deposit(self.engine_tag, s);
            }
        }
        // draft_dists[s][lane][j]
        let mut draft_dists: Vec<Vec<Vec<Categorical>>> =
            vec![vec![Vec::with_capacity(l); k]; seqs.len()];
        // Flat token arena: token of (seq s, lane, pos j) lives at
        // `(s·K + lane)·L + j`. One allocation for the whole batch; verify
        // jobs and emission read it through `TokenMatrix` views instead of
        // the former per-(seq, lane) `Vec<u32>` rows.
        let mut arena: Vec<u32> = vec![0u32; seqs.len() * k * l];
        let mut topk_scratch: Vec<u32> = Vec::new();
        for j in 0..l {
            let logits = self.pair.draft.next_logits(&rows);
            for (s, seq) in seqs.iter().enumerate() {
                for lane in 0..k {
                    let idx = s * k + lane;
                    let sp = self.cfg.draft_params_for(lane);
                    let p = Categorical::from_logits_with_scratch(
                        &logits[idx],
                        sp.temperature,
                        sp.top_k,
                        &mut topk_scratch,
                    );
                    // Coupled drafting: the same (slot, lane) coordinates
                    // the verifier will use — Alg. 2 line 4.
                    let slot = seq.next_slot + j as u64;
                    let tok = if records[s] {
                        panels[s].record_race(&p, &seq_rngs[s], slot, lane as u64) as u32
                    } else {
                        p.sample_race(&seq_rngs[s], slot, lane as u64) as u32
                    };
                    rows[idx].push(tok);
                    arena[idx * l + j] = tok;
                    draft_dists[s][lane].push(p);
                }
            }
        }
        self.metrics.draft_time += t0.elapsed();
        self.metrics.draft_steps += (l * seqs.len()) as u64;

        // --- Target phase: ONE span pass over every lane of every seq. ----
        let t1 = Instant::now();
        // All lanes of a sequence share its start; per-row starts let the
        // whole continuous batch go through a single backend call even when
        // sequence lengths differ (span_logits_multi), instead of one call
        // per distinct start.
        let row_starts: Vec<usize> = seqs
            .iter()
            .flat_map(|s| std::iter::repeat(s.tokens.len() + 1).take(k))
            .collect();
        let span = self.pair.target.span_logits_multi(&rows, &row_starts);
        // Regroup flat rows back into [s][lane][pos][vocab].
        let mut span_iter = span.into_iter();
        let target_logits: Vec<Vec<Vec<Vec<f32>>>> = (0..seqs.len())
            .map(|_| (0..k).map(|_| span_iter.next().expect("row per lane")).collect())
            .collect();
        self.metrics.target_time += t1.elapsed();

        // --- Verification phase (the coupling algorithms). ----------------
        // Per-sequence verification is a pure function of (draft data,
        // target logits, randomness lane), so it parallelizes across the
        // batch with no effect on outputs. Every registered verifier kind
        // runs `verify_block_kind` on a coupling workspace — the engine
        // thread's for the serial path, a persistent pool worker's (or a
        // scoped-spawn thread's) otherwise — with the sequence's draft-phase
        // panel slice handed to whichever workspace claims the job.
        let t2 = Instant::now();
        let tp = self.cfg.target_params;
        let arena = Arc::new(arena);
        let recycle_tx = if any_record { Some(self.recycler.return_sender()) } else { None };
        let mut panels = panels.into_iter();
        let jobs: Vec<VerifyJob> = draft_dists
            .into_iter()
            .zip(target_logits)
            .enumerate()
            .map(|(s, (dd, tl))| VerifyJob {
                kind: seq_kinds[s],
                draft_tokens: TokenMatrix::view(Arc::clone(&arena), s * k * l, k, l),
                draft_dists: dd,
                target_logits: tl,
                target_params: tp,
                rng: seq_rngs[s],
                slot0: seqs[s].next_slot,
                panel: panels.next().unwrap_or_default(),
                recycle: if records[s] { recycle_tx.clone() } else { None },
                // Claim-time lifecycle checkpoint: a worker claiming a
                // job whose sequence is already cut skips verification
                // and returns an empty output; the epilogue below
                // re-checks the same monotone signals, so that empty
                // output is never committed as real tokens.
                cut: Some(JobCut {
                    cancel: seqs[s].cancel.clone(),
                    deadline_at: seqs[s].deadline_at,
                }),
            })
            .collect();

        // Every path yields one `Option<BlockOutput>` per sequence: `None`
        // marks a job whose verifier panicked (contained — the sequence
        // fails, the engine and pool survive).
        let (outs, cache_stats): (Vec<Option<_>>, PanelCacheStats) = if !parallel {
            let mut outs = Vec::with_capacity(seqs.len());
            let mut stats = PanelCacheStats::default();
            for job in jobs {
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    job.run(&mut self.ws)
                }));
                match res {
                    Ok(out) => outs.push(Some(out)),
                    Err(_) => {
                        // Scratch state after an unwind is unspecified;
                        // caches are value-keyed, so a fresh workspace
                        // only costs warm-up.
                        stats.merge(self.ws.drain_cache_stats());
                        self.ws = CouplingWorkspace::new();
                        outs.push(None);
                    }
                }
            }
            stats.merge(self.ws.drain_cache_stats());
            (outs, stats)
        } else {
            match self.cfg.verify_backend {
                VerifyBackend::Pool => {
                    let tag = self.engine_tag;
                    let retry = self.cfg.retry_transient_faults;
                    // Retry spares are cloned *before* submission (the
                    // originals are consumed by the pool); panel-free
                    // clones are bit-exact, just cold. Cost is why the
                    // policy is opt-in.
                    let spares: Vec<VerifyJob> = if retry {
                        jobs.iter().map(VerifyJob::clone_for_retry).collect()
                    } else {
                        Vec::new()
                    };
                    let pool = self
                        .pool
                        .get_or_insert_with(|| Arc::new(VerifyPool::new(workers)));
                    match pool.run_batch(tag, jobs) {
                        Ok(batch) => {
                            (batch.outputs.into_iter().map(Some).collect(), batch.cache)
                        }
                        Err(PoolError::JobsPanicked { failed, mut completed, mut cache }) => {
                            if retry && !failed.is_empty() {
                                // Retry-once: resubmit exactly the failed
                                // jobs. Transient faults (a worker dying
                                // mid-ticket) succeed on the spare;
                                // deterministic verifier panics fail again
                                // and the sequence retires Failed as
                                // before.
                                let mut spares: Vec<Option<VerifyJob>> =
                                    spares.into_iter().map(Some).collect();
                                let retry_jobs: Vec<VerifyJob> = failed
                                    .iter()
                                    .map(|&i| spares[i].take().expect("spare per job"))
                                    .collect();
                                self.metrics.verify_retries += retry_jobs.len() as u64;
                                match pool.run_batch(tag, retry_jobs) {
                                    Ok(batch) => {
                                        cache.merge(batch.cache);
                                        for (&i, out) in failed.iter().zip(batch.outputs) {
                                            self.metrics.verify_retries_recovered += 1;
                                            completed[i] = Some(out);
                                        }
                                    }
                                    Err(PoolError::JobsPanicked {
                                        completed: retried,
                                        cache: c2,
                                        ..
                                    }) => {
                                        cache.merge(c2);
                                        for (&i, out) in failed.iter().zip(retried) {
                                            if out.is_some() {
                                                self.metrics.verify_retries_recovered += 1;
                                            }
                                            completed[i] = out;
                                        }
                                    }
                                }
                            }
                            (completed, cache)
                        }
                    }
                }
                VerifyBackend::Spawn => {
                    let (outs, stats) = VerifyPool::run_scoped(jobs, workers);
                    (outs.into_iter().map(Some).collect(), stats)
                }
                VerifyBackend::Serial => unreachable!("parallel implies non-serial backend"),
            }
        };
        self.metrics.panel_cache_hits += cache_stats.hits;
        self.metrics.panel_cache_misses += cache_stats.misses;
        self.metrics.panel_cache_overwrites += cache_stats.overwrites;

        // --- Serial epilogue: sequence state, KV commits, metrics. --------
        let mut outcomes = Vec::with_capacity(seqs.len());
        for (seq, out) in seqs.iter_mut().zip(outs) {
            // Lifecycle cut at the block boundary (checked BEFORE the
            // output is committed): roll the block's reservation back and
            // retire the sequence `Cancelled` — the same template the
            // `Failed` path below uses. Monotonicity of the cut signals
            // guarantees this check fires whenever the claim-time check
            // in `VerifyJob::run` did, so a worker's empty cut output is
            // never mistaken for real tokens. Checking cut before the
            // fault branch means a cancel wins over a concurrent panic
            // (the client no longer wants the result either way).
            if let Some(cause) = seq.cut_now() {
                self.kv.commit(seq.id, 0).expect("rollback within reservation");
                seq.phase = SeqPhase::Cancelled;
                seq.cancelled = Some(cause);
                match cause {
                    CancelCause::Explicit => self.metrics.cancelled += 1,
                    CancelCause::DeadlineExpired => self.metrics.timed_out += 1,
                }
                outcomes.push(BlockOutcome { emitted: Vec::new(), accepted: 0, failed: false });
                continue;
            }
            let Some(mut out) = out else {
                // Verification fault: emit nothing, roll the block's KV
                // reservation back, and mark the sequence failed so the
                // scheduler retires it instead of spinning on it forever.
                self.kv.commit(seq.id, 0).expect("rollback within reservation");
                seq.phase = SeqPhase::Failed;
                self.metrics.verify_faults += 1;
                outcomes.push(BlockOutcome { emitted: Vec::new(), accepted: 0, failed: true });
                continue;
            };
            // Never emit beyond the request budget: truncate the verifier
            // output in place and move it straight into the sequence and
            // the outcome — no intermediate collect.
            let budget = seq.remaining();
            if out.tokens.len() > budget {
                out.tokens.truncate(budget);
            }
            let accepted = out.accepted.min(out.tokens.len());

            if seq.generated() == 0 && !out.tokens.is_empty() {
                // First generated token for this sequence: stamp TTFT.
                seq.first_token_at = Some(seq.submitted_at.elapsed());
            }
            seq.tokens.extend_from_slice(&out.tokens);
            seq.next_slot += (l + 1) as u64;
            seq.target_calls += 1;
            seq.draft_steps += l;
            self.kv.commit(seq.id, out.tokens.len()).expect("commit within reservation");

            self.metrics.blocks += 1;
            self.metrics.emitted_tokens += out.tokens.len() as u64;
            self.metrics.accepted_tokens += accepted as u64;

            outcomes.push(BlockOutcome { emitted: out.tokens, accepted, failed: false });
        }
        self.metrics.verify_time += t2.elapsed();
        outcomes
    }

    /// Decode a whole request synchronously (used by tests, examples and
    /// the algorithm benches; the server drives `step_blocks` directly for
    /// continuous batching).
    pub fn decode_sequence(&mut self, seq: &mut SequenceState) {
        self.kv
            .register(seq.id, seq.tokens.len(), seq.tokens.len() + seq.remaining(), self.cfg.block_len + 1)
            .expect("kv admit");
        seq.phase = SeqPhase::Running;
        while seq.phase == SeqPhase::Running && !seq.is_done(self.cfg.max_seq_len) {
            let mut batch = [&mut *seq];
            self.step_blocks(&mut batch);
        }
        if seq.phase == SeqPhase::Running {
            seq.phase = SeqPhase::Finished;
        }
        self.kv.release(seq.id).expect("kv release");
        self.metrics.completed += 1;
        self.metrics.be.push(seq.block_efficiency());
        self.metrics.latency.record(seq.submitted_at.elapsed().as_secs_f64());
        if let Some(t) = seq.first_token_at {
            self.metrics.ttft.record(t.as_secs_f64());
        }
        let gen = seq.generated();
        if gen > 0 {
            self.metrics
                .token_latency
                .record(seq.submitted_at.elapsed().as_secs_f64() / gen as f64);
        }
    }

    /// Direct autoregressive decoding from the target model (no drafts) —
    /// the correctness oracle: with the same randomness lane, GLS's output
    /// distribution must match this one (paper Prop. 3).
    pub fn autoregressive_target(&mut self, prompt: &[u32], n: usize, lane: u64) -> Vec<u32> {
        let rng = self.root_rng.split(lane);
        let mut toks = prompt.to_vec();
        let tp = self.cfg.target_params;
        for step in 0..n {
            // One-row batch without cloning the growing context each step.
            let logits = self.pair.target.next_logits(std::slice::from_ref(&toks));
            let q = Categorical::from_logits(&logits[0], tp.temperature, tp.top_k);
            // Lane-0 race at the right slot: matches Alg. 2's Y selection
            // when all drafts stay active (K = 1).
            let tok = q.sample_race(&rng, step as u64, 0) as u32;
            toks.push(tok);
        }
        toks.split_off(prompt.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sequence::Request;
    use crate::model::sim::SimLm;
    use crate::model::sampling::SamplingParams;

    fn engine(verifier: VerifierKind, k: usize, divergence: f32, seed: u64) -> SpecDecodeEngine {
        let (draft, target) = SimLm::pair(32, seed, divergence);
        let cfg = EngineConfig {
            num_drafts: k,
            block_len: 4,
            verifier,
            target_params: SamplingParams::new(1.0, None),
            draft_params: vec![SamplingParams::new(1.0, None)],
            max_seq_len: 256,
            seed,
            ..EngineConfig::default()
        };
        let kv = PagedKvCache::new(1024, 16);
        SpecDecodeEngine::new(cfg, ModelPair::new(Box::new(draft), Box::new(target)), kv)
    }

    #[test]
    fn decode_produces_requested_tokens_every_verifier() {
        for &vk in VerifierKind::all() {
            let mut eng = engine(vk, 3, 1.0, 7);
            let req = Request::new(1, vec![1, 2, 3], 20);
            let mut seq = SequenceState::from_request(&req);
            eng.decode_sequence(&mut seq);
            assert_eq!(seq.generated(), 20, "verifier {vk:?}");
            assert!(seq.target_calls > 0);
            assert_eq!(eng.kv.used_pages(), 0, "kv leak with {vk:?}");
        }
    }

    #[test]
    fn perfect_draft_alignment_accepts_everything() {
        // divergence = 0 → draft == target; GLS must accept every position
        // (coupled races agree), so BE = L + 1 exactly.
        let mut eng = engine(VerifierKind::Gls, 2, 0.0, 3);
        let req = Request::new(1, vec![5, 6], 30);
        let mut seq = SequenceState::from_request(&req);
        eng.decode_sequence(&mut seq);
        let be = seq.block_efficiency();
        assert!((be - 5.0).abs() < 1e-9, "BE {be} != L+1");
    }

    #[test]
    fn more_drafts_do_not_hurt_block_efficiency() {
        let run = |k: usize| {
            let mut total = 0.0;
            for s in 0..8u64 {
                let mut eng = engine(VerifierKind::Gls, k, 2.5, 40 + s);
                let req = Request::new(1, vec![1], 40);
                let mut seq = SequenceState::from_request(&req);
                eng.decode_sequence(&mut seq);
                total += seq.block_efficiency();
            }
            total / 8.0
        };
        let be1 = run(1);
        let be8 = run(8);
        assert!(be8 >= be1 - 0.05, "K=8 BE {be8} < K=1 BE {be1}");
    }

    #[test]
    fn gls_output_distribution_matches_autoregressive_target() {
        // Prop. 3 sequence-level correctness: the engine's first-token
        // marginal equals the target model's next-token distribution.
        let trials = 8000u64;
        let vocab = 16;
        let mut counts_spec = vec![0usize; vocab];
        let (draft, target) = SimLm::pair(vocab, 11, 2.0);
        let q_expect =
            Categorical::from_logits(&target.logits_at(&[2, 7]), 1.0, None);
        let cfg = EngineConfig {
            num_drafts: 3,
            block_len: 3,
            verifier: VerifierKind::Gls,
            target_params: SamplingParams::new(1.0, None),
            draft_params: vec![SamplingParams::new(1.0, None)],
            max_seq_len: 64,
            seed: 123,
            ..EngineConfig::default()
        };
        let mut eng = SpecDecodeEngine::new(
            cfg,
            ModelPair::new(Box::new(draft), Box::new(target)),
            PagedKvCache::new(4096, 16),
        );
        for lane in 0..trials {
            let req = Request::new(lane, vec![2, 7], 1);
            let mut seq = SequenceState::from_request(&req);
            eng.decode_sequence(&mut seq);
            counts_spec[seq.tokens[2] as usize] += 1;
        }
        // Chi-square against the exact target distribution; dof = 15,
        // 99.9th percentile ≈ 37.7 — allow slack.
        let mut chi2 = 0.0;
        for i in 0..vocab {
            let e = q_expect.prob(i) * trials as f64;
            if e > 1.0 {
                chi2 += (counts_spec[i] as f64 - e).powi(2) / e;
            }
        }
        assert!(chi2 < 45.0, "chi2 = {chi2}, counts = {counts_spec:?}");
    }

    #[test]
    fn single_draft_verifier_ignores_extra_lanes() {
        let mut a = engine(VerifierKind::SingleDraft, 1, 1.5, 9);
        let mut b = engine(VerifierKind::SingleDraft, 6, 1.5, 9);
        let req = Request::new(1, vec![4], 15);
        let mut sa = SequenceState::from_request(&req);
        let mut sb = SequenceState::from_request(&req);
        a.decode_sequence(&mut sa);
        b.decode_sequence(&mut sb);
        assert_eq!(sa.tokens, sb.tokens);
    }

    #[test]
    fn pooled_stepping_matches_serial_and_reuses_draft_panels() {
        // One engine with the persistent pool forced on (threshold 0, two
        // workers), one with the serial oracle backend: identical tokens,
        // and the pooled engine's metrics must show draft-phase panels
        // firing on the workers.
        use super::super::config::VerifyBackend;
        let mk = |backend: VerifyBackend, workers: usize| {
            let (draft, target) = SimLm::pair(64, 11, 2.0);
            let cfg = EngineConfig {
                num_drafts: 3,
                block_len: 4,
                verifier: VerifierKind::Gls,
                target_params: SamplingParams::new(1.0, Some(20)),
                draft_params: vec![SamplingParams::new(1.0, Some(20))],
                max_seq_len: 256,
                seed: 5,
                parallel_threshold: 0,
                verify_workers: workers,
                verify_backend: backend,
                ..EngineConfig::default()
            };
            SpecDecodeEngine::new(
                cfg,
                ModelPair::new(Box::new(draft), Box::new(target)),
                PagedKvCache::new(2048, 16),
            )
        };
        let mk_seqs = || -> Vec<SequenceState> {
            (0..5u64)
                .map(|i| SequenceState::from_request(&Request::new(i, vec![1, (i % 7) as u32], 12)))
                .collect()
        };
        let mut pooled = mk(VerifyBackend::Pool, 2);
        let mut serial = mk(VerifyBackend::Serial, 0);
        let mut ps = mk_seqs();
        let mut ss = mk_seqs();
        for s in &ps {
            pooled.kv.register(s.id, s.tokens.len(), s.tokens.len() + 17, 5).unwrap();
        }
        for s in &ss {
            serial.kv.register(s.id, s.tokens.len(), s.tokens.len() + 17, 5).unwrap();
        }
        for _ in 0..2 {
            let mut pb: Vec<&mut SequenceState> = ps.iter_mut().collect();
            pooled.step_blocks(&mut pb);
            let mut sb: Vec<&mut SequenceState> = ss.iter_mut().collect();
            serial.step_blocks(&mut sb);
        }
        for (a, b) in ps.iter().zip(&ss) {
            assert_eq!(a.tokens, b.tokens, "seq {} diverged under pooling", a.id);
        }
        assert!(
            pooled.metrics.panel_cache_hits > 0,
            "handed-off draft panels never hit on pool workers"
        );
        assert!(
            serial.metrics.panel_cache_hits > 0,
            "draft panels never hit on the serial path"
        );
        // The leaky cache's miss counter must also flow back through both
        // paths: a cold workspace's first probes are always misses.
        assert!(
            pooled.metrics.panel_cache_misses > 0,
            "pool workers never reported cold-probe misses"
        );
        assert!(
            serial.metrics.panel_cache_misses > 0,
            "serial path never reported cold-probe misses"
        );
        // Block 2's draft phase must lease slices recycled from block 1's
        // consumers — on both the pooled and serial paths.
        assert!(
            pooled.metrics.panel_slices_recycled > 0,
            "spent slices never recycled back from pool workers"
        );
        assert!(
            serial.metrics.panel_slices_recycled > 0,
            "spent slices never recycled on the serial path"
        );
    }

    #[test]
    fn single_sequence_batch_never_fans_out() {
        // A one-job batch stays on the engine thread regardless of backend
        // or threshold — and the pool is never spawned for it.
        use super::super::config::VerifyBackend;
        let mut eng = engine(VerifierKind::Gls, 2, 1.5, 9);
        eng.cfg.parallel_threshold = 0;
        eng.cfg.verify_backend = VerifyBackend::Pool;
        let req = Request::new(1, vec![4], 10);
        let mut seq = SequenceState::from_request(&req);
        eng.decode_sequence(&mut seq);
        assert_eq!(seq.generated(), 10);
        assert!(eng.pool.is_none(), "pool spawned for single-sequence batches");
    }

    use crate::testkit::PoisonDraft;

    fn poisoned_engine(backend: VerifyBackend, workers: usize, trigger: u32) -> SpecDecodeEngine {
        let (draft, target) = SimLm::pair(32, 13, 1.5);
        let cfg = EngineConfig {
            num_drafts: 2,
            block_len: 4,
            verifier: VerifierKind::FaultInjection,
            target_params: SamplingParams::new(1.0, None),
            draft_params: vec![SamplingParams::new(1.0, None)],
            max_seq_len: 128,
            seed: 21,
            parallel_threshold: 0,
            verify_workers: workers,
            verify_backend: backend,
            ..EngineConfig::default()
        };
        SpecDecodeEngine::new(
            cfg,
            ModelPair::new(Box::new(PoisonDraft { inner: draft, trigger }), Box::new(target)),
            PagedKvCache::new(1024, 16),
        )
    }

    #[test]
    fn verify_fault_fails_one_sequence_not_the_engine() {
        // One poisoned request among honest ones, driven through the
        // scheduler on BOTH the serial path and the shared-worker pool:
        // the poisoned sequence retires with `failed`, everyone else
        // completes normally, KV drains to zero, and the engine keeps
        // serving afterwards.
        use crate::coordinator::scheduler::Scheduler;
        use crate::coordinator::sequence::Request;
        // Out-of-vocab marker: only a prompt can carry it (SimLm hashes
        // arbitrary token values), so honest sequences can never start
        // containing it mid-decode.
        let trigger = 999u32;
        for (backend, workers) in [(VerifyBackend::Serial, 0), (VerifyBackend::Pool, 2)] {
            let mut eng = poisoned_engine(backend, workers, trigger);
            let mut sched = Scheduler::new(8);
            for i in 0..3u64 {
                sched.submit(Request::new(i, vec![1, 2 + i as u32], 12));
            }
            sched.submit(Request::new(3, vec![trigger], 12)); // poisoned
            let results = sched.run_to_completion(&mut eng);
            assert_eq!(results.len(), 4, "{backend:?}: every request must retire");
            for r in &results {
                if r.id == 3 {
                    assert!(r.failed, "{backend:?}: poisoned request must fail");
                    assert_eq!(r.tokens, vec![trigger], "{backend:?}: no tokens past the fault");
                } else {
                    assert!(!r.failed, "{backend:?}: honest request {} failed", r.id);
                    assert_eq!(r.tokens.len(), 2 + 12, "{backend:?}: request {}", r.id);
                }
            }
            assert_eq!(eng.kv.used_pages(), 0, "{backend:?}: KV leak after fault");
            assert!(eng.metrics.verify_faults >= 1, "{backend:?}: fault not counted");
            // The engine (and its pool) must still serve new work.
            let mut sched2 = Scheduler::new(8);
            sched2.submit(Request::new(10, vec![4, 5], 8));
            let after = sched2.run_to_completion(&mut eng);
            assert_eq!(after.len(), 1);
            assert!(!after[0].failed, "{backend:?}: engine wedged after fault");
            assert_eq!(after[0].tokens.len(), 2 + 8);
        }
    }

    #[test]
    fn decode_sequence_terminates_on_fault() {
        let mut eng = poisoned_engine(VerifyBackend::Serial, 0, 999);
        let req = Request::new(1, vec![999], 16);
        let mut seq = SequenceState::from_request(&req);
        eng.decode_sequence(&mut seq); // must not loop forever
        assert_eq!(seq.phase, SeqPhase::Failed);
        assert_eq!(seq.generated(), 0);
        assert_eq!(eng.kv.used_pages(), 0);
    }

    #[test]
    fn per_request_verifier_override_matches_dedicated_engine() {
        // A request-level override on a Gls engine must decode
        // bit-identically to a dedicated engine of that kind with the
        // same num_drafts: drafting is batch-wide at the host's K,
        // single-draft kinds read only lane 0, and record_race /
        // sample_race are bit-exact.
        for vk in [
            VerifierKind::SpecInfer,
            VerifierKind::SpecTr,
            VerifierKind::SingleDraft,
            VerifierKind::Daliri,
        ] {
            let mut host = engine(VerifierKind::Gls, 3, 2.0, 7);
            let req = Request::new(1, vec![1, 2, 3], 15).with_verifier(Some(vk));
            let mut sa = SequenceState::from_request(&req);
            host.decode_sequence(&mut sa);

            let mut dedicated = engine(vk, 3, 2.0, 7);
            let req = Request::new(1, vec![1, 2, 3], 15);
            let mut sb = SequenceState::from_request(&req);
            dedicated.decode_sequence(&mut sb);
            assert_eq!(sa.tokens, sb.tokens, "override {vk:?} diverged from dedicated engine");
            // And a None override is exactly the engine default.
            let mut plain = engine(vk, 3, 2.0, 7);
            let req = Request::new(1, vec![1, 2, 3], 15).with_verifier(None);
            let mut sc = SequenceState::from_request(&req);
            plain.decode_sequence(&mut sc);
            assert_eq!(sb.tokens, sc.tokens, "None override must be the default path");
        }
    }

    #[test]
    fn transient_pool_fault_retries_once_and_recovers() {
        use super::super::pool::VerifyPool;
        use crate::coordinator::scheduler::Scheduler;

        let mk_eng = |retry: bool| {
            let mut eng = engine(VerifierKind::Gls, 3, 2.0, 17);
            eng.cfg.parallel_threshold = 0;
            eng.cfg.verify_backend = VerifyBackend::Pool;
            eng.cfg.retry_transient_faults = retry;
            // Attach the pool explicitly so the fuse can be armed before
            // the first batch.
            let pool = Arc::new(VerifyPool::new(2));
            eng.attach_shared_pool(Arc::clone(&pool), 0);
            (eng, pool)
        };
        let submit = |sched: &mut Scheduler| {
            for i in 0..3u64 {
                sched.submit(Request::new(i, vec![1, 2 + i as u32], 12));
            }
        };
        // Clean baseline (no fault, retry irrelevant).
        let (mut clean, _pool) = mk_eng(false);
        let mut sched = Scheduler::new(8);
        submit(&mut sched);
        let mut baseline = sched.run_to_completion(&mut clean);
        baseline.sort_by_key(|r| r.id);

        // Retry on + one armed transient fault: no sequence fails, tokens
        // are bit-identical to the clean run, and the retry counters tick.
        let (mut eng, pool) = mk_eng(true);
        pool.inject_transient_faults(1);
        let mut sched = Scheduler::new(8);
        submit(&mut sched);
        let mut results = sched.run_to_completion(&mut eng);
        results.sort_by_key(|r| r.id);
        assert!(results.iter().all(|r| !r.failed), "retry must absorb the transient fault");
        for (a, b) in results.iter().zip(&baseline) {
            assert_eq!(a.tokens, b.tokens, "request {} diverged through retry", a.id);
        }
        assert_eq!(eng.metrics.verify_retries, 1);
        assert_eq!(eng.metrics.verify_retries_recovered, 1);
        assert_eq!(eng.metrics.verify_faults, 0, "recovered fault must not count");

        // Control: the same fault with retry off fails exactly one
        // sequence — the pre-retry behavior.
        let (mut eng, pool) = mk_eng(false);
        pool.inject_transient_faults(1);
        let mut sched = Scheduler::new(8);
        submit(&mut sched);
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.iter().filter(|r| r.failed).count(), 1);
        assert_eq!(eng.metrics.verify_faults, 1);
        assert_eq!(eng.metrics.verify_retries, 0);
    }

    #[test]
    fn cancelled_sequence_rolls_kv_back_and_counts() {
        use crate::coordinator::sequence::CancelCause;
        let mut eng = engine(VerifierKind::Gls, 2, 1.5, 31);
        let req = Request::new(1, vec![1, 2], 20);
        req.cancel.cancel();
        let mut seq = SequenceState::from_request(&req);
        eng.decode_sequence(&mut seq); // must cut at the first block boundary
        assert_eq!(seq.phase, SeqPhase::Cancelled);
        assert_eq!(seq.generated(), 0, "cut before any commit emits nothing");
        assert_eq!(eng.kv.used_pages(), 0, "cancel must roll KV back to zero");
        assert_eq!(eng.metrics.cancelled, 1);
        assert_eq!(eng.metrics.timed_out, 0);
        let res = seq.into_result();
        assert_eq!(res.cancelled, Some(CancelCause::Explicit));
        assert!(!res.failed);
        assert!(!res.ok());
    }

    #[test]
    fn expired_deadline_times_out_at_the_block_boundary() {
        use crate::coordinator::sequence::CancelCause;
        let mut eng = engine(VerifierKind::Gls, 2, 1.5, 31);
        let req = Request::new(2, vec![1, 2], 20).with_deadline(std::time::Duration::ZERO);
        let mut seq = SequenceState::from_request(&req);
        eng.decode_sequence(&mut seq);
        assert_eq!(seq.phase, SeqPhase::Cancelled);
        assert_eq!(seq.generated(), 0);
        assert_eq!(eng.kv.used_pages(), 0);
        assert_eq!(eng.metrics.timed_out, 1);
        assert_eq!(eng.metrics.cancelled, 0);
        assert_eq!(seq.into_result().cancelled, Some(CancelCause::DeadlineExpired));
    }

    #[test]
    fn mid_decode_cancel_keeps_emitted_prefix_bit_exact() {
        // Cancel after two blocks: the partial output must be the exact
        // prefix the uncancelled run produced at the same block boundary,
        // and the cut must not disturb a co-batched honest sequence.
        let mk_seqs = || {
            (
                SequenceState::from_request(&Request::new(1, vec![1, 2], 40)),
                SequenceState::from_request(&Request::new(2, vec![3], 40)),
            )
        };
        let run = |cancel_after: Option<usize>| -> (Vec<u32>, Vec<u32>) {
            let mut eng = engine(VerifierKind::Gls, 2, 2.0, 55);
            let (mut a, mut b) = mk_seqs();
            eng.kv.register(1, 2, 42, 5).unwrap();
            eng.kv.register(2, 1, 41, 5).unwrap();
            a.phase = SeqPhase::Running;
            b.phase = SeqPhase::Running;
            for block in 0..4 {
                if cancel_after == Some(block) {
                    a.cancel.cancel();
                }
                if a.phase == SeqPhase::Running {
                    let mut batch = [&mut a, &mut b];
                    eng.step_blocks(&mut batch);
                } else {
                    let mut batch = [&mut b];
                    eng.step_blocks(&mut batch);
                }
            }
            if a.phase == SeqPhase::Cancelled {
                eng.kv.release(1).unwrap();
                assert_eq!(eng.kv.num_sequences(), 1, "only the honest seq holds KV");
            }
            (a.tokens, b.tokens)
        };
        let (full_a, full_b) = run(None);
        let (cut_a, cut_b) = run(Some(2));
        assert!(cut_a.len() < full_a.len(), "cancel must cut generation short");
        assert_eq!(cut_a[..], full_a[..cut_a.len()], "partial output is an exact prefix");
        assert_eq!(cut_b, full_b, "co-batched honest sequence perturbed by a cancel");
    }

    #[test]
    fn batched_and_sequential_stepping_agree_all_verifiers() {
        // Determinism for every verifier kind: stepping two sequences in
        // one batch produces the same tokens as stepping them separately
        // (verification is a pure function of per-sequence randomness
        // lanes, whichever kernel-backed scheme runs it).
        for &vk in VerifierKind::all() {
            let mk = || {
                (
                    SequenceState::from_request(&Request::new(1, vec![1, 2], 10)),
                    SequenceState::from_request(&Request::new(2, vec![3], 10)),
                )
            };
            let (mut a1, mut a2) = mk();
            let mut eng = engine(vk, 2, 2.0, 77);
            eng.kv.register(1, 2, 12, 5).unwrap();
            eng.kv.register(2, 1, 11, 5).unwrap();
            {
                let mut batch = [&mut a1, &mut a2];
                eng.step_blocks(&mut batch);
            }
            let (mut b1, mut b2) = mk();
            let mut eng2 = engine(vk, 2, 2.0, 77);
            eng2.kv.register(1, 2, 12, 5).unwrap();
            eng2.kv.register(2, 1, 11, 5).unwrap();
            {
                let mut batch = [&mut b1];
                eng2.step_blocks(&mut batch);
                let mut batch = [&mut b2];
                eng2.step_blocks(&mut batch);
            }
            assert_eq!(a1.tokens, b1.tokens, "verifier {vk:?}");
            assert_eq!(a2.tokens, b2.tokens, "verifier {vk:?}");
        }
    }
}
