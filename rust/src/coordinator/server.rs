//! Server facade: the one-stop entrypoint examples and benches use.
//!
//! Owns a [`Router`], assigns request ids, runs a workload to completion
//! and reports serving statistics (token rate, latency percentiles, block
//! efficiency) — the measurements behind the paper's TR columns. Each
//! worker's engine verifies through the persistent pool
//! (`coordinator::pool`), auto-sized per worker by the router;
//! [`ServeReport::metrics`] carries the merged `panel_cache_hits`
//! observability for the draft-exponential handoff.

use std::time::Instant;

use super::config::{EngineConfig, ServerConfig};
use super::metrics::EngineMetrics;
use super::router::{AdmitError, DrainPolicy, Router, RoutingPolicy};
use super::sequence::{Request, RequestResult};
use crate::model::backend::ModelPair;

/// Aggregate results of one served workload.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub results: Vec<RequestResult>,
    pub metrics: EngineMetrics,
    pub wall: std::time::Duration,
}

impl ServeReport {
    /// Generated tokens per second of wall clock — the paper's token rate.
    pub fn token_rate(&self) -> f64 {
        let toks: usize = self.results.iter().map(|r| r.target_calls).sum::<usize>();
        let _ = toks;
        let generated: u64 = self.metrics.emitted_tokens;
        generated as f64 / self.wall.as_secs_f64()
    }

    /// Mean per-request block efficiency (paper BE).
    pub fn mean_block_efficiency(&self) -> f64 {
        if self.results.is_empty() {
            return 0.0;
        }
        self.results.iter().map(|r| r.block_efficiency).sum::<f64>() / self.results.len() as f64
    }

    pub fn p50_latency(&self) -> f64 {
        self.metrics.latency.quantile(0.5)
    }

    pub fn p95_latency(&self) -> f64 {
        self.metrics.latency.quantile(0.95)
    }

    pub fn p99_latency(&self) -> f64 {
        self.metrics.latency.quantile(0.99)
    }

    /// Per-token latency quantiles (request latency / generated tokens),
    /// recorded at retire time by the scheduler.
    pub fn p95_token_latency(&self) -> f64 {
        self.metrics.token_latency.quantile(0.95)
    }

    pub fn p99_token_latency(&self) -> f64 {
        self.metrics.token_latency.quantile(0.99)
    }

    /// Time-to-first-token quantiles (submission to first emitted token).
    pub fn p50_ttft(&self) -> f64 {
        self.metrics.ttft.quantile(0.5)
    }

    pub fn p95_ttft(&self) -> f64 {
        self.metrics.ttft.quantile(0.95)
    }

    /// Generated tokens per wall-clock second counting only sequences
    /// that completed cleanly — the harness's goodput measure. Failed and
    /// cancelled sequences' partial output is real work but not useful
    /// output, so both are excluded; `token_rate` keeps the raw number.
    /// (With nothing cancelled, `r.ok()` is exactly the old `!r.failed`.)
    pub fn goodput(&self) -> f64 {
        let toks: usize = self
            .results
            .iter()
            .filter(|r| r.ok())
            .map(|r| r.tokens.len().saturating_sub(r.prompt_len))
            .sum();
        toks as f64 / self.wall.as_secs_f64()
    }

    /// Sequences retired by explicit cancellation.
    pub fn cancelled(&self) -> u64 {
        self.metrics.cancelled
    }

    /// Sequences retired because their deadline expired mid-flight or in
    /// the queue.
    pub fn timed_out(&self) -> u64 {
        self.metrics.timed_out
    }

    /// Submissions shed at admission (queue-full plus already-expired).
    pub fn shed(&self) -> u64 {
        self.metrics.shed_full + self.metrics.shed_expired
    }

    /// High-water mark of in-flight requests observed at admission.
    pub fn queue_peak(&self) -> u64 {
        self.metrics.queue_peak
    }
}

pub struct Server {
    router: Router,
    next_id: u64,
    submitted: usize,
}

impl Server {
    pub fn start<F>(
        server_cfg: &ServerConfig,
        engine_cfg: &EngineConfig,
        policy: RoutingPolicy,
        make_pair: F,
    ) -> Self
    where
        F: Fn(usize) -> ModelPair,
    {
        Self { router: Router::start(server_cfg, engine_cfg, policy, make_pair), next_id: 0, submitted: 0 }
    }

    /// The server-global verify pool when `pool_scope = server` (the
    /// default with the pool backend) — observability for stats, benches
    /// and thread-census tests.
    pub fn verify_pool(&self) -> Option<&std::sync::Arc<super::pool::VerifyPool>> {
        self.router.verify_pool()
    }

    /// Submit a prompt; returns the assigned request id.
    pub fn submit(&mut self, prompt: Vec<u32>, max_new_tokens: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.submitted += 1;
        self.router.submit(Request::new(id, prompt, max_new_tokens));
        id
    }

    /// Submit a fully built [`Request`] (deadline, cancel handle, pinned
    /// verifier) through admission control, assigning the next server id.
    /// On a shed, the request never reaches a worker and the typed error
    /// says why — the caller decides whether to retry or drop.
    pub fn try_submit(&mut self, req: Request) -> Result<u64, AdmitError> {
        let id = self.next_id;
        // Follow `Request::new`'s lane = id convention (the registry's
        // `server_request_lane` contract) unless the caller pinned a custom
        // randomness lane.
        let rng_lane = if req.rng_lane == req.id {
            crate::analysis::lanes::server_request_lane(id)
        } else {
            req.rng_lane
        };
        let req = Request { id, rng_lane, ..req };
        self.router.try_submit(req)?;
        self.next_id += 1;
        self.submitted += 1;
        Ok(id)
    }

    /// Graceful drain: close intake, apply `policy` to everything in
    /// flight, join all workers, and report. `wall` spans only the drain
    /// itself (callers timing a full workload should wrap externally).
    pub fn drain(self, policy: DrainPolicy) -> ServeReport {
        let start = Instant::now();
        let (metrics, mut results) = self.router.drain(policy);
        let wall = start.elapsed();
        results.sort_by_key(|r| r.id);
        ServeReport { results, metrics, wall }
    }

    /// Block until all submitted requests complete, then shut down.
    pub fn finish(self) -> ServeReport {
        let start = Instant::now();
        let mut results = Vec::with_capacity(self.submitted);
        for _ in 0..self.submitted {
            results.push(self.router.results_rx.recv().expect("worker dropped"));
        }
        let wall = start.elapsed();
        let metrics = self.router.shutdown();
        results.sort_by_key(|r| r.id);
        ServeReport { results, metrics, wall }
    }

    /// Serve a closed-loop workload: submit everything, then wait. Returns
    /// the report with wall measured across the full span (submission to
    /// last completion), which is what throughput should be charged for.
    pub fn serve_all<F>(
        server_cfg: &ServerConfig,
        engine_cfg: &EngineConfig,
        policy: RoutingPolicy,
        make_pair: F,
        workload: Vec<(Vec<u32>, usize)>,
    ) -> ServeReport
    where
        F: Fn(usize) -> ModelPair,
    {
        let start = Instant::now();
        let mut server = Self::start(server_cfg, engine_cfg, policy, make_pair);
        let n = workload.len();
        for (prompt, max_new) in workload {
            server.submit(prompt, max_new);
        }
        let mut results = Vec::with_capacity(n);
        for _ in 0..n {
            results.push(server.router.results_rx.recv().expect("worker dropped"));
        }
        let wall = start.elapsed();
        let metrics = server.router.shutdown();
        results.sort_by_key(|r| r.id);
        ServeReport { results, metrics, wall }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sim::SimLm;
    use crate::spec::types::VerifierKind;
    use std::time::Duration;

    fn cfgs() -> (ServerConfig, EngineConfig) {
        (
            ServerConfig {
                workers: 2,
                max_batch: 4,
                batch_deadline: Duration::from_millis(1),
                max_running: 8,
                kv_pages: 1024,
                kv_page_size: 16,
                ..ServerConfig::default()
            },
            EngineConfig {
                verifier: VerifierKind::Gls,
                num_drafts: 3,
                block_len: 4,
                max_seq_len: 256,
                ..EngineConfig::default()
            },
        )
    }

    #[test]
    fn serve_all_reports_consistent_numbers() {
        let (sc, ec) = cfgs();
        let workload: Vec<(Vec<u32>, usize)> = (0..12).map(|i| (vec![i as u32, 1], 16)).collect();
        let report = Server::serve_all(
            &sc,
            &ec,
            RoutingPolicy::LeastLoaded,
            |_| {
                let (d, t) = SimLm::pair(32, 9, 1.5);
                ModelPair::new(Box::new(d), Box::new(t))
            },
            workload,
        );
        assert_eq!(report.results.len(), 12);
        assert_eq!(report.metrics.completed, 12);
        assert!(report.token_rate() > 0.0);
        let be = report.mean_block_efficiency();
        assert!(be > 1.0 && be <= 5.0, "BE {be}");
        assert!(report.p95_latency() >= report.p50_latency());
        assert!(report.p99_latency() >= report.p95_latency());
        // Every request emitted a first token, so TTFT and per-token
        // latency are populated and their quantiles ordered.
        assert_eq!(report.metrics.ttft.count(), 12);
        assert_eq!(report.metrics.token_latency.count(), 12);
        assert!(report.p95_ttft() >= report.p50_ttft());
        assert!(report.p99_token_latency() >= report.p95_token_latency());
        assert!(report.goodput() > 0.0);
        // No faults here, so goodput counts exactly the generated tokens.
        let gen: usize =
            report.results.iter().map(|r| r.tokens.len() - r.prompt_len).sum();
        let expected = gen as f64 / report.wall.as_secs_f64();
        assert!((report.goodput() - expected).abs() < 1e-9);
        // Results sorted by id.
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn serve_all_with_forced_verify_pool_matches_serial_serving() {
        // The full serving stack (router → scheduler → engine) with the
        // verify pool forced on must emit exactly the tokens the serial
        // oracle emits, and the handoff must demonstrably fire.
        use crate::coordinator::config::VerifyBackend;
        let (sc, ec) = cfgs();
        let workload: Vec<(Vec<u32>, usize)> =
            (0..10).map(|i| (vec![i as u32, 3], 14)).collect();
        let run = |backend: VerifyBackend, workers: usize| {
            let ec = EngineConfig {
                parallel_threshold: 0,
                verify_workers: workers,
                verify_backend: backend,
                ..ec.clone()
            };
            Server::serve_all(
                &sc,
                &ec,
                RoutingPolicy::RoundRobin,
                |_| {
                    let (d, t) = SimLm::pair(32, 9, 1.5);
                    ModelPair::new(Box::new(d), Box::new(t))
                },
                workload.clone(),
            )
        };
        let pooled = run(VerifyBackend::Pool, 2);
        let serial = run(VerifyBackend::Serial, 0);
        assert_eq!(pooled.results.len(), serial.results.len());
        for (a, b) in pooled.results.iter().zip(&serial.results) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "request {} diverged under pooling", a.id);
        }
        assert!(
            pooled.metrics.panel_cache_hits > 0,
            "panel handoff never fired through the serving stack"
        );
    }

    #[test]
    fn shared_and_per_engine_pool_scopes_serve_identical_tokens() {
        // The server-global pool is a pure execution-topology change:
        // every request's tokens must be bit-identical across
        // pool_scope = server / engine and the serial oracle.
        use crate::coordinator::config::{PoolScope, VerifyBackend};
        let (sc, ec) = cfgs();
        let workload: Vec<(Vec<u32>, usize)> =
            (0..12).map(|i| (vec![i as u32, 5], 14)).collect();
        let run = |scope: PoolScope, backend: VerifyBackend| {
            let sc = ServerConfig { pool_scope: scope, ..sc.clone() };
            let ec = EngineConfig {
                parallel_threshold: 0,
                verify_workers: 2,
                verify_backend: backend,
                ..ec.clone()
            };
            Server::serve_all(
                &sc,
                &ec,
                RoutingPolicy::RoundRobin,
                |_| {
                    let (d, t) = SimLm::pair(32, 17, 1.5);
                    ModelPair::new(Box::new(d), Box::new(t))
                },
                workload.clone(),
            )
        };
        let shared = run(PoolScope::Server, VerifyBackend::Pool);
        let per_engine = run(PoolScope::Engine, VerifyBackend::Pool);
        let serial = run(PoolScope::Server, VerifyBackend::Serial);
        for ((a, b), c) in shared.results.iter().zip(&per_engine.results).zip(&serial.results) {
            assert_eq!(a.tokens, b.tokens, "request {} diverged across pool scopes", a.id);
            assert_eq!(a.tokens, c.tokens, "request {} diverged from serial", a.id);
            assert!(!a.failed);
        }
        assert!(
            shared.metrics.panel_cache_hits > 0,
            "panel handoff never fired through the shared pool"
        );
    }

    #[test]
    fn server_drain_reports_one_terminal_state_per_request() {
        let (sc, ec) = cfgs();
        let mut server = Server::start(&sc, &ec, RoutingPolicy::RoundRobin, |_| {
            let (d, t) = SimLm::pair(32, 4, 1.0);
            ModelPair::new(Box::new(d), Box::new(t))
        });
        for i in 0..8u32 {
            server
                .try_submit(Request::new(0, vec![i], 60))
                .expect("default admission is open");
        }
        let report = server.drain(DrainPolicy::CancelInFlight);
        assert_eq!(report.results.len(), 8);
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.id, i as u64, "server assigns dense ids");
            assert!(!r.failed);
            assert!(r.cancelled.is_some() || r.tokens.len() == 61);
        }
        assert_eq!(report.metrics.completed, 8);
        assert_eq!(
            report.cancelled() + report.timed_out(),
            report.results.iter().filter(|r| r.cancelled.is_some()).count() as u64
        );
        assert_eq!(report.shed(), 0);
        // Goodput counts clean completions only; cancelled output is
        // excluded even though its partial tokens are in `results`.
        let clean: usize = report
            .results
            .iter()
            .filter(|r| r.ok())
            .map(|r| r.tokens.len() - r.prompt_len)
            .sum();
        assert!((report.goodput() - clean as f64 / report.wall.as_secs_f64()).abs() < 1e-9);
    }

    #[test]
    fn server_surfaces_typed_sheds() {
        use crate::coordinator::router::AdmitError;
        let (sc, ec) = cfgs();
        let sc = ServerConfig { shed_expired: true, ..sc };
        let mut server = Server::start(&sc, &ec, RoutingPolicy::RoundRobin, |_| {
            let (d, t) = SimLm::pair(32, 4, 1.0);
            ModelPair::new(Box::new(d), Box::new(t))
        });
        let err = server
            .try_submit(Request::new(0, vec![1], 8).with_deadline(Duration::ZERO))
            .unwrap_err();
        assert_eq!(err, AdmitError::DeadlineExpired);
        server.try_submit(Request::new(0, vec![1], 8)).unwrap();
        let report = server.finish();
        assert_eq!(report.results.len(), 1);
        assert_eq!(report.shed(), 1);
        assert!(report.results[0].ok());
    }

    #[test]
    fn incremental_submit_then_finish() {
        let (sc, ec) = cfgs();
        let mut server = Server::start(&sc, &ec, RoutingPolicy::RoundRobin, |_| {
            let (d, t) = SimLm::pair(32, 4, 1.0);
            ModelPair::new(Box::new(d), Box::new(t))
        });
        for i in 0..5 {
            server.submit(vec![i], 8);
        }
        let report = server.finish();
        assert_eq!(report.results.len(), 5);
        for r in &report.results {
            assert_eq!(r.tokens.len(), 9);
        }
    }
}
