//! Dynamic batching: collect requests until the batch is full or the
//! deadline expires, whichever comes first — the standard latency/
//! throughput trade-off dial of serving systems.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::sequence::Request;

/// Size/deadline batcher over an mpsc receiver.
pub struct DynamicBatcher {
    pub max_batch: usize,
    pub deadline: Duration,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, deadline: Duration) -> Self {
        assert!(max_batch >= 1);
        Self { max_batch, deadline }
    }

    /// Block for the first request, then keep collecting until `max_batch`
    /// or `deadline` since the first arrival. Returns `None` when the
    /// channel has disconnected and no requests remain.
    pub fn next_batch(&self, rx: &Receiver<Request>) -> Option<Vec<Request>> {
        let first = rx.recv().ok()?;
        let mut batch = vec![first];
        let start = Instant::now();
        while batch.len() < self.max_batch {
            let remaining = self.deadline.saturating_sub(start.elapsed());
            if remaining.is_zero() {
                break;
            }
            match rx.recv_timeout(remaining) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }

    /// Drain whatever is immediately available (non-blocking), capped at
    /// `max_batch`. Used by the scheduler to admit work between decode
    /// iterations without stalling running sequences.
    pub fn drain_ready(&self, rx: &Receiver<Request>) -> Vec<Request> {
        let mut batch = Vec::new();
        while batch.len() < self.max_batch {
            match rx.try_recv() {
                Ok(req) => batch.push(req),
                Err(_) => break,
            }
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(Request::new(i, vec![0], 1)).unwrap();
        }
        let b = DynamicBatcher::new(3, Duration::from_millis(50));
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 3);
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(Request::new(1, vec![0], 1)).unwrap();
        let b = DynamicBatcher::new(10, Duration::from_millis(10));
        let t0 = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(9));
        drop(tx);
    }

    #[test]
    fn disconnect_returns_none_when_empty() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let b = DynamicBatcher::new(4, Duration::from_millis(1));
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn late_arrivals_join_within_deadline() {
        let (tx, rx) = mpsc::channel();
        let b = DynamicBatcher::new(4, Duration::from_millis(60));
        let handle = std::thread::spawn(move || {
            tx.send(Request::new(1, vec![0], 1)).unwrap();
            std::thread::sleep(Duration::from_millis(15));
            tx.send(Request::new(2, vec![0], 1)).unwrap();
        });
        let batch = b.next_batch(&rx).unwrap();
        handle.join().unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn drain_ready_is_nonblocking() {
        let (tx, rx) = mpsc::channel();
        let b = DynamicBatcher::new(8, Duration::from_millis(100));
        let t0 = Instant::now();
        assert!(b.drain_ready(&rx).is_empty());
        assert!(t0.elapsed() < Duration::from_millis(20));
        tx.send(Request::new(1, vec![0], 1)).unwrap();
        tx.send(Request::new(2, vec![0], 1)).unwrap();
        assert_eq!(b.drain_ready(&rx).len(), 2);
    }
}
