//! Engine and server metrics: block efficiency, token rates, latency.

use std::time::Duration;

use crate::stats::summary::{Histogram, OnlineStats};

/// Per-engine counters; merged across workers for the server view.
#[derive(Clone, Debug)]
pub struct EngineMetrics {
    /// Speculative blocks executed (== target model calls).
    pub blocks: u64,
    /// Draft model steps executed (block_len per block per lane batch).
    pub draft_steps: u64,
    /// Tokens emitted to clients.
    pub emitted_tokens: u64,
    /// Draft positions accepted.
    pub accepted_tokens: u64,
    /// Completed requests.
    pub completed: u64,
    /// Per-request block efficiency.
    pub be: OnlineStats,
    /// Request latency histogram (seconds).
    pub latency: Histogram,
    /// Wall time spent in the target model (verification).
    pub target_time: Duration,
    /// Wall time spent drafting.
    pub draft_time: Duration,
    /// Wall time spent in verification math (the coupling algorithms).
    pub verify_time: Duration,
    /// Exponential-panel rows verification reused from the draft phase
    /// (serial cache hits + pool-worker hits via the panel-slice handoff).
    pub panel_cache_hits: u64,
    /// Panel-cache probes that found no usable row (cold lane, or the
    /// slot's previous occupant was overwritten) — the recompute side of
    /// the leaky cache's hit/miss ledger.
    pub panel_cache_misses: u64,
    /// Occupied direct-mapped slots reclaimed for a different lane key
    /// (the "leak" in the leaky cache: collisions overwrite, they never
    /// chain or grow).
    pub panel_cache_overwrites: u64,
    /// Draft-phase panel-slice leases served from the recycling channel
    /// (spent buffers returned by consuming workspaces) rather than fresh
    /// allocation — the observable of the slice lease/return protocol.
    pub panel_slices_recycled: u64,
    /// Verify jobs that panicked and were contained (the sequence failed,
    /// the engine and pool survived).
    pub verify_faults: u64,
    /// Time-to-first-token histogram (seconds from submission to the
    /// first generated token), recorded as sequences retire.
    pub ttft: Histogram,
    /// Per-token latency histogram (seconds per generated token,
    /// request latency / generated count), recorded as sequences retire.
    pub token_latency: Histogram,
    /// Verify jobs resubmitted after a transient pool fault
    /// (`EngineConfig::retry_transient_faults`).
    pub verify_retries: u64,
    /// Resubmitted jobs that then completed — sequences the retry-once
    /// policy saved from `SeqPhase::Failed`.
    pub verify_retries_recovered: u64,
    /// Sequences cut by an explicit client cancel (`CancelToken`); their
    /// KV pages rolled back like failed sequences.
    pub cancelled: u64,
    /// Sequences cut by deadline expiry.
    pub timed_out: u64,
    /// Submissions refused with `AdmitError::QueueFull` (router-side;
    /// folded into the merged view at shutdown/drain).
    pub shed_full: u64,
    /// Submissions refused with `AdmitError::DeadlineExpired`.
    pub shed_expired: u64,
    /// High-water mark of in-flight admitted requests (router-side).
    /// Merged with `max`, not `+`: workers share one admission queue.
    pub queue_peak: u64,
}

impl Default for EngineMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self {
            blocks: 0,
            draft_steps: 0,
            emitted_tokens: 0,
            accepted_tokens: 0,
            completed: 0,
            be: OnlineStats::new(),
            latency: Histogram::latency(),
            target_time: Duration::ZERO,
            draft_time: Duration::ZERO,
            verify_time: Duration::ZERO,
            panel_cache_hits: 0,
            panel_cache_misses: 0,
            panel_cache_overwrites: 0,
            panel_slices_recycled: 0,
            verify_faults: 0,
            ttft: Histogram::latency(),
            token_latency: Histogram::latency(),
            verify_retries: 0,
            verify_retries_recovered: 0,
            cancelled: 0,
            timed_out: 0,
            shed_full: 0,
            shed_expired: 0,
            queue_peak: 0,
        }
    }

    /// Aggregate block efficiency: emitted tokens per target call.
    pub fn block_efficiency(&self) -> f64 {
        if self.blocks == 0 {
            0.0
        } else {
            self.emitted_tokens as f64 / self.blocks as f64
        }
    }

    /// Token acceptance rate: accepted draft positions per drafted position.
    pub fn acceptance_rate(&self, block_len: usize) -> f64 {
        if self.blocks == 0 {
            0.0
        } else {
            self.accepted_tokens as f64 / (self.blocks as f64 * block_len as f64)
        }
    }

    pub fn merge(&mut self, other: &EngineMetrics) {
        self.blocks += other.blocks;
        self.draft_steps += other.draft_steps;
        self.emitted_tokens += other.emitted_tokens;
        self.accepted_tokens += other.accepted_tokens;
        self.completed += other.completed;
        self.be.merge(&other.be);
        self.latency.merge(&other.latency);
        self.target_time += other.target_time;
        self.draft_time += other.draft_time;
        self.verify_time += other.verify_time;
        self.panel_cache_hits += other.panel_cache_hits;
        self.panel_cache_misses += other.panel_cache_misses;
        self.panel_cache_overwrites += other.panel_cache_overwrites;
        self.panel_slices_recycled += other.panel_slices_recycled;
        self.verify_faults += other.verify_faults;
        self.ttft.merge(&other.ttft);
        self.token_latency.merge(&other.token_latency);
        self.verify_retries += other.verify_retries;
        self.verify_retries_recovered += other.verify_retries_recovered;
        self.cancelled += other.cancelled;
        self.timed_out += other.timed_out;
        self.shed_full += other.shed_full;
        self.shed_expired += other.shed_expired;
        self.queue_peak = self.queue_peak.max(other.queue_peak);
    }

    pub fn report(&self) -> String {
        format!(
            "blocks={} emitted={} BE={:.3} accept/blk={:.3} completed={} \
             p50={:.1}ms p95={:.1}ms target={:.0}ms draft={:.0}ms verify={:.2}ms \
             panel-hits={}/m{}/o{} slices-recycled={} faults={} \
             ttft-p50={:.1}ms tok-p95={:.2}ms retries={}/{} \
             cancelled={} timed-out={} shed={}/{} queue-peak={}",
            self.blocks,
            self.emitted_tokens,
            self.block_efficiency(),
            if self.blocks > 0 { self.accepted_tokens as f64 / self.blocks as f64 } else { 0.0 },
            self.completed,
            self.latency.quantile(0.5) * 1e3,
            self.latency.quantile(0.95) * 1e3,
            self.target_time.as_secs_f64() * 1e3,
            self.draft_time.as_secs_f64() * 1e3,
            self.verify_time.as_secs_f64() * 1e3,
            self.panel_cache_hits,
            self.panel_cache_misses,
            self.panel_cache_overwrites,
            self.panel_slices_recycled,
            self.verify_faults,
            self.ttft.quantile(0.5) * 1e3,
            self.token_latency.quantile(0.95) * 1e3,
            self.verify_retries_recovered,
            self.verify_retries,
            self.cancelled,
            self.timed_out,
            self.shed_full,
            self.shed_expired,
            self.queue_peak,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_efficiency_math() {
        let mut m = EngineMetrics::new();
        m.blocks = 4;
        m.emitted_tokens = 18;
        m.accepted_tokens = 14;
        assert!((m.block_efficiency() - 4.5).abs() < 1e-12);
        assert!((m.acceptance_rate(5) - 0.7).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = EngineMetrics::new();
        a.blocks = 2;
        a.emitted_tokens = 8;
        let mut b = EngineMetrics::new();
        b.blocks = 3;
        b.emitted_tokens = 12;
        b.completed = 1;
        a.merge(&b);
        assert_eq!(a.blocks, 5);
        assert_eq!(a.emitted_tokens, 20);
        assert_eq!(a.completed, 1);
    }

    #[test]
    fn merge_accumulates_panel_cache_counters() {
        let mut a = EngineMetrics::new();
        a.panel_cache_hits = 5;
        a.panel_cache_misses = 2;
        a.panel_cache_overwrites = 1;
        let mut b = EngineMetrics::new();
        b.panel_cache_hits = 3;
        b.panel_cache_misses = 4;
        b.panel_cache_overwrites = 2;
        a.merge(&b);
        assert_eq!(a.panel_cache_hits, 8);
        assert_eq!(a.panel_cache_misses, 6);
        assert_eq!(a.panel_cache_overwrites, 3);
    }

    #[test]
    fn merge_accumulates_latency_and_retry_counters() {
        let mut a = EngineMetrics::new();
        a.ttft.record(0.010);
        a.token_latency.record(0.002);
        a.verify_retries = 2;
        a.verify_retries_recovered = 1;
        let mut b = EngineMetrics::new();
        b.ttft.record(0.020);
        b.token_latency.record(0.004);
        b.verify_retries = 1;
        b.verify_retries_recovered = 1;
        a.merge(&b);
        assert_eq!(a.ttft.count(), 2);
        assert_eq!(a.token_latency.count(), 2);
        assert_eq!(a.verify_retries, 3);
        assert_eq!(a.verify_retries_recovered, 2);
        assert!(a.ttft.quantile(0.95) >= a.ttft.quantile(0.5));
    }

    #[test]
    fn merge_lifecycle_counters_add_and_queue_peak_maxes() {
        let mut a = EngineMetrics::new();
        a.cancelled = 2;
        a.timed_out = 1;
        a.shed_full = 3;
        a.shed_expired = 1;
        a.queue_peak = 7;
        let mut b = EngineMetrics::new();
        b.cancelled = 1;
        b.timed_out = 4;
        b.shed_full = 2;
        b.shed_expired = 2;
        b.queue_peak = 5;
        a.merge(&b);
        assert_eq!(a.cancelled, 3);
        assert_eq!(a.timed_out, 5);
        assert_eq!(a.shed_full, 5);
        assert_eq!(a.shed_expired, 3);
        // High-water mark takes the max — the workers shared one queue.
        assert_eq!(a.queue_peak, 7);
    }

    #[test]
    fn empty_metrics_do_not_divide_by_zero() {
        let m = EngineMetrics::new();
        assert_eq!(m.block_efficiency(), 0.0);
        assert_eq!(m.acceptance_rate(4), 0.0);
        assert!(!m.report().is_empty());
    }
}
