//! Request and sequence state tracked by the scheduler/engine.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::spec::types::VerifierKind;

/// Shared cancellation handle for one request. Cloning yields another
/// handle to the same flag, so a client can keep one side and hand the
/// other to the router; flipping it is monotone (a cancelled request
/// never un-cancels), which is what lets the engine epilogue and the
/// verify-job claim check observe it independently without racing.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self(Arc::new(AtomicBool::new(false)))
    }

    /// Request cancellation. Idempotent; takes effect at the next block
    /// boundary or verify-job claim, whichever the sequence hits first.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }

    /// Number of live handles to this flag (used by the router to prune
    /// its registry once the client side is dropped).
    pub(crate) fn handle_count(&self) -> usize {
        Arc::strong_count(&self.0)
    }
}

/// Why a sequence was cut short of its generation budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelCause {
    /// The client flipped the request's `CancelToken`.
    Explicit,
    /// The request's deadline elapsed before completion.
    DeadlineExpired,
}

/// An inference request as submitted by a client.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Per-request randomness lane; the engine splits the shared root key
    /// with this so concurrent requests have independent coupling streams.
    pub rng_lane: u64,
    /// Per-request verification-scheme override; `None` uses the engine's
    /// configured verifier. This is how mixed-verifier traces run through
    /// one engine (drafting stays batch-wide; kinds that consume fewer
    /// lanes ignore the extras bit-exactly) and how the workload drills
    /// arm `VerifierKind::FaultInjection` on exactly the scripted
    /// requests.
    pub verifier: Option<VerifierKind>,
    /// Wall-clock budget measured from `Request::new`. `None` = no
    /// deadline. Checked at block boundaries and at verify-job claim
    /// time; an expired sequence retires as
    /// `CancelCause::DeadlineExpired` with its KV rolled back.
    pub deadline: Option<Duration>,
    /// Cancellation flag shared with whoever called `cancel_handle`.
    pub cancel: CancelToken,
    /// Stamped at construction so the deadline clock (and reported
    /// latency) covers queue wait, not just decode time.
    pub submitted_at: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            rng_lane: crate::analysis::lanes::server_request_lane(id),
            verifier: None,
            deadline: None,
            cancel: CancelToken::new(),
            submitted_at: Instant::now(),
        }
    }

    /// Builder-style verifier override (`None` = engine default).
    pub fn with_verifier(mut self, verifier: Option<VerifierKind>) -> Self {
        self.verifier = verifier;
        self
    }

    /// Builder-style deadline, measured from construction.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// A clone of the request's cancellation handle for the client to
    /// keep after submitting.
    pub fn cancel_handle(&self) -> CancelToken {
        self.cancel.clone()
    }
}

/// Completed request with per-request accounting.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Target-model calls consumed (blocks executed).
    pub target_calls: usize,
    /// Draft-model steps consumed (block_len per block).
    pub draft_steps: usize,
    /// Tokens produced per target call — the paper's block efficiency.
    pub block_efficiency: f64,
    /// Wall-clock latency from submission to completion.
    pub latency: std::time::Duration,
    /// Wall-clock time from submission to the first generated token
    /// (`None` if the sequence produced nothing before retiring).
    pub ttft: Option<Duration>,
    /// The request's declared generation budget. Together with
    /// `prompt_len` and `verifier` this reconstructs the exact
    /// `routing_cost` the router charged the worker's load counter at
    /// submission, so completion credits the identical amount back
    /// (the `LeastLoaded` signal is additive).
    pub max_new_tokens: usize,
    /// Prompt length of the originating request (for routing-cost credit
    /// and per-token goodput accounting).
    pub prompt_len: usize,
    /// The request's verifier override, echoed back for routing-cost
    /// credit symmetry.
    pub verifier: Option<VerifierKind>,
    /// The sequence failed mid-decode (a verification fault): `tokens`
    /// holds whatever was emitted before the failure. A failed request
    /// never takes down its worker — it is retired like any completion.
    pub failed: bool,
    /// The sequence was cut short (client cancel or deadline): `tokens`
    /// holds whatever was emitted before the cut. Cancelled requests
    /// retire through the same KV-rollback path as failed ones.
    pub cancelled: Option<CancelCause>,
}

impl RequestResult {
    /// The request ran to its natural completion: neither failed nor cut.
    pub fn ok(&self) -> bool {
        !self.failed && self.cancelled.is_none()
    }
}

/// Lifecycle of a sequence inside one worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    /// Waiting for KV admission.
    Queued,
    /// Admitted, decoding blocks.
    Running,
    /// Hit max_new_tokens or max_seq_len.
    Finished,
    /// A verification fault (panicking verify job) killed this sequence;
    /// the scheduler retires it with `RequestResult::failed = true`
    /// instead of letting it wedge the engine.
    Failed,
    /// Cut short by client cancellation or deadline expiry; retired with
    /// the same KV rollback as `Failed` but reported separately.
    Cancelled,
}

/// Scheduler-side state of an in-flight sequence.
#[derive(Clone, Debug)]
pub struct SequenceState {
    pub id: u64,
    /// Prompt followed by generated tokens.
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub phase: SeqPhase,
    pub rng_lane: u64,
    /// Next shared-randomness slot (absolute decode position).
    pub next_slot: u64,
    pub target_calls: usize,
    pub draft_steps: usize,
    pub submitted_at: Instant,
    /// Per-sequence verifier override carried from the request.
    pub verifier: Option<VerifierKind>,
    /// Stamped by the engine when the first generated token lands.
    pub first_token_at: Option<Duration>,
    /// Cancellation flag carried from the request.
    pub cancel: CancelToken,
    /// Absolute deadline (`submitted_at + deadline`), precomputed once so
    /// every checkpoint (engine epilogue, verify-job claim, scheduler
    /// reap) agrees monotonically: once expired, always expired.
    pub deadline_at: Option<Instant>,
    /// Set when a cut is first observed, so the terminal cause is stable
    /// even if the deadline also expires later.
    pub cancelled: Option<CancelCause>,
}

impl SequenceState {
    pub fn from_request(req: &Request) -> Self {
        Self {
            id: req.id,
            tokens: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new_tokens: req.max_new_tokens,
            phase: SeqPhase::Queued,
            rng_lane: req.rng_lane,
            next_slot: 0,
            target_calls: 0,
            draft_steps: 0,
            submitted_at: req.submitted_at,
            verifier: req.verifier,
            first_token_at: None,
            cancel: req.cancel.clone(),
            deadline_at: req.deadline.map(|d| req.submitted_at + d),
            cancelled: None,
        }
    }

    /// Should this sequence be cut right now? Explicit cancellation wins
    /// over deadline expiry when both hold.
    pub fn cut_now(&self) -> Option<CancelCause> {
        if self.cancel.is_cancelled() {
            return Some(CancelCause::Explicit);
        }
        match self.deadline_at {
            Some(at) if Instant::now() >= at => Some(CancelCause::DeadlineExpired),
            _ => None,
        }
    }

    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    pub fn remaining(&self) -> usize {
        self.max_new_tokens.saturating_sub(self.generated())
    }

    pub fn is_done(&self, max_seq_len: usize) -> bool {
        self.remaining() == 0 || self.tokens.len() >= max_seq_len
    }

    pub fn block_efficiency(&self) -> f64 {
        if self.target_calls == 0 {
            0.0
        } else {
            self.generated() as f64 / self.target_calls as f64
        }
    }

    pub fn into_result(self) -> RequestResult {
        let be = self.block_efficiency();
        RequestResult {
            id: self.id,
            tokens: self.tokens,
            target_calls: self.target_calls,
            draft_steps: self.draft_steps,
            block_efficiency: be,
            latency: self.submitted_at.elapsed(),
            ttft: self.first_token_at,
            max_new_tokens: self.max_new_tokens,
            prompt_len: self.prompt_len,
            verifier: self.verifier,
            failed: self.phase == SeqPhase::Failed,
            cancelled: if self.phase == SeqPhase::Cancelled {
                self.cancelled.or(Some(CancelCause::Explicit))
            } else {
                None
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_accounting() {
        let req = Request::new(7, vec![1, 2, 3], 10);
        let mut seq = SequenceState::from_request(&req);
        assert_eq!(seq.generated(), 0);
        assert_eq!(seq.remaining(), 10);
        assert!(!seq.is_done(100));
        seq.tokens.extend([4, 5, 6, 7]);
        seq.target_calls = 1;
        assert_eq!(seq.generated(), 4);
        assert_eq!(seq.remaining(), 6);
        assert!((seq.block_efficiency() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn done_via_max_new_or_max_len() {
        let req = Request::new(1, vec![0; 8], 4);
        let mut seq = SequenceState::from_request(&req);
        seq.tokens.extend([1, 1, 1, 1]);
        assert!(seq.is_done(1000));
        let req = Request::new(2, vec![0; 8], 100);
        let mut seq = SequenceState::from_request(&req);
        seq.tokens.extend([1, 1]);
        assert!(seq.is_done(10));
        assert!(!seq.is_done(64));
    }

    #[test]
    fn result_carries_block_efficiency() {
        let req = Request::new(3, vec![9], 5);
        let mut seq = SequenceState::from_request(&req);
        seq.tokens.extend([1, 2, 3, 4, 5]);
        seq.target_calls = 2;
        let res = seq.into_result();
        assert!((res.block_efficiency - 2.5).abs() < 1e-12);
        assert_eq!(res.tokens.len(), 6);
        assert!(res.ok());
    }

    #[test]
    fn cancel_token_is_shared_and_monotone() {
        let req = Request::new(4, vec![1], 8);
        let handle = req.cancel_handle();
        let seq = SequenceState::from_request(&req);
        assert_eq!(seq.cut_now(), None);
        handle.cancel();
        assert_eq!(seq.cut_now(), Some(CancelCause::Explicit));
        // Idempotent: a second cancel changes nothing.
        handle.cancel();
        assert_eq!(seq.cut_now(), Some(CancelCause::Explicit));
    }

    #[test]
    fn zero_deadline_expires_immediately() {
        let req = Request::new(5, vec![1, 2], 8).with_deadline(Duration::ZERO);
        let seq = SequenceState::from_request(&req);
        assert_eq!(seq.cut_now(), Some(CancelCause::DeadlineExpired));
        // A generous deadline does not trip.
        let req = Request::new(6, vec![1, 2], 8).with_deadline(Duration::from_secs(3600));
        let seq = SequenceState::from_request(&req);
        assert_eq!(seq.cut_now(), None);
    }

    #[test]
    fn explicit_cancel_wins_over_expired_deadline() {
        let req = Request::new(7, vec![1], 8).with_deadline(Duration::ZERO);
        req.cancel.cancel();
        let seq = SequenceState::from_request(&req);
        assert_eq!(seq.cut_now(), Some(CancelCause::Explicit));
    }

    #[test]
    fn cancelled_phase_maps_into_result() {
        let req = Request::new(8, vec![1, 2, 3], 8);
        let mut seq = SequenceState::from_request(&req);
        seq.tokens.push(42);
        seq.phase = SeqPhase::Cancelled;
        seq.cancelled = Some(CancelCause::DeadlineExpired);
        let res = seq.into_result();
        assert!(!res.failed);
        assert_eq!(res.cancelled, Some(CancelCause::DeadlineExpired));
        assert!(!res.ok());
        // Partial output survives the cut.
        assert_eq!(res.tokens.len(), 4);
    }
}
