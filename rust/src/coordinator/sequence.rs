//! Request and sequence state tracked by the scheduler/engine.

use std::time::{Duration, Instant};

use crate::spec::types::VerifierKind;

/// An inference request as submitted by a client.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Per-request randomness lane; the engine splits the shared root key
    /// with this so concurrent requests have independent coupling streams.
    pub rng_lane: u64,
    /// Per-request verification-scheme override; `None` uses the engine's
    /// configured verifier. This is how mixed-verifier traces run through
    /// one engine (drafting stays batch-wide; kinds that consume fewer
    /// lanes ignore the extras bit-exactly) and how the workload drills
    /// arm `VerifierKind::FaultInjection` on exactly the scripted
    /// requests.
    pub verifier: Option<VerifierKind>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        Self { id, prompt, max_new_tokens, rng_lane: id, verifier: None }
    }

    /// Builder-style verifier override (`None` = engine default).
    pub fn with_verifier(mut self, verifier: Option<VerifierKind>) -> Self {
        self.verifier = verifier;
        self
    }
}

/// Completed request with per-request accounting.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Target-model calls consumed (blocks executed).
    pub target_calls: usize,
    /// Draft-model steps consumed (block_len per block).
    pub draft_steps: usize,
    /// Tokens produced per target call — the paper's block efficiency.
    pub block_efficiency: f64,
    /// Wall-clock latency from submission to completion.
    pub latency: std::time::Duration,
    /// Wall-clock time from submission to the first generated token
    /// (`None` if the sequence produced nothing before retiring).
    pub ttft: Option<Duration>,
    /// The request's declared generation budget. Together with
    /// `prompt_len` and `verifier` this reconstructs the exact
    /// `routing_cost` the router charged the worker's load counter at
    /// submission, so completion credits the identical amount back
    /// (the `LeastLoaded` signal is additive).
    pub max_new_tokens: usize,
    /// Prompt length of the originating request (for routing-cost credit
    /// and per-token goodput accounting).
    pub prompt_len: usize,
    /// The request's verifier override, echoed back for routing-cost
    /// credit symmetry.
    pub verifier: Option<VerifierKind>,
    /// The sequence failed mid-decode (a verification fault): `tokens`
    /// holds whatever was emitted before the failure. A failed request
    /// never takes down its worker — it is retired like any completion.
    pub failed: bool,
}

/// Lifecycle of a sequence inside one worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    /// Waiting for KV admission.
    Queued,
    /// Admitted, decoding blocks.
    Running,
    /// Hit max_new_tokens or max_seq_len.
    Finished,
    /// A verification fault (panicking verify job) killed this sequence;
    /// the scheduler retires it with `RequestResult::failed = true`
    /// instead of letting it wedge the engine.
    Failed,
}

/// Scheduler-side state of an in-flight sequence.
#[derive(Clone, Debug)]
pub struct SequenceState {
    pub id: u64,
    /// Prompt followed by generated tokens.
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub phase: SeqPhase,
    pub rng_lane: u64,
    /// Next shared-randomness slot (absolute decode position).
    pub next_slot: u64,
    pub target_calls: usize,
    pub draft_steps: usize,
    pub submitted_at: Instant,
    /// Per-sequence verifier override carried from the request.
    pub verifier: Option<VerifierKind>,
    /// Stamped by the engine when the first generated token lands.
    pub first_token_at: Option<Duration>,
}

impl SequenceState {
    pub fn from_request(req: &Request) -> Self {
        Self {
            id: req.id,
            tokens: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new_tokens: req.max_new_tokens,
            phase: SeqPhase::Queued,
            rng_lane: req.rng_lane,
            next_slot: 0,
            target_calls: 0,
            draft_steps: 0,
            submitted_at: Instant::now(),
            verifier: req.verifier,
            first_token_at: None,
        }
    }

    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    pub fn remaining(&self) -> usize {
        self.max_new_tokens.saturating_sub(self.generated())
    }

    pub fn is_done(&self, max_seq_len: usize) -> bool {
        self.remaining() == 0 || self.tokens.len() >= max_seq_len
    }

    pub fn block_efficiency(&self) -> f64 {
        if self.target_calls == 0 {
            0.0
        } else {
            self.generated() as f64 / self.target_calls as f64
        }
    }

    pub fn into_result(self) -> RequestResult {
        let be = self.block_efficiency();
        RequestResult {
            id: self.id,
            tokens: self.tokens,
            target_calls: self.target_calls,
            draft_steps: self.draft_steps,
            block_efficiency: be,
            latency: self.submitted_at.elapsed(),
            ttft: self.first_token_at,
            max_new_tokens: self.max_new_tokens,
            prompt_len: self.prompt_len,
            verifier: self.verifier,
            failed: self.phase == SeqPhase::Failed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_accounting() {
        let req = Request::new(7, vec![1, 2, 3], 10);
        let mut seq = SequenceState::from_request(&req);
        assert_eq!(seq.generated(), 0);
        assert_eq!(seq.remaining(), 10);
        assert!(!seq.is_done(100));
        seq.tokens.extend([4, 5, 6, 7]);
        seq.target_calls = 1;
        assert_eq!(seq.generated(), 4);
        assert_eq!(seq.remaining(), 6);
        assert!((seq.block_efficiency() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn done_via_max_new_or_max_len() {
        let req = Request::new(1, vec![0; 8], 4);
        let mut seq = SequenceState::from_request(&req);
        seq.tokens.extend([1, 1, 1, 1]);
        assert!(seq.is_done(1000));
        let req = Request::new(2, vec![0; 8], 100);
        let mut seq = SequenceState::from_request(&req);
        seq.tokens.extend([1, 1]);
        assert!(seq.is_done(10));
        assert!(!seq.is_done(64));
    }

    #[test]
    fn result_carries_block_efficiency() {
        let req = Request::new(3, vec![9], 5);
        let mut seq = SequenceState::from_request(&req);
        seq.tokens.extend([1, 2, 3, 4, 5]);
        seq.target_calls = 2;
        let res = seq.into_result();
        assert!((res.block_efficiency - 2.5).abs() < 1e-12);
        assert_eq!(res.tokens.len(), 6);
    }
}
