//! Paged KV-cache manager (vLLM-style).
//!
//! Tracks token-granular cache occupancy in fixed-size pages with
//! per-sequence page tables. Speculative decoding adds one twist over
//! vanilla paged attention: a block speculatively extends a sequence by up
//! to L+1 tokens, and on partial acceptance the tail must be **rolled
//! back** — pages allocated for rejected positions are returned to the free
//! list. The engine drives exactly that cycle:
//!
//! ```text
//! reserve_block(seq, L+1) → verify → commit(seq, accepted+1) / rollback
//! ```
//!
//! The manager is also the admission-control authority: the scheduler only
//! admits a queued sequence when `can_admit` says its prompt plus one full
//! speculative block fits.

use std::collections::HashMap;

#[derive(Debug, PartialEq, Eq)]
pub enum KvError {
    OutOfPages { requested: usize, free: usize },
    UnknownSequence(u64),
    DuplicateSequence(u64),
    CommitTooLong { commit: usize, reserved: usize },
    /// `reserve_block` called while a reservation was already in flight
    /// (the engine must commit or rollback first). Formerly a
    /// `debug_assert` that vanished in release builds, letting an
    /// unbalanced reserve/commit cycle silently corrupt page accounting.
    UnbalancedReserve { seq_id: u64, reserved: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfPages { requested, free } => {
                write!(f, "out of KV pages (requested {requested}, free {free})")
            }
            KvError::UnknownSequence(id) => write!(f, "unknown sequence {id}"),
            KvError::DuplicateSequence(id) => write!(f, "sequence {id} already registered"),
            KvError::CommitTooLong { commit, reserved } => {
                write!(f, "commit length {commit} exceeds reservation {reserved}")
            }
            KvError::UnbalancedReserve { seq_id, reserved } => {
                write!(
                    f,
                    "sequence {seq_id} already holds a {reserved}-token reservation \
                     (commit or rollback before reserving again)"
                )
            }
        }
    }
}

impl std::error::Error for KvError {}

#[derive(Clone, Debug)]
struct SeqEntry {
    /// Committed token count (prompt + accepted generation).
    committed: usize,
    /// Reserved-but-uncommitted tokens (in-flight speculative block).
    reserved: usize,
    /// Allocated page ids (covers committed + reserved).
    pages: Vec<usize>,
    /// Worst-case page budget promised at admission. The admission
    /// controller sums budgets, not current usage, so a batch of admitted
    /// sequences can always grow to completion without deadlocking on
    /// pages mid-flight.
    budget_pages: usize,
}

/// Paged KV-cache accounting.
#[derive(Debug)]
pub struct PagedKvCache {
    page_size: usize,
    free: Vec<usize>,
    seqs: HashMap<u64, SeqEntry>,
    total_pages: usize,
    /// Sum of live sequences' budget pages (admission-control ledger).
    budgeted_pages: usize,
    /// High-water mark for reporting.
    peak_used: usize,
}

impl PagedKvCache {
    pub fn new(total_pages: usize, page_size: usize) -> Self {
        assert!(total_pages > 0 && page_size > 0);
        Self {
            page_size,
            free: (0..total_pages).rev().collect(),
            seqs: HashMap::new(),
            total_pages,
            budgeted_pages: 0,
            peak_used: 0,
        }
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free.len()
    }

    pub fn peak_used(&self) -> usize {
        self.peak_used
    }

    pub fn utilization(&self) -> f64 {
        self.used_pages() as f64 / self.total_pages as f64
    }

    pub fn num_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Worst-case page budget for a sequence: its lifetime committed
    /// length (`max_tokens`, floored at `prompt_len` — a prompt longer
    /// than the declared cap still occupies its pages) plus one in-flight
    /// speculative block. The **single** formula both [`Self::can_admit`]
    /// and [`Self::register`] use: they previously disagreed
    /// (`can_admit` ignored `prompt_len`), so a prompt longer than
    /// `max_tokens` could pass admission and then fail — or over-debit —
    /// at registration.
    fn budget_pages(&self, prompt_len: usize, max_tokens: usize, block: usize) -> usize {
        self.pages_for(max_tokens.max(prompt_len) + block)
    }

    /// Whether a new sequence (prompt `prompt_len`, lifetime worst case
    /// `max_tokens` committed, one in-flight block of `block` tokens) can
    /// be admitted *and* guaranteed to run to completion: checks the
    /// budget ledger, not instantaneous free pages. Admission granted here
    /// is binding — [`Self::register`] debits the identical
    /// [`Self::budget_pages`] figure, so it cannot fail after a true
    /// `can_admit`.
    pub fn can_admit(&self, prompt_len: usize, max_tokens: usize, block: usize) -> bool {
        let budget = self.budget_pages(prompt_len, max_tokens, block);
        self.budgeted_pages + budget <= self.total_pages
    }

    /// Register a sequence: allocate pages for the prompt and debit its
    /// worst-case budget (`max_tokens` committed + `block` in flight) —
    /// the same [`Self::budget_pages`] formula admission checked.
    pub fn register(
        &mut self,
        seq_id: u64,
        prompt_len: usize,
        max_tokens: usize,
        block: usize,
    ) -> Result<(), KvError> {
        if self.seqs.contains_key(&seq_id) {
            return Err(KvError::DuplicateSequence(seq_id));
        }
        let budget_pages = self.budget_pages(prompt_len, max_tokens, block);
        if self.budgeted_pages + budget_pages > self.total_pages {
            return Err(KvError::OutOfPages {
                requested: budget_pages,
                free: self.total_pages - self.budgeted_pages,
            });
        }
        let need = self.pages_for(prompt_len);
        debug_assert!(need <= self.free.len(), "budget ledger must guarantee pages");
        let pages: Vec<usize> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.seqs
            .insert(seq_id, SeqEntry { committed: prompt_len, reserved: 0, pages, budget_pages });
        self.budgeted_pages += budget_pages;
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(())
    }

    /// Reserve page capacity for an in-flight speculative block of
    /// `tokens` positions (typically L+1). Idempotent per block: the engine
    /// must commit or rollback before reserving again.
    pub fn reserve_block(&mut self, seq_id: u64, tokens: usize) -> Result<(), KvError> {
        let entry = self.seqs.get(&seq_id).ok_or(KvError::UnknownSequence(seq_id))?;
        if entry.reserved != 0 {
            // A real error, not a debug_assert: in release builds the
            // assert vanished and a double reserve silently corrupted the
            // page accounting (reserved overwritten, pages double-counted
            // against the budget).
            return Err(KvError::UnbalancedReserve { seq_id, reserved: entry.reserved });
        }
        let have = entry.pages.len();
        let need_total = self.pages_for(entry.committed + tokens);
        // Budget enforcement: a sequence may never outgrow what admission
        // promised — this is what makes `reserve_block` infallible for
        // well-behaved engines even under full KV pressure.
        if need_total > entry.budget_pages {
            return Err(KvError::OutOfPages {
                requested: need_total - entry.budget_pages,
                free: 0,
            });
        }
        let need_extra = need_total.saturating_sub(have);
        if need_extra > self.free.len() {
            return Err(KvError::OutOfPages { requested: need_extra, free: self.free.len() });
        }
        let new_pages: Vec<usize> = (0..need_extra).map(|_| self.free.pop().unwrap()).collect();
        let entry = self.seqs.get_mut(&seq_id).unwrap();
        entry.pages.extend(new_pages);
        entry.reserved = tokens;
        self.peak_used = self.peak_used.max(self.used_pages());
        Ok(())
    }

    /// Commit `accepted` of the reserved positions (accepted prefix + the
    /// emitted final token) and release pages beyond the new committed
    /// length — the speculative rollback.
    pub fn commit(&mut self, seq_id: u64, accepted: usize) -> Result<(), KvError> {
        let entry = self.seqs.get_mut(&seq_id).ok_or(KvError::UnknownSequence(seq_id))?;
        if accepted > entry.reserved {
            return Err(KvError::CommitTooLong { commit: accepted, reserved: entry.reserved });
        }
        entry.committed += accepted;
        entry.reserved = 0;
        let keep = entry.committed.div_ceil(self.page_size);
        while entry.pages.len() > keep {
            self.free.push(entry.pages.pop().unwrap());
        }
        Ok(())
    }

    /// Free everything held by a finished sequence (pages + budget).
    pub fn release(&mut self, seq_id: u64) -> Result<(), KvError> {
        let entry = self.seqs.remove(&seq_id).ok_or(KvError::UnknownSequence(seq_id))?;
        self.free.extend(entry.pages);
        self.budgeted_pages -= entry.budget_pages;
        Ok(())
    }

    /// Committed token count of a sequence (for invariant checks).
    pub fn committed_tokens(&self, seq_id: u64) -> Option<usize> {
        self.seqs.get(&seq_id).map(|e| e.committed)
    }

    /// Internal consistency: every page is either free or owned by exactly
    /// one sequence. Used by the property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.total_pages];
        for &p in &self.free {
            if seen[p] {
                return Err(format!("page {p} double-booked (free list)"));
            }
            seen[p] = true;
        }
        let mut budget_sum = 0;
        for (id, e) in &self.seqs {
            budget_sum += e.budget_pages;
            if e.pages.len() > e.budget_pages {
                return Err(format!(
                    "seq {id}: {} pages exceed budget {}",
                    e.pages.len(),
                    e.budget_pages
                ));
            }
            let min_pages = e.committed.div_ceil(self.page_size);
            let max_pages = (e.committed + e.reserved).div_ceil(self.page_size);
            if e.pages.len() < min_pages || e.pages.len() > max_pages.max(min_pages) {
                return Err(format!(
                    "seq {id}: {} pages for {} committed + {} reserved",
                    e.pages.len(),
                    e.committed,
                    e.reserved
                ));
            }
            for &p in &e.pages {
                if seen[p] {
                    return Err(format!("page {p} double-booked (seq {id})"));
                }
                seen[p] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked pages (neither free nor owned)".into());
        }
        if budget_sum != self.budgeted_pages {
            return Err(format!(
                "budget ledger {} != sum of budgets {budget_sum}",
                self.budgeted_pages
            ));
        }
        if budget_sum > self.total_pages {
            return Err("over-committed budget ledger".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_reserve_commit_cycle() {
        let mut kv = PagedKvCache::new(10, 4);
        kv.register(1, 6, 11, 5).unwrap(); // 2 pages now, 4-page budget
        assert_eq!(kv.used_pages(), 2);
        kv.reserve_block(1, 5).unwrap(); // 6+5=11 tokens → 3 pages
        assert_eq!(kv.used_pages(), 3);
        kv.commit(1, 2).unwrap(); // 8 tokens → 2 pages, 1 released
        assert_eq!(kv.used_pages(), 2);
        assert_eq!(kv.committed_tokens(1), Some(8));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn rollback_frees_speculative_pages() {
        let mut kv = PagedKvCache::new(10, 4);
        kv.register(1, 4, 4, 8).unwrap(); // 1 page now, 3-page budget
        kv.reserve_block(1, 8).unwrap(); // 12 tokens → 3 pages
        assert_eq!(kv.used_pages(), 3);
        kv.commit(1, 0).unwrap(); // full rejection: back to 1 page
        assert_eq!(kv.used_pages(), 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn out_of_pages_is_reported_not_panicked() {
        let mut kv = PagedKvCache::new(2, 4);
        kv.register(1, 8, 8, 0).unwrap(); // both pages
        let err = kv.register(2, 1, 1, 0).unwrap_err();
        assert!(matches!(err, KvError::OutOfPages { .. }));
        assert!(!kv.can_admit(1, 1, 1));
        kv.release(1).unwrap();
        assert!(kv.can_admit(1, 1, 1));
    }

    #[test]
    fn admission_and_registration_agree_when_prompt_exceeds_max_tokens() {
        // Regression: `can_admit` used to budget `pages_for(max_tokens +
        // block)` while `register` budgeted with the prompt floor, so a
        // prompt longer than `max_tokens` passed admission and then failed
        // (or over-debited) at registration. The shared formula makes a
        // true `can_admit` binding.
        let mut kv = PagedKvCache::new(4, 4); // 16-token capacity
        // prompt 10 > max_tokens 4: budget = pages_for(max(4, 10) + 5) = 4.
        assert!(kv.can_admit(10, 4, 5));
        kv.register(1, 10, 4, 5).expect("admission must be binding");
        kv.check_invariants().unwrap();
        // The ledger is now full: the old can_admit formula (prompt
        // ignored) would claim a second such sequence fits.
        assert!(!kv.can_admit(10, 4, 5));
        assert_eq!(kv.register(2, 10, 4, 5).unwrap_err(), KvError::OutOfPages { requested: 4, free: 0 });
        kv.release(1).unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn double_reserve_is_a_typed_error_not_corruption() {
        let mut kv = PagedKvCache::new(10, 4);
        kv.register(1, 6, 11, 5).unwrap();
        kv.reserve_block(1, 5).unwrap();
        let used = kv.used_pages();
        // Second reserve without an intervening commit/rollback: typed
        // error (previously a release-mode silent corruption), accounting
        // untouched.
        assert_eq!(
            kv.reserve_block(1, 5).unwrap_err(),
            KvError::UnbalancedReserve { seq_id: 1, reserved: 5 }
        );
        assert_eq!(kv.used_pages(), used, "failed reserve must not move pages");
        kv.check_invariants().unwrap();
        // The cycle still completes normally afterwards.
        kv.commit(1, 2).unwrap();
        kv.reserve_block(1, 5).unwrap();
        kv.commit(1, 0).unwrap();
        kv.check_invariants().unwrap();
        kv.release(1).unwrap();
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn duplicate_and_unknown_sequences_rejected() {
        let mut kv = PagedKvCache::new(4, 4);
        kv.register(1, 1, 1, 0).unwrap();
        assert_eq!(kv.register(1, 1, 1, 0).unwrap_err(), KvError::DuplicateSequence(1));
        assert_eq!(kv.commit(9, 0).unwrap_err(), KvError::UnknownSequence(9));
        assert_eq!(kv.release(9).unwrap_err(), KvError::UnknownSequence(9));
    }

    #[test]
    fn commit_longer_than_reservation_rejected() {
        let mut kv = PagedKvCache::new(4, 4);
        kv.register(1, 2, 2, 3).unwrap();
        kv.reserve_block(1, 3).unwrap();
        assert!(matches!(kv.commit(1, 4), Err(KvError::CommitTooLong { .. })));
    }

    #[test]
    fn release_returns_all_pages() {
        let mut kv = PagedKvCache::new(8, 2);
        kv.register(1, 5, 5, 0).unwrap();
        kv.register(2, 3, 3, 0).unwrap();
        kv.release(1).unwrap();
        kv.release(2).unwrap();
        assert_eq!(kv.free_pages(), 8);
        assert_eq!(kv.used_pages(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut kv = PagedKvCache::new(8, 2);
        kv.register(1, 8, 8, 0).unwrap(); // 4 pages
        kv.release(1).unwrap();
        kv.register(2, 2, 2, 0).unwrap(); // 1 page
        assert_eq!(kv.peak_used(), 4);
    }

    #[test]
    fn property_random_workload_preserves_invariants() {
        use crate::stats::rng::XorShift128;
        let mut rng = XorShift128::new(99);
        let mut kv = PagedKvCache::new(64, 4);
        let mut live: Vec<u64> = Vec::new();
        let mut reserved: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..2000 {
            match rng.next_below(4) {
                0 => {
                    let len = 1 + rng.next_below(20) as usize;
                    if kv.register(next_id, len, len, 6).is_ok() {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 => {
                    if let Some(&id) = live.iter().find(|id| !reserved.contains(id)) {
                        if kv.reserve_block(id, 1 + rng.next_below(6) as usize).is_ok() {
                            reserved.push(id);
                        }
                    }
                }
                2 => {
                    if let Some(pos) = reserved.pop() {
                        let commit = rng.next_below(3) as usize;
                        let _ = kv.commit(pos, commit.min(1));
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        let id = live.swap_remove(i);
                        if reserved.contains(&id) {
                            reserved.retain(|&r| r != id);
                        }
                        kv.release(id).unwrap();
                    }
                }
            }
            kv.check_invariants().unwrap();
        }
    }
}
