//! Continuous-batching scheduler: one per worker.
//!
//! Maintains a queue of admitted-but-waiting sequences and a running set.
//! Each iteration: (1) admit queued sequences while KV capacity and the
//! running-set cap allow, (2) run one speculative block for every running
//! sequence as a single engine batch, (3) retire finished sequences and
//! emit results. Admission order is FIFO — no starvation: a sequence that
//! cannot be admitted blocks later arrivals of the queue head position.

use std::collections::VecDeque;

use super::engine::SpecDecodeEngine;
use super::sequence::{CancelCause, Request, RequestResult, SeqPhase, SequenceState};

/// Consecutive no-progress ticks (work pending, nothing admitted, nothing
/// stepped, nothing retired) before the watchdog fails every remaining
/// sequence through the typed error path instead of spinning forever.
/// Generous: a healthy scheduler always either steps a batch (tokens
/// grow), admits, or retires on every tick, so any stall this long is a
/// genuine wedge (e.g. a request whose KV budget exceeds pages that were
/// reserved outside the scheduler's view).
const WATCHDOG_STALL_TICKS: u32 = 64;

pub struct Scheduler {
    pub max_running: usize,
    queued: VecDeque<SequenceState>,
    running: Vec<SequenceState>,
    /// Retire-pass scratch, swapped with `running` each tick so the
    /// steady-state scheduler loop allocates nothing (the engine's verify
    /// path is allocation-free too — see `coordinator::pool`).
    retire_scratch: Vec<SequenceState>,
    /// Consecutive ticks that made no progress while work was pending
    /// (the watchdog counter — see [`WATCHDOG_STALL_TICKS`]).
    stalled: u32,
}

impl Scheduler {
    pub fn new(max_running: usize) -> Self {
        assert!(max_running >= 1);
        Self {
            max_running,
            queued: VecDeque::new(),
            running: Vec::new(),
            retire_scratch: Vec::new(),
            stalled: 0,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queued.push_back(SequenceState::from_request(&req));
    }

    pub fn queued_len(&self) -> usize {
        self.queued.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.queued.is_empty() || !self.running.is_empty()
    }

    /// Total tokens queued+running. Observability only: the router's
    /// `LeastLoaded` signal is additive (charged at submission, credited
    /// at completion) and no longer reads this — a stored snapshot missed
    /// requests still queued in the worker's channel, which is exactly the
    /// staleness the additive signal fixed.
    pub fn load(&self) -> usize {
        self.queued.iter().map(|s| s.max_new_tokens).sum::<usize>()
            + self.running.iter().map(|s| s.remaining()).sum::<usize>()
    }

    /// Admit from the queue head while capacity allows (FIFO, head-of-line
    /// blocking by design — fairness over packing).
    fn admit(&mut self, engine: &mut SpecDecodeEngine) {
        let block = engine.cfg.block_len + 1;
        while self.running.len() < self.max_running {
            let Some(head) = self.queued.front() else { break };
            if head.tokens.len() + head.max_new_tokens + block > engine.cfg.max_seq_len {
                // Oversized request: reject by finishing immediately empty.
                let mut seq = self.queued.pop_front().unwrap();
                seq.phase = SeqPhase::Finished;
                self.running.push(seq);
                continue;
            }
            // Conservative admission: reserve headroom for the sequence's
            // full growth (prompt + budget + one in-flight block) so decode
            // can never dead-lock on KV mid-flight. Real deployments would
            // preempt instead; FIFO + worst-case admission keeps the engine
            // invariant (`reserve_block` never fails) simple and auditable.
            // `can_admit` and `register` now share one budget formula, so a
            // true answer here is binding even when prompt > max_tokens.
            if !engine.kv.can_admit(
                head.tokens.len(),
                head.tokens.len() + head.max_new_tokens,
                block,
            ) {
                // If nothing is running and the cache holds no sequences,
                // waiting cannot help: this request's worst-case budget
                // exceeds the entire cache, so it would block the queue
                // head forever. Fail it typed instead of wedging the loop.
                // (It is never registered — the retire pass probes
                // registration before releasing.)
                if self.running.is_empty() && engine.kv.num_sequences() == 0 {
                    let mut seq = self.queued.pop_front().unwrap();
                    seq.phase = SeqPhase::Failed;
                    self.running.push(seq);
                    continue;
                }
                break;
            }
            let mut seq = self.queued.pop_front().unwrap();
            engine
                .kv
                .register(seq.id, seq.tokens.len(), seq.tokens.len() + seq.max_new_tokens, block)
                .expect("can_admit checked");
            seq.phase = SeqPhase::Running;
            self.running.push(seq);
        }
    }

    /// Reap cut (cancelled / deadline-expired) sequences still waiting in
    /// the queue. They were never KV-registered, so they retire directly
    /// into results — no release, no rollback — before they can block the
    /// FIFO head or waste an admission slot.
    fn reap_queued(&mut self, engine: &mut SpecDecodeEngine, results: &mut Vec<RequestResult>) {
        let mut i = 0;
        while i < self.queued.len() {
            let Some(cause) = self.queued[i].cut_now() else {
                i += 1;
                continue;
            };
            let mut seq = self.queued.remove(i).expect("index in bounds");
            seq.phase = SeqPhase::Cancelled;
            seq.cancelled = Some(cause);
            // Running sequences get these counters bumped in the engine's
            // block epilogue; queued ones never reach the engine, so the
            // scheduler accounts for them here.
            match cause {
                CancelCause::Explicit => engine.metrics.cancelled += 1,
                CancelCause::DeadlineExpired => engine.metrics.timed_out += 1,
            }
            engine.metrics.completed += 1;
            engine.metrics.be.push(seq.block_efficiency());
            engine
                .metrics
                .latency
                .record(seq.submitted_at.elapsed().as_secs_f64());
            results.push(seq.into_result());
        }
    }

    /// Watchdog trip: fail every remaining sequence through the typed
    /// error path. Queued sequences join `running` so the next retire pass
    /// emits their results; none of the newly failed queued entries were
    /// KV-registered, and the retire pass probes registration before
    /// releasing, so the cache stays consistent.
    fn fail_all_pending(&mut self) {
        for mut seq in self.queued.drain(..) {
            seq.phase = SeqPhase::Failed;
            self.running.push(seq);
        }
        for seq in &mut self.running {
            if seq.phase == SeqPhase::Running {
                seq.phase = SeqPhase::Failed;
            }
        }
    }

    /// One scheduling iteration. Returns results of sequences that finished
    /// during this iteration.
    pub fn tick(&mut self, engine: &mut SpecDecodeEngine) -> Vec<RequestResult> {
        let mut results = Vec::new();
        self.reap_queued(engine, &mut results);
        let queued_before = self.queued.len();
        self.admit(engine);
        let admitted = self.queued.len() != queued_before;
        let max_len = engine.cfg.max_seq_len;

        // Run one block for every running (non-finished) sequence.
        let mut stepped = false;
        {
            let mut batch: Vec<&mut SequenceState> = self
                .running
                .iter_mut()
                .filter(|s| s.phase == SeqPhase::Running)
                .collect();
            if !batch.is_empty() {
                stepped = true;
                engine.step_blocks(&mut batch);
            }
        }

        // Retire. `keep` is the persistent scratch (capacity retained
        // across ticks), swapped back into `running` at the end.
        let mut keep = std::mem::take(&mut self.retire_scratch);
        keep.clear();
        for mut seq in self.running.drain(..) {
            let rejected = seq.phase == SeqPhase::Finished; // oversized
            // A verification fault (panicking verify job) retires the
            // sequence like a completion — with `RequestResult::failed`
            // set — rather than wedging the worker's pipeline. Cancelled
            // sequences retire the same way with `RequestResult::cancelled`
            // set (the engine already rolled their in-flight block back).
            let failed = seq.phase == SeqPhase::Failed;
            let cancelled = seq.phase == SeqPhase::Cancelled;
            if rejected || failed || cancelled || seq.is_done(max_len) {
                // Release only sequences the cache actually knows:
                // oversized rejects, impossible-admission failures, and
                // watchdog-failed queue entries were never registered.
                if engine.kv.committed_tokens(seq.id).is_some() {
                    engine.kv.release(seq.id).expect("release running seq");
                }
                if !failed && !cancelled {
                    seq.phase = SeqPhase::Finished;
                }
                engine.metrics.completed += 1;
                engine.metrics.be.push(seq.block_efficiency());
                engine
                    .metrics
                    .latency
                    .record(seq.submitted_at.elapsed().as_secs_f64());
                if let Some(t) = seq.first_token_at {
                    engine.metrics.ttft.record(t.as_secs_f64());
                }
                let gen = seq.generated();
                if gen > 0 {
                    engine
                        .metrics
                        .token_latency
                        .record(seq.submitted_at.elapsed().as_secs_f64() / gen as f64);
                }
                results.push(seq.into_result());
            } else {
                keep.push(seq);
            }
        }
        self.retire_scratch = std::mem::replace(&mut self.running, keep);

        // Stall watchdog: a healthy tick always retires, admits, or steps
        // (tokens grow every stepped block), so a long run of do-nothing
        // ticks with work still pending is a wedge — fail what's left
        // rather than spinning the worker thread forever.
        if !results.is_empty() || admitted || stepped || !self.has_work() {
            self.stalled = 0;
        } else {
            self.stalled += 1;
            if self.stalled >= WATCHDOG_STALL_TICKS {
                self.stalled = 0;
                self.fail_all_pending();
            }
        }
        results
    }

    /// Drive to completion (used by tests and offline benches).
    pub fn run_to_completion(&mut self, engine: &mut SpecDecodeEngine) -> Vec<RequestResult> {
        let mut all = Vec::new();
        while self.has_work() {
            all.extend(self.tick(engine));
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::EngineConfig;
    use crate::coordinator::kv::PagedKvCache;
    use crate::model::backend::ModelPair;
    use crate::model::sim::SimLm;
    use crate::spec::types::VerifierKind;

    fn engine_with_kv(pages: usize) -> SpecDecodeEngine {
        let (draft, target) = SimLm::pair(32, 5, 1.5);
        let cfg = EngineConfig {
            verifier: VerifierKind::Gls,
            num_drafts: 2,
            block_len: 4,
            max_seq_len: 128,
            ..EngineConfig::default()
        };
        SpecDecodeEngine::new(
            cfg,
            ModelPair::new(Box::new(draft), Box::new(target)),
            PagedKvCache::new(pages, 16),
        )
    }

    #[test]
    fn completes_all_requests() {
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(4);
        for i in 0..10 {
            sched.submit(Request::new(i, vec![1, 2, 3], 12));
        }
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 10);
        for r in &results {
            assert_eq!(r.tokens.len() - 3, 12);
        }
        assert_eq!(eng.kv.used_pages(), 0);
    }

    #[test]
    fn respects_max_running() {
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(2);
        for i in 0..6 {
            sched.submit(Request::new(i, vec![1], 8));
        }
        sched.tick(&mut eng);
        assert!(sched.running_len() <= 2);
        assert_eq!(sched.queued_len(), 4);
        sched.run_to_completion(&mut eng);
    }

    #[test]
    fn kv_pressure_defers_admission_without_loss() {
        // Tiny KV: only ~2 sequences fit at once; everything still finishes.
        let mut eng = engine_with_kv(8);
        let mut sched = Scheduler::new(16);
        for i in 0..6 {
            sched.submit(Request::new(i, vec![1, 2, 3, 4], 16));
        }
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 6);
        assert_eq!(eng.kv.used_pages(), 0);
        assert!(eng.kv.peak_used() <= 8);
    }

    #[test]
    fn long_prompt_short_budget_admission_is_binding() {
        // Regression for the can_admit/register budget mismatch: requests
        // whose prompts dwarf their generation budgets, driven through the
        // scheduler's admission path on a KV sized so the budget formula
        // decides everything. Admission and registration share one formula
        // now, so `register` can never fail after `can_admit`, and the
        // tight cache forces the second request to wait for the first.
        let mut eng = engine_with_kv(4); // 64 tokens of KV, page 16
        let mut sched = Scheduler::new(8);
        for i in 0..2 {
            // prompt 40 ≫ max_new 4: budget = pages(max(44, 40) + 5) = 4
            // pages — exactly the whole cache, one sequence at a time.
            sched.submit(Request::new(i, vec![0; 40], 4));
        }
        sched.tick(&mut eng);
        assert_eq!(sched.running_len(), 1, "tight budget must serialize admission");
        eng.kv.check_invariants().unwrap();
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 2);
        for r in &results {
            assert!(!r.failed);
            assert_eq!(r.tokens.len(), 44, "request {}", r.id);
        }
        assert_eq!(eng.kv.used_pages(), 0);
        eng.kv.check_invariants().unwrap();
    }

    #[test]
    fn oversized_request_rejected_cleanly() {
        let mut eng = engine_with_kv(64);
        let mut sched = Scheduler::new(4);
        sched.submit(Request::new(1, vec![0; 100], 100)); // > max_seq_len 128
        sched.submit(Request::new(2, vec![1], 8));
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 2);
        let r1 = results.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(r1.tokens.len(), 100, "oversized request returns prompt only");
        let r2 = results.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(r2.tokens.len(), 9);
    }

    #[test]
    fn load_reflects_outstanding_tokens() {
        let mut sched = Scheduler::new(4);
        assert_eq!(sched.load(), 0);
        sched.submit(Request::new(1, vec![0], 25));
        sched.submit(Request::new(2, vec![0], 10));
        assert_eq!(sched.load(), 35);
    }

    #[test]
    fn ttft_and_token_latency_accounting() {
        // Every retired generating sequence records exactly one TTFT and
        // one per-token latency sample; counters are monotone across
        // batches, TTFT never exceeds total latency, and quantiles are
        // monotone in q.
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(8);
        for i in 0..5 {
            sched.submit(Request::new(i, vec![1, 2], 10));
        }
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 5);
        assert_eq!(eng.metrics.ttft.count(), 5);
        assert_eq!(eng.metrics.token_latency.count(), 5);
        for r in &results {
            let ttft = r.ttft.expect("generating sequence must stamp TTFT");
            assert!(ttft <= r.latency, "request {}: TTFT {ttft:?} > latency {:?}", r.id, r.latency);
        }
        assert!(eng.metrics.ttft.quantile(0.99) >= eng.metrics.ttft.quantile(0.5));
        assert!(
            eng.metrics.token_latency.quantile(0.99) >= eng.metrics.token_latency.quantile(0.5)
        );
        // Monotone counters: one more request, counts advance by one.
        let mut sched2 = Scheduler::new(8);
        sched2.submit(Request::new(10, vec![3], 6));
        sched2.run_to_completion(&mut eng);
        assert_eq!(eng.metrics.ttft.count(), 6);
        assert_eq!(eng.metrics.token_latency.count(), 6);
    }

    #[test]
    fn impossible_request_fails_typed_instead_of_hanging() {
        // Regression: 2 pages × 16 = 32 tokens of KV. The request's
        // worst-case budget is pages(4 + 40 + 5) = 4 pages > 2 total, yet
        // 49 < max_seq_len = 128 so the oversized check passes — the old
        // scheduler spun forever waiting for pages that cannot exist.
        let mut eng = engine_with_kv(2);
        let mut sched = Scheduler::new(4);
        sched.submit(Request::new(7, vec![0; 4], 40));
        sched.submit(Request::new(8, vec![0; 4], 8)); // feasible, behind it
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 2);
        let r7 = results.iter().find(|r| r.id == 7).unwrap();
        assert!(r7.failed, "impossible budget must fail typed");
        assert_eq!(r7.tokens.len(), 4, "prompt only, nothing generated");
        let r8 = results.iter().find(|r| r.id == 8).unwrap();
        assert!(r8.ok(), "feasible request behind the wedge still completes");
        assert_eq!(r8.tokens.len(), 12);
        assert_eq!(eng.kv.used_pages(), 0);
        eng.kv.check_invariants().unwrap();
    }

    #[test]
    fn stall_watchdog_fails_stranded_work() {
        // Occupy the cache behind the scheduler's back so the queue head
        // can never admit while the cache is NOT empty: the instant
        // impossible-admission check cannot fire, and only the tick-level
        // watchdog can unwedge the loop.
        let mut eng = engine_with_kv(4);
        let block = eng.cfg.block_len + 1;
        eng.kv.register(999, 16, 48, block).unwrap(); // hogs all 4 pages
        let mut sched = Scheduler::new(4);
        sched.submit(Request::new(1, vec![0; 8], 16));
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 1);
        assert!(results[0].failed, "watchdog must fail stranded work typed");
        assert_eq!(results[0].tokens.len(), 8);
        eng.kv.release(999).unwrap();
        assert_eq!(eng.kv.used_pages(), 0);
        eng.kv.check_invariants().unwrap();
    }

    #[test]
    fn cancelled_queued_request_is_reaped_without_kv_registration() {
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(1);
        sched.submit(Request::new(1, vec![1, 2], 8));
        let req = Request::new(2, vec![3, 4], 8);
        let handle = req.cancel_handle();
        sched.submit(req);
        handle.cancel();
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 2);
        let r2 = results.iter().find(|r| r.id == 2).unwrap();
        assert!(!r2.ok());
        assert!(!r2.failed, "cancellation is not a failure");
        assert_eq!(r2.cancelled, Some(CancelCause::Explicit));
        assert_eq!(r2.tokens.len(), 2, "prompt only");
        assert!(results.iter().find(|r| r.id == 1).unwrap().ok());
        assert_eq!(eng.metrics.cancelled, 1);
        assert_eq!(eng.kv.used_pages(), 0);
    }

    #[test]
    fn expired_deadline_in_queue_times_out_typed() {
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(4);
        sched.submit(
            Request::new(5, vec![1], 6).with_deadline(std::time::Duration::ZERO),
        );
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].cancelled, Some(CancelCause::DeadlineExpired));
        assert_eq!(results[0].tokens.len(), 1);
        assert_eq!(eng.metrics.timed_out, 1);
        assert_eq!(eng.metrics.completed, 1);
        assert_eq!(eng.kv.used_pages(), 0);
    }

    #[test]
    fn fifo_admission_order() {
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(1);
        sched.submit(Request::new(10, vec![1], 4));
        sched.submit(Request::new(11, vec![1], 4));
        let first = loop {
            let r = sched.tick(&mut eng);
            if !r.is_empty() {
                break r;
            }
        };
        assert_eq!(first[0].id, 10, "queue must be FIFO");
        sched.run_to_completion(&mut eng);
    }
}
