//! Continuous-batching scheduler: one per worker.
//!
//! Maintains a queue of admitted-but-waiting sequences and a running set.
//! Each iteration: (1) admit queued sequences while KV capacity and the
//! running-set cap allow, (2) run one speculative block for every running
//! sequence as a single engine batch, (3) retire finished sequences and
//! emit results. Admission order is FIFO — no starvation: a sequence that
//! cannot be admitted blocks later arrivals of the queue head position.

use std::collections::VecDeque;

use super::engine::SpecDecodeEngine;
use super::sequence::{Request, RequestResult, SeqPhase, SequenceState};

pub struct Scheduler {
    pub max_running: usize,
    queued: VecDeque<SequenceState>,
    running: Vec<SequenceState>,
    /// Retire-pass scratch, swapped with `running` each tick so the
    /// steady-state scheduler loop allocates nothing (the engine's verify
    /// path is allocation-free too — see `coordinator::pool`).
    retire_scratch: Vec<SequenceState>,
}

impl Scheduler {
    pub fn new(max_running: usize) -> Self {
        assert!(max_running >= 1);
        Self {
            max_running,
            queued: VecDeque::new(),
            running: Vec::new(),
            retire_scratch: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queued.push_back(SequenceState::from_request(&req));
    }

    pub fn queued_len(&self) -> usize {
        self.queued.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.queued.is_empty() || !self.running.is_empty()
    }

    /// Total tokens queued+running. Observability only: the router's
    /// `LeastLoaded` signal is additive (charged at submission, credited
    /// at completion) and no longer reads this — a stored snapshot missed
    /// requests still queued in the worker's channel, which is exactly the
    /// staleness the additive signal fixed.
    pub fn load(&self) -> usize {
        self.queued.iter().map(|s| s.max_new_tokens).sum::<usize>()
            + self.running.iter().map(|s| s.remaining()).sum::<usize>()
    }

    /// Admit from the queue head while capacity allows (FIFO, head-of-line
    /// blocking by design — fairness over packing).
    fn admit(&mut self, engine: &mut SpecDecodeEngine) {
        let block = engine.cfg.block_len + 1;
        while self.running.len() < self.max_running {
            let Some(head) = self.queued.front() else { break };
            if head.tokens.len() + head.max_new_tokens + block > engine.cfg.max_seq_len {
                // Oversized request: reject by finishing immediately empty.
                let mut seq = self.queued.pop_front().unwrap();
                seq.phase = SeqPhase::Finished;
                self.running.push(seq);
                continue;
            }
            // Conservative admission: reserve headroom for the sequence's
            // full growth (prompt + budget + one in-flight block) so decode
            // can never dead-lock on KV mid-flight. Real deployments would
            // preempt instead; FIFO + worst-case admission keeps the engine
            // invariant (`reserve_block` never fails) simple and auditable.
            // `can_admit` and `register` now share one budget formula, so a
            // true answer here is binding even when prompt > max_tokens.
            if !engine.kv.can_admit(
                head.tokens.len(),
                head.tokens.len() + head.max_new_tokens,
                block,
            ) {
                break;
            }
            let mut seq = self.queued.pop_front().unwrap();
            engine
                .kv
                .register(seq.id, seq.tokens.len(), seq.tokens.len() + seq.max_new_tokens, block)
                .expect("can_admit checked");
            seq.phase = SeqPhase::Running;
            self.running.push(seq);
        }
    }

    /// One scheduling iteration. Returns results of sequences that finished
    /// during this iteration.
    pub fn tick(&mut self, engine: &mut SpecDecodeEngine) -> Vec<RequestResult> {
        self.admit(engine);
        let max_len = engine.cfg.max_seq_len;

        // Run one block for every running (non-finished) sequence.
        {
            let mut batch: Vec<&mut SequenceState> = self
                .running
                .iter_mut()
                .filter(|s| s.phase == SeqPhase::Running)
                .collect();
            if !batch.is_empty() {
                engine.step_blocks(&mut batch);
            }
        }

        // Retire. `keep` is the persistent scratch (capacity retained
        // across ticks), swapped back into `running` at the end.
        let mut results = Vec::new();
        let mut keep = std::mem::take(&mut self.retire_scratch);
        keep.clear();
        for mut seq in self.running.drain(..) {
            let rejected = seq.phase == SeqPhase::Finished; // oversized
            // A verification fault (panicking verify job) retires the
            // sequence like a completion — with `RequestResult::failed`
            // set — rather than wedging the worker's pipeline.
            let failed = seq.phase == SeqPhase::Failed;
            if rejected || failed || seq.is_done(max_len) {
                if !rejected {
                    engine.kv.release(seq.id).expect("release running seq");
                }
                if !failed {
                    seq.phase = SeqPhase::Finished;
                }
                engine.metrics.completed += 1;
                engine.metrics.be.push(seq.block_efficiency());
                engine
                    .metrics
                    .latency
                    .record(seq.submitted_at.elapsed().as_secs_f64());
                if let Some(t) = seq.first_token_at {
                    engine.metrics.ttft.record(t.as_secs_f64());
                }
                let gen = seq.generated();
                if gen > 0 {
                    engine
                        .metrics
                        .token_latency
                        .record(seq.submitted_at.elapsed().as_secs_f64() / gen as f64);
                }
                results.push(seq.into_result());
            } else {
                keep.push(seq);
            }
        }
        self.retire_scratch = std::mem::replace(&mut self.running, keep);
        results
    }

    /// Drive to completion (used by tests and offline benches).
    pub fn run_to_completion(&mut self, engine: &mut SpecDecodeEngine) -> Vec<RequestResult> {
        let mut all = Vec::new();
        while self.has_work() {
            all.extend(self.tick(engine));
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::EngineConfig;
    use crate::coordinator::kv::PagedKvCache;
    use crate::model::backend::ModelPair;
    use crate::model::sim::SimLm;
    use crate::spec::types::VerifierKind;

    fn engine_with_kv(pages: usize) -> SpecDecodeEngine {
        let (draft, target) = SimLm::pair(32, 5, 1.5);
        let cfg = EngineConfig {
            verifier: VerifierKind::Gls,
            num_drafts: 2,
            block_len: 4,
            max_seq_len: 128,
            ..EngineConfig::default()
        };
        SpecDecodeEngine::new(
            cfg,
            ModelPair::new(Box::new(draft), Box::new(target)),
            PagedKvCache::new(pages, 16),
        )
    }

    #[test]
    fn completes_all_requests() {
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(4);
        for i in 0..10 {
            sched.submit(Request::new(i, vec![1, 2, 3], 12));
        }
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 10);
        for r in &results {
            assert_eq!(r.tokens.len() - 3, 12);
        }
        assert_eq!(eng.kv.used_pages(), 0);
    }

    #[test]
    fn respects_max_running() {
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(2);
        for i in 0..6 {
            sched.submit(Request::new(i, vec![1], 8));
        }
        sched.tick(&mut eng);
        assert!(sched.running_len() <= 2);
        assert_eq!(sched.queued_len(), 4);
        sched.run_to_completion(&mut eng);
    }

    #[test]
    fn kv_pressure_defers_admission_without_loss() {
        // Tiny KV: only ~2 sequences fit at once; everything still finishes.
        let mut eng = engine_with_kv(8);
        let mut sched = Scheduler::new(16);
        for i in 0..6 {
            sched.submit(Request::new(i, vec![1, 2, 3, 4], 16));
        }
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 6);
        assert_eq!(eng.kv.used_pages(), 0);
        assert!(eng.kv.peak_used() <= 8);
    }

    #[test]
    fn long_prompt_short_budget_admission_is_binding() {
        // Regression for the can_admit/register budget mismatch: requests
        // whose prompts dwarf their generation budgets, driven through the
        // scheduler's admission path on a KV sized so the budget formula
        // decides everything. Admission and registration share one formula
        // now, so `register` can never fail after `can_admit`, and the
        // tight cache forces the second request to wait for the first.
        let mut eng = engine_with_kv(4); // 64 tokens of KV, page 16
        let mut sched = Scheduler::new(8);
        for i in 0..2 {
            // prompt 40 ≫ max_new 4: budget = pages(max(44, 40) + 5) = 4
            // pages — exactly the whole cache, one sequence at a time.
            sched.submit(Request::new(i, vec![0; 40], 4));
        }
        sched.tick(&mut eng);
        assert_eq!(sched.running_len(), 1, "tight budget must serialize admission");
        eng.kv.check_invariants().unwrap();
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 2);
        for r in &results {
            assert!(!r.failed);
            assert_eq!(r.tokens.len(), 44, "request {}", r.id);
        }
        assert_eq!(eng.kv.used_pages(), 0);
        eng.kv.check_invariants().unwrap();
    }

    #[test]
    fn oversized_request_rejected_cleanly() {
        let mut eng = engine_with_kv(64);
        let mut sched = Scheduler::new(4);
        sched.submit(Request::new(1, vec![0; 100], 100)); // > max_seq_len 128
        sched.submit(Request::new(2, vec![1], 8));
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 2);
        let r1 = results.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(r1.tokens.len(), 100, "oversized request returns prompt only");
        let r2 = results.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(r2.tokens.len(), 9);
    }

    #[test]
    fn load_reflects_outstanding_tokens() {
        let mut sched = Scheduler::new(4);
        assert_eq!(sched.load(), 0);
        sched.submit(Request::new(1, vec![0], 25));
        sched.submit(Request::new(2, vec![0], 10));
        assert_eq!(sched.load(), 35);
    }

    #[test]
    fn ttft_and_token_latency_accounting() {
        // Every retired generating sequence records exactly one TTFT and
        // one per-token latency sample; counters are monotone across
        // batches, TTFT never exceeds total latency, and quantiles are
        // monotone in q.
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(8);
        for i in 0..5 {
            sched.submit(Request::new(i, vec![1, 2], 10));
        }
        let results = sched.run_to_completion(&mut eng);
        assert_eq!(results.len(), 5);
        assert_eq!(eng.metrics.ttft.count(), 5);
        assert_eq!(eng.metrics.token_latency.count(), 5);
        for r in &results {
            let ttft = r.ttft.expect("generating sequence must stamp TTFT");
            assert!(ttft <= r.latency, "request {}: TTFT {ttft:?} > latency {:?}", r.id, r.latency);
        }
        assert!(eng.metrics.ttft.quantile(0.99) >= eng.metrics.ttft.quantile(0.5));
        assert!(
            eng.metrics.token_latency.quantile(0.99) >= eng.metrics.token_latency.quantile(0.5)
        );
        // Monotone counters: one more request, counts advance by one.
        let mut sched2 = Scheduler::new(8);
        sched2.submit(Request::new(10, vec![3], 6));
        sched2.run_to_completion(&mut eng);
        assert_eq!(eng.metrics.ttft.count(), 6);
        assert_eq!(eng.metrics.token_latency.count(), 6);
    }

    #[test]
    fn fifo_admission_order() {
        let mut eng = engine_with_kv(1024);
        let mut sched = Scheduler::new(1);
        sched.submit(Request::new(10, vec![1], 4));
        sched.submit(Request::new(11, vec![1], 4));
        let first = loop {
            let r = sched.tick(&mut eng);
            if !r.is_empty() {
                break r;
            }
        };
        assert_eq!(first[0].id, 10, "queue must be FIFO");
        sched.run_to_completion(&mut eng);
    }
}
