//! Persistent verification worker pool, shareable server-wide.
//!
//! The pre-pool engine fanned verification out with a fresh
//! `std::thread::scope` per speculative block: every block paid thread
//! spawn/join (~tens of µs), every spawned worker rebuilt its
//! [`CouplingWorkspace`] from cold, and the draft-phase exponential-panel
//! reuse was lost entirely on the parallel path (the panel cache was
//! thread-local to the engine thread). This module replaces that with
//! std-only long-lived workers (rayon is unavailable offline):
//!
//! * **Parked threads.** `VerifyPool::new(w)` spawns `w` threads that park
//!   on a condvar between batches; steady-state dispatch is one mutex
//!   round-trip per claimed chunk, no spawns.
//! * **Persistent workspaces.** Each worker owns a `CouplingWorkspace`
//!   (race scratch + residual scratch + top-k scratch + panel cache) that
//!   persists across blocks, so verification stays zero-allocation after
//!   warm-up — the same property the serial path has always had.
//! * **Chunked self-scheduling.** A batch is published as a job vector and
//!   workers repeatedly claim the next unclaimed chunk (work-stealing
//!   style dynamic scheduling: fast workers claim more chunks), which
//!   balances continuous batches whose sequences have different support
//!   sizes. Results land by job index, so outputs are order-independent.
//! * **Panel handoff + recycling.** Each [`VerifyJob`] carries the
//!   sequence's [`PanelSlice`] recorded by the engine's draft phase; the
//!   claiming worker adopts it into its workspace cache before verifying
//!   and ships the spent buffers back through the job's return channel,
//!   which keeps draft-phase recording allocation-free in steady state
//!   (see `spec::kernel` module docs, "Panel-slice handoff protocol").
//!
//! # Ticket protocol (server-global sharing)
//!
//! One pool serves *every* engine of a server: `run_batch` takes `&self`,
//! so router workers submit concurrently through a shared `Arc<VerifyPool>`
//! and steady-state verify-thread count is the pool size — independent of
//! how many server workers exist (previously each engine owned a pool, so
//! a W-worker server parked `W × verify_workers` threads).
//!
//! Each submission becomes a **ticket**: an epoch-tagged (`id` from a
//! monotonic counter) batch record holding the job vector, the output
//! slots, a claim cursor, and the submitting engine's tag. Workers scan
//! tickets in epoch order and claim chunks from the first ticket with
//! unclaimed jobs, so concurrent batches interleave FIFO without ever
//! mixing state: claims, outputs, and the panel-cache counters all live
//! on the ticket they came from, which is what keeps per-engine metrics
//! (`EngineMetrics::panel_cache_hits`, [`VerifyPool::engine_stats`])
//! attributable under sharing. The submitter parks on a condvar until its
//! ticket's `pending` hits zero, then removes the ticket and takes the
//! outputs — tickets never outlive their submitter's call.
//!
//! # Panic containment
//!
//! A verify job that panics must never poison the pool or wedge another
//! engine (one bad request, one failed request — nothing more):
//!
//! * every job runs under `catch_unwind`; a panic marks that job index
//!   failed on its ticket and the worker replaces its workspace (scratch
//!   state after an unwind is unspecified; caches are value-keyed so this
//!   only costs warm-up) and keeps serving;
//! * pool state transitions never execute code that can panic while
//!   holding the state mutex, and every lock acquisition goes through a
//!   poison-recovering helper, so even an unexpected unwind cannot turn
//!   into a permanently poisoned mutex;
//! * a claim guard decrements `pending` for any chunk a dying worker
//!   failed to publish, so the submitter always wakes; `run_batch`
//!   additionally respawns any worker thread that died since the last
//!   submission;
//! * the submitter surfaces failures as [`PoolError::JobsPanicked`] — a
//!   typed error carrying the failed indices *and* the successful outputs,
//!   so the engine can fail exactly the affected sequences — and the pool
//!   is immediately reusable (its ticket is gone, no residual state).
//!
//! Determinism: a job's output is a pure function of the job (workspace
//! caches are keyed by exact RNG lane prefixes, so cross-sequence reuse
//! cannot alter values), hence pooled, scoped-spawn, and serial execution
//! are bit-exact for every verifier — enforced by the pool grid in
//! `tests/kernel_parity.rs`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use super::sequence::CancelToken;
use crate::model::sampling::SamplingParams;
use crate::spec::kernel::{CouplingWorkspace, PanelCacheStats, PanelSlice, SliceBank};
use crate::spec::types::{BlockInput, BlockOutput, Categorical, TokenMatrix, VerifierKind};
use crate::stats::rng::CounterRng;

/// Cancellation checkpoint a job carries to its claiming worker: the
/// request's `CancelToken` plus its precomputed absolute deadline. Both
/// signals are monotone (a flipped token never unflips; an expired
/// instant stays expired), so the engine epilogue re-checking the same
/// handle is guaranteed to see any cut the worker saw — the claim-time
/// shortcut below can never leak a half-processed block as real tokens.
#[derive(Clone, Debug, Default)]
pub struct JobCut {
    pub cancel: CancelToken,
    pub deadline_at: Option<Instant>,
}

impl JobCut {
    /// Is the owning sequence cut as of now?
    pub fn is_cut(&self) -> bool {
        self.cancel.is_cancelled()
            || self.deadline_at.is_some_and(|at| Instant::now() >= at)
    }
}

/// One sequence's verification work, fully owned so it can migrate to a
/// persistent worker (`'static` + `Send`): the flat-arena token view, the
/// draft distributions, the *raw* target logits (each worker builds its
/// `Categorical`s with its own reusable top-k scratch), the per-sequence
/// randomness stream, and the draft-phase panel slice to adopt.
pub struct VerifyJob {
    pub kind: VerifierKind,
    pub draft_tokens: TokenMatrix,
    pub draft_dists: Vec<Vec<Categorical>>,
    /// `[lane][pos][vocab]` f32 logits from the target span pass.
    pub target_logits: Vec<Vec<Vec<f32>>>,
    pub target_params: SamplingParams,
    /// The sequence's split randomness stream (`root.split(rng_lane)`).
    pub rng: CounterRng,
    pub slot0: u64,
    /// Draft-phase exponential rows for this sequence (empty for verifier
    /// kinds that consume disjoint RNG coordinates).
    pub panel: PanelSlice,
    /// Return channel for the spent panel slice (step 5 of the handoff
    /// protocol): the consuming workspace ships the displaced buffers back
    /// to the recording engine's `SliceRecycler`. `None` disables
    /// recycling (e.g. the faithful scoped-spawn baseline).
    pub recycle: Option<std::sync::mpsc::Sender<PanelSlice>>,
    /// Lifecycle checkpoint: when set and already cut at claim time, the
    /// worker skips verification entirely and returns an empty output —
    /// the engine epilogue (which re-checks the same monotone handle)
    /// discards it and retires the sequence `Cancelled`. `None` (parity
    /// suites, benches) keeps the job bit-identical to the pre-lifecycle
    /// pool.
    pub cut: Option<JobCut>,
}

impl VerifyJob {
    /// Clone the job's inputs for a retry spare (the engine's
    /// retry-once policy on transient pool faults). The panel slice and
    /// recycle channel are deliberately dropped: panel handoff is a pure
    /// perf optimization — verification re-derives the exponential rows
    /// from the RNG coordinates — so the spare is bit-exact with the
    /// original, just cold.
    pub fn clone_for_retry(&self) -> VerifyJob {
        VerifyJob {
            kind: self.kind,
            draft_tokens: self.draft_tokens.clone(),
            draft_dists: self.draft_dists.clone(),
            target_logits: self.target_logits.clone(),
            target_params: self.target_params,
            rng: self.rng,
            slot0: self.slot0,
            panel: PanelSlice::default(),
            recycle: None,
            cut: self.cut.clone(),
        }
    }

    /// Run the job on `ws`. Pure in `(self)` — the workspace only
    /// contributes reusable scratch and value-keyed caches, never state
    /// that can change an outcome — except for the claim-time cut check,
    /// whose empty output is only ever observed by an epilogue that also
    /// sees the cut (monotonicity; see [`JobCut`]).
    pub fn run(mut self, ws: &mut CouplingWorkspace) -> BlockOutput {
        if self.cut.as_ref().is_some_and(JobCut::is_cut) {
            // Best-effort return the unconsumed panel so the recycler
            // keeps its buffers (the next lease demotes the rows to
            // spares); no verification work happens for a cut sequence.
            if let Some(tx) = self.recycle.take() {
                let _ = tx.send(std::mem::take(&mut self.panel));
            }
            return BlockOutput { tokens: Vec::new(), accepted: 0, surviving_draft: None };
        }
        if !self.panel.is_empty() {
            let spent = ws.adopt_panel_slice(std::mem::take(&mut self.panel));
            if let Some(tx) = self.recycle.take() {
                // Best-effort: a dropped engine-side receiver only costs
                // the next lease a fresh allocation.
                let _ = tx.send(spent);
            }
        }
        let tp = self.target_params;
        let target_dists: Vec<Vec<Categorical>> = self
            .target_logits
            .iter()
            .map(|lane_rows| {
                lane_rows
                    .iter()
                    .map(|lg| {
                        Categorical::from_logits_with_scratch(
                            lg,
                            tp.temperature,
                            tp.top_k,
                            &mut ws.topk_scratch,
                        )
                    })
                    .collect()
            })
            .collect();
        let input = BlockInput {
            draft_tokens: self.draft_tokens,
            draft_dists: self.draft_dists,
            target_dists,
        };
        ws.verify_block_kind(self.kind, &input, &self.rng, self.slot0)
    }
}

/// Outputs of one successfully verified batch, in job order, plus the
/// panel-cache reuse counters (hits / misses / collision overwrites) the
/// workers observed while running exactly this batch's jobs (per-ticket
/// attribution — see the module docs).
#[derive(Debug)]
pub struct BatchOutput {
    pub outputs: Vec<BlockOutput>,
    pub cache: PanelCacheStats,
}

/// Typed failure surface of [`VerifyPool::run_batch`].
#[derive(Debug)]
pub enum PoolError {
    /// One or more jobs panicked on a worker. `failed` holds their job
    /// indices (ascending); `completed[i]` holds the output of every job
    /// that did finish, so the submitter can fail exactly the affected
    /// sequences and keep the rest. The pool itself has already recovered
    /// and is reusable.
    JobsPanicked {
        failed: Vec<usize>,
        completed: Vec<Option<BlockOutput>>,
        cache: PanelCacheStats,
    },
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::JobsPanicked { failed, completed, .. } => write!(
                f,
                "{} of {} verify jobs panicked (indices {:?})",
                failed.len(),
                completed.len(),
                failed
            ),
        }
    }
}

impl std::error::Error for PoolError {}

/// Per-engine accounting of a shared pool (keyed by the engine tag passed
/// to [`VerifyPool::run_batch`]) — the observability that keeps metrics
/// attributable when many engines share one pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolEngineStats {
    /// Batches this engine submitted.
    pub batches: u64,
    /// Jobs across those batches.
    pub jobs: u64,
    /// Panel-cache hits attributed to this engine's jobs.
    pub cache_hits: u64,
    /// Jobs that panicked.
    pub faults: u64,
}

/// One submitted batch (see "Ticket protocol" in the module docs).
struct Ticket {
    /// Epoch tag: monotonically increasing submission id.
    id: u64,
    /// Submitting engine's tag (metrics attribution).
    engine: u64,
    /// Published jobs; workers `take()` them as they claim chunks.
    jobs: Vec<Option<VerifyJob>>,
    outs: Vec<Option<BlockOutput>>,
    /// Job indices that panicked.
    failed: Vec<usize>,
    /// Next unclaimed job index.
    next: usize,
    /// Claim granularity for this ticket.
    chunk: usize,
    /// Jobs not yet completed (claimed or unclaimed).
    pending: usize,
    /// Panel-cache reuse counters observed while running this ticket's
    /// jobs.
    cache: PanelCacheStats,
}

struct PoolState {
    /// Live tickets in epoch order; workers claim from the first one with
    /// unclaimed jobs, submitters remove their own on completion.
    tickets: Vec<Ticket>,
    next_ticket: u64,
    /// Per-engine accounting, folded in at ticket collection.
    stats: Vec<(u64, PoolEngineStats)>,
    shutdown: bool,
}

impl PoolState {
    fn ticket_mut(&mut self, id: u64) -> Option<&mut Ticket> {
        self.tickets.iter_mut().find(|t| t.id == id)
    }

    fn stats_mut(&mut self, engine: u64) -> &mut PoolEngineStats {
        if let Some(pos) = self.stats.iter().position(|(e, _)| *e == engine) {
            &mut self.stats[pos].1
        } else {
            self.stats.push((engine, PoolEngineStats::default()));
            &mut self.stats.last_mut().expect("just pushed").1
        }
    }
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between batches.
    work: Condvar,
    /// Submitters park here until their ticket's `pending == 0`.
    done: Condvar,
    /// Armed transient-fault budget (testkit): while positive, each job
    /// execution decrements it and panics *before* running the job, so a
    /// resubmitted clone succeeds — the workload drills' model of a
    /// worker dying mid-ticket.
    fault_fuse: AtomicUsize,
}

impl PoolShared {
    /// Burn one armed fault if any remain; fires inside the per-job
    /// `catch_unwind`, so it is contained exactly like a verifier panic.
    fn trip_injected_fault(&self) {
        if self
            .fault_fuse
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
            .is_ok()
        {
            panic!("injected transient pool fault (testkit)");
        }
    }
    /// Poison-recovering lock: a panic on another thread while it held the
    /// mutex must not cascade (state transitions are written to be
    /// panic-free under the lock, so recovered state is always coherent).
    /// Delegates to the crate-wide helpers in [`crate::sync`] — the one
    /// blessed lock discipline, enforced by the repo lint.
    fn lock(&self) -> MutexGuard<'_, PoolState> {
        crate::sync::lock_recover(&self.state)
    }

    fn wait<'a>(&self, cv: &Condvar, g: MutexGuard<'a, PoolState>) -> MutexGuard<'a, PoolState> {
        crate::sync::wait_recover(cv, g)
    }
}

/// Marks a claimed-but-unpublished chunk failed if the owning worker dies
/// mid-run, so `pending` always reaches zero and the submitter always
/// wakes (the last line of the panic-containment defense; per-job
/// `catch_unwind` means it normally never fires).
struct ClaimGuard<'a> {
    shared: &'a PoolShared,
    ticket: u64,
    unpublished: Vec<usize>,
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        if self.unpublished.is_empty() {
            return;
        }
        let mut st = self.shared.lock();
        if let Some(t) = st.ticket_mut(self.ticket) {
            for &i in &self.unpublished {
                t.failed.push(i);
                t.pending -= 1;
            }
            if t.pending == 0 {
                self.shared.done.notify_all();
            }
        }
    }
}

/// Long-lived verification worker pool — see the module docs. Shareable:
/// all methods take `&self`, so one `Arc<VerifyPool>` can serve every
/// engine of a server concurrently.
pub struct VerifyPool {
    shared: Arc<PoolShared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
    /// Total workers ever spawned (names for respawned replacements).
    spawned: AtomicUsize,
    /// Pool-level spare `PanelSlice` free list shared by every attached
    /// engine: engines deposit surplus recycler returns here and lease
    /// from it when their own recycler runs dry, so recycling capacity
    /// follows load across engines instead of stranding per-engine.
    bank: Arc<SliceBank>,
}

impl VerifyPool {
    /// Spawn `workers` (≥ 1) parked worker threads.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                tickets: Vec::new(),
                next_ticket: 0,
                stats: Vec::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            fault_fuse: AtomicUsize::new(0),
        });
        let handles = (0..workers).map(|i| spawn_worker(&shared, i)).collect();
        Self {
            shared,
            handles: Mutex::new(handles),
            workers,
            spawned: AtomicUsize::new(workers),
            bank: Arc::new(SliceBank::new()),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The pool-level spare-slice bank shared by every attached engine.
    pub fn slice_bank(&self) -> Arc<SliceBank> {
        Arc::clone(&self.bank)
    }

    /// Join any dead worker threads and respawn replacements so the pool
    /// holds its configured size even after an unexpected worker unwind
    /// (per-job `catch_unwind` makes that near-impossible, but a shared
    /// service must not erode). Called on every submission; the common
    /// path is `workers` cheap `is_finished` loads.
    fn ensure_workers(&self) {
        let mut hs = crate::sync::lock_recover(&self.handles);
        let mut i = 0;
        while i < hs.len() {
            if hs[i].is_finished() {
                let _ = hs.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        while hs.len() < self.workers {
            let n = self.spawned.fetch_add(1, Ordering::Relaxed);
            hs.push(spawn_worker(&self.shared, n));
        }
    }

    /// Submit one batch as an epoch-tagged ticket and block until every
    /// job completes. `engine` tags the ticket for metrics attribution
    /// ([`VerifyPool::engine_stats`]). Concurrent callers are fine —
    /// tickets are independent — and the pool is reusable immediately
    /// after, including after an error.
    pub fn run_batch(&self, engine: u64, jobs: Vec<VerifyJob>) -> Result<BatchOutput, PoolError> {
        let n = jobs.len();
        if n == 0 {
            return Ok(BatchOutput { outputs: Vec::new(), cache: PanelCacheStats::default() });
        }
        self.ensure_workers();
        let id = {
            let mut st = self.shared.lock();
            let id = st.next_ticket;
            st.next_ticket += 1;
            st.tickets.push(Ticket {
                id,
                engine,
                jobs: jobs.into_iter().map(Some).collect(),
                outs: (0..n).map(|_| None).collect(),
                failed: Vec::new(),
                next: 0,
                // Finer than jobs/workers so fast workers rebalance
                // stragglers; claiming costs one lock round-trip per
                // chunk, so don't go below 1.
                chunk: (n / (self.workers * 4)).max(1),
                pending: n,
                cache: PanelCacheStats::default(),
            });
            self.shared.work.notify_all();
            id
        };
        // ---- Park until this ticket completes, then collect it. ----
        let mut st = self.shared.lock();
        loop {
            let pos = st
                .tickets
                .iter()
                .position(|t| t.id == id)
                .expect("submitted ticket present until collected");
            if st.tickets[pos].pending == 0 {
                let mut t = st.tickets.remove(pos);
                let s = st.stats_mut(t.engine);
                s.batches += 1;
                s.jobs += n as u64;
                s.cache_hits += t.cache.hits;
                s.faults += t.failed.len() as u64;
                drop(st);
                return if t.failed.is_empty() {
                    Ok(BatchOutput {
                        outputs: t
                            .outs
                            .into_iter()
                            .map(|o| o.expect("job completed"))
                            .collect(),
                        cache: t.cache,
                    })
                } else {
                    t.failed.sort_unstable();
                    Err(PoolError::JobsPanicked {
                        failed: t.failed,
                        completed: t.outs,
                        cache: t.cache,
                    })
                };
            }
            st = self.shared.wait(&self.shared.done, st);
        }
    }

    /// Arm `n` transient faults: the next `n` job executions (on
    /// whichever workers claim them) panic before running their job, as
    /// if the worker died mid-ticket. The jobs themselves are untouched,
    /// so resubmitting them succeeds — the failure mode the engine's
    /// retry-once policy targets. Testkit-facing; never fires unarmed.
    pub fn inject_transient_faults(&self, n: usize) {
        self.shared.fault_fuse.fetch_add(n, Ordering::Relaxed);
    }

    /// Per-engine accounting (zero if the tag never submitted).
    pub fn engine_stats(&self, engine: u64) -> PoolEngineStats {
        let st = self.shared.lock();
        st.stats
            .iter()
            .find(|(e, _)| *e == engine)
            .map(|(_, s)| *s)
            .unwrap_or_default()
    }

    /// Scoped-spawn reference executor: the pre-pool engine behavior —
    /// fresh threads, cold workspaces, and NO draft-phase panel reuse or
    /// recycling (panel slices are discarded, reproducing the thread-local
    /// cache the old parallel path could never reach; dropping them is a
    /// pure perf difference, never a token difference). Preserved as the
    /// baseline `benches/perf_engine.rs` races the pool against and as a
    /// config escape hatch (`verify_backend = spawn`). Returns the outputs
    /// in job order plus the panel-cache reuse counters observed (hits ~0
    /// by construction).
    pub fn run_scoped(jobs: Vec<VerifyJob>, threads: usize) -> (Vec<BlockOutput>, PanelCacheStats) {
        let n = jobs.len();
        let threads = threads.max(1).min(n.max(1));
        let mut jobs: Vec<Option<VerifyJob>> = jobs
            .into_iter()
            .map(|mut job| {
                job.panel = PanelSlice::new();
                job.recycle = None;
                Some(job)
            })
            .collect();
        let mut outs: Vec<Option<BlockOutput>> = (0..n).map(|_| None).collect();
        let hits = AtomicU64::new(0);
        let misses = AtomicU64::new(0);
        let overwrites = AtomicU64::new(0);
        let publish = |ws: &mut CouplingWorkspace| {
            let s = ws.drain_cache_stats();
            hits.fetch_add(s.hits, Ordering::Relaxed);
            misses.fetch_add(s.misses, Ordering::Relaxed);
            overwrites.fetch_add(s.overwrites, Ordering::Relaxed);
        };
        if threads <= 1 {
            let mut ws = CouplingWorkspace::new();
            for (slot, job) in outs.iter_mut().zip(jobs.iter_mut()) {
                *slot = Some(job.take().expect("job unclaimed").run(&mut ws));
            }
            publish(&mut ws);
        } else {
            let chunk = n.div_ceil(threads);
            std::thread::scope(|scope| {
                for (out_chunk, job_chunk) in outs.chunks_mut(chunk).zip(jobs.chunks_mut(chunk)) {
                    let publish = &publish;
                    scope.spawn(move || {
                        let mut ws = CouplingWorkspace::new();
                        for (slot, job) in out_chunk.iter_mut().zip(job_chunk.iter_mut()) {
                            *slot = Some(job.take().expect("job unclaimed").run(&mut ws));
                        }
                        publish(&mut ws);
                    });
                }
            });
        }
        drop(publish);
        (
            outs.into_iter().map(|o| o.expect("job ran")).collect(),
            PanelCacheStats {
                hits: hits.into_inner(),
                misses: misses.into_inner(),
                overwrites: overwrites.into_inner(),
            },
        )
    }
}

impl Drop for VerifyPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        let handles = std::mem::take(crate::sync::get_mut_recover(&mut self.handles));
        for h in handles {
            let _ = h.join();
        }
    }
}

fn spawn_worker(shared: &Arc<PoolShared>, idx: usize) -> JoinHandle<()> {
    let sh = Arc::clone(shared);
    std::thread::Builder::new()
        .name(format!("gls-verify-{idx}"))
        .spawn(move || worker_loop(sh))
        .expect("spawn verify worker")
}

fn worker_loop(shared: Arc<PoolShared>) {
    let mut ws = CouplingWorkspace::new();
    loop {
        // ---- Claim a chunk from the first ticket with unclaimed jobs. ----
        let (ticket_id, claimed) = {
            let mut st = shared.lock();
            'claim: loop {
                if st.shutdown {
                    return;
                }
                for t in st.tickets.iter_mut() {
                    if t.next < t.jobs.len() {
                        let start = t.next;
                        let end = (start + t.chunk).min(t.jobs.len());
                        t.next = end;
                        let mut claimed = Vec::with_capacity(end - start);
                        for i in start..end {
                            claimed.push((i, t.jobs[i].take().expect("unclaimed job present")));
                        }
                        break 'claim (t.id, claimed);
                    }
                }
                st = shared.wait(&shared.work, st);
            }
        };
        let mut guard = ClaimGuard {
            shared: &*shared,
            ticket: ticket_id,
            unpublished: claimed.iter().map(|(i, _)| *i).collect(),
        };
        // ---- Run outside the lock; each job individually contained. ----
        let mut done: Vec<(usize, Option<BlockOutput>)> = Vec::with_capacity(claimed.len());
        let mut stats = PanelCacheStats::default();
        for (i, job) in claimed {
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                shared.trip_injected_fault();
                job.run(&mut ws)
            }))
            .ok();
            if out.is_none() {
                // Scratch state after an unwind is unspecified; caches are
                // value-keyed, so a fresh workspace only costs warm-up.
                stats.merge(ws.drain_cache_stats());
                ws = CouplingWorkspace::new();
            }
            done.push((i, out));
        }
        stats.merge(ws.drain_cache_stats());
        // ---- Publish results on the ticket (panic-free under lock). ----
        let mut st = shared.lock();
        if let Some(t) = st.ticket_mut(ticket_id) {
            t.cache.merge(stats);
            for (i, out) in done {
                match out {
                    Some(o) => t.outs[i] = Some(o),
                    None => t.failed.push(i),
                }
                t.pending -= 1;
            }
            if t.pending == 0 {
                shared.done.notify_all();
            }
        }
        guard.unpublished.clear();
        drop(st);
        drop(guard);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::types::{BlockInput, FAULT_MARKER_TOKEN};
    use crate::stats::rng::XorShift128;
    use crate::testkit;

    /// A synthetic job whose expected output can be recomputed serially.
    fn mk_job(gen: &mut XorShift128, kind: VerifierKind, seed: u64) -> VerifyJob {
        let (k, l, n) = (3usize, 3usize, 24usize);
        let tp = SamplingParams::new(1.0, Some(8));
        let p: Vec<Categorical> = (0..l).map(|_| testkit::gen_categorical(gen, n)).collect();
        let rng = CounterRng::new(seed);
        let mut panel = PanelSlice::new();
        let mut flat = vec![0u32; k * l];
        for j in 0..l {
            for lane in 0..k {
                flat[lane * l + j] = panel.record_race(&p[j], &rng, j as u64, lane as u64) as u32;
            }
        }
        let target_logits: Vec<Vec<Vec<f32>>> = (0..k)
            .map(|_| {
                (0..=l)
                    .map(|_| (0..n).map(|_| (gen.next_f64() * 6.0) as f32).collect())
                    .collect()
            })
            .collect();
        VerifyJob {
            kind,
            draft_tokens: TokenMatrix::view(Arc::new(flat), 0, k, l),
            draft_dists: vec![p; k],
            target_logits,
            target_params: tp,
            rng,
            slot0: 0,
            panel,
            recycle: None,
            cut: None,
        }
    }

    /// A job rigged to trip the FaultInjection verifier: every draft token
    /// is the marker, so `run` panics on whichever worker claims it.
    fn mk_fault_job(gen: &mut XorShift128, seed: u64) -> VerifyJob {
        let mut job = mk_job(gen, VerifierKind::FaultInjection, seed);
        let (k, l) = (job.draft_dists.len(), job.draft_dists[0].len());
        job.panel = PanelSlice::new(); // recorded rows are irrelevant here
        job.draft_tokens = TokenMatrix::view(Arc::new(vec![FAULT_MARKER_TOKEN; k * l]), 0, k, l);
        job
    }

    /// Rebuild the same job's BlockInput serially (fresh scratch) and
    /// verify on a cold workspace — the oracle the pool must match.
    fn expected(gen: &mut XorShift128, kind: VerifierKind, seed: u64) -> BlockOutput {
        let job = mk_job(gen, kind, seed);
        let rng = job.rng;
        let slot0 = job.slot0;
        let tp = job.target_params;
        let mut scratch = Vec::new();
        let target_dists: Vec<Vec<Categorical>> = job
            .target_logits
            .iter()
            .map(|rows| {
                rows.iter()
                    .map(|lg| {
                        Categorical::from_logits_with_scratch(
                            lg,
                            tp.temperature,
                            tp.top_k,
                            &mut scratch,
                        )
                    })
                    .collect()
            })
            .collect();
        let input = BlockInput {
            draft_tokens: job.draft_tokens.clone(),
            draft_dists: job.draft_dists.clone(),
            target_dists,
        };
        CouplingWorkspace::new().verify_block_kind(kind, &input, &rng, slot0)
    }

    #[test]
    fn pool_matches_serial_oracle_across_batches_and_sizes() {
        for &workers in &[1usize, 2, 4] {
            let pool = VerifyPool::new(workers);
            // Several batches through the SAME pool: workspaces persist,
            // outcomes must not.
            for batch in 0..3u64 {
                let kinds = [VerifierKind::Gls, VerifierKind::SpecInfer, VerifierKind::Daliri];
                let jobs: Vec<VerifyJob> = (0..7u64)
                    .map(|i| {
                        let kind = kinds[(i % 3) as usize];
                        let mut gen = XorShift128::new(100 + batch * 10 + i);
                        mk_job(&mut gen, kind, batch * 100 + i)
                    })
                    .collect();
                let outs = pool.run_batch(0, jobs).expect("no faults").outputs;
                for (i, out) in outs.iter().enumerate() {
                    let kind = kinds[i % 3];
                    let mut gen = XorShift128::new(100 + batch * 10 + i as u64);
                    let want = expected(&mut gen, kind, batch * 100 + i as u64);
                    assert_eq!(
                        *out, want,
                        "workers {workers} batch {batch} job {i} ({kind:?})"
                    );
                }
            }
        }
    }

    #[test]
    fn concurrent_submitters_share_one_pool() {
        // The ticket protocol: several "engines" submit interleaved
        // batches through ONE pool from their own threads; every output
        // must match its serial oracle, and per-engine stats must
        // attribute exactly the jobs each engine submitted.
        let pool = Arc::new(VerifyPool::new(2));
        let n_engines = 3u64;
        let batches = 4u64;
        let per_batch = 5u64;
        std::thread::scope(|scope| {
            for e in 0..n_engines {
                let pool = Arc::clone(&pool);
                scope.spawn(move || {
                    for b in 0..batches {
                        let jobs: Vec<VerifyJob> = (0..per_batch)
                            .map(|i| {
                                let seed = e * 1000 + b * 10 + i;
                                let mut gen = XorShift128::new(7 + seed);
                                mk_job(&mut gen, VerifierKind::Gls, seed)
                            })
                            .collect();
                        let outs = pool.run_batch(e, jobs).expect("no faults").outputs;
                        for (i, out) in outs.iter().enumerate() {
                            let seed = e * 1000 + b * 10 + i as u64;
                            let mut gen = XorShift128::new(7 + seed);
                            let want = expected(&mut gen, VerifierKind::Gls, seed);
                            assert_eq!(*out, want, "engine {e} batch {b} job {i}");
                        }
                    }
                });
            }
        });
        for e in 0..n_engines {
            let s = pool.engine_stats(e);
            assert_eq!(s.batches, batches, "engine {e} batch count");
            assert_eq!(s.jobs, batches * per_batch, "engine {e} job count");
            assert_eq!(s.faults, 0, "engine {e} fault count");
        }
        assert_eq!(pool.engine_stats(99), PoolEngineStats::default());
    }

    #[test]
    fn panicking_job_surfaces_typed_error_and_spares_the_rest() {
        let pool = VerifyPool::new(2);
        let mut gen = XorShift128::new(0xFA);
        let jobs = vec![
            mk_job(&mut gen, VerifierKind::Gls, 1),
            mk_fault_job(&mut gen, 2),
            mk_job(&mut gen, VerifierKind::Daliri, 3),
        ];
        let err = pool.run_batch(7, jobs).expect_err("fault job must fail the batch");
        let PoolError::JobsPanicked { failed, completed, .. } = err;
        assert_eq!(failed, vec![1], "exactly the fault job fails");
        assert_eq!(completed.len(), 3);
        assert!(completed[1].is_none());
        let mut gen = XorShift128::new(0xFA);
        let want0 = expected(&mut gen, VerifierKind::Gls, 1);
        let _ = mk_fault_job(&mut gen, 2); // advance the generator identically
        let want2 = expected(&mut gen, VerifierKind::Daliri, 3);
        assert_eq!(completed[0].as_ref(), Some(&want0), "good job 0 must complete");
        assert_eq!(completed[2].as_ref(), Some(&want2), "good job 2 must complete");
        assert_eq!(pool.engine_stats(7).faults, 1);
    }

    #[test]
    fn pool_is_reusable_after_panics_without_poisoning() {
        // Repeated fault storms followed by clean batches: no deadlock, no
        // poisoned locks, no residual ticket state, bit-exact outputs.
        let pool = VerifyPool::new(3);
        for round in 0..3u64 {
            let mut gen = XorShift128::new(200 + round);
            let all_bad: Vec<VerifyJob> = (0..6).map(|i| mk_fault_job(&mut gen, i)).collect();
            match pool.run_batch(0, all_bad) {
                Err(PoolError::JobsPanicked { failed, completed, .. }) => {
                    assert_eq!(failed, (0..6).collect::<Vec<_>>());
                    assert!(completed.iter().all(|o| o.is_none()));
                }
                Ok(_) => panic!("round {round}: all-fault batch reported success"),
            }
            // The same pool must serve a clean batch correctly right after.
            let mut gen = XorShift128::new(300 + round);
            let jobs: Vec<VerifyJob> =
                (0..5u64).map(|i| mk_job(&mut gen, VerifierKind::SpecTr, 40 + i)).collect();
            let outs = pool.run_batch(0, jobs).expect("clean batch after faults").outputs;
            for (i, out) in outs.iter().enumerate() {
                let mut gen = XorShift128::new(300 + round);
                for _ in 0..i {
                    let _ = mk_job(&mut gen, VerifierKind::SpecTr, 0); // advance generator
                }
                let want = expected(&mut gen, VerifierKind::SpecTr, 40 + i as u64);
                assert_eq!(*out, want, "round {round} job {i} after fault storm");
            }
        }
        assert_eq!(pool.engine_stats(0).faults, 18);
    }

    #[test]
    fn pool_handoff_panels_hit_on_worker_threads() {
        let pool = VerifyPool::new(2);
        let jobs: Vec<VerifyJob> = (0..6u64)
            .map(|i| {
                let mut gen = XorShift128::new(900 + i);
                mk_job(&mut gen, VerifierKind::Gls, 500 + i)
            })
            .collect();
        let out = pool.run_batch(4, jobs).expect("no faults");
        assert!(
            out.cache.hits > 0,
            "draft-phase panels must be reused on worker threads"
        );
        assert_eq!(
            pool.engine_stats(4).cache_hits,
            out.cache.hits,
            "per-engine stats must attribute the same hits"
        );
    }

    #[test]
    fn spent_slices_return_through_job_recycle_channel() {
        let pool = VerifyPool::new(2);
        let recycler = crate::spec::kernel::SliceRecycler::new();
        let n = 6u64;
        let jobs: Vec<VerifyJob> = (0..n)
            .map(|i| {
                let mut gen = XorShift128::new(70 + i);
                let mut job = mk_job(&mut gen, VerifierKind::Gls, 60 + i);
                job.recycle = Some(recycler.return_sender());
                job
            })
            .collect();
        let recorded = jobs[0].panel.len();
        assert!(recorded > 0);
        let _ = pool.run_batch(0, jobs).expect("no faults");
        // Every job's spent slice must have come back with one spare
        // buffer pair per adopted row (run_batch returning means all jobs
        // finished, so all sends have happened).
        let mut recycler = recycler;
        let mut returned = 0;
        for _ in 0..n {
            let slice = recycler.lease();
            if slice.spare_len() > 0 {
                assert_eq!(slice.spare_len(), recorded);
                returned += 1;
            }
        }
        assert_eq!(returned, n, "every spent slice returns to the engine");
        assert_eq!(recycler.drain_recycled(), n);
    }

    #[test]
    fn run_scoped_matches_pool() {
        let mk_batch = || -> Vec<VerifyJob> {
            (0..5u64)
                .map(|i| {
                    let mut gen = XorShift128::new(70 + i);
                    mk_job(&mut gen, VerifierKind::SpecTr, 40 + i)
                })
                .collect()
        };
        let pool = VerifyPool::new(3);
        let a = pool.run_batch(0, mk_batch()).expect("no faults").outputs;
        let (b, _stats) = VerifyPool::run_scoped(mk_batch(), 3);
        assert_eq!(a, b);
    }

    #[test]
    fn injected_transient_fault_fails_job_once_then_resubmission_succeeds() {
        let pool = VerifyPool::new(2);
        pool.inject_transient_faults(1);
        let mk_batch = || -> Vec<VerifyJob> {
            (0..4u64)
                .map(|i| {
                    let mut gen = XorShift128::new(40 + i);
                    mk_job(&mut gen, VerifierKind::Gls, 80 + i)
                })
                .collect()
        };
        // Spares cloned up front, the way the engine's retry path does it.
        let jobs = mk_batch();
        let spares: Vec<VerifyJob> = jobs.iter().map(VerifyJob::clone_for_retry).collect();
        let err = pool.run_batch(0, jobs).expect_err("armed fault must fail one job");
        let PoolError::JobsPanicked { failed, completed, .. } = err;
        assert_eq!(failed.len(), 1, "exactly one armed fault fires: {failed:?}");
        let idx = failed[0];
        assert!(completed[idx].is_none());
        // The fault was transient (it fired before the job ran):
        // resubmitting the spare for the same job must now succeed and
        // match the serial oracle bit-exactly.
        let mut spares: Vec<Option<VerifyJob>> = spares.into_iter().map(Some).collect();
        let retry = vec![spares[idx].take().expect("spare per job")];
        let outs = pool.run_batch(0, retry).expect("resubmission succeeds").outputs;
        let mut gen = XorShift128::new(40 + idx as u64);
        let want = expected(&mut gen, VerifierKind::Gls, 80 + idx as u64);
        assert_eq!(outs[0], want, "retried job {idx} diverged from oracle");
        // Fuse exhausted: a fresh batch is clean.
        let outs = pool.run_batch(0, mk_batch()).expect("fuse exhausted").outputs;
        assert_eq!(outs.len(), 4);
        assert_eq!(pool.engine_stats(0).faults, 1);
    }

    #[test]
    fn cut_job_skips_verification_and_returns_empty_output() {
        let pool = VerifyPool::new(2);
        let mut gen = XorShift128::new(0xC07);
        // Job 0 is cut before submission, job 1 is live: the cut one must
        // come back empty, the live one bit-exact — co-batching a cut
        // sequence never perturbs its neighbors.
        let mut cut_job = mk_job(&mut gen, VerifierKind::Gls, 11);
        let token = CancelToken::new();
        token.cancel();
        cut_job.cut = Some(JobCut { cancel: token, deadline_at: None });
        let live_job = mk_job(&mut gen, VerifierKind::Gls, 12);
        let outs = pool.run_batch(0, vec![cut_job, live_job]).expect("no faults").outputs;
        assert!(outs[0].tokens.is_empty(), "cut job must not emit tokens");
        assert_eq!(outs[0].accepted, 0);
        let mut gen = XorShift128::new(0xC07);
        let _ = mk_job(&mut gen, VerifierKind::Gls, 11); // advance generator
        let want = expected(&mut gen, VerifierKind::Gls, 12);
        assert_eq!(outs[1], want, "live neighbor unaffected by the cut job");
        // An uncut handle runs normally.
        let mut gen = XorShift128::new(0x5EED);
        let mut job = mk_job(&mut gen, VerifierKind::Gls, 13);
        job.cut = Some(JobCut::default());
        let outs = pool.run_batch(0, vec![job]).expect("no faults").outputs;
        let mut gen = XorShift128::new(0x5EED);
        let want = expected(&mut gen, VerifierKind::Gls, 13);
        assert_eq!(outs[0], want, "an armed-but-uncut handle must not change output");
    }

    #[test]
    fn cut_job_still_returns_its_panel_for_recycling() {
        let pool = VerifyPool::new(1);
        let mut recycler = crate::spec::kernel::SliceRecycler::new();
        let mut gen = XorShift128::new(0x90);
        let mut job = mk_job(&mut gen, VerifierKind::Gls, 21);
        assert!(!job.panel.is_empty());
        job.recycle = Some(recycler.return_sender());
        let token = CancelToken::new();
        token.cancel();
        job.cut = Some(JobCut { cancel: token, deadline_at: None });
        let _ = pool.run_batch(0, vec![job]).expect("no faults");
        let slice = recycler.lease();
        assert!(
            slice.spare_len() > 0,
            "cut job's panel buffers must flow back to the recycler"
        );
        assert_eq!(recycler.drain_recycled(), 1);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let pool = VerifyPool::new(2);
        assert!(pool.run_batch(0, Vec::new()).expect("empty ok").outputs.is_empty());
        // Pool still alive and usable.
        let mut gen = XorShift128::new(1);
        let outs = pool
            .run_batch(0, vec![mk_job(&mut gen, VerifierKind::Daliri, 9)])
            .expect("no faults")
            .outputs;
        assert_eq!(outs.len(), 1);
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = VerifyPool::new(4);
        let mut gen = XorShift128::new(2);
        let _ = pool.run_batch(0, vec![mk_job(&mut gen, VerifierKind::Gls, 3)]).unwrap();
        drop(pool); // must not hang or leak parked threads
    }
}
