//! Persistent verification worker pool.
//!
//! The pre-pool engine fanned verification out with a fresh
//! `std::thread::scope` per speculative block: every block paid thread
//! spawn/join (~tens of µs), every spawned worker rebuilt its
//! [`CouplingWorkspace`] from cold, and the draft-phase exponential-panel
//! reuse was lost entirely on the parallel path (the panel cache was
//! thread-local to the engine thread). This module replaces that with
//! std-only long-lived workers (rayon is unavailable offline):
//!
//! * **Parked threads.** `VerifyPool::new(w)` spawns `w` threads that park
//!   on a condvar between batches; steady-state dispatch is one mutex
//!   round-trip per claimed chunk, no spawns.
//! * **Persistent workspaces.** Each worker owns a `CouplingWorkspace`
//!   (race scratch + residual scratch + top-k scratch + panel cache) that
//!   persists across blocks, so verification stays zero-allocation after
//!   warm-up — the same property the serial path has always had.
//! * **Chunked self-scheduling.** A batch is published as a job vector and
//!   workers repeatedly claim the next unclaimed chunk (work-stealing
//!   style dynamic scheduling: fast workers claim more chunks), which
//!   balances continuous batches whose sequences have different support
//!   sizes. Results land by job index, so outputs are order-independent.
//! * **Panel handoff.** Each [`VerifyJob`] carries the sequence's
//!   [`PanelSlice`] recorded by the engine's draft phase; the claiming
//!   worker adopts it into its workspace cache before verifying, which
//!   extends draft-exponential reuse to the parallel path (see
//!   `spec::kernel` module docs, "Panel-slice handoff protocol").
//!
//! Determinism: a job's output is a pure function of the job (workspace
//! caches are keyed by exact RNG lane prefixes, so cross-sequence reuse
//! cannot alter values), hence pooled, scoped-spawn, and serial execution
//! are bit-exact for every verifier — enforced by the pool grid in
//! `tests/kernel_parity.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::model::sampling::SamplingParams;
use crate::spec::kernel::{CouplingWorkspace, PanelSlice};
use crate::spec::types::{BlockInput, BlockOutput, Categorical, TokenMatrix, VerifierKind};
use crate::stats::rng::CounterRng;

/// One sequence's verification work, fully owned so it can migrate to a
/// persistent worker (`'static` + `Send`): the flat-arena token view, the
/// draft distributions, the *raw* target logits (each worker builds its
/// `Categorical`s with its own reusable top-k scratch), the per-sequence
/// randomness stream, and the draft-phase panel slice to adopt.
pub struct VerifyJob {
    pub kind: VerifierKind,
    pub draft_tokens: TokenMatrix,
    pub draft_dists: Vec<Vec<Categorical>>,
    /// `[lane][pos][vocab]` f32 logits from the target span pass.
    pub target_logits: Vec<Vec<Vec<f32>>>,
    pub target_params: SamplingParams,
    /// The sequence's split randomness stream (`root.split(rng_lane)`).
    pub rng: CounterRng,
    pub slot0: u64,
    /// Draft-phase exponential rows for this sequence (empty for verifier
    /// kinds that consume disjoint RNG coordinates).
    pub panel: PanelSlice,
}

impl VerifyJob {
    /// Run the job on `ws`. Pure in `(self)` — the workspace only
    /// contributes reusable scratch and value-keyed caches, never state
    /// that can change an outcome.
    pub fn run(mut self, ws: &mut CouplingWorkspace) -> BlockOutput {
        if !self.panel.is_empty() {
            ws.adopt_panel_slice(std::mem::take(&mut self.panel));
        }
        let tp = self.target_params;
        let target_dists: Vec<Vec<Categorical>> = self
            .target_logits
            .iter()
            .map(|lane_rows| {
                lane_rows
                    .iter()
                    .map(|lg| {
                        Categorical::from_logits_with_scratch(
                            lg,
                            tp.temperature,
                            tp.top_k,
                            &mut ws.topk_scratch,
                        )
                    })
                    .collect()
            })
            .collect();
        let input = BlockInput {
            draft_tokens: self.draft_tokens,
            draft_dists: self.draft_dists,
            target_dists,
        };
        ws.verify_block_kind(self.kind, &input, &self.rng, self.slot0)
    }
}

struct PoolState {
    /// Published batch; workers `take()` jobs as they claim chunks.
    jobs: Vec<Option<VerifyJob>>,
    outs: Vec<Option<BlockOutput>>,
    /// Next unclaimed job index.
    next: usize,
    /// Claim granularity for this batch.
    chunk: usize,
    /// Jobs not yet completed (claimed or unclaimed).
    pending: usize,
    /// A job panicked on a worker; surfaced to the submitter.
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between batches.
    work: Condvar,
    /// The submitter parks here until `pending == 0`.
    done: Condvar,
    /// Panel-cache hits accumulated across workers since the last drain.
    cache_hits: AtomicU64,
}

/// Long-lived verification worker pool — see the module docs.
pub struct VerifyPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl VerifyPool {
    /// Spawn `workers` (≥ 1) parked worker threads.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                jobs: Vec::new(),
                outs: Vec::new(),
                next: 0,
                chunk: 1,
                pending: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            cache_hits: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gls-verify-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn verify worker")
            })
            .collect();
        Self { shared, handles, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute one batch and return the outputs in job order. Blocks the
    /// caller until every job completes; the pool is reusable immediately
    /// after. Takes `&mut self` so the one-batch-in-flight invariant is
    /// compile-time enforced (a shared pool submitting concurrently would
    /// interleave `jobs`/`outs` state).
    pub fn run_batch(&mut self, jobs: Vec<VerifyJob>) -> Vec<BlockOutput> {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        {
            let mut st = self.shared.state.lock().expect("pool lock");
            debug_assert_eq!(st.pending, 0, "one batch in flight at a time");
            st.jobs = jobs.into_iter().map(Some).collect();
            st.outs = (0..n).map(|_| None).collect();
            st.next = 0;
            // Finer than jobs/workers so fast workers rebalance stragglers;
            // claiming costs one lock round-trip per chunk, so don't go
            // below 1.
            st.chunk = (n / (self.workers * 4)).max(1);
            st.pending = n;
            self.shared.work.notify_all();
        }
        let mut st = self.shared.state.lock().expect("pool lock");
        while st.pending > 0 {
            st = self.shared.done.wait(st).expect("pool wait");
        }
        assert!(!std::mem::take(&mut st.panicked), "verify pool job panicked");
        st.jobs.clear();
        st.outs.drain(..).map(|o| o.expect("job completed")).collect()
    }

    /// Take the panel-cache hits accumulated by the workers since the last
    /// drain (the engine folds this into `EngineMetrics` per block).
    pub fn drain_cache_hits(&self) -> u64 {
        self.shared.cache_hits.swap(0, Ordering::Relaxed)
    }

    /// Scoped-spawn reference executor: the pre-pool engine behavior —
    /// fresh threads, cold workspaces, and NO draft-phase panel reuse
    /// (panel slices are discarded, reproducing the thread-local cache the
    /// old parallel path could never reach; dropping them is a pure perf
    /// difference, never a token difference). Preserved as the baseline
    /// `benches/perf_engine.rs` races the pool against and as a config
    /// escape hatch (`verify_backend = spawn`). Returns the outputs in job
    /// order plus the panel-cache hits observed (~0 by construction).
    pub fn run_scoped(jobs: Vec<VerifyJob>, threads: usize) -> (Vec<BlockOutput>, u64) {
        let n = jobs.len();
        let threads = threads.max(1).min(n.max(1));
        let mut jobs: Vec<Option<VerifyJob>> = jobs
            .into_iter()
            .map(|mut job| {
                job.panel = PanelSlice::new();
                Some(job)
            })
            .collect();
        let mut outs: Vec<Option<BlockOutput>> = (0..n).map(|_| None).collect();
        let hits = AtomicU64::new(0);
        if threads <= 1 {
            let mut ws = CouplingWorkspace::new();
            for (slot, job) in outs.iter_mut().zip(jobs.iter_mut()) {
                *slot = Some(job.take().expect("job unclaimed").run(&mut ws));
            }
            hits.fetch_add(ws.drain_panel_cache_hits(), Ordering::Relaxed);
        } else {
            let chunk = n.div_ceil(threads);
            std::thread::scope(|scope| {
                for (out_chunk, job_chunk) in outs.chunks_mut(chunk).zip(jobs.chunks_mut(chunk)) {
                    let hits = &hits;
                    scope.spawn(move || {
                        let mut ws = CouplingWorkspace::new();
                        for (slot, job) in out_chunk.iter_mut().zip(job_chunk.iter_mut()) {
                            *slot = Some(job.take().expect("job unclaimed").run(&mut ws));
                        }
                        hits.fetch_add(ws.drain_panel_cache_hits(), Ordering::Relaxed);
                    });
                }
            });
        }
        (
            outs.into_iter().map(|o| o.expect("job ran")).collect(),
            hits.into_inner(),
        )
    }
}

impl Drop for VerifyPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("pool lock");
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    let mut ws = CouplingWorkspace::new();
    let mut claimed: Vec<(usize, VerifyJob)> = Vec::new();
    loop {
        {
            let mut st = shared.state.lock().expect("pool lock");
            loop {
                if st.shutdown {
                    return;
                }
                if st.next < st.jobs.len() {
                    break;
                }
                st = shared.work.wait(st).expect("pool wait");
            }
            let start = st.next;
            let end = (start + st.chunk).min(st.jobs.len());
            st.next = end;
            claimed.extend((start..end).map(|i| (i, st.jobs[i].take().expect("job unclaimed"))));
        }
        // Run outside the lock; a panicking job must not hang the
        // submitter, so it is caught, flagged, and re-raised over there.
        let mut done: Vec<(usize, Result<BlockOutput, ()>)> = Vec::with_capacity(claimed.len());
        for (i, job) in claimed.drain(..) {
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job.run(&mut ws)))
                .map_err(|_| ());
            done.push((i, out));
        }
        shared
            .cache_hits
            .fetch_add(ws.drain_panel_cache_hits(), Ordering::Relaxed);
        let mut st = shared.state.lock().expect("pool lock");
        for (i, out) in done {
            match out {
                Ok(out) => st.outs[i] = Some(out),
                Err(()) => st.panicked = true,
            }
            st.pending -= 1;
        }
        if st.pending == 0 {
            shared.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::types::BlockInput;
    use crate::stats::rng::XorShift128;
    use crate::testkit;

    /// A synthetic job whose expected output can be recomputed serially.
    fn mk_job(gen: &mut XorShift128, kind: VerifierKind, seed: u64) -> VerifyJob {
        let (k, l, n) = (3usize, 3usize, 24usize);
        let tp = SamplingParams::new(1.0, Some(8));
        let p: Vec<Categorical> = (0..l).map(|_| testkit::gen_categorical(gen, n)).collect();
        let rng = CounterRng::new(seed);
        let mut panel = PanelSlice::new();
        let mut flat = vec![0u32; k * l];
        for j in 0..l {
            for lane in 0..k {
                flat[lane * l + j] = panel.record_race(&p[j], &rng, j as u64, lane as u64) as u32;
            }
        }
        let target_logits: Vec<Vec<Vec<f32>>> = (0..k)
            .map(|_| {
                (0..=l)
                    .map(|_| (0..n).map(|_| (gen.next_f64() * 6.0) as f32).collect())
                    .collect()
            })
            .collect();
        VerifyJob {
            kind,
            draft_tokens: TokenMatrix::view(Arc::new(flat), 0, k, l),
            draft_dists: vec![p; k],
            target_logits,
            target_params: tp,
            rng,
            slot0: 0,
            panel,
        }
    }

    /// Rebuild the same job's BlockInput serially (fresh scratch) and
    /// verify on a cold workspace — the oracle the pool must match.
    fn expected(gen: &mut XorShift128, kind: VerifierKind, seed: u64) -> BlockOutput {
        let job = mk_job(gen, kind, seed);
        let rng = job.rng;
        let slot0 = job.slot0;
        let tp = job.target_params;
        let mut scratch = Vec::new();
        let target_dists: Vec<Vec<Categorical>> = job
            .target_logits
            .iter()
            .map(|rows| {
                rows.iter()
                    .map(|lg| {
                        Categorical::from_logits_with_scratch(
                            lg,
                            tp.temperature,
                            tp.top_k,
                            &mut scratch,
                        )
                    })
                    .collect()
            })
            .collect();
        let input = BlockInput {
            draft_tokens: job.draft_tokens.clone(),
            draft_dists: job.draft_dists.clone(),
            target_dists,
        };
        CouplingWorkspace::new().verify_block_kind(kind, &input, &rng, slot0)
    }

    #[test]
    fn pool_matches_serial_oracle_across_batches_and_sizes() {
        for &workers in &[1usize, 2, 4] {
            let mut pool = VerifyPool::new(workers);
            // Several batches through the SAME pool: workspaces persist,
            // outcomes must not.
            for batch in 0..3u64 {
                let kinds = [VerifierKind::Gls, VerifierKind::SpecInfer, VerifierKind::Daliri];
                let jobs: Vec<VerifyJob> = (0..7u64)
                    .map(|i| {
                        let kind = kinds[(i % 3) as usize];
                        let mut gen = XorShift128::new(100 + batch * 10 + i);
                        mk_job(&mut gen, kind, batch * 100 + i)
                    })
                    .collect();
                let outs = pool.run_batch(jobs);
                for (i, out) in outs.iter().enumerate() {
                    let kind = kinds[i % 3];
                    let mut gen = XorShift128::new(100 + batch * 10 + i as u64);
                    let want = expected(&mut gen, kind, batch * 100 + i as u64);
                    assert_eq!(
                        *out, want,
                        "workers {workers} batch {batch} job {i} ({kind:?})"
                    );
                }
            }
        }
    }

    #[test]
    fn pool_handoff_panels_hit_on_worker_threads() {
        let mut pool = VerifyPool::new(2);
        let jobs: Vec<VerifyJob> = (0..6u64)
            .map(|i| {
                let mut gen = XorShift128::new(900 + i);
                mk_job(&mut gen, VerifierKind::Gls, 500 + i)
            })
            .collect();
        let _ = pool.run_batch(jobs);
        assert!(
            pool.drain_cache_hits() > 0,
            "draft-phase panels must be reused on worker threads"
        );
        assert_eq!(pool.drain_cache_hits(), 0, "drain must reset");
    }

    #[test]
    fn run_scoped_matches_pool() {
        let mk_batch = || -> Vec<VerifyJob> {
            (0..5u64)
                .map(|i| {
                    let mut gen = XorShift128::new(70 + i);
                    mk_job(&mut gen, VerifierKind::SpecTr, 40 + i)
                })
                .collect()
        };
        let mut pool = VerifyPool::new(3);
        let a = pool.run_batch(mk_batch());
        let (b, _hits) = VerifyPool::run_scoped(mk_batch(), 3);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut pool = VerifyPool::new(2);
        assert!(pool.run_batch(Vec::new()).is_empty());
        // Pool still alive and usable.
        let mut gen = XorShift128::new(1);
        let outs = pool.run_batch(vec![mk_job(&mut gen, VerifierKind::Daliri, 9)]);
        assert_eq!(outs.len(), 1);
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let mut pool = VerifyPool::new(4);
        let mut gen = XorShift128::new(2);
        let _ = pool.run_batch(vec![mk_job(&mut gen, VerifierKind::Gls, 3)]);
        drop(pool); // must not hang or leak parked threads
    }
}
