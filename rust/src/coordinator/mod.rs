//! Layer-3 serving framework: a vLLM-router-style coordinator whose
//! first-class feature is GLS multi-draft speculative decoding.
//!
//! Data flow:
//!
//! ```text
//! client → Router (round-robin / least-loaded)
//!        → per-worker DynamicBatcher (size/deadline)
//!        → Scheduler (continuous batching, KV admission)
//!        → SpecDecodeEngine (draft K×L → verify → accept/rollback)
//!        → Backend (PJRT artifacts or native SimLm)
//! ```
//!
//! All components are plain std threads + mpsc channels: deterministic,
//! easily audited, no async runtime required (none is available offline —
//! see DESIGN.md §2).

pub mod batcher;
pub mod config;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod pool;
pub mod router;
pub mod scheduler;
pub mod sequence;
pub mod server;

pub use config::{EngineConfig, PoolScope, ServerConfig, VerifyBackend};
pub use engine::SpecDecodeEngine;
pub use kv::PagedKvCache;
pub use metrics::EngineMetrics;
pub use pool::{BatchOutput, JobCut, PoolEngineStats, PoolError, VerifyJob, VerifyPool};
pub use router::{AdmitError, DrainPolicy, Router, RoutingPolicy};
pub use sequence::{CancelCause, CancelToken, Request, RequestResult, SeqPhase, SequenceState};
pub use server::Server;
