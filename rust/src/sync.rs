//! Poison-recovering lock discipline, shared across the coordinator and the
//! compression service.
//!
//! A `Mutex` poisons when a thread panics while holding the guard. Everywhere
//! in this crate the data behind a lock is either plain bookkeeping (counters,
//! queues of already-validated work) or is re-validated by the reader, so the
//! right response to poison is to keep going with the inner value — a panicked
//! *worker* must surface as a typed outcome (`WorkerOutcome::Panicked`,
//! `DecoderOutcome::Panicked`), never as a cascading `PoisonError` unwrap in an
//! unrelated thread. `coordinator/pool.rs` established this discipline; these
//! helpers make it the one blessed way to take a lock so the repo lint
//! (`analysis/repo_lint.rs`, rule `LockUnwrap`) can reject every raw
//! `.lock().unwrap()` in `rust/src`.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Acquire `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv`, recovering the re-acquired guard if the lock was poisoned
/// while we slept.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// Exclusive access through a `&mut Mutex<T>` (no other threads can hold the
/// lock), still recovering from a poison flag left by an earlier panic.
pub fn get_mut_recover<T>(m: &mut Mutex<T>) -> &mut T {
    m.get_mut().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    fn poison<T: Send + 'static>(m: &Arc<Mutex<T>>) {
        let m2 = Arc::clone(m);
        let h = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock on purpose");
        });
        assert!(h.join().is_err());
    }

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        poison(&m);
        assert!(m.is_poisoned());
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 9;
        assert_eq!(*lock_recover(&m), 9);
    }

    #[test]
    fn get_mut_recover_survives_poison() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        poison(&m);
        let mut m = Arc::try_unwrap(m).expect("sole owner");
        get_mut_recover(&mut m).push(4);
        assert_eq!(get_mut_recover(&mut m).len(), 4);
    }

    #[test]
    fn wait_recover_wakes_after_poisoning_notifier() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let notifier = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut g = m.lock().unwrap();
            *g = true;
            cv.notify_all();
            panic!("poison while the waiter sleeps");
        });
        let (m, cv) = &*pair;
        let mut g = lock_recover(m);
        while !*g {
            g = wait_recover(cv, g);
        }
        assert!(*g);
        assert!(notifier.join().is_err());
    }
}
