//! Repository-level configuration: artifact discovery and a tiny CLI
//! argument parser (no clap offline).

use std::path::PathBuf;

/// Locate the `artifacts/` directory: `$GLS_ARTIFACTS`, else walk up from
/// the current directory looking for `artifacts/manifest.txt`.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(dir) = std::env::var("GLS_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.join("manifest.txt").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let candidate = cur.join("artifacts");
        if candidate.join("manifest.txt").exists() {
            return Some(candidate);
        }
        if !cur.pop() {
            return None;
        }
    }
}

/// Check whether AOT artifacts are present (benches degrade to the native
/// backend with a notice when they are not).
pub fn artifacts_available() -> bool {
    artifacts_dir().is_some()
}

/// Minimal `--key value` / `--flag` argument parser.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: Vec<(String, String)>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.push((k.to_string(), v.to_string()));
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.push((name.to_string(), it.next().unwrap()));
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: cannot parse '{v}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = Args::parse(argv(&["serve", "--workers", "4", "--fast", "--k=8", "extra"]))
            .unwrap();
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("workers"), Some("4"));
        assert_eq!(a.get("k"), Some("8"));
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn later_options_override_earlier() {
        let a = Args::parse(argv(&["--k", "2", "--k", "5"])).unwrap();
        assert_eq!(a.get("k"), Some("5"));
    }

    #[test]
    fn get_parse_defaults_and_errors() {
        let a = Args::parse(argv(&["--n", "7"])).unwrap();
        assert_eq!(a.get_parse("n", 1usize).unwrap(), 7);
        assert_eq!(a.get_parse("missing", 3usize).unwrap(), 3);
        let b = Args::parse(argv(&["--n", "x"])).unwrap();
        assert!(b.get_parse::<usize>("n", 0).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(argv(&["--a", "--b"])).unwrap();
        assert!(a.has_flag("a") && a.has_flag("b"));
    }
}
