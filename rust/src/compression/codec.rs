//! The GLS coding scheme of §5.1 with the App. C importance-sampling
//! extension, generic over the source model.
//!
//! Protocol per block (one source symbol):
//!
//! 1. Shared randomness (both sides derive it from the same `CounterRng`):
//!    candidates `U_1..U_N ~ p_W` (the prior/marginal), bin labels
//!    `ℓ_1..ℓ_N ~ Unif{0..L_max-1}`, and exponentials `S_i^{(k)}`.
//! 2. Encoder sees `A = a`, computes unnormalized importance weights
//!    `λ_q,i = p_{W|A}(U_i | a) / p_W(U_i)` and selects
//!    `Y = argmin_i min_k S_i^{(k)} / λ_q,i` — GLS with the K decoders'
//!    exponentials. It transmits `M = ℓ_Y` (R = log2 L_max bits).
//! 3. Decoder k sees `T_k = t_k` and `M`, computes
//!    `λ_p,i^{(k)} = p_{W|T}(U_i | t_k) · 1{ℓ_i = M} / p_W(U_i)` and selects
//!    `X^{(k)} = argmin_i S_i^{(k)} / λ_p,i^{(k)}`, outputting `U_{X^{(k)}}`.
//!
//! Success means some decoder recovers the encoder's index. The baseline
//! (paper Fig. 2/4 "BL") replaces the K exponential sets with a single
//! shared set — decoders differ only through their side information, so
//! extra decoders help far less.

use crate::stats::rng::CounterRng;

/// Whether each decoder has its own exponential set (GLS) or all share one
/// (the paper's baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RandomnessMode {
    /// GLS: `S_i^{(k)}` independent across k (list coupling).
    Independent,
    /// Baseline: `S_i^{(k)} = S_i^{(0)}` for every k.
    Shared,
}

/// Source model plugged into the codec: prior sampling plus the two
/// importance-weight oracles.
pub trait SourceModel {
    /// Source realization type (what the encoder observes).
    type Source;
    /// Side-information type (what each decoder observes).
    type Side;
    /// Candidate/reconstruction type (`W` values).
    type Sample: Clone;

    /// Draw one candidate from the prior `p_W` using uniforms from `draw`.
    fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> Self::Sample;

    /// Unnormalized encoder weight `p_{W|A}(u | a) / p_W(u)`.
    fn weight_enc(&self, u: &Self::Sample, a: &Self::Source) -> f64;

    /// Unnormalized decoder weight `p_{W|T}(u | t) / p_W(u)`.
    fn weight_dec(&self, u: &Self::Sample, t: &Self::Side) -> f64;
}

/// Codec parameters: N candidates, L_max bins, K decoders.
#[derive(Clone, Copy, Debug)]
pub struct CodecConfig {
    pub n_samples: usize,
    pub l_max: u64,
    pub k_decoders: usize,
    pub seed: u64,
    pub mode: RandomnessMode,
}

impl CodecConfig {
    /// Rate in bits per source symbol.
    pub fn rate_bits(&self) -> f64 {
        (self.l_max as f64).log2()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_samples == 0 || self.l_max == 0 || self.k_decoders == 0 {
            return Err("n_samples, l_max, k_decoders must be ≥ 1".into());
        }
        Ok(())
    }
}

/// Result of encoding one source symbol.
#[derive(Clone, Debug)]
pub struct EncodeResult {
    /// Selected candidate index Y.
    pub index: usize,
    /// Transmitted message `M = ℓ_Y` (one of L_max values).
    pub message: u64,
}

/// The GLS (or baseline) codec over a source model.
pub struct GlsCodec<'a, M: SourceModel> {
    pub model: &'a M,
    pub cfg: CodecConfig,
    rng: CounterRng,
}

// Sub-stream tags: candidate draws, bin labels, exponentials.
const LANE_PRIOR: u64 = 1 << 32;
const LANE_BINS: u64 = (1 << 32) + 1;

impl<'a, M: SourceModel> GlsCodec<'a, M> {
    pub fn new(model: &'a M, cfg: CodecConfig) -> Self {
        cfg.validate().expect("codec config");
        Self { model, cfg, rng: CounterRng::new(cfg.seed) }
    }

    /// Materialize the shared candidate list and bin labels for a block.
    /// Both encoder and decoders call this with the same block id.
    pub fn shared_randomness(&self, block: u64) -> (Vec<M::Sample>, Vec<u64>) {
        let n = self.cfg.n_samples;
        let mut samples = Vec::with_capacity(n);
        for i in 0..n {
            let mut ctr = 0u64;
            let mut draw = || {
                let u = self.rng.uniform(block, LANE_PRIOR, (i as u64) * 1024 + ctr);
                ctr += 1;
                u
            };
            samples.push(self.model.sample_prior(&mut draw));
        }
        let bins: Vec<u64> = (0..n)
            .map(|i| {
                (self.rng.uniform(block, LANE_BINS, i as u64) * self.cfg.l_max as f64) as u64
                    % self.cfg.l_max
            })
            .collect();
        (samples, bins)
    }

    #[inline]
    fn exp_s(&self, block: u64, k: usize, i: usize) -> f64 {
        let lane = match self.cfg.mode {
            RandomnessMode::Independent => k as u64,
            RandomnessMode::Shared => 0,
        };
        self.rng.exponential(block, lane, i as u64)
    }

    /// Encoder: select Y via GLS over the K decoders' exponentials and emit
    /// the bin label message.
    pub fn encode(&self, a: &M::Source, block: u64) -> EncodeResult {
        let (samples, bins) = self.shared_randomness(block);
        let k_eff = match self.cfg.mode {
            RandomnessMode::Independent => self.cfg.k_decoders,
            RandomnessMode::Shared => 1,
        };
        let mut best = f64::INFINITY;
        let mut arg = 0usize;
        for (i, u) in samples.iter().enumerate() {
            let w = self.model.weight_enc(u, a);
            if w <= 0.0 {
                continue;
            }
            for k in 0..k_eff {
                let v = self.exp_s(block, k, i) / w;
                if v < best {
                    best = v;
                    arg = i;
                }
            }
        }
        EncodeResult { index: arg, message: bins[arg] }
    }

    /// Decoder k: select its candidate index given side info and message.
    pub fn decode(&self, t: &M::Side, message: u64, k: usize, block: u64) -> usize {
        assert!(k < self.cfg.k_decoders);
        let (samples, bins) = self.shared_randomness(block);
        let mut best = f64::INFINITY;
        let mut arg = usize::MAX;
        for (i, u) in samples.iter().enumerate() {
            if bins[i] != message {
                continue; // the 1{ℓ_i = M} mask
            }
            let w = self.model.weight_dec(u, t);
            if w <= 0.0 {
                continue;
            }
            let v = self.exp_s(block, k, i) / w;
            if v < best {
                best = v;
                arg = i;
            }
        }
        // All masked or zero-weight (pathological): fall back to the first
        // in-bin candidate so the decoder always outputs something.
        if arg == usize::MAX {
            arg = bins.iter().position(|&b| b == message).unwrap_or(0);
        }
        arg
    }

    /// Run one full block with K decoders: returns the encoder result, the
    /// decoder indices, and whether any decoder matched (the paper's
    /// success event `Y ∈ {X^{(1)}, …, X^{(K)}}`).
    pub fn roundtrip(&self, a: &M::Source, sides: &[M::Side], block: u64) -> (EncodeResult, Vec<usize>, bool) {
        assert_eq!(sides.len(), self.cfg.k_decoders);
        let enc = self.encode(a, block);
        let dec: Vec<usize> = sides
            .iter()
            .enumerate()
            .map(|(k, t)| self.decode(t, enc.message, k, block))
            .collect();
        let hit = dec.contains(&enc.index);
        (enc, dec, hit)
    }

    /// Candidate value by index (for reconstruction).
    pub fn candidate(&self, block: u64, index: usize) -> M::Sample {
        let (samples, _) = self.shared_randomness(block);
        samples[index].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trivial discrete model: W uniform on {0..9}, A = W observed through
    /// a noisy channel, T = W observed through a noisier channel. Weights
    /// are explicit categorical ratios — this exercises the §5.1 discrete
    /// scheme (no importance sampling needed).
    struct ToyDiscrete {
        flip_enc: f64,
        flip_dec: f64,
    }

    impl SourceModel for ToyDiscrete {
        type Source = usize;
        type Side = usize;
        type Sample = usize;

        fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> usize {
            (draw() * 10.0) as usize % 10
        }

        fn weight_enc(&self, u: &usize, a: &usize) -> f64 {
            // p_{W|A}(u|a): stay with prob 1-flip, else uniform.
            let p = if u == a { 1.0 - self.flip_enc } else { self.flip_enc / 9.0 };
            p / 0.1
        }

        fn weight_dec(&self, u: &usize, t: &usize) -> f64 {
            let p = if u == t { 1.0 - self.flip_dec } else { self.flip_dec / 9.0 };
            p / 0.1
        }
    }

    fn run_match_rate(mode: RandomnessMode, k: usize, l_max: u64, trials: u64) -> f64 {
        let model = ToyDiscrete { flip_enc: 0.1, flip_dec: 0.35 };
        let cfg = CodecConfig { n_samples: 64, l_max, k_decoders: k, seed: 5, mode };
        let codec = GlsCodec::new(&model, cfg);
        let rng = CounterRng::new(999);
        let mut hits = 0;
        for b in 0..trials {
            let a = (rng.uniform(b, 7, 0) * 10.0) as usize % 10;
            // Side infos: noisy copies of a.
            let sides: Vec<usize> = (0..k)
                .map(|kk| {
                    if rng.uniform(b, 8, kk as u64) < 0.65 {
                        a
                    } else {
                        (rng.uniform(b, 9, kk as u64) * 10.0) as usize % 10
                    }
                })
                .collect();
            let (_, _, hit) = codec.roundtrip(&a, &sides, b);
            if hit {
                hits += 1;
            }
        }
        hits as f64 / trials as f64
    }

    #[test]
    fn decoders_reproduce_shared_randomness() {
        let model = ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 };
        let cfg = CodecConfig {
            n_samples: 32,
            l_max: 4,
            k_decoders: 2,
            seed: 11,
            mode: RandomnessMode::Independent,
        };
        let codec = GlsCodec::new(&model, cfg);
        let (s1, b1) = codec.shared_randomness(3);
        let (s2, b2) = codec.shared_randomness(3);
        assert_eq!(s1, s2);
        assert_eq!(b1, b2);
        assert!(b1.iter().all(|&b| b < 4));
    }

    #[test]
    fn matching_improves_with_k_under_gls_but_not_baseline() {
        let trials = 1500;
        let gls_k1 = run_match_rate(RandomnessMode::Independent, 1, 4, trials);
        let gls_k4 = run_match_rate(RandomnessMode::Independent, 4, 4, trials);
        let bl_k1 = run_match_rate(RandomnessMode::Shared, 1, 4, trials);
        let bl_k4 = run_match_rate(RandomnessMode::Shared, 4, 4, trials);
        // GLS gains clearly with K.
        assert!(gls_k4 > gls_k1 + 0.05, "gls K4 {gls_k4} vs K1 {gls_k1}");
        // K = 1: the two schemes are the same algorithm.
        assert!((gls_k1 - bl_k1).abs() < 0.05, "{gls_k1} vs {bl_k1}");
        // At K = 4 GLS beats the shared-randomness baseline (the baseline
        // still gains a little from side-information diversity alone, as in
        // the paper's Fig. 2 where its curves move slightly with K).
        assert!(gls_k4 > bl_k4 + 0.01, "gls {gls_k4} <= baseline {bl_k4}");
    }

    #[test]
    fn matching_improves_with_rate() {
        let trials = 1500;
        let low = run_match_rate(RandomnessMode::Independent, 2, 2, trials);
        let high = run_match_rate(RandomnessMode::Independent, 2, 32, trials);
        assert!(high > low, "rate 5 bits {high} <= rate 1 bit {low}");
    }

    #[test]
    fn decoder_always_outputs_valid_index() {
        let model = ToyDiscrete { flip_enc: 0.05, flip_dec: 0.2 };
        let cfg = CodecConfig {
            n_samples: 16,
            l_max: 8,
            k_decoders: 3,
            seed: 2,
            mode: RandomnessMode::Independent,
        };
        let codec = GlsCodec::new(&model, cfg);
        for b in 0..200u64 {
            let enc = codec.encode(&3, b);
            assert!(enc.message < 8);
            for k in 0..3 {
                let idx = codec.decode(&5, enc.message, k, b);
                assert!(idx < 16);
            }
        }
    }

    #[test]
    fn rate_bits_is_log2_lmax() {
        let cfg = CodecConfig {
            n_samples: 8,
            l_max: 32,
            k_decoders: 1,
            seed: 0,
            mode: RandomnessMode::Independent,
        };
        assert!((cfg.rate_bits() - 5.0).abs() < 1e-12);
    }
}
