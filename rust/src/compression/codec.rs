//! The GLS coding scheme of §5.1 with the App. C importance-sampling
//! extension, generic over the source model.
//!
//! Protocol per block (one source symbol):
//!
//! 1. Shared randomness (both sides derive it from the same `CounterRng`):
//!    candidates `U_1..U_N ~ p_W` (the prior/marginal), bin labels
//!    `ℓ_1..ℓ_N ~ Unif{0..L_max-1}`, and exponentials `S_i^{(k)}`.
//! 2. Encoder sees `A = a`, computes unnormalized importance weights
//!    `λ_q,i = p_{W|A}(U_i | a) / p_W(U_i)` and selects
//!    `Y = argmin_i min_k S_i^{(k)} / λ_q,i` — GLS with the K decoders'
//!    exponentials. It transmits `M = ℓ_Y` (R = log2 L_max bits).
//! 3. Decoder k sees `T_k = t_k` and `M`, computes
//!    `λ_p,i^{(k)} = p_{W|T}(U_i | t_k) · 1{ℓ_i = M} / p_W(U_i)` and selects
//!    `X^{(k)} = argmin_i S_i^{(k)} / λ_p,i^{(k)}`, outputting `U_{X^{(k)}}`.
//!
//! Success means some decoder recovers the encoder's index. The baseline
//! (paper Fig. 2/4 "BL") replaces the K exponential sets with a single
//! shared set — decoders differ only through their side information, so
//! extra decoders help far less.
//!
//! # Kernel path vs scalar references
//!
//! The hot paths follow the coupling-kernel discipline of `spec/kernel.rs`:
//! shared randomness is materialized **once** per block into a
//! [`BlockContext`], races run out of a reusable [`CodecWorkspace`] over the
//! sparse support of usable weights with the per-(block, lane) RNG prefix
//! hoisted (`CounterRng::lane`), and the straightforward full re-derivation
//! paths are retained as [`GlsCodec::encode_scalar`] /
//! [`GlsCodec::decode_scalar`] parity references. The kernel path must stay
//! **bit-exact** with the scalar references: it visits the same usable
//! candidates in the same `(i asc, k inner)` order, compares with strict
//! `<`, and derives every variate from identical RNG coordinates —
//! `tests/compression.rs` enforces this across models, modes, and K the
//! same way `tests/kernel_parity.rs` does for the verifiers.
//!
//! # Degenerate weights
//!
//! Weights that are NaN, infinite, or ≤ 0 carry no usable mass and are
//! skipped *explicitly* on both paths. (The seed filtered only `w <= 0.0`:
//! NaN weights slipped through the filter and then silently lost every
//! `v < best` comparison, and an all-nonpositive block silently transmitted
//! candidate 0's bin.) If **no** candidate has a usable weight, the encoder
//! falls back deterministically to candidate 0 and says so via
//! [`EncodeResult::degenerate`]; a decoder in the same situation falls back
//! to the first in-bin candidate and reports [`DecodeOutcome::fallback`] —
//! the two fallbacks mirror each other and are regression-tested.

use crate::analysis::lanes;
use crate::spec::kernel::fill_exp_panel;
use crate::stats::rng::CounterRng;

/// Whether each decoder has its own exponential set (GLS) or all share one
/// (the paper's baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RandomnessMode {
    /// GLS: `S_i^{(k)}` independent across k (list coupling).
    Independent,
    /// Baseline: `S_i^{(k)} = S_i^{(0)}` for every k.
    Shared,
}

/// Source model plugged into the codec: prior sampling plus the two
/// importance-weight oracles.
pub trait SourceModel {
    /// Source realization type (what the encoder observes).
    type Source;
    /// Side-information type (what each decoder observes).
    type Side;
    /// Candidate/reconstruction type (`W` values).
    type Sample: Clone;

    /// Draw one candidate from the prior `p_W` using uniforms from `draw`.
    fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> Self::Sample;

    /// Unnormalized encoder weight `p_{W|A}(u | a) / p_W(u)`.
    fn weight_enc(&self, u: &Self::Sample, a: &Self::Source) -> f64;

    /// Unnormalized decoder weight `p_{W|T}(u | t) / p_W(u)`.
    fn weight_dec(&self, u: &Self::Sample, t: &Self::Side) -> f64;
}

/// Codec parameters: N candidates, L_max bins, K decoders.
#[derive(Clone, Copy, Debug)]
pub struct CodecConfig {
    pub n_samples: usize,
    pub l_max: u64,
    pub k_decoders: usize,
    pub seed: u64,
    pub mode: RandomnessMode,
}

impl CodecConfig {
    /// Rate in bits per source symbol.
    pub fn rate_bits(&self) -> f64 {
        (self.l_max as f64).log2()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_samples == 0 || self.l_max == 0 || self.k_decoders == 0 {
            return Err("n_samples, l_max, k_decoders must be ≥ 1".into());
        }
        // Full lane-layout check against the central registry: the
        // per-candidate prior block must fit its reserved span and all
        // regions (exp sets, bins, priors) must stay pairwise disjoint.
        lanes::check_codec_layout(self.n_samples, self.k_decoders).map_err(|e| e.to_string())
    }
}

/// Result of encoding one source symbol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EncodeResult {
    /// Selected candidate index Y.
    pub index: usize,
    /// Transmitted message `M = ℓ_Y` (one of L_max values).
    pub message: u64,
    /// True when **every** candidate weight was unusable (NaN, infinite or
    /// ≤ 0) and the encoder fell back deterministically to candidate 0 —
    /// the encoder-side mirror of [`DecodeOutcome::fallback`].
    pub degenerate: bool,
}

/// Result of one decoder's selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeOutcome {
    /// Selected candidate index `X^{(k)}`.
    pub index: usize,
    /// True when no in-bin candidate had a usable weight and the decoder
    /// fell back to the first in-bin candidate (candidate 0 if the bin is
    /// empty), so it always outputs *something*.
    pub fallback: bool,
}

/// Shared randomness of one block, materialized once: the candidate list
/// and bin labels both sides derive from the block id. Encoder, all K
/// decoders, and reconstruction read the same context — the seed paths
/// re-derived it K+2 times per block (once in `encode`, once per `decode`,
/// again in `candidate`), turning O(N) work into O((K+2)·N).
#[derive(Clone, Debug)]
pub struct BlockContext<S> {
    pub block: u64,
    pub samples: Vec<S>,
    pub bins: Vec<u64>,
}

/// Reusable race scratch for the kernel codec paths (the codec's analogue
/// of `spec::kernel::RaceScratch`): sparse support of usable candidates,
/// their weights, and the hoisted exponential panel. One workspace serves
/// any number of blocks without reallocating in steady state.
#[derive(Default)]
pub struct CodecWorkspace {
    /// Candidate indices with usable weight (ascending).
    support: Vec<u32>,
    /// Weight per support entry (same order).
    weights: Vec<f64>,
    /// Item-major `support.len() × rows` exponential panel
    /// (`panel[j * rows + k]` — see [`fill_exp_panel`]): the encoder race
    /// visits `(j outer, k inner)`, so its reads walk memory in order.
    panel: Vec<f64>,
}

impl CodecWorkspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The GLS (or baseline) codec over a source model.
pub struct GlsCodec<'a, M: SourceModel> {
    pub model: &'a M,
    pub cfg: CodecConfig,
    rng: CounterRng,
}

// Sub-stream tags (the `draft` coordinate of the block's counter RNG):
// exponential sets occupy lanes 0..K (one per decoder), bin labels live in
// LANE_BINS, and candidate i's prior draws get the dedicated lane
// PRIOR_LANE_BASE + i. The seed packed every candidate into one lane at a
// 1024-draw stride, so a source model drawing more than 1024 uniforms for
// one candidate silently read candidate i+1's counter coordinates,
// correlating supposedly independent candidates. A dedicated lane gives
// each candidate the full 2^64 counter space; PRIOR_DRAW_BUDGET is a debug
// tripwire (and the cap on n_samples, so lanes never alias LANE_BINS).
//
// The values are owned by the central lane registry (`analysis::lanes`,
// human-readable table in EXPERIMENTS.md §Analysis); `validate()` runs the
// registry's overlap/budget check so a layout change that introduces
// aliasing fails as a typed error, not silent correlation.
const LANE_BINS: u64 = lanes::CODEC_LANE_BINS;
const PRIOR_LANE_BASE: u64 = lanes::CODEC_PRIOR_LANE_BASE;
const PRIOR_DRAW_BUDGET: u64 = lanes::CODEC_PRIOR_DRAW_BUDGET;

/// A weight carries usable mass only if it is a strictly positive finite
/// number; NaN, ±∞ and anything ≤ 0 select nothing.
#[inline]
fn usable(w: f64) -> bool {
    w.is_finite() && w > 0.0
}

impl<'a, M: SourceModel> GlsCodec<'a, M> {
    pub fn new(model: &'a M, cfg: CodecConfig) -> Self {
        cfg.validate().expect("codec config");
        Self { model, cfg, rng: CounterRng::new(cfg.seed) }
    }

    /// Effective number of exponential sets racing on the encoder side.
    #[inline]
    fn k_eff(&self) -> usize {
        match self.cfg.mode {
            RandomnessMode::Independent => self.cfg.k_decoders,
            RandomnessMode::Shared => 1,
        }
    }

    /// RNG lane holding decoder k's exponential set.
    #[inline]
    fn exp_lane(&self, k: usize) -> u64 {
        match self.cfg.mode {
            RandomnessMode::Independent => k as u64,
            RandomnessMode::Shared => 0,
        }
    }

    /// Materialize the shared candidate list and bin labels for a block.
    /// Both encoder and decoders call this with the same block id. Hot
    /// paths should materialize once via [`Self::block_context`] and share
    /// the result.
    pub fn shared_randomness(&self, block: u64) -> (Vec<M::Sample>, Vec<u64>) {
        let n = self.cfg.n_samples;
        let mut samples = Vec::with_capacity(n);
        for i in 0..n {
            let lane = self.rng.lane(block, PRIOR_LANE_BASE + i as u64);
            let mut ctr = 0u64;
            let mut draw = || {
                debug_assert!(
                    ctr < PRIOR_DRAW_BUDGET,
                    "source model exhausted candidate {i}'s prior draw budget"
                );
                let u = lane.uniform(ctr);
                ctr += 1;
                u
            };
            samples.push(self.model.sample_prior(&mut draw));
        }
        let bin_lane = self.rng.lane(block, LANE_BINS);
        let bins: Vec<u64> = (0..n)
            .map(|i| (bin_lane.uniform(i as u64) * self.cfg.l_max as f64) as u64 % self.cfg.l_max)
            .collect();
        (samples, bins)
    }

    /// Materialize one block's shared randomness as a reusable context.
    pub fn block_context(&self, block: u64) -> BlockContext<M::Sample> {
        let (samples, bins) = self.shared_randomness(block);
        BlockContext { block, samples, bins }
    }

    #[inline]
    fn exp_s(&self, block: u64, k: usize, i: usize) -> f64 {
        self.rng.exponential(block, self.exp_lane(k), i as u64)
    }

    // -----------------------------------------------------------------
    // Scalar parity references (straightforward full re-derivation).
    // -----------------------------------------------------------------

    /// Scalar encoder reference: re-materializes the block's randomness and
    /// races with per-variate RNG coordinates. Kept for parity testing and
    /// as the throughput baseline; must stay bit-exact with
    /// [`Self::encode_with`].
    pub fn encode_scalar(&self, a: &M::Source, block: u64) -> EncodeResult {
        let (samples, bins) = self.shared_randomness(block);
        let k_eff = self.k_eff();
        let mut best = f64::INFINITY;
        let mut arg = usize::MAX;
        for (i, u) in samples.iter().enumerate() {
            let w = self.model.weight_enc(u, a);
            if !usable(w) {
                continue;
            }
            for k in 0..k_eff {
                let v = self.exp_s(block, k, i) / w;
                if v < best {
                    best = v;
                    arg = i;
                }
            }
        }
        match arg {
            usize::MAX => EncodeResult { index: 0, message: bins[0], degenerate: true },
            i => EncodeResult { index: i, message: bins[i], degenerate: false },
        }
    }

    /// Scalar decoder reference; must stay bit-exact with
    /// [`Self::decode_with`].
    pub fn decode_scalar(&self, t: &M::Side, message: u64, k: usize, block: u64) -> DecodeOutcome {
        assert!(k < self.cfg.k_decoders);
        let (samples, bins) = self.shared_randomness(block);
        let mut best = f64::INFINITY;
        let mut arg = usize::MAX;
        for (i, u) in samples.iter().enumerate() {
            if bins[i] != message {
                continue; // the 1{ℓ_i = M} mask
            }
            let w = self.model.weight_dec(u, t);
            if !usable(w) {
                continue;
            }
            let v = self.exp_s(block, k, i) / w;
            if v < best {
                best = v;
                arg = i;
            }
        }
        if arg == usize::MAX {
            // All masked or unusable: fall back to the first in-bin
            // candidate so the decoder always outputs something.
            let idx = bins.iter().position(|&b| b == message).unwrap_or(0);
            return DecodeOutcome { index: idx, fallback: true };
        }
        DecodeOutcome { index: arg, fallback: false }
    }

    // -----------------------------------------------------------------
    // Kernel paths (sparse race out of a reusable workspace).
    // -----------------------------------------------------------------

    /// Kernel encoder: sparse race over usable weights with the per-lane
    /// RNG prefix hoisted. The exponential panel is item-major — the same
    /// `(i asc, k inner)` order the race visits, so panel reads are
    /// sequential — and strict-`<` tie-breaking matches
    /// [`Self::encode_scalar`] bit-for-bit (variate *values* are pure
    /// functions of their coordinates, so layout cannot move an outcome).
    pub fn encode_with(
        &self,
        ws: &mut CodecWorkspace,
        ctx: &BlockContext<M::Sample>,
        a: &M::Source,
    ) -> EncodeResult {
        debug_assert_eq!(ctx.samples.len(), self.cfg.n_samples);
        let k_eff = self.k_eff();
        ws.support.clear();
        ws.weights.clear();
        for (i, u) in ctx.samples.iter().enumerate() {
            let w = self.model.weight_enc(u, a);
            if usable(w) {
                ws.support.push(i as u32);
                ws.weights.push(w);
            }
        }
        if ws.support.is_empty() {
            return EncodeResult { index: 0, message: ctx.bins[0], degenerate: true };
        }
        fill_exp_panel(&mut ws.panel, &self.rng, ctx.block, k_eff, &ws.support, |k| {
            self.exp_lane(k)
        });
        let mut best = f64::INFINITY;
        let mut arg = usize::MAX;
        for (j, &iu) in ws.support.iter().enumerate() {
            let w = ws.weights[j];
            for k in 0..k_eff {
                let v = ws.panel[j * k_eff + k] / w;
                if v < best {
                    best = v;
                    arg = iu as usize;
                }
            }
        }
        match arg {
            // Every ratio overflowed to +∞ (subnormal weights) — the scalar
            // reference lands on the same fallback.
            usize::MAX => EncodeResult { index: 0, message: ctx.bins[0], degenerate: true },
            i => EncodeResult { index: i, message: ctx.bins[i], degenerate: false },
        }
    }

    /// Kernel decoder k: sparse race over the in-bin usable candidates.
    pub fn decode_with(
        &self,
        ws: &mut CodecWorkspace,
        ctx: &BlockContext<M::Sample>,
        t: &M::Side,
        message: u64,
        k: usize,
    ) -> DecodeOutcome {
        assert!(k < self.cfg.k_decoders);
        debug_assert_eq!(ctx.samples.len(), self.cfg.n_samples);
        ws.support.clear();
        ws.weights.clear();
        for (i, u) in ctx.samples.iter().enumerate() {
            if ctx.bins[i] != message {
                continue;
            }
            let w = self.model.weight_dec(u, t);
            if usable(w) {
                ws.support.push(i as u32);
                ws.weights.push(w);
            }
        }
        if ws.support.is_empty() {
            let idx = ctx.bins.iter().position(|&b| b == message).unwrap_or(0);
            return DecodeOutcome { index: idx, fallback: true };
        }
        let lane = self.exp_lane(k);
        fill_exp_panel(&mut ws.panel, &self.rng, ctx.block, 1, &ws.support, |_| lane);
        let mut best = f64::INFINITY;
        let mut arg = usize::MAX;
        for (j, &iu) in ws.support.iter().enumerate() {
            let v = ws.panel[j] / ws.weights[j];
            if v < best {
                best = v;
                arg = iu as usize;
            }
        }
        if arg == usize::MAX {
            let idx = ctx.bins.iter().position(|&b| b == message).unwrap_or(0);
            return DecodeOutcome { index: idx, fallback: true };
        }
        DecodeOutcome { index: arg, fallback: false }
    }

    /// One full block against an already-materialized context: encoder plus
    /// all K decoders out of one workspace.
    pub fn roundtrip_with(
        &self,
        ws: &mut CodecWorkspace,
        ctx: &BlockContext<M::Sample>,
        a: &M::Source,
        sides: &[M::Side],
    ) -> (EncodeResult, Vec<usize>, bool) {
        assert_eq!(sides.len(), self.cfg.k_decoders);
        let enc = self.encode_with(ws, ctx, a);
        let dec: Vec<usize> = sides
            .iter()
            .enumerate()
            .map(|(k, t)| self.decode_with(ws, ctx, t, enc.message, k).index)
            .collect();
        let hit = dec.contains(&enc.index);
        (enc, dec, hit)
    }

    // -----------------------------------------------------------------
    // Convenience wrappers (kernel-backed, one-shot).
    // -----------------------------------------------------------------

    /// Encoder: select Y via GLS over the K decoders' exponentials and emit
    /// the bin label message.
    pub fn encode(&self, a: &M::Source, block: u64) -> EncodeResult {
        let ctx = self.block_context(block);
        self.encode_with(&mut CodecWorkspace::new(), &ctx, a)
    }

    /// Decoder k: select its candidate index given side info and message.
    pub fn decode(&self, t: &M::Side, message: u64, k: usize, block: u64) -> usize {
        let ctx = self.block_context(block);
        self.decode_with(&mut CodecWorkspace::new(), &ctx, t, message, k).index
    }

    /// Run one full block with K decoders: returns the encoder result, the
    /// decoder indices, and whether any decoder matched (the paper's
    /// success event `Y ∈ {X^{(1)}, …, X^{(K)}}`). Materializes the shared
    /// randomness once for the whole block.
    pub fn roundtrip(
        &self,
        a: &M::Source,
        sides: &[M::Side],
        block: u64,
    ) -> (EncodeResult, Vec<usize>, bool) {
        let ctx = self.block_context(block);
        self.roundtrip_with(&mut CodecWorkspace::new(), &ctx, a, sides)
    }

    /// Candidate value by index (for reconstruction). One-shot: hot paths
    /// should read `BlockContext::samples` instead of re-materializing.
    pub fn candidate(&self, block: u64, index: usize) -> M::Sample {
        let (samples, _) = self.shared_randomness(block);
        samples[index].clone()
    }
}

/// Toy discrete source shared by the codec's unit, conformance, and parity
/// suites: W uniform on {0..9}, encoder/decoder observe W through symmetric
/// flip channels, weights are explicit categorical ratios — the §5.1
/// discrete scheme with no importance sampling needed.
#[derive(Clone, Copy, Debug)]
pub struct ToyDiscrete {
    pub flip_enc: f64,
    pub flip_dec: f64,
}

impl ToyDiscrete {
    /// `p_{W|A}(·|a)` as an explicit 10-way categorical (the chi-square
    /// conformance target for the encoder-selected candidate marginal).
    pub fn enc_posterior(&self, a: usize) -> Vec<f64> {
        (0..10)
            .map(|u| if u == a { 1.0 - self.flip_enc } else { self.flip_enc / 9.0 })
            .collect()
    }
}

impl SourceModel for ToyDiscrete {
    type Source = usize;
    type Side = usize;
    type Sample = usize;

    fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> usize {
        (draw() * 10.0) as usize % 10
    }

    fn weight_enc(&self, u: &usize, a: &usize) -> f64 {
        // p_{W|A}(u|a): stay with prob 1-flip, else uniform.
        let p = if u == a { 1.0 - self.flip_enc } else { self.flip_enc / 9.0 };
        p / 0.1
    }

    fn weight_dec(&self, u: &usize, t: &usize) -> f64 {
        let p = if u == t { 1.0 - self.flip_dec } else { self.flip_dec / 9.0 };
        p / 0.1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_match_rate(mode: RandomnessMode, k: usize, l_max: u64, trials: u64) -> f64 {
        let model = ToyDiscrete { flip_enc: 0.1, flip_dec: 0.35 };
        let cfg = CodecConfig { n_samples: 64, l_max, k_decoders: k, seed: 5, mode };
        let codec = GlsCodec::new(&model, cfg);
        let rng = CounterRng::new(999);
        let mut hits = 0;
        for b in 0..trials {
            let a = (rng.uniform(b, 7, 0) * 10.0) as usize % 10;
            // Side infos: noisy copies of a.
            let sides: Vec<usize> = (0..k)
                .map(|kk| {
                    if rng.uniform(b, 8, kk as u64) < 0.65 {
                        a
                    } else {
                        (rng.uniform(b, 9, kk as u64) * 10.0) as usize % 10
                    }
                })
                .collect();
            let (_, _, hit) = codec.roundtrip(&a, &sides, b);
            if hit {
                hits += 1;
            }
        }
        hits as f64 / trials as f64
    }

    #[test]
    fn decoders_reproduce_shared_randomness() {
        let model = ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 };
        let cfg = CodecConfig {
            n_samples: 32,
            l_max: 4,
            k_decoders: 2,
            seed: 11,
            mode: RandomnessMode::Independent,
        };
        let codec = GlsCodec::new(&model, cfg);
        let (s1, b1) = codec.shared_randomness(3);
        let (s2, b2) = codec.shared_randomness(3);
        assert_eq!(s1, s2);
        assert_eq!(b1, b2);
        assert!(b1.iter().all(|&b| b < 4));
    }

    #[test]
    fn matching_improves_with_k_under_gls_but_not_baseline() {
        let trials = 1500;
        let gls_k1 = run_match_rate(RandomnessMode::Independent, 1, 4, trials);
        let gls_k4 = run_match_rate(RandomnessMode::Independent, 4, 4, trials);
        let bl_k1 = run_match_rate(RandomnessMode::Shared, 1, 4, trials);
        let bl_k4 = run_match_rate(RandomnessMode::Shared, 4, 4, trials);
        // GLS gains clearly with K.
        assert!(gls_k4 > gls_k1 + 0.05, "gls K4 {gls_k4} vs K1 {gls_k1}");
        // K = 1: the two schemes are the same algorithm.
        assert!((gls_k1 - bl_k1).abs() < 0.05, "{gls_k1} vs {bl_k1}");
        // At K = 4 GLS beats the shared-randomness baseline (the baseline
        // still gains a little from side-information diversity alone, as in
        // the paper's Fig. 2 where its curves move slightly with K).
        assert!(gls_k4 > bl_k4 + 0.01, "gls {gls_k4} <= baseline {bl_k4}");
    }

    #[test]
    fn matching_improves_with_rate() {
        let trials = 1500;
        let low = run_match_rate(RandomnessMode::Independent, 2, 2, trials);
        let high = run_match_rate(RandomnessMode::Independent, 2, 32, trials);
        assert!(high > low, "rate 5 bits {high} <= rate 1 bit {low}");
    }

    #[test]
    fn decoder_always_outputs_valid_index() {
        let model = ToyDiscrete { flip_enc: 0.05, flip_dec: 0.2 };
        let cfg = CodecConfig {
            n_samples: 16,
            l_max: 8,
            k_decoders: 3,
            seed: 2,
            mode: RandomnessMode::Independent,
        };
        let codec = GlsCodec::new(&model, cfg);
        for b in 0..200u64 {
            let enc = codec.encode(&3, b);
            assert!(enc.message < 8);
            for k in 0..3 {
                let idx = codec.decode(&5, enc.message, k, b);
                assert!(idx < 16);
            }
        }
    }

    #[test]
    fn rate_bits_is_log2_lmax() {
        let cfg = CodecConfig {
            n_samples: 8,
            l_max: 32,
            k_decoders: 1,
            seed: 0,
            mode: RandomnessMode::Independent,
        };
        assert!((cfg.rate_bits() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn kernel_path_matches_scalar_reference() {
        let model = ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 };
        for mode in [RandomnessMode::Independent, RandomnessMode::Shared] {
            let cfg = CodecConfig { n_samples: 48, l_max: 4, k_decoders: 3, seed: 21, mode };
            let codec = GlsCodec::new(&model, cfg);
            let mut ws = CodecWorkspace::new();
            for b in 0..60u64 {
                let a = (b % 10) as usize;
                let ctx = codec.block_context(b);
                let enc = codec.encode_with(&mut ws, &ctx, &a);
                assert_eq!(enc, codec.encode_scalar(&a, b));
                for k in 0..3 {
                    let t = ((b + k as u64) % 10) as usize;
                    let dec = codec.decode_with(&mut ws, &ctx, &t, enc.message, k);
                    assert_eq!(dec, codec.decode_scalar(&t, enc.message, k, b));
                }
            }
        }
    }

    /// Model whose encoder weight is NaN on one candidate value and honest
    /// elsewhere — exercises the degenerate-weight filter.
    struct NanOn {
        inner: ToyDiscrete,
        poison: usize,
    }

    impl SourceModel for NanOn {
        type Source = usize;
        type Side = usize;
        type Sample = usize;

        fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> usize {
            self.inner.sample_prior(draw)
        }

        fn weight_enc(&self, u: &usize, a: &usize) -> f64 {
            if *u == self.poison {
                f64::NAN
            } else {
                self.inner.weight_enc(u, a)
            }
        }

        fn weight_dec(&self, u: &usize, t: &usize) -> f64 {
            self.inner.weight_dec(u, t)
        }
    }

    #[test]
    fn nan_weights_never_selected_and_paths_agree() {
        let inner = ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 };
        let model = NanOn { inner, poison: 7 };
        let cfg = CodecConfig {
            n_samples: 64,
            l_max: 4,
            k_decoders: 2,
            seed: 31,
            mode: RandomnessMode::Independent,
        };
        let codec = GlsCodec::new(&model, cfg);
        let mut ws = CodecWorkspace::new();
        for b in 0..100u64 {
            let a = 7usize; // the poisoned value is also the likeliest one
            let ctx = codec.block_context(b);
            let enc = codec.encode_with(&mut ws, &ctx, &a);
            assert_eq!(enc, codec.encode_scalar(&a, b));
            assert!(!enc.degenerate);
            assert_ne!(ctx.samples[enc.index], 7, "selected a NaN-weight candidate");
        }
    }

    /// Model with no usable weight anywhere: encoder weight is NaN on even
    /// candidates and 0 on odd ones, decoder weight always −1.
    struct AllDegenerate;

    impl SourceModel for AllDegenerate {
        type Source = usize;
        type Side = usize;
        type Sample = usize;

        fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> usize {
            (draw() * 10.0) as usize % 10
        }

        fn weight_enc(&self, u: &usize, _a: &usize) -> f64 {
            if u % 2 == 0 {
                f64::NAN
            } else {
                0.0
            }
        }

        fn weight_dec(&self, _u: &usize, _t: &usize) -> f64 {
            -1.0
        }
    }

    #[test]
    fn degenerate_block_falls_back_explicitly_on_both_sides() {
        let cfg = CodecConfig {
            n_samples: 32,
            l_max: 4,
            k_decoders: 2,
            seed: 13,
            mode: RandomnessMode::Independent,
        };
        let codec = GlsCodec::new(&AllDegenerate, cfg);
        let mut ws = CodecWorkspace::new();
        for b in 0..50u64 {
            let ctx = codec.block_context(b);
            let enc = codec.encode_with(&mut ws, &ctx, &0);
            assert!(enc.degenerate, "all-unusable weights must be explicit");
            assert_eq!(enc.index, 0);
            assert_eq!(enc.message, ctx.bins[0]);
            assert_eq!(enc, codec.encode_scalar(&0, b));
            // Decoder mirror: nothing usable in the bin → typed fallback to
            // the first in-bin candidate.
            let dec = codec.decode_with(&mut ws, &ctx, &0, enc.message, 0);
            assert!(dec.fallback);
            let expect = ctx.bins.iter().position(|&x| x == enc.message).unwrap();
            assert_eq!(dec.index, expect);
            assert_eq!(dec, codec.decode_scalar(&0, enc.message, 0, b));
        }
    }

    /// Source model that burns `draws` uniforms per candidate and keeps the
    /// first — used to pin down per-candidate stream isolation.
    struct Hungry {
        draws: usize,
        keep_last: bool,
    }

    impl SourceModel for Hungry {
        type Source = usize;
        type Side = usize;
        type Sample = u64;

        fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> u64 {
            let mut first = 0.0;
            let mut last = 0.0;
            for j in 0..self.draws {
                let u = draw();
                if j == 0 {
                    first = u;
                }
                last = u;
            }
            let kept = if self.keep_last { last } else { first };
            (kept * 1e12) as u64
        }

        fn weight_enc(&self, _u: &u64, _a: &usize) -> f64 {
            1.0
        }

        fn weight_dec(&self, _u: &u64, _t: &usize) -> f64 {
            1.0
        }
    }

    #[test]
    fn hungry_source_models_do_not_alias_neighbour_candidates() {
        // Seed bug: candidate i's draws lived at counter i*1024 + ctr, so a
        // model drawing 1025 uniforms read candidate i+1's first coordinate
        // — `frugal[i+1]` would equal `hungry[i]` exactly. Dedicated lanes
        // make every candidate's stream independent of its neighbours'.
        let cfg = CodecConfig {
            n_samples: 16,
            l_max: 2,
            k_decoders: 1,
            seed: 3,
            mode: RandomnessMode::Independent,
        };
        let hungry = Hungry { draws: 1025, keep_last: true };
        let frugal = Hungry { draws: 1, keep_last: false };
        let (h, _) = GlsCodec::new(&hungry, cfg).shared_randomness(0);
        let (f, _) = GlsCodec::new(&frugal, cfg).shared_randomness(0);
        for i in 0..15 {
            assert_ne!(h[i], f[i + 1], "candidate {i} aliased its neighbour's stream");
        }
        // And the first draw is the same coordinate no matter how many
        // draws follow it: frugal candidates are a prefix of hungry ones.
        let hungry_first = Hungry { draws: 1025, keep_last: false };
        let (hf, _) = GlsCodec::new(&hungry_first, cfg).shared_randomness(0);
        assert_eq!(hf, f);
    }

    #[test]
    fn shared_and_independent_agree_at_k1() {
        let model = ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 };
        let base = CodecConfig {
            n_samples: 64,
            l_max: 8,
            k_decoders: 1,
            seed: 17,
            mode: RandomnessMode::Independent,
        };
        let ind = GlsCodec::new(&model, base);
        let sh = GlsCodec::new(&model, CodecConfig { mode: RandomnessMode::Shared, ..base });
        for b in 0..100u64 {
            let a = (b % 10) as usize;
            let t = ((b + 3) % 10) as usize;
            assert_eq!(ind.roundtrip(&a, &[t], b), sh.roundtrip(&a, &[t], b));
        }
    }
}
