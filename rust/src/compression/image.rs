//! Distributed image compression (paper §5.2 "Lossy compression on MNIST",
//! Fig. 3/4, Tables 8/9).
//!
//! Data substitution (DESIGN.md §2): MNIST is unavailable offline, so the
//! dataset is procedurally rendered 28×28 stroke glyphs with the same
//! source/side-information split — source = right half (28×14), side
//! information = a 7×7 crop from the left half at a random position, drawn
//! independently per decoder.
//!
//! The latent codec behind `p_{W|A}` / `p_{W|T}` is abstracted as
//! [`LatentCodecModel`] with two implementations:
//!
//! * [`AnalyticVae`] — a linear-Gaussian codec *fit in Rust* on a
//!   calibration set (ridge regressions for the side→latent estimator and
//!   the (latent, side)→pixels decoder). Fast, artifact-free; drives the
//!   Fig. 4 bench.
//! * `runtime::PjrtVae` — the AOT-compiled β-VAE artifacts (the paper's
//!   actual architecture, miniaturized), exercised by the integration
//!   tests and the compression example when artifacts are present.

use std::sync::Arc;

use crate::stats::dist::normal_logpdf;
use crate::stats::rng::XorShift128;

use super::codec::{CodecConfig, RandomnessMode, SourceModel};
use super::service::{run_blocks_scalar, run_blocks_workspace, BatchOutput, CompressionRequest};

pub const IMG: usize = 28;
pub const HALF_W: usize = 14;
pub const SRC_PIXELS: usize = IMG * HALF_W; // right half
pub const CROP: usize = 7;
pub const CROP_PIXELS: usize = CROP * CROP;

/// Render `n` synthetic digit-like glyphs (row-major 28×28 in [0,1]).
pub fn synthetic_digits(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = XorShift128::new(seed);
    // 10 class prototypes: 4 strokes each, spanning both halves so the
    // left half is informative about the right (the correlation the
    // side-information decoder exploits).
    let mut protos: Vec<Vec<(f32, f32, f32, f32)>> = Vec::with_capacity(10);
    let mut prng = XorShift128::new(0xD161_7000);
    for _ in 0..10 {
        let strokes: Vec<(f32, f32, f32, f32)> = (0..4)
            .map(|_| {
                let x0 = 4.0 + 8.0 * prng.next_f64() as f32;
                let y0 = 3.0 + 22.0 * prng.next_f64() as f32;
                let x1 = 14.0 + 10.0 * prng.next_f64() as f32;
                let y1 = 3.0 + 22.0 * prng.next_f64() as f32;
                (x0, y0, x1, y1)
            })
            .collect();
        protos.push(strokes);
    }
    (0..n)
        .map(|_| {
            let class = rng.next_below(10) as usize;
            let dx = rng.next_f64() as f32 * 4.0 - 2.0;
            let dy = rng.next_f64() as f32 * 4.0 - 2.0;
            let mut img = vec![0.0f32; IMG * IMG];
            for &(x0, y0, x1, y1) in &protos[class] {
                let (x0, y0, x1, y1) = (x0 + dx, y0 + dy, x1 + dx, y1 + dy);
                // Render the segment with Gaussian falloff.
                for py in 0..IMG {
                    for px in 0..IMG {
                        let d = point_segment_dist(px as f32, py as f32, x0, y0, x1, y1);
                        let v = (-d * d / 1.6).exp();
                        let idx = py * IMG + px;
                        img[idx] = (img[idx] + v).min(1.0);
                    }
                }
            }
            // Mild pixel noise.
            for p in img.iter_mut() {
                *p = (*p + 0.05 * rng.next_f64() as f32).clamp(0.0, 1.0);
            }
            img
        })
        .collect()
}

fn point_segment_dist(px: f32, py: f32, x0: f32, y0: f32, x1: f32, y1: f32) -> f32 {
    let (dx, dy) = (x1 - x0, y1 - y0);
    let len2 = dx * dx + dy * dy;
    let t = if len2 <= 1e-9 { 0.0 } else { ((px - x0) * dx + (py - y0) * dy) / len2 };
    let t = t.clamp(0.0, 1.0);
    let (cx, cy) = (x0 + t * dx, y0 + t * dy);
    ((px - cx) * (px - cx) + (py - cy) * (py - cy)).sqrt()
}

/// Right half of an image (the compression source).
pub fn right_half(img: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(SRC_PIXELS);
    for y in 0..IMG {
        out.extend_from_slice(&img[y * IMG + HALF_W..y * IMG + IMG]);
    }
    out
}

/// 7×7 crop from the left half at (cx, cy); cx ∈ [0, HALF_W - CROP].
pub fn left_crop(img: &[f32], cx: usize, cy: usize) -> Vec<f32> {
    assert!(cx + CROP <= HALF_W && cy + CROP <= IMG);
    let mut out = Vec::with_capacity(CROP_PIXELS);
    for y in 0..CROP {
        for x in 0..CROP {
            out.push(img[(cy + y) * IMG + cx + x]);
        }
    }
    out
}

/// Latent codec interface: everything §5.1 needs from the β-VAE stack.
pub trait LatentCodecModel {
    fn latent_dim(&self) -> usize;
    /// Encoder network: `p_{W|A}(·|a) = N(mu, diag(var))`.
    fn encode(&self, source: &[f32]) -> (Vec<f64>, Vec<f64>);
    /// Projection network: side crop → feature vector.
    fn project(&self, side: &[f32]) -> Vec<f64>;
    /// Estimator network: `log p_{W|T}(w|t) − log p_W(w)` (unnormalized ok).
    fn estimate_logratio(&self, w: &[f64], side_feat: &[f64]) -> f64;
    /// Decoder network: reconstruction of the source half.
    fn decode(&self, w: &[f64], side_feat: &[f64]) -> Vec<f32>;
}

// ---------------------------------------------------------------------------
// Analytic (linear-Gaussian) codec fit by ridge regression.
// ---------------------------------------------------------------------------

/// Linear-Gaussian stand-in for the β-VAE, fit on a calibration set.
///
/// * encoder: `mu = P·a` (P row-normalized random projection, calibrated to
///   unit marginal variance), `var = σ²` ("β" dial);
/// * estimator: per-latent-dim ridge regression from side features;
/// * decoder: ridge regression from (latent ⊕ side) to pixels.
pub struct AnalyticVae {
    latent: usize,
    proj: Vec<Vec<f64>>,      // latent × SRC_PIXELS
    proj_means: Vec<f64>,     // centering offsets per latent dim
    enc_var: f64,             // σ²_{W|A}
    est_w: Vec<Vec<f64>>,     // latent × (CROP_PIXELS+1) regression weights
    est_var: Vec<f64>,        // residual variance per latent dim
    dec_w: Vec<Vec<f64>>,     // SRC_PIXELS × (latent+CROP_PIXELS+1)
}

impl AnalyticVae {
    /// Fit on `calib` images. `enc_var` plays the role of the paper's β
    /// sweep: smaller = higher-fidelity encoder target.
    pub fn fit(calib: &[Vec<f32>], latent: usize, enc_var: f64, seed: u64) -> Self {
        assert!(!calib.is_empty() && latent >= 1 && enc_var > 0.0);
        let mut rng = XorShift128::new(seed);
        // Random projection rows.
        let mut proj: Vec<Vec<f64>> = (0..latent)
            .map(|_| (0..SRC_PIXELS).map(|_| rng.next_f64() * 2.0 - 1.0).collect())
            .collect();
        // Calibrate each row to zero-mean unit variance over the set.
        let sources: Vec<Vec<f32>> = calib.iter().map(|img| right_half(img)).collect();
        for row in proj.iter_mut() {
            let vals: Vec<f64> = sources.iter().map(|s| dot_f32(row, s)).collect();
            let m = vals.iter().sum::<f64>() / vals.len() as f64;
            let v = vals.iter().map(|x| (x - m).powi(2)).sum::<f64>() / vals.len() as f64;
            let scale = 1.0 / v.sqrt().max(1e-9);
            row.iter_mut().for_each(|w| *w *= scale);
            // Fold the mean shift into an implicit centering: subtract m*scale
            // by appending to... keep simple: center via the first weight on a
            // constant — instead adjust: we center by subtracting mean during
            // encode using stored offsets.
            // (offset handled below via `proj_mean`)
        }
        let proj_mean: Vec<f64> = proj
            .iter()
            .map(|row| {
                sources.iter().map(|s| dot_f32(row, s)).sum::<f64>() / sources.len() as f64
            })
            .collect();
        // Latent "truth" per calibration image (mean of p_{W|A}).
        let latents: Vec<Vec<f64>> = sources
            .iter()
            .map(|s| {
                (0..latent)
                    .map(|d| dot_f32(&proj[d], s) - proj_mean[d])
                    .collect()
            })
            .collect();

        // Side features: center crop (calibration uses the central crop; at
        // run time crops vary, which adds realistic estimator noise).
        let sides: Vec<Vec<f64>> = calib
            .iter()
            .map(|img| {
                left_crop(img, (HALF_W - CROP) / 2, (IMG - CROP) / 2)
                    .iter()
                    .map(|&x| x as f64)
                    .collect()
            })
            .collect();

        // Estimator: latent_d ~ ridge(side features).
        let mut est_w = Vec::with_capacity(latent);
        let mut est_var = Vec::with_capacity(latent);
        for d in 0..latent {
            let ys: Vec<f64> = latents.iter().map(|l| l[d]).collect();
            let w = ridge(&sides, &ys, 1e-2);
            let resid: f64 = sides
                .iter()
                .zip(&ys)
                .map(|(s, &y)| {
                    let pred = predict(&w, s);
                    (y - pred) * (y - pred)
                })
                .sum::<f64>()
                / sides.len() as f64;
            est_w.push(w);
            est_var.push((resid + enc_var).max(1e-4));
            // p_{W|T} variance: estimator residual plus the encoder channel.
        }

        // Decoder: pixel ~ ridge(latent ⊕ side features).
        let feats: Vec<Vec<f64>> = latents
            .iter()
            .zip(&sides)
            .map(|(l, s)| l.iter().chain(s.iter()).copied().collect())
            .collect();
        let mut dec_w = Vec::with_capacity(SRC_PIXELS);
        for px in 0..SRC_PIXELS {
            let ys: Vec<f64> = sources.iter().map(|s| s[px] as f64).collect();
            dec_w.push(ridge(&feats, &ys, 1e-2));
        }

        Self { latent, proj, proj_means: proj_mean, enc_var, est_w, est_var, dec_w }
    }

    /// Adjust the encoder channel variance (the paper's β sweep dial).
    pub fn set_enc_var(&mut self, v: f64) {
        assert!(v > 0.0);
        self.enc_var = v;
        for ev in self.est_var.iter_mut() {
            *ev = ev.max(1e-4);
        }
    }
}

fn dot_f32(w: &[f64], x: &[f32]) -> f64 {
    w.iter().zip(x).map(|(a, &b)| a * b as f64).sum()
}

fn predict(w: &[f64], x: &[f64]) -> f64 {
    // w = [coef..., intercept]
    w[..x.len()].iter().zip(x).map(|(a, b)| a * b).sum::<f64>() + w[x.len()]
}

/// Ridge regression y ~ X·w + b via normal equations (small dims only).
fn ridge(xs: &[Vec<f64>], ys: &[f64], lambda: f64) -> Vec<f64> {
    let n = xs.len();
    let d = xs[0].len() + 1; // + intercept
    let mut a = vec![vec![0.0; d]; d];
    let mut b = vec![0.0; d];
    for (x, &y) in xs.iter().zip(ys) {
        let xe: Vec<f64> = x.iter().copied().chain(std::iter::once(1.0)).collect();
        for i in 0..d {
            b[i] += xe[i] * y;
            for j in 0..d {
                a[i][j] += xe[i] * xe[j];
            }
        }
    }
    for i in 0..d {
        a[i][i] += lambda * n as f64;
    }
    solve_spd(a, b)
}

/// Pivot magnitude with NaN ranked below every real value (including 0), so
/// a NaN entry can never be *chosen* as pivot while real rows remain, and
/// `max_by` stays total instead of panicking mid-elimination.
fn pivot_key(v: f64) -> f64 {
    if v.is_nan() {
        -1.0
    } else {
        v.abs()
    }
}

/// Gaussian elimination with partial pivoting (small dense systems).
fn solve_spd(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        let piv = (col..n)
            .max_by(|&i, &j| pivot_key(a[i][col]).total_cmp(&pivot_key(a[j][col])))
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let diag = a[col][col];
        for row in col + 1..n {
            let f = a[row][col] / diag;
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in row + 1..n {
            acc -= a[row][k] * x[k];
        }
        x[row] = acc / a[row][row];
    }
    x
}

impl LatentCodecModel for AnalyticVae {
    fn latent_dim(&self) -> usize {
        self.latent
    }

    fn encode(&self, source: &[f32]) -> (Vec<f64>, Vec<f64>) {
        let mu: Vec<f64> = (0..self.latent)
            .map(|d| dot_f32(&self.proj[d], source) - self.proj_means[d])
            .collect();
        (mu, vec![self.enc_var; self.latent])
    }

    fn project(&self, side: &[f32]) -> Vec<f64> {
        side.iter().map(|&x| x as f64).collect()
    }

    fn estimate_logratio(&self, w: &[f64], side_feat: &[f64]) -> f64 {
        (0..self.latent)
            .map(|d| {
                let m = predict(&self.est_w[d], side_feat);
                normal_logpdf(w[d], m, self.est_var[d]) - normal_logpdf(w[d], 0.0, 1.0)
            })
            .sum()
    }

    fn decode(&self, w: &[f64], side_feat: &[f64]) -> Vec<f32> {
        let feat: Vec<f64> = w.iter().chain(side_feat.iter()).copied().collect();
        self.dec_w
            .iter()
            .map(|wrow| predict(wrow, &feat).clamp(0.0, 1.0) as f32)
            .collect()
    }
}

// ---------------------------------------------------------------------------
// SourceModel adapter: plugs any LatentCodecModel into the GLS codec.
// ---------------------------------------------------------------------------

/// Precomputed per-image encoder state: the Source type of the adapter.
#[derive(Clone, Debug)]
pub struct EncState {
    pub mu: Vec<f64>,
    pub var: Vec<f64>,
}

/// Draw one latent candidate from the standard-normal prior.
fn latent_sample_prior(dim: usize, draw: &mut dyn FnMut() -> f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(dim);
    while out.len() < dim {
        let (z0, z1) = crate::stats::dist::box_muller(draw(), draw());
        out.push(z0);
        if out.len() < dim {
            out.push(z1);
        }
    }
    out
}

/// `p_{W|A}(u|a) / p_W(u)` in latent space (diagonal Gaussians).
fn latent_weight_enc(u: &[f64], a: &EncState) -> f64 {
    let lp: f64 = (0..u.len())
        .map(|d| normal_logpdf(u[d], a.mu[d], a.var[d]) - normal_logpdf(u[d], 0.0, 1.0))
        .sum();
    lp.exp()
}

/// SourceModel over latent space: prior `p_W = N(0, I)`.
pub struct LatentSource<'m, M: LatentCodecModel> {
    pub model: &'m M,
}

impl<'m, M: LatentCodecModel> SourceModel for LatentSource<'m, M> {
    type Source = EncState;
    type Side = Vec<f64>; // projected side features
    type Sample = Vec<f64>; // latent w

    fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> Vec<f64> {
        latent_sample_prior(self.model.latent_dim(), draw)
    }

    fn weight_enc(&self, u: &Vec<f64>, a: &EncState) -> f64 {
        latent_weight_enc(u, a)
    }

    fn weight_dec(&self, u: &Vec<f64>, t: &Vec<f64>) -> f64 {
        self.model.estimate_logratio(u, t).exp()
    }
}

/// Owned (`Arc`-backed) twin of [`LatentSource`] for the multi-decoder
/// [`super::service::CompressionServer`], whose persistent workers need a
/// `'static` model. Same weights, same prior — bit-exact with the borrowed
/// adapter.
pub struct SharedLatentSource<M: LatentCodecModel> {
    pub model: Arc<M>,
}

impl<M: LatentCodecModel> SourceModel for SharedLatentSource<M> {
    type Source = EncState;
    type Side = Vec<f64>;
    type Sample = Vec<f64>;

    fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> Vec<f64> {
        latent_sample_prior(self.model.latent_dim(), draw)
    }

    fn weight_enc(&self, u: &Vec<f64>, a: &EncState) -> f64 {
        latent_weight_enc(u, a)
    }

    fn weight_dec(&self, u: &Vec<f64>, t: &Vec<f64>) -> f64 {
        self.model.estimate_logratio(u, t).exp()
    }
}

/// One cell of Tables 8/9: (K, L_max) → best MSE over the hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct ImagePoint {
    pub k: usize,
    pub l_max: u64,
    pub n_samples: usize,
    pub enc_var: f64,
    pub match_rate: f64,
    pub mse: f64,
}

/// Materialize one service request per image: the encoder state plus K
/// independent side crops. The crop RNG is sequential over (image, k), so
/// every runner consuming the same `(images, k, seed)` sees identical
/// inputs.
pub fn image_requests<M: LatentCodecModel>(
    model: &M,
    images: &[Vec<f32>],
    k: usize,
    seed: u64,
) -> Vec<CompressionRequest<EncState, Vec<f64>>> {
    let mut crop_rng = XorShift128::new(seed ^ 0xC209);
    images
        .iter()
        .enumerate()
        .map(|(b, img)| {
            let source = right_half(img);
            let (mu, var) = model.encode(&source);
            // Independent side crops per decoder.
            let sides: Vec<Vec<f64>> = (0..k)
                .map(|_| {
                    let cx = crop_rng.next_below((HALF_W - CROP + 1) as u64) as usize;
                    let cy = crop_rng.next_below((IMG - CROP + 1) as u64) as usize;
                    model.project(&left_crop(img, cx, cy))
                })
                .collect();
            CompressionRequest { block: b as u64, source: EncState { mu, var }, sides }
        })
        .collect()
}

/// Fold a batch's results into a table cell: match rate plus the best
/// decoder's pixel-space reconstruction error.
pub fn image_point<M: LatentCodecModel>(
    model: &M,
    cfg: CodecConfig,
    images: &[Vec<f32>],
    requests: &[CompressionRequest<EncState, Vec<f64>>],
    batch: &BatchOutput<Vec<f64>>,
) -> ImagePoint {
    let mut hits = 0u64;
    let mut total_mse = 0.0;
    for ((img, req), blk) in images.iter().zip(requests).zip(&batch.blocks) {
        let source = right_half(img);
        if blk.hit {
            hits += 1;
        }
        // Reconstruct with each surviving decoder's latent; keep the best.
        let best = blk
            .decoded
            .iter()
            .zip(&req.sides)
            .filter_map(|(d, side)| {
                d.index().map(|idx| {
                    let recon = model.decode(&blk.ctx.samples[idx], side);
                    mse(&recon, &source)
                })
            })
            .fold(f64::INFINITY, f64::min);
        total_mse += best;
    }
    ImagePoint {
        k: cfg.k_decoders,
        l_max: cfg.l_max,
        n_samples: cfg.n_samples,
        enc_var: 0.0,
        match_rate: hits as f64 / images.len() as f64,
        mse: total_mse / images.len() as f64,
    }
}

/// Run the image pipeline on `images`, one block per image (kernel path:
/// one context materialization per block, reused workspace).
pub fn run_image<M: LatentCodecModel>(
    model: &M,
    images: &[Vec<f32>],
    k: usize,
    l_max: u64,
    n_samples: usize,
    seed: u64,
    mode: RandomnessMode,
) -> ImagePoint {
    let cfg = CodecConfig { n_samples, l_max, k_decoders: k, seed, mode };
    let requests = image_requests(model, images, k, seed);
    let src = LatentSource { model };
    let batch = run_blocks_workspace(&src, cfg, &requests);
    image_point(model, cfg, images, &requests, &batch)
}

/// Scalar twin of [`run_image`] on the retained seed-style paths — the
/// throughput benches' baseline; must agree with the kernel runner
/// bit-for-bit.
pub fn run_image_scalar<M: LatentCodecModel>(
    model: &M,
    images: &[Vec<f32>],
    k: usize,
    l_max: u64,
    n_samples: usize,
    seed: u64,
    mode: RandomnessMode,
) -> ImagePoint {
    let cfg = CodecConfig { n_samples, l_max, k_decoders: k, seed, mode };
    let requests = image_requests(model, images, k, seed);
    let src = LatentSource { model };
    let batch = run_blocks_scalar(&src, cfg, &requests);
    image_point(model, cfg, images, &requests, &batch)
}

pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spd_pivot_select_survives_nan_input() {
        // Column 0 holds {NaN, 3.0}: max_by over partial_cmp used to panic
        // here. The NaN still propagates through elimination arithmetic (the
        // system is garbage-in), but the solver must return, not unwind.
        let a = vec![vec![f64::NAN, 1.0], vec![3.0, 0.5]];
        let b = vec![1.0, 2.0];
        let x = solve_spd(a, b);
        assert_eq!(x.len(), 2);
    }

    #[test]
    fn pivot_key_ranks_nan_below_zero() {
        assert!(pivot_key(f64::NAN) < pivot_key(0.0));
        assert!(pivot_key(-2.0) > pivot_key(1.0));
        assert_eq!(pivot_key(-0.5), 0.5);
    }

    #[test]
    fn solve_spd_unchanged_on_well_posed_systems() {
        // 2x2: [[2,1],[1,3]] x = [3,5] -> x = [4/5, 7/5].
        let x = solve_spd(vec![vec![2.0, 1.0], vec![1.0, 3.0]], vec![3.0, 5.0]);
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn synthetic_digits_have_structure() {
        let imgs = synthetic_digits(20, 3);
        assert_eq!(imgs.len(), 20);
        for img in &imgs {
            assert_eq!(img.len(), IMG * IMG);
            let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
            assert!(mean > 0.01 && mean < 0.9, "degenerate image, mean {mean}");
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        // Determinism.
        assert_eq!(synthetic_digits(3, 7), synthetic_digits(3, 7));
    }

    #[test]
    fn halves_and_crops_shaped_right() {
        let img = synthetic_digits(1, 1).pop().unwrap();
        assert_eq!(right_half(&img).len(), SRC_PIXELS);
        assert_eq!(left_crop(&img, 0, 0).len(), CROP_PIXELS);
        assert_eq!(left_crop(&img, HALF_W - CROP, IMG - CROP).len(), CROP_PIXELS);
    }

    #[test]
    fn ridge_recovers_linear_map() {
        let mut rng = XorShift128::new(9);
        let xs: Vec<Vec<f64>> = (0..200).map(|_| vec![rng.next_f64(), rng.next_f64()]).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x[0] - 2.0 * x[1] + 0.5).collect();
        let w = ridge(&xs, &ys, 1e-6);
        assert!((w[0] - 3.0).abs() < 0.05, "{w:?}");
        assert!((w[1] + 2.0).abs() < 0.05);
        assert!((w[2] - 0.5).abs() < 0.05);
    }

    #[test]
    fn analytic_vae_side_info_is_informative() {
        let imgs = synthetic_digits(150, 5);
        let vae = AnalyticVae::fit(&imgs[..100], 4, 0.05, 11);
        // The estimator should predict the latent better than the prior:
        // mean |w - pred| < mean |w| on held-out images.
        let mut err_est = 0.0;
        let mut err_prior = 0.0;
        for img in &imgs[100..] {
            let (mu, _) = vae.encode(&right_half(img));
            let side = vae.project(&left_crop(img, 3, 10));
            for d in 0..4 {
                let pred = predict(&vae.est_w[d], &side);
                err_est += (mu[d] - pred).abs();
                err_prior += mu[d].abs();
            }
        }
        assert!(err_est < err_prior, "estimator no better than prior: {err_est} vs {err_prior}");
    }

    #[test]
    fn image_pipeline_improves_with_k_and_beats_baseline() {
        let imgs = synthetic_digits(180, 21);
        let vae = AnalyticVae::fit(&imgs[..120], 4, 0.05, 13);
        let eval = &imgs[120..];
        let k1 = run_image(&vae, eval, 1, 4, 128, 3, RandomnessMode::Independent);
        let k4 = run_image(&vae, eval, 4, 4, 128, 3, RandomnessMode::Independent);
        let bl4 = run_image(&vae, eval, 4, 4, 128, 3, RandomnessMode::Shared);
        assert!(k4.match_rate > k1.match_rate, "{} vs {}", k4.match_rate, k1.match_rate);
        assert!(
            k4.match_rate > bl4.match_rate,
            "gls {} vs baseline {}",
            k4.match_rate,
            bl4.match_rate
        );
        assert!(k4.mse <= k1.mse + 1e-3, "more decoders should not hurt MSE");
    }

    #[test]
    fn scalar_and_kernel_runners_agree_bitwise() {
        let imgs = synthetic_digits(60, 4);
        let vae = AnalyticVae::fit(&imgs[..40], 4, 0.05, 7);
        let eval = &imgs[40..];
        for mode in [RandomnessMode::Independent, RandomnessMode::Shared] {
            let kern = run_image(&vae, eval, 2, 4, 64, 9, mode);
            let scal = run_image_scalar(&vae, eval, 2, 4, 64, 9, mode);
            assert_eq!(kern.match_rate.to_bits(), scal.match_rate.to_bits());
            assert_eq!(kern.mse.to_bits(), scal.mse.to_bits());
        }
    }

    #[test]
    fn decode_is_bounded() {
        let imgs = synthetic_digits(60, 2);
        let vae = AnalyticVae::fit(&imgs, 4, 0.05, 3);
        let side = vae.project(&left_crop(&imgs[0], 0, 0));
        let recon = vae.decode(&vec![0.3, -0.2, 1.0, 0.0], &side);
        assert_eq!(recon.len(), SRC_PIXELS);
        assert!(recon.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
}
