//! Proposition 4: empirical evaluation of the error-probability bound
//! `Pr[fail] ≤ 1 − E[(1 + 2^{i(W;A|T)} / (K L_max))^{-1}]` against the
//! measured failure rate of the Gaussian codec.

use crate::spec::lml::proposition4_success_bound;
use crate::stats::dist::box_muller;
use crate::stats::rng::CounterRng;

use super::gaussian::GaussianSource;

/// Monte-Carlo estimate of the Prop. 4 success lower bound for the
/// Gaussian source: samples (A, W, T) from the joint model and averages
/// the bound integrand.
pub fn gaussian_prop4_bound(
    src: GaussianSource,
    k: usize,
    l_max: u64,
    samples: usize,
    seed: u64,
) -> f64 {
    let rng = CounterRng::new(seed);
    let mut densities = Vec::with_capacity(samples);
    for i in 0..samples as u64 {
        let (za, zw) = box_muller(rng.uniform(i, 0, 0), rng.uniform(i, 0, 1));
        let (zt, _) = box_muller(rng.uniform(i, 0, 2), rng.uniform(i, 0, 3));
        let a = za;
        let w = a + zw * src.var_w_given_a.sqrt();
        let t = a + zt * src.var_t_given_a.sqrt();
        densities.push(src.info_density(w, a, t));
    }
    proposition4_success_bound(&densities, k, l_max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::codec::RandomnessMode;
    use crate::compression::gaussian::run_gaussian;

    #[test]
    fn bound_rises_with_k_and_rate() {
        let s = GaussianSource::paper_default(0.01);
        let b_base = gaussian_prop4_bound(s, 1, 4, 4000, 1);
        let b_k = gaussian_prop4_bound(s, 4, 4, 4000, 1);
        let b_rate = gaussian_prop4_bound(s, 1, 64, 4000, 1);
        assert!(b_k > b_base);
        assert!(b_rate > b_base);
        assert!(b_base > 0.0 && b_rate <= 1.0);
    }

    #[test]
    fn empirical_success_dominates_bound() {
        // The codec (with large enough N) must succeed at least as often as
        // Prop. 4's lower bound predicts.
        let s = GaussianSource::paper_default(0.005);
        for &(k, l_max) in &[(1usize, 8u64), (2, 8), (4, 16)] {
            let bound = gaussian_prop4_bound(s, k, l_max, 6000, 3);
            let point =
                run_gaussian(s, k, l_max, 1 << 11, 400, 17, RandomnessMode::Independent);
            assert!(
                point.match_rate + 0.05 >= bound,
                "K={k} L={l_max}: empirical {} < bound {}",
                point.match_rate,
                bound
            );
        }
    }
}
