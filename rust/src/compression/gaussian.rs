//! The synthetic Gaussian source of paper §5.2 / App. D.2.
//!
//! Source `A ~ N(0,1)`; encoder target `p_{W|A}(·|a) = N(a, σ²_{W|A})`;
//! side information `T_k = A + ζ_k`, `ζ_k ~ N(0, σ²_{T|A})`. Everything a
//! decoder needs is analytic:
//!
//! * marginal `p_W = N(0, σ²_W)`, `σ²_W = 1 + σ²_{W|A}`;
//! * decoder target `p_{W|T}(·|t) = N(t/σ²_T, σ²_W − 1/σ²_T)`,
//!   `σ²_T = 1 + σ²_{T|A}`;
//! * MMSE reconstruction
//!   `g(w, t) = (σ²_ζ w + σ²_η t) / (σ²_η + σ²_ζ + σ²_η σ²_ζ)`.

use crate::stats::dist::{box_muller, normal_logpdf};
use crate::stats::rng::CounterRng;

use super::codec::{CodecConfig, RandomnessMode, SourceModel};
use super::service::{run_blocks_scalar, run_blocks_workspace, BatchOutput, CompressionRequest};

/// Gaussian source/side-information model.
#[derive(Clone, Copy, Debug)]
pub struct GaussianSource {
    /// Encoder distortion channel variance σ²_{W|A} (= σ²_η).
    pub var_w_given_a: f64,
    /// Side-information noise variance σ²_{T|A} (= σ²_ζ).
    pub var_t_given_a: f64,
}

impl GaussianSource {
    pub fn new(var_w_given_a: f64, var_t_given_a: f64) -> Self {
        assert!(var_w_given_a > 0.0 && var_t_given_a > 0.0);
        Self { var_w_given_a, var_t_given_a }
    }

    /// Paper defaults: σ²_{T|A} = 0.5.
    pub fn paper_default(var_w_given_a: f64) -> Self {
        Self::new(var_w_given_a, 0.5)
    }

    pub fn var_w(&self) -> f64 {
        1.0 + self.var_w_given_a
    }

    pub fn var_t(&self) -> f64 {
        1.0 + self.var_t_given_a
    }

    /// Decoder target distribution parameters `(mean, var)` given `t`.
    pub fn w_given_t(&self, t: f64) -> (f64, f64) {
        (t / self.var_t(), self.var_w() - 1.0 / self.var_t())
    }

    /// MMSE estimate of A from (w, t) — App. D.2.
    pub fn mmse(&self, w: f64, t: f64) -> f64 {
        let ve = self.var_w_given_a; // σ²_η
        let vz = self.var_t_given_a; // σ²_ζ
        (vz * w + ve * t) / (ve + vz + ve * vz)
    }

    /// Conditional information density `i(w; a | t)` in **bits**
    /// (Prop. 4's exponent): `log2 p_{W|A}(w|a) − log2 p_{W|T}(w|t)`.
    pub fn info_density(&self, w: f64, a: f64, t: f64) -> f64 {
        let (mt, vt) = self.w_given_t(t);
        (normal_logpdf(w, a, self.var_w_given_a) - normal_logpdf(w, mt, vt))
            / std::f64::consts::LN_2
    }
}

impl SourceModel for GaussianSource {
    type Source = f64; // a
    type Side = f64; // t_k
    type Sample = f64; // candidate w

    fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> f64 {
        let (z, _) = box_muller(draw(), draw());
        z * self.var_w().sqrt()
    }

    fn weight_enc(&self, u: &f64, a: &f64) -> f64 {
        // p_{W|A}(u|a) / p_W(u), computed in log space for stability.
        (normal_logpdf(*u, *a, self.var_w_given_a) - normal_logpdf(*u, 0.0, self.var_w())).exp()
    }

    fn weight_dec(&self, u: &f64, t: &f64) -> f64 {
        let (m, v) = self.w_given_t(*t);
        (normal_logpdf(*u, m, v) - normal_logpdf(*u, 0.0, self.var_w())).exp()
    }
}

/// One experiment point: match probability and distortion at a given
/// (K, L_max, σ²_{W|A}) configuration — a cell of Tables 5/6.
#[derive(Clone, Copy, Debug)]
pub struct GaussianPoint {
    pub k: usize,
    pub l_max: u64,
    pub var_w_given_a: f64,
    pub match_rate: f64,
    /// Mean squared error of the best decoder's MMSE reconstruction.
    pub mse: f64,
    /// Distortion in dB: 10 log10(mse).
    pub mse_db: f64,
}

/// Source symbol and the K side observations for one block — the same
/// counter-RNG coordinates whichever runner (kernel, scalar, service)
/// consumes them, so every path sees identical inputs.
pub fn gaussian_block_inputs(src: GaussianSource, k: usize, seed: u64, b: u64) -> (f64, Vec<f64>) {
    let noise = CounterRng::new(seed ^ 0xABCD_EF01);
    let (za, _) = box_muller(noise.uniform(b, 0, 0), noise.uniform(b, 0, 1));
    let a = za;
    let sides: Vec<f64> = (0..k)
        .map(|kk| {
            let (z, _) = box_muller(
                noise.uniform(b, 1, kk as u64 * 2),
                noise.uniform(b, 1, kk as u64 * 2 + 1),
            );
            a + z * src.var_t_given_a.sqrt()
        })
        .collect();
    (a, sides)
}

/// Materialize `trials` blocks of Gaussian service requests.
pub fn gaussian_requests(
    src: GaussianSource,
    k: usize,
    trials: u64,
    seed: u64,
) -> Vec<CompressionRequest<f64, f64>> {
    (0..trials)
        .map(|b| {
            let (a, sides) = gaussian_block_inputs(src, k, seed, b);
            CompressionRequest { block: b, source: a, sides }
        })
        .collect()
}

/// Fold a batch's results into a table cell: match rate plus the best
/// decoder's MMSE reconstruction error (paper: "choose the estimate with
/// the least distortion among all decoders").
pub fn gaussian_point(
    src: GaussianSource,
    cfg: CodecConfig,
    requests: &[CompressionRequest<f64, f64>],
    batch: &BatchOutput<f64>,
) -> GaussianPoint {
    let mut hits = 0u64;
    let mut sq_err = 0.0f64;
    for (req, blk) in requests.iter().zip(&batch.blocks) {
        if blk.hit {
            hits += 1;
        }
        let a = req.source;
        let best = blk
            .decoded
            .iter()
            .zip(&req.sides)
            .filter_map(|(d, &t)| {
                d.index().map(|idx| {
                    let w = blk.ctx.samples[idx];
                    let a_hat = src.mmse(w, t);
                    (a - a_hat) * (a - a_hat)
                })
            })
            .fold(f64::INFINITY, f64::min);
        sq_err += best;
    }
    let trials = requests.len() as f64;
    let mse = sq_err / trials;
    GaussianPoint {
        k: cfg.k_decoders,
        l_max: cfg.l_max,
        var_w_given_a: src.var_w_given_a,
        match_rate: hits as f64 / trials,
        mse,
        mse_db: 10.0 * mse.log10(),
    }
}

/// Run `trials` independent source symbols through the Gaussian pipeline
/// (kernel path: one context materialization per block, reused workspace).
pub fn run_gaussian(
    src: GaussianSource,
    k: usize,
    l_max: u64,
    n_samples: usize,
    trials: u64,
    seed: u64,
    mode: RandomnessMode,
) -> GaussianPoint {
    let cfg = CodecConfig { n_samples, l_max, k_decoders: k, seed, mode };
    let requests = gaussian_requests(src, k, trials, seed);
    let batch = run_blocks_workspace(&src, cfg, &requests);
    gaussian_point(src, cfg, &requests, &batch)
}

/// Scalar twin of [`run_gaussian`] on the retained seed-style paths —
/// the throughput benches' baseline; must agree with the kernel runner
/// bit-for-bit.
pub fn run_gaussian_scalar(
    src: GaussianSource,
    k: usize,
    l_max: u64,
    n_samples: usize,
    trials: u64,
    seed: u64,
    mode: RandomnessMode,
) -> GaussianPoint {
    let cfg = CodecConfig { n_samples, l_max, k_decoders: k, seed, mode };
    let requests = gaussian_requests(src, k, trials, seed);
    let batch = run_blocks_scalar(&src, cfg, &requests);
    gaussian_point(src, cfg, &requests, &batch)
}

/// Sweep σ²_{W|A} over the paper's grid and keep the best (lowest-MSE)
/// configuration — the paper's per-(K, L_max) optimization (App. D.2).
pub fn best_over_distortion_grid(
    k: usize,
    l_max: u64,
    n_samples: usize,
    trials: u64,
    seed: u64,
    mode: RandomnessMode,
) -> GaussianPoint {
    // Paper grid: {0.01, 0.008, 0.006, 0.005, 0.003, 0.002, 0.001}.
    const GRID: [f64; 7] = [0.01, 0.008, 0.006, 0.005, 0.003, 0.002, 0.001];
    best_point(GRID.iter().map(|&v| {
        run_gaussian(GaussianSource::paper_default(v), k, l_max, n_samples, trials, seed, mode)
    }))
}

/// Lowest-MSE point of a non-empty sweep. A NaN MSE (a degenerate sweep cell)
/// must lose to every real measurement instead of panicking the whole sweep,
/// so the comparator gives NaN an explicit "worst" rank.
fn best_point<I: Iterator<Item = GaussianPoint>>(points: I) -> GaussianPoint {
    points.min_by(|a, b| mse_order(a.mse, b.mse)).expect("empty sweep")
}

/// Total order on MSE values with NaN ranked strictly worst. `total_cmp`
/// alone is not enough: x86 can produce *negative* NaN (e.g. `0.0 / 0.0`),
/// which `total_cmp` orders below -inf — i.e. best. Rank NaN explicitly.
fn mse_order(a: f64, b: f64) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(&b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(mse: f64) -> GaussianPoint {
        GaussianPoint {
            k: 2,
            l_max: 4,
            var_w_given_a: 0.01,
            match_rate: 0.5,
            mse,
            mse_db: 10.0 * mse.log10(),
        }
    }

    #[test]
    fn min_mse_select_ranks_nan_strictly_worst() {
        // Both NaN signs: x86 0.0/0.0 yields negative NaN, which raw
        // total_cmp would rank *best*. Neither may win while a real
        // measurement exists, and neither may panic the sweep.
        let neg_nan = f64::NAN.copysign(-1.0);
        let best = best_point([point(f64::NAN), point(0.25), point(neg_nan), point(0.5)].into_iter());
        assert_eq!(best.mse, 0.25);
        // An all-NaN sweep still returns (degenerate, but not a panic).
        let degenerate = best_point([point(f64::NAN), point(neg_nan)].into_iter());
        assert!(degenerate.mse.is_nan());
    }

    #[test]
    fn mse_order_is_a_total_order_on_the_grid() {
        use std::cmp::Ordering;
        assert_eq!(mse_order(0.1, 0.2), Ordering::Less);
        assert_eq!(mse_order(0.2, 0.1), Ordering::Greater);
        assert_eq!(mse_order(0.1, 0.1), Ordering::Equal);
        assert_eq!(mse_order(f64::NAN, f64::INFINITY), Ordering::Greater);
        assert_eq!(mse_order(f64::NEG_INFINITY, f64::NAN), Ordering::Less);
        assert_eq!(mse_order(f64::NAN, f64::NAN), Ordering::Equal);
    }

    #[test]
    fn conditional_distribution_matches_paper_formula() {
        let s = GaussianSource::paper_default(0.01);
        let (m, v) = s.w_given_t(1.5);
        assert!((m - 1.5 / 1.5).abs() < 1e-12); // σ²_T = 1.5
        assert!((v - (1.01 - 1.0 / 1.5)).abs() < 1e-12);
        assert!(v > 0.0);
    }

    #[test]
    fn mmse_reduces_to_known_limits() {
        let s = GaussianSource::new(1e-9, 0.5);
        // Perfect W (σ²_η → 0): estimate ≈ w.
        assert!((s.mmse(0.7, -2.0) - 0.7).abs() < 1e-6);
        let s = GaussianSource::new(0.5, 1e-9);
        // Perfect T: estimate ≈ t.
        assert!((s.mmse(3.0, 0.2) - 0.2).abs() < 1e-6);
    }

    #[test]
    fn weights_are_likelihood_ratios() {
        let s = GaussianSource::paper_default(0.01);
        // At u = a the encoder weight is large; far away it vanishes.
        assert!(s.weight_enc(&0.5, &0.5) > s.weight_enc(&2.0, &0.5));
        assert!(s.weight_enc(&5.0, &0.0) < 1e-6);
        // Decoder weight peaks near t/σ²_T.
        let (m, _) = s.w_given_t(1.0);
        assert!(s.weight_dec(&m, &1.0) > s.weight_dec(&(m + 2.0), &1.0));
    }

    #[test]
    fn match_rate_increases_with_k_and_rate() {
        let n = 1 << 9;
        let t = 250;
        let base = run_gaussian(GaussianSource::paper_default(0.005), 1, 4, n, t, 3, RandomnessMode::Independent);
        let more_k = run_gaussian(GaussianSource::paper_default(0.005), 4, 4, n, t, 3, RandomnessMode::Independent);
        let more_rate = run_gaussian(GaussianSource::paper_default(0.005), 1, 64, n, t, 3, RandomnessMode::Independent);
        assert!(more_k.match_rate > base.match_rate, "{} vs {}", more_k.match_rate, base.match_rate);
        assert!(more_rate.match_rate > base.match_rate, "{} vs {}", more_rate.match_rate, base.match_rate);
    }

    #[test]
    fn gls_beats_baseline_at_k4_low_rate() {
        let n = 1 << 9;
        let t = 300;
        let gls = run_gaussian(GaussianSource::paper_default(0.005), 4, 2, n, t, 7, RandomnessMode::Independent);
        let bl = run_gaussian(GaussianSource::paper_default(0.005), 4, 2, n, t, 7, RandomnessMode::Shared);
        assert!(
            gls.match_rate > bl.match_rate + 0.03,
            "gls {} vs baseline {}",
            gls.match_rate,
            bl.match_rate
        );
        assert!(gls.mse <= bl.mse * 1.2, "gls mse {} way above baseline {}", gls.mse, bl.mse);
    }

    #[test]
    fn distortion_improves_with_rate() {
        let n = 1 << 9;
        let t = 300;
        let low = run_gaussian(GaussianSource::paper_default(0.005), 2, 2, n, t, 5, RandomnessMode::Independent);
        let high = run_gaussian(GaussianSource::paper_default(0.005), 2, 64, n, t, 5, RandomnessMode::Independent);
        assert!(high.mse < low.mse, "high-rate mse {} >= low-rate {}", high.mse, low.mse);
    }

    #[test]
    fn scalar_and_kernel_runners_agree_bitwise() {
        for mode in [RandomnessMode::Independent, RandomnessMode::Shared] {
            let kern = run_gaussian(GaussianSource::paper_default(0.005), 3, 4, 1 << 8, 100, 11, mode);
            let scal =
                run_gaussian_scalar(GaussianSource::paper_default(0.005), 3, 4, 1 << 8, 100, 11, mode);
            assert_eq!(kern.match_rate.to_bits(), scal.match_rate.to_bits());
            assert_eq!(kern.mse.to_bits(), scal.mse.to_bits());
        }
    }

    #[test]
    fn info_density_zero_when_t_equals_knowledge() {
        // If p_{W|A} and p_{W|T} coincide (impossible exactly here), the
        // density is finite and small near the overlap; sanity: it is
        // larger when the side info is misleading.
        let s = GaussianSource::paper_default(0.01);
        let good = s.info_density(1.0, 1.0, 1.5); // t consistent with a
        let bad = s.info_density(1.0, 1.0, -3.0); // t way off
        assert!(bad > good);
    }
}
