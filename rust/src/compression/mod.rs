//! Paper §5: distributed lossy compression with independent side
//! information at K decoders, built on GLS.
//!
//! * [`codec`] — the generic GLS coding scheme (§5.1) with the importance-
//!   sampling extension to continuous sources (App. C), plus the shared-
//!   randomness baseline the paper compares against.
//! * [`gaussian`] — the synthetic Gaussian source: analytic `p_{W|T}`,
//!   MMSE reconstruction (App. D.2), rate-distortion sweeps (Fig. 2,
//!   Tables 5/6).
//! * [`image`] — distributed image compression (Fig. 3/4, Tables 8/9):
//!   synthetic-digit sources with a latent-variable codec; the latent
//!   model is either the AOT-compiled β-VAE artifacts or an analytic
//!   linear-Gaussian stand-in for artifact-free tests/benches.
//! * [`bounds`] — Proposition 4 error-bound evaluation.
//! * [`service`] — the batched multi-decoder compression service: one
//!   encoder fans each block's message out to K persistent decode workers
//!   (the `VerifyPool` worker discipline applied to the paper's
//!   distributed topology), bit-exact with the serial references.
//!
//! The codec hot paths run kernel-style (sparse race out of a reusable
//! [`codec::CodecWorkspace`] over a once-per-block [`codec::BlockContext`],
//! RNG prefixes hoisted) with the straightforward scalar paths retained as
//! bit-exact parity references — see `tests/compression.rs`.

pub mod bounds;
pub mod codec;
pub mod gaussian;
pub mod image;
pub mod service;

pub use codec::{
    BlockContext, CodecConfig, CodecWorkspace, DecodeOutcome, EncodeResult, GlsCodec,
    RandomnessMode, SourceModel, ToyDiscrete,
};
pub use gaussian::GaussianSource;
pub use service::{
    BatchOutput, BlockResult, CompressionRequest, CompressionServer, DecoderOutcome,
    ServiceError,
};
