//! Paper §5: distributed lossy compression with independent side
//! information at K decoders, built on GLS.
//!
//! * [`codec`] — the generic GLS coding scheme (§5.1) with the importance-
//!   sampling extension to continuous sources (App. C), plus the shared-
//!   randomness baseline the paper compares against.
//! * [`gaussian`] — the synthetic Gaussian source: analytic `p_{W|T}`,
//!   MMSE reconstruction (App. D.2), rate-distortion sweeps (Fig. 2,
//!   Tables 5/6).
//! * [`image`] — distributed image compression (Fig. 3/4, Tables 8/9):
//!   synthetic-digit sources with a latent-variable codec; the latent
//!   model is either the AOT-compiled β-VAE artifacts or an analytic
//!   linear-Gaussian stand-in for artifact-free tests/benches.
//! * [`bounds`] — Proposition 4 error-bound evaluation.

pub mod bounds;
pub mod codec;
pub mod gaussian;
pub mod image;

pub use codec::{CodecConfig, EncodeResult, GlsCodec, RandomnessMode, SourceModel};
pub use gaussian::GaussianSource;
