//! Batched multi-decoder compression service: the paper's distributed
//! topology (§5, Fig. 2) run as a serving component rather than a bench
//! loop. One encoder thread walks a batch of blocks; every encoded block
//! fans out to the K decoders as independent decode jobs consumed by a
//! pool of persistent workers.
//!
//! The worker discipline mirrors `coordinator/pool.rs` (`VerifyPool`):
//!
//! * workers are long-lived threads parked on a condvar, each owning its
//!   [`CodecWorkspace`] across blocks — no per-block spawn, no per-block
//!   scratch allocation in steady state;
//! * jobs are published incrementally as the encoder finishes each block
//!   and claimed through a shared cursor, so decoding of block b overlaps
//!   encoding of block b+1 (no global barrier between the two stages);
//! * a panicking decode job is contained with `catch_unwind`: it fails
//!   only its own `(block, decoder)` slot (reported as
//!   [`DecoderOutcome::Panicked`] and in [`BatchOutput::panicked`]), the
//!   worker replaces its scratch and keeps serving, and every other job's
//!   output is untouched;
//! * every lock acquisition goes through the poison-recovering helpers in
//!   [`crate::sync`] (the `VerifyPool` discipline): a panic on any thread
//!   while it held the state mutex must not cascade into other threads'
//!   unwraps — panic reporting stays exactly per-job, never lock-induced;
//! * results are bit-exact with the single-threaded reference
//!   ([`run_blocks_workspace`]) regardless of worker count or scheduling —
//!   every decode is a pure function of `(cfg, block, side, message, k)`.
//!
//! The block's shared randomness is materialized **once** by the encoder
//! ([`BlockContext`]) and handed to all K decode jobs behind an `Arc`, so
//! a batch costs O(N) materialization per block instead of the seed's
//! O((K+2)·N).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use super::codec::{BlockContext, CodecConfig, CodecWorkspace, EncodeResult, GlsCodec, SourceModel};
use crate::sync::{lock_recover, wait_recover};

/// One block's worth of work for the service: the block id, what the
/// encoder observes, and one side-information observation per decoder.
#[derive(Clone, Debug)]
pub struct CompressionRequest<Src, Side> {
    pub block: u64,
    pub source: Src,
    /// Length must equal `cfg.k_decoders`.
    pub sides: Vec<Side>,
}

/// What one decoder produced for one block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecoderOutcome {
    /// The decoder selected a candidate (`fallback` as in
    /// [`super::codec::DecodeOutcome`]).
    Decoded { index: usize, fallback: bool },
    /// The decode job panicked; only this `(block, decoder)` slot is lost.
    Panicked,
}

impl DecoderOutcome {
    /// Selected candidate index, if the decoder survived.
    pub fn index(&self) -> Option<usize> {
        match self {
            DecoderOutcome::Decoded { index, .. } => Some(*index),
            DecoderOutcome::Panicked => None,
        }
    }
}

/// One block's full result: encoder output, all K decoder outcomes, and
/// the materialized context (kept for reconstruction — `ctx.samples[i]` is
/// candidate i's value, so callers never re-derive the randomness).
#[derive(Clone, Debug)]
pub struct BlockResult<S> {
    pub block: u64,
    pub enc: EncodeResult,
    pub decoded: Vec<DecoderOutcome>,
    /// The paper's success event: some surviving decoder recovered Y.
    pub hit: bool,
    pub ctx: Arc<BlockContext<S>>,
}

/// A batch's results in request order, plus which jobs panicked.
#[derive(Clone, Debug)]
pub struct BatchOutput<S> {
    pub blocks: Vec<BlockResult<S>>,
    /// `(index into the batch, decoder k)` of every panicked decode job.
    pub panicked: Vec<(usize, usize)>,
}

impl<S> BatchOutput<S> {
    /// All-clean results, or a typed error naming the failed jobs.
    pub fn ok(self) -> Result<Vec<BlockResult<S>>, ServiceError> {
        if self.panicked.is_empty() {
            Ok(self.blocks)
        } else {
            Err(ServiceError::DecodersPanicked { failed: self.panicked })
        }
    }
}

/// Typed service failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// Some decode jobs panicked; everything else completed normally.
    DecodersPanicked { failed: Vec<(usize, usize)> },
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::DecodersPanicked { failed } => {
                write!(f, "{} decode job(s) panicked: {failed:?}", failed.len())
            }
        }
    }
}

impl std::error::Error for ServiceError {}

/// An encoded block published to the decode workers: the shared context,
/// the transmitted message, and the decoders' side observations.
struct EncodedBlock<S, T> {
    ctx: Arc<BlockContext<S>>,
    message: u64,
    sides: Vec<T>,
}

struct ServiceState<S, T> {
    /// Flat decode-job list: `(block, decoder)` pairs in publication order
    /// (job id `bi * K + k` for batch index `bi`).
    jobs: Vec<(Arc<EncodedBlock<S, T>>, usize)>,
    /// Claim cursor: workers self-schedule by bumping it under the lock.
    next: usize,
    /// Slot per job, pre-filled `Panicked`; a surviving worker overwrites.
    results: Vec<DecoderOutcome>,
    /// Published minus completed jobs.
    pending: usize,
    /// The current batch is fully published (drain signal).
    closed: bool,
    shutdown: bool,
}

struct ServiceShared<S, T> {
    cfg: CodecConfig,
    state: Mutex<ServiceState<S, T>>,
    /// Workers park here when the job list is drained.
    work_cv: Condvar,
    /// The submitter parks here until `pending == 0 && closed`.
    done_cv: Condvar,
}

/// The multi-decoder compression service. One instance owns its decode
/// workers for its whole life; `run_batch` is the (exclusive) submission
/// path. Dropping the server shuts the workers down and joins them.
pub struct CompressionServer<M: SourceModel> {
    model: Arc<M>,
    shared: Arc<ServiceShared<M::Sample, M::Side>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl<M> CompressionServer<M>
where
    M: SourceModel + Send + Sync + 'static,
    M::Sample: Send + Sync,
    M::Side: Send + Sync,
{
    pub fn new(model: Arc<M>, cfg: CodecConfig, workers: usize) -> Self {
        cfg.validate().expect("codec config");
        assert!(workers >= 1, "need at least one decode worker");
        let shared = Arc::new(ServiceShared {
            cfg,
            state: Mutex::new(ServiceState {
                jobs: Vec::new(),
                next: 0,
                results: Vec::new(),
                pending: 0,
                closed: true,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (0..workers)
            .map(|wid| {
                let sh = Arc::clone(&shared);
                let m = Arc::clone(&model);
                thread::Builder::new()
                    .name(format!("gls-compress-dec-{wid}"))
                    .spawn(move || worker_loop(sh, m))
                    .expect("spawn compression decode worker")
            })
            .collect();
        Self { model, shared, workers }
    }

    /// Encode every request in order, fanning each block's message out to
    /// the K decode workers as soon as it is encoded. Blocks come back in
    /// request order; scheduling never changes the bits (each decode is a
    /// pure function of its inputs).
    pub fn run_batch(
        &mut self,
        requests: Vec<CompressionRequest<M::Source, M::Side>>,
    ) -> BatchOutput<M::Sample> {
        let k = self.shared.cfg.k_decoders;
        let codec = GlsCodec::new(&*self.model, self.shared.cfg);
        let mut enc_ws = CodecWorkspace::new();
        {
            let mut st = lock_recover(&self.shared.state);
            debug_assert!(st.closed && st.pending == 0, "overlapping batch");
            st.jobs.clear();
            st.results.clear();
            st.next = 0;
            st.closed = false;
        }
        let mut encoded = Vec::with_capacity(requests.len());
        for req in requests {
            assert_eq!(req.sides.len(), k, "one side observation per decoder");
            let ctx = Arc::new(codec.block_context(req.block));
            let enc = codec.encode_with(&mut enc_ws, &ctx, &req.source);
            let eb =
                Arc::new(EncodedBlock { ctx, message: enc.message, sides: req.sides });
            {
                let mut st = lock_recover(&self.shared.state);
                for kk in 0..k {
                    st.jobs.push((Arc::clone(&eb), kk));
                    st.results.push(DecoderOutcome::Panicked);
                }
                st.pending += k;
            }
            self.shared.work_cv.notify_all();
            encoded.push((enc, eb));
        }
        let results = {
            let mut st = lock_recover(&self.shared.state);
            st.closed = true;
            while st.pending > 0 {
                st = wait_recover(&self.shared.done_cv, st);
            }
            std::mem::take(&mut st.results)
        };
        let mut blocks = Vec::with_capacity(encoded.len());
        let mut panicked = Vec::new();
        for (bi, (enc, eb)) in encoded.into_iter().enumerate() {
            let decoded = results[bi * k..(bi + 1) * k].to_vec();
            for (kk, d) in decoded.iter().enumerate() {
                if *d == DecoderOutcome::Panicked {
                    panicked.push((bi, kk));
                }
            }
            let hit = decoded.iter().any(|d| d.index() == Some(enc.index));
            let ctx = Arc::clone(&eb.ctx);
            blocks.push(BlockResult { block: ctx.block, enc, decoded, hit, ctx });
        }
        BatchOutput { blocks, panicked }
    }
}

impl<M: SourceModel> Drop for CompressionServer<M> {
    fn drop(&mut self) {
        {
            let mut st = lock_recover(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop<M>(shared: Arc<ServiceShared<M::Sample, M::Side>>, model: Arc<M>)
where
    M: SourceModel + Send + Sync + 'static,
    M::Sample: Send + Sync,
    M::Side: Send + Sync,
{
    let codec = GlsCodec::new(&*model, shared.cfg);
    let mut ws = CodecWorkspace::new();
    loop {
        let (id, eb, k) = {
            let mut st = lock_recover(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.next < st.jobs.len() {
                    break;
                }
                st = wait_recover(&shared.work_cv, st);
            }
            let id = st.next;
            st.next += 1;
            let (eb, k) = &st.jobs[id];
            (id, Arc::clone(eb), *k)
        };
        let out = catch_unwind(AssertUnwindSafe(|| {
            codec.decode_with(&mut ws, &eb.ctx, &eb.sides[k], eb.message, k)
        }));
        if out.is_err() {
            // The scratch may have been mid-mutation when the model
            // panicked; replace it rather than trust its contents.
            ws = CodecWorkspace::new();
        }
        let mut st = lock_recover(&shared.state);
        if let Ok(d) = out {
            st.results[id] = DecoderOutcome::Decoded { index: d.index, fallback: d.fallback };
        }
        st.pending -= 1;
        if st.pending == 0 && st.closed {
            shared.done_cv.notify_all();
        }
    }
}

/// Single-threaded kernel reference: same contexts, same workspace path,
/// no worker pool. The service must match this bit-for-bit.
pub fn run_blocks_workspace<M: SourceModel>(
    model: &M,
    cfg: CodecConfig,
    requests: &[CompressionRequest<M::Source, M::Side>],
) -> BatchOutput<M::Sample> {
    let codec = GlsCodec::new(model, cfg);
    let mut ws = CodecWorkspace::new();
    let blocks = requests
        .iter()
        .map(|req| {
            assert_eq!(req.sides.len(), cfg.k_decoders);
            let ctx = Arc::new(codec.block_context(req.block));
            let enc = codec.encode_with(&mut ws, &ctx, &req.source);
            let decoded: Vec<DecoderOutcome> = req
                .sides
                .iter()
                .enumerate()
                .map(|(k, t)| {
                    let d = codec.decode_with(&mut ws, &ctx, t, enc.message, k);
                    DecoderOutcome::Decoded { index: d.index, fallback: d.fallback }
                })
                .collect();
            let hit = decoded.iter().any(|d| d.index() == Some(enc.index));
            BlockResult { block: ctx.block, enc, decoded, hit, ctx }
        })
        .collect();
    BatchOutput { blocks, panicked: Vec::new() }
}

/// Scalar baseline: the retained seed-style paths, re-materializing the
/// shared randomness for the encoder, every decoder, and reconstruction —
/// the throughput benches' denominator for the kernel speedup gate.
pub fn run_blocks_scalar<M: SourceModel>(
    model: &M,
    cfg: CodecConfig,
    requests: &[CompressionRequest<M::Source, M::Side>],
) -> BatchOutput<M::Sample> {
    let codec = GlsCodec::new(model, cfg);
    let blocks = requests
        .iter()
        .map(|req| {
            assert_eq!(req.sides.len(), cfg.k_decoders);
            let enc = codec.encode_scalar(&req.source, req.block);
            let decoded: Vec<DecoderOutcome> = req
                .sides
                .iter()
                .enumerate()
                .map(|(k, t)| {
                    let d = codec.decode_scalar(t, enc.message, k, req.block);
                    DecoderOutcome::Decoded { index: d.index, fallback: d.fallback }
                })
                .collect();
            let hit = decoded.iter().any(|d| d.index() == Some(enc.index));
            // Seed-faithful reconstruction access: one more materialization.
            let ctx = Arc::new(codec.block_context(req.block));
            BlockResult { block: req.block, enc, decoded, hit, ctx }
        })
        .collect();
    BatchOutput { blocks, panicked: Vec::new() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::codec::{RandomnessMode, ToyDiscrete};

    fn toy_requests(k: usize, blocks: u64) -> Vec<CompressionRequest<usize, usize>> {
        (0..blocks)
            .map(|b| CompressionRequest {
                block: b,
                source: (b % 10) as usize,
                sides: (0..k).map(|kk| ((b + kk as u64) % 10) as usize).collect(),
            })
            .collect()
    }

    fn toy_cfg(k: usize) -> CodecConfig {
        CodecConfig {
            n_samples: 48,
            l_max: 4,
            k_decoders: k,
            seed: 9,
            mode: RandomnessMode::Independent,
        }
    }

    fn assert_same_blocks(a: &BatchOutput<usize>, b: &BatchOutput<usize>) {
        assert_eq!(a.blocks.len(), b.blocks.len());
        for (x, y) in a.blocks.iter().zip(&b.blocks) {
            assert_eq!(x.block, y.block);
            assert_eq!(x.enc, y.enc);
            assert_eq!(x.decoded, y.decoded);
            assert_eq!(x.hit, y.hit);
        }
    }

    #[test]
    fn service_matches_serial_reference_across_worker_counts() {
        let model = Arc::new(ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 });
        let cfg = toy_cfg(3);
        let requests = toy_requests(3, 40);
        let reference = run_blocks_workspace(&*model, cfg, &requests);
        assert!(reference.panicked.is_empty());
        for workers in [1, 2, 4] {
            let mut server = CompressionServer::new(Arc::clone(&model), cfg, workers);
            let out = server.run_batch(requests.clone());
            assert!(out.panicked.is_empty(), "workers={workers}");
            assert_same_blocks(&out, &reference);
        }
    }

    #[test]
    fn scalar_and_workspace_references_agree() {
        let model = ToyDiscrete { flip_enc: 0.1, flip_dec: 0.35 };
        let cfg = toy_cfg(2);
        let requests = toy_requests(2, 60);
        let scalar = run_blocks_scalar(&model, cfg, &requests);
        let kernel = run_blocks_workspace(&model, cfg, &requests);
        assert_same_blocks(&scalar, &kernel);
    }

    #[test]
    fn server_survives_across_batches() {
        let model = Arc::new(ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 });
        let cfg = toy_cfg(2);
        let mut server = CompressionServer::new(Arc::clone(&model), cfg, 2);
        for round in 0..3u64 {
            let requests: Vec<_> = toy_requests(2, 15)
                .into_iter()
                .map(|mut r| {
                    r.block += round * 1000;
                    r
                })
                .collect();
            let reference = run_blocks_workspace(&*model, cfg, &requests);
            let out = server.run_batch(requests);
            assert_same_blocks(&out, &reference);
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let model = Arc::new(ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 });
        let mut server = CompressionServer::new(model, toy_cfg(1), 2);
        let out = server.run_batch(Vec::new());
        assert!(out.blocks.is_empty() && out.panicked.is_empty());
        assert!(out.ok().is_ok());
    }

    /// Decoder weight panics on a sentinel side value — only that job dies.
    struct PoisonSide {
        inner: ToyDiscrete,
    }

    const POISON: usize = usize::MAX;

    impl SourceModel for PoisonSide {
        type Source = usize;
        type Side = usize;
        type Sample = usize;

        fn sample_prior(&self, draw: &mut dyn FnMut() -> f64) -> usize {
            self.inner.sample_prior(draw)
        }

        fn weight_enc(&self, u: &usize, a: &usize) -> f64 {
            self.inner.weight_enc(u, a)
        }

        fn weight_dec(&self, u: &usize, t: &usize) -> f64 {
            assert!(*t != POISON, "poisoned side observation");
            self.inner.weight_dec(u, t)
        }
    }

    #[test]
    fn panicking_decode_fails_only_its_own_slot() {
        let model = Arc::new(PoisonSide { inner: ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 } });
        let cfg = toy_cfg(2);
        let mut requests: Vec<CompressionRequest<usize, usize>> = toy_requests(2, 20);
        requests[7].sides[1] = POISON;
        let honest: Vec<_> =
            requests.iter().filter(|r| !r.sides.contains(&POISON)).cloned().collect();
        let reference = run_blocks_workspace(&*model, cfg, &honest);

        let mut server = CompressionServer::new(Arc::clone(&model), cfg, 2);
        let out = server.run_batch(requests);
        assert_eq!(out.panicked, vec![(7, 1)]);
        assert_eq!(out.blocks[7].decoded[1], DecoderOutcome::Panicked);
        // Decoder 0 of the poisoned block still decoded.
        assert!(matches!(out.blocks[7].decoded[0], DecoderOutcome::Decoded { .. }));
        // Every honest block is bit-exact with the serial reference.
        let mut ref_iter = reference.blocks.iter();
        for blk in out.blocks.iter().filter(|b| b.block != 7) {
            let want = ref_iter.next().unwrap();
            assert_eq!(blk.enc, want.enc);
            assert_eq!(blk.decoded, want.decoded);
            assert_eq!(blk.hit, want.hit);
        }
        // The typed error path names the failed job.
        let mut server2 = CompressionServer::new(Arc::clone(&model), cfg, 2);
        let mut requests2 = toy_requests(2, 5);
        requests2[2].sides[0] = POISON;
        match server2.run_batch(requests2).ok() {
            Err(ServiceError::DecodersPanicked { failed }) => assert_eq!(failed, vec![(2, 0)]),
            other => panic!("expected typed panic error, got {other:?}"),
        }
        // And the server keeps serving clean batches afterwards.
        let clean = toy_requests(2, 10);
        let again = server2.run_batch(clean.clone());
        assert!(again.panicked.is_empty());
        assert_same_blocks(&again, &run_blocks_workspace(&*model, cfg, &clean));
    }

    /// Panic while *holding the state lock* (poisoning it), then prove the
    /// service neither cascades the panic nor misreports anything as
    /// `DecodersPanicked`: the next batch is clean and bit-exact, and a
    /// genuinely panicking decode job is still reported exactly per-slot.
    #[test]
    fn poisoned_state_lock_does_not_cascade_or_misreport() {
        let model = Arc::new(PoisonSide { inner: ToyDiscrete { flip_enc: 0.1, flip_dec: 0.3 } });
        let cfg = toy_cfg(2);
        let mut server = CompressionServer::new(Arc::clone(&model), cfg, 2);

        // A thread dies mid-critical-section; the state mutex is now
        // poisoned under the parked workers and the future submitter.
        let sh = Arc::clone(&server.shared);
        let poisoner = thread::spawn(move || {
            let _g = sh.state.lock().unwrap();
            panic!("die while holding the service state lock");
        });
        assert!(poisoner.join().is_err());
        assert!(server.shared.state.is_poisoned());

        // Clean batch over the poisoned lock: no cascade, no phantom
        // Panicked slots, bit-exact with the serial reference.
        let requests = toy_requests(2, 25);
        let reference = run_blocks_workspace(&*model, cfg, &requests);
        let out = server.run_batch(requests);
        assert!(out.panicked.is_empty(), "poison misreported: {:?}", out.panicked);
        assert_same_blocks(&out, &reference);

        // A real decode panic on the still-poisoned lock is reported for
        // exactly its own slot — poison adds nothing, hides nothing.
        let mut requests = toy_requests(2, 8);
        requests[3].sides[0] = POISON;
        let out = server.run_batch(requests);
        assert_eq!(out.panicked, vec![(3, 0)]);
        assert_eq!(out.blocks[3].decoded[0], DecoderOutcome::Panicked);
        assert!(matches!(out.blocks[3].decoded[1], DecoderOutcome::Decoded { .. }));
    }
}
