//! # gls-serve
//!
//! A production-style reproduction of *"List-Level Distribution Coupling with
//! Applications to Speculative Decoding and Lossy Compression"* (Rowan, Phan,
//! Khisti, 2025) as a three-layer Rust + JAX + Pallas serving stack.
//!
//! The paper's contribution — **Gumbel-max List Sampling (GLS)** and its
//! **List Matching Lemma** — lives in [`spec`]. Two applications are built on
//! top of it:
//!
//! * **Drafter-invariant multi-draft speculative decoding** (paper §4), run by
//!   the serving framework in [`coordinator`] against AOT-compiled JAX
//!   transformer artifacts loaded through [`runtime`].
//! * **Distributed lossy compression with side information at K decoders**
//!   (paper §5), in [`compression`].
//!
//! Layering (Python never on the request path):
//!
//! ```text
//! L3  rust   coordinator/  router, batcher, scheduler, KV cache, engine
//! L2  jax    python/compile/model.py  transformer fwd (prefill/decode/verify)
//! L1  pallas python/compile/kernels/  GLS select, attention (interpret=True)
//!     bridge runtime/  PJRT CPU client over artifacts/*.hlo.txt
//! ```
//!
//! Everything below `runtime` also has a native-Rust mirror ([`model`]) so
//! the algorithm layer is testable and benchable without artifacts.

pub mod analysis;
pub mod bench;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod lp;
pub mod model;
pub mod perf;
/// PJRT bridge; needs the vendored `xla` crate — see Cargo.toml `pjrt`
/// feature notes. The default (offline) build runs entirely on the native
/// Rust mirror in [`model`].
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod spec;
pub mod stats;
pub mod sync;
pub mod testkit;
pub mod workload;

pub use spec::gls::{sample_gls, sample_gls_bilateral, BilateralOutcome, GlsOutcome};
pub use spec::kernel::CouplingWorkspace;
pub use spec::types::{Categorical, VerifierKind};
