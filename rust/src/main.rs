//! `gls-serve` CLI: launcher for the serving stack and the compression
//! pipelines.
//!
//! ```text
//! gls-serve serve    [--verifier gls] [--k 4] [--l 4] [--workers 2]
//!                    [--requests 50] [--suite gsm8k-sim] [--pjrt]
//! gls-serve compress [--source gaussian|image] [--k 2] [--lmax 16]
//! gls-serve info
//! ```

use gls_serve::bench::Table;
use gls_serve::compression::codec::RandomnessMode;
use gls_serve::compression::gaussian::{run_gaussian, GaussianSource};
use gls_serve::compression::image::{run_image, synthetic_digits, AnalyticVae};
use gls_serve::config::Args;
use gls_serve::coordinator::router::RoutingPolicy;
use gls_serve::coordinator::server::Server;
use gls_serve::coordinator::{EngineConfig, ServerConfig};
use gls_serve::model::backend::ModelPair;
use gls_serve::model::sampling::SamplingParams;
#[cfg(feature = "pjrt")]
use gls_serve::runtime::{Artifacts, PjrtLm};
use gls_serve::spec::types::VerifierKind;
use gls_serve::workload::suites::TaskSuite;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "serve" => cmd_serve(&args),
        "compress" => cmd_compress(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "gls-serve — List-Level Distribution Coupling (GLS) serving stack\n\n\
USAGE:\n\
  gls-serve serve    [--verifier gls|gls-strong|specinfer|spectr|single-draft|daliri]\n\
                     [--k N] [--l N] [--workers N] [--requests N]\n\
                     [--suite gsm8k-sim|humaneval-sim|naturalreasoning-sim|mbpp-sim|drop-sim]\n\
                     [--target-temp T] [--draft-temps a,b] [--pjrt]\n\
  gls-serve compress [--source gaussian|image] [--k N] [--lmax N] [--trials N] [--baseline]\n\
  gls-serve info"
    );
}

fn cmd_serve(args: &Args) -> i32 {
    let verifier = args
        .get("verifier")
        .map(|v| VerifierKind::parse(v).expect("unknown verifier"))
        .unwrap_or(VerifierKind::Gls);
    let k = args.get_parse("k", 4usize).unwrap();
    let l = args.get_parse("l", 4usize).unwrap();
    let workers = args.get_parse("workers", 2usize).unwrap();
    let requests = args.get_parse("requests", 32usize).unwrap();
    let suite_name = args.get("suite").unwrap_or("gsm8k-sim");
    let target_temp = args.get_parse("target-temp", 1.0f64).unwrap();
    let use_pjrt = args.has_flag("pjrt");

    let suite = TaskSuite::by_name(suite_name).expect("unknown suite");
    let draft_params: Vec<SamplingParams> = match args.get("draft-temps") {
        None => vec![SamplingParams::new(1.0, Some(50))],
        Some(spec) => spec
            .split(',')
            .map(|t| SamplingParams::new(t.trim().parse().expect("bad temp"), Some(50)))
            .collect(),
    };

    let engine_cfg = EngineConfig {
        num_drafts: k,
        block_len: l,
        verifier,
        target_params: SamplingParams::new(target_temp, Some(50)),
        draft_params,
        max_seq_len: 512,
        seed: args.get_parse("seed", 0xC0FFEEu64).unwrap(),
        ..EngineConfig::default()
    };
    let server_cfg = ServerConfig { workers, ..ServerConfig::default() };

    #[cfg(not(feature = "pjrt"))]
    if use_pjrt {
        eprintln!("error: this binary was built without the `pjrt` feature");
        return 2;
    }
    #[cfg(feature = "pjrt")]
    let vocab = if use_pjrt {
        Artifacts::discover().and_then(|m| m.get_usize("vocab")).unwrap_or(64)
    } else {
        64
    };
    #[cfg(not(feature = "pjrt"))]
    let vocab = 64;
    let max_new = if use_pjrt { 24 } else { suite.max_new_tokens };
    let prompts = suite.prompts(requests, vocab.min(256), 42);
    let workload: Vec<(Vec<u32>, usize)> =
        prompts.into_iter().map(|p| (p, max_new)).collect();

    println!(
        "serving {requests} requests | suite={} verifier={} K={k} L={l} workers={workers} backend={}",
        suite.name,
        verifier.name(),
        if use_pjrt { "pjrt" } else { "sim" }
    );

    #[cfg(feature = "pjrt")]
    let report = if use_pjrt {
        let manifest = Artifacts::discover().expect("run `make artifacts` first");
        Server::serve_all(
            &server_cfg,
            &engine_cfg,
            RoutingPolicy::LeastLoaded,
            |_| {
                let draft = PjrtLm::load(&manifest, "draft_lm").expect("load draft");
                let target = PjrtLm::load(&manifest, "target_lm").expect("load target");
                ModelPair::new(Box::new(draft), Box::new(target))
            },
            workload,
        )
    } else {
        Server::serve_all(
            &server_cfg,
            &engine_cfg,
            RoutingPolicy::LeastLoaded,
            |_| suite.model_pair(vocab, 7),
            workload,
        )
    };
    #[cfg(not(feature = "pjrt"))]
    let report = Server::serve_all(
        &server_cfg,
        &engine_cfg,
        RoutingPolicy::LeastLoaded,
        |_| suite.model_pair(vocab, 7),
        workload,
    );

    println!("{}", report.metrics.report());
    println!(
        "BE={:.3}  tokens/s={:.1}  p50={:.1}ms  p95={:.1}ms",
        report.mean_block_efficiency(),
        report.token_rate(),
        report.p50_latency() * 1e3,
        report.p95_latency() * 1e3
    );
    0
}

fn cmd_compress(args: &Args) -> i32 {
    let source = args.get("source").unwrap_or("gaussian");
    let k = args.get_parse("k", 2usize).unwrap();
    let l_max = args.get_parse("lmax", 16u64).unwrap();
    let trials = args.get_parse("trials", 500u64).unwrap();
    let mode = if args.has_flag("baseline") {
        RandomnessMode::Shared
    } else {
        RandomnessMode::Independent
    };
    match source {
        "gaussian" => {
            let p = run_gaussian(
                GaussianSource::paper_default(0.005),
                k,
                l_max,
                1 << 12,
                trials,
                7,
                mode,
            );
            println!(
                "gaussian: K={} L_max={} rate={:.1} bits  match={:.3}  distortion={:.2} dB",
                p.k,
                p.l_max,
                (l_max as f64).log2(),
                p.match_rate,
                p.mse_db
            );
        }
        "image" => {
            let imgs = synthetic_digits(400, 21);
            let vae = AnalyticVae::fit(&imgs[..250], 4, 0.05, 13);
            let p = run_image(&vae, &imgs[250..], k, l_max, 256, 3, mode);
            println!(
                "image: K={} L_max={}  match={:.3}  MSE={:.4}",
                p.k, p.l_max, p.match_rate, p.mse
            );
        }
        other => {
            eprintln!("unknown source '{other}'");
            return 2;
        }
    }
    0
}

fn cmd_info() -> i32 {
    let mut t = Table::new(&["component", "status"]);
    t.row(&["library".into(), format!("gls-serve {}", env!("CARGO_PKG_VERSION"))]);
    match gls_serve::config::artifacts_dir() {
        Some(dir) => {
            t.row(&["artifacts".into(), dir.display().to_string()]);
            #[cfg(feature = "pjrt")]
            match Artifacts::discover() {
                Ok(m) => {
                    for key in ["vocab", "lm_batch", "lm_max_seq", "vae_latent"] {
                        if m.has(key) {
                            t.row(&[key.into(), m.get(key).unwrap().to_string()]);
                        }
                    }
                }
                Err(e) => t.row(&["manifest".into(), format!("error: {e}")]),
            }
            #[cfg(not(feature = "pjrt"))]
            t.row(&["manifest".into(), "unread (built without `pjrt`)".into()]);
        }
        None => t.row(&["artifacts".into(), "missing (run `make artifacts`)".into()]),
    }
    t.print();
    0
}
