//! Two-phase dense simplex with Bland's rule.

#[derive(Debug)]
pub enum LpError {
    Infeasible(f64),
    Unbounded,
    Dimension(String),
}

impl std::fmt::Display for LpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LpError::Infeasible(obj) => write!(f, "infeasible LP (phase-1 objective {obj} > 0)"),
            LpError::Unbounded => write!(f, "unbounded LP"),
            LpError::Dimension(msg) => write!(f, "dimension mismatch: {msg}"),
        }
    }
}

impl std::error::Error for LpError {}

/// Solution of max c^T x s.t. Ax = b, x ≥ 0.
#[derive(Clone, Debug)]
pub struct LpSolution {
    pub objective: f64,
    pub x: Vec<f64>,
}

const EPS: f64 = 1e-9;

/// Solve `max c^T x  s.t.  A x = b, x ≥ 0` (A given row-major as `a[row]`).
///
/// `b` entries may be negative; rows are sign-flipped internally so the
/// phase-1 artificial basis is valid.
pub fn solve(a: &[Vec<f64>], b: &[f64], c: &[f64]) -> Result<LpSolution, LpError> {
    let m = a.len();
    if b.len() != m {
        return Err(LpError::Dimension(format!("{} rows vs {} rhs", m, b.len())));
    }
    let n = c.len();
    for (i, row) in a.iter().enumerate() {
        if row.len() != n {
            return Err(LpError::Dimension(format!("row {i}: {} cols vs {n}", row.len())));
        }
    }

    // Tableau: m rows × (n + m artificials + 1 rhs column).
    let width = n + m + 1;
    let mut t = vec![vec![0.0; width]; m];
    for i in 0..m {
        let flip = if b[i] < 0.0 { -1.0 } else { 1.0 };
        for j in 0..n {
            t[i][j] = flip * a[i][j];
        }
        t[i][n + i] = 1.0;
        t[i][width - 1] = flip * b[i];
    }
    let mut basis: Vec<usize> = (n..n + m).collect();

    // Phase 1: minimize sum of artificials == maximize -(sum of artificials).
    // Row0 = -c reduced against the artificial basis: start with +1 on each
    // artificial column (c = -1 there), then subtract every row once so the
    // basic (artificial) reduced costs are zero.
    let mut obj1 = vec![0.0; width];
    for i in 0..m {
        obj1[n + i] = 1.0;
    }
    for i in 0..m {
        for j in 0..width {
            obj1[j] -= t[i][j];
        }
    }
    run_simplex(&mut t, &mut obj1, &mut basis, n + m)?;
    let phase1 = -obj1[width - 1];
    if phase1 > 1e-6 {
        return Err(LpError::Infeasible(phase1));
    }

    // Drive any artificial still in the basis out (degenerate rows).
    for i in 0..m {
        if basis[i] >= n {
            if let Some(j) = (0..n).find(|&j| t[i][j].abs() > EPS) {
                pivot(&mut t, &mut obj1, &mut basis, i, j);
            }
            // else: zero row, harmless.
        }
    }

    // Phase 2: maximize c^T x. Reduced objective row:
    let mut obj2 = vec![0.0; width];
    for j in 0..n {
        obj2[j] = -c[j]; // maximize => row holds -c, we pivot until no negative
    }
    // Make the objective row consistent with the current basis.
    for i in 0..m {
        let bj = basis[i];
        if bj < n && obj2[bj].abs() > 0.0 {
            let factor = obj2[bj];
            for j in 0..width {
                obj2[j] -= factor * t[i][j];
            }
        }
    }
    run_simplex(&mut t, &mut obj2, &mut basis, n)?;

    let mut x = vec![0.0; n];
    for i in 0..m {
        if basis[i] < n {
            x[basis[i]] = t[i][width - 1];
        }
    }
    let objective: f64 = c.iter().zip(&x).map(|(ci, xi)| ci * xi).sum();
    Ok(LpSolution { objective, x })
}

/// Pivot until no improving column (Bland's rule), restricted to the first
/// `cols` columns (phase 1 allows artificials, phase 2 does not).
fn run_simplex(
    t: &mut [Vec<f64>],
    obj: &mut [f64],
    basis: &mut [usize],
    cols: usize,
) -> Result<(), LpError> {
    let m = t.len();
    let width = obj.len();
    let max_iters = 50_000;
    for _ in 0..max_iters {
        // Bland: first column with negative reduced cost.
        let Some(col) = (0..cols).find(|&j| obj[j] < -EPS) else {
            return Ok(());
        };
        // Ratio test; Bland tie-break on smallest basis index.
        let mut pivot_row: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            if t[i][col] > EPS {
                let ratio = t[i][width - 1] / t[i][col];
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && pivot_row.map_or(true, |r| basis[i] < basis[r]))
                {
                    best_ratio = ratio;
                    pivot_row = Some(i);
                }
            }
        }
        let Some(row) = pivot_row else {
            return Err(LpError::Unbounded);
        };
        pivot(t, obj, basis, row, col);
    }
    // Bland's rule guarantees termination; hitting the cap means numerics.
    Err(LpError::Unbounded)
}

fn pivot(t: &mut [Vec<f64>], obj: &mut [f64], basis: &mut [usize], row: usize, col: usize) {
    let width = obj.len();
    let piv = t[row][col];
    debug_assert!(piv.abs() > 1e-12);
    for j in 0..width {
        t[row][j] /= piv;
    }
    for i in 0..t.len() {
        if i != row && t[i][col].abs() > 1e-14 {
            let f = t[i][col];
            for j in 0..width {
                t[i][j] -= f * t[row][j];
            }
        }
    }
    if obj[col].abs() > 1e-14 {
        let f = obj[col];
        for j in 0..width {
            obj[j] -= f * t[row][j];
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_textbook_lp() {
        // max 3x + 2y s.t. x + y + s1 = 4; x + 3y + s2 = 6; x,y,s >= 0.
        // Optimum: x=4, y=0 → 12.
        let a = vec![vec![1.0, 1.0, 1.0, 0.0], vec![1.0, 3.0, 0.0, 1.0]];
        let b = vec![4.0, 6.0];
        let c = vec![3.0, 2.0, 0.0, 0.0];
        let sol = solve(&a, &b, &c).unwrap();
        assert!((sol.objective - 12.0).abs() < 1e-7, "obj {}", sol.objective);
        assert!((sol.x[0] - 4.0).abs() < 1e-7);
    }

    #[test]
    fn detects_infeasible() {
        // x = 1 and x = 2 simultaneously.
        let a = vec![vec![1.0], vec![1.0]];
        let b = vec![1.0, 2.0];
        let c = vec![0.0];
        assert!(matches!(solve(&a, &b, &c), Err(LpError::Infeasible(_))));
    }

    #[test]
    fn detects_unbounded() {
        // max x s.t. x - y = 0 => x unbounded with y.
        let a = vec![vec![1.0, -1.0]];
        let b = vec![0.0];
        let c = vec![1.0, 0.0];
        assert!(matches!(solve(&a, &b, &c), Err(LpError::Unbounded)));
    }

    #[test]
    fn handles_negative_rhs() {
        // -x = -3 => x = 3; max x bounded by that equality.
        let a = vec![vec![-1.0]];
        let b = vec![-3.0];
        let c = vec![1.0];
        let sol = solve(&a, &b, &c).unwrap();
        assert!((sol.objective - 3.0).abs() < 1e-7);
    }

    #[test]
    fn degenerate_basis_ok() {
        // Redundant constraint producing a zero row after phase 1.
        let a = vec![vec![1.0, 1.0], vec![2.0, 2.0]];
        let b = vec![1.0, 2.0];
        let c = vec![1.0, 0.0];
        let sol = solve(&a, &b, &c).unwrap();
        assert!((sol.objective - 1.0).abs() < 1e-7);
    }

    #[test]
    fn maximal_coupling_lp_matches_tv_formula() {
        // The classic test: optimal coupling acceptance = 1 - d_TV.
        // Variables π(x, y) ≥ 0 on a 3×3 grid; constraints: row sums = p,
        // col sums = q; objective: Σ_x π(x, x).
        let p = [0.5, 0.3, 0.2];
        let q = [0.2, 0.3, 0.5];
        let n = 3;
        let var = |x: usize, y: usize| x * n + y;
        let mut a = Vec::new();
        let mut b = Vec::new();
        for x in 0..n {
            let mut row = vec![0.0; n * n];
            for y in 0..n {
                row[var(x, y)] = 1.0;
            }
            a.push(row);
            b.push(p[x]);
        }
        for y in 0..n {
            let mut row = vec![0.0; n * n];
            for x in 0..n {
                row[var(x, y)] = 1.0;
            }
            a.push(row);
            b.push(q[y]);
        }
        let mut c = vec![0.0; n * n];
        for x in 0..n {
            c[var(x, x)] = 1.0;
        }
        let sol = solve(&a, &b, &c).unwrap();
        let tv = 0.5 * p.iter().zip(&q).map(|(a, b)| (a - b).abs()).sum::<f64>();
        assert!((sol.objective - (1.0 - tv)).abs() < 1e-7, "obj {}", sol.objective);
    }
}
