//! Dense two-phase simplex LP solver.
//!
//! Built in-house (no LP crates in the offline vendor set) to compute the
//! *optimal multi-draft acceptance probability with communication* — the
//! upper-bound curve of paper Figure 6, which the paper computes "via a
//! linear programming approach [33]". Solves
//!
//! ```text
//!   maximize    c^T x
//!   subject to  A x = b,  x ≥ 0
//! ```
//!
//! with Bland's anti-cycling rule. Problem sizes here are small (≤ a few
//! thousand variables), so a dense tableau is appropriate.

pub mod simplex;

pub use simplex::{solve, LpError, LpSolution};
