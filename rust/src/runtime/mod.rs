//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//!
//! Python never runs at serve time: `make artifacts` lowers the JAX
//! transformer (L2) with its Pallas kernels (L1) to HLO **text** once;
//! everything here is `HloModuleProto::from_text_file` → `client.compile`
//! → `execute` through the `xla` crate's PJRT CPU client.
//!
//! HLO text (not serialized protos) is the interchange format: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;
pub mod client;
pub mod lm;
pub mod vae;

pub use artifacts::{ArtifactManifest, Artifacts};
pub use client::{compile_hlo_file, execute_tuple, new_client};
pub use lm::PjrtLm;
pub use vae::PjrtVae;
