//! PJRT-backed language model: executes the AOT-compiled JAX transformer
//! (with its Pallas attention kernel lowered inside) as an [`LmBackend`].
//!
//! Artifact signature (see python/compile/aot.py):
//!
//! ```text
//! lm_logits: tokens i32[B, S]  ->  (logits f32[B, S, V],)
//! ```
//!
//! The module is a full-context forward at fixed (B, S); rows are padded
//! with PAD and batches chunked to B. A full forward per call (rather than
//! device-resident KV) is deliberate on this backend: xla_extension 0.5.1
//! round-trips every buffer host↔device per execute, so at our model sizes
//! recompute is faster than shipping the KV cache both ways (DESIGN.md
//! §Perf). The *logical* KV accounting still runs in the coordinator.

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::backend::LmBackend;
use crate::model::tokenizer::PAD;

use super::artifacts::ArtifactManifest;
use super::client::{compile_hlo_file, execute_tuple, new_client, SendBundle};

struct Inner {
    _client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

pub struct PjrtLm {
    inner: SendBundle<Inner>,
    batch: usize,
    max_seq: usize,
    vocab: usize,
    name: String,
}

impl PjrtLm {
    /// Load the `which` LM from the manifest (`"draft_lm"` / `"target_lm"`).
    pub fn load(manifest: &ArtifactManifest, which: &str) -> Result<Self> {
        let client = new_client()?;
        let path = manifest.path(which)?;
        let exe = compile_hlo_file(&client, &path)?;
        Ok(Self {
            inner: SendBundle(Inner { _client: client, exe }),
            batch: manifest.get_usize("lm_batch")?,
            max_seq: manifest.get_usize("lm_max_seq")?,
            vocab: manifest.get_usize("vocab")?,
            name: which.to_string(),
        })
    }

    pub fn load_from_file(path: &Path, batch: usize, max_seq: usize, vocab: usize) -> Result<Self> {
        let client = new_client()?;
        let exe = compile_hlo_file(&client, path)?;
        Ok(Self {
            inner: SendBundle(Inner { _client: client, exe }),
            batch,
            max_seq,
            vocab,
            name: path.display().to_string(),
        })
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Run the fixed-shape forward on up to `batch` rows; returns
    /// `[rows][S][V]` logits (padded positions included — callers slice).
    fn forward_chunk(&mut self, rows: &[Vec<u32>]) -> Result<Vec<Vec<Vec<f32>>>> {
        assert!(rows.len() <= self.batch);
        let (b, s, v) = (self.batch, self.max_seq, self.vocab);
        let mut tokens = vec![PAD as i32; b * s];
        for (r, row) in rows.iter().enumerate() {
            assert!(
                row.len() <= s,
                "sequence length {} exceeds compiled max_seq {s}",
                row.len()
            );
            for (i, &t) in row.iter().enumerate() {
                tokens[r * s + i] = t as i32;
            }
        }
        let lit = xla::Literal::vec1(&tokens)
            .reshape(&[b as i64, s as i64])
            .context("reshape tokens")?;
        let outs = execute_tuple(&self.inner.exe, &[lit])?;
        let logits: Vec<f32> = outs[0].to_vec().context("logits to_vec")?;
        anyhow::ensure!(logits.len() == b * s * v, "unexpected logits size {}", logits.len());
        Ok(rows
            .iter()
            .enumerate()
            .map(|(r, _)| {
                (0..s)
                    .map(|pos| {
                        let base = r * s * v + pos * v;
                        logits[base..base + v].to_vec()
                    })
                    .collect()
            })
            .collect())
    }

    fn forward(&mut self, rows: &[Vec<u32>]) -> Vec<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(self.batch) {
            out.extend(self.forward_chunk(chunk).expect("pjrt lm forward"));
        }
        out
    }
}

impl LmBackend for PjrtLm {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn next_logits(&mut self, seqs: &[Vec<u32>]) -> Vec<Vec<f32>> {
        let all = self.forward(seqs);
        seqs.iter()
            .zip(all)
            .map(|(seq, mut per_pos)| per_pos.swap_remove(seq.len() - 1))
            .collect()
    }

    fn span_logits(&mut self, seqs: &[Vec<u32>], start: usize) -> Vec<Vec<Vec<f32>>> {
        self.span_logits_multi(seqs, &vec![start; seqs.len()])
    }

    fn span_logits_multi(&mut self, seqs: &[Vec<u32>], starts: &[usize]) -> Vec<Vec<Vec<f32>>> {
        // One fused forward over every row regardless of start mix; the
        // per-row start only affects host-side slicing.
        assert_eq!(seqs.len(), starts.len(), "one start per row");
        let all = self.forward(seqs);
        seqs.iter()
            .zip(starts)
            .zip(all)
            .map(|((seq, &start), per_pos)| {
                // Predictive distribution for prefix length P lives at
                // logits index P-1; the span covers prefix lengths
                // start-1 ..= len, i.e. indices start-2 ..= len-1. start ≥ 2
                // always holds here (prompts begin with BOS).
                assert!(start >= 2 && start <= seq.len() + 1, "start {start} out of range");
                (start - 2..=seq.len() - 1)
                    .map(|idx| per_pos[idx].clone())
                    .collect()
            })
            .collect()
    }

    fn describe(&self) -> String {
        format!("pjrt-lm({}, B={}, S={}, V={})", self.name, self.batch, self.max_seq, self.vocab)
    }
}
