//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. `artifacts/manifest.txt` is `key = value` lines describing
//! every exported HLO module and its shapes.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Parsed manifest plus the directory it lives in.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    entries: HashMap<String, String>,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut entries = HashMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("manifest line {}: expected key = value", lineno + 1);
            };
            entries.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, key: &str) -> Result<&str> {
        self.entries
            .get(key)
            .map(|s| s.as_str())
            .with_context(|| format!("manifest missing key '{key}'"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.parse().with_context(|| format!("manifest key '{key}' not a usize"))
    }

    pub fn path(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(self.get(key)?))
    }

    pub fn has(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }
}

/// Convenience: locate and load the manifest via [`crate::config`].
pub struct Artifacts;

impl Artifacts {
    pub fn discover() -> Result<ArtifactManifest> {
        let dir = crate::config::artifacts_dir()
            .context("artifacts/ not found — run `make artifacts` first")?;
        ArtifactManifest::load(&dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_roundtrip() {
        let dir = std::env::temp_dir().join(format!("gls-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# comment\nvocab = 259\ntarget_lm = target.hlo.txt\n",
        )
        .unwrap();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.get_usize("vocab").unwrap(), 259);
        assert!(m.path("target_lm").unwrap().ends_with("target.hlo.txt"));
        assert!(m.has("vocab"));
        assert!(!m.has("nope"));
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_manifest_rejected() {
        let dir = std::env::temp_dir().join(format!("gls-manifest-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "novalue\n").unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
