//! PJRT-backed β-VAE latent codec: the compression application's L2/L1
//! stack (paper App. D.3), AOT-compiled by python/compile/aot.py.
//!
//! Artifact signatures (all batch-1, f32):
//!
//! ```text
//! vae_encode   : source [1, 392]            -> (mu [1,4], logvar [1,4])
//! vae_project  : side   [1, 49]             -> (feat [1, F],)
//! vae_estimate : w [1, 4], feat [1, F]      -> (logit [1],)
//! vae_decode   : w [1, 4], feat [1, F]      -> (recon [1, 392],)
//! ```
//!
//! The estimator outputs the pre-sigmoid logit of the joint-vs-marginal
//! classifier; by the density-ratio trick that logit *is*
//! `log p_{W|T}(w|t) − log p_W(w)`, exactly what the codec's decoder
//! weights need.

use anyhow::{Context, Result};

use crate::compression::image::LatentCodecModel;

use super::artifacts::ArtifactManifest;
use super::client::{compile_hlo_file, execute_tuple, new_client, SendBundle};

struct Inner {
    _client: xla::PjRtClient,
    encode: xla::PjRtLoadedExecutable,
    project: xla::PjRtLoadedExecutable,
    estimate: xla::PjRtLoadedExecutable,
    decode: xla::PjRtLoadedExecutable,
}

pub struct PjrtVae {
    inner: SendBundle<Inner>,
    latent: usize,
    feat_dim: usize,
    src_pixels: usize,
    side_pixels: usize,
}

impl PjrtVae {
    pub fn load(manifest: &ArtifactManifest) -> Result<Self> {
        let client = new_client()?;
        let compile = |key: &str| -> Result<xla::PjRtLoadedExecutable> {
            compile_hlo_file(&client, &manifest.path(key)?)
        };
        Ok(Self {
            inner: SendBundle(Inner {
                encode: compile("vae_encode")?,
                project: compile("vae_project")?,
                estimate: compile("vae_estimate")?,
                decode: compile("vae_decode")?,
                _client: client,
            }),
            latent: manifest.get_usize("vae_latent")?,
            feat_dim: manifest.get_usize("vae_feat_dim")?,
            src_pixels: manifest.get_usize("vae_src_pixels")?,
            side_pixels: manifest.get_usize("vae_side_pixels")?,
        })
    }

    fn lit_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        anyhow::ensure!(data.len() == rows * cols, "literal shape mismatch");
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .context("reshape literal")
    }
}

impl LatentCodecModel for PjrtVae {
    fn latent_dim(&self) -> usize {
        self.latent
    }

    fn encode(&self, source: &[f32]) -> (Vec<f64>, Vec<f64>) {
        assert_eq!(source.len(), self.src_pixels);
        let lit = Self::lit_2d(source, 1, self.src_pixels).unwrap();
        let outs = execute_tuple(&self.inner.encode, &[lit]).expect("vae_encode");
        let mu: Vec<f32> = outs[0].to_vec().expect("mu");
        let logvar: Vec<f32> = outs[1].to_vec().expect("logvar");
        (
            mu.iter().map(|&x| x as f64).collect(),
            logvar.iter().map(|&x| (x as f64).exp().max(1e-6)).collect(),
        )
    }

    fn project(&self, side: &[f32]) -> Vec<f64> {
        assert_eq!(side.len(), self.side_pixels);
        let lit = Self::lit_2d(side, 1, self.side_pixels).unwrap();
        let outs = execute_tuple(&self.inner.project, &[lit]).expect("vae_project");
        let feat: Vec<f32> = outs[0].to_vec().expect("feat");
        feat.iter().map(|&x| x as f64).collect()
    }

    fn estimate_logratio(&self, w: &[f64], side_feat: &[f64]) -> f64 {
        assert_eq!(w.len(), self.latent);
        assert_eq!(side_feat.len(), self.feat_dim);
        let wf: Vec<f32> = w.iter().map(|&x| x as f32).collect();
        let ff: Vec<f32> = side_feat.iter().map(|&x| x as f32).collect();
        let wl = Self::lit_2d(&wf, 1, self.latent).unwrap();
        let fl = Self::lit_2d(&ff, 1, self.feat_dim).unwrap();
        let outs = execute_tuple(&self.inner.estimate, &[wl, fl]).expect("vae_estimate");
        let logit: Vec<f32> = outs[0].to_vec().expect("logit");
        logit[0] as f64
    }

    fn decode(&self, w: &[f64], side_feat: &[f64]) -> Vec<f32> {
        let wf: Vec<f32> = w.iter().map(|&x| x as f32).collect();
        let ff: Vec<f32> = side_feat.iter().map(|&x| x as f32).collect();
        let wl = Self::lit_2d(&wf, 1, self.latent).unwrap();
        let fl = Self::lit_2d(&ff, 1, self.feat_dim).unwrap();
        let outs = execute_tuple(&self.inner.decode, &[wl, fl]).expect("vae_decode");
        outs[0].to_vec().expect("recon")
    }
}
