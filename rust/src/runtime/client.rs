//! PJRT client construction and HLO compilation helpers.
//!
//! Thread-safety note: the `xla` crate wraps its client in an `Rc`, making
//! handles `!Send` even though the underlying `xla::PjRtClient` (C++) is
//! thread-safe. Our backends therefore each own a *private* client plus the
//! executables compiled on it; the whole bundle moves to a worker thread
//! once and is never shared, so the Rc refcounts are single-threaded. The
//! backends assert this by wrapping the bundle in [`SendBundle`].

use std::path::Path;

use anyhow::{Context, Result};

/// Create a fresh PJRT CPU client (one per backend instance).
pub fn new_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().context("creating PJRT CPU client")
}

/// Load one HLO-text artifact and compile it on `client`.
pub fn compile_hlo_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
}

/// Execute and unpack the result tuple (`aot.py` lowers with
/// `return_tuple=True`, so outputs are always a tuple literal).
pub fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(inputs).context("pjrt execute")?;
    let lit = result[0][0].to_literal_sync().context("fetch result")?;
    lit.to_tuple().context("untuple result")
}

/// Marker wrapper asserting single-threaded ownership of `!Send` PJRT
/// handles. Safety contract: the wrapped value (client + executables whose
/// internal `Rc`s all point into that client) is moved between threads as
/// one unit and never aliased across threads.
pub struct SendBundle<T>(pub T);

// SAFETY: see type-level docs — exclusive ownership, the C++ objects behind
// the Rc are thread-safe, and the Rc itself is never cloned across threads.
unsafe impl<T> Send for SendBundle<T> {}

impl<T> std::ops::Deref for SendBundle<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> std::ops::DerefMut for SendBundle<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}
