//! In-house benchmark harness (criterion is unavailable offline).
//!
//! Provides warmed, repeated timing with mean ± SEM reporting, plus an
//! aligned table printer used by every `rust/benches/*` target to emit the
//! paper's rows. Benches are `harness = false` binaries that call these.

use std::time::{Duration, Instant};

use crate::stats::summary::Summary;

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Seconds per iteration.
    pub per_iter: Summary,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        if self.per_iter.mean <= 0.0 {
            0.0
        } else {
            units_per_iter / self.per_iter.mean
        }
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>12.3} ms ± {:>8.3} ms  ({} iters)",
            self.name,
            self.per_iter.mean * 1e3,
            self.per_iter.sem * 1e3,
            self.iters
        )
    }
}

/// Time `f` for `iters` measured runs after `warmup` unmeasured ones.
pub fn time<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), iters, per_iter: Summary::of(&samples) }
}

/// Time `f` adaptively: keep iterating until `budget` wall time is spent
/// (at least `min_iters`). Good for cases whose cost is unknown a priori.
pub fn time_budget<F: FnMut()>(name: &str, budget: Duration, min_iters: usize, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < budget {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    BenchResult { name: name.to_string(), iters: samples.len(), per_iter: Summary::of(&samples) }
}

/// Fixed-width table printer: benches print paper-style rows with it.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        for (i, c) in cells.iter().enumerate() {
            self.widths[i] = self.widths[i].max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let parts: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, &w)| format!("{c:<w$}"))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers, &self.widths);
        let sep: Vec<String> = self.widths.iter().map(|&w| "-".repeat(w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// `mean ± sem` with 2 decimals — the paper's cell format.
pub fn pm(mean: f64, sem: f64) -> String {
    format!("{mean:.2} ± {sem:.2}")
}

const PERF_ENTRIES_MARK: &str = "\"entries\":[\n";
const PERF_SUMMARY_MARK: &str = "\n],\n\"summary\":{";

/// Merging sink for `BENCH_perf.json` (schema `gls-serve/BENCH_perf/v1`,
/// hand-rolled — no serde offline). Several bench binaries share one perf
/// log in CI: each declares which `"section"` entries and summary-key
/// prefixes it owns, re-reads the log, keeps everything foreign, and
/// replaces only its own stale records. The path comes from
/// `BENCH_PERF_JSON` (default `BENCH_perf.json`).
pub struct MergingPerfJson {
    path: String,
    entries: Vec<String>,
    /// Raw `"key":value` summary items (kept raw to avoid reparsing floats
    /// written by other benches).
    summary: Vec<String>,
}

impl MergingPerfJson {
    /// Load the existing log, dropping entries whose `"section"` is in
    /// `sections` and summary keys starting with any of `key_prefixes`
    /// (the caller is about to rewrite those).
    pub fn load(sections: &[&str], key_prefixes: &[&str]) -> Self {
        let path = std::env::var("BENCH_PERF_JSON").unwrap_or_else(|_| "BENCH_perf.json".into());
        let doc = std::fs::read_to_string(&path).unwrap_or_default();
        let (entries, summary) = Self::parse_foreign(&doc, sections, key_prefixes);
        Self { path, entries, summary }
    }

    /// Split an existing log into the entries / summary items that belong
    /// to *other* benches (everything not matching `sections` /
    /// `key_prefixes`).
    fn parse_foreign(
        doc: &str,
        sections: &[&str],
        key_prefixes: &[&str],
    ) -> (Vec<String>, Vec<String>) {
        let owned_entry: Vec<String> =
            sections.iter().map(|s| format!("\"section\":\"{s}\"")).collect();
        let owned_key: Vec<String> = key_prefixes.iter().map(|p| format!("\"{p}")).collect();
        let mut entries = Vec::new();
        let mut summary = Vec::new();
        if let (Some(es), Some(ss)) = (doc.find(PERF_ENTRIES_MARK), doc.find(PERF_SUMMARY_MARK)) {
            let body = &doc[es + PERF_ENTRIES_MARK.len()..ss];
            entries.extend(
                body.split(",\n")
                    .map(str::trim)
                    .filter(|e| !e.is_empty())
                    .filter(|e| !owned_entry.iter().any(|m| e.contains(m.as_str())))
                    .map(String::from),
            );
            let rest = &doc[ss + PERF_SUMMARY_MARK.len()..];
            if let Some(end) = rest.find('}') {
                summary.extend(
                    rest[..end]
                        .split(',')
                        .map(str::trim)
                        .filter(|s| !s.is_empty())
                        .filter(|s| !owned_key.iter().any(|p| s.starts_with(p.as_str())))
                        .map(String::from),
                );
            }
        }
        (entries, summary)
    }

    /// Append one raw JSON entry object (the caller formats it).
    pub fn entry(&mut self, raw: String) {
        self.entries.push(raw);
    }

    /// Append one numeric summary metric.
    pub fn metric(&mut self, key: &str, value: f64) {
        self.summary.push(format!("\"{key}\":{value:.3}"));
    }

    pub fn write(&self) {
        let doc = format!(
            "{{\n\"schema\":\"gls-serve/BENCH_perf/v1\",\n\"entries\":[\n{}\n],\n\"summary\":{{{}}}\n}}\n",
            self.entries.join(",\n"),
            self.summary.join(",")
        );
        match std::fs::write(&self.path, doc) {
            Ok(()) => println!("\nwrote {}", self.path),
            Err(e) => eprintln!("\nfailed to write {}: {e}", self.path),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures_positive_duration() {
        let r = time("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.per_iter.mean >= 0.0);
    }

    #[test]
    fn time_budget_respects_min_iters() {
        let r = time_budget("quick", Duration::from_millis(1), 3, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            per_iter: Summary { mean: 0.5, sem: 0.0, n: 1 },
        };
        assert!((r.throughput(100.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()]);
        }));
        assert!(res.is_err());
    }

    #[test]
    fn pm_formats_like_paper() {
        assert_eq!(pm(4.783, 0.238), "4.78 ± 0.24");
    }

    #[test]
    fn merging_perf_json_keeps_foreign_records_only() {
        let doc = concat!(
            "{\n\"schema\":\"gls-serve/BENCH_perf/v1\",\n\"entries\":[\n",
            "{\"section\":\"serving-load\",\"case\":\"steady\"},\n",
            "{\"section\":\"fig2-gaussian\",\"case\":\"kernel\"}\n",
            "],\n\"summary\":{\"serving_load_goodput\":12.000,",
            "\"compression_gaussian_kernel_speedup\":2.100}\n}\n",
        );
        let (entries, summary) = MergingPerfJson::parse_foreign(
            doc,
            &["fig2-gaussian"],
            &["compression_gaussian_"],
        );
        assert_eq!(entries, vec!["{\"section\":\"serving-load\",\"case\":\"steady\"}"]);
        assert_eq!(summary, vec!["\"serving_load_goodput\":12.000"]);

        // A missing / empty log yields a clean slate rather than an error.
        let (e2, s2) = MergingPerfJson::parse_foreign("", &["fig2-gaussian"], &[]);
        assert!(e2.is_empty() && s2.is_empty());
    }

    #[test]
    fn merging_perf_json_round_trips_through_its_own_format() {
        let mut j = MergingPerfJson {
            path: String::new(),
            entries: vec!["{\"section\":\"a\",\"x\":1}".into()],
            summary: vec!["\"a_x\":1.000".into()],
        };
        j.entry("{\"section\":\"b\",\"y\":2}".into());
        j.metric("b_y", 2.0);
        let doc = format!(
            "{{\n\"schema\":\"gls-serve/BENCH_perf/v1\",\n\"entries\":[\n{}\n],\n\"summary\":{{{}}}\n}}\n",
            j.entries.join(",\n"),
            j.summary.join(",")
        );
        // Re-parsing while owning section "b" recovers exactly section "a".
        let (entries, summary) = MergingPerfJson::parse_foreign(&doc, &["b"], &["b_"]);
        assert_eq!(entries, vec!["{\"section\":\"a\",\"x\":1}"]);
        assert_eq!(summary, vec!["\"a_x\":1.000"]);
    }
}
