//! Accelerator-latency simulation wrapper.
//!
//! The paper's token-rate results depend on a property of GPU serving that
//! a CPU-native backend does not have: a batched forward pass costs
//! (almost) the same wall time for 1 row or K·B rows, up to a capacity
//! limit. `TimedLm` wraps any [`LmBackend`] and enforces exactly that cost
//! model: every call takes at least
//!
//! ```text
//! latency = base_latency × ceil(rows / capacity)   (per span position for
//!                                                   span_logits)
//! ```
//!
//! by spin-waiting after the real computation finishes. The draft/target
//! `base_latency` ratio is calibrated to the paper's 0.5B-draft / 7B-target
//! pair (DESIGN.md §2); with it, multi-draft token-rate *speedups* become
//! meaningful on this testbed — the quantity Tables 1–4 report.

use std::time::{Duration, Instant};

use super::backend::LmBackend;

pub struct TimedLm<B: LmBackend> {
    inner: B,
    /// Minimum wall time of one forward call over ≤ `capacity` rows.
    pub base_latency: Duration,
    /// Max rows served at `base_latency` (accelerator batch capacity).
    pub capacity: usize,
}

impl<B: LmBackend> TimedLm<B> {
    pub fn new(inner: B, base_latency: Duration, capacity: usize) -> Self {
        assert!(capacity >= 1);
        Self { inner, base_latency, capacity }
    }

    fn pay(&self, start: Instant, rows: usize, positions: usize) {
        let chunks = rows.div_ceil(self.capacity) as u32;
        // A span pass over P positions is one forward over P-token tails:
        // on an accelerator it is a single call; cost grows sub-linearly.
        // We charge one base latency per chunk (positions folded into the
        // same pass, like real batched verification).
        let _ = positions;
        let min = self.base_latency * chunks;
        while start.elapsed() < min {
            std::hint::spin_loop();
        }
    }
}

impl<B: LmBackend> LmBackend for TimedLm<B> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn next_logits(&mut self, seqs: &[Vec<u32>]) -> Vec<Vec<f32>> {
        let t0 = Instant::now();
        let out = self.inner.next_logits(seqs);
        self.pay(t0, seqs.len(), 1);
        out
    }

    fn span_logits(&mut self, seqs: &[Vec<u32>], start: usize) -> Vec<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let out = self.inner.span_logits(seqs, start);
        let positions = out.first().map_or(1, |r| r.len());
        self.pay(t0, seqs.len(), positions);
        out
    }

    fn span_logits_multi(&mut self, seqs: &[Vec<u32>], starts: &[usize]) -> Vec<Vec<Vec<f32>>> {
        // One fused accelerator pass regardless of start mix: charge a
        // single batched-call latency, not one per distinct start.
        let t0 = Instant::now();
        let out = self.inner.span_logits_multi(seqs, starts);
        let positions = out.iter().map(|r| r.len()).max().unwrap_or(1);
        self.pay(t0, seqs.len(), positions);
        out
    }

    fn describe(&self) -> String {
        format!(
            "timed({}, {}µs, cap {})",
            self.inner.describe(),
            self.base_latency.as_micros(),
            self.capacity
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sim::SimLm;

    #[test]
    fn enforces_minimum_latency() {
        let mut lm = TimedLm::new(
            SimLm::new(16, 1, 2, 4.0, 0.0),
            Duration::from_micros(300),
            64,
        );
        let t0 = Instant::now();
        lm.next_logits(&[vec![1, 2, 3]]);
        assert!(t0.elapsed() >= Duration::from_micros(300));
    }

    #[test]
    fn batch_within_capacity_costs_one_unit() {
        let mut lm = TimedLm::new(
            SimLm::new(16, 1, 2, 4.0, 0.0),
            Duration::from_micros(500),
            64,
        );
        let rows: Vec<Vec<u32>> = (0..32).map(|i| vec![i, 1]).collect();
        let t0 = Instant::now();
        lm.next_logits(&rows);
        let one = t0.elapsed();
        assert!(one >= Duration::from_micros(500));
        assert!(one < Duration::from_micros(1500), "batched call overpriced: {one:?}");
    }

    #[test]
    fn beyond_capacity_costs_multiple_chunks() {
        let mut lm = TimedLm::new(
            SimLm::new(16, 1, 2, 4.0, 0.0),
            Duration::from_micros(400),
            8,
        );
        let rows: Vec<Vec<u32>> = (0..17).map(|i| vec![i]).collect(); // 3 chunks
        let t0 = Instant::now();
        lm.next_logits(&rows);
        assert!(t0.elapsed() >= Duration::from_micros(1200));
    }

    #[test]
    fn passthrough_values_unchanged() {
        let mut plain = SimLm::new(16, 1, 2, 4.0, 0.5);
        let mut timed = TimedLm::new(plain.clone(), Duration::from_micros(50), 64);
        let seqs = vec![vec![1u32, 2, 3], vec![4, 5]];
        assert_eq!(plain.next_logits(&seqs), timed.next_logits(&seqs));
        assert_eq!(plain.span_logits(&seqs, 2), timed.span_logits(&seqs, 2));
    }
}
