//! Byte-level tokenizer.
//!
//! The build-time corpus and the serving path share this trivial,
//! dependency-free scheme: token = byte value, plus BOS/EOS/PAD specials
//! above 255. The JAX training script (`python/compile/train.py`) uses the
//! identical mapping, so artifacts and the Rust coordinator agree on ids.

pub const BOS: u32 = 256;
pub const EOS: u32 = 257;
pub const PAD: u32 = 258;
/// Total vocabulary (bytes + specials, rounded up for the model head).
pub const VOCAB: usize = 259;

#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        Self
    }

    pub fn vocab(&self) -> usize {
        VOCAB
    }

    /// Encode text with a leading BOS.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(BOS);
        out.extend(text.bytes().map(|b| b as u32));
        out
    }

    /// Decode tokens, skipping specials; invalid UTF-8 is replaced.
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens.iter().filter(|&&t| t < 256).map(|&t| t as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let toks = t.encode("hello, GLS!");
        assert_eq!(toks[0], BOS);
        assert_eq!(t.decode(&toks), "hello, GLS!");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer::new();
        let s = "héllo ∑";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn decode_skips_specials() {
        let t = ByteTokenizer::new();
        assert_eq!(t.decode(&[BOS, b'a' as u32, EOS, PAD, b'b' as u32]), "ab");
    }

    #[test]
    fn vocab_covers_all_tokens() {
        let t = ByteTokenizer::new();
        let toks = t.encode("xyz");
        assert!(toks.iter().all(|&tok| (tok as usize) < t.vocab()));
    }
}
