//! Sampling configuration shared by drafting and verification.

/// Temperature / top-k post-processing applied to raw logits before any
/// coupling math — matching the paper's LLM experiments (top-k 50, varying
/// temperatures per drafter, target temperature 1.0 or 2.0).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    pub temperature: f64,
    pub top_k: Option<usize>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 1.0, top_k: Some(50) }
    }
}

impl SamplingParams {
    pub fn new(temperature: f64, top_k: Option<usize>) -> Self {
        assert!(temperature > 0.0, "temperature must be positive");
        Self { temperature, top_k }
    }

    pub fn greedy_ish(temperature: f64) -> Self {
        Self { temperature, top_k: Some(1) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::types::Categorical;

    #[test]
    fn params_apply_through_categorical() {
        let logits = vec![2.0f32, 1.0, 0.0, -1.0];
        let sp = SamplingParams::new(0.5, Some(2));
        let c = Categorical::from_logits(&logits, sp.temperature, sp.top_k);
        assert_eq!(c.prob(2), 0.0);
        assert_eq!(c.prob(3), 0.0);
        assert!(c.prob(0) > c.prob(1));
    }

    #[test]
    #[should_panic]
    fn zero_temperature_rejected() {
        SamplingParams::new(0.0, None);
    }
}
