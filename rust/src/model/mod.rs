//! Model abstractions for the serving engine.
//!
//! Two interchangeable backends implement [`LmBackend`]:
//!
//! * [`crate::runtime::PjrtLm`] — the production path: AOT-compiled JAX
//!   transformer artifacts executed through the PJRT CPU client.
//! * [`sim::SimLm`] — a native-Rust simulated language model with a
//!   controllable draft/target alignment knob. It mirrors the logits
//!   interface exactly and is used by unit tests and the algorithm-level
//!   benches, where thousands of decode steps per second matter.

pub mod backend;
pub mod sampling;
pub mod sim;
pub mod timed;
pub mod tokenizer;

pub use backend::LmBackend;
pub use sampling::SamplingParams;
pub use sim::SimLm;
pub use timed::TimedLm;
pub use tokenizer::ByteTokenizer;
