//! The backend trait the coordinator drives.

/// A causal language model the engine can query for next-token logits.
///
/// Implementations may cache internal state (KV pages) keyed by the
/// sequence contents; the interface is deliberately *functional* (context
/// in, logits out) so verification replay and the drafter-invariance audits
/// can re-run any step.
pub trait LmBackend: Send {
    /// Vocabulary size (logit vector length).
    fn vocab(&self) -> usize;

    /// Next-token logits for each sequence in the batch. `seqs[i]` is the
    /// full token context of row i; the result has one `[vocab]` row per
    /// input row.
    fn next_logits(&mut self, seqs: &[Vec<u32>]) -> Vec<Vec<f32>>;

    /// Logits at positions `start-1 .. seq.len()-1` of each row — i.e. the
    /// model's predictive distribution for tokens `start ..= seq.len()`,
    /// one extra position past the end (the verification pass of
    /// speculative decoding: score L draft positions plus the bonus slot in
    /// one call). Returns `[rows][seq.len() - start + 1][vocab]`.
    fn span_logits(&mut self, seqs: &[Vec<u32>], start: usize) -> Vec<Vec<Vec<f32>>>;

    /// Span pass with a *per-row* start: row `i` is scored from
    /// `starts[i]`. One engine iteration verifies every sequence of a
    /// continuous batch in a single call through this method, rather than
    /// one `span_logits` call per distinct start. The default groups
    /// consecutive equal-start runs (still one call for the common
    /// uniform-batch case); accelerator backends override it with a single
    /// fused forward.
    fn span_logits_multi(&mut self, seqs: &[Vec<u32>], starts: &[usize]) -> Vec<Vec<Vec<f32>>> {
        assert_eq!(seqs.len(), starts.len(), "one start per row");
        let mut out = Vec::with_capacity(seqs.len());
        let mut i = 0;
        while i < seqs.len() {
            let mut j = i + 1;
            while j < seqs.len() && starts[j] == starts[i] {
                j += 1;
            }
            out.extend(self.span_logits(&seqs[i..j], starts[i]));
            i = j;
        }
        out
    }

    /// Human-readable backend identifier for metrics/logs.
    fn describe(&self) -> String {
        "lm-backend".to_string()
    }
}

/// A draft/target pair, as the engine consumes them. `draft_temps` allows
/// per-draft-lane temperature (the diverse-drafts experiments, Table 2/4).
pub struct ModelPair {
    pub draft: Box<dyn LmBackend>,
    pub target: Box<dyn LmBackend>,
}

impl ModelPair {
    pub fn new(draft: Box<dyn LmBackend>, target: Box<dyn LmBackend>) -> Self {
        assert_eq!(draft.vocab(), target.vocab(), "draft/target vocab mismatch");
        Self { draft, target }
    }

    pub fn vocab(&self) -> usize {
        self.target.vocab()
    }
}
