//! Simulated language models with controllable draft/target alignment.
//!
//! `SimLm` produces deterministic pseudo-random logits as a smooth function
//! of the recent context n-gram. Two instances sharing a `base_seed` but
//! with different `divergence` produce a draft/target pair whose token
//! distributions overlap heavily but not perfectly — the regime where
//! speculative decoding is interesting. The `divergence` knob plays the
//! role of "0.5B draft vs 7B target" alignment and is calibrated in the
//! benches so single-draft block efficiency lands in the paper's observed
//! range (≈3–4.3 with L=4–5).

use crate::stats::rng::SplitMix64;

use super::backend::LmBackend;

/// Deterministic simulated LM.
///
/// Logits are `sharpness * u1(ctx, i) + divergence * u2(ctx, i)` where `u1`
/// derives from the shared `base_seed` (the "true" signal both models see)
/// and `u2` from the private `model_seed` (this model's idiosyncrasy).
#[derive(Clone, Debug)]
pub struct SimLm {
    vocab: usize,
    base_seed: u64,
    model_seed: u64,
    /// Peakedness of the shared signal; higher = lower-entropy next-token
    /// distributions (task difficulty knob: "GSM8K-like" vs "DROP-like").
    sharpness: f32,
    /// Weight of the private signal; 0 = identical to any sibling model.
    divergence: f32,
    /// Context window for the hash (n-gram order).
    order: usize,
}

impl SimLm {
    pub fn new(vocab: usize, base_seed: u64, model_seed: u64, sharpness: f32, divergence: f32) -> Self {
        assert!(vocab >= 2);
        Self { vocab, base_seed, model_seed, sharpness, divergence, order: 3 }
    }

    /// A well-aligned draft/target pair for quick tests.
    pub fn pair(vocab: usize, seed: u64, divergence: f32) -> (SimLm, SimLm) {
        let target = SimLm::new(vocab, seed, seed ^ 0x1111, 4.0, 0.0);
        let draft = SimLm::new(vocab, seed, seed ^ 0x2222, 4.0, divergence);
        (draft, target)
    }

    #[inline]
    fn ctx_hash(&self, seq: &[u32]) -> u64 {
        let start = seq.len().saturating_sub(self.order);
        let mut h = self.base_seed;
        for &t in &seq[start..] {
            h = SplitMix64::mix(h ^ (t as u64).wrapping_mul(0x100000001B3));
        }
        h
    }

    /// Logits for the next token after `seq`.
    pub fn logits_at(&self, seq: &[u32]) -> Vec<f32> {
        let h = self.ctx_hash(seq);
        let hp = SplitMix64::mix(h ^ self.model_seed);
        let mut out = Vec::with_capacity(self.vocab);
        for i in 0..self.vocab {
            let shared = SplitMix64::mix(h ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let private = SplitMix64::mix(hp ^ (i as u64).wrapping_mul(0xC2B2AE3D27D4EB4F));
            let u1 = (shared >> 40) as f32 / (1u64 << 24) as f32; // [0,1)
            let u2 = (private >> 40) as f32 / (1u64 << 24) as f32;
            out.push(self.sharpness * u1 + self.divergence * u2);
        }
        out
    }
}

impl LmBackend for SimLm {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn next_logits(&mut self, seqs: &[Vec<u32>]) -> Vec<Vec<f32>> {
        seqs.iter().map(|s| self.logits_at(s)).collect()
    }

    fn span_logits(&mut self, seqs: &[Vec<u32>], start: usize) -> Vec<Vec<Vec<f32>>> {
        seqs.iter()
            .map(|s| {
                assert!(start >= 1 && start <= s.len() + 1, "start {start} out of range");
                (start - 1..=s.len().saturating_sub(0))
                    .filter(|&pos| pos <= s.len())
                    .map(|pos| self.logits_at(&s[..pos]))
                    .collect()
            })
            .collect()
    }

    fn describe(&self) -> String {
        format!(
            "sim-lm(vocab={}, sharpness={}, divergence={})",
            self.vocab, self.sharpness, self.divergence
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::types::Categorical;

    #[test]
    fn logits_deterministic_and_context_sensitive() {
        let lm = SimLm::new(32, 1, 2, 4.0, 0.0);
        assert_eq!(lm.logits_at(&[1, 2, 3]), lm.logits_at(&[1, 2, 3]));
        assert_ne!(lm.logits_at(&[1, 2, 3]), lm.logits_at(&[1, 2, 4]));
        // Order-3 hash: tokens further back than 3 positions don't matter.
        assert_eq!(lm.logits_at(&[9, 1, 2, 3]), lm.logits_at(&[7, 1, 2, 3]));
    }

    #[test]
    fn zero_divergence_pair_is_identical() {
        let (mut draft, mut target) = SimLm::pair(16, 5, 0.0);
        let ctx = vec![3u32, 1, 4];
        assert_eq!(draft.next_logits(&[ctx.clone()]), target.next_logits(&[ctx]));
    }

    #[test]
    fn divergence_controls_tv_distance() {
        let ctxs: Vec<Vec<u32>> = (0..20).map(|i| vec![i, i + 1, i + 2]).collect();
        let tv_at = |div: f32| {
            let (draft, target) = SimLm::pair(64, 5, div);
            let mut total = 0.0;
            for ctx in &ctxs {
                let p = Categorical::from_logits(&draft.logits_at(ctx), 1.0, None);
                let q = Categorical::from_logits(&target.logits_at(ctx), 1.0, None);
                total += p.tv_distance(&q);
            }
            total / ctxs.len() as f64
        };
        let low = tv_at(0.5);
        let high = tv_at(4.0);
        assert!(low < high, "tv(0.5)={low} vs tv(4.0)={high}");
        assert!(low > 0.0);
    }

    #[test]
    fn span_logits_matches_repeated_next_logits() {
        let mut lm = SimLm::new(16, 3, 4, 4.0, 1.0);
        let seq = vec![1u32, 2, 3, 4, 5];
        let span = lm.span_logits(&[seq.clone()], 3);
        // Positions: predictive dist for tokens 3, 4, 5, and one past end.
        assert_eq!(span[0].len(), seq.len() - 3 + 2);
        assert_eq!(span[0][0], lm.logits_at(&seq[..2]));
        assert_eq!(span[0][1], lm.logits_at(&seq[..3]));
        assert_eq!(span[0].last().unwrap(), &lm.logits_at(&seq));
    }

    #[test]
    fn sharpness_lowers_entropy() {
        let flat = SimLm::new(64, 9, 9, 0.5, 0.0);
        let sharp = SimLm::new(64, 9, 9, 8.0, 0.0);
        let ctx = vec![1u32, 2];
        let ent = |lm: &SimLm| {
            let c = Categorical::from_logits(&lm.logits_at(&ctx), 1.0, None);
            -c.probs().iter().filter(|&&p| p > 0.0).map(|&p| p * p.ln()).sum::<f64>()
        };
        assert!(ent(&sharp) < ent(&flat));
    }
}
