//! Open-loop request traces: Poisson arrivals for latency-under-load
//! experiments (the serving benches and the e2e example), plus the
//! trace-driven load harness — bursty (Markov-modulated Poisson)
//! arrivals, heavy-tailed (log-normal / Zipf) prompt and output
//! lengths, and per-request `VerifierKind` mixes — all deterministic
//! per seed so drills replay bit-identically.

use crate::analysis::lanes::{self, TraceStream};
use crate::spec::types::VerifierKind;
use crate::stats::rng::XorShift128;

/// One scheduled request arrival.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Offset from trace start.
    pub at: std::time::Duration,
    /// Index into the workload's prompt list.
    pub prompt_idx: usize,
}

/// Poisson-process arrival trace.
#[derive(Clone, Debug)]
pub struct PoissonTrace {
    pub events: Vec<TraceEvent>,
}

impl PoissonTrace {
    /// `rate` requests/second for `n` requests, cycling over `num_prompts`.
    pub fn generate(rate: f64, n: usize, num_prompts: usize, seed: u64) -> Self {
        assert!(rate > 0.0 && num_prompts > 0);
        let mut rng = XorShift128::new(seed);
        let mut t = 0.0f64;
        let events = (0..n)
            .map(|i| {
                t += -rng.next_f64().ln() / rate; // Exp(rate) inter-arrival
                TraceEvent {
                    at: std::time::Duration::from_secs_f64(t),
                    prompt_idx: i % num_prompts,
                }
            })
            .collect();
        Self { events }
    }

    pub fn duration(&self) -> std::time::Duration {
        self.events.last().map(|e| e.at).unwrap_or_default()
    }

    /// Empirical arrival rate (events per second over the span).
    pub fn empirical_rate(&self) -> f64 {
        let d = self.duration().as_secs_f64();
        if d <= 0.0 {
            0.0
        } else {
            self.events.len() as f64 / d
        }
    }
}

/// Arrival-process family for the load harness.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at `rate` requests/second.
    Poisson { rate: f64 },
    /// Two-state Markov-modulated Poisson process (a doubly-stochastic
    /// Poisson process): arrivals alternate between a calm and a burst
    /// intensity, with exponentially distributed dwell times in each
    /// state. The inter-arrival coefficient of variation exceeds 1
    /// (Poisson's CV) whenever the two rates differ — the over-dispersed
    /// regime real serving traffic lives in.
    Mmpp {
        calm_rate: f64,
        burst_rate: f64,
        /// Mean dwell time in the calm state, seconds.
        calm_dwell_s: f64,
        /// Mean dwell time in the burst state, seconds.
        burst_dwell_s: f64,
    },
}

impl ArrivalProcess {
    /// Sample `n` sorted arrival offsets (seconds).
    fn sample_arrivals(&self, n: usize, rng: &mut XorShift128) -> Vec<f64> {
        match *self {
            ArrivalProcess::Poisson { rate } => {
                assert!(rate > 0.0, "Poisson rate must be positive");
                let mut t = 0.0f64;
                (0..n).map(|_| { t += exp_sample(rng, rate); t }).collect()
            }
            ArrivalProcess::Mmpp { calm_rate, burst_rate, calm_dwell_s, burst_dwell_s } => {
                assert!(
                    calm_rate > 0.0 && burst_rate > 0.0 && calm_dwell_s > 0.0 && burst_dwell_s > 0.0,
                    "MMPP rates and dwells must be positive"
                );
                let mut out = Vec::with_capacity(n);
                let mut t = 0.0f64;
                let mut burst = false;
                // Remaining time before the modulating chain switches state.
                let mut dwell = exp_sample(rng, 1.0 / calm_dwell_s);
                while out.len() < n {
                    let rate = if burst { burst_rate } else { calm_rate };
                    let x = exp_sample(rng, rate);
                    if x <= dwell {
                        t += x;
                        dwell -= x;
                        out.push(t);
                    } else {
                        // No arrival before the switch: advance to the
                        // boundary and toggle. Memorylessness of the
                        // exponential justifies resampling the
                        // inter-arrival from scratch in the new state.
                        t += dwell;
                        burst = !burst;
                        let mean = if burst { burst_dwell_s } else { calm_dwell_s };
                        dwell = exp_sample(rng, 1.0 / mean);
                    }
                }
                out
            }
        }
    }
}

/// Exp(rate) sample via inverse CDF (guarding ln(0)).
fn exp_sample(rng: &mut XorShift128, rate: f64) -> f64 {
    -rng.next_f64().max(f64::MIN_POSITIVE).ln() / rate
}

/// Length distribution for prompt and output sizes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LengthModel {
    Fixed(usize),
    /// `exp(Normal(mu, sigma))` rounded and clamped to `[min, max]` —
    /// the classic heavy-tailed prompt-length model.
    LogNormal { mu: f64, sigma: f64, min: usize, max: usize },
    /// Zipf over the integer support `[min, max]` with exponent `s`
    /// (weight `k^-s`): small lengths dominate, the tail decays
    /// polynomially.
    Zipf { s: f64, min: usize, max: usize },
}

impl LengthModel {
    pub fn sample(&self, rng: &mut XorShift128) -> usize {
        match *self {
            LengthModel::Fixed(n) => n,
            LengthModel::LogNormal { mu, sigma, min, max } => {
                assert!(min <= max && min > 0, "LogNormal support must be non-empty and positive");
                // Box-Muller.
                let u1 = rng.next_f64().max(f64::MIN_POSITIVE);
                let u2 = rng.next_f64();
                let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
                let x = (mu + sigma * z).exp();
                (x.round() as usize).clamp(min, max)
            }
            LengthModel::Zipf { s, min, max } => {
                assert!(min >= 1 && min <= max, "Zipf support must be non-empty with min >= 1");
                // Inverse CDF over the finite support; O(max - min) per
                // draw, fine at drill scale.
                let total: f64 = (min..=max).map(|k| (k as f64).powf(-s)).sum();
                let mut u = rng.next_f64() * total;
                for k in min..=max {
                    u -= (k as f64).powf(-s);
                    if u <= 0.0 {
                        return k;
                    }
                }
                max
            }
        }
    }
}

/// Full specification of a load trace. Every field feeds a dedicated
/// sub-RNG derived from `seed`, so changing (say) the verifier mix does
/// not perturb the arrival times.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub arrivals: ArrivalProcess,
    /// Number of requests.
    pub n: usize,
    pub prompt_len: LengthModel,
    pub output_len: LengthModel,
    /// Per-request verifier assignment as (kind, weight) pairs sampled
    /// proportionally; empty means every request uses the engine default
    /// (`verifier: None`).
    pub verifier_mix: Vec<(VerifierKind, f64)>,
    pub seed: u64,
}

/// One request in a generated load trace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceRequest {
    /// Offset from trace start.
    pub at: std::time::Duration,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    /// `None` = engine-default verifier.
    pub verifier: Option<VerifierKind>,
}

/// A fully materialized request trace (sorted by arrival time).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RequestTrace {
    pub requests: Vec<TraceRequest>,
}

impl RequestTrace {
    /// Deterministically expand a spec into a trace. Identical specs
    /// (including seed) produce bit-identical traces; each aspect
    /// (arrivals / prompt lengths / output lengths / verifier kinds)
    /// draws from its own salted sub-stream so marginals are stable
    /// under changes to the others.
    pub fn generate(spec: &TraceSpec) -> Self {
        // Sub-stream seeds come from the central lane registry
        // (`analysis::lanes`), which also proves the four salts (plus every
        // per-prompt salt) pairwise distinct as a tier-1 test.
        let mut arrival_rng =
            XorShift128::new(lanes::trace_stream_seed(spec.seed, TraceStream::Arrivals));
        let mut prompt_rng =
            XorShift128::new(lanes::trace_stream_seed(spec.seed, TraceStream::PromptLen));
        let mut output_rng =
            XorShift128::new(lanes::trace_stream_seed(spec.seed, TraceStream::OutputLen));
        let mut kind_rng =
            XorShift128::new(lanes::trace_stream_seed(spec.seed, TraceStream::VerifierMix));
        let total_weight: f64 = spec.verifier_mix.iter().map(|(_, w)| w).sum();
        let arrivals = spec.arrivals.sample_arrivals(spec.n, &mut arrival_rng);
        let requests = arrivals
            .into_iter()
            .map(|t| {
                let verifier = if spec.verifier_mix.is_empty() || total_weight <= 0.0 {
                    None
                } else {
                    let mut u = kind_rng.next_f64() * total_weight;
                    let mut pick = spec.verifier_mix.last().map(|(k, _)| *k);
                    for &(k, w) in &spec.verifier_mix {
                        u -= w;
                        if u <= 0.0 {
                            pick = Some(k);
                            break;
                        }
                    }
                    pick
                };
                TraceRequest {
                    at: std::time::Duration::from_secs_f64(t),
                    prompt_len: spec.prompt_len.sample(&mut prompt_rng).max(1),
                    max_new_tokens: spec.output_len.sample(&mut output_rng).max(1),
                    verifier,
                }
            })
            .collect();
        Self { requests }
    }

    pub fn duration(&self) -> std::time::Duration {
        self.requests.last().map(|r| r.at).unwrap_or_default()
    }

    /// Empirical arrival rate (requests per second over the span);
    /// 0.0 for empty or zero-span traces.
    pub fn empirical_rate(&self) -> f64 {
        let d = self.duration().as_secs_f64();
        if d <= 0.0 {
            0.0
        } else {
            self.requests.len() as f64 / d
        }
    }

    /// Deterministic prompt tokens for request `idx`: a fixed function
    /// of (trace seed, idx) so replays hand the engine bit-identical
    /// prompts regardless of generation order.
    pub fn prompt_tokens(&self, idx: usize, vocab: usize, seed: u64) -> Vec<u32> {
        let len = self.requests[idx].prompt_len;
        let mut rng = XorShift128::new(lanes::trace_prompt_seed(seed, idx));
        (0..len).map(|_| rng.next_below(vocab as u64) as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_sorted_and_rate_is_close() {
        let tr = PoissonTrace::generate(100.0, 2000, 10, 3);
        assert_eq!(tr.events.len(), 2000);
        for w in tr.events.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let rate = tr.empirical_rate();
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
    }

    #[test]
    fn prompt_indices_cycle() {
        let tr = PoissonTrace::generate(10.0, 25, 10, 1);
        assert_eq!(tr.events[0].prompt_idx, 0);
        assert_eq!(tr.events[10].prompt_idx, 0);
        assert_eq!(tr.events[24].prompt_idx, 4);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = PoissonTrace::generate(50.0, 100, 5, 9);
        let b = PoissonTrace::generate(50.0, 100, 5, 9);
        assert_eq!(a.duration(), b.duration());
    }

    #[test]
    fn poisson_trace_edge_cases_do_not_panic() {
        let empty = PoissonTrace::generate(10.0, 0, 3, 1);
        assert_eq!(empty.events.len(), 0);
        assert_eq!(empty.duration(), std::time::Duration::ZERO);
        assert_eq!(empty.empirical_rate(), 0.0);
        let one = PoissonTrace::generate(10.0, 1, 3, 1);
        assert_eq!(one.events.len(), 1);
        assert!(one.duration() > std::time::Duration::ZERO);
        assert!(one.empirical_rate().is_finite());
    }

    fn mixed_spec(seed: u64) -> TraceSpec {
        TraceSpec {
            arrivals: ArrivalProcess::Poisson { rate: 200.0 },
            n: 2000,
            prompt_len: LengthModel::LogNormal { mu: 2.5, sigma: 0.6, min: 2, max: 96 },
            output_len: LengthModel::Zipf { s: 0.9, min: 4, max: 40 },
            verifier_mix: vec![(VerifierKind::Gls, 0.5), (VerifierKind::SpecInfer, 0.5)],
            seed,
        }
    }

    #[test]
    fn request_trace_is_bit_identical_per_seed() {
        let spec = mixed_spec(77);
        let a = RequestTrace::generate(&spec);
        let b = RequestTrace::generate(&spec);
        assert_eq!(a, b, "identical specs must replay bit-identically");
        assert_eq!(a.prompt_tokens(5, 64, spec.seed), b.prompt_tokens(5, 64, spec.seed));
        let c = RequestTrace::generate(&mixed_spec(78));
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn request_trace_is_sorted_and_edge_cases_hold() {
        let tr = RequestTrace::generate(&mixed_spec(3));
        for w in tr.requests.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let mut spec = mixed_spec(3);
        spec.n = 0;
        let empty = RequestTrace::generate(&spec);
        assert_eq!(empty.requests.len(), 0);
        assert_eq!(empty.empirical_rate(), 0.0);
        assert_eq!(empty.duration(), std::time::Duration::ZERO);
        spec.n = 1;
        let one = RequestTrace::generate(&spec);
        assert_eq!(one.requests.len(), 1);
        assert!(one.empirical_rate().is_finite());
    }

    #[test]
    fn mmpp_arrivals_are_overdispersed_vs_poisson() {
        // Extreme rate separation: the inter-arrival CV must clearly
        // exceed the Poisson value of 1.
        let spec = TraceSpec {
            arrivals: ArrivalProcess::Mmpp {
                calm_rate: 5.0,
                burst_rate: 2000.0,
                calm_dwell_s: 0.5,
                burst_dwell_s: 0.05,
            },
            n: 2000,
            prompt_len: LengthModel::Fixed(4),
            output_len: LengthModel::Fixed(8),
            verifier_mix: vec![],
            seed: 11,
        };
        let tr = RequestTrace::generate(&spec);
        assert_eq!(tr.requests.len(), 2000);
        for w in tr.requests.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let gaps: Vec<f64> = tr
            .requests
            .windows(2)
            .map(|w| (w[1].at - w[0].at).as_secs_f64())
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!(cv > 1.2, "MMPP inter-arrival CV {cv} not over-dispersed");
        // Poisson control at the same empirical rate stays near CV = 1.
        let rate = tr.empirical_rate();
        let ctl = RequestTrace::generate(&TraceSpec {
            arrivals: ArrivalProcess::Poisson { rate },
            ..spec.clone()
        });
        let cgaps: Vec<f64> = ctl
            .requests
            .windows(2)
            .map(|w| (w[1].at - w[0].at).as_secs_f64())
            .collect();
        let cmean = cgaps.iter().sum::<f64>() / cgaps.len() as f64;
        let cvar = cgaps.iter().map(|g| (g - cmean).powi(2)).sum::<f64>() / cgaps.len() as f64;
        let ccv = cvar.sqrt() / cmean;
        assert!(ccv < 1.15, "Poisson control CV {ccv} unexpectedly high");
    }

    #[test]
    fn length_models_match_their_shapes() {
        let mut rng = XorShift128::new(5);
        // Log-normal: median near exp(mu), support clamped.
        let ln = LengthModel::LogNormal { mu: 2.5, sigma: 0.6, min: 2, max: 96 };
        let mut xs: Vec<usize> = (0..4000).map(|_| ln.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| (2..=96).contains(&x)));
        xs.sort_unstable();
        let median = xs[xs.len() / 2] as f64;
        let want = (2.5f64).exp(); // ≈ 12.18
        assert!((median - want).abs() < 4.0, "log-normal median {median} vs {want}");
        // Zipf: the smallest length is sampled more often than the largest.
        let zf = LengthModel::Zipf { s: 0.9, min: 4, max: 40 };
        let zs: Vec<usize> = (0..4000).map(|_| zf.sample(&mut rng)).collect();
        let at_min = zs.iter().filter(|&&x| x == 4).count();
        let at_max = zs.iter().filter(|&&x| x == 40).count();
        assert!(zs.iter().all(|&x| (4..=40).contains(&x)));
        assert!(at_min > at_max * 2, "Zipf head {at_min} not heavier than tail {at_max}");
        assert_eq!(LengthModel::Fixed(7).sample(&mut rng), 7);
    }

    #[test]
    fn verifier_mix_marginals_are_proportional() {
        let tr = RequestTrace::generate(&mixed_spec(21));
        let gls = tr
            .requests
            .iter()
            .filter(|r| r.verifier == Some(VerifierKind::Gls))
            .count();
        let spec_inf = tr
            .requests
            .iter()
            .filter(|r| r.verifier == Some(VerifierKind::SpecInfer))
            .count();
        assert_eq!(gls + spec_inf, 2000, "every request must get a kind from the mix");
        assert!((800..=1200).contains(&gls), "Gls share {gls} outside 40–60%");
        // Empty mix → engine-default verifier on every request.
        let mut spec = mixed_spec(21);
        spec.verifier_mix.clear();
        let plain = RequestTrace::generate(&spec);
        assert!(plain.requests.iter().all(|r| r.verifier.is_none()));
        // Arrival times are unperturbed by the mix change (salted
        // sub-streams).
        for (a, b) in tr.requests.iter().zip(&plain.requests) {
            assert_eq!(a.at, b.at);
        }
    }
}
