//! Open-loop request traces: Poisson arrivals for latency-under-load
//! experiments (the serving benches and the e2e example).

use crate::stats::rng::XorShift128;

/// One scheduled request arrival.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Offset from trace start.
    pub at: std::time::Duration,
    /// Index into the workload's prompt list.
    pub prompt_idx: usize,
}

/// Poisson-process arrival trace.
#[derive(Clone, Debug)]
pub struct PoissonTrace {
    pub events: Vec<TraceEvent>,
}

impl PoissonTrace {
    /// `rate` requests/second for `n` requests, cycling over `num_prompts`.
    pub fn generate(rate: f64, n: usize, num_prompts: usize, seed: u64) -> Self {
        assert!(rate > 0.0 && num_prompts > 0);
        let mut rng = XorShift128::new(seed);
        let mut t = 0.0f64;
        let events = (0..n)
            .map(|i| {
                t += -rng.next_f64().ln() / rate; // Exp(rate) inter-arrival
                TraceEvent {
                    at: std::time::Duration::from_secs_f64(t),
                    prompt_idx: i % num_prompts,
                }
            })
            .collect();
        Self { events }
    }

    pub fn duration(&self) -> std::time::Duration {
        self.events.last().map(|e| e.at).unwrap_or_default()
    }

    /// Empirical arrival rate (events per second over the span).
    pub fn empirical_rate(&self) -> f64 {
        let d = self.duration().as_secs_f64();
        if d <= 0.0 {
            0.0
        } else {
            self.events.len() as f64 / d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_sorted_and_rate_is_close() {
        let tr = PoissonTrace::generate(100.0, 2000, 10, 3);
        assert_eq!(tr.events.len(), 2000);
        for w in tr.events.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let rate = tr.empirical_rate();
        assert!((rate - 100.0).abs() < 10.0, "rate {rate}");
    }

    #[test]
    fn prompt_indices_cycle() {
        let tr = PoissonTrace::generate(10.0, 25, 10, 1);
        assert_eq!(tr.events[0].prompt_idx, 0);
        assert_eq!(tr.events[10].prompt_idx, 0);
        assert_eq!(tr.events[24].prompt_idx, 4);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = PoissonTrace::generate(50.0, 100, 5, 9);
        let b = PoissonTrace::generate(50.0, 100, 5, 9);
        assert_eq!(a.duration(), b.duration());
    }
}
