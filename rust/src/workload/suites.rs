//! Task-suite stand-ins for the paper's evaluation datasets.

use crate::model::backend::ModelPair;
use crate::model::sim::SimLm;
use crate::model::timed::TimedLm;
use crate::stats::rng::XorShift128;

/// A synthetic evaluation suite: prompt shapes plus the draft/target
/// alignment profile that emulates the dataset's difficulty.
#[derive(Clone, Copy, Debug)]
pub struct TaskSuite {
    /// Which paper dataset this suite stands in for.
    pub name: &'static str,
    /// Target-model peakedness: numeric/code tasks are low-entropy.
    pub sharpness: f32,
    /// Draft/target misalignment: harder tasks = drafter helps less.
    pub divergence: f32,
    /// Prompt length range (tokens).
    pub prompt_len: (usize, usize),
    /// Generation budget per request.
    pub max_new_tokens: usize,
}

/// The five suites, ordered as the paper's tables.
///
/// Calibration note (see EXPERIMENTS.md): with L=4, K=1 single-draft
/// verification, these profiles give BE ≈ 4.2 / 3.8 / 3.4 / 3.7 / 3.0 —
/// matching the paper's reported single-draft BEs of 4.18 / 3.75 / 3.43 /
/// 3.68 / 3.00 for GSM8K / HumanEval / NaturalReasoning / MBPP / DROP.
pub const SUITES: [TaskSuite; 5] = [
    TaskSuite {
        name: "gsm8k-sim",
        sharpness: 6.0,
        divergence: 1.35,
        prompt_len: (48, 96),
        max_new_tokens: 64,
    },
    TaskSuite {
        name: "humaneval-sim",
        sharpness: 5.0,
        divergence: 2.1,
        prompt_len: (64, 160),
        max_new_tokens: 64,
    },
    TaskSuite {
        name: "naturalreasoning-sim",
        sharpness: 4.0,
        divergence: 2.9,
        prompt_len: (32, 128),
        max_new_tokens: 64,
    },
    TaskSuite {
        name: "mbpp-sim",
        sharpness: 5.0,
        divergence: 2.3,
        prompt_len: (32, 96),
        max_new_tokens: 64,
    },
    TaskSuite {
        name: "drop-sim",
        sharpness: 3.2,
        divergence: 4.0,
        prompt_len: (96, 192),
        max_new_tokens: 64,
    },
];

impl TaskSuite {
    pub fn by_name(name: &str) -> Option<&'static TaskSuite> {
        SUITES.iter().find(|s| s.name == name)
    }

    /// Generate `n` prompts deterministically from `seed`.
    pub fn prompts(&self, n: usize, vocab: usize, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = XorShift128::new(seed ^ fnv(self.name));
        (0..n)
            .map(|_| {
                let span = self.prompt_len.1 - self.prompt_len.0;
                let len = self.prompt_len.0 + rng.next_below(span.max(1) as u64) as usize;
                (0..len).map(|_| rng.next_below(vocab as u64) as u32).collect()
            })
            .collect()
    }

    /// Build the draft/target model pair for this suite (untimed — pure
    /// distribution simulation for tests and BE-only measurements).
    pub fn model_pair(&self, vocab: usize, seed: u64) -> ModelPair {
        let target = SimLm::new(vocab, seed ^ fnv(self.name), seed ^ 0x7A11, self.sharpness, 0.0);
        let draft = SimLm::new(
            vocab,
            seed ^ fnv(self.name),
            seed ^ 0xD4AF,
            self.sharpness,
            self.divergence,
        );
        ModelPair::new(Box::new(draft), Box::new(target))
    }

    /// Draft-call latency of the simulated accelerator testbed.
    pub const DRAFT_LATENCY_US: u64 = 120;
    /// Target-call latency (≈ the paper's 0.5B-draft / 7B-target ratio).
    pub const TARGET_LATENCY_US: u64 = 950;
    /// Accelerator batch capacity (rows per base-latency call).
    pub const ACCEL_CAPACITY: usize = 64;

    /// Like [`TaskSuite::timed_model_pair`] but with the drafter's
    /// structural divergence scaled by `div_scale`. The diverse-drafts
    /// experiment (Table 2/4) uses `div_scale < 1`: the paper's drafters
    /// there are the *same* model at different temperatures, i.e. highly
    /// aligned structurally, with all diversity injected via temperature —
    /// that is the regime where GLS's symmetric coupling beats SpecInfer's
    /// order-biased rejection.
    pub fn timed_model_pair_scaled(&self, vocab: usize, seed: u64, div_scale: f32) -> ModelPair {
        let target = SimLm::new(vocab, seed ^ fnv(self.name), seed ^ 0x7A11, self.sharpness, 0.0);
        let draft = SimLm::new(
            vocab,
            seed ^ fnv(self.name),
            seed ^ 0xD4AF,
            self.sharpness,
            self.divergence * div_scale,
        );
        ModelPair::new(
            Box::new(TimedLm::new(
                draft,
                std::time::Duration::from_micros(Self::DRAFT_LATENCY_US),
                Self::ACCEL_CAPACITY,
            )),
            Box::new(TimedLm::new(
                target,
                std::time::Duration::from_micros(Self::TARGET_LATENCY_US),
                Self::ACCEL_CAPACITY,
            )),
        )
    }

    /// Like [`TaskSuite::model_pair`] but wrapped in [`TimedLm`] with
    /// accelerator batch-latency semantics — the testbed every token-rate
    /// (TR%) measurement runs on (DESIGN.md §2: wall-clock substitution).
    pub fn timed_model_pair(&self, vocab: usize, seed: u64) -> ModelPair {
        let target = SimLm::new(vocab, seed ^ fnv(self.name), seed ^ 0x7A11, self.sharpness, 0.0);
        let draft = SimLm::new(
            vocab,
            seed ^ fnv(self.name),
            seed ^ 0xD4AF,
            self.sharpness,
            self.divergence,
        );
        ModelPair::new(
            Box::new(TimedLm::new(
                draft,
                std::time::Duration::from_micros(Self::DRAFT_LATENCY_US),
                Self::ACCEL_CAPACITY,
            )),
            Box::new(TimedLm::new(
                target,
                std::time::Duration::from_micros(Self::TARGET_LATENCY_US),
                Self::ACCEL_CAPACITY,
            )),
        )
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_cover_paper_datasets() {
        assert_eq!(SUITES.len(), 5);
        assert!(TaskSuite::by_name("gsm8k-sim").is_some());
        assert!(TaskSuite::by_name("drop-sim").is_some());
        assert!(TaskSuite::by_name("mnist").is_none());
    }

    #[test]
    fn prompts_deterministic_and_in_range() {
        let s = TaskSuite::by_name("humaneval-sim").unwrap();
        let a = s.prompts(10, 64, 42);
        let b = s.prompts(10, 64, 42);
        assert_eq!(a, b);
        for p in &a {
            assert!(p.len() >= s.prompt_len.0 && p.len() < s.prompt_len.1);
            assert!(p.iter().all(|&t| (t as usize) < 64));
        }
    }

    #[test]
    fn different_suites_generate_different_prompts() {
        let a = SUITES[0].prompts(3, 64, 7);
        let b = SUITES[1].prompts(3, 64, 7);
        assert_ne!(a, b);
    }

    #[test]
    fn difficulty_ordering_easy_vs_hard() {
        // gsm8k-sim must be "easier" (lower divergence) than drop-sim.
        let easy = TaskSuite::by_name("gsm8k-sim").unwrap();
        let hard = TaskSuite::by_name("drop-sim").unwrap();
        assert!(easy.divergence < hard.divergence);
    }
}
