//! Synthetic task suites and request traces.
//!
//! The paper evaluates on GSM8K, HumanEval, NaturalReasoning, MBPP and
//! DROP. Offline we cannot ship those datasets, so each suite here is a
//! *statistical stand-in* (DESIGN.md §2): a prompt generator plus a
//! draft/target alignment profile calibrated so the single-draft block
//! efficiencies span the paper's observed spectrum (BE ≈ 4.2 on the
//! easiest suite down to ≈ 3.0 on the hardest, L = 4).

pub mod drills;
pub mod suites;
pub mod trace;

pub use drills::{Drill, DrillOutcome, Scenario};
pub use suites::{TaskSuite, SUITES};
pub use trace::{
    ArrivalProcess, LengthModel, PoissonTrace, RequestTrace, TraceEvent, TraceRequest, TraceSpec,
};
