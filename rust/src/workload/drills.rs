//! Failure-mode drills: scripted heavy-traffic scenarios for the serving
//! stack.
//!
//! A [`Drill`] composes a deterministic [`RequestTrace`] (bursty arrivals,
//! heavy-tailed lengths, mixed verifier kinds) with a fault script —
//! panic storms via `VerifierKind::FaultInjection` + the
//! [`PoisonDraft`] rig, KV-pressure spikes via a tiny page pool,
//! slow-backend stragglers via [`TimedLm`], and engine death (every
//! ticket on one worker faulting mid-flight) — and replays it against a
//! multi-worker router with the server-global verify pool. The outcome
//! carries the full [`ServeReport`] plus a thread census, so tests and
//! benches can gate goodput, latency quantiles, loss/duplication, KV
//! leaks, and thread-pool growth per scenario.
//!
//! Everything is a pure function of `(scenario, seed)`: two drills built
//! from the same pair replay bit-identically, and scenarios share the
//! base trace per seed so honest requests' tokens are comparable across
//! the no-fault and faulting runs (round-robin routing plus per-sequence
//! verification randomness make them bit-identical).

use std::time::{Duration, Instant};

use super::trace::{ArrivalProcess, LengthModel, RequestTrace, TraceSpec};
use crate::coordinator::config::{EngineConfig, PoolScope, ServerConfig, VerifyBackend};
use crate::coordinator::router::{DrainPolicy, Router, RoutingPolicy};
use crate::coordinator::sequence::Request;
use crate::coordinator::server::ServeReport;
use crate::model::backend::ModelPair;
use crate::model::sim::SimLm;
use crate::model::timed::TimedLm;
use crate::spec::types::VerifierKind;
use crate::testkit::{thread_census, PoisonDraft};

/// The drill catalog.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Baseline: the trace with no fault script.
    NoFault,
    /// Same requests, MMPP (calm/burst) arrivals replayed in real time.
    Bursty,
    /// Every 5th request is poisoned: its verify jobs panic on the shared
    /// pool's workers.
    PanicStorm,
    /// KV page pool shrunk so admission constantly defers and recycles.
    KvPressure,
    /// Worker 0's backends pay an accelerator latency per forward call.
    Straggler,
    /// Every ticket routed to worker 0 faults — the worker's engine keeps
    /// dying mid-ticket while worker 1 must stay healthy.
    EngineDeath,
    /// Every 3rd request carries an already-expired (zero) deadline: the
    /// lifecycle layer must reap each one typed (`timed_out`) while every
    /// other request's tokens stay bit-identical to the no-fault run.
    DeadlineStorm,
    /// Every 4th request's cancel handle is flipped before submission:
    /// typed `cancelled` retires, zero KV leak, honest requests bit-exact.
    CancelFlood,
    /// Bounded admission window + uniformly slowed backends: the submit
    /// burst outruns decode, so the router must shed typed (`QueueFull`)
    /// rather than queue without bound.
    OverloadShed,
    /// Panic storm, but the drill drains — cancelling everything in
    /// flight — after half the trace has been submitted: every submitted
    /// id must still land exactly one terminal state with a flat census.
    DrainUnderStorm,
    /// Panic storm + KV pressure + a straggler worker, all at once.
    ComposedFault,
}

impl Scenario {
    pub fn all() -> [Scenario; 11] {
        [
            Scenario::NoFault,
            Scenario::Bursty,
            Scenario::PanicStorm,
            Scenario::KvPressure,
            Scenario::Straggler,
            Scenario::EngineDeath,
            Scenario::DeadlineStorm,
            Scenario::CancelFlood,
            Scenario::OverloadShed,
            Scenario::DrainUnderStorm,
            Scenario::ComposedFault,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Scenario::NoFault => "no-fault",
            Scenario::Bursty => "bursty",
            Scenario::PanicStorm => "panic-storm",
            Scenario::KvPressure => "kv-pressure",
            Scenario::Straggler => "straggler",
            Scenario::EngineDeath => "engine-death",
            Scenario::DeadlineStorm => "deadline-storm",
            Scenario::CancelFlood => "cancel-flood",
            Scenario::OverloadShed => "overload-shed",
            Scenario::DrainUnderStorm => "drain-under-storm",
            Scenario::ComposedFault => "composed-fault",
        }
    }
}

/// A fully specified drill: configs + trace + fault script. Fields are
/// public so tests can scale the shape (e.g. shrink `trace` or toggle
/// `engine_cfg.retry_transient_faults`) before [`Drill::run`].
pub struct Drill {
    pub scenario: Scenario,
    pub seed: u64,
    pub server_cfg: ServerConfig,
    pub engine_cfg: EngineConfig,
    pub trace: RequestTrace,
    /// Request ids whose prompts carry the fault trigger.
    pub poisoned: Vec<u64>,
    /// Request ids scripted with an already-expired zero deadline.
    pub deadline_zero: Vec<u64>,
    /// Request ids whose cancel handle is flipped just before submission.
    pub cancel_at_submit: Vec<u64>,
    /// Submit only this many requests, then `drain(CancelInFlight)` the
    /// router instead of waiting for completions.
    pub drain_after: Option<usize>,
    /// `(worker, base_latency)` for the straggler's [`TimedLm`] wrap.
    pub straggler: Option<(usize, Duration)>,
    /// Wrap *every* worker's backends in [`TimedLm`] with this latency —
    /// overload-shed uses it so decode reliably outlasts the submit burst.
    pub slow_all: Option<Duration>,
    /// Transient pool faults to arm before replay (retry-once drills).
    pub inject_transient_faults: usize,
    pub vocab: usize,
    /// Out-of-vocab token that arms [`PoisonDraft`].
    pub trigger: u32,
    /// 0.0 replays as fast as possible; 1.0 honors trace arrival times.
    pub time_scale: f64,
}

impl Drill {
    pub fn new(scenario: Scenario, seed: u64) -> Self {
        let server_cfg = ServerConfig {
            workers: 2,
            max_batch: 8,
            batch_deadline: Duration::from_millis(1),
            max_running: 16,
            kv_pages: 4096,
            kv_page_size: 16,
            pool_scope: PoolScope::Server,
            ..ServerConfig::default()
        };
        let engine_cfg = EngineConfig {
            verifier: VerifierKind::Gls,
            num_drafts: 3,
            block_len: 4,
            max_seq_len: 256,
            // Force pool fan-out on every multi-sequence batch so the
            // shared pool actually carries the drill's verification load.
            parallel_threshold: 0,
            verify_workers: 3,
            verify_backend: VerifyBackend::Pool,
            ..EngineConfig::default()
        };
        // All scenarios share this base spec per seed: the prompt /
        // output / kind sub-streams are salted independently of the
        // arrival process, so even the bursty overlay keeps per-request
        // payloads identical to the no-fault run.
        let mut spec = TraceSpec {
            arrivals: ArrivalProcess::Poisson { rate: 600.0 },
            n: 48,
            // mu = ln 12: median prompt ≈ 12 tokens, tail to 96.
            prompt_len: LengthModel::LogNormal { mu: 2.485, sigma: 0.6, min: 2, max: 96 },
            output_len: LengthModel::Zipf { s: 0.9, min: 4, max: 40 },
            verifier_mix: vec![
                (VerifierKind::Gls, 0.55),
                (VerifierKind::SpecInfer, 0.2),
                (VerifierKind::SpecTr, 0.1),
                (VerifierKind::Daliri, 0.15),
            ],
            seed,
        };
        let mut drill = Drill {
            scenario,
            seed,
            server_cfg,
            engine_cfg,
            trace: RequestTrace { requests: Vec::new() },
            poisoned: Vec::new(),
            deadline_zero: Vec::new(),
            cancel_at_submit: Vec::new(),
            drain_after: None,
            straggler: None,
            slow_all: None,
            inject_transient_faults: 0,
            vocab: 64,
            trigger: 9_999,
            time_scale: 0.0,
        };
        match scenario {
            Scenario::NoFault => {}
            Scenario::Bursty => {
                spec.arrivals = ArrivalProcess::Mmpp {
                    calm_rate: 120.0,
                    burst_rate: 3000.0,
                    calm_dwell_s: 0.04,
                    burst_dwell_s: 0.01,
                };
                drill.time_scale = 1.0;
            }
            Scenario::PanicStorm => {
                drill.poisoned = (0..spec.n as u64).filter(|i| i % 5 == 0).collect();
            }
            Scenario::KvPressure => {
                // ~3 concurrent worst-case sequences' worth of pages:
                // admission must defer and recycle constantly.
                drill.server_cfg.kv_pages = 32;
            }
            Scenario::Straggler => {
                drill.straggler = Some((0, Duration::from_micros(400)));
            }
            Scenario::EngineDeath => {
                // RoundRobin sends id % workers to worker id % workers:
                // poisoning the even ids keeps killing worker 0's engine
                // mid-ticket for the whole run.
                let w = drill.server_cfg.workers as u64;
                drill.poisoned = (0..spec.n as u64).filter(|i| i % w == 0).collect();
            }
            Scenario::DeadlineStorm => {
                drill.deadline_zero = (0..spec.n as u64).filter(|i| i % 3 == 0).collect();
            }
            Scenario::CancelFlood => {
                drill.cancel_at_submit = (0..spec.n as u64).filter(|i| i % 4 == 0).collect();
            }
            Scenario::OverloadShed => {
                drill.server_cfg.admit_queue = 6;
                drill.slow_all = Some(Duration::from_micros(200));
            }
            Scenario::DrainUnderStorm => {
                drill.poisoned = (0..spec.n as u64).filter(|i| i % 5 == 0).collect();
                drill.drain_after = Some(spec.n / 2);
            }
            Scenario::ComposedFault => {
                drill.poisoned = (0..spec.n as u64).filter(|i| i % 5 == 0).collect();
                drill.server_cfg.kv_pages = 32;
                drill.straggler = Some((0, Duration::from_micros(400)));
            }
        }
        drill.trace = RequestTrace::generate(&spec);
        drill
    }

    /// The request for trace index `idx` (`id == idx`). Poisoned ids get
    /// the trigger prompt plus `FaultInjection`; everyone else gets the
    /// trace's deterministic prompt, budget and verifier kind.
    pub fn request(&self, idx: usize) -> Request {
        let id = idx as u64;
        let tr = &self.trace.requests[idx];
        let req = if self.poisoned.contains(&id) {
            Request::new(id, vec![self.trigger], tr.max_new_tokens)
                .with_verifier(Some(VerifierKind::FaultInjection))
        } else {
            Request::new(id, self.trace.prompt_tokens(idx, self.vocab, self.seed), tr.max_new_tokens)
                .with_verifier(tr.verifier)
        };
        if self.deadline_zero.contains(&id) {
            req.with_deadline(Duration::ZERO)
        } else {
            req
        }
    }

    /// Backend factory: the draft is always [`PoisonDraft`]-wrapped (it
    /// passes honest rows through untouched, so tokens stay bit-identical
    /// to an unwrapped run); the straggler worker's pair additionally
    /// pays a [`TimedLm`] latency per forward call (value-preserving).
    fn make_pair(&self) -> impl Fn(usize) -> ModelPair + '_ {
        let (vocab, seed, trigger, straggler, slow_all) =
            (self.vocab, self.seed, self.trigger, self.straggler, self.slow_all);
        move |w| {
            let (d, t) = SimLm::pair(vocab, seed, 2.0);
            let d = PoisonDraft { inner: d, trigger };
            let lat = match (slow_all, straggler) {
                (Some(lat), _) => Some(lat),
                (None, Some((sw, lat))) if sw == w => Some(lat),
                _ => None,
            };
            match lat {
                Some(lat) => ModelPair::new(
                    Box::new(TimedLm::new(d, lat, 64)),
                    Box::new(TimedLm::new(t, lat, 64)),
                ),
                None => ModelPair::new(Box::new(d), Box::new(t)),
            }
        }
    }

    /// Replay the drill to completion. RoundRobin routing keeps the
    /// request→worker assignment identical across scenarios, which is
    /// what makes honest tokens comparable against the no-fault run.
    pub fn run(&self) -> DrillOutcome {
        let baseline_census = thread_census();
        let mut router =
            Router::start(&self.server_cfg, &self.engine_cfg, RoutingPolicy::RoundRobin, self.make_pair());
        if self.inject_transient_faults > 0 {
            router
                .verify_pool()
                .expect("drills run with the server-global pool")
                .inject_transient_faults(self.inject_transient_faults);
        }
        let n = self.trace.requests.len();
        let submit_limit = self.drain_after.unwrap_or(n).min(n);
        let start = Instant::now();
        let mut submitted = 0usize;
        let mut admitted = 0usize;
        let mut shed_ids = Vec::new();
        let mut results = Vec::with_capacity(n);
        let mut peak_census = thread_census();
        loop {
            while submitted < submit_limit {
                let due = self.trace.requests[submitted].at.mul_f64(self.time_scale);
                if start.elapsed() >= due {
                    let req = self.request(submitted);
                    if self.cancel_at_submit.contains(&req.id) {
                        req.cancel.cancel();
                    }
                    // Sheds are typed and recorded — never silent: every
                    // submission ends as either one terminal result or one
                    // entry in `shed_ids`.
                    match router.try_submit(req) {
                        Ok(_) => admitted += 1,
                        Err(_) => shed_ids.push(submitted as u64),
                    }
                    submitted += 1;
                } else {
                    break;
                }
            }
            if submitted >= submit_limit && (self.drain_after.is_some() || results.len() >= admitted)
            {
                break;
            }
            if results.len() < admitted {
                match router.results_rx.recv_timeout(Duration::from_millis(1)) {
                    Ok(res) => results.push(res),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(e) => panic!("worker dropped mid-drill: {e}"),
                }
            } else {
                // Caught up on results but the next arrival isn't due yet.
                std::thread::sleep(Duration::from_micros(200));
            }
            if let (Some(p), Some(now)) = (peak_census, thread_census()) {
                peak_census = Some(p.max(now));
            }
        }
        // Drain drills cut everything still in flight and fold whatever
        // results the loop had not yet received; every admitted request
        // still gets exactly one terminal result.
        let metrics = if self.drain_after.is_some() {
            let (metrics, leftovers) = router.drain(DrainPolicy::CancelInFlight);
            results.extend(leftovers);
            metrics
        } else {
            router.shutdown()
        };
        let wall = start.elapsed();
        results.sort_by_key(|r| r.id);
        DrillOutcome {
            report: ServeReport { results, metrics, wall },
            baseline_census,
            peak_census,
            shed_ids,
        }
    }
}

/// Result of one drill replay: the serving report plus the thread census
/// bracketing the run (None off-Linux → census gates must skip, never
/// treat as zero).
pub struct DrillOutcome {
    pub report: ServeReport,
    pub baseline_census: Option<usize>,
    pub peak_census: Option<usize>,
    /// Ids shed at admission (typed `AdmitError`, never reached a worker).
    pub shed_ids: Vec<u64>,
}

impl DrillOutcome {
    /// Ids of sequences that failed (fault-rolled-back).
    pub fn failed_ids(&self) -> Vec<u64> {
        self.report.results.iter().filter(|r| r.failed).map(|r| r.id).collect()
    }

    /// Ids of sequences that retired cancelled (explicitly or by
    /// deadline), in id order.
    pub fn cancelled_ids(&self) -> Vec<u64> {
        self.report.results.iter().filter(|r| r.cancelled.is_some()).map(|r| r.id).collect()
    }

    /// Peak thread growth over the run's baseline, when measurable.
    pub fn census_delta(&self) -> Option<usize> {
        match (self.baseline_census, self.peak_census) {
            (Some(b), Some(p)) => Some(p.saturating_sub(b)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_stable() {
        let names: Vec<&str> = Scenario::all().iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            [
                "no-fault",
                "bursty",
                "panic-storm",
                "kv-pressure",
                "straggler",
                "engine-death",
                "deadline-storm",
                "cancel-flood",
                "overload-shed",
                "drain-under-storm",
                "composed-fault",
            ]
        );
    }

    #[test]
    fn lifecycle_scenarios_script_deterministically() {
        let storm = Drill::new(Scenario::DeadlineStorm, 5);
        assert_eq!(storm.deadline_zero, (0..48).filter(|i| i % 3 == 0).collect::<Vec<u64>>());
        assert!(storm.request(0).deadline.is_some());
        assert!(storm.request(1).deadline.is_none());
        let flood = Drill::new(Scenario::CancelFlood, 5);
        assert_eq!(flood.cancel_at_submit.len(), 12);
        let shed = Drill::new(Scenario::OverloadShed, 5);
        assert_eq!(shed.server_cfg.admit_queue, 6);
        assert!(shed.slow_all.is_some());
        let drain = Drill::new(Scenario::DrainUnderStorm, 5);
        assert_eq!(drain.drain_after, Some(24));
        assert!(!drain.poisoned.is_empty());
        let composed = Drill::new(Scenario::ComposedFault, 5);
        assert!(!composed.poisoned.is_empty());
        assert_eq!(composed.server_cfg.kv_pages, 32);
        assert!(composed.straggler.is_some());
        // All lifecycle scenarios share the base trace payloads per seed.
        let base = Drill::new(Scenario::NoFault, 5);
        assert_eq!(base.trace, storm.trace);
        assert_eq!(base.trace, flood.trace);
        assert_eq!(base.trace, composed.trace);
    }

    #[test]
    fn scenarios_share_payloads_and_script_their_faults() {
        let base = Drill::new(Scenario::NoFault, 5);
        let storm = Drill::new(Scenario::PanicStorm, 5);
        // Same base trace per seed: payload sub-streams are identical.
        assert_eq!(base.trace, storm.trace);
        assert_eq!(storm.poisoned.len(), 10);
        // Poisoned requests carry the trigger prompt + FaultInjection;
        // honest ones keep the trace's deterministic payload.
        let p = storm.request(0);
        assert_eq!(p.prompt, vec![storm.trigger]);
        assert_eq!(p.verifier, Some(VerifierKind::FaultInjection));
        let h = storm.request(1);
        assert_eq!(h.prompt, base.request(1).prompt);
        assert_eq!(h.verifier, base.trace.requests[1].verifier);
        assert!(h.prompt.iter().all(|&t| (t as usize) < storm.vocab));
        // Bursty only perturbs arrival times, not payloads.
        let bursty = Drill::new(Scenario::Bursty, 5);
        for (a, b) in base.trace.requests.iter().zip(&bursty.trace.requests) {
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.verifier, b.verifier);
        }
    }
}
