//! Optimal multi-draft acceptance **with communication** — the upper-bound
//! reference curve of paper Figure 6.
//!
//! Two evaluators:
//!
//! * [`upper_bound`] — the closed form `Σ_y min(q_y, 1 − (1 − p_y)^K)`:
//!   no coupling can match more than the overlap between q and the law of
//!   "y appears among K i.i.d. draws from p".
//! * [`lp_optimal`] — the exact optimum over all couplings of `Y ~ q` with
//!   `(X^{(1)}, …, X^{(K)}) ~ p^{⊗K}`, solved as an LP over the joint
//!   distribution (variables π(y, x_1..x_K); N^(K+1) of them — use only for
//!   small instances). The paper computes this the same way, citing the
//!   SpecTr LP approach.

use crate::lp;

use super::types::Categorical;

/// `Σ_y min(q_y, 1 − (1 − p_y)^K)` — the communication upper bound.
pub fn upper_bound(p: &Categorical, q: &Categorical, k: usize) -> f64 {
    assert_eq!(p.len(), q.len());
    assert!(k >= 1);
    p.probs()
        .iter()
        .zip(q.probs())
        .map(|(&pi, &qi)| qi.min(1.0 - (1.0 - pi).powi(k as i32)))
        .sum()
}

/// Exact optimal acceptance over all valid couplings, via LP.
///
/// Marginal constraints: `Σ_y π(y, x⃗) = Π_k p(x_k)` for every tuple x⃗, and
/// `Σ_x⃗ π(y, x⃗) = q(y)` for every y. Objective: mass where `y ∈ x⃗`.
/// Cost grows as N^(K+1); intended for N·K small (tests and the K ≤ 3
/// points of Figure 6's cross-check).
pub fn lp_optimal(p: &Categorical, q: &Categorical, k: usize) -> Result<f64, String> {
    assert_eq!(p.len(), q.len());
    assert!(k >= 1);
    let n = p.len();
    let tuples = n.pow(k as u32);
    let vars = n * tuples;
    if vars > 200_000 {
        return Err(format!("LP too large: {vars} variables"));
    }

    // Decode tuple index into component symbols.
    let decode = |mut t: usize| -> Vec<usize> {
        let mut xs = vec![0usize; k];
        for x in xs.iter_mut() {
            *x = t % n;
            t /= n;
        }
        xs
    };
    let var = |y: usize, t: usize| y * tuples + t;

    let mut a: Vec<Vec<f64>> = Vec::with_capacity(tuples + n);
    let mut b: Vec<f64> = Vec::with_capacity(tuples + n);

    // Tuple marginals (X i.i.d. from p).
    for t in 0..tuples {
        let mut row = vec![0.0; vars];
        for y in 0..n {
            row[var(y, t)] = 1.0;
        }
        a.push(row);
        let prob: f64 = decode(t).iter().map(|&x| p.prob(x)).product();
        b.push(prob);
    }
    // Y marginal.
    for y in 0..n {
        let mut row = vec![0.0; vars];
        for t in 0..tuples {
            row[var(y, t)] = 1.0;
        }
        a.push(row);
        b.push(q.prob(y));
    }

    let mut c = vec![0.0; vars];
    for t in 0..tuples {
        let xs = decode(t);
        for y in 0..n {
            if xs.contains(&y) {
                c[var(y, t)] = 1.0;
            }
        }
    }

    let sol = lp::solve(&a, &b, &c).map_err(|e| e.to_string())?;
    Ok(sol.objective.clamp(0.0, 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::lml;
    use crate::testkit;
    use crate::stats::rng::XorShift128;

    #[test]
    fn upper_bound_k1_is_one_minus_tv() {
        let p = Categorical::new(vec![0.6, 0.3, 0.1]);
        let q = Categorical::new(vec![0.2, 0.3, 0.5]);
        let ub = upper_bound(&p, &q, 1);
        assert!((ub - (1.0 - p.tv_distance(&q))).abs() < 1e-12);
    }

    #[test]
    fn upper_bound_monotone_in_k_and_at_most_one() {
        let p = Categorical::new(vec![0.25; 4]);
        let q = Categorical::new(vec![0.7, 0.1, 0.1, 0.1]);
        let mut last = 0.0;
        for k in 1..=30 {
            let ub = upper_bound(&p, &q, k);
            assert!(ub >= last - 1e-12 && ub <= 1.0 + 1e-12);
            last = ub;
        }
        assert!(last > 0.999, "should approach 1: {last}");
    }

    #[test]
    fn lp_matches_tv_coupling_for_k1() {
        let mut gen = XorShift128::new(5);
        for _ in 0..5 {
            let p = testkit::gen_categorical(&mut gen, 4);
            let q = testkit::gen_categorical(&mut gen, 4);
            let opt = lp_optimal(&p, &q, 1).unwrap();
            let expect = 1.0 - p.tv_distance(&q);
            assert!((opt - expect).abs() < 1e-6, "{opt} vs {expect}");
        }
    }

    #[test]
    fn lp_between_lml_bound_and_upper_bound() {
        let mut gen = XorShift128::new(6);
        for _ in 0..4 {
            let p = testkit::gen_categorical(&mut gen, 4);
            let q = testkit::gen_categorical(&mut gen, 4);
            for &k in &[1usize, 2] {
                let lower = lml::theorem1_bound(&p, &q, k);
                let opt = lp_optimal(&p, &q, k).unwrap();
                let ub = upper_bound(&p, &q, k);
                assert!(
                    lower <= opt + 1e-6 && opt <= ub + 1e-6,
                    "K={k}: lml {lower}, lp {opt}, ub {ub}"
                );
            }
        }
    }

    #[test]
    fn lp_upper_bound_is_close_for_k2() {
        // The closed form is an upper bound on the LP optimum; on small
        // random instances the gap stays modest (~0.1), which is why
        // Figure 6 plots the closed form where the LP is intractable —
        // labelled as an upper bound, exactly like the paper's "optimal
        // with communication" reference curve.
        let mut gen = XorShift128::new(7);
        let mut max_gap = 0.0f64;
        for _ in 0..5 {
            let p = testkit::gen_categorical(&mut gen, 3);
            let q = testkit::gen_categorical(&mut gen, 3);
            let opt = lp_optimal(&p, &q, 2).unwrap();
            let ub = upper_bound(&p, &q, 2);
            assert!(ub >= opt - 1e-6, "closed form must upper-bound the LP");
            max_gap = max_gap.max(ub - opt);
        }
        assert!(max_gap < 0.15, "gap {max_gap} too large");
    }

    #[test]
    fn lp_rejects_oversized_instances() {
        let p = Categorical::uniform(10);
        assert!(lp_optimal(&p, &p, 6).is_err());
    }
}
