//! SpecInfer verification (Miao et al., ASPLOS 2024): recursive multi-round
//! rejection sampling over the K candidate tokens at each position.
//!
//! At step j the verifier walks the active drafts **in index order**: draft
//! k's token x is accepted with probability `min(1, r(x) / p_k(x))` against
//! the running residual `r` (initialized to the target q); on rejection the
//! residual is updated to `norm((r - p_k)_+)` and the next draft is tried.
//! If every candidate is rejected, the final token is drawn from the last
//! residual. This preserves the target marginal exactly but:
//!
//! * it **depends on the drafter's probabilities** `p_k` — hence it is not
//!   drafter invariant (paper §4.1), and
//! * it is **order-sensitive**: the first draft enjoys the full residual,
//!   later drafts face a depleted one (the asymmetry Table 2 exposes).

use crate::stats::rng::CounterRng;

use super::kernel::with_workspace;
use super::types::{
    BlockInput, BlockOutput, BlockVerifier, Categorical, Invariance, VerifierKind,
};

#[derive(Clone, Debug, Default)]
pub struct SpecInferVerifier;

impl SpecInferVerifier {
    pub fn new() -> Self {
        Self
    }

    /// One multi-round rejection step. Returns the chosen token and whether
    /// it came from a draft (accept) or the residual (reject-all).
    ///
    /// `candidates[(k, token)]` must be in draft-index order. `q` is the
    /// target distribution at this position (all active drafts share the
    /// accepted prefix, so it is common). Uniforms are consumed from the
    /// shared stream at `(slot, K + round, 0)` so verification randomness
    /// never collides with the drafting randomness at the same slot.
    pub fn step(
        &self,
        q: &Categorical,
        candidates: &[(usize, u32, &Categorical)],
        rng: &CounterRng,
        slot: u64,
        k_total: usize,
    ) -> (u32, Option<usize>) {
        let mut residual = q.clone();
        for (round, &(k, token, p_k)) in candidates.iter().enumerate() {
            let u = rng.uniform(slot, (k_total + round) as u64, 0);
            let px = p_k.prob(token as usize);
            let rx = residual.prob(token as usize);
            let accept_prob = if px <= 0.0 { 1.0 } else { (rx / px).min(1.0) };
            if u < accept_prob {
                return (token, Some(k));
            }
            match residual.residual(p_k) {
                Some(r) => residual = r,
                // Residual exhausted: the remaining mass is a point mass at
                // whatever survives numerically; fall back to q's argmax.
                None => {
                    let arg = argmax(q);
                    return (arg as u32, None);
                }
            }
        }
        let u = rng.uniform(slot, (k_total + candidates.len()) as u64, 0);
        (residual.sample_inverse(u) as u32, None)
    }
}

/// First-occurrence argmax — the reject-all fallback when the residual is
/// numerically exhausted. Shared with the workspace kernel (`spec::kernel`),
/// which must apply the identical scan to stay bit-exact.
pub(crate) fn argmax(c: &Categorical) -> usize {
    let mut best = f64::NEG_INFINITY;
    let mut arg = 0;
    for (i, &p) in c.probs().iter().enumerate() {
        if p > best {
            best = p;
            arg = i;
        }
    }
    arg
}

impl SpecInferVerifier {
    /// Scalar reference for [`BlockVerifier::verify_block`] (the seed
    /// implementation, built on [`Self::step`]'s clone-per-round residual
    /// cascade). The workspace kernel path must match this bit-for-bit
    /// (`tests/kernel_parity.rs`); it is also the perf baseline in
    /// `benches/perf_engine`.
    pub fn verify_block_scalar(
        &self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok());
        let k = input.k();
        let l = input.block_len();
        let mut active: Vec<usize> = (0..k).collect();
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;

        for j in 0..l {
            // All active drafts share the accepted prefix ⇒ common target q.
            let q = &input.target_dists[active[0]][j];
            let candidates: Vec<(usize, u32, &Categorical)> = active
                .iter()
                .map(|&kk| (kk, input.draft_tokens[kk][j], &input.draft_dists[kk][j]))
                .collect();
            let (tok, from_draft) = self.step(q, &candidates, rng, slot0 + j as u64, k);
            tokens.push(tok);
            match from_draft {
                Some(_) => {
                    active.retain(|&kk| input.draft_tokens[kk][j] == tok);
                    debug_assert!(!active.is_empty());
                    accepted += 1;
                }
                None => {
                    return BlockOutput { tokens, accepted, surviving_draft: None };
                }
            }
        }

        // Bonus token from the target distribution after the full prefix.
        let q = &input.target_dists[active[0]][l];
        let u = rng.uniform(slot0 + l as u64, k as u64, 0);
        tokens.push(q.sample_inverse(u) as u32);
        BlockOutput { tokens, accepted, surviving_draft: active.first().copied() }
    }
}

impl BlockVerifier for SpecInferVerifier {
    fn kind(&self) -> VerifierKind {
        VerifierKind::SpecInfer
    }

    fn invariance(&self) -> Invariance {
        Invariance::None
    }

    /// Kernel-backed recursive rejection: the running residual lives in the
    /// thread workspace's sparse scratch (no `Categorical` clone or
    /// reallocation per round) — bit-exact with
    /// [`SpecInferVerifier::verify_block_scalar`].
    fn verify_block(&self, input: &BlockInput, rng: &CounterRng, slot0: u64) -> BlockOutput {
        with_workspace(|ws| ws.verify_block_specinfer(input, rng, slot0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::stats::rng::XorShift128;

    /// Empirical output distribution of a single verification step must be
    /// the target q regardless of the proposals — the core correctness
    /// property of recursive rejection.
    #[test]
    fn step_preserves_target_marginal() {
        let mut gen = XorShift128::new(3);
        let n = 5;
        let q = testkit::gen_categorical(&mut gen, n);
        let p1 = testkit::gen_categorical(&mut gen, n);
        let p2 = testkit::gen_categorical(&mut gen, n);
        let v = SpecInferVerifier::new();
        let trials = 80_000;
        let mut counts = vec![0usize; n];
        let rng = CounterRng::new(17);
        for t in 0..trials {
            // Draft tokens sampled from their own distributions, coupled to
            // nothing (SpecInfer does not require coupled proposals).
            let x1 = p1.sample_race(&rng, t as u64, 0) as u32;
            let x2 = p2.sample_race(&rng, t as u64, 1) as u32;
            let cands = [(0usize, x1, &p1), (1usize, x2, &p2)];
            let (tok, _) = v.step(&q, &cands, &rng, t as u64, 2);
            counts[tok as usize] += 1;
        }
        for i in 0..n {
            let f = counts[i] as f64 / trials as f64;
            assert!(
                (f - q.prob(i)).abs() < 0.012,
                "symbol {i}: empirical {f} vs target {}",
                q.prob(i)
            );
        }
    }

    #[test]
    fn step_accepts_identical_proposal_always() {
        let q = Categorical::new(vec![0.4, 0.6]);
        let v = SpecInferVerifier::new();
        let rng = CounterRng::new(5);
        for t in 0..2000 {
            let x = q.sample_race(&rng, t, 0) as u32;
            let cands = [(0usize, x, &q)];
            let (tok, from) = v.step(&q, &cands, &rng, t, 1);
            assert_eq!(tok, x);
            assert_eq!(from, Some(0));
        }
    }

    #[test]
    fn step_order_sensitivity_favors_first_draft() {
        // A well-aligned draft listed first is accepted more often than the
        // same draft listed second behind a misaligned one.
        let q = Categorical::new(vec![0.45, 0.45, 0.10]);
        let aligned = q.clone();
        let misaligned = Categorical::new(vec![0.05, 0.05, 0.90]);
        let v = SpecInferVerifier::new();
        let rng = CounterRng::new(9);
        let trials = 30_000;
        let mut firsts = 0;
        let mut seconds = 0;
        for t in 0..trials {
            let xa = aligned.sample_race(&rng, t as u64, 0) as u32;
            let xm = misaligned.sample_race(&rng, t as u64, 1) as u32;
            let (_, from) = v.step(&q, &[(0, xa, &aligned), (1, xm, &misaligned)], &rng, t as u64, 2);
            if from == Some(0) {
                firsts += 1;
            }
            let (_, from) = v.step(&q, &[(0, xm, &misaligned), (1, xa, &aligned)], &rng, t as u64, 2);
            if from == Some(1) {
                seconds += 1;
            }
        }
        // The aligned draft should win far more when listed first.
        assert!(firsts > seconds, "firsts {firsts} vs seconds {seconds}");
    }

    #[test]
    fn verify_block_structure_invariants() {
        let mut gen = XorShift128::new(11);
        for case in 0..25 {
            let n = 6;
            let l = 4;
            let k = 3;
            let p: Vec<Categorical> = (0..l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let q: Vec<Categorical> =
                (0..=l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let rng = CounterRng::new(case);
            let mut draft_tokens = vec![Vec::new(); k];
            for kk in 0..k {
                for j in 0..l {
                    draft_tokens[kk].push(p[j].sample_race(&rng, j as u64, kk as u64) as u32);
                }
            }
            let input = BlockInput {
                draft_tokens: draft_tokens.into(),
                draft_dists: vec![p.clone(); k],
                target_dists: vec![q.clone(); k],
            };
            let out = SpecInferVerifier::new().verify_block(&input, &rng, 0);
            assert!(out.tokens.len() == out.accepted + 1);
            assert!(out.accepted <= l);
            if let Some(sd) = out.surviving_draft {
                for j in 0..out.accepted {
                    assert_eq!(input.draft_tokens[sd][j], out.tokens[j]);
                }
            }
        }
    }
}
