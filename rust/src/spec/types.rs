//! Core types for distribution coupling and block verification.

use std::ops::{Index, IndexMut};
use std::sync::Arc;

use crate::stats::rng::CounterRng;

/// A discrete probability distribution on the alphabet `{0, .., N-1}`.
///
/// Stored densely in f64. All verification math runs in f64 on the
/// coordinator — the logits arrive as f32 from the PJRT artifacts and are
/// promoted once, which keeps acceptance decisions deterministic across
/// batching order (important for drafter invariance audits).
#[derive(Clone, Debug)]
pub struct Categorical {
    probs: Vec<f64>,
    /// Ascending indices of the positive-mass symbols, cached when the
    /// constructor gets it for free (top-k truncation). `None` means
    /// "unknown / assume dense" — consumers must fall back to scanning
    /// `probs`. The coupling kernel unions these lists instead of
    /// rescanning N-length prob vectors per race.
    support: Option<Vec<u32>>,
}

/// Equality is over the distribution itself; the support cache is derived
/// metadata and must not affect comparisons.
impl PartialEq for Categorical {
    fn eq(&self, other: &Self) -> bool {
        self.probs == other.probs
    }
}

impl Categorical {
    /// Build from (possibly unnormalized) non-negative masses.
    pub fn new(mut probs: Vec<f64>) -> Self {
        assert!(!probs.is_empty(), "empty categorical");
        let mut total = 0.0;
        for &p in &probs {
            assert!(p >= 0.0 && p.is_finite(), "invalid mass {p}");
            total += p;
        }
        assert!(total > 0.0, "all-zero categorical");
        if (total - 1.0).abs() > 1e-12 {
            probs.iter_mut().for_each(|p| *p /= total);
        }
        Self { probs, support: None }
    }

    /// Build from f32 logits with temperature and optional top-k truncation
    /// — the exact post-processing pipeline of the paper's LLM experiments
    /// (top-k 50, varying temperatures).
    pub fn from_logits(logits: &[f32], temperature: f64, top_k: Option<usize>) -> Self {
        let mut scratch = Vec::new();
        Self::from_logits_with_scratch(logits, temperature, top_k, &mut scratch)
    }

    /// [`Categorical::from_logits`] with a caller-provided top-k selection
    /// buffer. The engine hot path calls this K×(L+1) times per speculative
    /// block; reusing `scratch` (and selecting the threshold on *indices*
    /// rather than a cloned value vector) removes the per-call scratch
    /// allocation the seed paid.
    pub fn from_logits_with_scratch(
        logits: &[f32],
        temperature: f64,
        top_k: Option<usize>,
        scratch: &mut Vec<u32>,
    ) -> Self {
        assert!(!logits.is_empty());
        assert!(temperature > 0.0);
        let inv_t = 1.0 / temperature;
        // NaN logits (garbage rows from a crashed forward pass) are masked to
        // -inf up front: they can never enter the support, the top-k select
        // below stays a total order, and the max fold stays NaN-free.
        let mut w: Vec<f64> = logits
            .iter()
            .map(|&l| {
                let s = l as f64 * inv_t;
                if s.is_nan() {
                    f64::NEG_INFINITY
                } else {
                    s
                }
            })
            .collect();
        if let Some(k) = top_k {
            if k < w.len() {
                scratch.clear();
                scratch.extend(0..w.len() as u32);
                // k-th largest = (k-1)-th in descending order; O(n) via
                // select_nth on the index buffer, values untouched.
                let (_, mid, _) = scratch.select_nth_unstable_by(k - 1, |&a, &b| {
                    w[b as usize].total_cmp(&w[a as usize])
                });
                let thresh = w[*mid as usize];
                for s in w.iter_mut() {
                    if *s < thresh {
                        *s = f64::NEG_INFINITY;
                    }
                }
            }
        }
        let max = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(
            max > f64::NEG_INFINITY,
            "all logits are NaN or -inf: no symbol can carry mass"
        );
        let mut total = 0.0;
        for s in w.iter_mut() {
            *s = (*s - max).exp();
            total += *s;
        }
        let inv = 1.0 / total;
        w.iter_mut().for_each(|x| *x *= inv);
        // A truncated distribution's support is tiny (top_k of N) and known
        // right here for the cost of one more pass — cache it so races
        // iterate O(top_k) indices instead of rescanning all N probs.
        let support = if top_k.is_some_and(|k| k < w.len()) {
            Some(
                w.iter()
                    .enumerate()
                    .filter(|&(_, &v)| v > 0.0)
                    .map(|(i, _)| i as u32)
                    .collect(),
            )
        } else {
            None
        };
        Self { probs: w, support }
    }

    /// Uniform distribution on `n` symbols.
    pub fn uniform(n: usize) -> Self {
        Self { probs: vec![1.0 / n as f64; n], support: None }
    }

    /// Point mass at `i` on an alphabet of `n` symbols.
    pub fn delta(n: usize, i: usize) -> Self {
        let mut probs = vec![0.0; n];
        probs[i] = 1.0;
        Self { probs, support: None }
    }

    /// Cached ascending positive-mass indices, when known (see field docs).
    #[inline]
    pub fn support(&self) -> Option<&[u32]> {
        self.support.as_deref()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.probs.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        false // constructor rejects empty
    }

    #[inline]
    pub fn probs(&self) -> &[f64] {
        &self.probs
    }

    #[inline]
    pub fn prob(&self, i: usize) -> f64 {
        self.probs[i]
    }

    /// Total variation distance to `other`.
    pub fn tv_distance(&self, other: &Categorical) -> f64 {
        assert_eq!(self.len(), other.len());
        0.5 * self
            .probs
            .iter()
            .zip(&other.probs)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
    }

    /// Sample via the exponential race with explicit shared randomness:
    /// `argmin_i S_i / p_i` where `S_i = rng.exponential(slot, draft, i)`.
    /// This *is* the paper's Gumbel-max sampling (eq. 1) — any party holding
    /// the same `CounterRng` coordinates reproduces the identical race.
    pub fn sample_race(&self, rng: &CounterRng, slot: u64, draft: u64) -> usize {
        // The (slot, draft) hash prefix is constant across the race: hoist
        // it once (CounterRng::lane), leaving one mix round per item.
        // Bit-exact with the unhoisted rng.exponential(slot, draft, i).
        let lane = rng.lane(slot, draft);
        let mut best = f64::INFINITY;
        let mut arg = 0;
        let mut race = |i: usize, p: f64| {
            // Zero-mass symbols can never win an argmin, so skipping them
            // (dense scan) and never visiting them (cached support) are the
            // same race; the support cache may be a superset, hence the
            // mass check stays in both paths.
            if p <= 0.0 {
                return;
            }
            let s = lane.exponential(i as u64) / p;
            if s < best {
                best = s;
                arg = i;
            }
        };
        match self.support.as_deref() {
            // Top-k truncated: O(top_k) instead of an O(N) scan.
            Some(sup) => {
                for &i in sup {
                    race(i as usize, self.probs[i as usize]);
                }
            }
            None => {
                for (i, &p) in self.probs.iter().enumerate() {
                    race(i, p);
                }
            }
        }
        arg
    }

    /// Plain inverse-CDF sample from a single uniform (used for residual
    /// and bonus-token draws in the baselines, where no coupling is
    /// required).
    ///
    /// Walking only the cached support is bit-exact with the dense scan:
    /// a zero-mass symbol adds an exact `+0.0` to the running CDF, so it
    /// can never be the first index where `u < acc` turns true; the
    /// out-of-mass fallback stays the dense walk's last index `N - 1`.
    pub fn sample_inverse(&self, u: f64) -> usize {
        let mut acc = 0.0;
        match self.support.as_deref() {
            // Top-k truncated: O(top_k) instead of an O(N) walk.
            Some(sup) => {
                for &i in sup {
                    acc += self.probs[i as usize];
                    if u < acc {
                        return i as usize;
                    }
                }
            }
            None => {
                for (i, &p) in self.probs.iter().enumerate() {
                    acc += p;
                    if u < acc {
                        return i;
                    }
                }
            }
        }
        self.probs.len() - 1
    }

    /// `(self - other)_+` renormalized — the residual distribution of
    /// rejection-sampling verification. Returns `None` if the positive part
    /// has zero mass (i.e. `other` dominates `self`).
    pub fn residual(&self, other: &Categorical) -> Option<Categorical> {
        assert_eq!(self.len(), other.len());
        let w: Vec<f64> = self
            .probs
            .iter()
            .zip(&other.probs)
            .map(|(a, b)| (a - b).max(0.0))
            .collect();
        let total: f64 = w.iter().sum();
        if total <= 1e-15 {
            None
        } else {
            Some(Categorical::new(w))
        }
    }
}

/// Which drafter-invariance guarantee a verification scheme provides
/// (paper Def. 1 "conditional" and Def. 2 "strong"; baselines have none).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Invariance {
    None,
    Conditional,
    Strong,
}

/// Verification scheme selector (CLI / config facing).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VerifierKind {
    /// GLS multi-draft, conditionally drafter-invariant (paper Alg. 2).
    Gls,
    /// GLS multi-draft, strongly drafter-invariant (paper App. B, Prop. 6).
    GlsStrong,
    /// SpecInfer recursive multi-round rejection.
    SpecInfer,
    /// SpecTr k-sequential-selection (i.i.d. drafts only).
    SpecTr,
    /// Classic single-draft rejection sampling (TR baseline).
    SingleDraft,
    /// Daliri et al. single-draft Gumbel-max coupling.
    Daliri,
    /// Test-only fault injector for the serving runtime's panic-recovery
    /// suites: behaves as [`VerifierKind::Gls`] unless *every* draft token
    /// of the block equals [`FAULT_MARKER_TOKEN`], in which case
    /// verification panics. Deliberately excluded from
    /// [`VerifierKind::all`] (and therefore from the config parser and the
    /// conformance/parity registries) — production code can never select
    /// it by accident.
    FaultInjection,
}

/// Draft-token value that arms [`VerifierKind::FaultInjection`] when a
/// block consists of nothing else. Tests rig a point-mass draft model on
/// this token (`testkit::PoisonDraft`); requiring *every* one of the
/// block's `K × L` drafted positions keeps stochastic models from tripping
/// it by chance. Caveat: `0` is an ordinary, legitimate token id, so a
/// degenerate draft model that deterministically emits token 0 (a point
/// mass or near-zero temperature favoring it) WILL arm the fault — only
/// pair `FaultInjection` with models whose token-0 probability is
/// unexceptional, or rig the marker deliberately as `PoisonDraft` does.
pub const FAULT_MARKER_TOKEN: u32 = 0;

impl VerifierKind {
    pub fn all() -> &'static [VerifierKind] {
        &[
            VerifierKind::Gls,
            VerifierKind::GlsStrong,
            VerifierKind::SpecInfer,
            VerifierKind::SpecTr,
            VerifierKind::SingleDraft,
            VerifierKind::Daliri,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            VerifierKind::Gls => "gls",
            VerifierKind::GlsStrong => "gls-strong",
            VerifierKind::SpecInfer => "specinfer",
            VerifierKind::SpecTr => "spectr",
            VerifierKind::SingleDraft => "single-draft",
            VerifierKind::Daliri => "daliri",
            VerifierKind::FaultInjection => "fault-injection",
        }
    }

    pub fn parse(s: &str) -> Option<VerifierKind> {
        VerifierKind::all().iter().copied().find(|k| k.name() == s)
    }

    /// Single-draft schemes use only draft 0 regardless of engine K.
    pub fn is_single_draft(&self) -> bool {
        matches!(self, VerifierKind::SingleDraft | VerifierKind::Daliri)
    }
}

/// Draft tokens of one speculative block as a row-major view into a flat
/// token arena: row `k` (one per draft lane) is the `L` tokens
/// `X_1^{(k)}, …, X_L^{(k)}`, stored contiguously at
/// `flat[offset + k·L ..]`.
///
/// The engine drafts *all* sequences of a continuous batch into one shared
/// `Arc<Vec<u32>>` arena and hands each verification job a zero-copy
/// `(offset, K, L)` view of it — replacing the former per-block
/// `Vec<Vec<Vec<u32>>>` nest (one heap row per `(seq, lane)`) with a single
/// allocation per batch. Views are cheap to clone and `Send`, which is what
/// lets jobs migrate to persistent verify-pool workers without copying
/// tokens.
///
/// `Index` yields the per-lane token row as a slice, so verifier code reads
/// `draft_tokens[k][j]` exactly as it did against the nested representation.
#[derive(Clone, Debug)]
pub struct TokenMatrix {
    flat: Arc<Vec<u32>>,
    offset: usize,
    rows: usize,
    cols: usize,
}

impl TokenMatrix {
    /// Build from nested per-lane rows (tests and one-off callers). All
    /// rows must have equal length — `BlockInput` requires rectangular
    /// drafts and the arena layout makes raggedness unrepresentable.
    pub fn from_rows(rows: Vec<Vec<u32>>) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        let mut flat = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged draft-token rows");
            flat.extend_from_slice(row);
        }
        Self { flat: Arc::new(flat), offset: 0, rows: r, cols: c }
    }

    /// A `(rows × cols)` window of a shared flat arena starting at
    /// `offset` — the engine's per-sequence view of the batch arena.
    pub fn view(flat: Arc<Vec<u32>>, offset: usize, rows: usize, cols: usize) -> Self {
        assert!(
            offset + rows * cols <= flat.len(),
            "token-arena view out of bounds: {} + {}x{} > {}",
            offset,
            rows,
            cols,
            flat.len()
        );
        Self { flat, offset, rows, cols }
    }

    /// Number of draft lanes (K).
    #[inline]
    pub fn num_rows(&self) -> usize {
        self.rows
    }

    /// Tokens per lane (L).
    #[inline]
    pub fn row_len(&self) -> usize {
        self.cols
    }

    /// Lane `r`'s tokens as a slice of the arena. Bounds-checked against
    /// *this view's* rows — an out-of-range lane on a mid-arena view would
    /// otherwise land inside a neighboring sequence's region and read its
    /// tokens as if they were valid.
    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        assert!(r < self.rows, "lane {r} out of range (K = {})", self.rows);
        let start = self.offset + r * self.cols;
        &self.flat[start..start + self.cols]
    }
}

impl From<Vec<Vec<u32>>> for TokenMatrix {
    fn from(rows: Vec<Vec<u32>>) -> Self {
        Self::from_rows(rows)
    }
}

impl Index<usize> for TokenMatrix {
    type Output = [u32];

    #[inline]
    fn index(&self, r: usize) -> &[u32] {
        self.row(r)
    }
}

/// Mutation is copy-on-write (tests edit draft tokens to probe invariance);
/// the hot path never writes through a view.
impl IndexMut<usize> for TokenMatrix {
    fn index_mut(&mut self, r: usize) -> &mut [u32] {
        assert!(r < self.rows, "lane {r} out of range");
        let start = self.offset + r * self.cols;
        let cols = self.cols;
        let flat = Arc::make_mut(&mut self.flat);
        &mut flat[start..start + cols]
    }
}

/// Input to block verification: everything the target-side verifier knows
/// after the parallel target pass of one speculative block.
///
/// Indexing follows Alg. 2: `draft_tokens[k][j]` is `X_{j+1}^{(k)}`,
/// `draft_dists[k][j]` is `p^{(j+1,k)}` (the drafter's distribution that
/// produced that token), and `target_dists[k][j]` for `j = 0..=L` is
/// `q^{(j+1,k)} = M_b(· | X_{1:j}^{(k)}, c)` — the target's distribution at
/// position j+1 given draft k's prefix (so `target_dists[k][L]` is the bonus
/// position).
#[derive(Clone, Debug)]
pub struct BlockInput {
    /// Flat-arena view of the K×L draft tokens (see [`TokenMatrix`]).
    pub draft_tokens: TokenMatrix,
    pub draft_dists: Vec<Vec<Categorical>>,
    pub target_dists: Vec<Vec<Categorical>>,
}

impl BlockInput {
    pub fn k(&self) -> usize {
        self.draft_tokens.num_rows()
    }

    pub fn block_len(&self) -> usize {
        if self.draft_tokens.num_rows() == 0 {
            0
        } else {
            self.draft_tokens.row_len()
        }
    }

    /// Structural sanity: K ≥ 1, all drafts the same length L ≥ 1, dists
    /// shaped [K][L] (draft) and [K][L+1] (target), consistent alphabets.
    /// (Rectangularity of the token rows is a [`TokenMatrix`] construction
    /// invariant and needs no re-check here.)
    pub fn validate(&self) -> Result<(), String> {
        let k = self.k();
        if k == 0 {
            return Err("no drafts".into());
        }
        if self.draft_dists.len() != k || self.target_dists.len() != k {
            return Err("draft/target dist outer dims must equal K".into());
        }
        let l = self.block_len();
        if l == 0 {
            return Err("empty draft".into());
        }
        let n = self.target_dists[0][0].len();
        for kk in 0..k {
            if self.draft_dists[kk].len() != l {
                return Err(format!("draft {kk} dists length != {l}"));
            }
            if self.target_dists[kk].len() != l + 1 {
                return Err(format!("target {kk} dists length != {}", l + 1));
            }
            for d in self.draft_dists[kk].iter().chain(self.target_dists[kk].iter()) {
                if d.len() != n {
                    return Err("inconsistent alphabet size".into());
                }
            }
            for (j, &t) in self.draft_tokens[kk].iter().enumerate() {
                if t as usize >= n {
                    return Err(format!("draft {kk} token {j} out of alphabet"));
                }
            }
        }
        Ok(())
    }
}

/// Result of verifying one speculative block.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockOutput {
    /// Tokens emitted this block (accepted prefix + the final token, which
    /// is either a residual sample or the bonus token). Length = τ ≥ 1.
    pub tokens: Vec<u32>,
    /// Number of draft positions accepted (τ - 1 unless the full block was
    /// accepted, in which case == L and the last emitted token is the bonus).
    pub accepted: usize,
    /// A draft index whose tokens match the accepted prefix, if any — the
    /// engine reuses that draft's KV-cache pages for the accepted prefix.
    pub surviving_draft: Option<usize>,
}

impl BlockOutput {
    /// Block efficiency contribution: accepted tokens + the final token,
    /// i.e. tokens produced per target-model call (paper's BE numerator).
    pub fn tokens_per_call(&self) -> usize {
        self.tokens.len()
    }
}

/// A block verification scheme. Implementations must be pure functions of
/// `(input, rng, slot0)` — statelessness is what makes the coordinator's
/// replay/audit mode and the drafter-invariance tests possible.
pub trait BlockVerifier {
    fn kind(&self) -> VerifierKind;

    fn invariance(&self) -> Invariance;

    /// Verify one block. `rng` is the shared randomness `\mathcal{R}`
    /// (split per request by the engine); `slot0` is the absolute decoding
    /// position of the block's first token, so that step j uses randomness
    /// slot `slot0 + j` — fresh uniforms per position, shared across drafts,
    /// exactly Alg. 2 line 1.
    fn verify_block(&self, input: &BlockInput, rng: &CounterRng, slot0: u64) -> BlockOutput;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nan_logits_are_masked_out_of_the_support() {
        // A NaN logit must behave as -inf: zero mass, excluded from top-k,
        // and no panic inside the top-k index select.
        let logits = [1.0f32, f32::NAN, 3.0, f32::NAN, 2.0];
        let c = Categorical::from_logits(&logits, 1.0, None);
        assert_eq!(c.prob(1), 0.0);
        assert_eq!(c.prob(3), 0.0);
        let total: f64 = (0..logits.len()).map(|i| c.prob(i)).sum();
        assert!((total - 1.0).abs() < 1e-12);

        let t = Categorical::from_logits(&logits, 0.7, Some(2));
        assert_eq!(t.prob(1), 0.0);
        assert_eq!(t.prob(3), 0.0);
        let support = t.support().expect("top-k caches support");
        assert_eq!(support, &[2, 4]);
    }

    #[test]
    fn nan_logits_with_topk_larger_than_real_support_do_not_panic() {
        // top_k = 4 forces the select threshold onto a masked NaN entry.
        let logits = [5.0f32, f32::NAN, f32::NAN, f32::NAN, 1.0];
        let c = Categorical::from_logits(&logits, 1.0, Some(4));
        assert!(c.prob(0) > c.prob(4));
        assert_eq!(c.prob(1) + c.prob(2) + c.prob(3), 0.0);
    }

    #[test]
    #[should_panic(expected = "no symbol can carry mass")]
    fn all_nan_logits_panic_with_a_typed_message() {
        let _ = Categorical::from_logits(&[f32::NAN, f32::NAN], 1.0, None);
    }

    #[test]
    fn categorical_normalizes() {
        let c = Categorical::new(vec![2.0, 6.0]);
        assert!((c.prob(0) - 0.25).abs() < 1e-12);
        assert!((c.prob(1) - 0.75).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn categorical_rejects_all_zero() {
        Categorical::new(vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn categorical_rejects_negative() {
        Categorical::new(vec![0.5, -0.1]);
    }

    #[test]
    fn from_logits_softmax_and_topk() {
        let c = Categorical::from_logits(&[0.0, 0.0, 0.0, 0.0], 1.0, None);
        for i in 0..4 {
            assert!((c.prob(i) - 0.25).abs() < 1e-9);
        }
        let c = Categorical::from_logits(&[10.0, 9.0, 1.0, 0.0], 1.0, Some(2));
        assert_eq!(c.prob(2), 0.0);
        assert_eq!(c.prob(3), 0.0);
        assert!((c.prob(0) + c.prob(1) - 1.0).abs() < 1e-12);
        assert!(c.prob(0) > c.prob(1));
    }

    #[test]
    fn from_logits_topk_caches_exact_support() {
        let logits: Vec<f32> = (0..200).map(|i| ((i * 7) % 31) as f32).collect();
        let c = Categorical::from_logits(&logits, 1.0, Some(23));
        let sup = c.support().expect("top-k must cache support");
        let expect: Vec<u32> =
            (0..200u32).filter(|&i| c.prob(i as usize) > 0.0).collect();
        assert_eq!(sup, &expect[..]);
        // Untruncated logits stay dense (no cache needed).
        assert!(Categorical::from_logits(&logits, 1.0, None).support().is_none());
        assert!(Categorical::from_logits(&logits, 1.0, Some(200)).support().is_none());
        // The cache is derived metadata: equality ignores it.
        let dense_copy = Categorical::new(c.probs().to_vec());
        assert_eq!(c, dense_copy);
    }

    #[test]
    fn from_logits_temperature_extremes() {
        let logits = [3.0, 1.0, 0.0];
        let cold = Categorical::from_logits(&logits, 0.05, None);
        assert!(cold.prob(0) > 0.999);
        let hot = Categorical::from_logits(&logits, 100.0, None);
        assert!((hot.prob(0) - 1.0 / 3.0).abs() < 0.01);
    }

    #[test]
    fn tv_distance_properties() {
        let a = Categorical::new(vec![0.5, 0.5]);
        let b = Categorical::new(vec![0.5, 0.5]);
        assert_eq!(a.tv_distance(&b), 0.0);
        let c = Categorical::delta(2, 0);
        let d = Categorical::delta(2, 1);
        assert!((c.tv_distance(&d) - 1.0).abs() < 1e-12);
        assert!((a.tv_distance(&c) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sample_race_matches_marginal() {
        // Statistical check of the Gumbel-max trick through CounterRng.
        let p = Categorical::new(vec![0.2, 0.5, 0.3]);
        let rng = CounterRng::new(77);
        let n = 60_000;
        let mut counts = [0usize; 3];
        for slot in 0..n {
            counts[p.sample_race(&rng, slot as u64, 0)] += 1;
        }
        for i in 0..3 {
            let f = counts[i] as f64 / n as f64;
            assert!((f - p.prob(i)).abs() < 0.01, "symbol {i}: {f} vs {}", p.prob(i));
        }
    }

    #[test]
    fn sample_race_skips_zero_mass() {
        let p = Categorical::new(vec![0.0, 1.0, 0.0]);
        let rng = CounterRng::new(3);
        for slot in 0..100 {
            assert_eq!(p.sample_race(&rng, slot, 0), 1);
        }
    }

    #[test]
    fn sample_inverse_endpoints() {
        let p = Categorical::new(vec![0.25, 0.25, 0.5]);
        assert_eq!(p.sample_inverse(0.0), 0);
        assert_eq!(p.sample_inverse(0.9999999), 2);
        assert_eq!(p.sample_inverse(0.3), 1);
    }

    #[test]
    fn sample_inverse_support_cache_is_exact() {
        // The sparse walk over a cached top-k support must agree with the
        // dense scan on the identical probability vector at every uniform.
        let logits: Vec<f32> = (0..300).map(|i| ((i * 11) % 37) as f32).collect();
        let c = Categorical::from_logits(&logits, 1.0, Some(40));
        assert!(c.support().is_some());
        let dense = Categorical::new(c.probs().to_vec());
        assert!(dense.support().is_none());
        for t in 0..2000 {
            let u = (t as f64 + 0.5) / 2000.0;
            assert_eq!(c.sample_inverse(u), dense.sample_inverse(u), "u = {u}");
        }
        // Out-of-mass fallback matches the dense walk's last index.
        assert_eq!(c.sample_inverse(1.5), dense.sample_inverse(1.5));
    }

    #[test]
    fn residual_matches_hand_computation() {
        let q = Categorical::new(vec![0.6, 0.4]);
        let p = Categorical::new(vec![0.2, 0.8]);
        let r = q.residual(&p).unwrap();
        // (q-p)_+ = [0.4, 0] -> normalized [1, 0]
        assert!((r.prob(0) - 1.0).abs() < 1e-12);
        assert!(q.residual(&q).is_none());
    }

    #[test]
    fn block_input_validation_catches_shape_errors() {
        let n = 4;
        let q = Categorical::uniform(n);
        let good = BlockInput {
            draft_tokens: vec![vec![0, 1]].into(),
            draft_dists: vec![vec![q.clone(), q.clone()]],
            target_dists: vec![vec![q.clone(), q.clone(), q.clone()]],
        };
        assert!(good.validate().is_ok());
        let bad = BlockInput {
            draft_tokens: vec![vec![0, 1]].into(),
            draft_dists: vec![vec![q.clone()]],
            target_dists: vec![vec![q.clone(), q.clone(), q.clone()]],
        };
        assert!(bad.validate().is_err());
        let bad_tok = BlockInput {
            draft_tokens: vec![vec![0, 9]].into(),
            draft_dists: vec![vec![q.clone(), q.clone()]],
            target_dists: vec![vec![q.clone(), q.clone(), q.clone()]],
        };
        assert!(bad_tok.validate().is_err());
    }

    #[test]
    fn token_matrix_roundtrips_nested_rows() {
        let rows = vec![vec![1u32, 2, 3], vec![4, 5, 6], vec![7, 8, 9]];
        let m = TokenMatrix::from_rows(rows.clone());
        assert_eq!(m.num_rows(), 3);
        assert_eq!(m.row_len(), 3);
        for (k, row) in rows.iter().enumerate() {
            assert_eq!(&m[k], row.as_slice());
            for (j, &t) in row.iter().enumerate() {
                assert_eq!(m[k][j], t);
            }
        }
    }

    #[test]
    fn token_matrix_views_share_one_arena() {
        // The engine layout: [seq][lane][pos] flattened, one view per seq.
        let (seqs, k, l) = (3usize, 2usize, 4usize);
        let arena: Arc<Vec<u32>> = Arc::new((0..(seqs * k * l) as u32).collect());
        for s in 0..seqs {
            let v = TokenMatrix::view(Arc::clone(&arena), s * k * l, k, l);
            for lane in 0..k {
                for j in 0..l {
                    assert_eq!(v[lane][j], ((s * k + lane) * l + j) as u32);
                }
            }
        }
    }

    #[test]
    fn token_matrix_mutation_is_copy_on_write() {
        let arena: Arc<Vec<u32>> = Arc::new(vec![0; 8]);
        let mut a = TokenMatrix::view(Arc::clone(&arena), 0, 2, 2);
        let b = TokenMatrix::view(Arc::clone(&arena), 4, 2, 2);
        a[0][1] = 42;
        assert_eq!(a[0][1], 42);
        // The shared arena (and every other view of it) is untouched.
        assert!(arena.iter().all(|&t| t == 0));
        assert_eq!(b[0][1], 0);
    }

    #[test]
    #[should_panic]
    fn token_matrix_rejects_ragged_rows() {
        TokenMatrix::from_rows(vec![vec![1, 2], vec![3]]);
    }

    #[test]
    fn verifier_kind_roundtrip() {
        for &k in VerifierKind::all() {
            assert_eq!(VerifierKind::parse(k.name()), Some(k));
        }
        assert_eq!(VerifierKind::parse("nope"), None);
    }
}
