//! Classic single-draft speculative decoding verification (Leviathan et
//! al. 2023 / Chen et al. 2023): accept the draft token x with probability
//! `min(1, q(x)/p(x))`, otherwise emit a sample from the normalized
//! residual `(q − p)_+`. This is the reference scheme against which the
//! paper reports all token-rate speedups (TR is defined relative to it).

use crate::stats::rng::CounterRng;

use super::kernel::with_workspace;
use super::types::{
    BlockInput, BlockOutput, BlockVerifier, Categorical, Invariance, VerifierKind,
};

#[derive(Clone, Debug, Default)]
pub struct SingleDraftVerifier;

impl SingleDraftVerifier {
    pub fn new() -> Self {
        Self
    }

    /// One accept/reject decision. Returns (token, accepted?).
    pub fn step(
        &self,
        p: &Categorical,
        q: &Categorical,
        token: u32,
        rng: &CounterRng,
        slot: u64,
    ) -> (u32, bool) {
        let u = rng.uniform(slot, 1, 0);
        let px = p.prob(token as usize);
        let qx = q.prob(token as usize);
        let accept = if px <= 0.0 { true } else { u < (qx / px).min(1.0) };
        if accept {
            (token, true)
        } else {
            let u2 = rng.uniform(slot, 2, 0);
            match q.residual(p) {
                Some(r) => (r.sample_inverse(u2) as u32, false),
                None => (q.sample_inverse(u2) as u32, false),
            }
        }
    }

    /// Scalar reference for [`BlockVerifier::verify_block`] (the seed
    /// implementation, built on [`Self::step`]'s dense residual +
    /// `Categorical::new` allocation per rejection). The workspace kernel
    /// path must match this bit-for-bit (`tests/kernel_parity.rs`).
    pub fn verify_block_scalar(
        &self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok());
        let l = input.block_len();
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;
        for j in 0..l {
            let (tok, ok) = self.step(
                &input.draft_dists[0][j],
                &input.target_dists[0][j],
                input.draft_tokens[0][j],
                rng,
                slot0 + j as u64,
            );
            tokens.push(tok);
            if !ok {
                return BlockOutput { tokens, accepted, surviving_draft: None };
            }
            accepted += 1;
        }
        let q = &input.target_dists[0][l];
        let u = rng.uniform(slot0 + l as u64, 1, 0);
        tokens.push(q.sample_inverse(u) as u32);
        BlockOutput { tokens, accepted, surviving_draft: Some(0) }
    }
}

impl BlockVerifier for SingleDraftVerifier {
    fn kind(&self) -> VerifierKind {
        VerifierKind::SingleDraft
    }

    fn invariance(&self) -> Invariance {
        Invariance::None
    }

    /// Kernel-backed rejection sampling: the residual `(q − p)₊` is built
    /// and renormalized in the thread workspace's sparse scratch (no
    /// `Categorical` allocation per rejection) — bit-exact with
    /// [`SingleDraftVerifier::verify_block_scalar`].
    fn verify_block(&self, input: &BlockInput, rng: &CounterRng, slot0: u64) -> BlockOutput {
        with_workspace(|ws| ws.verify_block_single_draft(input, rng, slot0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::stats::rng::XorShift128;

    #[test]
    fn step_preserves_target_marginal() {
        let mut gen = XorShift128::new(8);
        let n = 5;
        let p = testkit::gen_categorical(&mut gen, n);
        let q = testkit::gen_categorical(&mut gen, n);
        let v = SingleDraftVerifier::new();
        let rng = CounterRng::new(31);
        let trials = 80_000;
        let mut counts = vec![0usize; n];
        for t in 0..trials {
            let x = p.sample_race(&rng, t as u64, 0) as u32;
            let (tok, _) = v.step(&p, &q, x, &rng, t as u64);
            counts[tok as usize] += 1;
        }
        for i in 0..n {
            let f = counts[i] as f64 / trials as f64;
            assert!((f - q.prob(i)).abs() < 0.012, "symbol {i}: {f} vs {}", q.prob(i));
        }
    }

    #[test]
    fn acceptance_rate_equals_one_minus_tv() {
        let p = Categorical::new(vec![0.7, 0.2, 0.1]);
        let q = Categorical::new(vec![0.3, 0.3, 0.4]);
        let v = SingleDraftVerifier::new();
        let rng = CounterRng::new(12);
        let trials = 60_000;
        let mut hits = 0;
        for t in 0..trials {
            let x = p.sample_race(&rng, t as u64, 0) as u32;
            if v.step(&p, &q, x, &rng, t as u64).1 {
                hits += 1;
            }
        }
        let emp = hits as f64 / trials as f64;
        let expect = 1.0 - p.tv_distance(&q);
        assert!((emp - expect).abs() < 0.01, "emp {emp} vs {expect}");
    }

    #[test]
    fn identical_distributions_always_accept() {
        let p = Categorical::new(vec![0.5, 0.5]);
        let v = SingleDraftVerifier::new();
        let rng = CounterRng::new(1);
        for t in 0..1000 {
            let x = p.sample_race(&rng, t, 0) as u32;
            assert!(v.step(&p, &p, x, &rng, t).1);
        }
    }
}
