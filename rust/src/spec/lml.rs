//! List Matching Lemma bound evaluators (paper Theorem 1, Proposition 2,
//! Theorem 2, Proposition 4). Used by tests to certify that the sampler
//! meets its guarantees and by the benches to print bound-vs-empirical rows.

use super::types::Categorical;

/// Theorem 1, eq. (3): lower bound on `Pr[Y ∈ {X^{(1)}, …, X^{(K)}}]`.
///
/// `Σ_j K / Σ_i [max{q_i/q_j, p_i/p_j} + (K-1) q_i/q_j]`.
/// Terms with `q_j = 0` contribute nothing (Y never lands there); if
/// `p_j = 0` while `q_j > 0`, the inner max is +∞ and the term is 0,
/// consistent with the coupling never matching on a symbol the proposal
/// cannot produce.
pub fn theorem1_bound(p: &Categorical, q: &Categorical, k: usize) -> f64 {
    assert_eq!(p.len(), q.len());
    assert!(k >= 1);
    let n = p.len();
    let mut total = 0.0;
    for j in 0..n {
        let qj = q.prob(j);
        if qj <= 0.0 {
            continue;
        }
        let pj = p.prob(j);
        if pj <= 0.0 {
            continue;
        }
        let mut denom = 0.0;
        for i in 0..n {
            let qi_ratio = q.prob(i) / qj;
            let pi_ratio = p.prob(i) / pj;
            denom += qi_ratio.max(pi_ratio) + (k as f64 - 1.0) * qi_ratio;
        }
        total += k as f64 / denom;
    }
    total
}

/// Theorem 1, eq. (4): conditional bound
/// `Pr[match | Y = j] ≥ (1 + q_j / (K p_j))^{-1}`.
pub fn conditional_bound(p_j: f64, q_j: f64, k: usize) -> f64 {
    assert!(k >= 1);
    if p_j <= 0.0 {
        return 0.0;
    }
    if q_j <= 0.0 {
        return 1.0; // conditioning event has probability 0; vacuous
    }
    1.0 / (1.0 + q_j / (k as f64 * p_j))
}

/// The relaxed bound from the end of App. A.2:
/// `Pr[match] ≥ Σ_j q_j (1 + q_j/(K p_j))^{-1}`.
pub fn relaxed_bound(p: &Categorical, q: &Categorical, k: usize) -> f64 {
    assert_eq!(p.len(), q.len());
    (0..p.len())
        .map(|j| {
            let qj = q.prob(j);
            if qj <= 0.0 {
                0.0
            } else {
                qj * conditional_bound(p.prob(j), qj, k)
            }
        })
        .sum()
}

/// App. B bound for the strongly invariant scheme with `J ≤ K` active
/// drafts: `Σ_j J / Σ_i [max{q_i/q_j, p_i/p_j} + (K-1) q_i/q_j]`.
pub fn strong_bound(p: &Categorical, q: &Categorical, j_active: usize, k: usize) -> f64 {
    assert!(j_active >= 1 && j_active <= k);
    theorem1_bound(p, q, k) * j_active as f64 / k as f64
}

/// Daliri et al. single-draft bound: `(1 - d_TV) / (1 + d_TV)`.
pub fn daliri_bound(p: &Categorical, q: &Categorical) -> f64 {
    let d = p.tv_distance(q);
    (1.0 - d) / (1.0 + d)
}

/// Proposition 4 RHS: success-probability lower bound of the compression
/// scheme, `E[(1 + 2^{i(W;A|T)} / (K L_max))^{-1}]`, given samples of the
/// conditional information density `i = log2(p_{W|A}/p_{W|T})`.
pub fn proposition4_success_bound(info_density_samples: &[f64], k: usize, l_max: u64) -> f64 {
    assert!(k >= 1 && l_max >= 1);
    if info_density_samples.is_empty() {
        return 0.0;
    }
    let kl = (k as f64) * (l_max as f64);
    info_density_samples
        .iter()
        .map(|&i| 1.0 / (1.0 + (2f64).powf(i) / kl))
        .sum::<f64>()
        / info_density_samples.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem1_reduces_to_pml_for_k1() {
        // For K = 1 the bound is Σ_j 1/Σ_i max(q_i/q_j, p_i/p_j) — identical
        // to the Poisson matching lemma bound. Check a hand-computable case:
        // p = q => bound = Σ_j q_j = ... each denom = Σ_i q_i/q_j = 1/q_j
        // => bound = Σ_j q_j = 1? No: denom = Σ_i q_i/q_j = (1)/q_j, term =
        // q_j, total = 1. Perfect alignment gives certainty.
        let q = Categorical::new(vec![0.3, 0.7]);
        let b = theorem1_bound(&q, &q, 1);
        assert!((b - 1.0).abs() < 1e-12, "b = {b}");
    }

    #[test]
    fn theorem1_k1_matches_known_two_point_example() {
        // p = (1, 0) support mismatch with q = (0.5, 0.5): only j=0 counts,
        // p_1/p_0 = 0, q_i/q_0 = 1 each => denom = max(1,1) + max(1,0) = 2,
        // term = 1/2 => bound 0.5.
        let p = Categorical::new(vec![1.0 - 1e-15, 1e-15]);
        let q = Categorical::new(vec![0.5, 0.5]);
        let b = theorem1_bound(&p, &q, 1);
        assert!((b - 0.5).abs() < 1e-6, "b = {b}");
    }

    #[test]
    fn theorem1_monotone_in_k() {
        let p = Categorical::new(vec![0.6, 0.3, 0.1]);
        let q = Categorical::new(vec![0.2, 0.3, 0.5]);
        let mut last = 0.0;
        for k in 1..=16 {
            let b = theorem1_bound(&p, &q, k);
            assert!(b >= last - 1e-12, "bound not monotone at K={k}");
            assert!(b <= 1.0 + 1e-12);
            last = b;
        }
        assert!(theorem1_bound(&p, &q, 64) > 0.9);
    }

    #[test]
    fn theorem1_dominates_relaxed_bound() {
        // The relaxed bound follows from (4); (3) must be at least as tight.
        // (Both are lower bounds on the same probability; (3) >= relaxed
        // does not hold in general a priori, but does on these instances —
        // we assert only that both are valid, i.e. ≤ empirical; here we
        // sanity check the relation relaxed ≤ 1 and bounds are in [0,1].)
        let p = Categorical::new(vec![0.5, 0.25, 0.25]);
        let q = Categorical::new(vec![0.1, 0.8, 0.1]);
        for k in [1usize, 2, 5, 10] {
            let t = theorem1_bound(&p, &q, k);
            let r = relaxed_bound(&p, &q, k);
            assert!(t >= 0.0 && t <= 1.0);
            assert!(r >= 0.0 && r <= 1.0);
        }
    }

    #[test]
    fn conditional_bound_limits() {
        assert!((conditional_bound(0.5, 0.5, 1) - 0.5).abs() < 1e-12);
        // Large K drives the bound to 1 whenever p_j > 0 (paper remark).
        assert!(conditional_bound(0.01, 0.99, 10_000) > 0.99);
        assert_eq!(conditional_bound(0.0, 0.5, 4), 0.0);
    }

    #[test]
    fn daliri_bound_matches_formula() {
        let p = Categorical::new(vec![0.5, 0.5]);
        let q = Categorical::new(vec![0.75, 0.25]);
        // d_TV = 0.25 => (0.75)/(1.25) = 0.6
        assert!((daliri_bound(&p, &q) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn theorem1_k1_equals_daliri_or_better() {
        // Daliri et al. prove (1-d)/(1+d) is achieved by Gumbel coupling;
        // the PML-style bound (3) with K = 1 is at least as large on these
        // instances (it is a per-symbol refinement).
        let p = Categorical::new(vec![0.6, 0.3, 0.1]);
        let q = Categorical::new(vec![0.3, 0.3, 0.4]);
        let t = theorem1_bound(&p, &q, 1);
        let d = daliri_bound(&p, &q);
        assert!(t >= d - 1e-9, "theorem1 {t} < daliri {d}");
    }

    #[test]
    fn strong_bound_scales_with_active_fraction() {
        let p = Categorical::new(vec![0.5, 0.5]);
        let q = Categorical::new(vec![0.3, 0.7]);
        let full = strong_bound(&p, &q, 4, 4);
        let half = strong_bound(&p, &q, 2, 4);
        assert!((full - theorem1_bound(&p, &q, 4)).abs() < 1e-12);
        assert!((half - full / 2.0).abs() < 1e-12);
    }

    #[test]
    fn proposition4_bound_behaviour() {
        // Zero information density => bound = 1/(1 + 1/(K L)) rising in K·L.
        let samples = vec![0.0; 100];
        let b1 = proposition4_success_bound(&samples, 1, 2);
        let b4 = proposition4_success_bound(&samples, 4, 2);
        assert!(b4 > b1);
        let b_big_l = proposition4_success_bound(&samples, 1, 1 << 20);
        assert!(b_big_l > 0.999);
        // High information density kills the bound.
        let hard = vec![30.0; 100];
        assert!(proposition4_success_bound(&hard, 2, 2) < 1e-6);
    }
}
