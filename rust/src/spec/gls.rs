//! Gumbel-max List Sampling — the paper's core algorithm.
//!
//! * [`sample_gls`] is Algorithm 1: one-shot coupled sampling of
//!   `Y ~ q` and `X^{(1)}, …, X^{(K)} ~ p` from shared exponentials.
//! * [`GlsVerifier`] is Algorithm 2: the drafter-invariant multi-draft
//!   speculative-decoding block verifier, in both the conditionally
//!   invariant (Def. 1) and strongly invariant (Def. 2 / Prop. 6) variants.
//!
//! The public entry points run on the zero-allocation sparse-support
//! kernel ([`super::kernel::CouplingWorkspace`]); the `*_scalar` functions
//! are the straightforward full-alphabet reference implementations the
//! kernel is required (by `tests/kernel_parity.rs`) to match bit-for-bit.

use crate::stats::rng::CounterRng;

use super::kernel::with_workspace;
use super::types::{
    BlockInput, BlockOutput, BlockVerifier, Categorical, Invariance, VerifierKind,
};

/// Result of one-shot GLS (Algorithm 1).
#[derive(Clone, Debug, PartialEq)]
pub struct GlsOutcome {
    /// Bob's sample `Y ~ q`.
    pub y: usize,
    /// Alice's list `X^{(k)} ~ p`, i.i.d. across k.
    pub xs: Vec<usize>,
    /// `Y ∈ {X^{(1)}, …, X^{(K)}}`.
    pub accept: bool,
}

/// Algorithm 1 (SampleGLS). `slot` selects the randomness block so repeated
/// calls with different slots are independent; both parties calling with the
/// same `(rng, slot)` reproduce the identical coupled outcome — that is the
/// communication-free coupling.
///
/// `Y = argmin_i min_k S_i^{(k)} / q_i`, `X^{(k)} = argmin_i S_i^{(k)} / p_i`
/// with `S_i^{(k)} = -ln U_i^{(k)}` shared Exp(1) variates.
///
/// Runs on the sparse-support workspace kernel; bit-exact with
/// [`sample_gls_scalar`].
pub fn sample_gls(p: &Categorical, q: &Categorical, k: usize, rng: &CounterRng, slot: u64) -> GlsOutcome {
    with_workspace(|ws| ws.sample_gls(p, q, k, rng, slot))
}

/// Scalar full-alphabet reference for [`sample_gls`] (the seed
/// implementation): the kernel parity tests and the perf baseline both
/// race against this.
pub fn sample_gls_scalar(p: &Categorical, q: &Categorical, k: usize, rng: &CounterRng, slot: u64) -> GlsOutcome {
    assert_eq!(p.len(), q.len(), "alphabet mismatch");
    assert!(k >= 1);
    let n = p.len();

    let mut y_best = f64::INFINITY;
    let mut y_arg = 0usize;
    let mut xs = vec![0usize; k];
    let mut x_best = vec![f64::INFINITY; k];

    for i in 0..n {
        let qi = q.prob(i);
        let pi = p.prob(i);
        if qi <= 0.0 && pi <= 0.0 {
            continue;
        }
        for kk in 0..k {
            let s = rng.exponential(slot, kk as u64, i as u64);
            if qi > 0.0 {
                let v = s / qi;
                if v < y_best {
                    y_best = v;
                    y_arg = i;
                }
            }
            if pi > 0.0 {
                let v = s / pi;
                if v < x_best[kk] {
                    x_best[kk] = v;
                    xs[kk] = i;
                }
            }
        }
    }

    let accept = xs.contains(&y_arg);
    GlsOutcome { y: y_arg, xs, accept }
}

/// GLS with per-draft proposal distributions `p^{(k)}` (paper App. A.3,
/// Prop. 5): each `X^{(k)} ~ p^{(k)}`, `Y ~ q`, all coupled through the same
/// exponentials. Used by the diverse-drafts experiments (Table 2/4).
///
/// Runs on the sparse-support workspace kernel; bit-exact with
/// [`sample_gls_diverse_scalar`].
pub fn sample_gls_diverse(
    ps: &[Categorical],
    q: &Categorical,
    rng: &CounterRng,
    slot: u64,
) -> GlsOutcome {
    with_workspace(|ws| ws.sample_gls_diverse(ps, q, rng, slot))
}

/// Scalar full-alphabet reference for [`sample_gls_diverse`].
pub fn sample_gls_diverse_scalar(
    ps: &[Categorical],
    q: &Categorical,
    rng: &CounterRng,
    slot: u64,
) -> GlsOutcome {
    assert!(!ps.is_empty());
    for p in ps {
        assert_eq!(p.len(), q.len(), "alphabet mismatch");
    }
    let n = q.len();
    let k = ps.len();

    let mut y_best = f64::INFINITY;
    let mut y_arg = 0usize;
    let mut xs = vec![0usize; k];
    let mut x_best = vec![f64::INFINITY; k];

    for i in 0..n {
        let qi = q.prob(i);
        for kk in 0..k {
            let pi = ps[kk].prob(i);
            if qi <= 0.0 && pi <= 0.0 {
                continue;
            }
            let s = rng.exponential(slot, kk as u64, i as u64);
            if qi > 0.0 {
                let v = s / qi;
                if v < y_best {
                    y_best = v;
                    y_arg = i;
                }
            }
            if pi > 0.0 {
                let v = s / pi;
                if v < x_best[kk] {
                    x_best[kk] = v;
                    xs[kk] = i;
                }
            }
        }
    }

    let accept = xs.contains(&y_arg);
    GlsOutcome { y: y_arg, xs, accept }
}

/// Result of bilateral (list-vs-list) GLS — the paper's Conclusion
/// future-work relaxation, implemented here as an extension.
#[derive(Clone, Debug, PartialEq)]
pub struct BilateralOutcome {
    /// Alice's list `X^{(k)} ~ p`, i.i.d. across k.
    pub xs: Vec<usize>,
    /// Bob's list `Y^{(m)} ~ q`, i.i.d. across m.
    pub ys: Vec<usize>,
    /// `{X} ∩ {Y} ≠ ∅`.
    pub accept: bool,
}

/// Bilateral GLS: *both* parties generate lists, accept iff the lists
/// intersect (paper §6: "an alternative relaxation of distribution
/// coupling might allow both parties to generate a list and declare an
/// accept if the intersection between the lists is nonempty").
///
/// Construction (a symmetric generalization of Alg. 1): draw a K×M grid of
/// shared exponential sets `S^{(k,m)}_i`; then
///
/// ```text
/// X^{(k)} = argmin_i  min_m S^{(k,m)}_i / p_i      (k = 1..K)
/// Y^{(m)} = argmin_i  min_k S^{(k,m)}_i / q_i      (m = 1..M)
/// ```
///
/// Marginal correctness follows exactly as in Prop. 1: `min_m S^{(k,m)}_i`
/// is Exp(M) i.i.d. over i, so each race yields a valid sample; ditto for
/// Y with Exp(K). At M = 1 this *is* Algorithm 1 (Y's race folds all K
/// sets); at K = M = 1 it is the Daliri et al. pairwise coupling. The
/// tests verify marginals, the reduction, and that the intersection
/// probability is monotone in both list lengths.
///
/// Runs on the sparse-support workspace kernel; bit-exact with
/// [`sample_gls_bilateral_scalar`].
pub fn sample_gls_bilateral(
    p: &Categorical,
    q: &Categorical,
    k_a: usize,
    k_b: usize,
    rng: &CounterRng,
    slot: u64,
) -> BilateralOutcome {
    with_workspace(|ws| ws.sample_gls_bilateral(p, q, k_a, k_b, rng, slot))
}

/// Scalar full-alphabet reference for [`sample_gls_bilateral`].
pub fn sample_gls_bilateral_scalar(
    p: &Categorical,
    q: &Categorical,
    k_a: usize,
    k_b: usize,
    rng: &CounterRng,
    slot: u64,
) -> BilateralOutcome {
    assert_eq!(p.len(), q.len(), "alphabet mismatch");
    assert!(k_a >= 1 && k_b >= 1);
    let n = p.len();

    let mut xs = vec![0usize; k_a];
    let mut x_best = vec![f64::INFINITY; k_a];
    let mut ys = vec![0usize; k_b];
    let mut y_best = vec![f64::INFINITY; k_b];

    for i in 0..n {
        let pi = p.prob(i);
        let qi = q.prob(i);
        if pi <= 0.0 && qi <= 0.0 {
            continue;
        }
        for k in 0..k_a {
            for m in 0..k_b {
                // Grid lane id folds (k, m) into the draft coordinate.
                let s = rng.exponential(slot, (k * k_b + m) as u64, i as u64);
                if pi > 0.0 {
                    let v = s / pi;
                    if v < x_best[k] {
                        x_best[k] = v;
                        xs[k] = i;
                    }
                }
                if qi > 0.0 {
                    let v = s / qi;
                    if v < y_best[m] {
                        y_best[m] = v;
                        ys[m] = i;
                    }
                }
            }
        }
    }

    let accept = ys.iter().any(|y| xs.contains(y));
    BilateralOutcome { xs, ys, accept }
}

/// Select `Y_j` given per-active-draft target distributions (Alg. 2 line 9 /
/// line 13): `argmin_i min_{k ∈ active} -ln U_i^{(j,k)} / q_i^{(j,k)}`.
///
/// `dists[k]` must be draft k's target distribution; only indices in
/// `active` participate. All distributions of active drafts are equal in
/// Alg. 2 (active drafts share the accepted prefix) but we do not rely on
/// that: the selection is written exactly as the paper states it, which is
/// what makes the strong variant (distinct prefixes!) share this code.
///
/// Runs on the sparse-support workspace kernel; bit-exact with
/// [`select_target_token_scalar`].
pub fn select_target_token(
    dists: &[&Categorical],
    active: &[usize],
    rng: &CounterRng,
    slot: u64,
) -> usize {
    with_workspace(|ws| ws.select_target_token(dists, active, rng, slot))
}

/// Scalar full-alphabet reference for [`select_target_token`].
pub fn select_target_token_scalar(
    dists: &[&Categorical],
    active: &[usize],
    rng: &CounterRng,
    slot: u64,
) -> usize {
    assert!(!active.is_empty());
    let n = dists[active[0]].len();
    let mut best = f64::INFINITY;
    let mut arg = 0usize;
    for i in 0..n {
        for &k in active {
            let qi = dists[k].prob(i);
            if qi <= 0.0 {
                continue;
            }
            let v = rng.exponential(slot, k as u64, i as u64) / qi;
            if v < best {
                best = v;
                arg = i;
            }
        }
    }
    arg
}

/// Algorithm 2: drafter-invariant multi-draft block verification.
///
/// Conditional variant (paper §4.2): the min in lines 9/13 ranges over the
/// *active* draft set `S`, which shrinks as drafts diverge from the output.
///
/// Strong variant (App. B, Prop. 6): the min always ranges over all K
/// drafts, which removes every dependence on the draft tokens from the
/// output (Def. 2) at a small acceptance cost (the App. B bound with J ≤ K).
#[derive(Clone, Debug)]
pub struct GlsVerifier {
    strong: bool,
}

impl GlsVerifier {
    pub fn conditional() -> Self {
        Self { strong: false }
    }

    pub fn strong() -> Self {
        Self { strong: true }
    }

    /// Scalar full-alphabet reference for
    /// [`BlockVerifier::verify_block`] (the seed implementation, built on
    /// [`select_target_token_scalar`]). The kernel path must match this
    /// bit-for-bit; it is also the perf baseline in `benches/perf_engine`.
    pub fn verify_block_scalar(
        &self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok(), "{:?}", input.validate());
        let k = input.k();
        let l = input.block_len();
        let all: Vec<usize> = (0..k).collect();
        let mut active: Vec<usize> = all.clone();
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;

        for j in 0..l {
            let dists: Vec<&Categorical> = (0..k).map(|kk| &input.target_dists[kk][j]).collect();
            let participants: &[usize] = if self.strong { &all } else { &active };
            let yj = select_target_token_scalar(&dists, participants, rng, slot0 + j as u64) as u32;
            tokens.push(yj);
            active.retain(|&kk| input.draft_tokens[kk][j] == yj);
            if active.is_empty() {
                // All drafts diverged: Y_j was still emitted (it is a valid
                // target sample), and the block ends here — Alg. 2 line 12.
                return BlockOutput { tokens, accepted, surviving_draft: None };
            }
            accepted += 1;
        }

        // Full block accepted: emit the bonus token Y_{L+1} (Alg. 2 line 13).
        let dists: Vec<&Categorical> = (0..k).map(|kk| &input.target_dists[kk][l]).collect();
        let participants: &[usize] = if self.strong { &all } else { &active };
        let bonus = select_target_token_scalar(&dists, participants, rng, slot0 + l as u64) as u32;
        tokens.push(bonus);
        BlockOutput { tokens, accepted, surviving_draft: active.first().copied() }
    }
}

impl BlockVerifier for GlsVerifier {
    fn kind(&self) -> VerifierKind {
        if self.strong {
            VerifierKind::GlsStrong
        } else {
            VerifierKind::Gls
        }
    }

    fn invariance(&self) -> Invariance {
        if self.strong {
            Invariance::Strong
        } else {
            Invariance::Conditional
        }
    }

    /// Kernel-backed verification: one sparse-support panel race per block
    /// position, zero scratch allocations in steady state. Bit-exact with
    /// [`GlsVerifier::verify_block_scalar`].
    fn verify_block(&self, input: &BlockInput, rng: &CounterRng, slot0: u64) -> BlockOutput {
        with_workspace(|ws| ws.verify_block_gls(input, rng, slot0, self.strong))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::lml;
    use crate::testkit;
    use crate::stats::rng::XorShift128;

    fn freq_of(counts: &[usize], n: usize) -> Vec<f64> {
        let total: usize = counts.iter().sum();
        counts.iter().map(|&c| c as f64 / total as f64).take(n).collect()
    }

    #[test]
    fn gls_marginals_proposition_1() {
        // Pr[Y=j] = q_j and Pr[X^{(k)}=j] = p_j for every k (Prop. 1).
        let p = Categorical::new(vec![0.1, 0.6, 0.3]);
        let q = Categorical::new(vec![0.4, 0.2, 0.4]);
        let rng = CounterRng::new(42);
        let trials = 60_000;
        let k = 3;
        let mut yc = vec![0usize; 3];
        let mut xc = vec![vec![0usize; 3]; k];
        for t in 0..trials {
            let out = sample_gls(&p, &q, k, &rng, t as u64);
            yc[out.y] += 1;
            for (kk, &x) in out.xs.iter().enumerate() {
                xc[kk][x] += 1;
            }
        }
        let yf = freq_of(&yc, 3);
        for i in 0..3 {
            assert!((yf[i] - q.prob(i)).abs() < 0.012, "Y marginal off at {i}: {yf:?}");
        }
        for kk in 0..k {
            let xf = freq_of(&xc[kk], 3);
            for i in 0..3 {
                assert!((xf[i] - p.prob(i)).abs() < 0.012, "X{kk} marginal off at {i}: {xf:?}");
            }
        }
    }

    #[test]
    fn gls_acceptance_beats_lml_bound() {
        // Empirical acceptance ≥ Theorem 1 lower bound, for several (p,q,K).
        let mut gen = XorShift128::new(7);
        for _case in 0..10 {
            let p = testkit::gen_categorical(&mut gen, 8);
            let q = testkit::gen_categorical(&mut gen, 8);
            for &k in &[1usize, 2, 4, 8] {
                let rng = CounterRng::new(1000 + k as u64);
                let trials = 20_000;
                let hits = (0..trials)
                    .filter(|&t| sample_gls(&p, &q, k, &rng, t as u64).accept)
                    .count();
                let emp = hits as f64 / trials as f64;
                let bound = lml::theorem1_bound(&p, &q, k);
                assert!(
                    emp + 0.015 >= bound,
                    "empirical {emp} < bound {bound} for K={k}"
                );
            }
        }
    }

    #[test]
    fn gls_acceptance_increases_with_k() {
        let p = Categorical::new(vec![0.25, 0.25, 0.25, 0.25]);
        let q = Categorical::new(vec![0.7, 0.1, 0.1, 0.1]);
        let rng = CounterRng::new(5);
        let trials = 30_000;
        let rate = |k: usize| {
            (0..trials)
                .filter(|&t| sample_gls(&p, &q, k, &rng, t as u64).accept)
                .count() as f64
                / trials as f64
        };
        let r1 = rate(1);
        let r4 = rate(4);
        let r16 = rate(16);
        assert!(r1 < r4 && r4 < r16, "{r1} {r4} {r16}");
        assert!(r16 > 0.9, "K=16 should approach 1: {r16}");
    }

    #[test]
    fn gls_identical_distributions_k1_accepts_almost_surely() {
        let p = Categorical::new(vec![0.3, 0.7]);
        let rng = CounterRng::new(9);
        for t in 0..2000 {
            let out = sample_gls(&p, &p, 1, &rng, t);
            assert!(out.accept, "p = q must always match with shared randomness");
            assert_eq!(out.y, out.xs[0]);
        }
    }

    #[test]
    fn gls_deterministic_given_randomness() {
        let p = Categorical::new(vec![0.5, 0.2, 0.3]);
        let q = Categorical::new(vec![0.2, 0.2, 0.6]);
        let rng = CounterRng::new(31);
        let a = sample_gls(&p, &q, 4, &rng, 12);
        let b = sample_gls(&p, &q, 4, &rng, 12);
        assert_eq!(a, b);
    }

    #[test]
    fn gls_diverse_marginals_proposition_5() {
        let ps = vec![
            Categorical::new(vec![0.7, 0.2, 0.1]),
            Categorical::new(vec![0.1, 0.1, 0.8]),
        ];
        let q = Categorical::new(vec![0.3, 0.4, 0.3]);
        let rng = CounterRng::new(88);
        let trials = 60_000;
        let mut yc = vec![0usize; 3];
        let mut xc = vec![vec![0usize; 3]; 2];
        for t in 0..trials {
            let out = sample_gls_diverse(&ps, &q, &rng, t as u64);
            yc[out.y] += 1;
            for (kk, &x) in out.xs.iter().enumerate() {
                xc[kk][x] += 1;
            }
        }
        let yf = freq_of(&yc, 3);
        for i in 0..3 {
            assert!((yf[i] - q.prob(i)).abs() < 0.012);
        }
        for kk in 0..2 {
            let xf = freq_of(&xc[kk], 3);
            for i in 0..3 {
                assert!((xf[i] - ps[kk].prob(i)).abs() < 0.012, "draft {kk}: {xf:?}");
            }
        }
    }

    #[test]
    fn gls_zero_mass_symbols_never_selected() {
        let p = Categorical::new(vec![0.0, 0.5, 0.5, 0.0]);
        let q = Categorical::new(vec![0.5, 0.5, 0.0, 0.0]);
        let rng = CounterRng::new(13);
        for t in 0..3000 {
            let out = sample_gls(&p, &q, 2, &rng, t);
            assert!(out.y != 2 && out.y != 3);
            assert!(out.xs.iter().all(|&x| x == 1 || x == 2));
        }
    }

    fn toy_block(k: usize, l: usize, n: usize, seed: u64) -> BlockInput {
        // Drafts sampled from the actual proposal race so prefixes are
        // realistic; target dists per draft prefix are generated pseudo-
        // randomly but deterministically from (prefix, j).
        let mut gen = XorShift128::new(seed);
        let p: Vec<Categorical> = (0..l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
        let rng = CounterRng::new(seed ^ 0xDEAD);
        let mut draft_tokens = vec![Vec::with_capacity(l); k];
        for kk in 0..k {
            for j in 0..l {
                draft_tokens[kk].push(p[j].sample_race(&rng, j as u64, kk as u64) as u32);
            }
        }
        let mut gen_q = XorShift128::new(seed ^ 0xBEEF);
        let shared_q: Vec<Categorical> =
            (0..=l).map(|_| testkit::gen_categorical(&mut gen_q, n)).collect();
        BlockInput {
            draft_dists: vec![p.clone(); k],
            // Conditional-variant tests use equal target dists across drafts
            // (active drafts share prefixes in the engine).
            target_dists: vec![shared_q; k],
            draft_tokens: draft_tokens.into(),
        }
    }

    #[test]
    fn verify_block_emits_at_least_one_token_and_accept_count_consistent() {
        for seed in 0..30 {
            let input = toy_block(4, 5, 6, seed);
            let rng = CounterRng::new(seed * 31 + 7);
            for v in [GlsVerifier::conditional(), GlsVerifier::strong()] {
                let out = v.verify_block(&input, &rng, 0);
                assert!(!out.tokens.is_empty());
                assert!(out.accepted <= input.block_len());
                if out.accepted == input.block_len() {
                    assert_eq!(out.tokens.len(), input.block_len() + 1);
                    assert!(out.surviving_draft.is_some());
                } else {
                    assert_eq!(out.tokens.len(), out.accepted + 1);
                }
                // Accepted prefix must match the surviving draft.
                if let Some(sd) = out.surviving_draft {
                    for j in 0..out.accepted {
                        assert_eq!(input.draft_tokens[sd][j], out.tokens[j]);
                    }
                }
            }
        }
    }

    #[test]
    fn conditional_invariance_output_fixed_given_draft_tokens() {
        // Def. 1: holding randomness and draft TOKEN sequences fixed, the
        // output cannot depend on the drafter's distributions.
        for seed in 0..20 {
            let mut input = toy_block(3, 4, 5, seed);
            let rng = CounterRng::new(seed + 999);
            let v = GlsVerifier::conditional();
            let base = v.verify_block(&input, &rng, 0);
            // Replace the draft distributions wholesale (different "models").
            let mut gen = XorShift128::new(seed ^ 0xF00D);
            for kk in 0..input.k() {
                for j in 0..input.block_len() {
                    input.draft_dists[kk][j] = testkit::gen_categorical(&mut gen, 5);
                }
            }
            let swapped = v.verify_block(&input, &rng, 0);
            assert_eq!(base, swapped, "conditional invariance violated at seed {seed}");
        }
    }

    #[test]
    fn strong_invariance_output_fixed_even_when_tokens_change() {
        // Def. 2: the emitted token at each step must not depend on draft
        // tokens at all — only the STOPPING point may change. We check the
        // emitted prefix agrees up to the shorter length under token edits.
        for seed in 0..20 {
            let input = toy_block(3, 4, 5, seed);
            let rng = CounterRng::new(seed + 555);
            let v = GlsVerifier::strong();
            let base = v.verify_block(&input, &rng, 0);
            let mut edited = input.clone();
            // Corrupt one draft's tokens entirely.
            for j in 0..edited.block_len() {
                edited.draft_tokens[2][j] = (edited.draft_tokens[2][j] + 1) % 5;
            }
            let out = v.verify_block(&edited, &rng, 0);
            let m = base.tokens.len().min(out.tokens.len());
            assert_eq!(&base.tokens[..m], &out.tokens[..m], "strong invariance violated");
        }
    }

    #[test]
    fn conditional_beats_strong_on_average_acceptance() {
        // App. B: strong invariance costs acceptance (J ≤ K in the bound).
        // The effect is an expectation statement; run enough blocks and
        // allow sampling slack in the comparison.
        let mut cond_total = 0usize;
        let mut strong_total = 0usize;
        for seed in 0..1500 {
            let input = toy_block(4, 4, 6, seed);
            let rng = CounterRng::new(seed * 17 + 3);
            cond_total += GlsVerifier::conditional().verify_block(&input, &rng, 0).accepted;
            strong_total += GlsVerifier::strong().verify_block(&input, &rng, 0).accepted;
        }
        assert!(
            cond_total as f64 >= strong_total as f64 * 0.97,
            "conditional {cond_total} < strong {strong_total}"
        );
    }

    #[test]
    fn bilateral_marginals_preserved() {
        // Both lists' marginals follow their distributions (the Prop. 1
        // argument applied to Exp(M)/Exp(K) folded races).
        let p = Categorical::new(vec![0.2, 0.5, 0.3]);
        let q = Categorical::new(vec![0.6, 0.1, 0.3]);
        let rng = CounterRng::new(7);
        let trials = 40_000;
        let (ka, kb) = (3usize, 2usize);
        let mut xc = vec![vec![0usize; 3]; ka];
        let mut yc = vec![vec![0usize; 3]; kb];
        for t in 0..trials {
            let out = sample_gls_bilateral(&p, &q, ka, kb, &rng, t as u64);
            for (k, &x) in out.xs.iter().enumerate() {
                xc[k][x] += 1;
            }
            for (m, &y) in out.ys.iter().enumerate() {
                yc[m][y] += 1;
            }
        }
        for k in 0..ka {
            for i in 0..3 {
                let f = xc[k][i] as f64 / trials as f64;
                assert!((f - p.prob(i)).abs() < 0.015, "X{k}[{i}]: {f}");
            }
        }
        for m in 0..kb {
            for i in 0..3 {
                let f = yc[m][i] as f64 / trials as f64;
                assert!((f - q.prob(i)).abs() < 0.015, "Y{m}[{i}]: {f}");
            }
        }
    }

    #[test]
    fn bilateral_reduces_to_gls_at_m_equals_one() {
        let p = Categorical::new(vec![0.3, 0.3, 0.4]);
        let q = Categorical::new(vec![0.5, 0.2, 0.3]);
        let rng = CounterRng::new(13);
        for slot in 0..500 {
            let bi = sample_gls_bilateral(&p, &q, 4, 1, &rng, slot);
            let uni = sample_gls(&p, &q, 4, &rng, slot);
            // Same randomness coordinates (lane = k·1 + 0 = k): identical.
            assert_eq!(bi.xs, uni.xs);
            assert_eq!(bi.ys[0], uni.y);
            assert_eq!(bi.accept, uni.accept);
        }
    }

    #[test]
    fn bilateral_intersection_monotone_in_both_lists() {
        let mut gen = XorShift128::new(3);
        let p = testkit::gen_categorical(&mut gen, 8);
        let q = testkit::gen_categorical(&mut gen, 8);
        let rng = CounterRng::new(29);
        let trials = 15_000;
        let rate = |ka: usize, kb: usize| {
            (0..trials)
                .filter(|&t| sample_gls_bilateral(&p, &q, ka, kb, &rng, t as u64).accept)
                .count() as f64
                / trials as f64
        };
        let r11 = rate(1, 1);
        let r41 = rate(4, 1);
        let r14 = rate(1, 4);
        let r44 = rate(4, 4);
        assert!(r41 > r11 && r14 > r11, "{r11} {r41} {r14}");
        assert!(r44 > r41 && r44 > r14, "{r41} {r14} {r44}");
        // And bilateral lists beat the same total budget spent one-sided
        // in at least one direction sanity: 4×4 ≥ 4×1.
        assert!(r44 > 0.5 * (r41 + r14) - 0.05);
    }

    #[test]
    fn select_target_token_single_active_matches_race() {
        let q = Categorical::new(vec![0.2, 0.3, 0.5]);
        let rng = CounterRng::new(4);
        for slot in 0..200 {
            let via_select = select_target_token(&[&q], &[0], &rng, slot);
            let via_race = q.sample_race(&rng, slot, 0);
            assert_eq!(via_select, via_race);
        }
    }
}
