//! Zero-allocation batched GLS coupling kernel.
//!
//! The scalar reference implementations in [`super::gls`] evaluate
//! `O(N · K)` counter-RNG hashes and `ln()` calls per race, re-deriving the
//! `(slot, draft)` hash prefix for every vocabulary item and walking the
//! full alphabet even when the distributions are top-k truncated (the
//! paper's LLM experiments run top-k 50 over 2048+ vocabularies, so ≥97% of
//! the race is provably dead weight). This module is the serving hot path's
//! answer:
//!
//! * [`CouplingWorkspace`] owns reusable flat scratch buffers — races make
//!   **no heap allocations** beyond their mandated outputs once the
//!   workspace has warmed up.
//! * Exponentials are materialized once per race into a single row-major
//!   **panel** (`panel[row * support_len + j]`), with the per-`(slot,
//!   draft)` SplitMix64 prefix hoisted via [`CounterRng::lane`] so each
//!   item costs one mix round instead of three.
//! * Races iterate a **sparse support**: the ascending union
//!   `supp(p) ∪ supp(q)` (resp. the union over participating drafts).
//!   This is *exact*, not approximate — a zero-mass symbol is skipped by
//!   the scalar `argmin` too, so it can never win — and turns `O(N · K)`
//!   into `O(top_k · K)` for truncated distributions.
//!
//! Determinism is load-bearing (drafter invariance, replay audits), so the
//! kernel is **bit-exact** with the scalar path: panel entries reproduce
//! `CounterRng::exponential` exactly and every race visits its candidates
//! in the scalar order (items ascending, lanes in scalar iteration order).
//! `rust/tests/kernel_parity.rs` enforces this property.

use std::cell::RefCell;

use crate::stats::rng::CounterRng;

use super::gls::{BilateralOutcome, GlsOutcome};
use super::types::{BlockInput, BlockOutput, Categorical};

/// Reusable scratch for one coupling race.
struct RaceScratch {
    /// Ascending union-of-support item indices of the current race.
    support: Vec<u32>,
    /// Occupancy bitset used to build `support` (one bit per item).
    mask: Vec<u64>,
    /// Row-major exponential panel: `panel[row * support.len() + j]` is the
    /// Exp(1) variate of panel row `row` at item `support[j]`.
    panel: Vec<f64>,
    /// Per-lane running minima and argmins.
    best: Vec<f64>,
    arg: Vec<usize>,
}

impl RaceScratch {
    fn new() -> Self {
        Self {
            support: Vec::new(),
            mask: Vec::new(),
            panel: Vec::new(),
            best: Vec::new(),
            arg: Vec::new(),
        }
    }

    /// Rebuild `support` as the ascending union of the supports of
    /// `dists`, over an alphabet of `n` items.
    ///
    /// Distributions carrying a cached support list
    /// ([`Categorical::support`], e.g. top-k truncated ones) contribute it
    /// directly — O(top_k) bit sets instead of an O(n) prob rescan — which
    /// is what keeps the whole race O(top_k · K) in the paper's LLM regime.
    /// A cached list is allowed to be a superset of the true support (the
    /// races re-check every candidate's mass), so exactness is unaffected.
    fn build_support<'a, I>(&mut self, n: usize, dists: I)
    where
        I: Iterator<Item = &'a Categorical> + Clone,
    {
        let words = n.div_ceil(64);
        self.mask.clear();
        self.mask.resize(words, 0);
        let mut all_cached = true;
        for d in dists.clone() {
            debug_assert_eq!(d.len(), n);
            match d.support() {
                Some(sup) => {
                    for &i in sup {
                        self.mask[(i as usize) >> 6] |= 1u64 << (i & 63);
                    }
                }
                None => {
                    all_cached = false;
                    break;
                }
            }
        }
        if !all_cached {
            // At least one dense/unknown-support distribution: rescan all
            // of them (the mask may hold partial state from the first loop).
            self.mask.iter_mut().for_each(|w| *w = 0);
            for d in dists {
                debug_assert_eq!(d.len(), n);
                for (i, &p) in d.probs().iter().enumerate() {
                    if p > 0.0 {
                        self.mask[i >> 6] |= 1u64 << (i & 63);
                    }
                }
            }
        }
        self.support.clear();
        for (w, &bits) in self.mask.iter().enumerate() {
            let mut b = bits;
            while b != 0 {
                let t = b.trailing_zeros() as usize;
                self.support.push((w * 64 + t) as u32);
                b &= b - 1;
            }
        }
    }

    /// Fill `rows` panel rows of exponentials over the current support;
    /// panel row `r` uses the draft coordinate `lane_of(r)`. Entries are
    /// bit-exact with `rng.exponential(slot, lane_of(r), item)`.
    fn fill_panel(
        &mut self,
        rng: &CounterRng,
        slot: u64,
        rows: usize,
        mut lane_of: impl FnMut(usize) -> u64,
    ) {
        self.panel.clear();
        self.panel.reserve(rows * self.support.len());
        for r in 0..rows {
            let lane = rng.lane(slot, lane_of(r));
            for &i in &self.support {
                self.panel.push(lane.exponential(i as u64));
            }
        }
    }

    /// Alg. 2 line 9/13 selection over the union support:
    /// `argmin_i min_{k ∈ participants} S_i^{(slot,k)} / q_i^{(k)}` where
    /// `dist_of(k)` yields draft k's target distribution. Candidate visit
    /// order matches [`super::gls::select_target_token_scalar`] exactly.
    fn select_with<'a, F>(
        &mut self,
        n: usize,
        participants: &[usize],
        dist_of: F,
        rng: &CounterRng,
        slot: u64,
    ) -> usize
    where
        F: Fn(usize) -> &'a Categorical,
    {
        assert!(!participants.is_empty());
        self.build_support(n, participants.iter().map(|&k| dist_of(k)));
        self.fill_panel(rng, slot, participants.len(), |r| participants[r] as u64);
        let s = self.support.len();
        let mut best = f64::INFINITY;
        let mut arg = 0usize;
        for (j, &iu) in self.support.iter().enumerate() {
            let i = iu as usize;
            for (r, &k) in participants.iter().enumerate() {
                let qi = dist_of(k).prob(i);
                if qi <= 0.0 {
                    continue;
                }
                let v = self.panel[r * s + j] / qi;
                if v < best {
                    best = v;
                    arg = i;
                }
            }
        }
        arg
    }
}

/// Reusable flat scratch buffers for the whole coupling data path.
///
/// One workspace per thread (see [`with_workspace`]); every race reuses the
/// grown buffers, so steady-state verification makes no allocations beyond
/// the `GlsOutcome` / `BlockOutput` it must return.
pub struct CouplingWorkspace {
    race: RaceScratch,
    /// Alg. 2's active draft set S (conditional variant).
    active: Vec<usize>,
    /// The full draft set 0..K (strong variant participants).
    all: Vec<usize>,
    /// Reusable index scratch for `Categorical::from_logits_with_scratch`.
    pub topk_scratch: Vec<u32>,
}

impl Default for CouplingWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl CouplingWorkspace {
    pub fn new() -> Self {
        Self {
            race: RaceScratch::new(),
            active: Vec::new(),
            all: Vec::new(),
            topk_scratch: Vec::new(),
        }
    }

    /// Algorithm 1 (SampleGLS) over the sparse union support — bit-exact
    /// with [`super::gls::sample_gls_scalar`].
    pub fn sample_gls(
        &mut self,
        p: &Categorical,
        q: &Categorical,
        k: usize,
        rng: &CounterRng,
        slot: u64,
    ) -> GlsOutcome {
        assert_eq!(p.len(), q.len(), "alphabet mismatch");
        assert!(k >= 1);
        let race = &mut self.race;
        race.build_support(p.len(), [p, q].into_iter());
        race.fill_panel(rng, slot, k, |r| r as u64);
        let s = race.support.len();

        let mut y_best = f64::INFINITY;
        let mut y_arg = 0usize;
        race.best.clear();
        race.best.resize(k, f64::INFINITY);
        race.arg.clear();
        race.arg.resize(k, 0);

        for (j, &iu) in race.support.iter().enumerate() {
            let i = iu as usize;
            let qi = q.prob(i);
            let pi = p.prob(i);
            for kk in 0..k {
                let e = race.panel[kk * s + j];
                if qi > 0.0 {
                    let v = e / qi;
                    if v < y_best {
                        y_best = v;
                        y_arg = i;
                    }
                }
                if pi > 0.0 {
                    let v = e / pi;
                    if v < race.best[kk] {
                        race.best[kk] = v;
                        race.arg[kk] = i;
                    }
                }
            }
        }

        let xs = race.arg[..k].to_vec();
        let accept = xs.contains(&y_arg);
        GlsOutcome { y: y_arg, xs, accept }
    }

    /// GLS with per-draft proposals (paper App. A.3, Prop. 5) — bit-exact
    /// with [`super::gls::sample_gls_diverse_scalar`].
    pub fn sample_gls_diverse(
        &mut self,
        ps: &[Categorical],
        q: &Categorical,
        rng: &CounterRng,
        slot: u64,
    ) -> GlsOutcome {
        assert!(!ps.is_empty());
        for p in ps {
            assert_eq!(p.len(), q.len(), "alphabet mismatch");
        }
        let n = q.len();
        let k = ps.len();
        let race = &mut self.race;
        race.build_support(n, ps.iter().chain(std::iter::once(q)));
        race.fill_panel(rng, slot, k, |r| r as u64);
        let s = race.support.len();

        let mut y_best = f64::INFINITY;
        let mut y_arg = 0usize;
        race.best.clear();
        race.best.resize(k, f64::INFINITY);
        race.arg.clear();
        race.arg.resize(k, 0);

        for (j, &iu) in race.support.iter().enumerate() {
            let i = iu as usize;
            let qi = q.prob(i);
            for kk in 0..k {
                let pi = ps[kk].prob(i);
                if qi <= 0.0 && pi <= 0.0 {
                    continue;
                }
                let e = race.panel[kk * s + j];
                if qi > 0.0 {
                    let v = e / qi;
                    if v < y_best {
                        y_best = v;
                        y_arg = i;
                    }
                }
                if pi > 0.0 {
                    let v = e / pi;
                    if v < race.best[kk] {
                        race.best[kk] = v;
                        race.arg[kk] = i;
                    }
                }
            }
        }

        let xs = race.arg[..k].to_vec();
        let accept = xs.contains(&y_arg);
        GlsOutcome { y: y_arg, xs, accept }
    }

    /// Bilateral (list-vs-list) GLS — bit-exact with
    /// [`super::gls::sample_gls_bilateral_scalar`]. Panel rows are the
    /// K×M grid lanes; X minima fold over m, Y minima fold over k, both
    /// tracked in one fused pass over the union support.
    pub fn sample_gls_bilateral(
        &mut self,
        p: &Categorical,
        q: &Categorical,
        k_a: usize,
        k_b: usize,
        rng: &CounterRng,
        slot: u64,
    ) -> BilateralOutcome {
        assert_eq!(p.len(), q.len(), "alphabet mismatch");
        assert!(k_a >= 1 && k_b >= 1);
        let race = &mut self.race;
        race.build_support(p.len(), [p, q].into_iter());
        race.fill_panel(rng, slot, k_a * k_b, |r| r as u64);
        let s = race.support.len();

        // best/arg lanes: [0, k_a) for X, [k_a, k_a + k_b) for Y.
        race.best.clear();
        race.best.resize(k_a + k_b, f64::INFINITY);
        race.arg.clear();
        race.arg.resize(k_a + k_b, 0);

        for (j, &iu) in race.support.iter().enumerate() {
            let i = iu as usize;
            let pi = p.prob(i);
            let qi = q.prob(i);
            for k in 0..k_a {
                for m in 0..k_b {
                    let e = race.panel[(k * k_b + m) * s + j];
                    if pi > 0.0 {
                        let v = e / pi;
                        if v < race.best[k] {
                            race.best[k] = v;
                            race.arg[k] = i;
                        }
                    }
                    if qi > 0.0 {
                        let v = e / qi;
                        if v < race.best[k_a + m] {
                            race.best[k_a + m] = v;
                            race.arg[k_a + m] = i;
                        }
                    }
                }
            }
        }

        let xs = race.arg[..k_a].to_vec();
        let ys = race.arg[k_a..k_a + k_b].to_vec();
        let accept = ys.iter().any(|y| xs.contains(y));
        BilateralOutcome { xs, ys, accept }
    }

    /// Alg. 2 target-token selection — bit-exact with
    /// [`super::gls::select_target_token_scalar`].
    pub fn select_target_token(
        &mut self,
        dists: &[&Categorical],
        active: &[usize],
        rng: &CounterRng,
        slot: u64,
    ) -> usize {
        assert!(!active.is_empty());
        let n = dists[active[0]].len();
        self.race.select_with(n, active, |k| dists[k], rng, slot)
    }

    /// Algorithm 2 block verification (conditional or strong variant) over
    /// the workspace kernel — bit-exact with
    /// [`super::gls::GlsVerifier::verify_block_scalar`].
    pub fn verify_block_gls(
        &mut self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
        strong: bool,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok(), "{:?}", input.validate());
        let k = input.k();
        let l = input.block_len();
        let n = input.target_dists[0][0].len();
        let Self { race, active, all, .. } = self;
        all.clear();
        all.extend(0..k);
        active.clear();
        active.extend(0..k);
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;

        for j in 0..l {
            let participants: &[usize] = if strong { &all[..] } else { &active[..] };
            let yj = race
                .select_with(n, participants, |kk| &input.target_dists[kk][j], rng, slot0 + j as u64)
                as u32;
            tokens.push(yj);
            active.retain(|&kk| input.draft_tokens[kk][j] == yj);
            if active.is_empty() {
                // All drafts diverged: Y_j was still emitted (it is a valid
                // target sample), and the block ends here — Alg. 2 line 12.
                return BlockOutput { tokens, accepted, surviving_draft: None };
            }
            accepted += 1;
        }

        // Full block accepted: emit the bonus token Y_{L+1} (Alg. 2 line 13).
        let participants: &[usize] = if strong { &all[..] } else { &active[..] };
        let bonus = race
            .select_with(n, participants, |kk| &input.target_dists[kk][l], rng, slot0 + l as u64)
            as u32;
        tokens.push(bonus);
        BlockOutput { tokens, accepted, surviving_draft: active.first().copied() }
    }
}

thread_local! {
    static WORKSPACE: RefCell<CouplingWorkspace> = RefCell::new(CouplingWorkspace::new());
}

/// Run `f` with this thread's coupling workspace. The thread-local keeps
/// the public free-function API of [`super::gls`] allocation-free on the
/// hot path and plays well with the engine's parallel stepping: each
/// verification thread warms its own scratch once and reuses it forever.
pub fn with_workspace<R>(f: impl FnOnce(&mut CouplingWorkspace) -> R) -> R {
    WORKSPACE.with(|w| f(&mut w.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::gls;
    use crate::stats::rng::XorShift128;
    use crate::testkit;

    #[test]
    fn support_union_is_sorted_and_exact() {
        let p = Categorical::new(vec![0.0, 0.5, 0.5, 0.0, 0.0]);
        let q = Categorical::new(vec![0.5, 0.0, 0.0, 0.0, 0.5]);
        let mut race = RaceScratch::new();
        race.build_support(5, [&p, &q].into_iter());
        assert_eq!(race.support, vec![0, 1, 2, 4]);
    }

    #[test]
    fn support_handles_alphabets_beyond_one_word() {
        // > 64 items exercises the multi-word bitset path.
        let mut gen = XorShift128::new(9);
        let p = testkit::gen_sparse_categorical(&mut gen, 150, 7);
        let q = testkit::gen_sparse_categorical(&mut gen, 150, 5);
        let mut race = RaceScratch::new();
        race.build_support(150, [&p, &q].into_iter());
        let expect: Vec<u32> = (0..150u32)
            .filter(|&i| p.prob(i as usize) > 0.0 || q.prob(i as usize) > 0.0)
            .collect();
        assert_eq!(race.support, expect);
    }

    #[test]
    fn support_union_mixes_cached_and_dense_lists() {
        // q: top-k truncated (cached support); p: dense constructor (no
        // cache) — the union must fall back to scanning and stay exact.
        let logits: Vec<f32> = (0..100).map(|i| (i % 13) as f32).collect();
        let q = Categorical::from_logits(&logits, 1.0, Some(10));
        assert!(q.support().is_some());
        let mut masses = vec![0.0; 100];
        masses[3] = 0.7;
        masses[98] = 0.3;
        let p = Categorical::new(masses);
        assert!(p.support().is_none());
        let mut race = RaceScratch::new();
        race.build_support(100, [&p, &q].into_iter());
        let expect: Vec<u32> = (0..100u32)
            .filter(|&i| p.prob(i as usize) > 0.0 || q.prob(i as usize) > 0.0)
            .collect();
        assert_eq!(race.support, expect);

        // Both cached: the fast path must produce the same union.
        let q2 = Categorical::from_logits(&logits, 1.0, Some(7));
        race.build_support(100, [&q, &q2].into_iter());
        let expect: Vec<u32> = (0..100u32)
            .filter(|&i| q.prob(i as usize) > 0.0 || q2.prob(i as usize) > 0.0)
            .collect();
        assert_eq!(race.support, expect);
    }

    #[test]
    fn panel_entries_match_counter_rng() {
        let p = Categorical::new(vec![0.25; 4]);
        let rng = CounterRng::new(3);
        let mut race = RaceScratch::new();
        race.build_support(4, std::iter::once(&p));
        race.fill_panel(&rng, 11, 3, |r| r as u64);
        for k in 0..3u64 {
            for i in 0..4u64 {
                assert_eq!(
                    race.panel[(k as usize) * 4 + i as usize],
                    rng.exponential(11, k, i)
                );
            }
        }
    }

    #[test]
    fn workspace_reuse_does_not_change_outcomes() {
        // The same workspace must give identical results before and after
        // being used for unrelated races (stale scratch must not leak).
        let mut gen = XorShift128::new(21);
        let p = testkit::gen_categorical(&mut gen, 12);
        let q = testkit::gen_categorical(&mut gen, 12);
        let rng = CounterRng::new(5);
        let mut ws = CouplingWorkspace::new();
        let fresh = ws.sample_gls(&p, &q, 4, &rng, 9);
        // Pollute the scratch with differently-shaped races.
        let small = testkit::gen_sparse_categorical(&mut gen, 70, 3);
        ws.sample_gls(&small, &small, 9, &rng, 1);
        ws.sample_gls_bilateral(&p, &q, 2, 3, &rng, 2);
        let again = ws.sample_gls(&p, &q, 4, &rng, 9);
        assert_eq!(fresh, again);
    }

    #[test]
    fn kernel_matches_scalar_smoke() {
        // Full parity lives in tests/kernel_parity.rs; this is the in-module
        // canary so `cargo test --lib` catches drift too.
        let mut gen = XorShift128::new(33);
        let mut ws = CouplingWorkspace::new();
        for seed in 0..20u64 {
            let p = testkit::gen_categorical(&mut gen, 9);
            let q = testkit::gen_categorical(&mut gen, 9);
            let rng = CounterRng::new(seed);
            assert_eq!(
                ws.sample_gls(&p, &q, 3, &rng, seed),
                gls::sample_gls_scalar(&p, &q, 3, &rng, seed)
            );
        }
    }
}
