//! Zero-allocation batched coupling kernel for the serving stack's
//! verification schemes: the GLS family, SpecTr, SpecInfer, Daliri, and
//! the classic single-draft TR baseline all run their `verify_block` here.
//!
//! The scalar reference implementations (`*_scalar` in [`super::gls`],
//! [`super::spectr`], [`super::specinfer`], [`super::daliri`]) evaluate
//! `O(N · K)` counter-RNG hashes / `ln()` calls / dense vector passes per
//! race or rejection round, re-deriving the `(slot, draft)` hash prefix for
//! every vocabulary item and walking the full alphabet even when the
//! distributions are top-k truncated (the paper's LLM experiments run top-k
//! 50 over 2048+ vocabularies, so ≥97% of the work is provably dead
//! weight). This module is the serving hot path's answer:
//!
//! * [`CouplingWorkspace`] owns reusable flat scratch buffers — races and
//!   rejection cascades make **no heap allocations** beyond their mandated
//!   outputs once the workspace has warmed up.
//! * Exponentials are materialized once per race into a single
//!   **item-major panel** (`panel[j * rows + row]`): races visit items
//!   outer and lanes inner, so the panel's memory order *is* the read
//!   order — at the paper's hot shape (K=8, top-k 50) one item's column
//!   of 8 `f64`s is one 64-byte cache line, where the previous row-major
//!   layout cost K strided touches per item. The per-`(slot, draft)`
//!   SplitMix64 prefix is hoisted via [`CounterRng::lane`] so each item
//!   costs one mix round instead of three. (Layout audit note: the panel
//!   is the only k×items buffer the races stride through; the
//!   [`ResidualScratch`] mass buffer must stay dense/item-indexed because
//!   the rejection cascades read `mass[token]` by raw token id — the
//!   scalar parity contract pins that shape.)
//! * Races iterate a **sparse support**: the ascending union
//!   `supp(p) ∪ supp(q)` (resp. the union over participating drafts).
//!   This is *exact*, not approximate — a zero-mass symbol is skipped by
//!   the scalar `argmin` too, so it can never win — and turns `O(N · K)`
//!   into `O(top_k · K)` for truncated distributions.
//! * The rejection-cascade baselines (SpecInfer recursive residuals,
//!   SpecTr K-SEQ calibration and its optimal-transport residual plan) run
//!   on a [`ResidualScratch`]: the residual distribution lives in a dense
//!   mass buffer tracked by an ascending support list, so residual updates
//!   and inverse-CDF draws cost `O(|supp(q)|)` instead of `O(N)` plus a
//!   `Categorical` allocation per rejection round.
//! * Draft-phase races run through [`CouplingWorkspace::sample_race`],
//!   which memoizes the evaluated exponentials in a [`PanelCache`] keyed
//!   by the `(slot, draft)` lane prefix ([`CounterLane::key`]); a later
//!   verification race on the same workspace at the same coordinates (the
//!   coupled verify step — the draft/verifier coordinate overlap *is* the
//!   paper's shared-randomness coupling) reassembles its panel from the
//!   cache instead of re-hashing. The cache is **leaky** (see "Leaky
//!   panel-cache contract" below): a fixed array of direct-mapped slots
//!   over flat backing storage, overwrite on collision. Entries are keyed
//!   by exactly the value that determines the variates, so reuse is
//!   structurally bit-exact — a hit, a miss, and an overwritten entry all
//!   produce identical panels.
//! * The same reuse works **across threads** via [`PanelSlice`]: the
//!   engine's draft phase records each race's evaluated exponentials into
//!   a per-sequence, `Send`-able slice
//!   ([`PanelSlice::record_race`], bit-exact with
//!   [`Categorical::sample_race`]), and whichever verify-pool worker later
//!   verifies that sequence installs the slice into its own workspace
//!   cache ([`CouplingWorkspace::adopt_panel_slice`]) before racing. See
//!   "Panel-slice handoff protocol" below.
//!
//! # Panel-slice handoff protocol
//!
//! The engine's persistent verify pool (`coordinator::pool`) runs each
//! sequence's verification on an arbitrary long-lived worker thread, so
//! the draft-phase exponentials — evaluated on the engine thread — cannot
//! be reused through a thread-local cache. The handoff closes that gap:
//!
//! 1. **Record.** For the panel-racing verifiers (GLS, GLS-strong,
//!    Daliri), the engine drafts lane `k`'s token at slot `j` through
//!    `PanelSlice::record_race(p, rng, slot, k)`, which appends one row
//!    `(key = rng.lane(slot, k).key(), items = supp(p), values = Exp(1)
//!    variates)` to the sequence's slice while returning the identical
//!    token `Categorical::sample_race` would.
//! 2. **Hand off.** The slice rides inside the sequence's verify job
//!    (plain owned data — `Send` needs no synchronization because every
//!    variate is a pure function of `(key, item)`;
//!    `CounterLane::key` documents that contract).
//! 3. **Install.** The worker that claims the job calls
//!    `adopt_panel_slice` *before* verification, copying each recorded
//!    row into its direct-mapped [`PanelCache`] slot (a bounded
//!    `memcpy` into flat storage — no re-hash, no allocation, and no
//!    capacity growth: rows longer than a slot store their ascending
//!    prefix, rows landing on an occupied slot overwrite it).
//! 4. **Reuse.** Verification races at the same `(slot, lane)`
//!    coordinates find the rows by key and merge cached items into their
//!    panels ([`RaceScratch::fill_panel`]), counting one panel-cache hit
//!    per merged row — [`CouplingWorkspace::panel_cache_hits`] is the
//!    observable the engine aggregates into its metrics and tests assert
//!    on (misses and collision overwrites travel alongside it in
//!    [`PanelCacheStats`]).
//! 5. **Recycle.** `adopt_panel_slice` hands the spent container back:
//!    the recorded values are copied into the cache and the rows' own
//!    buffers come back inside the same [`PanelSlice`] as *spare* row
//!    capacity (one spare pair per adopted row). The consumer ships the
//!    spent slice to the recording engine's [`SliceRecycler`] (an mpsc
//!    return channel; each verify job carries the sender), where the
//!    next block's [`SliceRecycler::lease`] hands it back to the draft
//!    phase. [`PanelSlice::record_race`] pops spare rows before
//!    allocating, so steady-state draft-phase recording makes **no heap
//!    allocations** — the cross-thread equivalent of the in-workspace
//!    warm path. Recycling moves only buffer *capacity*, never recorded
//!    values; a lost or late return degrades to a fresh allocation, not
//!    a wrong panel.
//!
//! A hit can never change an outcome — key equality implies variate
//! equality — so the handoff is a pure perf transport; adversarial slices
//! (wrong sequence, stale block) degrade to misses, not corruption.
//!
//! # Leaky panel-cache contract
//!
//! The cache follows the "leaky" design from the BDD-repo perf playbook:
//! reuse is an optimization, never correctness, so the cache is allowed
//! to *lose* entries at any time and for any reason. Concretely:
//!
//! * **Fixed size, direct-mapped.** [`PANEL_CACHE_SLOTS`] slots indexed
//!   by the low bits of the lane key (already a full SplitMix64 mix —
//!   every bit is avalanche-mixed, so no second hash is needed), each
//!   backed by a [`PANEL_CACHE_SLOT_CAP`]-item region of two flat
//!   arrays. A probe is one key compare plus two contiguous loads; there
//!   is no probing chain, no linked entries, and no per-entry heap
//!   allocation to chase.
//! * **Overwrite on collision.** Two live keys mapping to one slot simply
//!   take turns; the loser's next read is a miss that recomputes its
//!   variates (bit-identical by purity of `(key, item)`). Collision
//!   overwrites are counted ([`PanelCacheStats::overwrites`]) so the
//!   engine can see thrash, but nothing is ever rehoused or resized.
//! * **Prefix truncation.** A recorded row longer than a slot keeps only
//!   its first [`PANEL_CACHE_SLOT_CAP`] (ascending) items; the panel
//!   merge computes whatever the cache does not carry. The slot size
//!   covers the paper's hot shape (top-k 50 < 64) with a full line-pair
//!   of values.
//! * **Bounded memory, structurally.** The backing arrays are sized once
//!   in [`PanelCache::new`] and never grow — adopting an arbitrarily
//!   large slice cannot inflate the workspace (the old ring's
//!   `ensure_capacity` ratchet is gone; a regression test pins this).
//!
//! # Kernel contract
//!
//! Determinism is load-bearing (drafter invariance per paper Def. 1/2,
//! replay audits), so every kernel path is required to be **bit-exact**
//! with its scalar reference: equal outputs as *values* (same tokens, same
//! accept counts, same surviving draft) for every input and every
//! [`CounterRng`] — not merely equal in distribution. The rules that make
//! this tractable, and that any new verifier port must follow:
//!
//! 1. **Same variates.** Panel entries reproduce
//!    `rng.exponential(slot, draft, item)` exactly (the lane hoist applies
//!    the identical mix constants in the identical order), and uniform
//!    draws consume the identical `(slot, draft, item)` coordinates in the
//!    identical order as the scalar path.
//! 2. **Same visit order.** Races visit candidate items ascending and
//!    lanes in scalar iteration order; ties are broken by strict `<`, so
//!    the first-visited minimum wins in both paths.
//! 3. **Exact sparsity only.** Skipping an item is allowed only when it
//!    contributes an exact no-op in the scalar path: a zero-mass symbol
//!    can never win an argmin, and adds an exact `+0.0` to any
//!    nonnegative running sum (mass totals, CDF walks). Never skip based
//!    on an approximate threshold.
//! 4. **Replicate normalization bit-for-bit.** Residual renormalization
//!    copies [`Categorical::new`]'s exact branch
//!    (`if (total - 1.0).abs() > 1e-12 { divide }`) and the scalar
//!    `residual()`/`calibrate()` thresholds (`1e-15` / `1e-12`) verbatim,
//!    and inverse-CDF walks keep the scalar's dense fallback index
//!    `N - 1`.
//!
//! # RNG coordinate map
//!
//! This table is *declared as data* in the central lane registry
//! (`crate::analysis::lanes`), which checks region disjointness as a tier-1
//! test and debug-asserts it at [`CouplingWorkspace::verify_block_kind`]
//! dispatch; the consolidated human-readable map (engine, codec, trace,
//! server) lives in EXPERIMENTS.md §Analysis.
//!
//! Which shared-randomness coordinates each consumer reads (`slot` is the
//! absolute decoding position; K = number of drafts the engine runs):
//!
//! | consumer                  | coordinates                                             |
//! |---------------------------|---------------------------------------------------------|
//! | engine draft phase        | Exp at `(slot, lane, i)`, lane ∈ 0..K                   |
//! | GLS verify (cond./strong) | Exp at `(slot, k, i)`, k ∈ active / 0..K                |
//! | Daliri verify             | Exp at `(slot, 0, i)` (bonus token too)                 |
//! | bilateral GLS             | Exp at `(slot, k·M + m, i)`                             |
//! | SpecInfer / SpecTr verify | U at `(slot, K + round, 0)`, round ∈ 0..=\|active\|; bonus U at `(slot, K, 0)` |
//! | single-draft baseline     | U at `(slot, 1, 0)` / `(slot, 2, 0)`; bonus U at `(slot, 1, 0)` |
//!
//! GLS/Daliri verification reads the *same* `(slot, lane)` exponential
//! coordinates the draft phase wrote — that overlap is the coupling, and
//! it is what the panel cache exploits. The rejection baselines
//! deliberately consume draft coordinates `K..` so their verification
//! uniforms never collide with drafting randomness at the same slot.
//!
//! # Porting a new verifier onto the workspace
//!
//! 1. Keep (or extract) the straightforward full-alphabet implementation
//!    as a public `*_scalar` method — it is the parity oracle and the perf
//!    baseline.
//! 2. Implement the workspace method here on [`RaceScratch`] /
//!    [`ResidualScratch`], following the contract rules above.
//! 3. Point the `BlockVerifier::verify_block` trait impl at
//!    [`with_workspace`].
//! 4. Add a per-verifier bit-exactness suite to `tests/kernel_parity.rs`
//!    (randomized `(p, q, K, L, top_k)` grids *plus* degenerate supports:
//!    point masses, disjoint supports, `top_k ≥ vocab`).
//! 5. The statistical conformance suite (`tests/conformance.rs`) and the
//!    structural property suite (`tests/properties.rs`) pick the verifier
//!    up automatically through `spec::all_verifiers()` — register the new
//!    kind there.
//! 6. Add a scalar-vs-kernel pair to `benches/perf_engine.rs` and gate its
//!    speedup in `.github/workflows/ci.yml` (perf-smoke requires ≥3×).

use std::cell::RefCell;

use crate::stats::rng::CounterRng;

use super::gls::{BilateralOutcome, GlsOutcome};
use super::types::{BlockInput, BlockOutput, Categorical, VerifierKind, FAULT_MARKER_TOKEN};

/// Number of direct-mapped slots in the leaky [`PanelCache`]. Power of
/// two (the slot index is `key & (SLOTS - 1)`; the key is already a full
/// SplitMix64 mix, so its low bits are uniform). Sized to hold several
/// blocks' worth of `(slot, lane)` rows; a collision only costs
/// recomputation, never correctness.
pub const PANEL_CACHE_SLOTS: usize = 128;

/// Items each slot can memoize. A row longer than this keeps its first
/// `PANEL_CACHE_SLOT_CAP` (ascending) items — a *prefix*, still valid for
/// merging; missing items are recomputed. Covers the paper's hot shape
/// (top-k 50) with headroom: one slot's values span 8 cache lines read
/// contiguously, instead of a heap `Vec` found by linear scan.
pub const PANEL_CACHE_SLOT_CAP: usize = 64;

/// Slot-occupancy sentinel for [`PanelCache::lens`]. Distinct from every
/// real length (≤ [`PANEL_CACHE_SLOT_CAP`]) so an empty slot can never
/// false-hit, whatever key bits it holds.
const SLOT_EMPTY: u32 = u32::MAX;

/// Running reuse counters of one workspace's [`PanelCache`]: panel rows
/// served from cache (`hits`), rows that had to be fully recomputed
/// (`misses`), and live entries displaced by a different key mapping to
/// the same slot (`overwrites` — the "leak" actually leaking). Purely
/// observational; the engine drains them into `EngineMetrics` per block.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PanelCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub overwrites: u64,
}

impl PanelCacheStats {
    /// Fold another drain's counters into this one.
    pub fn merge(&mut self, other: PanelCacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.overwrites += other.overwrites;
    }
}

/// Leaky memo of recently evaluated draft-phase exponential rows, keyed
/// by the lane prefix ([`CounterLane::key`]). Since every variate is a
/// pure function of `(key, item)`, any slot with a matching key holds
/// valid values for the items it lists — reuse can never change an
/// outcome, only skip hash+`ln` work — and therefore the cache is free to
/// drop entries whenever convenient: fixed [`PANEL_CACHE_SLOTS`]
/// direct-mapped slots over flat `items`/`values` arrays, overwrite on
/// collision, prefix-truncate on oversized rows. See the module-level
/// "Leaky panel-cache contract".
///
/// [`CounterLane::key`]: crate::stats::rng::CounterLane::key
struct PanelCache {
    /// Per-slot lane key; only meaningful where `lens[slot] != SLOT_EMPTY`.
    keys: Vec<u64>,
    /// Per-slot recorded length, or [`SLOT_EMPTY`].
    lens: Vec<u32>,
    /// Flat ascending item ids: slot `s` owns `items[s*CAP .. (s+1)*CAP]`.
    items: Vec<u32>,
    /// Flat Exp(1) values, same geometry as `items`.
    values: Vec<f64>,
    /// Live entries displaced by a colliding key (not same-key refresh).
    overwrites: u64,
}

impl PanelCache {
    fn new() -> Self {
        Self {
            keys: vec![0; PANEL_CACHE_SLOTS],
            lens: vec![SLOT_EMPTY; PANEL_CACHE_SLOTS],
            items: vec![0; PANEL_CACHE_SLOTS * PANEL_CACHE_SLOT_CAP],
            values: vec![0.0; PANEL_CACHE_SLOTS * PANEL_CACHE_SLOT_CAP],
            overwrites: 0,
        }
    }

    #[inline]
    fn slot_of(key: u64) -> usize {
        (key & (PANEL_CACHE_SLOTS as u64 - 1)) as usize
    }

    /// Probe for `key`: one compare, then two contiguous flat-array
    /// slices. Returns the memoized `(items, values)` prefix on a hit.
    #[inline]
    fn find(&self, key: u64) -> Option<(&[u32], &[f64])> {
        let s = Self::slot_of(key);
        if self.lens[s] != SLOT_EMPTY && self.keys[s] == key {
            let len = self.lens[s] as usize;
            let base = s * PANEL_CACHE_SLOT_CAP;
            Some((&self.items[base..base + len], &self.values[base..base + len]))
        } else {
            None
        }
    }

    /// Claim `key`'s slot for recording, overwriting any colliding entry,
    /// and return a bounds-checked writer over its flat region.
    fn begin(&mut self, key: u64) -> SlotWriter<'_> {
        let s = Self::slot_of(key);
        if self.lens[s] != SLOT_EMPTY && self.keys[s] != key {
            self.overwrites += 1;
        }
        self.keys[s] = key;
        self.lens[s] = 0;
        let base = s * PANEL_CACHE_SLOT_CAP;
        SlotWriter {
            items: &mut self.items[base..base + PANEL_CACHE_SLOT_CAP],
            values: &mut self.values[base..base + PANEL_CACHE_SLOT_CAP],
            len: &mut self.lens[s],
        }
    }

    /// Install an externally recorded row (the panel-slice handoff):
    /// a bounded copy of its ascending prefix into `key`'s slot. Rows
    /// longer than a slot truncate (the merge recomputes the tail); rows
    /// colliding with a live entry overwrite it.
    fn adopt(&mut self, key: u64, items: &[u32], values: &[f64]) {
        debug_assert_eq!(items.len(), values.len());
        let s = Self::slot_of(key);
        if self.lens[s] != SLOT_EMPTY && self.keys[s] != key {
            self.overwrites += 1;
        }
        let n = items.len().min(PANEL_CACHE_SLOT_CAP);
        let base = s * PANEL_CACHE_SLOT_CAP;
        self.items[base..base + n].copy_from_slice(&items[..n]);
        self.values[base..base + n].copy_from_slice(&values[..n]);
        self.keys[s] = key;
        self.lens[s] = n as u32;
    }

    /// Take and reset the collision-overwrite counter.
    fn drain_overwrites(&mut self) -> u64 {
        std::mem::take(&mut self.overwrites)
    }
}

/// In-progress recording into one [`PanelCache`] slot: appends until the
/// slot region is full, then silently drops the tail (prefix truncation —
/// the leaky contract makes that safe).
struct SlotWriter<'a> {
    items: &'a mut [u32],
    values: &'a mut [f64],
    len: &'a mut u32,
}

impl SlotWriter<'_> {
    #[inline]
    fn push(&mut self, item: u32, value: f64) {
        let l = *self.len as usize;
        if l < PANEL_CACHE_SLOT_CAP {
            self.items[l] = item;
            self.values[l] = value;
            *self.len = (l + 1) as u32;
        }
    }
}

/// One recorded `(slot, draft)` row of exponentials in a [`PanelSlice`]:
/// `values[j]` is the Exp(1) variate at item `items[j]` (ascending) for
/// the lane identified by `key`
/// ([`crate::stats::rng::CounterLane::key`]).
#[derive(Debug, Default)]
struct PanelRow {
    key: u64,
    items: Vec<u32>,
    values: Vec<f64>,
}

/// A `Send`-able record of draft-phase exponential rows for *one*
/// sequence, keyed by the `(slot, draft)` lane prefix — the unit of the
/// cross-thread panel-cache handoff (see the module docs, "Panel-slice
/// handoff protocol").
///
/// The engine records each draft race into the sequence's slice via
/// [`PanelSlice::record_race`]; the verify-pool worker that later claims
/// the sequence installs the slice into its own workspace cache with
/// [`CouplingWorkspace::adopt_panel_slice`]. Rows are plain owned data:
/// variates are pure functions of `(key, item)`, so shipping them across
/// threads needs no synchronization and cannot change any outcome.
///
/// Cost note: recording pops a *spare* row (buffers recycled through the
/// [`SliceRecycler`] return channel — see the module docs, step 5 of the
/// handoff protocol) before allocating, so once returns flow, draft-phase
/// recording is allocation-free in steady state like the in-workspace warm
/// path of [`CouplingWorkspace::sample_race`]. A cold slice (no spares
/// yet) allocates one exact-sized buffer pair per `(slot, draft)` row —
/// the same order as the `Categorical` the draft step builds anyway.
#[derive(Debug, Default)]
pub struct PanelSlice {
    /// Recorded `(slot, draft)` rows awaiting adoption.
    rows: Vec<PanelRow>,
    /// Recycled row buffers (cleared-but-capacitated) awaiting reuse by
    /// [`PanelSlice::record_race`].
    spare: Vec<PanelRow>,
}

impl PanelSlice {
    pub fn new() -> Self {
        Self { rows: Vec::new(), spare: Vec::new() }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Recorded `(slot, draft)` rows so far.
    #[inline]
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Spare (recycled) row buffers available for reuse — observability
    /// for the recycling channel; correctness never depends on it.
    #[inline]
    pub fn spare_len(&self) -> usize {
        self.spare.len()
    }

    /// Demote any recorded rows to spares (dropping their contents but
    /// keeping the buffers). Called when a leased slice is reused before
    /// its rows were adopted — recorded values are only ever consumed via
    /// [`CouplingWorkspace::adopt_panel_slice`], so this cannot lose data
    /// a verifier still needs.
    fn recycle_rows(&mut self) {
        self.spare.append(&mut self.rows);
    }

    /// Draft-phase Gumbel-max race that records the evaluated exponentials
    /// as a slice row — bit-exact with [`Categorical::sample_race`] (same
    /// visit order, same strict-`<` tie-breaking, identical variates), and
    /// with [`CouplingWorkspace::sample_race`] (which records into the
    /// thread's own cache instead).
    pub fn record_race(&mut self, d: &Categorical, rng: &CounterRng, slot: u64, draft: u64) -> usize {
        let lane = rng.lane(slot, draft);
        // Exact-size rows (top-k supports are known): reuse a recycled
        // buffer pair when one is spare, else one allocation per buffer —
        // no push-growth realloc on the draft hot path either way.
        let cap = d.support().map_or(d.len(), |s| s.len());
        let mut row = self.spare.pop().unwrap_or_default();
        row.key = lane.key();
        row.items.clear();
        row.values.clear();
        row.items.reserve(cap);
        row.values.reserve(cap);
        let mut best = f64::INFINITY;
        let mut arg = 0usize;
        let mut consider = |i: usize, p: f64| {
            if p <= 0.0 {
                return;
            }
            let e = lane.exponential(i as u64);
            row.items.push(i as u32);
            row.values.push(e);
            let v = e / p;
            if v < best {
                best = v;
                arg = i;
            }
        };
        match d.support() {
            Some(sup) => {
                for &i in sup {
                    consider(i as usize, d.prob(i as usize));
                }
            }
            None => {
                for (i, &p) in d.probs().iter().enumerate() {
                    consider(i, p);
                }
            }
        }
        self.rows.push(row);
        arg
    }
}

/// Engine-side lease/return endpoint of the panel-slice recycling channel
/// (step 5 of the handoff protocol — see the module docs).
///
/// The recording engine owns one recycler. Per block it [`lease`]s one
/// slice per sequence; every verify job carries a [`return_sender`] clone,
/// and whichever workspace consumes the job (engine thread or pool worker)
/// sends the spent slice back after [`CouplingWorkspace::adopt_panel_slice`].
/// Returns are best-effort by design: a dropped receiver or an unreturned
/// slice only costs a fresh allocation on the next lease.
///
/// [`lease`]: SliceRecycler::lease
/// [`return_sender`]: SliceRecycler::return_sender
pub struct SliceRecycler {
    tx: std::sync::mpsc::Sender<PanelSlice>,
    rx: std::sync::mpsc::Receiver<PanelSlice>,
    /// Leases served from returned slices since the last drain.
    recycled: u64,
}

impl Default for SliceRecycler {
    fn default() -> Self {
        Self::new()
    }
}

impl SliceRecycler {
    pub fn new() -> Self {
        let (tx, rx) = std::sync::mpsc::channel();
        Self { tx, rx, recycled: 0 }
    }

    /// Hand out a slice for draft-phase recording: a returned (spent) one
    /// when available — its spare buffers make `record_race`
    /// allocation-free — else a fresh empty slice.
    pub fn lease(&mut self) -> PanelSlice {
        self.try_lease().unwrap_or_default()
    }

    /// Like [`SliceRecycler::lease`], but only when a returned slice is
    /// actually available — lets the engine fall back to the pool-level
    /// [`SliceBank`] (then to a fresh slice) when the local channel is
    /// dry, instead of silently allocating.
    pub fn try_lease(&mut self) -> Option<PanelSlice> {
        match self.rx.try_recv() {
            Ok(mut slice) => {
                slice.recycle_rows();
                self.recycled += 1;
                Some(slice)
            }
            Err(_) => None,
        }
    }

    /// A return-channel handle for a verify job to ship its spent slice
    /// back on (cheap clone; sends from any thread).
    pub fn return_sender(&self) -> std::sync::mpsc::Sender<PanelSlice> {
        self.tx.clone()
    }

    /// Take and reset the recycled-lease counter (the engine aggregates it
    /// into `EngineMetrics::panel_slices_recycled` once per block).
    pub fn drain_recycled(&mut self) -> u64 {
        std::mem::take(&mut self.recycled)
    }

    /// Drain every queued return beyond what `lease` consumed — surplus an
    /// engine with small batches accumulates but will never use. The
    /// engine deposits these into the pool-level [`SliceBank`] so another
    /// engine's leases can reuse the buffers. Draining does not count as
    /// recycling (nothing was leased).
    pub fn drain_surplus(&mut self) -> Vec<PanelSlice> {
        let mut out = Vec::new();
        while let Ok(mut slice) = self.rx.try_recv() {
            slice.recycle_rows();
            out.push(slice);
        }
        out
    }
}

/// Maximum spare slices a [`SliceBank`] holds before deposits are dropped
/// on the floor (buffers simply deallocate — correctness never depends on
/// the bank).
const SLICE_BANK_CAP: usize = 256;

/// Pool-level spare-`PanelSlice` free list, shared by every engine
/// attached to one `VerifyPool`.
///
/// The per-engine [`SliceRecycler`] only recycles within its own engine:
/// under skewed batch sizes a busy engine allocates fresh slices every
/// block while an idle engine's returns sit unused in its channel. The
/// bank closes that loop: engines deposit surplus returns (tagged with
/// their engine id) and lease from the bank when their own recycler runs
/// dry. Slices are inert owned buffers — sharing them across engines
/// cannot change any decoded token.
#[derive(Debug, Default)]
pub struct SliceBank {
    /// `(donor_engine_tag, slice)` pairs available for lease.
    inner: std::sync::Mutex<Vec<(u64, PanelSlice)>>,
    /// Leases where the donor engine differs from the borrower — the
    /// observable that capacity actually moves across engines.
    cross: std::sync::atomic::AtomicU64,
}

impl SliceBank {
    pub fn new() -> Self {
        Self::default()
    }

    /// Deposit a spent slice on behalf of engine `donor_tag`. Silently
    /// drops the slice when the bank is full.
    pub fn deposit(&self, donor_tag: u64, slice: PanelSlice) {
        let mut bank = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if bank.len() < SLICE_BANK_CAP {
            bank.push((donor_tag, slice));
        }
    }

    /// Lease a spare slice for engine `tag`, preferring one donated by a
    /// *different* engine (that is the whole point of the bank; it also
    /// makes the cross-engine counter deterministic when both kinds are
    /// present). Returns `None` when the bank is empty.
    pub fn lease(&self, tag: u64) -> Option<PanelSlice> {
        let mut bank = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let idx = bank
            .iter()
            .rposition(|(donor, _)| *donor != tag)
            .unwrap_or(bank.len().checked_sub(1)?);
        let (donor, slice) = bank.swap_remove(idx);
        drop(bank);
        if donor != tag {
            self.cross.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        Some(slice)
    }

    /// Spare slices currently banked.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Leases served from a different engine's deposits.
    pub fn cross_engine_reuses(&self) -> u64 {
        self.cross.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// Reusable scratch for one coupling race.
struct RaceScratch {
    /// Ascending union-of-support item indices of the current race.
    support: Vec<u32>,
    /// Occupancy bitset used to build `support` (one bit per item).
    mask: Vec<u64>,
    /// Item-major exponential panel: `panel[j * rows + row]` is the
    /// Exp(1) variate of panel row `row` at item `support[j]` — the
    /// layout the j-outer/lane-inner races read contiguously.
    panel: Vec<f64>,
    /// Per-row hoisted lane prefixes for the panel being filled.
    lanes: Vec<crate::stats::rng::CounterLane>,
    /// Per-row cache-slot base offset into the [`PanelCache`] flat
    /// arrays, or `usize::MAX` for a miss (row fully recomputed).
    row_base: Vec<usize>,
    /// Per-row cached-prefix length / merge cursor pair.
    row_len: Vec<u32>,
    row_cur: Vec<u32>,
    /// Per-lane running minima and argmins.
    best: Vec<f64>,
    arg: Vec<usize>,
    /// Panel rows assembled (at least partially) from cache/handoff
    /// entries instead of being re-hashed, and rows recomputed from
    /// scratch. Purely observational — the engine aggregates them into
    /// its metrics and the handoff tests assert hits fire on worker
    /// threads.
    cache_hits: u64,
    cache_misses: u64,
}

impl RaceScratch {
    fn new() -> Self {
        Self {
            support: Vec::new(),
            mask: Vec::new(),
            panel: Vec::new(),
            lanes: Vec::new(),
            row_base: Vec::new(),
            row_len: Vec::new(),
            row_cur: Vec::new(),
            best: Vec::new(),
            arg: Vec::new(),
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    /// Rebuild `support` as the ascending union of the supports of
    /// `dists`, over an alphabet of `n` items.
    ///
    /// Distributions carrying a cached support list
    /// ([`Categorical::support`], e.g. top-k truncated ones) contribute it
    /// directly — O(top_k) bit sets instead of an O(n) prob rescan — which
    /// is what keeps the whole race O(top_k · K) in the paper's LLM regime.
    /// A cached list is allowed to be a superset of the true support (the
    /// races re-check every candidate's mass), so exactness is unaffected.
    fn build_support<'a, I>(&mut self, n: usize, dists: I)
    where
        I: Iterator<Item = &'a Categorical> + Clone,
    {
        let words = n.div_ceil(64);
        self.mask.clear();
        self.mask.resize(words, 0);
        let mut all_cached = true;
        for d in dists.clone() {
            debug_assert_eq!(d.len(), n);
            match d.support() {
                Some(sup) => {
                    for &i in sup {
                        self.mask[(i as usize) >> 6] |= 1u64 << (i & 63);
                    }
                }
                None => {
                    all_cached = false;
                    break;
                }
            }
        }
        if !all_cached {
            // At least one dense/unknown-support distribution: rescan all
            // of them (the mask may hold partial state from the first loop).
            self.mask.iter_mut().for_each(|w| *w = 0);
            for d in dists {
                debug_assert_eq!(d.len(), n);
                for (i, &p) in d.probs().iter().enumerate() {
                    if p > 0.0 {
                        self.mask[i >> 6] |= 1u64 << (i & 63);
                    }
                }
            }
        }
        self.support.clear();
        for (w, &bits) in self.mask.iter().enumerate() {
            let mut b = bits;
            while b != 0 {
                let t = b.trailing_zeros() as usize;
                self.support.push((w * 64 + t) as u32);
                b &= b - 1;
            }
        }
    }

    /// Fill an item-major panel (`panel[j * rows + r]`) of exponentials
    /// over the current support; panel row `r` uses the draft coordinate
    /// `lane_of(r)`. Entries are bit-exact with
    /// `rng.exponential(slot, lane_of(r), item)` — rows whose lane prefix
    /// is memoized in `cache` (a draft-phase race at the same
    /// coordinates) merge the cached values in, the rest are computed;
    /// both sources yield identical bits by construction, and evaluation
    /// *order* is free to differ from the scalar path because every
    /// variate is a pure function of `(key, item)` (only race *visit*
    /// order is contractual — rule 2).
    ///
    /// Generation runs j-outer/row-inner so writes are sequential in the
    /// item-major layout; the per-row lane prefixes are hoisted into
    /// `lanes` once, and each cached row keeps its own two-pointer merge
    /// cursor (`row_cur`) that only ever advances as `j` ascends.
    fn fill_panel(
        &mut self,
        rng: &CounterRng,
        slot: u64,
        rows: usize,
        mut lane_of: impl FnMut(usize) -> u64,
        cache: &PanelCache,
    ) {
        self.lanes.clear();
        self.row_base.clear();
        self.row_len.clear();
        self.row_cur.clear();
        for r in 0..rows {
            let lane = rng.lane(slot, lane_of(r));
            match cache.find(lane.key()) {
                Some((items, _)) => {
                    self.cache_hits += 1;
                    self.row_base.push(PanelCache::slot_of(lane.key()) * PANEL_CACHE_SLOT_CAP);
                    self.row_len.push(items.len() as u32);
                }
                None => {
                    self.cache_misses += 1;
                    self.row_base.push(usize::MAX);
                    self.row_len.push(0);
                }
            }
            self.row_cur.push(0);
            self.lanes.push(lane);
        }
        self.panel.clear();
        self.panel.reserve(rows * self.support.len());
        for &i in &self.support {
            for r in 0..rows {
                let base = self.row_base[r];
                let mut cached = f64::NAN;
                let mut have = false;
                if base != usize::MAX {
                    // Two-pointer merge against the slot's ascending
                    // cached prefix: copy on item match, compute the rest.
                    let len = self.row_len[r];
                    let mut c = self.row_cur[r];
                    while c < len && cache.items[base + c as usize] < i {
                        c += 1;
                    }
                    if c < len && cache.items[base + c as usize] == i {
                        cached = cache.values[base + c as usize];
                        have = true;
                    }
                    self.row_cur[r] = c;
                }
                let v = if have { cached } else { self.lanes[r].exponential(i as u64) };
                self.panel.push(v);
            }
        }
    }

    /// Alg. 2 line 9/13 selection over the union support:
    /// `argmin_i min_{k ∈ participants} S_i^{(slot,k)} / q_i^{(k)}` where
    /// `dist_of(k)` yields draft k's target distribution. Candidate visit
    /// order matches [`super::gls::select_target_token_scalar`] exactly.
    fn select_with<'a, F>(
        &mut self,
        n: usize,
        participants: &[usize],
        dist_of: F,
        rng: &CounterRng,
        slot: u64,
        cache: &PanelCache,
    ) -> usize
    where
        F: Fn(usize) -> &'a Categorical,
    {
        assert!(!participants.is_empty());
        self.build_support(n, participants.iter().map(|&k| dist_of(k)));
        let rows = participants.len();
        self.fill_panel(rng, slot, rows, |r| participants[r] as u64, cache);
        let mut best = f64::INFINITY;
        let mut arg = 0usize;
        for (j, &iu) in self.support.iter().enumerate() {
            let i = iu as usize;
            for (r, &k) in participants.iter().enumerate() {
                let qi = dist_of(k).prob(i);
                if qi <= 0.0 {
                    continue;
                }
                // Item-major panel: this inner loop walks one contiguous
                // column of `rows` values.
                let v = self.panel[j * rows + r] / qi;
                if v < best {
                    best = v;
                    arg = i;
                }
            }
        }
        arg
    }
}

/// Reusable scratch for the rejection-cascade baselines: a residual (or
/// optimal-transport residual plan) distribution stored as a dense mass
/// buffer plus the ascending list of indices that may carry mass.
///
/// The support list is allowed to be a superset of the true support
/// (entries may decay to exactly 0.0); every consumer re-checks masses, and
/// sums over the superset are bit-identical to dense sums because the
/// skipped/zero entries contribute an exact `+0.0`.
struct ResidualScratch {
    /// Ascending indices that may carry mass. Always ⊆ the initial
    /// distribution's support (residual updates never create mass).
    support: Vec<u32>,
    /// Dense masses over the alphabet; exactly 0.0 outside `support`.
    mass: Vec<f64>,
}

impl ResidualScratch {
    fn new() -> Self {
        Self { support: Vec::new(), mass: Vec::new() }
    }

    /// Reset to the all-zero measure over an alphabet of `n` items.
    fn reset(&mut self, n: usize) {
        if self.mass.len() == n {
            // Only the tracked support can be nonzero; zero it surgically.
            for &i in &self.support {
                self.mass[i as usize] = 0.0;
            }
        } else {
            self.mass.clear();
            self.mass.resize(n, 0.0);
        }
        self.support.clear();
    }

    /// Load `d`'s masses as the residual (SpecInfer round 0: residual = q).
    fn load(&mut self, d: &Categorical) {
        self.reset(d.len());
        match d.support() {
            Some(sup) => {
                for &i in sup {
                    let m = d.prob(i as usize);
                    if m > 0.0 {
                        self.support.push(i);
                        self.mass[i as usize] = m;
                    }
                }
            }
            None => {
                for (i, &m) in d.probs().iter().enumerate() {
                    if m > 0.0 {
                        self.support.push(i as u32);
                        self.mass[i] = m;
                    }
                }
            }
        }
    }

    /// In-place `(r − p)₊` followed by renormalization — bit-exact with
    /// `r.residual(p)` + [`Categorical::new`] on the scalar path. Returns
    /// `false` when the positive part is exhausted (scalar's `None`).
    fn subtract_renormalize(&mut self, p: &Categorical) -> bool {
        let mut total = 0.0;
        for &i in &self.support {
            let w = (self.mass[i as usize] - p.prob(i as usize)).max(0.0);
            self.mass[i as usize] = w;
            total += w;
        }
        if total <= 1e-15 {
            return false;
        }
        // Categorical::new's exact normalization branch.
        if (total - 1.0).abs() > 1e-12 {
            for &i in &self.support {
                self.mass[i as usize] /= total;
            }
        }
        true
    }

    /// Inverse-CDF draw — bit-exact with the dense
    /// [`Categorical::sample_inverse`] walk (zero entries add an exact
    /// `+0.0` to the CDF and can never be the first index where
    /// `u < acc` turns true), including the dense fallback `n - 1`.
    fn sample_inverse(&self, n: usize, u: f64) -> usize {
        let mut acc = 0.0;
        for &i in &self.support {
            acc += self.mass[i as usize];
            if u < acc {
                return i as usize;
            }
        }
        n - 1
    }
}

/// Sparse `s(γ) = Σ_i min(p_i, q_i/γ)` over a prepared union support —
/// bit-exact with the dense sum in [`super::spectr::calibrate`]: items off
/// the union have `p_i = q_i = 0` and contribute an exact `+0.0`.
fn s_of_gamma_sparse(support: &[u32], p: &Categorical, q: &Categorical, gamma: f64) -> f64 {
    let mut s = 0.0;
    for &i in support {
        let i = i as usize;
        s += p.prob(i).min(q.prob(i) / gamma);
    }
    s
}

/// Reusable flat scratch buffers for the whole coupling data path.
///
/// One workspace per thread (see [`with_workspace`]); every race reuses the
/// grown buffers, so steady-state verification makes no allocations beyond
/// the `GlsOutcome` / `BlockOutput` it must return.
pub struct CouplingWorkspace {
    race: RaceScratch,
    residual: ResidualScratch,
    cache: PanelCache,
    /// Alg. 2's active draft set S (conditional variant); doubles as the
    /// rejection baselines' surviving-candidate set.
    active: Vec<usize>,
    /// The full draft set 0..K (strong variant participants).
    all: Vec<usize>,
    /// Reusable index scratch for `Categorical::from_logits_with_scratch`.
    pub topk_scratch: Vec<u32>,
}

impl Default for CouplingWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl CouplingWorkspace {
    pub fn new() -> Self {
        Self {
            race: RaceScratch::new(),
            residual: ResidualScratch::new(),
            cache: PanelCache::new(),
            active: Vec::new(),
            all: Vec::new(),
            topk_scratch: Vec::new(),
        }
    }

    /// Draft-phase Gumbel-max race — bit-exact with
    /// [`Categorical::sample_race`] (same visit order, same strict-`<`
    /// tie-breaking, identical variates).
    ///
    /// Beyond returning the sample, the evaluated exponentials are recorded
    /// in the workspace panel cache keyed by the `(slot, draft)` lane, so a
    /// later verification race on this workspace at the same coordinates —
    /// the coupled verify step of GLS/Daliri, which by construction reads
    /// the same shared-randomness cells — reuses them instead of
    /// re-hashing. (The engine's cross-thread equivalent is
    /// [`PanelSlice::record_race`] + [`CouplingWorkspace::adopt_panel_slice`].)
    pub fn sample_race(&mut self, d: &Categorical, rng: &CounterRng, slot: u64, draft: u64) -> usize {
        let lane = rng.lane(slot, draft);
        let mut entry = self.cache.begin(lane.key());
        let mut best = f64::INFINITY;
        let mut arg = 0usize;
        let mut consider = |i: usize, p: f64| {
            if p <= 0.0 {
                return;
            }
            let e = lane.exponential(i as u64);
            entry.push(i as u32, e);
            let v = e / p;
            if v < best {
                best = v;
                arg = i;
            }
        };
        match d.support() {
            Some(sup) => {
                for &i in sup {
                    consider(i as usize, d.prob(i as usize));
                }
            }
            None => {
                for (i, &p) in d.probs().iter().enumerate() {
                    consider(i, p);
                }
            }
        }
        arg
    }

    /// Install a [`PanelSlice`] recorded by the engine's draft phase into
    /// this workspace's panel cache — step 3 of the handoff protocol (see
    /// module docs). Each row's ascending prefix is copied into its
    /// direct-mapped slot (a bounded `memcpy`, never an allocation or a
    /// capacity change); subsequent races at the recorded `(slot, lane)`
    /// coordinates merge from the cache. Rows colliding in one slot
    /// overwrite each other and rows longer than a slot truncate — both
    /// degrade to recomputation, never to a wrong panel (the leaky
    /// contract).
    ///
    /// Returns the spent container: the recorded values now live in the
    /// cache, and the rows' own buffers ride back as spare capacity (one
    /// pair per adopted row) — ship it to the recording engine's
    /// [`SliceRecycler`] (step 5) so the next block's draft-phase
    /// recording reuses the allocations.
    pub fn adopt_panel_slice(&mut self, mut slice: PanelSlice) -> PanelSlice {
        for row in slice.rows.drain(..) {
            self.cache.adopt(row.key, &row.items, &row.values);
            slice.spare.push(row);
        }
        slice
    }

    /// Panel rows served from the cache (draft-phase reuse) since the
    /// workspace was created or last drained.
    #[inline]
    pub fn panel_cache_hits(&self) -> u64 {
        self.race.cache_hits
    }

    /// Take and reset the hit counter (the engine/pool aggregate this into
    /// `EngineMetrics::panel_cache_hits` once per block).
    #[inline]
    pub fn drain_panel_cache_hits(&mut self) -> u64 {
        std::mem::take(&mut self.race.cache_hits)
    }

    /// Take and reset all panel-cache reuse counters — hits, misses, and
    /// collision overwrites — as one [`PanelCacheStats`]. The pool/engine
    /// drain this once per batch into `EngineMetrics`.
    pub fn drain_cache_stats(&mut self) -> PanelCacheStats {
        PanelCacheStats {
            hits: std::mem::take(&mut self.race.cache_hits),
            misses: std::mem::take(&mut self.race.cache_misses),
            overwrites: self.cache.drain_overwrites(),
        }
    }

    /// Dispatch `verify_block` for any registered verifier kind onto this
    /// workspace. This is what the engine's serial path and the verify
    /// pool's workers run: every kind resolves to the same kernel method
    /// its `BlockVerifier` impl uses, so pooled, scoped-spawn, and serial
    /// execution are bit-exact by construction.
    pub fn verify_block_kind(
        &mut self,
        kind: VerifierKind,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        // Every dispatch re-checks this kind's lane-consumption shape
        // against the central registry (debug builds; the debug-assertions
        // CI lane runs the full suites with it armed), so a verifier whose
        // lane layout drifts out of the registered coordinate map fails
        // typed at its first block.
        debug_assert!(
            crate::analysis::lanes::check_engine_profile(
                crate::analysis::lanes::engine_profile_of(kind),
                input.k(),
            )
            .is_ok(),
            "lane registry rejects {kind:?} at K={}",
            input.k(),
        );
        match kind {
            VerifierKind::Gls => self.verify_block_gls(input, rng, slot0, false),
            VerifierKind::GlsStrong => self.verify_block_gls(input, rng, slot0, true),
            VerifierKind::SpecInfer => self.verify_block_specinfer(input, rng, slot0),
            VerifierKind::SpecTr => self.verify_block_spectr(input, rng, slot0),
            VerifierKind::SingleDraft => self.verify_block_single_draft(input, rng, slot0),
            VerifierKind::Daliri => self.verify_block_daliri(input, rng, slot0),
            VerifierKind::FaultInjection => {
                // Test-only: panic when the whole block is the marker token
                // (the panic-injection suites rig a point-mass draft model
                // to produce exactly that), else behave as GLS. The marker
                // condition requires EVERY drafted position so an honest
                // model can't trip it by chance.
                let all_marker = input.draft_dists.iter().enumerate().all(|(lane, dd)| {
                    (0..dd.len()).all(|j| input.draft_tokens[lane][j] == FAULT_MARKER_TOKEN)
                });
                if all_marker {
                    panic!("injected verification fault (VerifierKind::FaultInjection marker block)");
                }
                self.verify_block_gls(input, rng, slot0, false)
            }
        }
    }

    /// Algorithm 1 (SampleGLS) over the sparse union support — bit-exact
    /// with [`super::gls::sample_gls_scalar`].
    pub fn sample_gls(
        &mut self,
        p: &Categorical,
        q: &Categorical,
        k: usize,
        rng: &CounterRng,
        slot: u64,
    ) -> GlsOutcome {
        assert_eq!(p.len(), q.len(), "alphabet mismatch");
        assert!(k >= 1);
        let Self { race, cache, .. } = self;
        race.build_support(p.len(), [p, q].into_iter());
        race.fill_panel(rng, slot, k, |r| r as u64, cache);

        let mut y_best = f64::INFINITY;
        let mut y_arg = 0usize;
        race.best.clear();
        race.best.resize(k, f64::INFINITY);
        race.arg.clear();
        race.arg.resize(k, 0);

        for (j, &iu) in race.support.iter().enumerate() {
            let i = iu as usize;
            let qi = q.prob(i);
            let pi = p.prob(i);
            for kk in 0..k {
                let e = race.panel[j * k + kk];
                if qi > 0.0 {
                    let v = e / qi;
                    if v < y_best {
                        y_best = v;
                        y_arg = i;
                    }
                }
                if pi > 0.0 {
                    let v = e / pi;
                    if v < race.best[kk] {
                        race.best[kk] = v;
                        race.arg[kk] = i;
                    }
                }
            }
        }

        let xs = race.arg[..k].to_vec();
        let accept = xs.contains(&y_arg);
        GlsOutcome { y: y_arg, xs, accept }
    }

    /// GLS with per-draft proposals (paper App. A.3, Prop. 5) — bit-exact
    /// with [`super::gls::sample_gls_diverse_scalar`].
    pub fn sample_gls_diverse(
        &mut self,
        ps: &[Categorical],
        q: &Categorical,
        rng: &CounterRng,
        slot: u64,
    ) -> GlsOutcome {
        assert!(!ps.is_empty());
        for p in ps {
            assert_eq!(p.len(), q.len(), "alphabet mismatch");
        }
        let n = q.len();
        let k = ps.len();
        let Self { race, cache, .. } = self;
        race.build_support(n, ps.iter().chain(std::iter::once(q)));
        race.fill_panel(rng, slot, k, |r| r as u64, cache);

        let mut y_best = f64::INFINITY;
        let mut y_arg = 0usize;
        race.best.clear();
        race.best.resize(k, f64::INFINITY);
        race.arg.clear();
        race.arg.resize(k, 0);

        for (j, &iu) in race.support.iter().enumerate() {
            let i = iu as usize;
            let qi = q.prob(i);
            for kk in 0..k {
                let pi = ps[kk].prob(i);
                if qi <= 0.0 && pi <= 0.0 {
                    continue;
                }
                let e = race.panel[j * k + kk];
                if qi > 0.0 {
                    let v = e / qi;
                    if v < y_best {
                        y_best = v;
                        y_arg = i;
                    }
                }
                if pi > 0.0 {
                    let v = e / pi;
                    if v < race.best[kk] {
                        race.best[kk] = v;
                        race.arg[kk] = i;
                    }
                }
            }
        }

        let xs = race.arg[..k].to_vec();
        let accept = xs.contains(&y_arg);
        GlsOutcome { y: y_arg, xs, accept }
    }

    /// Bilateral (list-vs-list) GLS — bit-exact with
    /// [`super::gls::sample_gls_bilateral_scalar`]. Panel rows are the
    /// K×M grid lanes; X minima fold over m, Y minima fold over k, both
    /// tracked in one fused pass over the union support.
    pub fn sample_gls_bilateral(
        &mut self,
        p: &Categorical,
        q: &Categorical,
        k_a: usize,
        k_b: usize,
        rng: &CounterRng,
        slot: u64,
    ) -> BilateralOutcome {
        assert_eq!(p.len(), q.len(), "alphabet mismatch");
        assert!(k_a >= 1 && k_b >= 1);
        let Self { race, cache, .. } = self;
        race.build_support(p.len(), [p, q].into_iter());
        let rows = k_a * k_b;
        race.fill_panel(rng, slot, rows, |r| r as u64, cache);

        // best/arg lanes: [0, k_a) for X, [k_a, k_a + k_b) for Y.
        race.best.clear();
        race.best.resize(k_a + k_b, f64::INFINITY);
        race.arg.clear();
        race.arg.resize(k_a + k_b, 0);

        for (j, &iu) in race.support.iter().enumerate() {
            let i = iu as usize;
            let pi = p.prob(i);
            let qi = q.prob(i);
            for k in 0..k_a {
                for m in 0..k_b {
                    let e = race.panel[j * rows + (k * k_b + m)];
                    if pi > 0.0 {
                        let v = e / pi;
                        if v < race.best[k] {
                            race.best[k] = v;
                            race.arg[k] = i;
                        }
                    }
                    if qi > 0.0 {
                        let v = e / qi;
                        if v < race.best[k_a + m] {
                            race.best[k_a + m] = v;
                            race.arg[k_a + m] = i;
                        }
                    }
                }
            }
        }

        let xs = race.arg[..k_a].to_vec();
        let ys = race.arg[k_a..k_a + k_b].to_vec();
        let accept = ys.iter().any(|y| xs.contains(y));
        BilateralOutcome { xs, ys, accept }
    }

    /// Alg. 2 target-token selection — bit-exact with
    /// [`super::gls::select_target_token_scalar`].
    pub fn select_target_token(
        &mut self,
        dists: &[&Categorical],
        active: &[usize],
        rng: &CounterRng,
        slot: u64,
    ) -> usize {
        assert!(!active.is_empty());
        let n = dists[active[0]].len();
        let Self { race, cache, .. } = self;
        race.select_with(n, active, |k| dists[k], rng, slot, cache)
    }

    /// Algorithm 2 block verification (conditional or strong variant) over
    /// the workspace kernel — bit-exact with
    /// [`super::gls::GlsVerifier::verify_block_scalar`].
    pub fn verify_block_gls(
        &mut self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
        strong: bool,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok(), "{:?}", input.validate());
        let k = input.k();
        let l = input.block_len();
        let n = input.target_dists[0][0].len();
        let Self { race, cache, active, all, .. } = self;
        all.clear();
        all.extend(0..k);
        active.clear();
        active.extend(0..k);
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;

        for j in 0..l {
            let participants: &[usize] = if strong { &all[..] } else { &active[..] };
            let yj = race.select_with(
                n,
                participants,
                |kk| &input.target_dists[kk][j],
                rng,
                slot0 + j as u64,
                cache,
            ) as u32;
            tokens.push(yj);
            active.retain(|&kk| input.draft_tokens[kk][j] == yj);
            if active.is_empty() {
                // All drafts diverged: Y_j was still emitted (it is a valid
                // target sample), and the block ends here — Alg. 2 line 12.
                return BlockOutput { tokens, accepted, surviving_draft: None };
            }
            accepted += 1;
        }

        // Full block accepted: emit the bonus token Y_{L+1} (Alg. 2 line 13).
        let participants: &[usize] = if strong { &all[..] } else { &active[..] };
        let bonus = race.select_with(
            n,
            participants,
            |kk| &input.target_dists[kk][l],
            rng,
            slot0 + l as u64,
            cache,
        ) as u32;
        tokens.push(bonus);
        BlockOutput { tokens, accepted, surviving_draft: active.first().copied() }
    }

    /// Daliri et al. single-draft coupled verification on the workspace
    /// kernel — bit-exact with
    /// [`super::daliri::DaliriVerifier::verify_block_scalar`].
    ///
    /// `Y_j` is a lane-0 race on the target alone (the emitted token is a
    /// function of `(q, randomness)` only — that is the strong drafter
    /// invariance); comparing it to the recorded draft token *is* the
    /// `X = Y` check, because the drafter produced its token from the same
    /// exponential cells. When the engine drafted through
    /// [`CouplingWorkspace::sample_race`] on this workspace, those cells
    /// are already in the panel cache and the verification panel is
    /// assembled without re-hashing.
    pub fn verify_block_daliri(
        &mut self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok(), "{:?}", input.validate());
        let l = input.block_len();
        let Self { race, cache, .. } = self;
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;
        for j in 0..l {
            let q = &input.target_dists[0][j];
            let yj = race.select_with(q.len(), &[0], |_| q, rng, slot0 + j as u64, cache) as u32;
            tokens.push(yj);
            if yj != input.draft_tokens[0][j] {
                return BlockOutput { tokens, accepted, surviving_draft: None };
            }
            accepted += 1;
        }
        // Bonus token: lane-0 coupled race on the final target distribution.
        let q = &input.target_dists[0][l];
        let bonus = race.select_with(q.len(), &[0], |_| q, rng, slot0 + l as u64, cache) as u32;
        tokens.push(bonus);
        BlockOutput { tokens, accepted, surviving_draft: Some(0) }
    }

    /// One SpecInfer multi-round rejection step on the residual scratch —
    /// bit-exact with [`super::specinfer::SpecInferVerifier::step`], with
    /// the running residual updated in place over `supp(q)` instead of
    /// cloning/reallocating a `Categorical` per round.
    fn specinfer_step(
        residual: &mut ResidualScratch,
        input: &BlockInput,
        active: &[usize],
        j: usize,
        q: &Categorical,
        rng: &CounterRng,
        slot: u64,
        k_total: usize,
    ) -> (u32, Option<usize>) {
        residual.load(q);
        for (round, &kk) in active.iter().enumerate() {
            let token = input.draft_tokens[kk][j];
            let p_k = &input.draft_dists[kk][j];
            let u = rng.uniform(slot, (k_total + round) as u64, 0);
            let px = p_k.prob(token as usize);
            let rx = residual.mass[token as usize];
            let accept_prob = if px <= 0.0 { 1.0 } else { (rx / px).min(1.0) };
            if u < accept_prob {
                return (token, Some(kk));
            }
            if !residual.subtract_renormalize(p_k) {
                // Residual exhausted: scalar falls back to q's argmax.
                return (super::specinfer::argmax(q) as u32, None);
            }
        }
        let u = rng.uniform(slot, (k_total + active.len()) as u64, 0);
        (residual.sample_inverse(q.len(), u) as u32, None)
    }

    /// SpecInfer recursive multi-round rejection over the workspace —
    /// bit-exact with
    /// [`super::specinfer::SpecInferVerifier::verify_block_scalar`].
    pub fn verify_block_specinfer(
        &mut self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok(), "{:?}", input.validate());
        let k = input.k();
        let l = input.block_len();
        let Self { residual, active, .. } = self;
        active.clear();
        active.extend(0..k);
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;

        for j in 0..l {
            // All active drafts share the accepted prefix ⇒ common target q.
            let q = &input.target_dists[active[0]][j];
            let (tok, from_draft) =
                Self::specinfer_step(residual, input, active, j, q, rng, slot0 + j as u64, k);
            tokens.push(tok);
            match from_draft {
                Some(_) => {
                    active.retain(|&kk| input.draft_tokens[kk][j] == tok);
                    debug_assert!(!active.is_empty());
                    accepted += 1;
                }
                None => return BlockOutput { tokens, accepted, surviving_draft: None },
            }
        }

        // Bonus token from the target distribution after the full prefix.
        let q = &input.target_dists[active[0]][l];
        let u = rng.uniform(slot0 + l as u64, k as u64, 0);
        tokens.push(q.sample_inverse(u) as u32);
        BlockOutput { tokens, accepted, surviving_draft: active.first().copied() }
    }

    /// One SpecTr K-SEQ step: γ-calibration over the sparse union support,
    /// the candidate cascade, and (on reject-all) a draw from the
    /// optimal-transport residual plan built in the residual scratch —
    /// bit-exact with [`super::spectr::SpecTrVerifier::step`] +
    /// [`super::spectr::calibrate`].
    #[allow(clippy::too_many_arguments)]
    fn spectr_step(
        race: &mut RaceScratch,
        residual: &mut ResidualScratch,
        input: &BlockInput,
        active: &[usize],
        j: usize,
        p: &Categorical,
        q: &Categorical,
        rng: &CounterRng,
        slot: u64,
        k_total: usize,
    ) -> (u32, Option<usize>) {
        let n = q.len();
        let kc = active.len();
        race.build_support(n, [p, q].into_iter());

        // γ* = min{γ ∈ [1, K] : c(γ) ≤ γ}, bisected exactly as the scalar
        // `calibrate` — only the s(γ) sum is sparse (bit-identical, see
        // `s_of_gamma_sparse`).
        let feasible = |gamma: f64| {
            let s = s_of_gamma_sparse(&race.support, p, q, gamma);
            super::spectr::c_of_s(s, kc) <= gamma + 1e-12
        };
        let gamma = if kc == 1 || feasible(1.0) {
            1.0
        } else {
            let mut lo = 1.0;
            let mut hi = kc as f64; // always feasible: c ≤ K
            for _ in 0..60 {
                let mid = 0.5 * (lo + hi);
                if feasible(mid) {
                    hi = mid;
                } else {
                    lo = mid;
                }
            }
            hi
        };

        // Candidate cascade: accept x with probability min(1, q(x)/(γ p(x)))
        // — evaluated on demand instead of materializing the dense
        // accept-probability vector the scalar plan carries.
        for (round, &kk) in active.iter().enumerate() {
            let token = input.draft_tokens[kk][j];
            let u = rng.uniform(slot, (k_total + round) as u64, 0);
            let pi = p.prob(token as usize);
            let a = if pi <= 0.0 { 1.0 } else { (q.prob(token as usize) / (gamma * pi)).min(1.0) };
            if u < a {
                return (token, Some(kk));
            }
        }

        // All candidates rejected: draw from the K-SEQ transport residual
        // res(y) ∝ q(y) − c·min(p(y), q(y)/γ), assembled in the scratch.
        let s = s_of_gamma_sparse(&race.support, p, q, gamma);
        let c = super::spectr::c_of_s(s, kc);
        residual.reset(n);
        let mut total = 0.0;
        for &i in &race.support {
            let iu = i as usize;
            let w = (q.prob(iu) - c * p.prob(iu).min(q.prob(iu) / gamma)).max(0.0);
            if w > 0.0 {
                residual.support.push(i);
                residual.mass[iu] = w;
            }
            total += w;
        }
        let u = rng.uniform(slot, (k_total + kc) as u64, 0);
        if total > 1e-12 {
            // Categorical::new's exact normalization branch.
            if (total - 1.0).abs() > 1e-12 {
                for &i in &residual.support {
                    residual.mass[i as usize] /= total;
                }
            }
            (residual.sample_inverse(n, u) as u32, None)
        } else {
            (q.sample_inverse(u) as u32, None)
        }
    }

    /// SpecTr K-SEQ verification over the workspace — bit-exact with
    /// [`super::spectr::SpecTrVerifier::verify_block_scalar`].
    pub fn verify_block_spectr(
        &mut self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok(), "{:?}", input.validate());
        let k = input.k();
        let l = input.block_len();
        let Self { race, residual, active, .. } = self;
        active.clear();
        active.extend(0..k);
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;

        for j in 0..l {
            let q = &input.target_dists[active[0]][j];
            // K-SEQ assumes identical proposals: use the first active
            // draft's p (the engine only selects SpecTr for i.i.d. drafts).
            let p = &input.draft_dists[active[0]][j];
            let (tok, from) = Self::spectr_step(
                race,
                residual,
                input,
                active,
                j,
                p,
                q,
                rng,
                slot0 + j as u64,
                k,
            );
            tokens.push(tok);
            match from {
                Some(_) => {
                    active.retain(|&kk| input.draft_tokens[kk][j] == tok);
                    accepted += 1;
                }
                None => return BlockOutput { tokens, accepted, surviving_draft: None },
            }
        }
        let q = &input.target_dists[active[0]][l];
        let u = rng.uniform(slot0 + l as u64, k as u64, 0);
        tokens.push(q.sample_inverse(u) as u32);
        BlockOutput { tokens, accepted, surviving_draft: active.first().copied() }
    }

    /// Classic single-draft rejection sampling (the TR baseline) on the
    /// residual scratch — bit-exact with
    /// [`super::single_draft::SingleDraftVerifier::verify_block_scalar`].
    /// On rejection, the residual `(q − p)₊` is built and renormalized in
    /// place over `supp(q)` instead of materializing a `Categorical`
    /// (dense residual + `Categorical::new` on the scalar path), so the TR
    /// baseline shares the kernel residual machinery with
    /// SpecInfer/SpecTr.
    pub fn verify_block_single_draft(
        &mut self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok(), "{:?}", input.validate());
        let l = input.block_len();
        let n = input.target_dists[0][0].len();
        let Self { residual, .. } = self;
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;
        for j in 0..l {
            let p = &input.draft_dists[0][j];
            let q = &input.target_dists[0][j];
            let token = input.draft_tokens[0][j];
            let slot = slot0 + j as u64;
            let u = rng.uniform(slot, 1, 0);
            let px = p.prob(token as usize);
            let qx = q.prob(token as usize);
            let accept = if px <= 0.0 { true } else { u < (qx / px).min(1.0) };
            if accept {
                tokens.push(token);
                accepted += 1;
                continue;
            }
            let u2 = rng.uniform(slot, 2, 0);
            residual.load(q);
            let tok = if residual.subtract_renormalize(p) {
                residual.sample_inverse(n, u2) as u32
            } else {
                // (q − p)₊ exhausted: the scalar path falls back to q.
                q.sample_inverse(u2) as u32
            };
            tokens.push(tok);
            return BlockOutput { tokens, accepted, surviving_draft: None };
        }
        let q = &input.target_dists[0][l];
        let u = rng.uniform(slot0 + l as u64, 1, 0);
        tokens.push(q.sample_inverse(u) as u32);
        BlockOutput { tokens, accepted, surviving_draft: Some(0) }
    }
}

/// Fill `panel` with an **item-major** `items.len() × rows` block of
/// Exp(1) variates over a *sparse* item set: entry `[j * rows + r]` is
/// the variate at RNG coordinates `(slot, lane_of(r), items[j])` — the
/// layout a j-outer/row-inner race reads as contiguous columns. The
/// per-(slot, lane) prefix is hoisted once per row ([`CounterRng::lane`]),
/// so each variate costs a single mix round — the same trick every race in
/// [`CouplingWorkspace`] uses, exposed for other Gumbel-race consumers (the
/// compression codec races over its usable-weight support with it).
/// Bit-exact with calling `rng.exponential(slot, lane_of(r), items[j])`
/// per entry — evaluation order is free because each variate is a pure
/// function of its coordinates.
pub fn fill_exp_panel(
    panel: &mut Vec<f64>,
    rng: &CounterRng,
    slot: u64,
    rows: usize,
    items: &[u32],
    lane_of: impl Fn(usize) -> u64,
) {
    panel.clear();
    panel.reserve(rows * items.len());
    let mut lanes = [crate::stats::rng::CounterLane::default(); 16];
    if rows <= lanes.len() {
        // Common case (rows = K ≤ 16): hoist the lanes into a stack
        // array and emit in write order — sequential stores, no heap.
        for (r, lane) in lanes.iter_mut().enumerate().take(rows) {
            *lane = rng.lane(slot, lane_of(r));
        }
        for &i in items {
            for lane in lanes.iter().take(rows) {
                panel.push(lane.exponential(i as u64));
            }
        }
    } else {
        // Arbitrary row counts: fill column-by-column re-deriving lanes
        // per row (rows > 16 is outside every current caller's shape).
        panel.resize(rows * items.len(), 0.0);
        for r in 0..rows {
            let lane = rng.lane(slot, lane_of(r));
            for (j, &i) in items.iter().enumerate() {
                panel[j * rows + r] = lane.exponential(i as u64);
            }
        }
    }
}

thread_local! {
    static WORKSPACE: RefCell<CouplingWorkspace> = RefCell::new(CouplingWorkspace::new());
}

/// Run `f` with this thread's coupling workspace. The thread-local keeps
/// the public free-function API of [`super::gls`] (and the ported
/// baselines' `verify_block` impls) allocation-free on the hot path and
/// plays well with the engine's parallel stepping: each verification
/// thread warms its own scratch once and reuses it forever, and the
/// engine's draft phase (main thread) shares its panel cache with the
/// serial verification path.
pub fn with_workspace<R>(f: impl FnOnce(&mut CouplingWorkspace) -> R) -> R {
    WORKSPACE.with(|w| f(&mut w.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::daliri::DaliriVerifier;
    use crate::spec::gls;
    use crate::spec::specinfer::SpecInferVerifier;
    use crate::spec::spectr::SpecTrVerifier;
    use crate::stats::rng::XorShift128;
    use crate::testkit;

    #[test]
    fn fill_exp_panel_matches_unhoisted_coordinates() {
        let rng = CounterRng::new(0xFE11);
        let items: Vec<u32> = vec![0, 3, 7, 64, 1000];
        let mut panel = Vec::new();
        // Item-major layout: entry [j * rows + r].
        fill_exp_panel(&mut panel, &rng, 42, 3, &items, |r| 10 + r as u64);
        assert_eq!(panel.len(), 3 * items.len());
        for r in 0..3 {
            for (j, &i) in items.iter().enumerate() {
                let want = rng.exponential(42, 10 + r as u64, i as u64);
                assert_eq!(panel[j * 3 + r].to_bits(), want.to_bits());
            }
        }
        // More rows than the stack-hoisted lane array (the fallback
        // branch) must produce the identical layout and bits.
        let rows = 33;
        fill_exp_panel(&mut panel, &rng, 7, rows, &items, |r| r as u64);
        assert_eq!(panel.len(), rows * items.len());
        for r in 0..rows {
            for (j, &i) in items.iter().enumerate() {
                let want = rng.exponential(7, r as u64, i as u64);
                assert_eq!(panel[j * rows + r].to_bits(), want.to_bits());
            }
        }
        // Refill reuses the buffer and replaces the contents.
        fill_exp_panel(&mut panel, &rng, 42, 1, &items[..2], |_| 0);
        assert_eq!(panel.len(), 2);
    }

    #[test]
    fn support_union_is_sorted_and_exact() {
        let p = Categorical::new(vec![0.0, 0.5, 0.5, 0.0, 0.0]);
        let q = Categorical::new(vec![0.5, 0.0, 0.0, 0.0, 0.5]);
        let mut race = RaceScratch::new();
        race.build_support(5, [&p, &q].into_iter());
        assert_eq!(race.support, vec![0, 1, 2, 4]);
    }

    #[test]
    fn support_handles_alphabets_beyond_one_word() {
        // > 64 items exercises the multi-word bitset path.
        let mut gen = XorShift128::new(9);
        let p = testkit::gen_sparse_categorical(&mut gen, 150, 7);
        let q = testkit::gen_sparse_categorical(&mut gen, 150, 5);
        let mut race = RaceScratch::new();
        race.build_support(150, [&p, &q].into_iter());
        let expect: Vec<u32> = (0..150u32)
            .filter(|&i| p.prob(i as usize) > 0.0 || q.prob(i as usize) > 0.0)
            .collect();
        assert_eq!(race.support, expect);
    }

    #[test]
    fn support_union_mixes_cached_and_dense_lists() {
        // q: top-k truncated (cached support); p: dense constructor (no
        // cache) — the union must fall back to scanning and stay exact.
        let logits: Vec<f32> = (0..100).map(|i| (i % 13) as f32).collect();
        let q = Categorical::from_logits(&logits, 1.0, Some(10));
        assert!(q.support().is_some());
        let mut masses = vec![0.0; 100];
        masses[3] = 0.7;
        masses[98] = 0.3;
        let p = Categorical::new(masses);
        assert!(p.support().is_none());
        let mut race = RaceScratch::new();
        race.build_support(100, [&p, &q].into_iter());
        let expect: Vec<u32> = (0..100u32)
            .filter(|&i| p.prob(i as usize) > 0.0 || q.prob(i as usize) > 0.0)
            .collect();
        assert_eq!(race.support, expect);

        // Both cached: the fast path must produce the same union.
        let q2 = Categorical::from_logits(&logits, 1.0, Some(7));
        race.build_support(100, [&q, &q2].into_iter());
        let expect: Vec<u32> = (0..100u32)
            .filter(|&i| q.prob(i as usize) > 0.0 || q2.prob(i as usize) > 0.0)
            .collect();
        assert_eq!(race.support, expect);
    }

    #[test]
    fn panel_entries_match_counter_rng() {
        let p = Categorical::new(vec![0.25; 4]);
        let rng = CounterRng::new(3);
        let mut race = RaceScratch::new();
        race.build_support(4, std::iter::once(&p));
        race.fill_panel(&rng, 11, 3, |r| r as u64, &PanelCache::new());
        // Item-major: entry [j * rows + r].
        for k in 0..3u64 {
            for i in 0..4u64 {
                assert_eq!(
                    race.panel[(i as usize) * 3 + k as usize],
                    rng.exponential(11, k, i)
                );
            }
        }
    }

    #[test]
    fn workspace_reuse_does_not_change_outcomes() {
        // The same workspace must give identical results before and after
        // being used for unrelated races (stale scratch must not leak).
        let mut gen = XorShift128::new(21);
        let p = testkit::gen_categorical(&mut gen, 12);
        let q = testkit::gen_categorical(&mut gen, 12);
        let rng = CounterRng::new(5);
        let mut ws = CouplingWorkspace::new();
        let fresh = ws.sample_gls(&p, &q, 4, &rng, 9);
        // Pollute the scratch with differently-shaped races.
        let small = testkit::gen_sparse_categorical(&mut gen, 70, 3);
        ws.sample_gls(&small, &small, 9, &rng, 1);
        ws.sample_gls_bilateral(&p, &q, 2, 3, &rng, 2);
        let again = ws.sample_gls(&p, &q, 4, &rng, 9);
        assert_eq!(fresh, again);
    }

    #[test]
    fn kernel_matches_scalar_smoke() {
        // Full parity lives in tests/kernel_parity.rs; this is the in-module
        // canary so `cargo test --lib` catches drift too.
        let mut gen = XorShift128::new(33);
        let mut ws = CouplingWorkspace::new();
        for seed in 0..20u64 {
            let p = testkit::gen_categorical(&mut gen, 9);
            let q = testkit::gen_categorical(&mut gen, 9);
            let rng = CounterRng::new(seed);
            assert_eq!(
                ws.sample_gls(&p, &q, 3, &rng, seed),
                gls::sample_gls_scalar(&p, &q, 3, &rng, seed)
            );
        }
    }

    #[test]
    fn workspace_sample_race_matches_categorical() {
        let mut gen = XorShift128::new(61);
        let mut ws = CouplingWorkspace::new();
        for case in 0..40u64 {
            let d = match case % 3 {
                0 => testkit::gen_categorical(&mut gen, 30),
                1 => testkit::gen_sparse_categorical(&mut gen, 90, 6),
                _ => {
                    let logits: Vec<f32> =
                        (0..120).map(|_| (gen.next_f64() * 5.0) as f32).collect();
                    Categorical::from_logits(&logits, 1.0, Some(9))
                }
            };
            let rng = CounterRng::new(700 + case);
            for draft in 0..3u64 {
                assert_eq!(
                    ws.sample_race(&d, &rng, case, draft),
                    d.sample_race(&rng, case, draft),
                    "case {case} draft {draft}"
                );
            }
        }
    }

    #[test]
    fn panel_cache_reuse_is_bit_exact() {
        // Draft through the workspace (populating the cache at the exact
        // verification coordinates), then verify on the same workspace: the
        // warm path must equal a cold workspace AND the scalar reference.
        let mut gen = XorShift128::new(77);
        for seed in 0..15u64 {
            let n = 40;
            let l = 4;
            let p: Vec<Categorical> =
                (0..l).map(|_| testkit::gen_sparse_categorical(&mut gen, n, 8)).collect();
            let q: Vec<Categorical> =
                (0..=l).map(|_| testkit::gen_sparse_categorical(&mut gen, n, 8)).collect();
            let rng = CounterRng::new(seed ^ 0xCAFE);
            let mut warm = CouplingWorkspace::new();
            let draft_tokens: Vec<u32> = (0..l)
                .map(|j| warm.sample_race(&p[j], &rng, j as u64, 0) as u32)
                .collect();
            let input = BlockInput {
                draft_tokens: vec![draft_tokens].into(),
                draft_dists: vec![p.clone()],
                target_dists: vec![q.clone()],
            };
            let hot = warm.verify_block_daliri(&input, &rng, 0);
            let cold = CouplingWorkspace::new().verify_block_daliri(&input, &rng, 0);
            let scalar = DaliriVerifier::new().verify_block_scalar(&input, &rng, 0);
            assert_eq!(hot, cold, "seed {seed}: cache changed the outcome");
            assert_eq!(hot, scalar, "seed {seed}: kernel/scalar divergence");
            // GLS verification at the same coordinates also merges from the
            // cache — must stay bit-exact too.
            let hot_gls = warm.verify_block_gls(&input, &rng, 0, false);
            let cold_gls = CouplingWorkspace::new().verify_block_gls(&input, &rng, 0, false);
            assert_eq!(hot_gls, cold_gls, "seed {seed}: gls cache divergence");
        }
    }

    #[test]
    fn panel_cache_collision_overwrites_stay_exact() {
        // Record far more rows than slots so keys collide and overwrite
        // each other, then race: overwritten/stale entries must never
        // corrupt outcomes, and the overwrite counter must see the leak.
        let mut gen = XorShift128::new(91);
        let d = testkit::gen_categorical(&mut gen, 25);
        let rng = CounterRng::new(4);
        let mut ws = CouplingWorkspace::new();
        for slot in 0..(3 * PANEL_CACHE_SLOTS as u64) {
            assert_eq!(ws.sample_race(&d, &rng, slot, 1), d.sample_race(&rng, slot, 1));
        }
        let p = testkit::gen_categorical(&mut gen, 25);
        assert_eq!(
            ws.sample_gls(&p, &d, 2, &rng, 5),
            gls::sample_gls_scalar(&p, &d, 2, &rng, 5)
        );
        let stats = ws.drain_cache_stats();
        assert!(
            stats.overwrites > 0,
            "3× slot count of distinct keys must collide somewhere"
        );
        // Draining resets every counter.
        assert_eq!(ws.drain_cache_stats(), PanelCacheStats::default());
    }

    #[test]
    fn rows_longer_than_a_slot_truncate_and_stay_exact() {
        // A dense row wider than PANEL_CACHE_SLOT_CAP memoizes only its
        // ascending prefix; the verify-side merge must recompute the tail
        // bit-exactly (truncation is invisible except as saved work).
        let mut gen = XorShift128::new(0x7A1);
        let n = 3 * PANEL_CACHE_SLOT_CAP;
        let d = testkit::gen_categorical(&mut gen, n);
        let rng = CounterRng::new(19);
        let mut ws = CouplingWorkspace::new();
        assert_eq!(ws.sample_race(&d, &rng, 0, 0), d.sample_race(&rng, 0, 0));
        let p = testkit::gen_categorical(&mut gen, n);
        assert_eq!(
            ws.sample_gls(&p, &d, 1, &rng, 0),
            gls::sample_gls_scalar(&p, &d, 1, &rng, 0)
        );
        // The truncated row still counts as a (partial) hit.
        assert!(ws.panel_cache_hits() > 0);
    }

    #[test]
    fn ported_verifiers_match_scalar_smoke() {
        // In-module canary for the ported baselines; the full randomized
        // grids live in tests/kernel_parity.rs.
        let mut gen = XorShift128::new(0x90);
        let mut ws = CouplingWorkspace::new();
        for seed in 0..15u64 {
            let n = 12;
            let k = 3;
            let l = 3;
            let p: Vec<Categorical> =
                (0..l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let q: Vec<Categorical> =
                (0..=l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let rng = CounterRng::new(seed * 13 + 1);
            let mut draft_tokens = vec![Vec::with_capacity(l); k];
            for kk in 0..k {
                for j in 0..l {
                    draft_tokens[kk].push(p[j].sample_race(&rng, j as u64, kk as u64) as u32);
                }
            }
            let input = BlockInput {
                draft_tokens: draft_tokens.into(),
                draft_dists: vec![p.clone(); k],
                target_dists: vec![q.clone(); k],
            };
            assert_eq!(
                ws.verify_block_spectr(&input, &rng, seed),
                SpecTrVerifier::new().verify_block_scalar(&input, &rng, seed),
                "spectr seed {seed}"
            );
            assert_eq!(
                ws.verify_block_specinfer(&input, &rng, seed),
                SpecInferVerifier::new().verify_block_scalar(&input, &rng, seed),
                "specinfer seed {seed}"
            );
            assert_eq!(
                ws.verify_block_daliri(&input, &rng, seed),
                DaliriVerifier::new().verify_block_scalar(&input, &rng, seed),
                "daliri seed {seed}"
            );
        }
    }

    #[test]
    fn panel_slice_record_race_matches_categorical() {
        // Step 1 of the handoff protocol must be bit-exact with the plain
        // race at the same coordinates.
        let mut gen = XorShift128::new(0x511CE);
        for case in 0..40u64 {
            let d = match case % 3 {
                0 => testkit::gen_categorical(&mut gen, 25),
                1 => testkit::gen_sparse_categorical(&mut gen, 80, 5),
                _ => {
                    let logits: Vec<f32> =
                        (0..100).map(|_| (gen.next_f64() * 5.0) as f32).collect();
                    Categorical::from_logits(&logits, 1.0, Some(8))
                }
            };
            let rng = CounterRng::new(3100 + case);
            let mut slice = PanelSlice::new();
            for draft in 0..3u64 {
                assert_eq!(
                    slice.record_race(&d, &rng, case, draft),
                    d.sample_race(&rng, case, draft),
                    "case {case} draft {draft}"
                );
            }
            assert_eq!(slice.len(), 3);
        }
    }

    #[test]
    fn panel_slice_handoff_is_bit_exact_and_counts_hits() {
        // Record on a "drafting" slice, adopt into a *fresh* workspace (the
        // worker-thread scenario), verify: outcomes must equal a cold
        // workspace and the scalar reference, and the cache-hit counter
        // must show the adopted rows actually fired.
        let mut gen = XorShift128::new(0xAD0B);
        for seed in 0..15u64 {
            let n = 50;
            let l = 4;
            let p: Vec<Categorical> =
                (0..l).map(|_| testkit::gen_sparse_categorical(&mut gen, n, 7)).collect();
            let q: Vec<Categorical> =
                (0..=l).map(|_| testkit::gen_sparse_categorical(&mut gen, n, 7)).collect();
            let rng = CounterRng::new(seed ^ 0x5EED);
            let mut slice = PanelSlice::new();
            let draft_tokens: Vec<u32> = (0..l)
                .map(|j| slice.record_race(&p[j], &rng, j as u64, 0) as u32)
                .collect();
            let input = BlockInput {
                draft_tokens: vec![draft_tokens].into(),
                draft_dists: vec![p.clone()],
                target_dists: vec![q.clone()],
            };
            let mut worker_ws = CouplingWorkspace::new();
            worker_ws.adopt_panel_slice(slice);
            let adopted = worker_ws.verify_block_daliri(&input, &rng, 0);
            assert!(
                worker_ws.panel_cache_hits() > 0,
                "seed {seed}: adopted panel rows never hit"
            );
            let cold = CouplingWorkspace::new().verify_block_daliri(&input, &rng, 0);
            let scalar = DaliriVerifier::new().verify_block_scalar(&input, &rng, 0);
            assert_eq!(adopted, cold, "seed {seed}: handoff changed the outcome");
            assert_eq!(adopted, scalar, "seed {seed}: handoff/scalar divergence");
            assert!(worker_ws.drain_panel_cache_hits() > 0);
            assert_eq!(worker_ws.panel_cache_hits(), 0, "drain must reset");
        }
    }

    #[test]
    fn adopting_oversized_slice_keeps_memory_bounded_and_stays_exact() {
        // Satellite regression for the old `ensure_capacity` ratchet: a
        // slice with more rows than the cache has slots — and rows wider
        // than a slot — must neither grow the cache's backing storage nor
        // change any outcome. Colliding rows overwrite (the leak), missing
        // rows recompute; memory stays at its construction-time footprint.
        let mut gen = XorShift128::new(0xB16);
        let d = testkit::gen_sparse_categorical(&mut gen, 60, 6);
        let wide = testkit::gen_categorical(&mut gen, 2 * PANEL_CACHE_SLOT_CAP);
        let rng = CounterRng::new(88);
        let mut slice = PanelSlice::new();
        let rows_n = PANEL_CACHE_SLOTS + 40;
        let toks: Vec<usize> =
            (0..rows_n as u64).map(|slot| slice.record_race(&d, &rng, slot, 0)).collect();
        // A handful of oversized rows ride along at disjoint slots.
        for slot in 0..8u64 {
            slice.record_race(&wide, &rng, 1_000 + slot, 3);
        }
        let mut ws = CouplingWorkspace::new();
        let keys0 = ws.cache.keys.len();
        let (items0, values0) = (ws.cache.items.capacity(), ws.cache.values.capacity());
        ws.adopt_panel_slice(slice);
        // Re-race every recorded coordinate: identical tokens whether the
        // row survived adoption (hit) or was overwritten by a colliding
        // later row (recomputed miss).
        for (slot, &tok) in toks.iter().enumerate() {
            assert_eq!(ws.select_target_token(&[&d], &[0], &rng, slot as u64), tok);
        }
        let stats = ws.drain_cache_stats();
        assert!(stats.hits > 0, "surviving adopted rows must hit");
        assert!(stats.overwrites > 0, "more rows than slots must overwrite");
        // The bounded-memory contract: adoption never grows the cache.
        assert_eq!(ws.cache.keys.len(), keys0);
        assert_eq!(ws.cache.lens.len(), keys0);
        assert_eq!(ws.cache.items.capacity(), items0);
        assert_eq!(ws.cache.values.capacity(), values0);
        assert_eq!(ws.cache.items.len(), PANEL_CACHE_SLOTS * PANEL_CACHE_SLOT_CAP);
        // Processing small blocks afterwards stays exact and bounded too.
        let p = testkit::gen_sparse_categorical(&mut gen, 60, 5);
        assert_eq!(
            ws.sample_gls(&p, &d, 2, &rng, 7),
            gls::sample_gls_scalar(&p, &d, 2, &rng, 7)
        );
        assert_eq!(ws.cache.values.capacity(), values0);
    }

    #[test]
    fn slice_recycling_round_trip_is_bit_exact_and_reuses_buffers() {
        // Step 5 of the handoff protocol: lease → record → adopt → return
        // → lease again. Recycled-buffer recording must stay bit-exact
        // with a fresh slice AND with the plain race, and the second lease
        // must actually come from the return channel with spare capacity.
        let mut gen = XorShift128::new(0x4EC1);
        let mut recycler = SliceRecycler::new();
        let mut ws = CouplingWorkspace::new();
        let rng = CounterRng::new(0x715);
        let l = 5usize;
        for round in 0..4u64 {
            let p: Vec<Categorical> =
                (0..l).map(|_| testkit::gen_sparse_categorical(&mut gen, 60, 8)).collect();
            let mut slice = recycler.lease();
            if round > 0 {
                assert!(
                    slice.spare_len() >= l,
                    "round {round}: leased slice carries no recycled buffers"
                );
            }
            for (j, d) in p.iter().enumerate() {
                let slot = round * l as u64 + j as u64;
                let tok = slice.record_race(d, &rng, slot, 0);
                assert_eq!(tok, d.sample_race(&rng, slot, 0), "round {round} slot {slot}");
            }
            assert_eq!(slice.len(), l);
            let spent = ws.adopt_panel_slice(slice);
            assert!(spent.is_empty(), "adopt must consume every recorded row");
            assert_eq!(spent.spare_len(), l, "one displaced buffer pair per adopted row");
            recycler.return_sender().send(spent).expect("receiver alive");
        }
        assert_eq!(recycler.drain_recycled(), 3, "rounds 1..=3 lease recycled slices");
        assert_eq!(recycler.drain_recycled(), 0, "drain must reset");
    }

    #[test]
    fn slice_bank_prefers_cross_engine_donors_and_counts() {
        let bank = SliceBank::new();
        assert!(bank.is_empty());
        assert!(bank.lease(1).is_none());
        bank.deposit(1, PanelSlice::new());
        bank.deposit(2, PanelSlice::new());
        // Engine 1 leases: must take engine 2's deposit first.
        assert!(bank.lease(1).is_some());
        assert_eq!(bank.cross_engine_reuses(), 1);
        // Only its own deposit remains — still leasable, not cross.
        assert!(bank.lease(1).is_some());
        assert_eq!(bank.cross_engine_reuses(), 1);
        assert!(bank.is_empty());
    }

    #[test]
    fn recycler_surplus_flows_through_the_bank_bit_exactly() {
        // An engine's unclaimed returns drain into the bank; another
        // engine leases them and records bit-exactly on the used buffers.
        let mut gen = XorShift128::new(0xBA2C);
        let mut donor = SliceRecycler::new();
        let bank = SliceBank::new();
        let rng = CounterRng::new(0x91);
        let d = testkit::gen_sparse_categorical(&mut gen, 60, 8);
        let mut slice = donor.lease();
        let tok = slice.record_race(&d, &rng, 0, 0);
        assert_eq!(tok, d.sample_race(&rng, 0, 0));
        let mut ws = CouplingWorkspace::new();
        let spent = ws.adopt_panel_slice(slice);
        donor.return_sender().send(spent).expect("receiver alive");
        // The donor engine never leases again; its surplus moves banks.
        let surplus = donor.drain_surplus();
        assert_eq!(surplus.len(), 1);
        assert!(surplus[0].is_empty(), "drained surplus is demoted to spares");
        for s in surplus {
            bank.deposit(7, s);
        }
        // A different engine leases the banked slice and records on it.
        let mut leased = bank.lease(8).expect("banked slice available");
        assert_eq!(bank.cross_engine_reuses(), 1);
        assert!(leased.spare_len() > 0, "banked slice carries recycled buffers");
        let tok2 = leased.record_race(&d, &rng, 1, 0);
        assert_eq!(tok2, d.sample_race(&rng, 1, 0), "banked buffers stay bit-exact");
    }

    #[test]
    fn verify_block_kind_matches_direct_methods() {
        let mut gen = XorShift128::new(0xD15);
        for seed in 0..10u64 {
            let (n, k, l) = (14, 3, 3);
            let p: Vec<Categorical> =
                (0..l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let q: Vec<Categorical> =
                (0..=l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let rng = CounterRng::new(41 + seed);
            let mut draft_tokens = vec![Vec::with_capacity(l); k];
            for kk in 0..k {
                for j in 0..l {
                    draft_tokens[kk].push(p[j].sample_race(&rng, j as u64, kk as u64) as u32);
                }
            }
            let input = BlockInput {
                draft_tokens: draft_tokens.into(),
                draft_dists: vec![p.clone(); k],
                target_dists: vec![q.clone(); k],
            };
            let mut a = CouplingWorkspace::new();
            let mut b = CouplingWorkspace::new();
            for &kind in VerifierKind::all() {
                let via_kind = a.verify_block_kind(kind, &input, &rng, seed);
                let direct = match kind {
                    VerifierKind::Gls => b.verify_block_gls(&input, &rng, seed, false),
                    VerifierKind::GlsStrong => b.verify_block_gls(&input, &rng, seed, true),
                    VerifierKind::SpecInfer => b.verify_block_specinfer(&input, &rng, seed),
                    VerifierKind::SpecTr => b.verify_block_spectr(&input, &rng, seed),
                    VerifierKind::SingleDraft => {
                        b.verify_block_single_draft(&input, &rng, seed)
                    }
                    VerifierKind::Daliri => b.verify_block_daliri(&input, &rng, seed),
                    VerifierKind::FaultInjection => {
                        unreachable!("test-only kind is not in VerifierKind::all()")
                    }
                };
                assert_eq!(via_kind, direct, "seed {seed} kind {kind:?}");
            }
        }
    }

    #[test]
    fn single_draft_kernel_matches_scalar_smoke() {
        // Full grid in tests/kernel_parity.rs; in-module canary.
        use crate::spec::single_draft::SingleDraftVerifier;
        let mut gen = XorShift128::new(0x1D);
        let mut ws = CouplingWorkspace::new();
        for seed in 0..25u64 {
            let n = 16;
            let l = 4;
            let p: Vec<Categorical> = (0..l)
                .map(|_| match seed % 3 {
                    0 => testkit::gen_categorical(&mut gen, n),
                    1 => testkit::gen_sparse_categorical(&mut gen, n, 4),
                    _ => Categorical::delta(n, (seed as usize * 5) % n),
                })
                .collect();
            let q: Vec<Categorical> = (0..=l)
                .map(|_| testkit::gen_sparse_categorical(&mut gen, n, 6))
                .collect();
            let rng = CounterRng::new(seed * 7 + 2);
            let draft_tokens: Vec<u32> =
                (0..l).map(|j| p[j].sample_race(&rng, j as u64, 0) as u32).collect();
            let input = BlockInput {
                draft_tokens: vec![draft_tokens].into(),
                draft_dists: vec![p],
                target_dists: vec![q],
            };
            assert_eq!(
                ws.verify_block_single_draft(&input, &rng, seed),
                SingleDraftVerifier::new().verify_block_scalar(&input, &rng, seed),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn residual_scratch_matches_categorical_residual() {
        let mut gen = XorShift128::new(0x4E5);
        let mut scratch = ResidualScratch::new();
        for case in 0..30 {
            let n = 20;
            let q = testkit::gen_categorical(&mut gen, n);
            let p = testkit::gen_sparse_categorical(&mut gen, n, 5);
            scratch.load(&q);
            let alive = scratch.subtract_renormalize(&p);
            match q.residual(&p) {
                Some(r) => {
                    assert!(alive, "case {case}");
                    for i in 0..n {
                        assert_eq!(scratch.mass[i], r.prob(i), "case {case} item {i}");
                    }
                    for u in [0.001, 0.3, 0.5, 0.77, 0.9999] {
                        assert_eq!(scratch.sample_inverse(n, u), r.sample_inverse(u));
                    }
                }
                None => assert!(!alive, "case {case}"),
            }
        }
    }
}
