//! SpecTr verification (Sun et al., NeurIPS 2023): the *k-sequential
//! selection* (K-SEQ) scheme derived from optimal-transport relaxations.
//!
//! Given K i.i.d. candidates from a single draft distribution p and target
//! q, each candidate x is tested in turn with acceptance probability
//! `min(1, q(x) / (γ p(x)))` where `γ ∈ [1, K]` is calibrated by bisection
//! so that the combined accept-or-residual output marginal is *exactly* q:
//!
//! With `s(γ) = Σ_x min(p(x), q(x)/γ)` (per-candidate acceptance mass) and
//! `c(γ) = (1 − (1−s)^K) / s` (expected boost from K tries), validity
//! requires `c(γ) ≤ γ`; the smallest such γ maximizes acceptance. The
//! residual distribution is `res(y) ∝ q(y) − c·min(p(y), q(y)/γ)`, and the
//! identity `c·s = 1 − (1−s)^K` makes the marginal exactly q (verified by
//! a chi-square test below).
//!
//! K-SEQ is specialized to **identically distributed** proposals — the paper
//! (§4.3) notes it cannot be used in the diverse-drafts experiment.

use crate::stats::rng::CounterRng;

use super::kernel::with_workspace;
use super::types::{
    BlockInput, BlockOutput, BlockVerifier, Categorical, Invariance, VerifierKind,
};

/// Calibrated K-SEQ parameters for one (p, q, K) instance.
#[derive(Clone, Debug)]
pub struct KSeqPlan {
    pub gamma: f64,
    /// Per-candidate acceptance mass `s(γ)`.
    pub s: f64,
    /// Boost factor `c(γ) = (1-(1-s)^K)/s`.
    pub c: f64,
    /// Residual distribution (None iff residual mass ≈ 0).
    pub residual: Option<Categorical>,
    /// Acceptance probabilities per symbol: `min(1, q(x)/(γ p(x)))`.
    pub accept_prob: Vec<f64>,
}

fn s_of_gamma(p: &Categorical, q: &Categorical, gamma: f64) -> f64 {
    p.probs()
        .iter()
        .zip(q.probs())
        .map(|(&pi, &qi)| pi.min(qi / gamma))
        .sum()
}

/// Boost factor `c(γ) = (1-(1-s)^K)/s`. Shared with the workspace kernel's
/// sparse calibration (`spec::kernel`), which must apply the identical
/// arithmetic to stay bit-exact with [`calibrate`].
pub(crate) fn c_of_s(s: f64, k: usize) -> f64 {
    if s <= 0.0 {
        return k as f64; // lim_{s->0} (1-(1-s)^K)/s = K
    }
    (1.0 - (1.0 - s).powi(k as i32)) / s
}

/// Calibrate γ* = min{γ ∈ [1, K] : c(γ) ≤ γ} by bisection.
pub fn calibrate(p: &Categorical, q: &Categorical, k: usize) -> KSeqPlan {
    assert_eq!(p.len(), q.len());
    assert!(k >= 1);
    let feasible = |gamma: f64| {
        let s = s_of_gamma(p, q, gamma);
        c_of_s(s, k) <= gamma + 1e-12
    };
    let gamma = if k == 1 || feasible(1.0) {
        1.0
    } else {
        let mut lo = 1.0;
        let mut hi = k as f64; // always feasible: c ≤ K
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if feasible(mid) {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        hi
    };
    let s = s_of_gamma(p, q, gamma);
    let c = c_of_s(s, k);
    let accept_prob: Vec<f64> = p
        .probs()
        .iter()
        .zip(q.probs())
        .map(|(&pi, &qi)| if pi <= 0.0 { 1.0 } else { (qi / (gamma * pi)).min(1.0) })
        .collect();
    let res_mass: Vec<f64> = p
        .probs()
        .iter()
        .zip(q.probs())
        .map(|(&pi, &qi)| (qi - c * pi.min(qi / gamma)).max(0.0))
        .collect();
    let total: f64 = res_mass.iter().sum();
    let residual = if total > 1e-12 { Some(Categorical::new(res_mass)) } else { None };
    KSeqPlan { gamma, s, c, residual, accept_prob }
}

#[derive(Clone, Debug, Default)]
pub struct SpecTrVerifier;

impl SpecTrVerifier {
    pub fn new() -> Self {
        Self
    }

    /// One K-SEQ step over the candidate tokens (i.i.d. from p). Returns
    /// the chosen token and the index of the accepted candidate, if any.
    pub fn step(
        &self,
        p: &Categorical,
        q: &Categorical,
        candidates: &[(usize, u32)],
        rng: &CounterRng,
        slot: u64,
        k_total: usize,
    ) -> (u32, Option<usize>) {
        let plan = calibrate(p, q, candidates.len());
        for (round, &(k, token)) in candidates.iter().enumerate() {
            let u = rng.uniform(slot, (k_total + round) as u64, 0);
            if u < plan.accept_prob[token as usize] {
                return (token, Some(k));
            }
        }
        let u = rng.uniform(slot, (k_total + candidates.len()) as u64, 0);
        match &plan.residual {
            Some(r) => (r.sample_inverse(u) as u32, None),
            None => (q.sample_inverse(u) as u32, None),
        }
    }
}

impl SpecTrVerifier {
    /// Scalar full-alphabet reference for [`BlockVerifier::verify_block`]
    /// (the seed implementation, built on [`calibrate`] / [`Self::step`]).
    /// The workspace kernel path must match this bit-for-bit
    /// (`tests/kernel_parity.rs`); it is also the perf baseline in
    /// `benches/perf_engine`.
    pub fn verify_block_scalar(
        &self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok());
        let k = input.k();
        let l = input.block_len();
        let mut active: Vec<usize> = (0..k).collect();
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;

        for j in 0..l {
            let q = &input.target_dists[active[0]][j];
            // K-SEQ assumes identical proposals: use the first active
            // draft's p (the engine only selects SpecTr for i.i.d. drafts).
            let p = &input.draft_dists[active[0]][j];
            let candidates: Vec<(usize, u32)> =
                active.iter().map(|&kk| (kk, input.draft_tokens[kk][j])).collect();
            let (tok, from) = self.step(p, q, &candidates, rng, slot0 + j as u64, k);
            tokens.push(tok);
            match from {
                Some(_) => {
                    active.retain(|&kk| input.draft_tokens[kk][j] == tok);
                    accepted += 1;
                }
                None => return BlockOutput { tokens, accepted, surviving_draft: None },
            }
        }
        let q = &input.target_dists[active[0]][l];
        let u = rng.uniform(slot0 + l as u64, k as u64, 0);
        tokens.push(q.sample_inverse(u) as u32);
        BlockOutput { tokens, accepted, surviving_draft: active.first().copied() }
    }
}

impl BlockVerifier for SpecTrVerifier {
    fn kind(&self) -> VerifierKind {
        VerifierKind::SpecTr
    }

    fn invariance(&self) -> Invariance {
        Invariance::None
    }

    /// Kernel-backed K-SEQ verification: sparse-support γ-calibration and a
    /// zero-allocation transport-residual plan on the thread workspace —
    /// bit-exact with [`SpecTrVerifier::verify_block_scalar`].
    fn verify_block(&self, input: &BlockInput, rng: &CounterRng, slot0: u64) -> BlockOutput {
        with_workspace(|ws| ws.verify_block_spectr(input, rng, slot0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::stats::rng::XorShift128;

    #[test]
    fn calibration_feasible_and_in_range() {
        let mut gen = XorShift128::new(2);
        for _ in 0..40 {
            let p = testkit::gen_categorical(&mut gen, 10);
            let q = testkit::gen_categorical(&mut gen, 10);
            for &k in &[1usize, 2, 4, 8, 16] {
                let plan = calibrate(&p, &q, k);
                assert!(plan.gamma >= 1.0 - 1e-9 && plan.gamma <= k as f64 + 1e-9);
                assert!(plan.c <= plan.gamma + 1e-6, "c {} > gamma {}", plan.c, plan.gamma);
                assert!(plan.s > 0.0 && plan.s <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn calibration_identical_distributions_gives_gamma_one() {
        let p = Categorical::new(vec![0.3, 0.7]);
        let plan = calibrate(&p, &p, 4);
        // p = q: s(1) = 1, c = 1 ≤ 1 feasible at γ = 1; every candidate
        // accepted with probability 1.
        assert!((plan.gamma - 1.0).abs() < 1e-9);
        assert!(plan.accept_prob.iter().all(|&a| (a - 1.0).abs() < 1e-9));
        assert!(plan.residual.is_none());
    }

    #[test]
    fn step_preserves_target_marginal_chi_square() {
        // The defining property: K-SEQ output follows q exactly.
        let mut gen = XorShift128::new(6);
        let n = 6;
        let p = testkit::gen_categorical(&mut gen, n);
        let q = testkit::gen_categorical(&mut gen, n);
        let v = SpecTrVerifier::new();
        let k = 4;
        let trials = 80_000;
        let mut counts = vec![0usize; n];
        let rng = CounterRng::new(44);
        for t in 0..trials {
            let cands: Vec<(usize, u32)> =
                (0..k).map(|kk| (kk, p.sample_race(&rng, t as u64, kk as u64) as u32)).collect();
            let (tok, _) = v.step(&p, &q, &cands, &rng, t as u64, k);
            counts[tok as usize] += 1;
        }
        // Chi-square with n-1 = 5 dof; 99.9th pct ≈ 20.5. Allow slack.
        let mut chi2 = 0.0;
        for i in 0..n {
            let e = q.prob(i) * trials as f64;
            chi2 += (counts[i] as f64 - e).powi(2) / e;
        }
        assert!(chi2 < 25.0, "chi2 = {chi2}, counts = {counts:?}");
    }

    #[test]
    fn acceptance_improves_with_k() {
        let p = Categorical::new(vec![0.25, 0.25, 0.25, 0.25]);
        let q = Categorical::new(vec![0.55, 0.15, 0.15, 0.15]);
        let v = SpecTrVerifier::new();
        let rng = CounterRng::new(10);
        let trials = 30_000;
        let rate = |k: usize| {
            let mut hits = 0;
            for t in 0..trials {
                let cands: Vec<(usize, u32)> = (0..k)
                    .map(|kk| (kk, p.sample_race(&rng, t as u64, kk as u64) as u32))
                    .collect();
                let (_, from) = v.step(&p, &q, &cands, &rng, t as u64, k);
                if from.is_some() {
                    hits += 1;
                }
            }
            hits as f64 / trials as f64
        };
        let r1 = rate(1);
        let r4 = rate(4);
        let r8 = rate(8);
        assert!(r1 < r4 && r4 <= r8 + 0.01, "{r1} {r4} {r8}");
    }

    #[test]
    fn k1_reduces_to_classic_rejection_acceptance() {
        // With K = 1, γ = 1 and the acceptance is min(1, q/p): the expected
        // acceptance equals 1 - d_TV(p, q).
        let p = Categorical::new(vec![0.6, 0.4]);
        let q = Categorical::new(vec![0.3, 0.7]);
        let v = SpecTrVerifier::new();
        let rng = CounterRng::new(77);
        let trials = 60_000;
        let mut hits = 0;
        for t in 0..trials {
            let x = p.sample_race(&rng, t as u64, 0) as u32;
            let (_, from) = v.step(&p, &q, &[(0, x)], &rng, t as u64, 1);
            if from.is_some() {
                hits += 1;
            }
        }
        let emp = hits as f64 / trials as f64;
        let expect = 1.0 - p.tv_distance(&q);
        assert!((emp - expect).abs() < 0.01, "emp {emp} vs 1-dTV {expect}");
    }

    #[test]
    fn verify_block_structure() {
        let mut gen = XorShift128::new(21);
        for case in 0..20 {
            let n = 5;
            let l = 3;
            let k = 4;
            let p: Vec<Categorical> = (0..l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let q: Vec<Categorical> =
                (0..=l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let rng = CounterRng::new(case + 500);
            let mut draft_tokens = vec![Vec::new(); k];
            for kk in 0..k {
                for j in 0..l {
                    draft_tokens[kk].push(p[j].sample_race(&rng, j as u64, kk as u64) as u32);
                }
            }
            let input = BlockInput {
                draft_tokens: draft_tokens.into(),
                draft_dists: vec![p.clone(); k],
                target_dists: vec![q.clone(); k],
            };
            let out = SpecTrVerifier::new().verify_block(&input, &rng, 0);
            assert_eq!(out.tokens.len(), out.accepted + 1);
            assert!(out.accepted <= l);
        }
    }
}
