//! The paper's contribution: Gumbel-max List Sampling (§3) and multi-draft
//! speculative decoding verification schemes (§4).
//!
//! * [`types`] — categorical distributions, verification interfaces.
//! * [`gls`] — Algorithm 1 (`sample_gls`) and Algorithm 2 (the
//!   conditionally drafter-invariant block verifier), plus the strongly
//!   invariant variant of Appendix B (Prop. 6).
//! * [`kernel`] — the zero-allocation sparse-support coupling kernel the
//!   public GLS entry points run on (bit-exact with the scalar references).
//! * [`lml`] — Theorem 1 / Proposition 2 bound evaluators.
//! * [`specinfer`] — SpecInfer recursive multi-round rejection (Miao et al.).
//! * [`spectr`] — SpecTr k-sequential-selection verification (Sun et al.).
//! * [`single_draft`] — classic single-draft rejection sampling
//!   (Leviathan et al. / Chen et al.), the TR = 0% reference line.
//! * [`daliri`] — single-draft Gumbel-max coupling (Daliri et al.).
//! * [`optimal`] — optimal-with-communication acceptance: closed-form upper
//!   bound and exact LP (via [`crate::lp`]) for small instances.

pub mod daliri;
pub mod gls;
pub mod kernel;
pub mod lml;
pub mod optimal;
pub mod single_draft;
pub mod spectr;
pub mod specinfer;
pub mod types;

pub use kernel::CouplingWorkspace;
pub use types::{BlockInput, BlockOutput, BlockVerifier, Categorical, Invariance, VerifierKind};

/// Construct a verifier by kind. `k` is the number of drafts the engine will
/// run; single-draft kinds ignore all but the first draft.
pub fn make_verifier(kind: VerifierKind) -> Box<dyn BlockVerifier + Send + Sync> {
    match kind {
        VerifierKind::Gls => Box::new(gls::GlsVerifier::conditional()),
        VerifierKind::GlsStrong => Box::new(gls::GlsVerifier::strong()),
        VerifierKind::SpecInfer => Box::new(specinfer::SpecInferVerifier::new()),
        VerifierKind::SpecTr => Box::new(spectr::SpecTrVerifier::new()),
        VerifierKind::SingleDraft => Box::new(single_draft::SingleDraftVerifier::new()),
        VerifierKind::Daliri => Box::new(daliri::DaliriVerifier::new()),
    }
}
