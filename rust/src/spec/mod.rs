//! The paper's contribution: Gumbel-max List Sampling (§3) and multi-draft
//! speculative decoding verification schemes (§4).
//!
//! * [`types`] — categorical distributions, verification interfaces.
//! * [`gls`] — Algorithm 1 (`sample_gls`) and Algorithm 2 (the
//!   conditionally drafter-invariant block verifier), plus the strongly
//!   invariant variant of Appendix B (Prop. 6).
//! * [`kernel`] — the zero-allocation sparse-support coupling kernel every
//!   registered `verify_block` runs on (GLS, GLS-strong, SpecTr, SpecInfer,
//!   Daliri, and the single-draft TR baseline; bit-exact with the scalar
//!   references — see its module docs for the kernel contract, the RNG
//!   coordinate map, and the cross-thread panel-slice handoff protocol the
//!   serving pool uses).
//! * [`lml`] — Theorem 1 / Proposition 2 bound evaluators.
//! * [`specinfer`] — SpecInfer recursive multi-round rejection (Miao et al.).
//! * [`spectr`] — SpecTr k-sequential-selection verification (Sun et al.).
//! * [`single_draft`] — classic single-draft rejection sampling
//!   (Leviathan et al. / Chen et al.), the TR = 0% reference line.
//! * [`daliri`] — single-draft Gumbel-max coupling (Daliri et al.).
//! * [`optimal`] — optimal-with-communication acceptance: closed-form upper
//!   bound and exact LP (via [`crate::lp`]) for small instances.

pub mod daliri;
pub mod gls;
pub mod kernel;
pub mod lml;
pub mod optimal;
pub mod single_draft;
pub mod spectr;
pub mod specinfer;
pub mod types;

pub use kernel::{CouplingWorkspace, PanelCacheStats, PanelSlice, SliceBank, SliceRecycler};
pub use types::{
    BlockInput, BlockOutput, BlockVerifier, Categorical, Invariance, TokenMatrix, VerifierKind,
};

/// Construct a verifier by kind. `k` is the number of drafts the engine will
/// run; single-draft kinds ignore all but the first draft.
pub fn make_verifier(kind: VerifierKind) -> Box<dyn BlockVerifier + Send + Sync> {
    match kind {
        VerifierKind::Gls => Box::new(gls::GlsVerifier::conditional()),
        VerifierKind::GlsStrong => Box::new(gls::GlsVerifier::strong()),
        VerifierKind::SpecInfer => Box::new(specinfer::SpecInferVerifier::new()),
        VerifierKind::SpecTr => Box::new(spectr::SpecTrVerifier::new()),
        VerifierKind::SingleDraft => Box::new(single_draft::SingleDraftVerifier::new()),
        VerifierKind::Daliri => Box::new(daliri::DaliriVerifier::new()),
        VerifierKind::FaultInjection => panic!(
            "FaultInjection is test-only and runs exclusively through \
             CouplingWorkspace::verify_block_kind (it has no production verifier)"
        ),
    }
}

/// The verifier registry: one constructed instance of every
/// [`VerifierKind`], in [`VerifierKind::all`] order.
///
/// Property, conformance, and engine test suites iterate this instead of
/// hand-listing kinds, so a newly added verifier cannot be silently
/// omitted from coverage: registering the kind in [`VerifierKind::all`] /
/// [`make_verifier`] is the single step that enrolls it everywhere.
pub fn all_verifiers() -> Vec<Box<dyn BlockVerifier + Send + Sync>> {
    VerifierKind::all().iter().map(|&k| make_verifier(k)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_kind_exactly_once() {
        let kinds: Vec<VerifierKind> = all_verifiers().iter().map(|v| v.kind()).collect();
        assert_eq!(kinds.as_slice(), VerifierKind::all());
        // The registry relies on `make_verifier` being kind-consistent.
        for &k in VerifierKind::all() {
            assert_eq!(make_verifier(k).kind(), k);
        }
    }
}
