//! Daliri et al. (2025) single-draft drafter-invariant coupling: both sides
//! run the Gumbel-max race on the *same* shared exponentials; the drafter
//! proposes `X = argmin S_i/p_i`, the verifier computes `Y = argmin S_i/q_i`
//! and the step is accepted iff X = Y. The output is always Y, which is a
//! function of (q, randomness) only — hence strong drafter invariance —
//! and achieves `Pr[X=Y] ≥ (1 − d_TV)/(1 + d_TV)`.
//!
//! This is the K = 1 special case of GLS and the scheme the paper's tables
//! report as "Daliri et al. [9]".

use crate::stats::rng::CounterRng;

use super::gls::select_target_token_scalar;
use super::kernel::with_workspace;
use super::types::{
    BlockInput, BlockOutput, BlockVerifier, Invariance, VerifierKind,
};

#[derive(Clone, Debug, Default)]
pub struct DaliriVerifier;

impl DaliriVerifier {
    pub fn new() -> Self {
        Self
    }

    /// Scalar full-alphabet reference for [`BlockVerifier::verify_block`]:
    /// one dense lane-0 race on the target per position. The workspace
    /// kernel path must match this bit-for-bit (`tests/kernel_parity.rs`);
    /// it is also the perf baseline in `benches/perf_engine`.
    ///
    /// `Y_j` is a function of `(q, randomness)` alone — that is the strong
    /// drafter invariance. The drafter produced its token from the *same*
    /// exponential cells `(slot0 + j, lane 0, ·)`, so comparing `Y_j` to
    /// the recorded draft token is exactly the `X = Y` acceptance check
    /// (an invariant the integration tests assert against the engine).
    pub fn verify_block_scalar(
        &self,
        input: &BlockInput,
        rng: &CounterRng,
        slot0: u64,
    ) -> BlockOutput {
        debug_assert!(input.validate().is_ok());
        let l = input.block_len();
        let mut tokens = Vec::with_capacity(l + 1);
        let mut accepted = 0usize;
        for j in 0..l {
            let q = &input.target_dists[0][j];
            let yj = select_target_token_scalar(&[q], &[0], rng, slot0 + j as u64) as u32;
            tokens.push(yj);
            if yj != input.draft_tokens[0][j] {
                return BlockOutput { tokens, accepted, surviving_draft: None };
            }
            accepted += 1;
        }
        // Bonus token: coupled race on the target at the final position.
        let q = &input.target_dists[0][l];
        tokens.push(select_target_token_scalar(&[q], &[0], rng, slot0 + l as u64) as u32);
        BlockOutput { tokens, accepted, surviving_draft: Some(0) }
    }
}

impl BlockVerifier for DaliriVerifier {
    fn kind(&self) -> VerifierKind {
        VerifierKind::Daliri
    }

    fn invariance(&self) -> Invariance {
        Invariance::Strong
    }

    /// Kernel-backed coupled verification: sparse-support lane-0 races on
    /// the thread workspace, reusing draft-phase exponentials from the
    /// panel cache when the engine drafted on the same thread — bit-exact
    /// with [`DaliriVerifier::verify_block_scalar`].
    fn verify_block(&self, input: &BlockInput, rng: &CounterRng, slot0: u64) -> BlockOutput {
        with_workspace(|ws| ws.verify_block_daliri(input, rng, slot0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::lml::daliri_bound;
    use crate::spec::types::Categorical;
    use crate::testkit;
    use crate::stats::rng::XorShift128;

    #[test]
    fn acceptance_meets_daliri_bound() {
        let mut gen = XorShift128::new(4);
        for _ in 0..8 {
            let p = testkit::gen_categorical(&mut gen, 6);
            let q = testkit::gen_categorical(&mut gen, 6);
            let rng = CounterRng::new(3);
            let trials = 30_000;
            let mut hits = 0;
            for t in 0..trials {
                if crate::spec::gls::sample_gls(&p, &q, 1, &rng, t as u64).accept {
                    hits += 1;
                }
            }
            let emp = hits as f64 / trials as f64;
            let bound = daliri_bound(&p, &q);
            assert!(emp + 0.015 >= bound, "emp {emp} < bound {bound}");
        }
    }

    #[test]
    fn block_verification_consistent_with_coupled_drafting() {
        // When the drafter actually drafts with the same shared randomness,
        // every emitted token equals the draft token until the first miss.
        let mut gen = XorShift128::new(14);
        for case in 0..20u64 {
            let n = 5;
            let l = 4;
            let p: Vec<Categorical> = (0..l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let q: Vec<Categorical> =
                (0..=l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
            let rng = CounterRng::new(900 + case);
            // Draft with the same (rng, slot) the verifier will use.
            let draft_tokens: Vec<u32> =
                (0..l).map(|j| p[j].sample_race(&rng, j as u64, 0) as u32).collect();
            let input = BlockInput {
                draft_tokens: vec![draft_tokens.clone()].into(),
                draft_dists: vec![p.clone()],
                target_dists: vec![q.clone()],
            };
            let out = DaliriVerifier::new().verify_block(&input, &rng, 0);
            for j in 0..out.accepted {
                assert_eq!(out.tokens[j], draft_tokens[j]);
            }
            assert_eq!(out.tokens.len(), out.accepted + 1);
        }
    }

    #[test]
    fn output_is_drafter_invariant() {
        // Y depends only on target dists + randomness: replacing the draft
        // distributions must not change emitted tokens (only acceptance
        // counts may change through the tokens, which we hold fixed).
        let mut gen = XorShift128::new(25);
        let n = 5;
        let l = 3;
        let p: Vec<Categorical> = (0..l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
        let p2: Vec<Categorical> = (0..l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
        let q: Vec<Categorical> = (0..=l).map(|_| testkit::gen_categorical(&mut gen, n)).collect();
        let rng = CounterRng::new(62);
        let draft_tokens: Vec<u32> =
            (0..l).map(|j| p[j].sample_race(&rng, j as u64, 0) as u32).collect();
        let a = DaliriVerifier::new().verify_block(
            &BlockInput {
                draft_tokens: vec![draft_tokens.clone()].into(),
                draft_dists: vec![p],
                target_dists: vec![q.clone()],
            },
            &rng,
            0,
        );
        let b = DaliriVerifier::new().verify_block(
            &BlockInput {
                draft_tokens: vec![draft_tokens].into(),
                draft_dists: vec![p2],
                target_dists: vec![q],
            },
            &rng,
            0,
        );
        let m = a.tokens.len().min(b.tokens.len());
        assert_eq!(&a.tokens[..m], &b.tokens[..m]);
    }
}
