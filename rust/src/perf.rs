//! Hardware performance counters via `perf_event_open` — the measurement
//! side of the kernel memory-layout work (leaky panel cache, item-major
//! exponential panels).
//!
//! The offline build is dependency-free, so this is a std-only wrapper:
//! the syscall is issued with inline assembly (no libc), the attr struct
//! is laid out by hand at `PERF_ATTR_SIZE_VER0`, and everything is gated
//! behind the `perf-counters` feature **and** `x86_64-unknown-linux`.
//! Everywhere else — feature off, other OS/arch — the module still
//! compiles and [`PerfCounters::open`] returns
//! [`PerfError::CompiledOut`], so callers (the bench harness, CI) branch
//! on a typed error instead of `cfg` soup.
//!
//! `perf_event_open` is frequently forbidden at runtime too (seccomp in
//! containers, `kernel.perf_event_paranoid >= 3`): that surfaces as
//! [`PerfError::Denied`] / [`PerfError::Unsupported`], which the CI perf
//! job reports as a **labeled skip** — counter columns are absent with a
//! stated reason, never silently zero.
//!
//! What we count, per measured section: CPU cycles, retired instructions
//! (their ratio is IPC), and last-level-cache references + misses — the
//! four counters the layout pass optimizes for. All four are opened
//! userspace-only (`exclude_kernel | exclude_hv`) so syscall noise inside
//! a timed section does not pollute the columns.

/// Why counters are unavailable. `CompiledOut` is static (build config);
/// the rest are runtime answers from the kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PerfError {
    /// Built without `--features perf-counters`, or not x86_64-linux.
    CompiledOut,
    /// The kernel refused (`EPERM`/`EACCES`): seccomp filter or
    /// `kernel.perf_event_paranoid` too high for unprivileged counters.
    Denied,
    /// No usable PMU (`ENOSYS`/`ENOENT`/`ENODEV`/`EOPNOTSUPP`): common in
    /// VMs that don't virtualize hardware counters.
    Unsupported,
    /// Any other errno from `perf_event_open`/`ioctl`/`read`.
    Os(i32),
}

impl std::fmt::Display for PerfError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PerfError::CompiledOut => {
                write!(f, "perf counters compiled out (needs --features perf-counters on x86_64-linux)")
            }
            PerfError::Denied => {
                write!(f, "perf_event_open denied (seccomp or perf_event_paranoid)")
            }
            PerfError::Unsupported => write!(f, "hardware PMU unavailable"),
            PerfError::Os(e) => write!(f, "perf syscall failed (errno {e})"),
        }
    }
}

/// One reading of the four hardware counters across a measured section.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub cycles: u64,
    pub instructions: u64,
    /// Last-level-cache references (`PERF_COUNT_HW_CACHE_REFERENCES`).
    pub llc_refs: u64,
    /// Last-level-cache misses (`PERF_COUNT_HW_CACHE_MISSES`) — the
    /// number the panel transpose and the flat cache exist to shrink.
    pub llc_misses: u64,
}

impl CounterSnapshot {
    /// Instructions per cycle over the section.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instructions as f64 / self.cycles as f64
        }
    }

    /// LLC miss rate (misses / references) over the section.
    pub fn llc_miss_rate(&self) -> f64 {
        if self.llc_refs == 0 {
            0.0
        } else {
            self.llc_misses as f64 / self.llc_refs as f64
        }
    }
}

/// A set of opened hardware counters for the calling thread.
///
/// Usage: `open()` once, then `start()` / `stop()` brackets around each
/// measured section (`start` resets, so one `PerfCounters` serves many
/// sections). Descriptors close on drop.
pub struct PerfCounters {
    inner: imp::Counters,
}

impl PerfCounters {
    /// Open cycles/instructions/LLC-refs/LLC-misses for this thread,
    /// disabled. Fails with a typed [`PerfError`] when counters are
    /// compiled out or the kernel refuses.
    pub fn open() -> Result<Self, PerfError> {
        Ok(Self { inner: imp::Counters::open()? })
    }

    /// Reset all four counters to zero and enable them.
    pub fn start(&mut self) -> Result<(), PerfError> {
        self.inner.start()
    }

    /// Disable the counters and read the section's totals.
    pub fn stop(&mut self) -> Result<CounterSnapshot, PerfError> {
        self.inner.stop()
    }
}

/// Probe whether counters work here (open + trivial start/stop). The CI
/// perf job uses the error to print its labeled-skip reason.
pub fn probe() -> Result<(), PerfError> {
    let mut c = PerfCounters::open()?;
    c.start()?;
    c.stop()?;
    Ok(())
}

#[cfg(all(feature = "perf-counters", target_os = "linux", target_arch = "x86_64"))]
mod imp {
    //! The real implementation: raw syscalls, no libc.

    use super::{CounterSnapshot, PerfError};

    // x86_64 Linux syscall numbers.
    const SYS_READ: i64 = 0;
    const SYS_CLOSE: i64 = 3;
    const SYS_IOCTL: i64 = 16;
    const SYS_PERF_EVENT_OPEN: i64 = 298;

    // perf_event_attr.type / .config for the four counters.
    const PERF_TYPE_HARDWARE: u32 = 0;
    const HW_CPU_CYCLES: u64 = 0;
    const HW_INSTRUCTIONS: u64 = 1;
    const HW_CACHE_REFERENCES: u64 = 2;
    const HW_CACHE_MISSES: u64 = 3;

    // attr flag bits: disabled | exclude_kernel | exclude_hv.
    const ATTR_FLAGS: u64 = 1 | (1 << 5) | (1 << 6);

    const PERF_EVENT_IOC_ENABLE: u64 = 0x2400;
    const PERF_EVENT_IOC_DISABLE: u64 = 0x2401;
    const PERF_EVENT_IOC_RESET: u64 = 0x2403;
    const PERF_FLAG_FD_CLOEXEC: u64 = 1 << 3;

    /// `perf_event_attr` truncated at `PERF_ATTR_SIZE_VER0` (64 bytes) —
    /// the kernel accepts any published size, and VER0 covers every field
    /// we set. Field names follow the kernel header; the unions collapse
    /// to their first member since we sample nothing.
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PerfEventAttr {
        type_: u32,
        size: u32,
        config: u64,
        sample_period: u64,
        sample_type: u64,
        read_format: u64,
        flags: u64,
        wakeup_events: u32,
        bp_type: u32,
        bp_addr: u64,
    }

    const ATTR_SIZE: u32 = core::mem::size_of::<PerfEventAttr>() as u32;

    unsafe fn syscall5(n: i64, a1: i64, a2: i64, a3: i64, a4: i64, a5: i64) -> i64 {
        let ret: i64;
        core::arch::asm!(
            "syscall",
            inlateout("rax") n => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            out("rcx") _,
            out("r11") _,
            options(nostack),
        );
        ret
    }

    fn errno_of(ret: i64) -> i32 {
        (-ret) as i32
    }

    fn map_err(errno: i32) -> PerfError {
        match errno {
            1 | 13 => PerfError::Denied,             // EPERM, EACCES
            2 | 19 | 38 | 95 => PerfError::Unsupported, // ENOENT, ENODEV, ENOSYS, EOPNOTSUPP
            e => PerfError::Os(e),
        }
    }

    fn open_counter(config: u64) -> Result<i32, PerfError> {
        let attr = PerfEventAttr {
            type_: PERF_TYPE_HARDWARE,
            size: ATTR_SIZE,
            config,
            sample_period: 0,
            sample_type: 0,
            read_format: 0,
            flags: ATTR_FLAGS,
            wakeup_events: 0,
            bp_type: 0,
            bp_addr: 0,
        };
        // pid = 0 (this thread), cpu = -1 (any), group_fd = -1 (own group).
        let ret = unsafe {
            syscall5(
                SYS_PERF_EVENT_OPEN,
                &attr as *const PerfEventAttr as i64,
                0,
                -1,
                -1,
                PERF_FLAG_FD_CLOEXEC as i64,
            )
        };
        if ret < 0 {
            Err(map_err(errno_of(ret)))
        } else {
            Ok(ret as i32)
        }
    }

    fn ioctl(fd: i32, op: u64) -> Result<(), PerfError> {
        let ret = unsafe { syscall5(SYS_IOCTL, fd as i64, op as i64, 0, 0, 0) };
        if ret < 0 {
            Err(map_err(errno_of(ret)))
        } else {
            Ok(())
        }
    }

    fn read_u64(fd: i32) -> Result<u64, PerfError> {
        let mut buf = 0u64;
        let ret = unsafe {
            syscall5(SYS_READ, fd as i64, &mut buf as *mut u64 as i64, 8, 0, 0)
        };
        if ret < 0 {
            Err(map_err(errno_of(ret)))
        } else if ret != 8 {
            Err(PerfError::Os(0))
        } else {
            Ok(buf)
        }
    }

    pub(super) struct Counters {
        /// cycles, instructions, llc_refs, llc_misses — in that order.
        fds: [i32; 4],
    }

    impl Counters {
        pub(super) fn open() -> Result<Self, PerfError> {
            let configs =
                [HW_CPU_CYCLES, HW_INSTRUCTIONS, HW_CACHE_REFERENCES, HW_CACHE_MISSES];
            let mut fds = [-1i32; 4];
            for (slot, &config) in fds.iter_mut().zip(configs.iter()) {
                match open_counter(config) {
                    Ok(fd) => *slot = fd,
                    Err(e) => {
                        // Close the ones that did open before reporting.
                        for &fd in &fds {
                            if fd >= 0 {
                                unsafe { syscall5(SYS_CLOSE, fd as i64, 0, 0, 0, 0) };
                            }
                        }
                        return Err(e);
                    }
                }
            }
            Ok(Self { fds })
        }

        pub(super) fn start(&mut self) -> Result<(), PerfError> {
            for &fd in &self.fds {
                ioctl(fd, PERF_EVENT_IOC_RESET)?;
            }
            for &fd in &self.fds {
                ioctl(fd, PERF_EVENT_IOC_ENABLE)?;
            }
            Ok(())
        }

        pub(super) fn stop(&mut self) -> Result<CounterSnapshot, PerfError> {
            for &fd in &self.fds {
                ioctl(fd, PERF_EVENT_IOC_DISABLE)?;
            }
            Ok(CounterSnapshot {
                cycles: read_u64(self.fds[0])?,
                instructions: read_u64(self.fds[1])?,
                llc_refs: read_u64(self.fds[2])?,
                llc_misses: read_u64(self.fds[3])?,
            })
        }
    }

    impl Drop for Counters {
        fn drop(&mut self) {
            for &fd in &self.fds {
                if fd >= 0 {
                    unsafe { syscall5(SYS_CLOSE, fd as i64, 0, 0, 0, 0) };
                }
            }
        }
    }
}

#[cfg(not(all(feature = "perf-counters", target_os = "linux", target_arch = "x86_64")))]
mod imp {
    //! Stub: same surface, every entry point reports `CompiledOut`.

    use super::{CounterSnapshot, PerfError};

    pub(super) struct Counters;

    impl Counters {
        pub(super) fn open() -> Result<Self, PerfError> {
            Err(PerfError::CompiledOut)
        }

        #[allow(dead_code)]
        pub(super) fn start(&mut self) -> Result<(), PerfError> {
            Err(PerfError::CompiledOut)
        }

        #[allow(dead_code)]
        pub(super) fn stop(&mut self) -> Result<CounterSnapshot, PerfError> {
            Err(PerfError::CompiledOut)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_yields_counters_or_a_typed_reason() {
        // Whatever the environment (feature off, container seccomp, bare
        // metal), the answer must be typed — never a panic, never a
        // mystery errno for the common refusals.
        match PerfCounters::open() {
            Ok(_) => {}
            Err(PerfError::CompiledOut | PerfError::Denied | PerfError::Unsupported) => {}
            Err(PerfError::Os(e)) => panic!("unmapped perf_event_open errno {e}"),
        }
    }

    #[test]
    fn counters_observe_real_work_when_available() {
        let mut c = match PerfCounters::open() {
            Ok(c) => c,
            Err(_) => return, // labeled-skip environments: nothing to assert
        };
        c.start().expect("enable");
        // Opaque arithmetic the optimizer can't delete.
        let mut acc = 0u64;
        for i in 0..200_000u64 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(acc);
        let snap = c.stop().expect("read");
        assert!(snap.cycles > 0, "cycle counter stayed at zero");
        assert!(snap.instructions > 0, "instruction counter stayed at zero");
        assert!(snap.ipc() > 0.0);
        // And start() must reset: an empty section counts (almost)
        // nothing compared to the loop above.
        c.start().expect("re-enable");
        let empty = c.stop().expect("re-read");
        assert!(
            empty.instructions < snap.instructions,
            "IOC_RESET did not reset the section"
        );
    }

    #[test]
    fn probe_matches_open() {
        match (probe(), PerfCounters::open()) {
            (Ok(()), Ok(_)) => {}
            (Err(a), Err(b)) => assert_eq!(a, b),
            (p, o) => panic!("probe {p:?} disagrees with open {:?}", o.err()),
        }
    }
}
