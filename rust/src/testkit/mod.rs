//! In-house property-based testing support.
//!
//! `proptest` is not available in the offline vendor set, so this module
//! provides the subset we need: seeded random generators for the domain
//! types (categorical distributions, token sequences, request traces) and a
//! `forall` driver that runs a property across many generated cases and
//! reports the failing seed for reproduction. No shrinking — failures print
//! the full case, which is small for our domains.

use crate::spec::types::Categorical;
use crate::stats::rng::XorShift128;

/// Number of cases per property; override with `GLS_PROPTEST_CASES`.
pub fn default_cases() -> usize {
    std::env::var("GLS_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` on `cases` generated inputs. On failure, panic with the seed
/// and case index so the exact case can be re-generated.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut XorShift128) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = XorShift128::new(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property failed (seed={seed}, case={case}): {msg}\ninput: {input:?}");
        }
    }
}

/// Generate a strictly-positive categorical distribution on `n` symbols.
/// Masses are Dirichlet-ish: normalized Exp(1) draws, floored away from 0.
pub fn gen_categorical(rng: &mut XorShift128, n: usize) -> Categorical {
    let mut w: Vec<f64> = (0..n).map(|_| -rng.next_f64().ln() + 1e-9).collect();
    let total: f64 = w.iter().sum();
    w.iter_mut().for_each(|x| *x /= total);
    Categorical::new(w)
}

/// Generate a sparse categorical: roughly `support` symbols carry all mass;
/// the rest are exactly zero. Exercises the q_i = 0 / p_i = 0 edge cases.
pub fn gen_sparse_categorical(rng: &mut XorShift128, n: usize, support: usize) -> Categorical {
    assert!(support >= 1 && support <= n);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut w = vec![0.0; n];
    for &i in idx.iter().take(support) {
        w[i] = -rng.next_f64().ln() + 1e-9;
    }
    let total: f64 = w.iter().sum();
    w.iter_mut().for_each(|x| *x /= total);
    Categorical::new(w)
}

/// Generate a "peaked" categorical with temperature `t` applied to random
/// logits — mimics LLM next-token distributions (low t => near-deterministic).
pub fn gen_peaked_categorical(rng: &mut XorShift128, n: usize, temperature: f64) -> Categorical {
    let logits: Vec<f64> = (0..n).map(|_| 4.0 * rng.next_f64()).collect();
    let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut w: Vec<f64> = logits.iter().map(|l| ((l - max) / temperature).exp()).collect();
    let total: f64 = w.iter().sum();
    w.iter_mut().for_each(|x| *x /= total);
    Categorical::new(w)
}

/// Generate a random token sequence of length in [1, max_len].
pub fn gen_tokens(rng: &mut XorShift128, vocab: usize, max_len: usize) -> Vec<u32> {
    let len = 1 + rng.next_below(max_len as u64) as usize;
    (0..len).map(|_| rng.next_below(vocab as u64) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_categorical_is_normalized_and_positive() {
        let mut rng = XorShift128::new(1);
        for _ in 0..50 {
            let c = gen_categorical(&mut rng, 17);
            let sum: f64 = c.probs().iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(c.probs().iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn gen_sparse_categorical_has_requested_support() {
        let mut rng = XorShift128::new(2);
        let c = gen_sparse_categorical(&mut rng, 20, 5);
        let nz = c.probs().iter().filter(|&&p| p > 0.0).count();
        assert_eq!(nz, 5);
        assert!((c.probs().iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gen_peaked_low_temperature_concentrates() {
        let mut rng = XorShift128::new(3);
        let hot = gen_peaked_categorical(&mut rng, 50, 2.0);
        let mut rng = XorShift128::new(3);
        let cold = gen_peaked_categorical(&mut rng, 50, 0.1);
        let max_hot = hot.probs().iter().cloned().fold(0.0, f64::max);
        let max_cold = cold.probs().iter().cloned().fold(0.0, f64::max);
        assert!(max_cold > max_hot);
    }

    #[test]
    fn forall_reports_failures() {
        let result = std::panic::catch_unwind(|| {
            forall(
                0,
                16,
                |rng| rng.next_below(100),
                |&x| if x < 95 { Ok(()) } else { Err(format!("x={x} too big")) },
            );
        });
        assert!(result.is_err());
    }

    #[test]
    fn gen_tokens_within_bounds() {
        let mut rng = XorShift128::new(4);
        for _ in 0..100 {
            let toks = gen_tokens(&mut rng, 64, 12);
            assert!(!toks.is_empty() && toks.len() <= 12);
            assert!(toks.iter().all(|&t| (t as usize) < 64));
        }
    }
}
