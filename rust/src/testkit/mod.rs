//! In-house property-based testing support.
//!
//! `proptest` is not available in the offline vendor set, so this module
//! provides the subset we need: seeded random generators for the domain
//! types (categorical distributions, token sequences, request traces) and a
//! `forall` driver that runs a property across many generated cases and
//! reports the failing seed for reproduction. No shrinking — failures print
//! the full case, which is small for our domains.

use crate::model::backend::LmBackend;
use crate::spec::types::Categorical;
use crate::stats::rng::XorShift128;

/// Number of cases per property; override with `GLS_PROPTEST_CASES`.
pub fn default_cases() -> usize {
    std::env::var("GLS_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` on `cases` generated inputs. On failure, panic with the seed
/// and case index so the exact case can be re-generated.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut XorShift128) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = XorShift128::new(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property failed (seed={seed}, case={case}): {msg}\ninput: {input:?}");
        }
    }
}

/// Generate a strictly-positive categorical distribution on `n` symbols.
/// Masses are Dirichlet-ish: normalized Exp(1) draws, floored away from 0.
pub fn gen_categorical(rng: &mut XorShift128, n: usize) -> Categorical {
    let mut w: Vec<f64> = (0..n).map(|_| -rng.next_f64().ln() + 1e-9).collect();
    let total: f64 = w.iter().sum();
    w.iter_mut().for_each(|x| *x /= total);
    Categorical::new(w)
}

/// Generate a sparse categorical: roughly `support` symbols carry all mass;
/// the rest are exactly zero. Exercises the q_i = 0 / p_i = 0 edge cases.
pub fn gen_sparse_categorical(rng: &mut XorShift128, n: usize, support: usize) -> Categorical {
    assert!(support >= 1 && support <= n);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut w = vec![0.0; n];
    for &i in idx.iter().take(support) {
        w[i] = -rng.next_f64().ln() + 1e-9;
    }
    let total: f64 = w.iter().sum();
    w.iter_mut().for_each(|x| *x /= total);
    Categorical::new(w)
}

/// Generate a "peaked" categorical with temperature `t` applied to random
/// logits — mimics LLM next-token distributions (low t => near-deterministic).
pub fn gen_peaked_categorical(rng: &mut XorShift128, n: usize, temperature: f64) -> Categorical {
    let logits: Vec<f64> = (0..n).map(|_| 4.0 * rng.next_f64()).collect();
    let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut w: Vec<f64> = logits.iter().map(|l| ((l - max) / temperature).exp()).collect();
    let total: f64 = w.iter().sum();
    w.iter_mut().for_each(|x| *x /= total);
    Categorical::new(w)
}

/// Generate a random token sequence of length in [1, max_len].
pub fn gen_tokens(rng: &mut XorShift128, vocab: usize, max_len: usize) -> Vec<u32> {
    let len = 1 + rng.next_below(max_len as u64) as usize;
    (0..len).map(|_| rng.next_below(vocab as u64) as u32).collect()
}

/// Generate a pair of categoricals with *disjoint* supports on `n ≥ 2`
/// symbols — the coupling edge case where acceptance is impossible and
/// every `p_i = 0 ∨ q_i = 0` branch fires.
pub fn gen_disjoint_pair(rng: &mut XorShift128, n: usize) -> (Categorical, Categorical) {
    assert!(n >= 2);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let cut = 1 + rng.next_below((n - 1) as u64) as usize;
    let mut a = vec![0.0; n];
    let mut b = vec![0.0; n];
    for &i in &idx[..cut] {
        a[i] = -rng.next_f64().ln() + 1e-9;
    }
    for &i in &idx[cut..] {
        b[i] = -rng.next_f64().ln() + 1e-9;
    }
    (Categorical::new(a), Categorical::new(b))
}

/// Chi-square goodness-of-fit statistic of empirical `counts` against the
/// `expected` distribution over `trials` draws. Bins with expected count
/// ≤ 4 are skipped (standard practice for the chi-square approximation);
/// returns `(chi2, dof)` with `dof` = counted bins − 1.
pub fn chi_square_fit(counts: &[usize], expected: &Categorical, trials: usize) -> (f64, usize) {
    assert_eq!(counts.len(), expected.len());
    let mut chi2 = 0.0;
    let mut bins = 0usize;
    for (i, &c) in counts.iter().enumerate() {
        let e = expected.prob(i) * trials as f64;
        if e > 4.0 {
            chi2 += (c as f64 - e).powi(2) / e;
            bins += 1;
        }
    }
    (chi2, bins.saturating_sub(1))
}

/// Generous acceptance threshold for [`chi_square_fit`] at the given
/// degrees of freedom: mean + ~5σ + slack. Deterministic seeds make these
/// tests repeatable, so a crossing indicates a real marginal distortion,
/// not sampling noise.
pub fn chi_square_limit(dof: usize) -> f64 {
    let d = dof.max(1) as f64;
    d + 5.0 * (2.0 * d).sqrt() + 12.0
}

/// Assert the empirical `counts` are chi-square-consistent with `expected`
/// — the workhorse of the statistical conformance suite
/// (`tests/conformance.rs`).
pub fn assert_marginal(label: &str, counts: &[usize], expected: &Categorical, trials: usize) {
    let (chi2, dof) = chi_square_fit(counts, expected, trials);
    let limit = chi_square_limit(dof);
    assert!(
        chi2 <= limit,
        "{label}: chi2 {chi2:.1} > limit {limit:.1} (dof {dof}); counts {counts:?}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_categorical_is_normalized_and_positive() {
        let mut rng = XorShift128::new(1);
        for _ in 0..50 {
            let c = gen_categorical(&mut rng, 17);
            let sum: f64 = c.probs().iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(c.probs().iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn gen_sparse_categorical_has_requested_support() {
        let mut rng = XorShift128::new(2);
        let c = gen_sparse_categorical(&mut rng, 20, 5);
        let nz = c.probs().iter().filter(|&&p| p > 0.0).count();
        assert_eq!(nz, 5);
        assert!((c.probs().iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gen_peaked_low_temperature_concentrates() {
        let mut rng = XorShift128::new(3);
        let hot = gen_peaked_categorical(&mut rng, 50, 2.0);
        let mut rng = XorShift128::new(3);
        let cold = gen_peaked_categorical(&mut rng, 50, 0.1);
        let max_hot = hot.probs().iter().cloned().fold(0.0, f64::max);
        let max_cold = cold.probs().iter().cloned().fold(0.0, f64::max);
        assert!(max_cold > max_hot);
    }

    #[test]
    fn forall_reports_failures() {
        let result = std::panic::catch_unwind(|| {
            forall(
                0,
                16,
                |rng| rng.next_below(100),
                |&x| if x < 95 { Ok(()) } else { Err(format!("x={x} too big")) },
            );
        });
        assert!(result.is_err());
    }

    #[test]
    fn gen_disjoint_pair_supports_do_not_intersect() {
        let mut rng = XorShift128::new(6);
        for _ in 0..50 {
            let (a, b) = gen_disjoint_pair(&mut rng, 13);
            for i in 0..13 {
                assert!(
                    !(a.prob(i) > 0.0 && b.prob(i) > 0.0),
                    "supports intersect at {i}"
                );
            }
            assert!((a.probs().iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!((b.probs().iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn chi_square_fit_flags_distorted_marginals_only() {
        let q = Categorical::new(vec![0.25, 0.25, 0.25, 0.25]);
        let trials = 10_000;
        let good = vec![2510usize, 2470, 2530, 2490];
        let (chi2, dof) = chi_square_fit(&good, &q, trials);
        assert!(chi2 <= chi_square_limit(dof), "chi2 {chi2} over limit");
        let bad = vec![4000usize, 2000, 2000, 2000];
        let (chi2, dof) = chi_square_fit(&bad, &q, trials);
        assert!(chi2 > chi_square_limit(dof), "distortion not flagged: {chi2}");
    }

    #[test]
    fn gen_tokens_within_bounds() {
        let mut rng = XorShift128::new(4);
        for _ in 0..100 {
            let toks = gen_tokens(&mut rng, 64, 12);
            assert!(!toks.is_empty() && toks.len() <= 12);
            assert!(toks.iter().all(|&t| (t as usize) < 64));
        }
    }
}

/// Draft backend that emits a point mass on [`FAULT_MARKER_TOKEN`] for any
/// context containing an (ideally out-of-vocab) `trigger` token, and the
/// wrapped [`SimLm`] otherwise — the standard rig for driving
/// `VerifierKind::FaultInjection` through engines, schedulers, and servers:
/// poisoned *requests* (prompt carries the trigger) panic their verify
/// jobs while every other request drafts honestly.
///
/// [`FAULT_MARKER_TOKEN`]: crate::spec::types::FAULT_MARKER_TOKEN
/// [`SimLm`]: crate::model::sim::SimLm
pub struct PoisonDraft {
    pub inner: crate::model::sim::SimLm,
    pub trigger: u32,
}

impl LmBackend for PoisonDraft {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn next_logits(&mut self, seqs: &[Vec<u32>]) -> Vec<Vec<f32>> {
        let base = self.inner.next_logits(seqs);
        seqs.iter()
            .zip(base)
            .map(|(s, row)| {
                if s.contains(&self.trigger) {
                    let mut l = vec![-1e9f32; row.len()];
                    l[crate::spec::types::FAULT_MARKER_TOKEN as usize] = 0.0;
                    l
                } else {
                    row
                }
            })
            .collect()
    }

    fn span_logits(&mut self, seqs: &[Vec<u32>], start: usize) -> Vec<Vec<Vec<f32>>> {
        self.inner.span_logits(seqs, start)
    }
}

/// Live thread count of this process from `/proc/self/status` (Linux — the
/// CI and container platform). `None` elsewhere or on parse failure; census
/// consumers (the `tests/pool_shared.rs` suite, the `perf_engine` L3e
/// bench, and CI's gate on its JSON output) must treat `None`/sentinel as
/// "skip the census assertion", never as zero threads.
pub fn thread_census() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}
