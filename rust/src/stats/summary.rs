//! Summary statistics used by the benchmark harness and the experiment
//! tables: mean, sample standard deviation, standard error of the mean
//! (paper App. D.1 defines exactly these), and a streaming accumulator.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (the `std` of paper App. D.1, divisor M-1).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean: `std(x) / sqrt(M)` — the paper's error bars.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Mean ± SEM bundle, formatted like the paper's tables.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub sem: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        Self { mean: mean(xs), sem: sem(xs), n: xs.len() }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.2} ± {:.2}", self.mean, self.sem)
    }
}

/// Streaming mean/variance (Welford) plus min/max; used for latency metrics
/// in the coordinator where storing every observation would be wasteful.
#[derive(Clone, Debug)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for OnlineStats {
    fn default() -> Self {
        Self::new()
    }
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (divisor n-1).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let total = n1 + n2;
        self.mean += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket latency histogram with percentile queries (p50/p95/p99).
/// Buckets are exponential: bucket i covers [base*g^i, base*g^(i+1)).
#[derive(Clone, Debug)]
pub struct Histogram {
    base: f64,
    growth: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// Latency histogram from 1µs to ~100s with 5% resolution.
    pub fn latency() -> Self {
        Self::new(1e-6, 1.05, 400)
    }

    pub fn new(base: f64, growth: f64, buckets: usize) -> Self {
        Self { base, growth, counts: vec![0; buckets], total: 0 }
    }

    pub fn record(&mut self, x: f64) {
        let idx = if x <= self.base {
            0
        } else {
            ((x / self.base).ln() / self.growth.ln()) as usize
        };
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile (bucket upper edge); `q` in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return self.base * self.growth.powi(i as i32 + 1);
            }
        }
        self.base * self.growth.powi(self.counts.len() as i32)
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_sem_match_paper_formulas() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        // Sample std of 1..5 is sqrt(2.5).
        assert!((std_dev(&xs) - 2.5f64.sqrt()).abs() < 1e-12);
        assert!((sem(&xs) - 2.5f64.sqrt() / 5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton_edge_cases() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[7.0]), 0.0);
        assert_eq!(sem(&[]), 0.0);
    }

    #[test]
    fn online_stats_agree_with_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 / 7.0).collect();
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-9);
        assert!((o.std_dev() - std_dev(&xs)).abs() < 1e-9);
        assert_eq!(o.count(), 1000);
    }

    #[test]
    fn online_stats_merge_matches_concat() {
        let a: Vec<f64> = (0..500).map(|i| i as f64 * 0.1).collect();
        let b: Vec<f64> = (0..300).map(|i| 100.0 - i as f64).collect();
        let mut oa = OnlineStats::new();
        let mut ob = OnlineStats::new();
        a.iter().for_each(|&x| oa.push(x));
        b.iter().for_each(|&x| ob.push(x));
        oa.merge(&ob);
        let all: Vec<f64> = a.iter().chain(b.iter()).copied().collect();
        assert!((oa.mean() - mean(&all)).abs() < 1e-9);
        assert!((oa.std_dev() - std_dev(&all)).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_bracket_data() {
        let mut h = Histogram::latency();
        // 1ms..100ms uniform-ish.
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4);
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        assert!(p50 > 0.03 && p50 < 0.07, "p50={p50}");
        assert!(p95 > 0.08 && p95 < 0.12, "p95={p95}");
        assert!(h.quantile(1.0) >= p95);
    }

    #[test]
    fn summary_display_formats_like_paper() {
        let s = Summary::of(&[4.7, 4.8, 4.9]);
        assert_eq!(format!("{s}"), "4.80 ± 0.06");
    }
}
