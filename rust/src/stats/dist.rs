//! Scalar distribution transforms used throughout the reproduction.

use super::rng::XorShift128;

/// Exponential(rate) variate from a uniform `u` in (0, 1).
#[inline]
pub fn exponential(u: f64, rate: f64) -> f64 {
    debug_assert!(u > 0.0 && u < 1.0 && rate > 0.0);
    -u.ln() / rate
}

/// Standard Gumbel variate from a uniform `u` in (0, 1).
/// `argmax_i (log p_i + G_i)` with iid Gumbel `G_i` samples from `p` — the
/// classic Gumbel-max trick; GLS uses the equivalent exponential-race form.
#[inline]
pub fn gumbel(u: f64) -> f64 {
    -(-u.ln()).ln()
}

/// A standard normal pair via Box–Muller from two uniforms in (0, 1).
#[inline]
pub fn box_muller(u1: f64, u2: f64) -> (f64, f64) {
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = 2.0 * std::f64::consts::PI * u2;
    (r * theta.cos(), r * theta.sin())
}

/// Sequential standard-normal sampler over an [`XorShift128`] stream.
#[derive(Clone, Debug)]
pub struct NormalSampler {
    rng: XorShift128,
    cached: Option<f64>,
}

impl NormalSampler {
    pub fn new(seed: u64) -> Self {
        Self { rng: XorShift128::new(seed), cached: None }
    }

    pub fn from_rng(rng: XorShift128) -> Self {
        Self { rng, cached: None }
    }

    /// One N(0, 1) draw.
    pub fn next(&mut self) -> f64 {
        if let Some(z) = self.cached.take() {
            return z;
        }
        let (z0, z1) = box_muller(self.rng.next_f64(), self.rng.next_f64());
        self.cached = Some(z1);
        z0
    }

    /// One N(mu, sigma^2) draw.
    pub fn next_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.next()
    }
}

/// Density of N(mu, var) at x.
#[inline]
pub fn normal_pdf(x: f64, mu: f64, var: f64) -> f64 {
    debug_assert!(var > 0.0);
    let d = x - mu;
    (-(d * d) / (2.0 * var)).exp() / (2.0 * std::f64::consts::PI * var).sqrt()
}

/// Log-density of N(mu, var) at x.
#[inline]
pub fn normal_logpdf(x: f64, mu: f64, var: f64) -> f64 {
    let d = x - mu;
    -(d * d) / (2.0 * var) - 0.5 * (2.0 * std::f64::consts::PI * var).ln()
}

/// Draw a categorical sample from unnormalized weights using one uniform.
pub fn categorical_from_weights(weights: &[f64], u: f64) -> usize {
    let total: f64 = weights.iter().sum();
    debug_assert!(total > 0.0);
    let mut acc = 0.0;
    let target = u * total;
    for (i, w) in weights.iter().enumerate() {
        acc += w;
        if target < acc {
            return i;
        }
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_mean_matches_rate() {
        let mut rng = XorShift128::new(3);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| exponential(rng.next_f64(), 2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean = {mean}");
    }

    #[test]
    fn normal_sampler_moments() {
        let mut s = NormalSampler::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| s.next()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.03, "var = {var}");
    }

    #[test]
    fn normal_pdf_integrates_to_one() {
        // Trapezoid over [-8, 8].
        let steps = 4000;
        let h = 16.0 / steps as f64;
        let integral: f64 = (0..=steps)
            .map(|i| {
                let x = -8.0 + i as f64 * h;
                let w = if i == 0 || i == steps { 0.5 } else { 1.0 };
                w * normal_pdf(x, 0.0, 1.0)
            })
            .sum::<f64>()
            * h;
        assert!((integral - 1.0).abs() < 1e-6);
    }

    #[test]
    fn normal_logpdf_consistent_with_pdf() {
        for &x in &[-2.0, -0.5, 0.0, 1.3, 4.0] {
            let p = normal_pdf(x, 0.7, 2.3);
            let lp = normal_logpdf(x, 0.7, 2.3);
            assert!((p.ln() - lp).abs() < 1e-12);
        }
    }

    #[test]
    fn categorical_from_weights_respects_masses() {
        let weights = [1.0, 3.0, 6.0];
        let mut rng = XorShift128::new(5);
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[categorical_from_weights(&weights, rng.next_f64())] += 1;
        }
        let freqs: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
        assert!((freqs[0] - 0.1).abs() < 0.01);
        assert!((freqs[1] - 0.3).abs() < 0.01);
        assert!((freqs[2] - 0.6).abs() < 0.01);
    }

    #[test]
    fn gumbel_max_equals_categorical_sampling() {
        // argmax(log p + G) should follow p.
        let p: [f64; 3] = [0.5, 0.2, 0.3];
        let mut rng = XorShift128::new(23);
        let n = 100_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            let mut best = f64::NEG_INFINITY;
            let mut arg = 0;
            for (i, &pi) in p.iter().enumerate() {
                let g = pi.ln() + gumbel(rng.next_f64());
                if g > best {
                    best = g;
                    arg = i;
                }
            }
            counts[arg] += 1;
        }
        for i in 0..3 {
            assert!((counts[i] as f64 / n as f64 - p[i]).abs() < 0.01);
        }
    }
}
